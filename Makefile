# Tier-1 verification and smoke benchmarks.
#
#   make test         - the tier-1 suite (ROADMAP.md "Tier-1 verify")
#   make test-fast    - same, minus tests marked `slow`
#   make bench-smoke  - dispatch benchmark (writes BENCH_dispatch.json)
#   make bench        - full paper-figure benchmark sweep

PYTHONPATH := src$(if $(PYTHONPATH),:$(PYTHONPATH))
PY := PYTHONPATH=$(PYTHONPATH) python

.PHONY: test test-fast bench-smoke bench

test:
	$(PY) -m pytest -x -q

test-fast:
	$(PY) -m pytest -x -q -m "not slow"

bench-smoke:
	$(PY) benchmarks/bench_dispatch.py

bench:
	$(PY) -m benchmarks.run
