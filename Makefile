# Tier-1 verification and smoke benchmarks.
#
#   make test         - the tier-1 suite (ROADMAP.md "Tier-1 verify"):
#                       static lint (rowlint + docstring lint), then the
#                       mesh dispatch suite, then the rest
#   make lint         - static contract checks: tools/rowlint.py (opcode
#                       registry, stacked-id arithmetic, pool-mutation,
#                       stream-mirror rules) + the docstring lint
#   make test-mesh    - multi-device mesh dispatch tests only (the tests
#                       fork 8-host-device subprocesses themselves; the
#                       exported XLA_FLAGS also covers any future
#                       in-process mesh test)
#   make test-fault   - failure-injection and recovery suite only
#                       (ticket journal replay, checkpoint restore,
#                       FaultPlan scenarios)
#   make test-fast    - tier-1 minus tests marked `slow`
#   make check-docs   - fail if a public core/ or kernels/ symbol lacks a
#                       docstring (tools/check_docs.py)
#   make bench-smoke  - dispatch benchmark (writes BENCH_dispatch.json)
#   make bench-serve  - serve_round CI gate: fails if the fused serving
#                       paths regress above 1.0 launch/round, if
#                       double-buffered burst-admission rounds exceed
#                       1.0 launch/round, if ring/burst decode stops
#                       matching the baseline greedy tokens, or if the
#                       fault_recovery leg stops restoring 1.0
#                       launch/round + bitwise tokens within 2 rounds;
#                       runs bench-traffic first
#   make bench-traffic- serve_traffic CI gate: scheduler churn + QoS
#                       preemption must hold <= 1.0 launch/round, keep
#                       bitwise resume parity, and not regress p99 token
#                       latency > 1.5x vs committed BENCH_dispatch.json
#   make bench-autotune - profiler-driven constant sweep (bucket set,
#                       overlap, staging-ring capacity, delta-signature
#                       bound): writes configs/tuned/<backend>.json,
#                       which the engines load at startup.  The --check
#                       gate (run by bench-serve) fails if a committed
#                       profile regresses us_per_flush vs the defaults
#   make bench        - full paper-figure benchmark sweep

PYTHONPATH := src$(if $(PYTHONPATH),:$(PYTHONPATH))
PY := PYTHONPATH=$(PYTHONPATH) python
MESH_FLAGS := XLA_FLAGS=--xla_force_host_platform_device_count=8

.PHONY: test test-mesh test-fault test-fast lint check-docs bench-smoke bench-serve bench-traffic bench-autotune bench

test: lint test-mesh test-fault
	$(PY) -m pytest -x -q -m "not mesh and not fault"

lint: check-docs
	$(PY) tools/rowlint.py

test-mesh:
	$(MESH_FLAGS) $(PY) -m pytest -x -q -m mesh

test-fault:
	$(PY) -m pytest -x -q -m fault

test-fast:
	$(PY) -m pytest -x -q -m "not slow"

check-docs:
	$(PY) tools/check_docs.py

bench-smoke:
	$(PY) benchmarks/bench_dispatch.py

bench-serve: bench-traffic
	$(PY) benchmarks/bench_autotune.py --check
	$(PY) benchmarks/bench_dispatch.py --serve-smoke

bench-traffic:
	$(PY) benchmarks/bench_dispatch.py --traffic-smoke

bench-autotune:
	$(PY) benchmarks/bench_autotune.py

bench:
	$(PY) -m benchmarks.run
