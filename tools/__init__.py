"""Repo tooling package (check_docs docstring lint, rowlint static
checks) — importable so check_docs REQUIRED_SYMBOLS can pin the rowlint
rule functions by dotted path."""
