"""Docstring lint for the public bulk-movement + serving surface.

Fails (exit 1) when a public symbol in ``repro.core``, ``repro.kernels``,
``repro.models.paged``, or ``repro.launch`` lacks a docstring:
module-level functions and classes, plus public methods defined on public
classes.  "Public" = no leading underscore and defined in the package
itself (re-exports are checked once, at their definition site).

Run via ``make check-docs`` (wired into ``make test``):

    PYTHONPATH=src python tools/check_docs.py
"""
from __future__ import annotations

import importlib
import inspect
import os
import pkgutil
import sys

# make `tools.rowlint` pins resolvable when run as `python
# tools/check_docs.py` (sys.path[0] is tools/, not the repo root)
sys.path.insert(0, os.path.dirname(os.path.dirname(os.path.abspath(
    __file__))))

PACKAGES = ("repro.core", "repro.kernels", "repro.models.paged",
            "repro.launch", "repro.obs")

#: load-bearing public symbols that must EXIST (and hence get linted):
#: guards against the async-stream API surface silently disappearing or
#: moving without a docs/tooling update
REQUIRED_SYMBOLS = (
    "repro.core.stream.CommandStream",
    "repro.core.stream.FlushTicket",
    "repro.core.cmdqueue.space_war_rows",
    "repro.models.paged.pool_partition_spec",
    "repro.core.journal.TicketJournal",
    "repro.checkpoint.pool_checkpoint.PoolCheckpoint",
    "repro.runtime.fault.FaultPlan",
    "repro.kernels.fused_dispatch.add_drain_guard",
    # traffic layer: continuous batching + preemption-by-demotion surface
    "repro.launch.scheduler.RequestScheduler",
    "repro.launch.scheduler.TenantSpec",
    "repro.launch.serve.DemotedSeq",
    "repro.core.stream.CommandStream.adopt",
    "repro.core.rowclone.RowCloneEngine.retire_promotions",
    "repro.core.rowclone.RowCloneEngine.demote_to_spill",
    "repro.core.cow_cache.PagedCoWCache.remap_blocks",
    # bitwise opcodes (Ambit follow-on) + dedup-on-admit surface
    "repro.core.rowclone.RowCloneEngine.memand",
    "repro.core.rowclone.RowCloneEngine.memor",
    "repro.core.rowclone.RowCloneEngine.memnot",
    "repro.core.stream.CommandStream.memand",
    "repro.core.stream.CommandStream.memor",
    "repro.core.stream.CommandStream.memnot",
    "repro.kernels.fused_dispatch.pack_bitwise_src",
    "repro.launch.serve.xor_fold",
    "repro.launch.serve.page_fingerprint",
    "repro.launch.serve.ServingEngine.kv_bytes_live",
    # opcode contract registry + drain sanitizer + rowlint (PR 9): every
    # enqueueing engine verb's CommandStream mirror is pinned (rowlint
    # RC104 cross-checks this list against the engine's call graph)
    "repro.core.opcodes.OpSpec",
    "repro.core.opcodes.opspec",
    "repro.core.opcodes.row_rw",
    "repro.core.opcodes.check_pack_total",
    "repro.core.sanitizer.DrainSanitizer",
    "repro.core.sanitizer.SanitizerReport",
    "repro.core.sanitizer.SanitizerError",
    "repro.core.sanitizer.sanitize_enabled",
    "tools.rowlint.check_opcode_registry",
    "tools.rowlint.check_stacked_ids",
    "tools.rowlint.check_pool_mutation",
    "tools.rowlint.check_verb_mirrors",
    "repro.core.stream.CommandStream.memcopy",
    "repro.core.stream.CommandStream.memcopy_cross",
    "repro.core.stream.CommandStream.meminit",
    "repro.core.stream.CommandStream.materialize_zeros",
    "repro.core.stream.CommandStream.promote_staged",
    "repro.core.stream.CommandStream.demote_to_spill",
    "repro.core.stream.CommandStream.promote_spilled",
    # obs subsystem (telemetry + profiler-driven autotuning): metric
    # registry, the one sanctioned clock, spans, and the tuned-profile
    # startup surface
    "repro.obs.metrics.MetricsRegistry",
    "repro.obs.metrics.registry",
    "repro.obs.metrics.now",
    "repro.obs.metrics.Stopwatch",
    "repro.obs.metrics.summarize",
    "repro.obs.trace.span",
    "repro.obs.trace.FlushTiming",
    "repro.obs.autotune.TunedProfile",
    "repro.obs.autotune.load_profile",
    "repro.obs.autotune.apply_profile",
    "tools.rowlint.check_raw_clocks",
)

#: dataclass-generated or inherited members that need no prose of their own
SKIP_METHODS = {"__init__"}


def iter_modules(pkg_name):
    """Yield (name, module) for a package and its submodules — or just the
    module itself when ``pkg_name`` names a plain module (e.g.
    ``repro.models.paged``).  Namespace packages (no __init__.py, hence no
    module docstring of their own — ``repro.launch``) yield only their
    submodules."""
    pkg = importlib.import_module(pkg_name)
    if not hasattr(pkg, "__path__"):
        yield pkg_name, pkg
        return
    if getattr(pkg, "__file__", None) is not None:
        yield pkg_name, pkg
    for info in pkgutil.iter_modules(pkg.__path__, prefix=pkg_name + "."):
        yield info.name, importlib.import_module(info.name)


def check_symbol(qualname, obj, missing):
    if not (obj.__doc__ and obj.__doc__.strip()):
        missing.append(qualname)


def resolve(qual):
    """Resolve a dotted REQUIRED_SYMBOLS path: import the longest module
    prefix, then getattr the rest — so pins can name methods
    (``module.Class.method``), not just module-level symbols.  Returns
    None when any hop is missing."""
    parts = qual.split(".")
    for i in range(len(parts) - 1, 0, -1):
        try:
            obj = importlib.import_module(".".join(parts[:i]))
        except ImportError:
            continue
        try:
            for attr in parts[i:]:
                obj = inspect.getattr_static(obj, attr)
        except AttributeError:
            return None
        return obj
    return None


def main() -> int:
    missing = []
    for qual in REQUIRED_SYMBOLS:
        obj = resolve(qual)
        if obj is None:
            missing.append(f"{qual} (required symbol missing)")
            continue
        if isinstance(obj, property):
            obj = obj.fget
        elif isinstance(obj, (staticmethod, classmethod)):
            obj = obj.__func__
        check_symbol(qual, obj, missing)
    for pkg in PACKAGES:
        for mod_name, mod in iter_modules(pkg):
            if not (mod.__doc__ and mod.__doc__.strip()):
                missing.append(mod_name)
            for name, obj in vars(mod).items():
                if name.startswith("_"):
                    continue
                if not (inspect.isfunction(obj) or inspect.isclass(obj)):
                    continue
                if getattr(obj, "__module__", None) != mod_name:
                    continue        # re-export; checked where defined
                check_symbol(f"{mod_name}.{name}", obj, missing)
                if inspect.isclass(obj):
                    for mname, meth in vars(obj).items():
                        if mname.startswith("_") or mname in SKIP_METHODS:
                            continue
                        target = meth
                        if isinstance(meth, (staticmethod, classmethod)):
                            target = meth.__func__
                        elif isinstance(meth, property):
                            target = meth.fget
                        if not callable(target):
                            continue
                        check_symbol(f"{mod_name}.{name}.{mname}", target,
                                     missing)
    if missing:
        print("public symbols missing docstrings:")
        for m in sorted(missing):
            print(f"  {m}")
        return 1
    print(f"check-docs: all public {', '.join(PACKAGES)} symbols "
          "documented")
    return 0


if __name__ == "__main__":
    sys.exit(main())
