"""rowlint — AST static checks for the opcode/addressing contracts.

The opcode contract registry (src/repro/core/opcodes.py) is only a single
source of truth while nothing bypasses it.  This linter walks the ASTs of
every module under ``src/repro`` and fails (exit 1) on contract bypasses:

* **RC101 opcode-registry** — an ``OP_*`` identifier with no
  :class:`OpSpec` entry in the registry.  A new opcode must declare its
  contract (arity, operand addressing, staging legality) before any
  source file can reference it.
* **RC102 stacked-id-arithmetic** — raw stacked-id arithmetic
  (``pool * nblk + block`` / ``... * total_blocks + ...``) outside
  ``core/poolspec.py``.  Global ids are built by ``PoolGroup.gid`` /
  ``base()`` and decoded by ``locate()``; hand-rolled arithmetic silently
  breaks when pools stop sharing one block count.
* **RC103 pool-buffer-mutation** — direct assignment into an engine's
  pool buffers (``engine.pools[name] = ...``) outside the engine's own
  dispatch module (``core/rowclone.py``).  Every other byte movement
  must ride the command queue (or carry an explicit waiver where the
  write is a documented out-of-band path, e.g. decode-step jit results).
* **RC104 stream-mirror** — a public ``RowCloneEngine`` verb that
  (transitively) enqueues commands but has no same-named
  ``CommandStream`` mirror, or no ``check_docs.py`` REQUIRED_SYMBOLS pin
  for that mirror.  The async surface must cover every enqueueing verb,
  and the pin keeps it from silently disappearing.
* **RC105 raw-clock** — a raw ``time.time()`` / ``time.perf_counter()``
  / ``time.monotonic()`` (or ``_ns`` variant) call outside
  ``repro/obs``.  All timing rides the obs clock
  (``repro.obs.metrics.now`` / ``Stopwatch`` / ``time_us``) so spans,
  histograms and benchmarks agree on one time source; genuine
  wall-clock-of-day sites (e.g. checkpoint metadata timestamps) carry a
  line waiver.  Unlike the other rules this one also walks
  ``benchmarks/`` and ``examples/`` — ad-hoc bench timing is exactly
  what it exists to catch.

Waive a single line with a trailing ``# rowlint: disable=RC1xx`` comment
(comma-separate several rule ids).  Run from the repo root:

    python tools/rowlint.py [--root DIR]

Wired into ``make lint`` (and hence ``make test``).  The linter is
stdlib-only: the registry is loaded by file path, never through the
``repro`` package, so no jax import is needed.
"""
from __future__ import annotations

import argparse
import ast
import dataclasses
import importlib.util
import pathlib
import re
import sys
from typing import Dict, List, Set

#: method names that put a command on a queue — RC104's enqueue sinks
ENQUEUE_METHODS = {"enqueue", "enqueue_copy", "enqueue_zero"}
#: identifier names whose multiply-add use marks raw stacked-id math
STACK_KEYWORDS = {"nblk", "total_blocks"}
#: the one module allowed to do stacked-id arithmetic (it IS the codec)
STACK_HOME = "core/poolspec.py"
#: modules allowed to assign pool buffers (the dispatch/recovery paths)
POOL_MUTATION_HOME = ("core/rowclone.py",)
#: ``time`` module callables RC105 bans outside the obs subsystem
TIME_FUNCS = {"time", "perf_counter", "perf_counter_ns", "monotonic",
              "monotonic_ns"}

_OP_NAME = re.compile(r"^OP_[A-Z0-9_]+$")
_WAIVER = re.compile(r"#\s*rowlint:\s*disable=([A-Z0-9, ]+)")


@dataclasses.dataclass(frozen=True)
class Violation:
    """One lint finding: rule id, file, line, and what went wrong."""

    rule: str
    path: str
    line: int
    message: str

    def __str__(self) -> str:
        return f"{self.path}:{self.line}: {self.rule} {self.message}"


def load_registry_constants(root: pathlib.Path) -> Set[str]:
    """Load the ``OP_*`` constant names of the opcode registry by FILE
    path (``src/repro/core/opcodes.py``) — stdlib-only, so the linter
    never imports the jax-heavy ``repro`` package."""
    path = root / "src" / "repro" / "core" / "opcodes.py"
    spec = importlib.util.spec_from_file_location("_rowlint_opcodes", path)
    mod = importlib.util.module_from_spec(spec)
    # dataclass processing resolves the defining module through
    # sys.modules, so register before exec
    sys.modules[spec.name] = mod
    try:
        spec.loader.exec_module(mod)
    finally:
        sys.modules.pop(spec.name, None)
    return set(mod.CONSTANT_NAMES)


def line_waivers(source: str) -> Dict[int, Set[str]]:
    """Per-line rule waivers from ``# rowlint: disable=...`` comments."""
    out: Dict[int, Set[str]] = {}
    for i, line in enumerate(source.splitlines(), start=1):
        m = _WAIVER.search(line)
        if m:
            out[i] = {r.strip() for r in m.group(1).split(",") if r.strip()}
    return out


def _terminal_name(node) -> str:
    if isinstance(node, ast.Name):
        return node.id
    if isinstance(node, ast.Attribute):
        return node.attr
    return ""


def check_opcode_registry(tree: ast.AST, rel: str,
                          constants: Set[str]) -> List[Violation]:
    """RC101: every ``OP_*`` identifier (name or attribute) must be a
    registered constant of the core/opcodes.py :data:`OPCODES` registry —
    an opcode used before its contract is declared fails the lint."""
    out = []
    for node in ast.walk(tree):
        name = _terminal_name(node)
        if _OP_NAME.match(name) and name not in constants:
            out.append(Violation(
                "RC101", rel, node.lineno,
                f"opcode constant {name} has no OpSpec entry in the "
                "core/opcodes.py registry — declare its contract first"))
    return out


def check_stacked_ids(tree: ast.AST, rel: str) -> List[Violation]:
    """RC102: raw stacked-id arithmetic (a multiply by ``nblk`` /
    ``total_blocks`` inside an addition) is only legal in
    ``core/poolspec.py`` — everywhere else global ids go through the
    PoolGroup's ``gid``/``base``/``locate`` codec."""
    if rel.endswith(STACK_HOME):
        return []
    out = []
    for node in ast.walk(tree):
        if not (isinstance(node, ast.BinOp)
                and isinstance(node.op, ast.Add)):
            continue
        for side in (node.left, node.right):
            if isinstance(side, ast.BinOp) and \
                    isinstance(side.op, ast.Mult) and \
                    any(_terminal_name(x) in STACK_KEYWORDS
                        for x in (side.left, side.right)):
                out.append(Violation(
                    "RC102", rel, node.lineno,
                    "raw stacked-id arithmetic (`pool * nblk + block`); "
                    "build global ids with PoolGroup.gid()/base() "
                    "(core/poolspec.py) instead"))
    return out


def check_pool_mutation(tree: ast.AST, rel: str) -> List[Violation]:
    """RC103: assignment into a pool buffer (``<x>.pools[...] = ...``)
    outside the engine's own dispatch module — all other bulk movement
    must ride the command queue, or carry an explicit line waiver at a
    documented out-of-band write site."""
    if any(rel.endswith(h) for h in POOL_MUTATION_HOME):
        return []
    out = []
    for node in ast.walk(tree):
        if isinstance(node, ast.Assign):
            targets = node.targets
        elif isinstance(node, ast.AugAssign):
            targets = [node.target]
        else:
            continue
        for t in targets:
            # only attribute access (`engine.pools[...]`) marks an
            # engine-owned buffer; a bare local dict named `pools` (e.g.
            # pool construction helpers) is not a mutation of live state
            if isinstance(t, ast.Subscript) and \
                    isinstance(t.value, ast.Attribute) and \
                    t.value.attr == "pools":
                out.append(Violation(
                    "RC103", rel, node.lineno,
                    "direct pool-buffer mutation bypasses the command "
                    "queue (enqueue through the engine, or waive a "
                    "documented out-of-band write)"))
    return out


def check_raw_clocks(tree: ast.AST, rel: str) -> List[Violation]:
    """RC105: raw ``time.*`` clock calls outside ``repro/obs`` — timing
    goes through the obs clock (``repro.obs.metrics.now``/``Stopwatch``/
    ``time_us``) so engine spans, metric histograms and benchmark
    readouts share one time source.  Waive genuine time-of-day sites
    (checkpoint metadata) with ``# rowlint: disable=RC105``."""
    if "/obs/" in rel.replace("\\", "/"):
        return []
    out = []
    for node in ast.walk(tree):
        if isinstance(node, ast.Call) and \
                isinstance(node.func, ast.Attribute) and \
                node.func.attr in TIME_FUNCS and \
                isinstance(node.func.value, ast.Name) and \
                node.func.value.id == "time":
            out.append(Violation(
                "RC105", rel, node.lineno,
                f"raw time.{node.func.attr}() bypasses the obs clock; "
                "use repro.obs.metrics (now/Stopwatch/time_us) or waive "
                "a documented time-of-day site"))
    return out


def _class_methods(tree: ast.AST, cls_name: str) -> Dict[str,
                                                         ast.FunctionDef]:
    for node in ast.walk(tree):
        if isinstance(node, ast.ClassDef) and node.name == cls_name:
            return {n.name: n for n in node.body
                    if isinstance(n, ast.FunctionDef)}
    return {}


def check_verb_mirrors(root: pathlib.Path) -> List[Violation]:
    """RC104: every public ``RowCloneEngine`` method that transitively
    enqueues commands (reaches ``enqueue``/``enqueue_copy``/
    ``enqueue_zero`` through self-calls) must have a same-named
    ``CommandStream`` mirror AND a ``REQUIRED_SYMBOLS`` pin
    (``repro.core.stream.CommandStream.<verb>``) in
    ``tools/check_docs.py`` — the async surface covers every verb, and
    the pin stops a mirror from silently vanishing."""
    src = root / "src" / "repro" / "core"
    eng_rel = "src/repro/core/rowclone.py"
    eng_tree = ast.parse((src / "rowclone.py").read_text())
    methods = _class_methods(eng_tree, "RowCloneEngine")
    direct: Set[str] = set()
    calls: Dict[str, Set[str]] = {}
    for name, fn in methods.items():
        calls[name] = set()
        for node in ast.walk(fn):
            if not (isinstance(node, ast.Call)
                    and isinstance(node.func, ast.Attribute)):
                continue
            if node.func.attr in ENQUEUE_METHODS:
                direct.add(name)
            if isinstance(node.func.value, ast.Name) and \
                    node.func.value.id == "self" and \
                    node.func.attr in methods:
                calls[name].add(node.func.attr)
    reaching = set(direct)
    changed = True
    while changed:
        changed = False
        for name, callees in calls.items():
            if name not in reaching and callees & reaching:
                reaching.add(name)
                changed = True

    stream_tree = ast.parse((src / "stream.py").read_text())
    mirrors = set(_class_methods(stream_tree, "CommandStream"))
    docs_tree = ast.parse((root / "tools" / "check_docs.py").read_text())
    pins: Set[str] = set()
    for node in ast.walk(docs_tree):
        if isinstance(node, ast.Assign) and any(
                isinstance(t, ast.Name) and t.id == "REQUIRED_SYMBOLS"
                for t in node.targets):
            for c in ast.walk(node.value):
                if isinstance(c, ast.Constant) and isinstance(c.value, str):
                    pins.add(c.value)

    out = []
    for verb in sorted(reaching):
        if verb.startswith("_"):
            continue
        line = methods[verb].lineno
        if verb not in mirrors:
            out.append(Violation(
                "RC104", eng_rel, line,
                f"engine verb {verb!r} enqueues commands but has no "
                "CommandStream mirror (core/stream.py)"))
        pin = f"repro.core.stream.CommandStream.{verb}"
        if pin not in pins:
            out.append(Violation(
                "RC104", eng_rel, line,
                f"engine verb {verb!r} has no check_docs pin {pin!r} in "
                "tools/check_docs.py REQUIRED_SYMBOLS"))
    return out


def lint(root: pathlib.Path) -> List[Violation]:
    """Run every rule over ``<root>/src/repro``; returns the surviving
    (un-waived) violations, sorted by file and line."""
    constants = load_registry_constants(root)
    violations: List[Violation] = []
    pkg = root / "src" / "repro"
    for path in sorted(pkg.rglob("*.py")):
        rel = str(path.relative_to(root))
        source = path.read_text()
        tree = ast.parse(source, filename=rel)
        waived = line_waivers(source)
        found = (check_opcode_registry(tree, rel, constants)
                 + check_stacked_ids(tree, rel)
                 + check_pool_mutation(tree, rel)
                 + check_raw_clocks(tree, rel))
        violations += [v for v in found
                       if v.rule not in waived.get(v.line, ())]
    # benchmarks/ and examples/ are outside the package but are exactly
    # where ad-hoc wall-clock timing accumulates — RC105 only
    for extra in ("benchmarks", "examples"):
        d = root / extra
        if not d.is_dir():
            continue
        for path in sorted(d.rglob("*.py")):
            rel = str(path.relative_to(root))
            source = path.read_text()
            tree = ast.parse(source, filename=rel)
            waived = line_waivers(source)
            violations += [v for v in check_raw_clocks(tree, rel)
                           if v.rule not in waived.get(v.line, ())]
    violations += check_verb_mirrors(root)
    return sorted(violations, key=lambda v: (v.path, v.line, v.rule))


def main(argv=None) -> int:
    """CLI entry: lint the tree, print violations, exit 1 on any."""
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--root", default=None,
                    help="repo root (default: the linter's grandparent "
                         "directory)")
    args = ap.parse_args(argv)
    root = pathlib.Path(args.root) if args.root else \
        pathlib.Path(__file__).resolve().parent.parent
    violations = lint(root)
    for v in violations:
        print(v)
    if violations:
        print(f"rowlint: {len(violations)} violation(s)")
        return 1
    print("rowlint: clean (RC101 opcode-registry, RC102 stacked-ids, "
          "RC103 pool-mutation, RC104 stream-mirror, RC105 raw-clock)")
    return 0


if __name__ == "__main__":
    sys.exit(main())
