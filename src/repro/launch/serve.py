"""Serving engine: continuous batched decode over a RowClone-managed pool.

The serving loop is the paper's application showcase:

* admission (``add_request``) — prefill runs on a staging layout, then the
  staged KV pages move into allocator-chosen pool blocks via the engine's
  **memcopy** (FPM: same-slab DMA; this is the CPU→"process address space"
  copy that RowClone §3.2 accelerates);
* ``fork`` — parallel sampling / beam search shares every prompt page by
  refcount (zero bytes), CoW-splitting lazily on the first divergent append;
* fresh pages are BuZ-lazy-zeroed (ZI metadata bit);
* each decode step runs one jit'd ``model.decode_step`` over the shared
  pool with the cache's device tables.

CLI:  PYTHONPATH=src python -m repro.launch.serve --arch llama3.2-3b \
          --smoke --requests 8 --steps 32 --fork 2
"""
from __future__ import annotations

import argparse
import time
from typing import Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import RowCloneConfig, get_config
from repro.core import PagedCoWCache, RowCloneEngine, SubarrayAllocator
from repro.launch.mesh import pool_shard_count
from repro.models import build_model, split_params


class ServingEngine:
    def __init__(self, cfg, params, mesh=None, max_seqs: int = 16,
                 max_blocks_per_seq: int = 64, num_slabs: int = 4,
                 rc: Optional[RowCloneConfig] = None, impl: str = "ref"):
        self.cfg = cfg
        self.rc = rc or RowCloneConfig()
        self.mesh = mesh
        self.impl = impl
        self.model = build_model(cfg, self.rc)
        self.params = params
        page = self.rc.page_size
        L = cfg.num_attn_layers
        nblk = max_seqs * max_blocks_per_seq
        # pool must tile both the allocator slabs and the mesh's device
        # shards — the sharded fused dispatch partitions by device shard
        align = int(np.lcm(num_slabs, pool_shard_count(mesh)))
        nblk = -(-nblk // align) * align
        kv_dtype = jnp.bfloat16 if cfg.dtype == "bfloat16" else jnp.float32
        shape = (L, nblk, page, cfg.num_kv_heads, cfg.head_dim)
        alloc = SubarrayAllocator(nblk, num_slabs,
                                  reserved_zero_per_slab=self.rc
                                  .zero_blocks_per_slab)
        # the engine sees the mesh: every decode round's CoW splits + tail
        # inits drain as ONE shard_map'd collective launch at the flush
        # boundary (the seed pinned the serving engine to mesh=None)
        self.engine = RowCloneEngine(
            {"k": jnp.zeros(shape, kv_dtype), "v": jnp.zeros(shape, kv_dtype)},
            alloc, mesh=mesh, enable_fpm=self.rc.enable_fpm,
            enable_psm=self.rc.enable_psm, enable_zi=self.rc.enable_zi,
            block_axis=1)
        self.cache = PagedCoWCache(self.engine, page, max_blocks_per_seq,
                                   max_seqs)
        self.last_logits: Dict[int, np.ndarray] = {}
        self.tokens: Dict[int, List[int]] = {}
        self._decode_jit = jax.jit(self._decode_fn, donate_argnums=(1, 2))

    # ------------------------------------------------------------------
    def add_request(self, prompt: np.ndarray) -> int:
        """prompt: (S,) int32.  Prefill + stage pages into the pool."""
        S = int(prompt.shape[0])
        page = self.rc.page_size
        sid = self.cache.new_sequence(prompt_len=S)
        batch = {"tokens": jnp.asarray(prompt[None, :])}
        if self.cfg.family == "vlm":
            batch["patch_embeds"] = jnp.zeros(
                (1, self.cfg.vision_tokens, self.cfg.d_model), jnp.float32)
        if self.cfg.family == "encdec":
            batch["src_embeds"] = jnp.zeros(
                (1, max(S // self.cfg.src_frames_ratio, 1),
                 self.cfg.d_model), jnp.float32)
        logits, st = self.model.prefill(self.params, batch, self.mesh,
                                        margin_tokens=0)
        # stage prefill pages into allocator-assigned blocks (FPM memcopy)
        blocks = self.cache.blocks_of(sid)
        nper = len(blocks)
        staging_k = st["k_pools"]  # (L, nper, page, KVH, D)
        staging_v = st["v_pools"]
        dst = np.asarray(blocks, np.int32)
        self.engine.alloc.mark_written(blocks)
        kpool = self.engine.pools["k"]
        vpool = self.engine.pools["v"]
        self.engine.pools["k"] = _stage_jit(kpool, staging_k, jnp.asarray(dst))
        self.engine.pools["v"] = _stage_jit(vpool, staging_v, jnp.asarray(dst))
        self.last_logits[sid] = np.asarray(logits[0])
        self.tokens[sid] = [int(t) for t in prompt]
        # extra per-seq state (ssm/hybrid/encdec) kept host-side per slot
        self._store_extra_state(sid, st)
        return sid

    def _store_extra_state(self, sid, st):
        extras = {}
        for k in ("conv_state", "ssm_state", "cross_k", "cross_v"):
            if k in st:
                extras[k] = st[k]
        if extras:
            if not hasattr(self, "_extras"):
                self._extras = {}
            self._extras[sid] = extras

    def fork(self, sid: int, n: int) -> List[int]:
        kids = self.cache.fork(sid, n)
        for c in kids:
            self.last_logits[c] = self.last_logits[sid].copy()
            self.tokens[c] = list(self.tokens[sid])
            if hasattr(self, "_extras") and sid in self._extras:
                self._extras[c] = self._extras[sid]
        return kids

    def free(self, sid: int) -> None:
        self.cache.free_sequence(sid)
        self.last_logits.pop(sid, None)
        self.tokens.pop(sid, None)

    # ------------------------------------------------------------------
    def _decode_fn(self, params, k_pools, v_pools, table, mask, base,
                   seq_lens, tokens, slot_index):
        state = {"k_pools": k_pools, "v_pools": v_pools,
                 "block_table": table, "share_mask": mask, "base": base,
                 "seq_lens": seq_lens}
        logits, st = self.model.decode_step(params, state, tokens, self.mesh,
                                            impl=self.impl)
        return logits, st["k_pools"], st["v_pools"]

    def decode_round(self, sample_fn=None) -> Dict[int, int]:
        """One token for every live sequence (greedy by default)."""
        if self.cfg.family not in ("dense", "moe", "vlm"):
            raise NotImplementedError(
                "CLI decode loop demo targets decoder-only archs; other "
                "families decode through model.decode_step directly")
        live = sorted(self.cache.seqs)
        if not live:
            return {}
        # choose next token per sequence from last logits
        next_tok = {}
        for sid in live:
            lg = self.last_logits[sid]
            t = int(np.argmax(lg)) if sample_fn is None else sample_fn(lg)
            next_tok[sid] = t
        # CoW/allocation happens BEFORE the jit step (host metadata); all
        # CoW splits + tail-block inits for the round drain as ONE fused
        # launch at the attention-step flush boundary
        self.cache.append_tokens(live)
        table, mask, base = self.cache.device_tables()
        lens = self.cache.seq_lens()
        B = self.cache.max_seqs
        toks = np.zeros((B,), np.int32)
        seq_lens_dev = np.zeros((B,), np.int32)
        for sid in live:
            slot = self.cache.slot_of(sid)
            toks[slot] = next_tok[sid]
            # decode_step's pos = state.seq_lens = position of new token
            seq_lens_dev[slot] = self.cache.seqs[sid].length - 1
        logits, kp, vp = self._decode_jit(
            self.params, self.engine.pools["k"], self.engine.pools["v"],
            table, mask, base, jnp.asarray(seq_lens_dev), jnp.asarray(toks),
            None)
        self.engine.pools["k"] = kp
        self.engine.pools["v"] = vp
        logits = np.asarray(logits)
        for sid in live:
            slot = self.cache.slot_of(sid)
            self.last_logits[sid] = logits[slot]
            self.tokens[sid].append(next_tok[sid])
        return next_tok


@jax.jit
def _stage_jit(pool, staging, dst_ids):
    """Move staged prefill pages (L, nper, ...) into pool blocks (L, nblk,
    ...) — the FPM-cross path (same-device DMA, no compute)."""
    safe = jnp.where(dst_ids >= 0, dst_ids, pool.shape[1])
    return pool.at[:, safe].set(staging.astype(pool.dtype), mode="drop")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama3.2-3b")
    ap.add_argument("--requests", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--steps", type=int, default=16)
    ap.add_argument("--fork", type=int, default=0)
    ap.add_argument("--smoke", action="store_true", default=True)
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if args.smoke:
        cfg = cfg.reduced()
    model = build_model(cfg)
    params, _ = split_params(model.init_params(jax.random.key(0)))
    eng = ServingEngine(cfg, params, max_seqs=max(args.requests * 4, 8))
    rng = np.random.default_rng(0)
    sids = []
    for i in range(args.requests):
        p = rng.integers(2, cfg.vocab_size, size=args.prompt_len)
        sid = eng.add_request(p.astype(np.int32))
        sids.append(sid)
        print(f"[serve] admitted seq {sid} ({args.prompt_len} tokens)")
    if args.fork:
        kids = eng.fork(sids[0], args.fork)
        print(f"[serve] forked seq {sids[0]} -> {kids} "
              f"(CoW shares: {eng.engine.alloc.stats.cow_shares})")
    t0 = time.time()
    for step in range(args.steps):
        eng.decode_round()
    dt = time.time() - t0
    n_live = len(eng.cache.seqs)
    print(f"[serve] {args.steps} rounds x {n_live} seqs in {dt:.2f}s "
          f"({args.steps * n_live / dt:.1f} tok/s)")
    s = eng.engine.stats
    print(f"[serve] rowclone: fpm={s.fpm_copies} psm={s.psm_copies} "
          f"alias={s.alias_copies} lazy-zero={s.zero_lazy} "
          f"bytes_avoided={s.bytes_avoided}")


if __name__ == "__main__":
    main()
