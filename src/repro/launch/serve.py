"""Serving engine: continuous batched decode over a RowClone-managed pool.

The serving loop is the paper's application showcase:

* admission (``add_request``) — the prefill forward writes its KV pages
  directly into the engine's **staging pools** (inside the prefill jit —
  no separate staging dispatch), and the stage→KV-pool promotion enqueues
  ``OP_CROSS_POOL_COPY`` commands into the engine's command queue (this is
  the CPU→"process address space" copy that RowClone §3.2 accelerates,
  expressed as the GS-DRAM-style pool→pool transfer);
* ``fork`` — parallel sampling / beam search shares every prompt page by
  refcount (zero bytes), CoW-splitting lazily on the first divergent append;
* fresh pages are BuZ-lazy-zeroed (ZI metadata bit);
* each decode round drains the engine's **serve CommandStream** ONCE —
  promotions + CoW splits + tail inits are captured onto the stream
  (``stream.capture()``) and ride one fused launch at ``stream.flush()``,
  whose :class:`~repro.core.stream.FlushTicket` is kept in
  ``last_ticket`` — then runs one jit'd ``model.decode_step`` over the
  shared pool with the cache's device tables.  Under a mesh the batch
  shards over (pod, data) whenever the cache can pin each sequence's
  blocks in its group's slabs (``batch_shard_count``); the flush is one
  collective launch either way.

Staging sizing is policy-derived: ``max_admit_pages=None`` sizes the ring
at ``admissions_per_round x max_blocks_per_seq`` (the most pages an
in-policy round can park); ``double_buffer=True`` doubles the slots into
a live + shadow half, so admission bursts past the ring's nominal
capacity land in the shadow half while the live half's promotions are
still queued (their slots carry pending READS — the command queues'
source-hazard tracking) and the round still drains as ONE launch.
``max_admit_pages=ServingEngine.FULL_TWIN`` keeps the seed's full-size
staging twins.

``fused_staging=False`` restores the seed's ``_stage_legacy`` path (one
ad-hoc gather/scatter dispatch per pool per admission, KV pools written
directly) for A/B benchmarking — ``benchmarks/bench_dispatch.py
serve_round`` and the staging parity suite drive both.

CLI:  PYTHONPATH=src python -m repro.launch.serve --arch llama3.2-3b \
          --smoke --requests 8 --steps 32 --fork 2
"""
from __future__ import annotations

import argparse
import time
from typing import Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import CheckpointManager, PoolCheckpoint
from repro.configs import RowCloneConfig, get_config
from repro.core import PagedCoWCache, RowCloneEngine, SubarrayAllocator
from repro.core.journal import RecoveryReport
from repro.kernels.fused_dispatch import notify_launch
from repro.launch.mesh import pool_shard_count
from repro.models import build_model, split_params
from repro.models.paged import batch_shard_count, make_serving_pools


class ServingEngine:
    """Continuous-batching serving facade over RowCloneEngine +
    PagedCoWCache: admission (prefill + staged promotion), CoW fork, and
    greedy decode rounds whose bulk movement drains as one fused launch."""

    #: ``max_admit_pages`` sentinel: keep full-size staging twins (every
    #: KV block has a staging slot) instead of a recycled ring
    FULL_TWIN = 0

    def __init__(self, cfg, params, mesh=None, max_seqs: int = 16,
                 max_blocks_per_seq: int = 64, num_slabs: int = 4,
                 rc: Optional[RowCloneConfig] = None, impl: str = "ref",
                 fused_staging: bool = True,
                 max_admit_pages: Optional[int] = None,
                 admissions_per_round: int = 1,
                 double_buffer: bool = False,
                 fault_plan=None, auto_recover: bool = False,
                 ckpt_pages: int = 0, ckpt_dir: Optional[str] = None,
                 ckpt_window: Optional[int] = None):
        """``max_admit_pages`` sizes the staging pools as a RING of that
        many slots instead of a full-size twin of the KV pools — slots
        recycle at every round's flush, so the ring only needs to hold
        the pages admitted between two flushes.  ``None`` (default)
        DERIVES the size from the admission policy:
        ``admissions_per_round x max_blocks_per_seq`` (the most pages an
        in-policy round can park); :data:`FULL_TWIN` (0) keeps the seed's
        full twin.  A ring of a few blocks cuts the engine's resident
        pool bytes by ~2x at unchanged round latency and bitwise-identical
        decode (BENCH_dispatch.json serve_round).

        ``double_buffer=True`` doubles the ring into live + shadow
        halves: admissions bursting past the nominal ring capacity park
        in the shadow half while the live half's promotions are still
        queued on the serve stream (pending source reads guard those
        slots), keeping burst rounds at 1.0 bulk-movement launches
        instead of forcing an early drain.

        Under a mesh a ring that does not divide the pool shard count is
        REPLICATED (``PoolSpec.sharding == ()`` — held whole on every
        device) rather than rounded up; sharded rings partition like
        their KV twins.

        Fault tolerance: ``ckpt_pages > 0`` adds spill pools of that many
        blocks and a background :class:`PoolCheckpoint` driven one window
        per decode round (``ckpt_dir`` names the checkpoint directory);
        ``fault_plan`` installs a
        :class:`~repro.runtime.fault.FaultPlan`'s injections against this
        engine; ``auto_recover=True`` catches a failed round flush (or
        ckpt tick) and runs :meth:`recover` in place — the next round
        serves normally.  Admissions evicted by a recovery land in
        ``evicted_sids`` for the caller to re-admit."""
        self.cfg = cfg
        self.rc = rc or RowCloneConfig()
        self.mesh = mesh
        self.impl = impl
        self.model = build_model(cfg, self.rc)
        self.params = params
        self.fused_staging = fused_staging
        self.double_buffer = double_buffer
        page = self.rc.page_size
        L = cfg.num_attn_layers
        nblk = max_seqs * max_blocks_per_seq
        # pool must tile both the allocator slabs and the mesh's device
        # shards — the sharded fused dispatch partitions by device shard
        shards = pool_shard_count(mesh)
        align = int(np.lcm(num_slabs, shards))
        nblk = -(-nblk // align) * align
        if max_admit_pages is None:
            # admission-policy derivation: the ring must hold one round's
            # worth of staged pages (kwarg stays as an explicit override)
            max_admit_pages = admissions_per_round * max_blocks_per_seq
        replicate_staging = False
        if max_admit_pages == self.FULL_TWIN:
            stage_nblk = nblk          # full twin (seed sizing)
            self.ring_capacity = nblk
        else:
            self.ring_capacity = int(max_admit_pages)
            stage_nblk = int(max_admit_pages) * (2 if double_buffer else 1)
            if stage_nblk % shards:
                replicate_staging = True   # whole ring on every device
        kv_dtype = jnp.bfloat16 if cfg.dtype == "bfloat16" else jnp.float32
        alloc = SubarrayAllocator(nblk, num_slabs,
                                  reserved_zero_per_slab=self.rc
                                  .zero_blocks_per_slab)
        # K/V pools + staging pools are ONE PoolGroup (models/paged.py):
        # per-pool block counts in the group's prefix-sum address space,
        # so the (possibly much smaller) staging ring rides the same
        # fused launch.  The engine sees the mesh: every decode round's
        # promotions + CoW splits + tail inits drain as ONE (collective)
        # launch at the round's flush boundary
        self.ckpt_pages = int(ckpt_pages)
        replicate_ckpt = bool(self.ckpt_pages % shards) if self.ckpt_pages \
            else False
        pools, group = make_serving_pools(
            L, nblk, page, cfg.num_kv_heads, cfg.head_dim, kv_dtype,
            staging=fused_staging, stage_nblk=stage_nblk,
            replicate_staging=replicate_staging,
            ckpt_nblk=self.ckpt_pages, replicate_ckpt=replicate_ckpt)
        if mesh is not None:
            # honor each PoolSpec's sharding hint at placement time
            # (replicated rings stay whole per device; KV pools shard)
            from repro.launch.mesh import tree_shardings
            shardings = tree_shardings(
                mesh, pools, {n: group[n] for n in pools}, block_axis=1)
            pools = {n: jax.device_put(a, shardings[n])
                     for n, a in pools.items()}
        self.engine = RowCloneEngine(
            pools, alloc, mesh=mesh, enable_fpm=self.rc.enable_fpm,
            enable_psm=self.rc.enable_psm, enable_zi=self.rc.enable_zi,
            block_axis=1, group=group)
        # shard the decode batch over (pod, data) when the cache can pin
        # each sequence's blocks inside its batch group's slabs; otherwise
        # keep global share-mask columns (replicated batch — paged.py)
        dp = batch_shard_count(mesh, max_seqs)
        if dp > 1 and (num_slabs % dp or nblk % dp):
            dp = 1
        self.cache = PagedCoWCache(self.engine, page, max_blocks_per_seq,
                                   max_seqs, batch_groups=dp)
        self.last_logits: Dict[int, np.ndarray] = {}
        self.tokens: Dict[int, List[int]] = {}
        self._decode_jit = jax.jit(self._decode_fn, donate_argnums=(1, 2))
        # the staging pools ARE donated: a failure inside the donated call
        # kills buffers still holding earlier admissions' un-promoted
        # pages, and recover() handles exactly that — it resurrects the
        # staging ring and evicts the affected admissions (evicted_sids)
        # for re-admission.  Donation closes the seed-era extra copy the
        # un-donated scatter paid per admission.
        self._prefill_stage_jit = jax.jit(self._prefill_stage_fn,
                                          donate_argnums=(2, 3))
        # the round's bulk movement lives on a dedicated CommandStream:
        # admissions/forks CAPTURE their promotions and CoW work onto it,
        # and decode_round's stream.flush() drains everything as one
        # launch, returning the FlushTicket kept in ``last_ticket``
        self.stream = self.engine.stream("serve")
        self.last_ticket = None
        self.auto_recover = auto_recover
        self.fault_plan = fault_plan
        if fault_plan is not None:
            fault_plan.install(self.engine)
        #: admissions whose stage→KV promotions have not drained yet —
        #: recovery evicts exactly these when the staged bytes are lost
        self._staged_sids: List[int] = []
        #: sequences a recovery evicted; the caller re-admits their
        #: prompts (re-admission reproduces the KV bytes, so greedy
        #: tokens match the failure-free run)
        self.evicted_sids: List[int] = []
        self._admission_ordinal = 0
        self.last_recovery: Optional[RecoveryReport] = None
        self.pool_ckpt: Optional[PoolCheckpoint] = None
        if self.ckpt_pages:
            if ckpt_dir is None:
                raise ValueError("ckpt_pages > 0 needs ckpt_dir")
            self.pool_ckpt = PoolCheckpoint(
                self.engine, CheckpointManager(ckpt_dir),
                window=ckpt_window)

    # ------------------------------------------------------------------
    def _prefill_batch(self, prompt: np.ndarray) -> Dict[str, jnp.ndarray]:
        S = int(prompt.shape[0])
        batch = {"tokens": jnp.asarray(prompt[None, :])}
        if self.cfg.family == "vlm":
            batch["patch_embeds"] = jnp.zeros(
                (1, self.cfg.vision_tokens, self.cfg.d_model), jnp.float32)
        if self.cfg.family == "encdec":
            batch["src_embeds"] = jnp.zeros(
                (1, max(S // self.cfg.src_frames_ratio, 1),
                 self.cfg.d_model), jnp.float32)
        return batch

    def _prefill_stage_fn(self, params, batch, k_stage, v_stage, stage_ids):
        """Prefill forward + scatter of the prompt's KV pages into the
        staging pools, ONE jit: the staged write costs no extra dispatch,
        and the only bulk movement left (staging→KV promotion) goes
        through the command queue."""
        logits, st = self.model.prefill(params, batch, self.mesh,
                                        margin_tokens=0)
        safe = jnp.where(stage_ids >= 0, stage_ids, k_stage.shape[1])
        k_stage = k_stage.at[:, safe].set(
            st["k_pools"].astype(k_stage.dtype), mode="drop")
        v_stage = v_stage.at[:, safe].set(
            st["v_pools"].astype(v_stage.dtype), mode="drop")
        extras = {k: st[k] for k in ("conv_state", "ssm_state",
                                     "cross_k", "cross_v") if k in st}
        return logits, k_stage, v_stage, extras

    def add_request(self, prompt: np.ndarray) -> int:
        """prompt: (S,) int32.  Prefill into the staging pools and enqueue
        the stage→KV promotion (fused path), or scatter straight into the
        KV pools (seed ``fused_staging=False`` path)."""
        S = int(prompt.shape[0])
        if self.fused_staging:
            # any block inits the admission needs (e.g. ZI disabled) ride
            # the serve stream with the round's other bulk movement
            with self.stream.capture():
                sid = self.cache.new_sequence(prompt_len=S)
        else:
            sid = self.cache.new_sequence(prompt_len=S)
        batch = self._prefill_batch(prompt)
        blocks = self.cache.blocks_of(sid)
        if self.fused_staging:
            ordinal = self._admission_ordinal
            self._admission_ordinal += 1
            stage_ids = self.engine.stage_blocks(len(blocks))
            try:
                if self.fault_plan is not None:
                    # injection point for donation errors: fires AFTER the
                    # slots are reserved, simulating the prefill's donated
                    # staging buffers dying mid-call
                    self.fault_plan.check_admission(ordinal, self.engine)
                logits, k_stage, v_stage, extras = self._prefill_stage_jit(
                    self.params, batch, self.engine.pools["k_stage"],
                    self.engine.pools["v_stage"],
                    jnp.asarray(np.asarray(stage_ids, np.int32)))
            except Exception:
                # failed admission must not strand its staging slots.  The
                # staging pools are DONATED into the prefill call, so a
                # failure may have consumed them — then this admission
                # (and any earlier ones with queued promotions) lost its
                # staged bytes: evict it, and recover in place when asked
                self.engine.release_stage_blocks(stage_ids)
                dead = any(
                    getattr(self.engine.pools[n], "is_deleted",
                            lambda: False)()
                    for n in self.engine.staging)
                if dead:
                    self.free(sid)
                    self.evicted_sids.append(sid)
                    if self.auto_recover:
                        self.recover()
                raise
            self.engine.pools["k_stage"] = k_stage
            self.engine.pools["v_stage"] = v_stage
            # the promotion rides the round's serve stream (drained by
            # decode_round's stream.flush — one launch for the round)
            self.stream.promote_staged(list(zip(stage_ids, blocks)))
            self._staged_sids.append(sid)
            st = extras
        else:
            logits, st = self.model.prefill(self.params, batch, self.mesh,
                                            margin_tokens=0)
            # seed path: one ad-hoc gather/scatter dispatch per pool,
            # bypassing the command queue (kept for A/B)
            dst = jnp.asarray(np.asarray(blocks, np.int32))
            self.engine.alloc.mark_written(blocks)
            self.engine.pools["k"] = _stage_legacy(self.engine.pools["k"],
                                                   st["k_pools"], dst)
            notify_launch(len(blocks), 1, "legacy_stage")
            self.engine.pools["v"] = _stage_legacy(self.engine.pools["v"],
                                                   st["v_pools"], dst)
            notify_launch(len(blocks), 1, "legacy_stage")
        self.last_logits[sid] = np.asarray(logits[0])
        self.tokens[sid] = [int(t) for t in prompt]
        # extra per-seq state (ssm/hybrid/encdec) kept host-side per slot
        self._store_extra_state(sid, st)
        return sid

    def _store_extra_state(self, sid, st):
        extras = {}
        for k in ("conv_state", "ssm_state", "cross_k", "cross_v"):
            if k in st:
                extras[k] = st[k]
        if extras:
            if not hasattr(self, "_extras"):
                self._extras = {}
            self._extras[sid] = extras

    def fork(self, sid: int, n: int) -> List[int]:
        """CoW-fork ``sid`` into ``n`` children (parallel sampling / beam
        search): prompt pages share by refcount — zero bytes move.  Any
        eager cross-group copies a sharded-batch fork needs are captured
        onto the serve stream (they drain with the round)."""
        if self.fused_staging:
            with self.stream.capture():
                kids = self.cache.fork(sid, n)
        else:
            kids = self.cache.fork(sid, n)
        for c in kids:
            self.last_logits[c] = self.last_logits[sid].copy()
            self.tokens[c] = list(self.tokens[sid])
            if hasattr(self, "_extras") and sid in self._extras:
                self._extras[c] = self._extras[sid]
        return kids

    def free(self, sid: int) -> None:
        """Release a finished sequence's blocks, slot, and host state."""
        self.cache.free_sequence(sid)
        self.last_logits.pop(sid, None)
        self.tokens.pop(sid, None)

    # ------------------------------------------------------------------
    def recover(self) -> RecoveryReport:
        """Return the serving engine to a clean state after a failed
        flush, ckpt tick, or donated-admission error.

        Wraps ``RowCloneEngine.recover`` with serving policy: the latest
        pool checkpoint (when one exists) restores dead KV pools; a dead
        double-buffered staging ring comes back at SINGLE-buffer capacity
        (the degraded mode — bursts drain early instead of parking in the
        poisoned shadow half); and admissions whose staged bytes were
        lost (dead staging, or promotions evicted from the queues) are
        freed into ``evicted_sids`` — re-admitting their prompts
        reproduces the KV bytes, so greedy decode stays bitwise-identical
        to a failure-free run.  Aborted flushes' suffixes re-drain inside
        the engine call (retry/backoff), completing promotions that were
        already dispatched rather than evicting them."""
        eng = self.engine
        staging_dead = any(
            getattr(eng.pools[n], "is_deleted", lambda: False)()
            for n in eng.staging)
        degraded = None
        if staging_dead and self.double_buffer:
            degraded = self.ring_capacity
        snap = self.pool_ckpt.latest() if self.pool_ckpt is not None \
            else None
        rep = eng.recover(snapshot=snap,
                          degraded_stage_capacity=degraded)
        if self.pool_ckpt is not None:
            self.pool_ckpt.reset()
        if staging_dead or rep.evicted_promotions:
            # the staged bytes backing these admissions never reached the
            # KV pools (and are unrecoverable): evict for re-admission
            for sid in self._staged_sids:
                if sid in self.cache.seqs:
                    self.free(sid)
                    self.evicted_sids.append(sid)
        self._staged_sids = []
        self.last_ticket = None
        self.last_recovery = rep
        return rep

    # ------------------------------------------------------------------
    def _decode_fn(self, params, k_pools, v_pools, table, mask, base,
                   seq_lens, tokens, slot_index):
        state = {"k_pools": k_pools, "v_pools": v_pools,
                 "block_table": table, "share_mask": mask, "base": base,
                 "seq_lens": seq_lens}
        logits, st = self.model.decode_step(params, state, tokens, self.mesh,
                                            impl=self.impl)
        return logits, st["k_pools"], st["v_pools"]

    def decode_round(self, sample_fn=None) -> Dict[int, int]:
        """One token for every live sequence (greedy by default)."""
        if self.cfg.family not in ("dense", "moe", "vlm"):
            raise NotImplementedError(
                "CLI decode loop demo targets decoder-only archs; other "
                "families decode through model.decode_step directly")
        live = sorted(self.cache.seqs)
        if not live:
            return {}
        # choose next token per sequence from last logits
        next_tok = {}
        for sid in live:
            lg = self.last_logits[sid]
            t = int(np.argmax(lg)) if sample_fn is None else sample_fn(lg)
            next_tok[sid] = t
        # CoW/allocation happens BEFORE the jit step (host metadata); the
        # round's staged-prefill promotions + CoW splits + tail-block
        # inits all drain as ONE fused launch at this stream flush —
        # the FlushTicket records the round's launch accounting
        if self.fused_staging:
            with self.stream.capture():
                self.cache.append_tokens(live)
        else:
            self.cache.append_tokens(live)   # seed path: eager per-call
        try:
            self.last_ticket = self.stream.flush()
        except Exception:
            if not self.auto_recover:
                raise
            # recover in place: the aborted flush's suffix re-drains
            # inside recover() (same rows, same bytes), so this round's
            # decode proceeds normally and tokens match the clean run
            self.recover()
            # a recovery may have evicted admissions; decode the rest
            live = [s for s in live if s in self.cache.seqs]
            next_tok = {s: next_tok[s] for s in live}
            if not live:
                return {}
        self._staged_sids = []
        table, mask, base = self.cache.device_tables()
        lens = self.cache.seq_lens()
        B = self.cache.max_seqs
        toks = np.zeros((B,), np.int32)
        seq_lens_dev = np.zeros((B,), np.int32)
        for sid in live:
            slot = self.cache.slot_of(sid)
            toks[slot] = next_tok[sid]
            # decode_step's pos = state.seq_lens = position of new token
            seq_lens_dev[slot] = self.cache.seqs[sid].length - 1
        logits, kp, vp = self._decode_jit(
            self.params, self.engine.pools["k"], self.engine.pools["v"],
            table, mask, base, jnp.asarray(seq_lens_dev), jnp.asarray(toks),
            None)
        self.engine.pools["k"] = kp
        self.engine.pools["v"] = vp
        logits = np.asarray(logits)
        for sid in live:
            slot = self.cache.slot_of(sid)
            self.last_logits[sid] = logits[slot]
            self.tokens[sid].append(next_tok[sid])
        if self.pool_ckpt is not None:
            # one background checkpoint window per round: spill-pool
            # cross-copies on the ckpt stream, harvested next round (the
            # ticket's write-scoped wait never blocks on the KV pools
            # this round's decode just donated)
            try:
                self.pool_ckpt.step()
            except Exception:
                if not self.auto_recover:
                    raise
                self.recover()
        return next_tok


@jax.jit
def _stage_legacy(pool, staging, dst_ids):
    """SEED staging path (``fused_staging=False`` A/B only): scatter the
    prefill's pages (L, nper, ...) straight into the KV pool, one ad-hoc
    dispatch per pool, bypassing the command queue."""
    safe = jnp.where(dst_ids >= 0, dst_ids, pool.shape[1])
    return pool.at[:, safe].set(staging.astype(pool.dtype), mode="drop")


def main():
    """CLI: admit random prompts, optionally fork, greedy-decode, and
    print the RowClone mechanism stats (see the module docstring)."""
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama3.2-3b")
    ap.add_argument("--requests", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--steps", type=int, default=16)
    ap.add_argument("--fork", type=int, default=0)
    ap.add_argument("--smoke", action="store_true", default=True)
    ap.add_argument("--staging-ring", type=int, default=-1,
                    help="staging slots (max_admit_pages): size staging "
                         "as a recycled ring instead of full KV twins "
                         "(~2x less resident pool memory); 0 = full "
                         "twin, -1 = derive from the admission policy")
    ap.add_argument("--double-buffer", action="store_true",
                    help="double-buffered staging ring: admission bursts "
                         "past the ring capacity park in the shadow half "
                         "at 1.0 launches/round")
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if args.smoke:
        cfg = cfg.reduced()
    model = build_model(cfg)
    params, _ = split_params(model.init_params(jax.random.key(0)))
    eng = ServingEngine(cfg, params, max_seqs=max(args.requests * 4, 8),
                        max_admit_pages=(None if args.staging_ring < 0
                                         else args.staging_ring),
                        double_buffer=args.double_buffer)
    print(f"[serve] resident pool bytes: "
          f"{eng.engine.pool_bytes_resident() / 1e6:.1f} MB "
          f"(staging slots: {eng.engine.stage_capacity} of "
          f"{eng.engine.num_blocks} KV blocks)")
    rng = np.random.default_rng(0)
    sids = []
    for i in range(args.requests):
        p = rng.integers(2, cfg.vocab_size, size=args.prompt_len)
        sid = eng.add_request(p.astype(np.int32))
        sids.append(sid)
        print(f"[serve] admitted seq {sid} ({args.prompt_len} tokens)")
    if args.fork:
        kids = eng.fork(sids[0], args.fork)
        print(f"[serve] forked seq {sids[0]} -> {kids} "
              f"(CoW shares: {eng.engine.alloc.stats.cow_shares})")
    t0 = time.time()
    for step in range(args.steps):
        eng.decode_round()
    dt = time.time() - t0
    n_live = len(eng.cache.seqs)
    print(f"[serve] {args.steps} rounds x {n_live} seqs in {dt:.2f}s "
          f"({args.steps * n_live / dt:.1f} tok/s)")
    s = eng.engine.stats
    print(f"[serve] rowclone: fpm={s.fpm_copies} psm={s.psm_copies} "
          f"alias={s.alias_copies} lazy-zero={s.zero_lazy} "
          f"bytes_avoided={s.bytes_avoided}")


if __name__ == "__main__":
    main()
