"""Serving engine: continuous batched decode over a RowClone-managed pool.

The serving loop is the paper's application showcase:

* admission (``add_request``) — the prefill forward writes its KV pages
  directly into the engine's **staging pools** (inside the prefill jit —
  no separate staging dispatch), and the stage→KV-pool promotion enqueues
  ``OP_CROSS_POOL_COPY`` commands into the engine's command queue (this is
  the CPU→"process address space" copy that RowClone §3.2 accelerates,
  expressed as the GS-DRAM-style pool→pool transfer);
* ``fork`` — parallel sampling / beam search shares every prompt page by
  refcount (zero bytes), CoW-splitting lazily on the first divergent append;
* fresh pages are BuZ-lazy-zeroed (ZI metadata bit);
* ``dedup_admit=True`` — **dedup-on-admit**: every staged prompt page is
  fingerprinted with an XOR fold (:func:`page_fingerprint` — XOR composed
  from the engine's new in-memory bitwise opcode identities, ``x ^ y ==
  (x | y) & ~(x & y)``), and pages whose chained fingerprint matches a
  live registry entry collapse onto the donor's block: the dupe's
  promotion rows are skipped, its staging slots return to the ring, and
  the shared block rides the round's single fused launch exactly like a
  CoW fork share.  The first divergent append CoW-splits, and greedy
  tokens stay bitwise-identical to a dedup-off run;
* each decode round drains the engine's **serve CommandStream** ONCE —
  promotions + CoW splits + tail inits are captured onto the stream
  (``stream.capture()``) and ride one fused launch at ``stream.flush()``,
  whose :class:`~repro.core.stream.FlushTicket` is kept in
  ``last_ticket`` — then runs one jit'd ``model.decode_step`` over the
  shared pool with the cache's device tables.  Under a mesh the batch
  shards over (pod, data) whenever the cache can pin each sequence's
  blocks in its group's slabs (``batch_shard_count``); the flush is one
  collective launch either way.

Staging sizing is policy-derived: ``max_admit_pages=None`` sizes the ring
at ``admissions_per_round x max_blocks_per_seq`` (the most pages an
in-policy round can park); ``double_buffer=True`` doubles the slots into
a live + shadow half, so admission bursts past the ring's nominal
capacity land in the shadow half while the live half's promotions are
still queued (their slots carry pending READS — the command queues'
source-hazard tracking) and the round still drains as ONE launch.
``max_admit_pages=ServingEngine.FULL_TWIN`` keeps the seed's full-size
staging twins.

``fused_staging=False`` restores the seed's ``_stage_legacy`` path (one
ad-hoc gather/scatter dispatch per pool per admission, KV pools written
directly) for A/B benchmarking — ``benchmarks/bench_dispatch.py
serve_round`` and the staging parity suite drive both.

CLI:  PYTHONPATH=src python -m repro.launch.serve --arch llama3.2-3b \
          --smoke --requests 8 --steps 32 --fork 2
"""
from __future__ import annotations

import argparse
import dataclasses
from typing import Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import CheckpointManager, PoolCheckpoint
from repro.configs import RowCloneConfig, get_config
from repro.core import PagedCoWCache, RowCloneEngine, SubarrayAllocator
from repro.core.journal import RecoveryReport
from repro.kernels.fused_dispatch import notify_launch
from repro.launch.mesh import pool_shard_count
from repro.models import build_model, split_params
from repro.models.paged import batch_shard_count, make_serving_pools
from repro.obs import metrics as obs_metrics
from repro.obs.autotune import load_profile


@dataclasses.dataclass
class DemotedSeq:
    """Host-side parking record for a preempted sequence.

    :meth:`ServingEngine.demote` moves a victim's KV blocks into spill
    slots (``OP_CROSS_POOL_COPY`` — the reverse of admission promotion)
    and keeps everything needed to resume bitwise-identically here:
    length, the spill slots holding the bytes, slab affinity, the last
    logits (next-token source), the token history, and any extra host
    state (conv/ssm/cross-attention).  The KV pool blocks themselves are
    returned to the allocator after the round's flush."""

    length: int                  #: sequence length at demotion time
    slots: List[int]             #: spill slots parking the KV bytes
    slab_home: int               #: preferred slab for re-allocation
    logits: np.ndarray           #: last logits (greedy argmax source)
    tokens: List[int]            #: token history (prompt + generated)
    extras: Optional[dict]       #: non-dense host state, if any


#: 64-bit fold constants (splitmix64 / FNV mixes) for the page fingerprint
_FP_MASK = (1 << 64) - 1
_FP_WORD = 0x9E3779B97F4A7C15
_FP_POS = 0xC2B2AE3D27D4EB4F
_FP_CHAIN = 0x100000001B3


def xor_fold(acc: int, word: int) -> int:
    """One XOR-fold step over 64-bit words, composed EXACTLY from the
    engine's in-memory bitwise opcode identities: ``x ^ y == (x | y) &
    ~(x & y)`` — an ``OP_OR``, an ``OP_AND``, an ``OP_NOT``, and a final
    ``OP_AND``.  The host-side software analogue of folding a block
    fingerprint in DRAM with the Ambit triple-row ops the fused dispatch
    now executes (``memand``/``memor``/``memnot``)."""
    both = acc & word           # OP_AND
    either = acc | word         # OP_OR
    return (either & (~both & _FP_MASK)) & _FP_MASK   # OP_AND of OP_NOT


def page_fingerprint(chain: int, tokens) -> int:
    """Chained fingerprint of one prompt page: position-salted token
    words folded with :func:`xor_fold`, mixed into the previous page's
    fingerprint (``chain``) so equal keys mean equal page *prefixes*, not
    just equal pages.  Dedup-on-admit keys its prefix registry with
    these (and verifies the raw tokens on every hit, so a fold collision
    can never corrupt a sequence)."""
    fp = chain & _FP_MASK
    for i, t in enumerate(tokens):
        word = ((int(t) + 1) * _FP_WORD + (i + 1) * _FP_POS) & _FP_MASK
        fp = xor_fold((fp * _FP_CHAIN) & _FP_MASK, word)
    # fold the page's token count so a short tail page can never alias a
    # full page that starts with the same tokens
    return xor_fold(fp, (len(tokens) * _FP_POS) & _FP_MASK)


class ServingEngine:
    """Continuous-batching serving facade over RowCloneEngine +
    PagedCoWCache: admission (prefill + staged promotion), CoW fork,
    preemption by demotion (:meth:`demote`/:meth:`resume`), dedup-on-admit
    (``dedup_admit=True`` — identical prompt prefixes across tenants
    collapse onto shared CoW blocks at admission), and greedy decode
    rounds whose bulk movement drains as one fused launch."""

    #: ``max_admit_pages`` sentinel: keep full-size staging twins (every
    #: KV block has a staging slot) instead of a recycled ring
    FULL_TWIN = 0

    #: adaptive-ring observation window: rounds of sustained low
    #: admission pressure before the staging ring shrinks
    RING_WINDOW = 4

    def __init__(self, cfg, params, mesh=None, max_seqs: int = 16,
                 max_blocks_per_seq: int = 64, num_slabs: int = 4,
                 rc: Optional[RowCloneConfig] = None, impl: str = "ref",
                 fused_staging: bool = True,
                 max_admit_pages: Optional[int] = None,
                 admissions_per_round: int = 1,
                 double_buffer: bool = False,
                 fault_plan=None, auto_recover: bool = False,
                 ckpt_pages: int = 0, ckpt_dir: Optional[str] = None,
                 ckpt_window: Optional[int] = None,
                 spill_pages: int = 0, dedup_admit: bool = False,
                 adaptive_ring: bool = True):
        """``max_admit_pages`` sizes the staging pools as a RING of that
        many slots instead of a full-size twin of the KV pools — slots
        recycle at every round's flush, so the ring only needs to hold
        the pages admitted between two flushes.  ``None`` (default)
        DERIVES the size from the admission policy:
        ``admissions_per_round x max_blocks_per_seq`` (the most pages an
        in-policy round can park); :data:`FULL_TWIN` (0) keeps the seed's
        full twin.  A ring of a few blocks cuts the engine's resident
        pool bytes by ~2x at unchanged round latency and bitwise-identical
        decode (BENCH_dispatch.json serve_round).

        ``double_buffer=True`` doubles the ring into live + shadow
        halves: admissions bursting past the nominal ring capacity park
        in the shadow half while the live half's promotions are still
        queued on the serve stream (pending source reads guard those
        slots), keeping burst rounds at 1.0 bulk-movement launches
        instead of forcing an early drain.

        Under a mesh a ring that does not divide the pool shard count is
        REPLICATED (``PoolSpec.sharding == ()`` — held whole on every
        device) rather than rounded up; sharded rings partition like
        their KV twins.

        Fault tolerance: ``ckpt_pages > 0`` adds spill pools of that many
        blocks and a background :class:`PoolCheckpoint` driven one window
        per decode round (``ckpt_dir`` names the checkpoint directory);
        ``fault_plan`` installs a
        :class:`~repro.runtime.fault.FaultPlan`'s injections against this
        engine; ``auto_recover=True`` catches a failed round flush (or
        ckpt tick) and runs :meth:`recover` in place — the next round
        serves normally.  Admissions evicted by a recovery land in
        ``evicted_sids`` for the caller to re-admit.

        ``adaptive_ring=True`` (the default) lets the staging ring track
        admission pressure: after :data:`RING_WINDOW` consecutive rounds
        whose admitted pages peak at or below half the usable ring, the
        ring shrinks (``engine.set_stage_limit``) to twice that peak —
        free slots above the limit park, cutting the ring's working set;
        an admission that would not fit the clamped ring regrows it to
        full capacity BEFORE reserving slots, so admissions never fail
        or force an early flush because of the clamp.  The
        ``serve.ring_occupancy`` / ``serve.ring_limit`` gauges and the
        shrink/regrow counters ride the obs metrics registry.

        Dedup-on-admit: ``dedup_admit=True`` (fused staging only) keeps a
        prefix registry of chained page fingerprints
        (:func:`page_fingerprint`).  An admission whose prompt pages
        match live registry entries shares the donor blocks by refcount
        instead of promoting its own staged copies — the matched
        promotion rows never enqueue, the staging slots return to the
        ring immediately, and resident KV bytes (:meth:`kv_bytes_live`)
        grow by only the unmatched pages.  Registered pages pin one
        registry refcount so their bytes can never be recycled under a
        live entry; :meth:`free` of the registering sequence drops its
        entries.  Under sharded batches a donor block is only shared
        into a sequence pinned to the same batch group.

        Preemption: ``spill_pages > 0`` reserves that many EXTRA spill
        slots for :meth:`demote` / :meth:`resume` — the scheduler's
        preemption-by-demotion path.  The spill pools are shared with the
        checkpoint stream but partitioned by slot range: PoolCheckpoint
        windows keep slots ``[0, ckpt_pages)``, demotion owns
        ``[ckpt_pages, ckpt_pages + spill_pages)`` — the two never
        collide, and both ride the same ``OP_CROSS_POOL_COPY`` fused
        launches."""
        self.cfg = cfg
        self.rc = rc or RowCloneConfig()
        self.mesh = mesh
        self.impl = impl
        self.model = build_model(cfg, self.rc)
        self.params = params
        self.fused_staging = fused_staging
        self.double_buffer = double_buffer
        page = self.rc.page_size
        L = cfg.num_attn_layers
        nblk = max_seqs * max_blocks_per_seq
        # pool must tile both the allocator slabs and the mesh's device
        # shards — the sharded fused dispatch partitions by device shard
        shards = pool_shard_count(mesh)
        align = int(np.lcm(num_slabs, shards))
        nblk = -(-nblk // align) * align
        if max_admit_pages is None:
            # tuned-profile precedence: an autotuned ring size applies
            # only when the caller did not pass an explicit kwarg
            # (kwarg > profile > policy derivation)
            prof = load_profile()
            if prof is not None and prof.ring_capacity is not None:
                max_admit_pages = int(prof.ring_capacity)
        if max_admit_pages is None:
            # admission-policy derivation: the ring must hold one round's
            # worth of staged pages (kwarg stays as an explicit override)
            max_admit_pages = admissions_per_round * max_blocks_per_seq
        replicate_staging = False
        if max_admit_pages == self.FULL_TWIN:
            stage_nblk = nblk          # full twin (seed sizing)
            self.ring_capacity = nblk
        else:
            self.ring_capacity = int(max_admit_pages)
            stage_nblk = int(max_admit_pages) * (2 if double_buffer else 1)
            if stage_nblk % shards:
                replicate_staging = True   # whole ring on every device
        kv_dtype = jnp.bfloat16 if cfg.dtype == "bfloat16" else jnp.float32
        alloc = SubarrayAllocator(nblk, num_slabs,
                                  reserved_zero_per_slab=self.rc
                                  .zero_blocks_per_slab)
        # K/V pools + staging pools are ONE PoolGroup (models/paged.py):
        # per-pool block counts in the group's prefix-sum address space,
        # so the (possibly much smaller) staging ring rides the same
        # fused launch.  The engine sees the mesh: every decode round's
        # promotions + CoW splits + tail inits drain as ONE (collective)
        # launch at the round's flush boundary
        self.ckpt_pages = int(ckpt_pages)
        self.spill_pages = int(spill_pages)
        # one spill pool per primary (PoolCheckpoint keys spill pools by
        # their paired primary): checkpoint windows and demotion parking
        # SHARE it, partitioned by slot range
        total_spill = self.ckpt_pages + self.spill_pages
        replicate_ckpt = bool(total_spill % shards) if total_spill else False
        pools, group = make_serving_pools(
            L, nblk, page, cfg.num_kv_heads, cfg.head_dim, kv_dtype,
            staging=fused_staging, stage_nblk=stage_nblk,
            replicate_staging=replicate_staging,
            ckpt_nblk=total_spill, replicate_ckpt=replicate_ckpt)
        if mesh is not None:
            # honor each PoolSpec's sharding hint at placement time
            # (replicated rings stay whole per device; KV pools shard)
            from repro.launch.mesh import tree_shardings
            shardings = tree_shardings(
                mesh, pools, {n: group[n] for n in pools}, block_axis=1)
            pools = {n: jax.device_put(a, shardings[n])
                     for n, a in pools.items()}
        self.engine = RowCloneEngine(
            pools, alloc, mesh=mesh, enable_fpm=self.rc.enable_fpm,
            enable_psm=self.rc.enable_psm, enable_zi=self.rc.enable_zi,
            block_axis=1, group=group)
        # shard the decode batch over (pod, data) when the cache can pin
        # each sequence's blocks inside its batch group's slabs; otherwise
        # keep global share-mask columns (replicated batch — paged.py)
        dp = batch_shard_count(mesh, max_seqs)
        if dp > 1 and (num_slabs % dp or nblk % dp):
            dp = 1
        self.cache = PagedCoWCache(self.engine, page, max_blocks_per_seq,
                                   max_seqs, batch_groups=dp)
        self.last_logits: Dict[int, np.ndarray] = {}
        self.tokens: Dict[int, List[int]] = {}
        self._decode_jit = jax.jit(self._decode_fn, donate_argnums=(1, 2))
        # the staging pools ARE donated: a failure inside the donated call
        # kills buffers still holding earlier admissions' un-promoted
        # pages, and recover() handles exactly that — it resurrects the
        # staging ring and evicts the affected admissions (evicted_sids)
        # for re-admission.  Donation closes the seed-era extra copy the
        # un-donated scatter paid per admission.
        self._prefill_stage_jit = jax.jit(self._prefill_stage_fn,
                                          donate_argnums=(2, 3))
        # the round's bulk movement lives on a dedicated CommandStream:
        # admissions/forks CAPTURE their promotions and CoW work onto it,
        # and decode_round's stream.flush() drains everything as one
        # launch, returning the FlushTicket kept in ``last_ticket``
        self.stream = self.engine.stream("serve")
        self.last_ticket = None
        self.auto_recover = auto_recover
        self.fault_plan = fault_plan
        if fault_plan is not None:
            fault_plan.install(self.engine)
        #: admissions whose stage→KV promotions have not drained yet —
        #: recovery evicts exactly these when the staged bytes are lost
        self._staged_sids: List[int] = []
        #: per-admission stage→KV promotion pairs still queued — free()
        #: retires exactly these rows so a freed-before-flush sequence's
        #: promotion can never land in re-issued blocks
        self._pending_promotions: Dict[int, List[Tuple[int, int]]] = {}
        #: per-seq host state (conv/ssm/cross-attention) for non-dense
        #: families, keyed by sid — free()/demote() MUST drop the entry
        self._extras: Dict[int, dict] = {}
        #: sequences a recovery evicted; the caller re-admits their
        #: prompts (re-admission reproduces the KV bytes, so greedy
        #: tokens match the failure-free run)
        self.evicted_sids: List[int] = []
        #: preempted sequences parked in spill slots, keyed by sid —
        #: :meth:`resume` unparks (minting a NEW sid); :meth:`free`
        #: releases the parking without resuming
        self.demoted: Dict[int, DemotedSeq] = {}
        #: resumes whose spill→KV promotions have not drained yet —
        #: recovery evicts these the same way it evicts staged admissions
        self._resumed: List[Tuple[int, List[int]]] = []
        #: demoted blocks kept allocated until the round's flush drains
        #: the demote reads — freeing them early would let a same-round
        #: admission reuse the block and trip the cross-stream WAR guard
        #: (an extra launch), breaking the 1.0 launches/round contract
        self._free_after_flush: List[int] = []
        self._admission_ordinal = 0
        #: dedup-on-admit prefix registry: chained page fingerprint ->
        #: (donor block id, raw page tokens) — the token tuple is checked
        #: on every hit, so fingerprint collisions degrade to a miss
        self.dedup_admit = bool(dedup_admit) and fused_staging
        self._dedup_registry: Dict[int, Tuple[int, Tuple[int, ...]]] = {}
        #: registry keys registered per sid (free() drops them and
        #: releases the registry's own block refcount)
        self._dedup_keys: Dict[int, List[int]] = {}
        self.dedup_hits = 0           #: admissions that shared >= 1 page
        self.dedup_pages_shared = 0   #: prompt pages satisfied by sharing
        self.dedup_bytes_saved = 0    #: KV bytes those pages never took
        #: adaptive staging-ring controller (fused staging only): shrink
        #: under sustained low admission pressure, regrow on demand
        self.adaptive_ring = bool(adaptive_ring) and fused_staging
        self._ring_window: List[int] = []   #: admitted pages, last rounds
        self._round_admitted_pages = 0
        self.ring_shrinks = 0         #: times the controller clamped the ring
        self.ring_regrows = 0         #: times demand re-opened the full ring
        self.last_recovery: Optional[RecoveryReport] = None
        self.pool_ckpt: Optional[PoolCheckpoint] = None
        if self.ckpt_pages:
            if ckpt_dir is None:
                raise ValueError("ckpt_pages > 0 needs ckpt_dir")
            # cap the checkpoint window at ckpt_pages: with demotion the
            # spill pools are oversized, and windows must stay out of the
            # demotion slot range
            self.pool_ckpt = PoolCheckpoint(
                self.engine, CheckpointManager(ckpt_dir),
                window=(min(int(ckpt_window), self.ckpt_pages)
                        if ckpt_window is not None else self.ckpt_pages))
        if self.spill_pages:
            self.engine.enable_demotion(
                range(self.ckpt_pages, self.ckpt_pages + self.spill_pages))

    # ------------------------------------------------------------------
    def _prefill_batch(self, prompt: np.ndarray) -> Dict[str, jnp.ndarray]:
        S = int(prompt.shape[0])
        batch = {"tokens": jnp.asarray(prompt[None, :])}
        if self.cfg.family == "vlm":
            batch["patch_embeds"] = jnp.zeros(
                (1, self.cfg.vision_tokens, self.cfg.d_model), jnp.float32)
        if self.cfg.family == "encdec":
            batch["src_embeds"] = jnp.zeros(
                (1, max(S // self.cfg.src_frames_ratio, 1),
                 self.cfg.d_model), jnp.float32)
        return batch

    def _prefill_stage_fn(self, params, batch, k_stage, v_stage, stage_ids):
        """Prefill forward + scatter of the prompt's KV pages into the
        staging pools, ONE jit: the staged write costs no extra dispatch,
        and the only bulk movement left (staging→KV promotion) goes
        through the command queue."""
        logits, st = self.model.prefill(params, batch, self.mesh,
                                        margin_tokens=0)
        safe = jnp.where(stage_ids >= 0, stage_ids, k_stage.shape[1])
        k_stage = k_stage.at[:, safe].set(
            st["k_pools"].astype(k_stage.dtype), mode="drop")
        v_stage = v_stage.at[:, safe].set(
            st["v_pools"].astype(v_stage.dtype), mode="drop")
        extras = {k: st[k] for k in ("conv_state", "ssm_state",
                                     "cross_k", "cross_v") if k in st}
        return logits, k_stage, v_stage, extras

    def add_request(self, prompt: np.ndarray,
                    stream=None) -> int:
        """prompt: (S,) int32.  Prefill into the staging pools and enqueue
        the stage→KV promotion (fused path), or scatter straight into the
        KV pools (seed ``fused_staging=False`` path).

        ``stream`` routes the admission's bulk movement onto a caller
        stream instead of the engine's serve stream — the scheduler's
        per-tenant QoS lanes admit here and
        :meth:`~repro.core.stream.CommandStream.adopt` their rows into
        the round stream in priority order."""
        stream = self.stream if stream is None else stream
        S = int(prompt.shape[0])
        if self.fused_staging:
            # any block inits the admission needs (e.g. ZI disabled) ride
            # the serve stream with the round's other bulk movement
            with stream.capture():
                sid = self.cache.new_sequence(prompt_len=S)
        else:
            sid = self.cache.new_sequence(prompt_len=S)
        batch = self._prefill_batch(prompt)
        blocks = self.cache.blocks_of(sid)
        if self.fused_staging:
            ordinal = self._admission_ordinal
            self._admission_ordinal += 1
            rce = self.engine
            ceil = rce._stage_degraded_cap   # None = full capacity
            if self.adaptive_ring and rce.stage_limit is not None \
                    and rce.stage_slots_free < len(blocks) \
                    and (ceil is None or rce.stage_limit < ceil):
                # regrow on demand: re-open the ring (up to a degraded
                # recovery's sticky cap) BEFORE reserving, so the
                # adaptive clamp never fails or early-flushes an
                # admission the un-clamped ring could hold
                rce.set_stage_limit(ceil)
                self.ring_regrows += 1
                self._ring_window = []
                obs_metrics.inc("serve.ring_regrows")
            stage_ids = self.engine.stage_blocks(len(blocks))
            try:
                if self.fault_plan is not None:
                    # injection point for donation errors: fires AFTER the
                    # slots are reserved, simulating the prefill's donated
                    # staging buffers dying mid-call
                    self.fault_plan.check_admission(ordinal, self.engine)
                logits, k_stage, v_stage, extras = self._prefill_stage_jit(
                    self.params, batch, self.engine.pools["k_stage"],
                    self.engine.pools["v_stage"],
                    jnp.asarray(np.asarray(stage_ids, np.int32)))
            except Exception:
                # failed admission must not strand its staging slots.  The
                # staging pools are DONATED into the prefill call, so a
                # failure may have consumed them — then this admission
                # (and any earlier ones with queued promotions) lost its
                # staged bytes: evict it, and recover in place when asked
                self.engine.release_stage_blocks(stage_ids)
                dead = any(
                    getattr(self.engine.pools[n], "is_deleted",
                            lambda: False)()
                    for n in self.engine.staging)
                if dead:
                    self.free(sid)
                    self.evicted_sids.append(sid)
                    if self.auto_recover:
                        self.recover()
                raise
            # out-of-band prefill staging write (journal-exempt by
            # design, see docs/ARCHITECTURE.md "Failure model")
            self.engine.pools["k_stage"] = k_stage  # rowlint: disable=RC103
            self.engine.pools["v_stage"] = v_stage  # rowlint: disable=RC103
            # the promotion rides the round's serve stream (drained by
            # decode_round's stream.flush — one launch for the round)
            self._round_admitted_pages += len(stage_ids)
            pairs = list(zip(stage_ids, blocks))
            if self.dedup_admit:
                pairs = self._dedup_pages(sid, prompt, stage_ids, blocks)
            if pairs:
                stream.promote_staged(pairs)
            self._staged_sids.append(sid)
            self._pending_promotions[sid] = pairs
            st = extras
        else:
            logits, st = self.model.prefill(self.params, batch, self.mesh,
                                            margin_tokens=0)
            # seed path: one ad-hoc gather/scatter dispatch per pool,
            # bypassing the command queue (kept for A/B)
            dst = jnp.asarray(np.asarray(blocks, np.int32))
            self.engine.alloc.mark_written(blocks)
            self.engine.pools["k"] = _stage_legacy(  # rowlint: disable=RC103
                self.engine.pools["k"], st["k_pools"], dst)
            notify_launch(len(blocks), 1, "legacy_stage")
            self.engine.pools["v"] = _stage_legacy(  # rowlint: disable=RC103
                self.engine.pools["v"], st["v_pools"], dst)
            notify_launch(len(blocks), 1, "legacy_stage")
        self.last_logits[sid] = np.asarray(logits[0])
        self.tokens[sid] = [int(t) for t in prompt]
        # extra per-seq state (ssm/hybrid/encdec) kept host-side per slot
        self._store_extra_state(sid, st)
        return sid

    def _dedup_pages(self, sid: int, prompt: np.ndarray,
                     stage_ids: List[int],
                     blocks: List[int]) -> List[Tuple[int, int]]:
        """Collapse this admission's prompt pages onto registered donor
        blocks where the chained fingerprints (and raw tokens) match.
        Returns the surviving (stage slot, block) promotion pairs; matched
        pages share the donor by refcount, their staging slots return to
        the ring, and unmatched pages register as future donors (the
        registry holds its own refcount on each donor block, so a donor's
        bytes outlive CoW splits and frees of any individual sharer)."""
        seq = self.cache.seqs[sid]
        page = self.cache.page
        new_blocks = list(blocks)
        keep: List[Tuple[int, int]] = []
        released: List[int] = []
        registered: List[int] = []
        chain = 0
        for j, b in enumerate(blocks):
            toks = tuple(int(t) for t in prompt[j * page:(j + 1) * page])
            chain = page_fingerprint(chain, toks)
            hit = self._dedup_registry.get(chain)
            if hit is not None and hit[1] == toks and (
                    self.cache.batch_groups == 1
                    or self.cache.group_of_block(hit[0]) == seq.group):
                donor = hit[0]
                self.engine.alloc.share([donor])
                new_blocks[j] = donor
                released.append(stage_ids[j])
                self.dedup_pages_shared += 1
                self.dedup_bytes_saved += self.engine._block_bytes()
            else:
                keep.append((stage_ids[j], b))
                if hit is None:
                    # register as a donor: the registry's own refcount
                    # pins the block (and its promoted bytes) while the
                    # entry lives
                    self.engine.alloc.share([b])
                    self._dedup_registry[chain] = (b, toks)
                    registered.append(chain)
        if registered:
            self._dedup_keys[sid] = registered
        if released:
            self.dedup_hits += 1
            self.engine.release_stage_blocks(released)
            self.cache.remap_blocks(sid, new_blocks)
        return keep

    def kv_bytes_live(self) -> int:
        """Primary-pool KV bytes backed by currently-allocated blocks —
        the dedup-on-admit headline: admissions whose prompt pages
        collapse onto shared donor blocks grow this by less than their
        page count (``BENCH_dispatch.json`` v8 ``dedup_admit`` leg)."""
        alloc = self.engine.alloc
        used = alloc.num_blocks - alloc.total_free()
        return used * self.engine._block_bytes()

    def _store_extra_state(self, sid, st):
        extras = {}
        for k in ("conv_state", "ssm_state", "cross_k", "cross_v"):
            if k in st:
                extras[k] = st[k]
        if extras:
            self._extras[sid] = extras

    def fork(self, sid: int, n: int) -> List[int]:
        """CoW-fork ``sid`` into ``n`` children (parallel sampling / beam
        search): prompt pages share by refcount — zero bytes move.  Any
        eager cross-group copies a sharded-batch fork needs are captured
        onto the serve stream (they drain with the round)."""
        if self.fused_staging:
            with self.stream.capture():
                kids = self.cache.fork(sid, n)
        else:
            kids = self.cache.fork(sid, n)
        for c in kids:
            self.last_logits[c] = self.last_logits[sid].copy()
            self.tokens[c] = list(self.tokens[sid])
            if sid in self._extras:
                self._extras[c] = self._extras[sid]
        return kids

    def free(self, sid: int) -> None:
        """Release a finished sequence's blocks, slot, and host state —
        including lifecycle state a mid-round free would otherwise leak:

        * a still-queued stage→KV promotion is RETIRED (the rows leave
          the command queues without dispatching and the staging slots
          return to the ring) — otherwise the stale promotion lands in
          blocks the allocator may have re-issued to a NEWER sequence,
          silently corrupting its KV pages;
        * the sid leaves ``_staged_sids`` so a later recovery does not
          "evict" a sequence that no longer exists;
        * the ``_extras`` entry (conv/ssm/cross-attention host state) is
          dropped — previously it accumulated forever under churn;
        * dedup-on-admit registry entries this sid registered are
          invalidated (their registry refcount released) so no future
          admission can match a donor whose bytes may recycle — and a
          queued promotion into a block a LIVE dupe still shares is kept
          queued rather than retired: the dupe's page depends on exactly
          that write landing;
        * a DEMOTED sid releases its spill parking slots instead (no
          cache sequence exists for it)."""
        parked = self.demoted.pop(sid, None)
        if parked is not None:
            self.engine.release_spill_slots(parked.slots)
            self._extras.pop(sid, None)
            return
        for key in self._dedup_keys.pop(sid, []):
            blk, _ = self._dedup_registry.pop(key)
            self.engine.alloc.free([blk])
        pending = self._pending_promotions.pop(sid, None)
        if pending:
            if self.dedup_admit:
                # with the registry's refs gone, refcount > 1 on a dst
                # means a live dupe shares it — its staged write must
                # still land (the block cannot recycle while the dupe
                # holds it)
                pending = [(s, d) for s, d in pending
                           if not self.engine.alloc.is_shared(d)]
            if pending:
                self.engine.retire_promotions(pending)
        if sid in self._staged_sids:
            self._staged_sids.remove(sid)
        self.cache.free_sequence(sid)
        self.last_logits.pop(sid, None)
        self.tokens.pop(sid, None)
        self._extras.pop(sid, None)

    # ------------------------------------------------------------------
    def demote(self, sid: int, stream=None) -> None:
        """Preempt ``sid``: park its KV bytes in spill slots
        (``OP_CROSS_POOL_COPY``, the reverse of admission promotion) and
        release its batch slot + blocks — :meth:`resume` brings it back
        bitwise-identically.  Needs ``spill_pages`` capacity.

        The victim's blocks stay allocated until the round's flush
        drains the demote reads (``_free_after_flush``): freeing them
        immediately would let a same-round admission reuse a block whose
        demote read is still pending — the cross-stream WAR guard would
        force an early drain (an extra launch) to stay correct.  CoW
        forks are handled naturally: the parked copy is private, and
        siblings keep their shared refcounts.

        ``stream`` routes the demote copies onto a caller stream (a
        scheduler lane); default is the serve stream."""
        if sid in self._staged_sids:
            raise RuntimeError(
                f"cannot demote seq {sid}: its admission promotion has "
                "not drained yet (preempt it next round)")
        stream = self.stream if stream is None else stream
        seq = self.cache.seqs[sid]
        blocks = list(seq.blocks)
        # decode writes pool bytes inside the jit, out of band of the
        # allocator's ZI metadata — mark them written so the demote copy
        # moves the real bytes instead of re-materializing zeros
        self.engine.alloc.mark_written(blocks)
        slots = stream.demote_to_spill(blocks)
        self.demoted[sid] = DemotedSeq(
            length=seq.length, slots=list(slots), slab_home=seq.slab_home,
            logits=self.last_logits.pop(sid),
            tokens=self.tokens.pop(sid, []),
            extras=self._extras.pop(sid, None))
        # keep the blocks alive past free_sequence (share +1 / free -1)
        # and release the extra ref only after the flush
        self.engine.alloc.share(blocks)
        self.cache.free_sequence(sid)
        self._free_after_flush.extend(blocks)

    def resume(self, sid: int, stream=None) -> int:
        """Un-park a demoted sequence: allocate fresh blocks (same slab
        affinity), enqueue the spill→KV promotion, and restore the host
        state under a NEW sid (returned — callers map request→sid).
        Greedy decode from the resumed state is bitwise-identical to the
        unpreempted run (the parked bytes ARE the KV pages)."""
        d = self.demoted.pop(sid)
        stream = self.stream if stream is None else stream
        with stream.capture():
            new_sid = self.cache.new_sequence(prompt_len=d.length,
                                              prefer_slab=d.slab_home)
        blocks = self.cache.blocks_of(new_sid)
        assert len(blocks) == len(d.slots), (len(blocks), len(d.slots))
        stream.promote_spilled(list(zip(d.slots, blocks)))
        self.last_logits[new_sid] = d.logits
        self.tokens[new_sid] = d.tokens
        if d.extras is not None:
            self._extras[new_sid] = d.extras
        self._resumed.append((new_sid, list(d.slots)))
        return new_sid

    # ------------------------------------------------------------------
    def recover(self) -> RecoveryReport:
        """Return the serving engine to a clean state after a failed
        flush, ckpt tick, or donated-admission error.

        Wraps ``RowCloneEngine.recover`` with serving policy: the latest
        pool checkpoint (when one exists) restores dead KV pools; a dead
        double-buffered staging ring comes back at SINGLE-buffer capacity
        (the degraded mode — bursts drain early instead of parking in the
        poisoned shadow half); and admissions whose staged bytes were
        lost (dead staging, or promotions evicted from the queues) are
        freed into ``evicted_sids`` — re-admitting their prompts
        reproduces the KV bytes, so greedy decode stays bitwise-identical
        to a failure-free run.  Aborted flushes' suffixes re-drain inside
        the engine call (retry/backoff), completing promotions that were
        already dispatched rather than evicting them."""
        eng = self.engine
        staging_dead = any(
            getattr(eng.pools[n], "is_deleted", lambda: False)()
            for n in eng.staging)
        # probe spill-pool death BEFORE the engine resurrects the pools:
        # dead spill pools take every demoted sequence's parked bytes
        # with them
        spill_dead = any(
            getattr(eng.pools[s.name], "is_deleted", lambda: False)()
            for s in eng.group if s.role == "spill") if self.spill_pages \
            else False
        degraded = None
        if staging_dead and self.double_buffer:
            degraded = self.ring_capacity
        snap = self.pool_ckpt.latest() if self.pool_ckpt is not None \
            else None
        rep = eng.recover(snapshot=snap,
                          degraded_stage_capacity=degraded)
        if self.pool_ckpt is not None:
            self.pool_ckpt.reset()
        if staging_dead or rep.evicted_promotions:
            # the staged bytes backing these admissions never reached the
            # KV pools (and are unrecoverable): evict for re-admission
            for sid in list(self._staged_sids):
                if sid in self.cache.seqs:
                    self.free(sid)
                    self.evicted_sids.append(sid)
        # demoted victims' blocks: the aborted queues dropped the demote
        # reads, so the deferred frees happen NOW (release the extra ref)
        if self._free_after_flush:
            eng.alloc.free(self._free_after_flush)
            self._free_after_flush = []
        # in-flight resumes: their spill→KV promotions may have been
        # aborted with the queues — evict for re-admission (same contract
        # as staged admissions); release_spill_slots is idempotent, so
        # slots already reclaimed by an earlier drain are skipped
        for sid, slots in self._resumed:
            if sid in self.cache.seqs:
                self.free(sid)
                self.evicted_sids.append(sid)
            eng.release_spill_slots(slots)
        self._resumed = []
        if spill_dead:
            # the parked KV bytes died with the spill pools: evict every
            # demoted sequence for re-admission
            for sid in list(self.demoted):
                self.free(sid)
                self.evicted_sids.append(sid)
        self._staged_sids = []
        self._pending_promotions.clear()
        self.last_ticket = None
        self.last_recovery = rep
        return rep

    # ------------------------------------------------------------------
    def _post_flush(self) -> None:
        """Round-boundary bookkeeping after the stream flush drained the
        round's bulk movement: staged admissions and resumed sequences
        are no longer in flight, demoted victims' blocks (whose demote
        reads just drained) go back to the allocator, and the adaptive
        staging-ring controller takes its per-round sample."""
        self._staged_sids = []
        self._pending_promotions.clear()
        self._resumed = []
        if self._free_after_flush:
            self.engine.alloc.free(self._free_after_flush)
            self._free_after_flush = []
        eng = self.engine
        if not eng.staging:
            return
        effective = eng.stage_limit if eng.stage_limit is not None \
            else eng.stage_capacity
        in_use = eng.stage_capacity - eng.stage_slots_free \
            - len(eng._stage_parked)
        obs_metrics.set_gauge("serve.ring_occupancy", in_use)
        obs_metrics.set_gauge("serve.ring_limit", effective)
        if not self.adaptive_ring:
            return
        self._ring_window.append(self._round_admitted_pages)
        self._round_admitted_pages = 0
        if len(self._ring_window) < self.RING_WINDOW:
            return
        peak = max(self._ring_window)
        self._ring_window = []
        # sustained low pressure: a whole window peaked at <= half the
        # usable ring -> clamp to 2x that peak (regrow-on-demand covers
        # any later burst; never below one slot)
        if effective > 1 and peak <= effective // 2:
            new_limit = max(2 * peak, 1)
            if new_limit < effective:
                eng.set_stage_limit(new_limit)
                self.ring_shrinks += 1
                obs_metrics.inc("serve.ring_shrinks")

    def _decode_fn(self, params, k_pools, v_pools, table, mask, base,
                   seq_lens, tokens, slot_index):
        state = {"k_pools": k_pools, "v_pools": v_pools,
                 "block_table": table, "share_mask": mask, "base": base,
                 "seq_lens": seq_lens}
        logits, st = self.model.decode_step(params, state, tokens, self.mesh,
                                            impl=self.impl)
        return logits, st["k_pools"], st["v_pools"]

    def decode_round(self, sample_fn=None) -> Dict[int, int]:
        """One token for every live sequence (greedy by default)."""
        if self.cfg.family not in ("dense", "moe", "vlm"):
            raise NotImplementedError(
                "CLI decode loop demo targets decoder-only archs; other "
                "families decode through model.decode_step directly")
        live = sorted(self.cache.seqs)
        if not live:
            # still drain pending bulk movement (e.g. every sequence was
            # demoted this round): the parked bytes must land and the
            # deferred block frees must happen even with nothing to decode
            if len(self.stream.queue):
                try:
                    self.last_ticket = self.stream.flush()
                except Exception:
                    if not self.auto_recover:
                        raise
                    self.recover()
                self._post_flush()
            return {}
        # choose next token per sequence from last logits
        next_tok = {}
        for sid in live:
            lg = self.last_logits[sid]
            t = int(np.argmax(lg)) if sample_fn is None else sample_fn(lg)
            next_tok[sid] = t
        # CoW/allocation happens BEFORE the jit step (host metadata); the
        # round's staged-prefill promotions + CoW splits + tail-block
        # inits all drain as ONE fused launch at this stream flush —
        # the FlushTicket records the round's launch accounting
        if self.fused_staging:
            with self.stream.capture():
                self.cache.append_tokens(live)
        else:
            self.cache.append_tokens(live)   # seed path: eager per-call
        try:
            self.last_ticket = self.stream.flush()
        except Exception:
            if not self.auto_recover:
                raise
            # recover in place: the aborted flush's suffix re-drains
            # inside recover() (same rows, same bytes), so this round's
            # decode proceeds normally and tokens match the clean run
            self.recover()
            # a recovery may have evicted admissions; decode the rest
            live = [s for s in live if s in self.cache.seqs]
            next_tok = {s: next_tok[s] for s in live}
            if not live:
                return {}
        self._post_flush()
        table, mask, base = self.cache.device_tables()
        lens = self.cache.seq_lens()
        B = self.cache.max_seqs
        toks = np.zeros((B,), np.int32)
        seq_lens_dev = np.zeros((B,), np.int32)
        for sid in live:
            slot = self.cache.slot_of(sid)
            toks[slot] = next_tok[sid]
            # decode_step's pos = state.seq_lens = position of new token
            seq_lens_dev[slot] = self.cache.seqs[sid].length - 1
        logits, kp, vp = self._decode_jit(
            self.params, self.engine.pools["k"], self.engine.pools["v"],
            table, mask, base, jnp.asarray(seq_lens_dev), jnp.asarray(toks),
            None)
        # out-of-band decode-step append (reproduced by re-running the
        # producer on recovery, never by journal replay)
        self.engine.pools["k"] = kp  # rowlint: disable=RC103
        self.engine.pools["v"] = vp  # rowlint: disable=RC103
        logits = np.asarray(logits)
        for sid in live:
            slot = self.cache.slot_of(sid)
            self.last_logits[sid] = logits[slot]
            self.tokens[sid].append(next_tok[sid])
        if self.pool_ckpt is not None:
            # one background checkpoint window per round: spill-pool
            # cross-copies on the ckpt stream, harvested next round (the
            # ticket's write-scoped wait never blocks on the KV pools
            # this round's decode just donated)
            try:
                self.pool_ckpt.step()
            except Exception:
                if not self.auto_recover:
                    raise
                self.recover()
        return next_tok


@jax.jit
def _stage_legacy(pool, staging, dst_ids):
    """SEED staging path (``fused_staging=False`` A/B only): scatter the
    prefill's pages (L, nper, ...) straight into the KV pool, one ad-hoc
    dispatch per pool, bypassing the command queue."""
    safe = jnp.where(dst_ids >= 0, dst_ids, pool.shape[1])
    return pool.at[:, safe].set(staging.astype(pool.dtype), mode="drop")


def main():
    """CLI: admit random prompts, optionally fork, greedy-decode, and
    print the RowClone mechanism stats (see the module docstring)."""
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama3.2-3b")
    ap.add_argument("--requests", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--steps", type=int, default=16)
    ap.add_argument("--fork", type=int, default=0)
    ap.add_argument("--smoke", action="store_true", default=True)
    ap.add_argument("--staging-ring", type=int, default=-1,
                    help="staging slots (max_admit_pages): size staging "
                         "as a recycled ring instead of full KV twins "
                         "(~2x less resident pool memory); 0 = full "
                         "twin, -1 = derive from the admission policy")
    ap.add_argument("--double-buffer", action="store_true",
                    help="double-buffered staging ring: admission bursts "
                         "past the ring capacity park in the shadow half "
                         "at 1.0 launches/round")
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if args.smoke:
        cfg = cfg.reduced()
    model = build_model(cfg)
    params, _ = split_params(model.init_params(jax.random.key(0)))
    eng = ServingEngine(cfg, params, max_seqs=max(args.requests * 4, 8),
                        max_admit_pages=(None if args.staging_ring < 0
                                         else args.staging_ring),
                        double_buffer=args.double_buffer)
    print(f"[serve] resident pool bytes: "
          f"{eng.engine.pool_bytes_resident() / 1e6:.1f} MB "
          f"(staging slots: {eng.engine.stage_capacity} of "
          f"{eng.engine.num_blocks} KV blocks)")
    rng = np.random.default_rng(0)
    sids = []
    for i in range(args.requests):
        p = rng.integers(2, cfg.vocab_size, size=args.prompt_len)
        sid = eng.add_request(p.astype(np.int32))
        sids.append(sid)
        print(f"[serve] admitted seq {sid} ({args.prompt_len} tokens)")
    if args.fork:
        kids = eng.fork(sids[0], args.fork)
        print(f"[serve] forked seq {sids[0]} -> {kids} "
              f"(CoW shares: {eng.engine.alloc.stats.cow_shares})")
    with obs_metrics.Stopwatch() as sw:
        for step in range(args.steps):
            eng.decode_round()
    dt = sw.s
    n_live = len(eng.cache.seqs)
    print(f"[serve] {args.steps} rounds x {n_live} seqs in {dt:.2f}s "
          f"({args.steps * n_live / dt:.1f} tok/s)")
    s = eng.engine.stats
    print(f"[serve] rowclone: fpm={s.fpm_copies} psm={s.psm_copies} "
          f"alias={s.alias_copies} lazy-zero={s.zero_lazy} "
          f"bytes_avoided={s.bytes_avoided}")


if __name__ == "__main__":
    main()
