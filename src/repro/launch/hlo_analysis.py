"""Loop-aware HLO cost extraction for the roofline analysis.

``compiled.cost_analysis()`` visits every instruction ONCE — while-loop
(scan) bodies are not multiplied by trip count, which understates a scanned
80-layer model by 80×.  XLA leaves the trip count in each while op's
``backend_config={"known_trip_count":{"n":...}}``, so this module re-walks
the optimized per-device HLO text and accumulates

  * flops            — dot/convolution ops (exact from shapes + dims)
  * hbm bytes        — operand+result bytes of materializing ops
                       (fusions count at their boundary, i.e. post-fusion)
  * collective bytes — all-reduce / all-gather / reduce-scatter /
                       all-to-all / collective-permute result bytes, with
                       ring-wire multipliers

recursing through while bodies (×trip count), calls, and conditionals
(max over branches).  Fused computations are descended for FLOPs only —
their memory traffic is the fusion boundary.
"""
from __future__ import annotations

import re
from typing import Dict, List, Optional, Tuple

DTYPE_BYTES = {"f64": 8, "f32": 4, "bf16": 2, "f16": 2, "s32": 4, "u32": 4,
               "s8": 1, "u8": 1, "pred": 1, "s64": 8, "u64": 8, "s16": 2,
               "u16": 2, "c64": 8, "c128": 16, "f8e4m3fn": 1, "f8e5m2": 1}
SHAPE_RE = re.compile(r"\b(" + "|".join(DTYPE_BYTES) + r")\[([0-9,]*)\]")
HLO_OP_RE = re.compile(r"^\s*(?:ROOT\s+)?%?[\w\.\-]+\s*=\s*"
                   r"(?P<type>\([^)]*\)|[\w\[\]\{\},\/\* ]+?)\s*"
                   r"(?P<op>[\w\-]+)\(")
TRIP_RE = re.compile(r'known_trip_count[":{\s]+n[":\s]+(\d+)')
CALL_ATTR_RE = re.compile(
    r"(?:condition|body|calls|to_apply|true_computation|false_computation"
    r"|branch_computations)=\{?(%[\w\.\-]+(?:,\s*%[\w\.\-]+)*)\}?")

COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
               "collective-permute")
COLLECTIVE_MULT = {"all-reduce": 2.0, "all-gather": 1.0,
                   "reduce-scatter": 1.0, "all-to-all": 1.0,
                   "collective-permute": 1.0}
# ops whose operand/result traffic is NOT HBM-material (control/aliasing)
FREE_OPS = {"parameter", "constant", "tuple", "get-tuple-element", "bitcast",
            "after-all", "partition-id", "replica-id", "reshape",
            "custom-call"}  # custom-calls here are layout/no-op markers


def _strip_meta(line: str) -> str:
    line = re.sub(r"metadata=\{[^}]*\}", "", line)
    line = re.sub(r'backend_config=\{.*$', "", line)
    return line


def _shape_bytes(text: str) -> int:
    total = 0
    for dt, dims in SHAPE_RE.findall(text):
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * DTYPE_BYTES[dt]
    return total


def _result_elems_and_bytes(type_str: str) -> Tuple[int, int]:
    elems, byts = 0, 0
    for dt, dims in SHAPE_RE.findall(type_str):
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        elems += n
        byts += n * DTYPE_BYTES[dt]
    return elems, byts


PARAM_RE = re.compile(r"(%?[\w\.\-]+)\s*:\s*((?:" + "|".join(DTYPE_BYTES) +
                      r")\[[0-9,]*\](?:\{[^}]*\})?|\([^)]*\))")
RESULT_RE = re.compile(r"^(?:ROOT\s+)?(%?[\w\.\-]+)\s*=\s*"
                       r"(\([^)]*\)|[\w\[\]\{\},\/\* ]+?)\s+[\w\-]+\(")


def _parse_computations(hlo: str):
    """Returns (comp bodies, per-comp symbol table name->result type str)."""
    comps: Dict[str, List[str]] = {}
    syms: Dict[str, Dict[str, str]] = {}
    cur: Optional[str] = None
    body: List[str] = []
    table: Dict[str, str] = {}
    for line in hlo.splitlines():
        stripped = line.strip()
        header = re.match(r"^(?:ENTRY\s+)?%?([\w\.\-]+)\s*\([^;]*->.*\{",
                          line)
        if header and not line.startswith(" "):
            cur = header.group(1)
            body, table = [], {}
            comps[cur] = body
            syms[cur] = table
            if line.startswith("ENTRY"):
                comps["__entry__"] = body
                syms["__entry__"] = table
            # header params: "name: type"
            for pname, ptype in PARAM_RE.findall(line):
                table[pname.lstrip("%")] = ptype
            continue
        if stripped == "}":
            cur = None
            continue
        if cur is not None and "=" in stripped:
            body.append(stripped)
            rm = RESULT_RE.match(_strip_meta(stripped))
            if rm:
                table[rm.group(1).lstrip("%")] = rm.group(2)
    return comps, syms


class HloCost:
    """Loop-aware cost walk over a parsed HLO module: while bodies multiply
    by their inferred trip counts (XLA's own cost_analysis counts them
    once), giving honest FLOPs/bytes for scan-heavy models."""

    def __init__(self, hlo_text: str):
        self.comps, self.syms = _parse_computations(hlo_text)
        self._memo: Dict[Tuple[str, bool], Dict[str, float]] = {}
        self._cur_comp: str = "__entry__"

    def entry_cost(self) -> Dict[str, float]:
        """Aggregate cost dict for the module's entry computation."""
        return self._comp_cost("__entry__", flops_only=False)

    # ------------------------------------------------------------------
    def _comp_cost(self, name: str, flops_only: bool) -> Dict[str, float]:
        key = (name, flops_only)
        if key in self._memo:
            return self._memo[key]
        zero = {"flops": 0.0, "bytes": 0.0, "wire_bytes": 0.0}
        zero.update({c: 0.0 for c in COLLECTIVES})
        body = self.comps.get(name)
        if body is None:
            self._memo[key] = zero
            return zero
        total = dict(zero)
        for line in body:
            c = self._instr_cost(line, flops_only, name)
            for k in total:
                total[k] += c.get(k, 0.0)
        self._memo[key] = total
        return total

    def _instr_cost(self, line: str, flops_only: bool,
                    comp: str = "__entry__") -> Dict[str, float]:
        out = {"flops": 0.0, "bytes": 0.0, "wire_bytes": 0.0}
        out.update({c: 0.0 for c in COLLECTIVES})
        clean = _strip_meta(line)
        m = HLO_OP_RE.match(clean)
        if not m:
            return out
        op = m.group("op")
        rtype = m.group("type")

        if op == "while":
            trip = 1
            tm = TRIP_RE.search(line)
            if tm:
                trip = int(tm.group(1))
            cm = CALL_ATTR_RE.findall(clean)
            names = [n.strip().lstrip("%") for grp in cm
                     for n in grp.split(",")]
            # condition + body both execute per iteration
            for n in names:
                sub = self._comp_cost(n, flops_only)
                for k in out:
                    out[k] += trip * sub.get(k, 0.0)
            return out
        if op in ("call", "async-start"):
            for grp in CALL_ATTR_RE.findall(clean):
                for n in grp.split(","):
                    sub = self._comp_cost(n.strip().lstrip("%"), flops_only)
                    for k in out:
                        out[k] += sub.get(k, 0.0)
            return out
        if op == "conditional":
            branches = []
            for grp in CALL_ATTR_RE.findall(clean):
                for n in grp.split(","):
                    branches.append(
                        self._comp_cost(n.strip().lstrip("%"), flops_only))
            if branches:
                for k in out:
                    out[k] = max(b.get(k, 0.0) for b in branches)
            return out
        if op == "fusion":
            # descend for flops; memory traffic = fusion boundary
            called = []
            for grp in CALL_ATTR_RE.findall(clean):
                for n in grp.split(","):
                    called.append(n.strip().lstrip("%"))
                    sub = self._comp_cost(called[-1], flops_only=True)
                    out["flops"] += sub["flops"]
            if not flops_only:
                io = self._io_bytes(clean, comp, op)
                # fusion rooted in dynamic-update-slice is in-place: the
                # buffer operand and full-buffer result don't move — only
                # the update slice is read + written.  (Name heuristic
                # covers dus+convert fusions whose root is the convert.)
                root_dus = any(self._root_is_dus(n) for n in called) or \
                    "dynamic-update-slice" in clean.split("=")[0]
                if root_dus:
                    rbytes = _shape_bytes(clean.split(" fusion(")[0])
                    io = max(io - 2.0 * rbytes, 0.0) + \
                        2.0 * self._dus_update_bytes(called)
                out["bytes"] += io
            return out

        if op == "dot":
            out["flops"] += self._dot_flops(clean, comp)
            if not flops_only:
                out["bytes"] += self._io_bytes(clean, comp, op)
            return out
        if op == "convolution":
            out["flops"] += self._conv_flops(clean)
            if not flops_only:
                out["bytes"] += self._io_bytes(clean, comp, op)
            return out
        if op in COLLECTIVES or op.startswith(tuple(
                c + "-start" for c in COLLECTIVES)):
            base = op.replace("-start", "")
            _, byts = _result_elems_and_bytes(rtype)
            out[base] = out.get(base, 0.0) + byts
            out["wire_bytes"] += COLLECTIVE_MULT.get(base, 1.0) * byts
            if not flops_only:
                out["bytes"] += self._io_bytes(clean, comp, op)
            return out
        if op in FREE_OPS or op.endswith("-done"):
            return out
        if not flops_only:
            if op in ("dynamic-update-slice", "scatter"):
                # in-place on real hardware: traffic = the update slice
                # (read) + its write into the buffer, not the whole buffer
                out["bytes"] += self._update_bytes(clean, comp, op)
            else:
                out["bytes"] += self._io_bytes(clean, comp, op)
        return out

    def _root_is_dus(self, comp_name: str) -> bool:
        body = self.comps.get(comp_name, [])
        for line in body:
            if line.startswith("ROOT"):
                return " dynamic-update-slice(" in line
        return False

    def _dus_update_bytes(self, called) -> float:
        for name in called:
            table = self.syms.get(name, {})
            for line in self.comps.get(name, []):
                if " dynamic-update-slice(" in line:
                    m = re.search(r"dynamic-update-slice\(([^)]*)\)", line)
                    if m:
                        args = [a.strip() for a in m.group(1).split(",")]
                        if len(args) >= 2:
                            arg = args[1]
                            if SHAPE_RE.search(arg):
                                return float(_shape_bytes(arg))
                            return float(_shape_bytes(
                                table.get(arg.lstrip("%"), "")))
        return 0.0

    def _update_bytes(self, clean: str, comp: str, op: str) -> float:
        m = re.search(re.escape(op) + r"\(([^)]*)\)", clean)
        if not m:
            return 0.0
        args = [a.strip() for a in m.group(1).split(",") if a.strip()]
        table = self.syms.get(comp, {})
        total = 0.0
        # args[0] = buffer (skip); count the update operand + small indices
        for arg in args[1:]:
            if SHAPE_RE.search(arg):
                total += _shape_bytes(arg)
            else:
                total += _shape_bytes(table.get(arg.lstrip("%"), ""))
        return 2.0 * total  # read update + write into buffer

    def _io_bytes(self, clean: str, comp: str, op: str) -> float:
        """result bytes + operand bytes (operands resolved via the
        computation's symbol table when not inline-typed)."""
        b = float(_shape_bytes(clean.split(" " + op + "(")[0]))
        m = re.search(re.escape(op) + r"\(([^)]*)\)", clean)
        if m:
            table = self.syms.get(comp, {})
            for arg in m.group(1).split(","):
                arg = arg.strip()
                if not arg:
                    continue
                if SHAPE_RE.search(arg):
                    b += _shape_bytes(arg)
                else:
                    b += _shape_bytes(table.get(arg.lstrip("%"), ""))
        return b

    # ------------------------------------------------------------------
    _DOT_ARGS_RE = re.compile(r"dot\(([^)]*)\)")

    def _dot_operand_types(self, line: str, comp: str) -> List[str]:
        m = self._DOT_ARGS_RE.search(line)
        if not m:
            return []
        table = self.syms.get(comp, {})
        types = []
        for arg in m.group(1).split(","):
            arg = arg.strip()
            if SHAPE_RE.search(arg):       # inline-typed operand
                types.append(arg)
            else:
                types.append(table.get(arg.lstrip("%"), ""))
        return types

    def _dot_flops(self, line: str, comp: str) -> float:
        shapes = SHAPE_RE.findall(line.split(" dot(")[0])
        if not shapes:
            return 0.0
        _, rdims = shapes[0]
        relems = 1
        if rdims:
            for d in rdims.split(","):
                relems *= int(d)
        ops = self._dot_operand_types(line, comp)
        lshape: List[int] = []
        if ops:
            ls = SHAPE_RE.search(ops[0])
            if ls and ls.group(2):
                lshape = [int(d) for d in ls.group(2).split(",")]
        cm = re.search(r"lhs_contracting_dims=\{([0-9,]*)\}", line)
        contract = 1
        if cm and cm.group(1) and lshape:
            for i in cm.group(1).split(","):
                idx = int(i)
                if idx < len(lshape):
                    contract *= lshape[idx]
        return 2.0 * relems * contract


    def _conv_flops(self, line: str) -> float:
        shapes = SHAPE_RE.findall(line)
        if not shapes:
            return 0.0
        _, rdims = shapes[0]
        relems = 1
        if rdims:
            for d in rdims.split(","):
                relems *= int(d)
        wm = re.search(r"window=\{size=([0-9x]+)", line)
        ksize = 1
        if wm:
            for d in wm.group(1).split("x"):
                ksize *= int(d)
        fg = re.search(r"feature_group_count=(\d+)", line)
        # per-group input features
        in_feat = 1
        if len(shapes) >= 3:
            _, kdims = shapes[2]
            kd = [int(d) for d in kdims.split(",")] if kdims else []
            if len(kd) >= 2:
                in_feat = kd[-2]  # IO layout heuristic
        return 2.0 * relems * ksize * in_feat


def analyse_hlo(hlo_text: str) -> Dict[str, float]:
    """One-shot helper: loop-aware FLOPs/bytes/collectives for an HLO
    dump (see :class:`HloCost`)."""
    cost = HloCost(hlo_text).entry_cost()
    return cost
