"""Production-mesh dry-run: lower + compile every (arch, shape, mesh) cell
on 512 placeholder host devices and record memory / roofline / collective
analysis (EXPERIMENTS.md §Dry-run) — no arrays are ever materialized."""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# The env line above MUST run before any jax-importing module: jax locks the
# device count at first backend init.  512 placeholder host devices let
# jax.make_mesh build the production (2,16,16)/(16,16) meshes for the
# multi-pod dry-run: every (arch x shape x mesh) cell is lowered + compiled
# (ShapeDtypeStruct only, no allocation) and its memory/cost/collective
# analysis recorded for EXPERIMENTS.md §Dry-run / §Roofline.

import argparse   # noqa: E402
import json       # noqa: E402
import re         # noqa: E402
import traceback  # noqa: E402
from typing import Dict, Optional, Tuple  # noqa: E402

import jax        # noqa: E402
import jax.numpy as jnp  # noqa: E402
import numpy as np  # noqa: E402

from repro.configs import (SHAPES, TrainConfig, get_config, list_archs,  # noqa: E402
                           shape_applicable)
from repro.data import batch_logical_axes, batch_specs  # noqa: E402
from repro.launch.mesh import (make_production_mesh, sharding_for,  # noqa: E402
                               tree_shardings)
from repro.launch.train import TrainState, build_jit_train_step  # noqa: E402
from repro.models import build_model, split_params  # noqa: E402
from repro.obs import metrics as obs_metrics  # noqa: E402
from repro.optim import AdamWState, init_state  # noqa: E402

# TPU v5e hardware model (per chip)
PEAK_FLOPS = 197e12          # bf16
HBM_BW = 819e9               # bytes/s
ICI_BW = 50e9                # bytes/s/link

COLLECTIVE_RE = re.compile(
    r"=\s*(?P<rtype>\([^)]*\)|\S+)\s+"
    r"(?P<op>all-reduce|all-gather|reduce-scatter|all-to-all|"
    r"collective-permute)\b")
SHAPE_RE = re.compile(r"(f64|f32|bf16|f16|s32|u32|s8|u8|s64|u64|pred|s16|u16)"
                      r"\[([0-9,]*)\]")
DTYPE_BYTES = {"f64": 8, "f32": 4, "bf16": 2, "f16": 2, "s32": 4, "u32": 4,
               "s8": 1, "u8": 1, "pred": 1, "s64": 8, "u64": 8, "s16": 2,
               "u16": 2}
# ring-algorithm wire multipliers (bytes on the wire / result bytes)
COLLECTIVE_MULT = {"all-reduce": 2.0, "all-gather": 1.0,
                   "reduce-scatter": 1.0, "all-to-all": 1.0,
                   "collective-permute": 1.0}


def _bytes_of(type_str: str) -> int:
    total = 0
    for dt, dims in SHAPE_RE.findall(type_str):
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * DTYPE_BYTES[dt]
    return total


def collective_bytes(hlo_text: str) -> Dict[str, float]:
    """Per-collective result bytes + ring-model wire bytes parsed from an
    optimized HLO dump (regex scan; see COLLECTIVE_MULT)."""
    out: Dict[str, float] = {}
    wire = 0.0
    for m in COLLECTIVE_RE.finditer(hlo_text):
        op = m.group("op")
        b = _bytes_of(m.group("rtype"))
        out[op] = out.get(op, 0) + b
        wire += COLLECTIVE_MULT[op] * b
    out["wire_bytes"] = wire
    return out


# ---------------------------------------------------------------------------
# cell construction
# ---------------------------------------------------------------------------

def _eval_shape_tree(fn, *args, **kw):
    return jax.eval_shape(fn, *args, **kw)


def build_cell(arch: str, shape_name: str, mesh):
    """Returns (jitted, example_args) ready for .lower(*args)."""
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    model = build_model(cfg)

    ptree_sds = jax.eval_shape(
        lambda k: model.init_params(k), jax.random.key(0))
    params_sds, axes = split_params(ptree_sds)
    p_sh = tree_shardings(mesh, params_sds, axes)

    if shape.kind == "train":
        tcfg = TrainConfig()
        batch_ax = batch_logical_axes(cfg)
        b_sds = batch_specs(cfg, shape.global_batch, shape.seq_len)
        step_fn, shard_state, batch_shardings = build_jit_train_step(
            model, tcfg, mesh, axes, batch_ax)
        opt_sds = jax.eval_shape(init_state, params_sds)
        state_sds = TrainState(params_sds, opt_sds)
        state_sh = shard_state(params_sds)
        b_sh = batch_shardings(b_sds)
        jitted = jax.jit(step_fn, in_shardings=(state_sh, b_sh),
                         donate_argnums=(0,))
        return jitted, (state_sds, b_sds)

    # serving cells: bf16 params
    params_bf16 = jax.tree_util.tree_map(
        lambda s: jax.ShapeDtypeStruct(
            s.shape, jnp.bfloat16 if s.dtype == jnp.float32 else s.dtype),
        params_sds)
    p_sh16 = tree_shardings(mesh, params_bf16, axes)

    if shape.kind == "prefill":
        b_sds = batch_specs(cfg, shape.global_batch, shape.seq_len)
        batch_ax = batch_logical_axes(cfg)
        b_sh = {k: sharding_for(mesh, v.shape, batch_ax[k])
                for k, v in b_sds.items()}

        def prefill_fn(params, batch):
            return model.prefill(params, batch, mesh)

        jitted = jax.jit(prefill_fn, in_shardings=(p_sh16, b_sh))
        return jitted, (params_bf16, b_sds)

    # decode
    state_sds = jax.eval_shape(
        lambda: model.make_serve_state(shape.global_batch, shape.seq_len,
                                       mesh))
    st_ax = model.state_logical_axes(state_sds)
    st_sh = {k: sharding_for(mesh, v.shape, st_ax[k])
             for k, v in state_sds.items()}
    tok_sds = jax.ShapeDtypeStruct((shape.global_batch,), jnp.int32)
    tok_sh = sharding_for(mesh, tok_sds.shape, ("batch",))

    def serve_step(params, state, tokens):
        # identity layout: every block exclusively owned -> owner-mode
        return model.decode_step(params, state, tokens, mesh,
                                 exclusive=True)

    jitted = jax.jit(serve_step, in_shardings=(p_sh16, st_sh, tok_sh),
                     donate_argnums=(1,))
    return jitted, (params_bf16, state_sds, tok_sds)


# ---------------------------------------------------------------------------
# roofline terms
# ---------------------------------------------------------------------------

def analyse(compiled, cfg, shape, n_chips: int) -> Dict:
    """Roofline terms for one compiled cell: loop-aware FLOPs/bytes,
    per-chip memory, collective wire bytes, and the resulting
    compute/HBM/ICI-bound step-time estimate."""
    # loop-aware walk of the optimized per-device HLO (xla's cost_analysis
    # counts while bodies once — see hlo_analysis.py)
    from repro.launch.hlo_analysis import analyse_hlo
    hcost = analyse_hlo(compiled.as_text())
    flops = float(hcost["flops"])
    byts = float(hcost["bytes"])
    coll = {k: v for k, v in hcost.items()
            if k in COLLECTIVE_MULT or k == "wire_bytes"}
    xla_cost = compiled.cost_analysis()
    mem = compiled.memory_analysis()
    memd = {}
    for attr in ("argument_size_in_bytes", "output_size_in_bytes",
                 "temp_size_in_bytes", "generated_code_size_in_bytes",
                 "alias_size_in_bytes"):
        memd[attr] = int(getattr(mem, attr, 0) or 0)
    # cost_analysis is the per-device SPMD program
    t_compute = flops / PEAK_FLOPS
    t_memory = byts / HBM_BW
    t_coll = coll.get("wire_bytes", 0.0) / ICI_BW
    dominant = max((("compute", t_compute), ("memory", t_memory),
                    ("collective", t_coll)), key=lambda kv: kv[1])[0]
    n_tok = shape.global_batch * (shape.seq_len if shape.kind == "train"
                                  else (shape.seq_len if shape.kind ==
                                        "prefill" else 1))
    model_flops = 6.0 * cfg.active_param_count() * n_tok
    if shape.kind == "train":
        pass  # 6ND covers fwd+bwd
    else:
        model_flops /= 3.0  # forward only = 2ND
    per_dev_model_flops = model_flops / n_chips
    return {
        "hlo_flops_per_dev": flops,
        "hlo_bytes_per_dev": byts,
        "xla_flops_onepass": float(xla_cost.get("flops", 0.0)),
        "collectives": coll,
        "memory": memd,
        "t_compute_s": t_compute,
        "t_memory_s": t_memory,
        "t_collective_s": t_coll,
        "dominant": dominant,
        "model_flops_per_dev": per_dev_model_flops,
        "useful_flop_ratio": (per_dev_model_flops / flops) if flops else 0.0,
        "roofline_fraction": (per_dev_model_flops / PEAK_FLOPS /
                              max(t_compute, t_memory, t_coll))
        if max(t_compute, t_memory, t_coll) > 0 else 0.0,
    }


def run_cell(arch: str, shape_name: str, multi_pod: bool) -> Dict:
    """Lower + compile one (arch, shape, mesh) cell and return its row
    for the dry-run report (status/skip/error + analysis)."""
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    ok, reason = shape_applicable(cfg, shape)
    row = {"arch": arch, "shape": shape_name,
           "mesh": "2x16x16" if multi_pod else "16x16"}
    if not ok:
        row.update(status="skip", reason=reason)
        return row
    t0 = obs_metrics.now()
    mesh = make_production_mesh(multi_pod=multi_pod)
    try:
        with mesh:
            jitted, args = build_cell(arch, shape_name, mesh)
            lowered = jitted.lower(*args)
            t_lower = obs_metrics.now() - t0
            compiled = lowered.compile()
            t_compile = obs_metrics.now() - t0 - t_lower
            n_chips = int(np.prod(mesh.devices.shape))
            row.update(status="ok", lower_s=round(t_lower, 1),
                       compile_s=round(t_compile, 1),
                       **analyse(compiled, cfg, shape, n_chips))
    except Exception as e:  # noqa: BLE001
        row.update(status="error", error=f"{type(e).__name__}: {e}",
                   traceback=traceback.format_exc()[-2000:])
    return row


def main():
    """CLI: sweep the requested (arch, shape, mesh) cells and write the
    dry-run JSON report."""
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--mesh", choices=["single", "multi", "both"],
                    default="single")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--out", default="results/dryrun.jsonl")
    ap.add_argument("--resume", action="store_true",
                    help="skip cells already in --out")
    args = ap.parse_args()

    archs = list_archs() if args.all or args.arch is None else [args.arch]
    shapes = list(SHAPES) if args.all or args.shape is None else [args.shape]
    meshes = {"single": [False], "multi": [True],
              "both": [False, True]}[args.mesh]

    os.makedirs(os.path.dirname(args.out) or ".", exist_ok=True)
    done = set()
    if args.resume and os.path.exists(args.out):
        with open(args.out) as f:
            for line in f:
                r = json.loads(line)
                if r.get("status") in ("ok", "skip"):
                    done.add((r["arch"], r["shape"], r["mesh"]))

    with open(args.out, "a") as f:
        for arch in archs:
            for shape in shapes:
                for mp in meshes:
                    key = (arch, shape, "2x16x16" if mp else "16x16")
                    if key in done:
                        continue
                    print(f"[dryrun] {key} ...", flush=True)
                    row = run_cell(arch, shape, mp)
                    print(f"[dryrun] {key} -> {row['status']} "
                          f"{row.get('dominant', row.get('reason', row.get('error','')))[:120]}",
                          flush=True)
                    f.write(json.dumps(row) + "\n")
                    f.flush()


if __name__ == "__main__":
    main()
