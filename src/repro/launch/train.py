"""Training driver: jit'd train step (ZeRO-3 + TP), microbatch accumulation,
optional compressed DP all-reduce, checkpoint/restart, straggler ledger.

Usable both as the dry-run target (make_train_step -> jit -> lower) and as a
real CLI for CPU-scale runs:

    PYTHONPATH=src python -m repro.launch.train --arch llama3.2-3b \
        --smoke --steps 50
"""
from __future__ import annotations

import argparse
import functools
import time
from typing import Any, Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import CheckpointManager
from repro.configs import TrainConfig, get_config
from repro.data import batch_logical_axes, batch_specs, make_batch
from repro.launch.mesh import make_test_mesh, sharding_for, tree_shardings
from repro.models import build_model, split_params
from repro.models.common import stack_param_axes
from repro.optim import AdamWState, apply_updates, init_state
from repro.runtime import HeartbeatLedger, NodeFailure, RestartPolicy


class TrainState(NamedTuple):
    params: Any
    opt: AdamWState


def make_train_step(model, tcfg: TrainConfig, mesh):
    """Returns train_step(state, batch) -> (state, metrics).

    Microbatching: the batch's leading dim is split into ``tcfg.microbatches``
    slices scanned with fp32 grad accumulation — the per-microbatch backward
    pass's DP reduction overlaps the next microbatch's compute (XLA's
    latency-hiding scheduler sees independent collectives inside the scan).

    ``tcfg.sharding``: 'fsdp' activates FSDP_RULES during tracing (pure DP
    over every mesh axis, ZeRO-3 params — no activation collectives;
    §Perf iteration 3), 'tp' keeps the Megatron-style DEFAULT_RULES.
    """
    from repro.sharding.rules import DEFAULT_RULES, FSDP_RULES, use_rules
    rules = FSDP_RULES if tcfg.sharding == "fsdp" else DEFAULT_RULES

    def loss_fn(params, batch):
        return model.loss_fn(params, batch, mesh, remat=tcfg.remat_policy)

    def cast_bf16(params):
        """Mixed precision: compute against a bf16 view of the fp32 master
        (matrix params only).  The ZeRO-3 all-gathers inside the layer scan
        then move bf16 — half the wire bytes (§Perf iteration 2)."""
        return jax.tree_util.tree_map(
            lambda p: p.astype(jnp.bfloat16)
            if p.dtype == jnp.float32 and p.ndim > 1 else p, params)

    def grads_of(params, batch):
        (loss, metrics), grads = jax.value_and_grad(
            lambda p, b: loss_fn(cast_bf16(p), b), has_aux=True)(
                params, batch)
        return loss, metrics, grads

    def _train_step(state: TrainState, batch):
        params = state.params
        m = tcfg.microbatches
        if m > 1:
            def micro(carry, mb):
                acc, loss_acc = carry
                loss, metrics, grads = grads_of(params, mb)
                acc = jax.tree_util.tree_map(
                    lambda a, g: a + g.astype(jnp.float32), acc, grads)
                return (acc, loss_acc + loss), None

            mbatches = jax.tree_util.tree_map(
                lambda x: x.reshape((m, x.shape[0] // m) + x.shape[1:]),
                batch)
            zeros = jax.tree_util.tree_map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params)
            (grads, loss), _ = jax.lax.scan(
                micro, (zeros, jnp.float32(0)), mbatches)
            grads = jax.tree_util.tree_map(lambda g: g / m, grads)
            loss = loss / m
            metrics = {}
        else:
            loss, metrics, grads = grads_of(params, batch)
        new_params, new_opt, om = apply_updates(params, grads, state.opt,
                                                tcfg)
        out = {"loss": loss, **{k: v for k, v in metrics.items()}, **om}
        return TrainState(new_params, new_opt), out

    def train_step(state: TrainState, batch):
        with use_rules(rules):
            return _train_step(state, batch)

    return train_step


def build_jit_train_step(model, tcfg: TrainConfig, mesh, params_axes,
                         batch_ax):
    """jit with explicit in/out shardings + donation (params updated in
    place at the XLA level)."""
    from repro.sharding.rules import DEFAULT_RULES, FSDP_RULES, use_rules
    rules = FSDP_RULES if tcfg.sharding == "fsdp" else DEFAULT_RULES
    step_fn = make_train_step(model, tcfg, mesh)

    def shard_state(params_like):
        with use_rules(rules):
            p_sh = tree_shardings(mesh, params_like, params_axes)
            opt_sh = AdamWState(
                sharding_for(mesh, (), ()),
                p_sh, p_sh)
        return TrainState(p_sh, opt_sh)

    def batch_shardings(batch_like):
        with use_rules(rules):
            return {k: sharding_for(mesh, v.shape, batch_ax[k])
                    for k, v in batch_like.items()}

    return step_fn, shard_state, batch_shardings


# ---------------------------------------------------------------------------
# CLI driver (CPU-scale end-to-end)
# ---------------------------------------------------------------------------

def train_loop(arch: str, steps: int = 50, batch: int = 4, seq_len: int = 128,
               smoke: bool = True, ckpt_dir: Optional[str] = None,
               microbatches: int = 1, mesh=None, inject_failure_at:
               Optional[int] = None, log_every: int = 10,
               checkpoint_every: int = 20, seed: int = 0,
               learning_rate: float = 3e-4):
    """CPU-scale end-to-end training driver: synthetic batches through the
    jit'd train step, with optional checkpointing and fault injection
    (the elastic-runtime tests drive it).  Returns the final metrics."""
    cfg = get_config(arch)
    if smoke:
        cfg = cfg.reduced()
    tcfg = TrainConfig(total_steps=steps, warmup_steps=max(steps // 10, 1),
                       microbatches=microbatches, seed=seed,
                       learning_rate=learning_rate)
    model = build_model(cfg)
    ptree = model.init_params(jax.random.key(tcfg.seed))
    params, axes = split_params(ptree)
    state = TrainState(params, init_state(params))
    step_fn = make_train_step(model, tcfg, mesh)
    jit_step = jax.jit(step_fn, donate_argnums=(0,))

    ckpt = CheckpointManager(ckpt_dir) if ckpt_dir else None
    ledger = HeartbeatLedger()
    start = 0
    if ckpt and ckpt.latest_step() is not None:
        state, start = ckpt.restore(state)
        print(f"[train] restored step {start}")

    losses = []
    for step in range(start, steps):
        if inject_failure_at is not None and step == inject_failure_at:
            raise NodeFailure(f"injected at step {step}")
        ledger.step_start()
        np_batch = make_batch(cfg, batch, seq_len, step)
        batch_dev = {k: jnp.asarray(v) for k, v in np_batch.items()}
        state, metrics = jit_step(state, batch_dev)
        rep = ledger.step_end(step)
        if rep is not None:
            print(f"[straggler] step {rep.step} {rep.ratio:.1f}x median")
        losses.append(float(metrics["loss"]))
        if step % log_every == 0:
            print(f"[train] step {step} loss {losses[-1]:.4f} "
                  f"lr {float(metrics['lr']):.2e} "
                  f"gnorm {float(metrics['grad_norm']):.3f}")
        if ckpt and (step + 1) % checkpoint_every == 0:
            ckpt.save(step + 1, state)
    if ckpt:
        ckpt.wait()
    return state, losses


def main():
    """CLI wrapper over :func:`train_loop`."""
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--seq-len", type=int, default=128)
    ap.add_argument("--smoke", action="store_true", default=True)
    ap.add_argument("--full", dest="smoke", action="store_false")
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--microbatches", type=int, default=1)
    args = ap.parse_args()
    _, losses = train_loop(args.arch, steps=args.steps, batch=args.batch,
                           seq_len=args.seq_len, smoke=args.smoke,
                           ckpt_dir=args.ckpt_dir,
                           microbatches=args.microbatches)
    print(f"[train] done; loss {losses[0]:.4f} -> {losses[-1]:.4f}")


if __name__ == "__main__":
    main()
