"""Traffic layer: continuous batching with per-tenant QoS lanes.

The :class:`RequestScheduler` closes the loop between request traffic
and the serving engine's round structure.  Each :meth:`step` is one
continuous-batching round (admit/evict EVERY round, not batch-at-once):

1. **retire** — requests that hit their token budget free their
   sequences (``ServingEngine.free`` — the fixed lifecycle path: queued
   promotions retire, staging slots recycle, host state drops);
2. **resume** — previously preempted requests promote their parked KV
   bytes back from the spill slots when capacity allows
   (``ServingEngine.resume``), continuing bitwise-identically;
3. **admit** — queued requests enter in tenant-priority order while the
   admission PRECHECK holds (a free batch slot, enough free pool blocks
   with one-tail-block headroom per live sequence, enough staging
   slots) — prechecking is what keeps bursts from forcing early drains;
4. **preempt** — when a higher-priority request is still waiting,
   victims from strictly-lower-priority tenants demote to the spill
   pools (``ServingEngine.demote`` — ``OP_CROSS_POOL_COPY``, the reverse
   of admission promotion).  The victims' blocks return to the allocator
   at the round's flush, so the freed capacity admits the waiter NEXT
   round — preempting never costs an extra launch;
5. **merge + decode** — every tenant lane
   (:class:`~repro.core.stream.CommandStream` per tenant) is ADOPTED
   into the engine's serve stream in priority order (adoption order is
   DMA issue order in the fused table), then ``decode_round`` drains the
   whole round's bulk movement as ONE launch and decodes one token for
   every live sequence.

The per-round invariant the benchmark gate holds: **launches/round stays
1.0 under churn** — admission, preemption, resumption, CoW forks and
tail inits all ride the round's single fused launch.

Quickstart::

    sched = RequestScheduler(eng, [TenantSpec("gold", priority=2),
                                   TenantSpec("free", priority=0)])
    sched.submit("gold", prompt, max_new_tokens=32)
    while not sched.idle:
        report = sched.step()      # one continuous-batching round
"""
from __future__ import annotations

import collections
import dataclasses
from typing import Deque, Dict, List, Optional, Sequence

import numpy as np

from repro.launch.serve import ServingEngine
from repro.obs import metrics as obs_metrics


@dataclasses.dataclass(frozen=True)
class TenantSpec:
    """One tenant's QoS contract: a name and a priority (higher wins).

    Each tenant gets a dedicated command-stream lane; admission and
    preemption order follow ``priority`` (ties break by submission
    order).  Preemption is strict: a waiting request only evicts victims
    from tenants with STRICTLY lower priority."""

    name: str          #: tenant id (lane name: ``lane:<name>``)
    priority: int = 0  #: higher = more important


@dataclasses.dataclass
class Request:
    """One inference request's lifecycle record.

    ``state`` walks ``queued → running → done`` with a possible
    ``preempted`` detour (demoted to spill, later resumed under a NEW
    engine sid — ``sid`` always names the current sequence).  Round
    indices (``submitted_round``/``first_token_round``/``done_round``)
    let a closed-loop driver compute queueing and token latencies
    without the scheduler owning a clock."""

    rid: int                     #: request id (scheduler-wide)
    tenant: str                  #: owning tenant
    prompt: np.ndarray           #: (S,) int32 prompt tokens
    max_new_tokens: int          #: decode budget
    state: str = "queued"        #: queued|running|preempted|done|cancelled
    sid: Optional[int] = None    #: current engine sequence id
    generated: int = 0           #: decode tokens produced so far
    submitted_round: int = -1    #: round index at submit()
    first_token_round: int = -1  #: round index of the first decode token
    done_round: int = -1         #: round index the request finished
    preemptions: int = 0         #: times this request was demoted
    #: decode tokens produced, in order — survives the sequence's free
    #: (the engine's per-sid history dies with the sid)
    tokens_out: List[int] = dataclasses.field(default_factory=list)


@dataclasses.dataclass
class RoundReport:
    """Accounting for one :meth:`RequestScheduler.step` round."""

    round_index: int             #: which round this was
    launches: int                #: bulk-movement launches (gate: == 1)
    commands: int                #: command rows the round's flush drained
    admitted: List[int]          #: rids admitted this round
    finished: List[int]          #: rids retired this round
    preempted: List[int]         #: rids demoted this round
    resumed: List[int]           #: rids resumed this round
    tokens: Dict[str, int]       #: decode tokens per tenant this round
    round_us: float = 0.0        #: this round's wall-clock (step() span)
    p50_round_us: float = 0.0    #: running median over rounds so far
    p99_round_us: float = 0.0    #: running p99 over rounds so far


class _Lane:
    """One tenant's admission lane: a FIFO of queued requests plus a
    dedicated CommandStream the lane's bulk movement lands on."""

    def __init__(self, spec: TenantSpec, stream):
        self.spec = spec
        self.stream = stream
        self.queued: Deque[Request] = collections.deque()


class RequestScheduler:
    """Continuous-batching scheduler over a :class:`ServingEngine`.

    Maps tenants onto per-tenant QoS lanes (dedicated command streams),
    admits/evicts every round, and preempts by demotion — see the module
    docstring for the round structure.  The engine must be built with
    ``spill_pages > 0`` for preemption to be available; without it the
    scheduler still batches continuously but never preempts."""

    def __init__(self, eng: ServingEngine, tenants: Sequence[TenantSpec]):
        if not tenants:
            raise ValueError("need at least one TenantSpec")
        names = [t.name for t in tenants]
        if len(set(names)) != len(names):
            raise ValueError(f"duplicate tenant names: {names}")
        self.eng = eng
        #: lanes in priority order (highest first) — adoption order
        self.lanes: Dict[str, _Lane] = {
            t.name: _Lane(t, eng.engine.stream(f"lane:{t.name}"))
            for t in sorted(tenants, key=lambda t: -t.priority)}
        self.requests: Dict[int, Request] = {}
        self._by_sid: Dict[int, int] = {}     # engine sid -> rid
        self._running: List[int] = []         # rids with a live sequence
        self._preempted: List[int] = []       # rids parked in spill slots
        self._next_rid = 0
        self.round_index = 0
        self.reports: List[RoundReport] = []
        self._round_us: List[float] = []   # per-round wall-clock history

    # ------------------------------------------------------------------
    @property
    def idle(self) -> bool:
        """True when no request is queued, running, or preempted."""
        return not (self._running or self._preempted or
                    any(l.queued for l in self.lanes.values()))

    def submit(self, tenant: str, prompt: np.ndarray,
               max_new_tokens: int = 16) -> int:
        """Queue a request on ``tenant``'s lane; returns the request id.
        Admission happens inside a later :meth:`step` when the precheck
        passes — submit never blocks and never touches the device."""
        if tenant not in self.lanes:
            raise KeyError(f"unknown tenant {tenant!r} "
                           f"(have {sorted(self.lanes)})")
        req = Request(rid=self._next_rid, tenant=tenant,
                      prompt=np.asarray(prompt, np.int32),
                      max_new_tokens=int(max_new_tokens),
                      submitted_round=self.round_index)
        self._next_rid += 1
        self.requests[req.rid] = req
        self.lanes[tenant].queued.append(req)
        return req.rid

    def cancel(self, rid: int) -> None:
        """Abort a request in any state.  A running request frees
        mid-round — the lifecycle path ``ServingEngine.free`` fixes:
        queued promotions retire instead of landing in re-issued
        blocks."""
        req = self.requests[rid]
        if req.state == "queued":
            self.lanes[req.tenant].queued.remove(req)
        elif req.state in ("running", "preempted"):
            self.eng.free(req.sid)
            self._by_sid.pop(req.sid, None)
            if rid in self._running:
                self._running.remove(rid)
            if rid in self._preempted:
                self._preempted.remove(rid)
        req.state = "cancelled"
        req.done_round = self.round_index

    # ------------------------------------------------------------------
    # round internals
    # ------------------------------------------------------------------
    def _blocks_needed(self, length: int) -> int:
        page = self.eng.cache.page
        return max((int(length) + page - 1) // page, 0)

    def _admission_room(self, need_blocks: int) -> bool:
        """Admission precheck: a batch slot, free pool blocks with one
        tail block of headroom per live sequence (decode growth must
        never fail mid-round), and staging-ring room so ``stage_blocks``
        cannot force an early drain."""
        cache = self.eng.cache
        if len(cache.seqs) >= cache.max_seqs:
            return False
        headroom = len(cache.seqs)
        if cache.alloc.total_free() < need_blocks + headroom:
            return False
        if self.eng.fused_staging and \
                self.eng.engine.stage_slots_free < need_blocks:
            return False
        return True

    def _retire_finished(self) -> List[int]:
        done = []
        for rid in list(self._running):
            req = self.requests[rid]
            if req.generated >= req.max_new_tokens:
                self.eng.free(req.sid)
                self._by_sid.pop(req.sid, None)
                self._running.remove(rid)
                req.state = "done"
                req.done_round = self.round_index
                done.append(rid)
        return done

    def _admission_room_resume(self, need_blocks: int) -> bool:
        cache = self.eng.cache
        if len(cache.seqs) >= cache.max_seqs:
            return False
        return cache.alloc.total_free() >= need_blocks + len(cache.seqs)

    def _resume_one(self, rid: int) -> bool:
        req = self.requests[rid]
        parked = self.eng.demoted.get(req.sid)
        if parked is None:              # defensive: lost the parking
            self._preempted.remove(rid)
            return False
        if not self._admission_room_resume(len(parked.slots)):
            return False
        new_sid = self.eng.resume(req.sid,
                                  stream=self.lanes[req.tenant].stream)
        self._by_sid.pop(req.sid, None)
        req.sid = new_sid
        self._by_sid[new_sid] = rid
        req.state = "running"
        self._preempted.remove(rid)
        self._running.append(rid)
        return True

    def _admit_and_resume(self) -> tuple:
        """One priority-ordered pass over preempted + queued work.

        Within a lane, parked (preempted) requests resume before new
        admissions — older work first.  Across lanes, strictly priority
        order: a lower-priority lane never resumes into capacity a
        higher-priority waiter is about to admit into (resuming first
        would thrash — resume, demote again, repeat)."""
        admitted, resumed = [], []
        for lane in self.lanes.values():    # already priority-sorted
            parked = [r for r in list(self._preempted)
                      if self.requests[r].tenant == lane.spec.name]
            blocked = False
            for rid in parked:              # preemption order (FIFO)
                if self._resume_one(rid):
                    resumed.append(rid)
                else:
                    blocked = True
                    break
            if blocked:
                continue   # queued work must not overtake parked work
            while lane.queued:
                req = lane.queued[0]
                if not self._admission_room(
                        self._blocks_needed(len(req.prompt))):
                    break
                lane.queued.popleft()
                req.sid = self.eng.add_request(req.prompt,
                                               stream=lane.stream)
                self._by_sid[req.sid] = req.rid
                req.state = "running"
                self._running.append(req.rid)
                admitted.append(req.rid)
        return admitted, resumed

    def _preempt_for_waiters(self) -> List[int]:
        """Demote lowest-priority victims when a strictly-higher-priority
        request is still waiting — the freed blocks come back at the
        round's flush, so the waiter admits next round at zero extra
        launches."""
        if not self.eng.spill_pages:
            return []
        preempted = []
        for lane in self.lanes.values():
            # the lane's frontmost waiter: its oldest parked request
            # (resume blocked this round), else its queued head
            parked = [r for r in self._preempted
                      if self.requests[r].tenant == lane.spec.name]
            if parked:
                need = len(self.eng.demoted[self.requests[parked[0]].sid]
                           .slots)
            elif lane.queued:
                need = self._blocks_needed(len(lane.queued[0].prompt))
            else:
                continue
            if self._admission_room(need):
                continue   # waiting on staging, not on blocks/slots
            # victims: running requests of strictly lower priority,
            # lowest first, newest first within a priority tier
            victims = sorted(
                (r for r in self._running
                 if self.lanes[self.requests[r].tenant].spec.priority
                 < lane.spec.priority),
                key=lambda r: (self.lanes[self.requests[r].tenant]
                               .spec.priority, -r))
            freed = 0
            for vid in victims:
                vreq = self.requests[vid]
                if vreq.sid in self.eng._staged_sids:
                    continue   # admitted this round — demote next round
                vblocks = len(self.eng.cache.blocks_of(vreq.sid))
                if self.eng.engine.spill_slots_free < vblocks:
                    break      # spill parking exhausted
                self.eng.demote(vreq.sid,
                                stream=self.lanes[vreq.tenant].stream)
                # sid stays the key into eng.demoted until resume
                self._running.remove(vid)
                self._preempted.append(vid)
                vreq.state = "preempted"
                vreq.preemptions += 1
                preempted.append(vid)
                freed += vblocks
                if freed >= need:
                    break
        return preempted

    # ------------------------------------------------------------------
    def step(self, sample_fn=None) -> RoundReport:
        """Run ONE continuous-batching round (see the module docstring
        for the five stages) and return its :class:`RoundReport` —
        timed with the shared obs stopwatch, carrying the running
        p50/p99 round latency."""
        with obs_metrics.Stopwatch() as sw:
            finished = self._retire_finished()
            admitted, resumed = self._admit_and_resume()
            preempted = self._preempt_for_waiters()
            # lane merge: adopt every lane's pending rows onto the serve
            # stream in priority order — one flush, one launch, priority
            # traffic first in the fused table
            for lane in self.lanes.values():
                self.eng.stream.adopt(lane.stream)
            toks = self.eng.decode_round(sample_fn=sample_fn)
            per_tenant: Dict[str, int] = {t: 0 for t in self.lanes}
            for sid in toks:
                rid = self._by_sid.get(sid)
                if rid is None:
                    continue
                req = self.requests[rid]
                req.generated += 1
                req.tokens_out.append(int(toks[sid]))
                if req.first_token_round < 0:
                    req.first_token_round = self.round_index
                per_tenant[req.tenant] += 1
        self._round_us.append(sw.us)
        if obs_metrics.metrics_enabled():
            # per-lane lifecycle counters, labeled by tenant
            for rid_list, what in ((admitted, "admitted"),
                                   (finished, "finished"),
                                   (preempted, "preempted"),
                                   (resumed, "resumed")):
                for rid in rid_list:
                    obs_metrics.inc(f"lane.{what}",
                                    tenant=self.requests[rid].tenant)
            for tenant, n in per_tenant.items():
                if n:
                    obs_metrics.inc("lane.tokens", n, tenant=tenant)
            obs_metrics.observe("sched.round_us", sw.us)
        ticket = self.eng.last_ticket
        report = RoundReport(
            round_index=self.round_index,
            launches=ticket.launches if ticket is not None else 0,
            commands=ticket.commands if ticket is not None else 0,
            admitted=admitted, finished=finished,
            preempted=preempted, resumed=resumed, tokens=per_tenant,
            round_us=sw.us,
            p50_round_us=obs_metrics.percentile(self._round_us, 50),
            p99_round_us=obs_metrics.percentile(self._round_us, 99))
        self.reports.append(report)
        self.round_index += 1
        return report

    def drain(self, max_rounds: int = 10_000, sample_fn=None
              ) -> List[RoundReport]:
        """Step until :attr:`idle` (every submitted request finished),
        returning the round reports.  ``max_rounds`` guards against a
        workload that cannot finish (e.g. preempted requests that can
        never resume)."""
        out = []
        for _ in range(max_rounds):
            if self.idle:
                break
            out.append(self.step(sample_fn=sample_fn))
        else:
            raise RuntimeError(f"drain() did not converge in "
                               f"{max_rounds} rounds")
        return out


__all__ = ["RequestScheduler", "TenantSpec", "Request", "RoundReport"]
