"""Production mesh construction.

A FUNCTION, not a module-level constant — importing this module never
touches jax device state (the dry-run sets XLA_FLAGS before first init).
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.sharding import logical_to_spec


def make_production_mesh(*, multi_pod: bool = False):
    """16×16 = 256 chips/pod (TPU v5e pod slice); 2 pods = 512 chips."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_test_mesh(shape: Tuple[int, ...] = (2, 2),
                   axes: Tuple[str, ...] = ("data", "model")) -> Mesh:
    """Small named mesh over the first ``prod(shape)`` local devices (test
    and benchmark harnesses; raises when the host has too few)."""
    n = int(np.prod(shape))
    devs = jax.devices()
    if len(devs) < n:
        raise RuntimeError(f"need {n} devices, have {len(devs)}")
    return Mesh(np.asarray(devs[:n]).reshape(shape), axes)


def pool_shard_count(mesh: Optional[Mesh]) -> int:
    """Device shards of a block-pool's block axis (the arithmetic lives
    with the pool layout in models/paged.py; re-exported here for the
    launch layer)."""
    from repro.models.paged import pool_shard_count as _psc
    return _psc(mesh)


def pool_partition_spec(mesh: Mesh, spec=None, block_axis: int = 0):
    """PartitionSpec for one pool from its ``PoolSpec.sharding`` hint
    (models/paged.py owns the semantics; re-exported for the launch
    layer): None = default joint pool axes, ``()`` = replicated, a tuple
    = exactly those mesh axes."""
    from repro.models.paged import pool_partition_spec as _pps
    return _pps(mesh, spec, block_axis=block_axis)


def sharding_for(mesh: Mesh, shape: Tuple[int, ...], axes) -> NamedSharding:
    """Logical axes -> NamedSharding (divisibility-aware, uses the active
    rule set — mirrors sharding.rules.constrain)."""
    spec = logical_to_spec(axes, mesh, dims=tuple(shape[: len(axes)]))
    return NamedSharding(mesh, spec)


def tree_shardings(mesh: Mesh, value_tree, axes_tree, *,
                   block_axis: int = 0):
    """Matching pytree of NamedShardings.

    ``axes_tree`` leaves are logical-axis tuples — or
    :class:`~repro.core.poolspec.PoolSpec` descriptors, which resolve
    through their ``sharding`` hint via :func:`pool_partition_spec`
    (``block_axis`` positions the pool's block dimension): the hook that
    lets a serving layout replicate a small staging ring while its KV
    pools shard."""
    from repro.core.poolspec import PoolSpec

    def one(v, a):
        if isinstance(a, PoolSpec):
            return NamedSharding(
                mesh, pool_partition_spec(mesh, a, block_axis=block_axis))
        return sharding_for(mesh, v.shape, a)

    return jax.tree_util.tree_map(
        one, value_tree, axes_tree,
        is_leaf=lambda x: isinstance(x, PoolSpec) or (
            isinstance(x, tuple) and all(
                isinstance(e, (str, type(None))) for e in x)))
