"""PSM transfer kernel — RowClone Pipelined Serial Mode on TPU (TARGET code).

The DRAM mechanism: a new ``TRANSFER`` command moves cache lines between two
banks over the chip's shared internal bus, overlapping the read and the
write, never driving the external memory channel.  The TPU analogue: a
**remote DMA** kernel — ``pltpu.make_async_remote_copy`` pushes pool blocks
directly from this chip's HBM into a neighbour's HBM over ICI, without host
involvement and without touching VMEM/VREGs/MXU.  Pipelining (the paper's
overlapped READ/WRITE) comes from keeping ``PIPELINE_DEPTH`` RDMA sends in
flight.

CPU note: interpret mode cannot emulate cross-device RDMA, so this kernel is
validated structurally (it must lower for a multi-device mesh) while the
executable PSM path used everywhere on CPU is the collective formulation in
core/rowclone.py (``_psm_jit`` → XLA collective-permute).  On TPU the engine
would route cross-slab ``memcopy`` here.

Layout contract: the caller runs this inside shard_map over the pool axes;
``send_ids``/``recv_ids`` are slab-local block ids, ``target`` is the
destination device's linear index along the transfer axis.  Like FPM,
sources must be disjoint from in-flight destinations.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.compat import axis_size, tpu_compiler_params

PIPELINE_DEPTH = 2


def _psm_kernel(ids_ref, src_ref, _dst_in, dst_ref, send_sems, recv_sems, *,
                axis_name):
    """grid = (m,).  ids_ref rows: [src_local, dst_local, target_offset].

    target_offset is the signed hop count along ``axis_name`` (DRAM bank →
    neighbouring bank; ICI is a torus so most migrations are single-hop).
    """
    i = pl.program_id(0)
    src = ids_ref[i, 0]
    dst = ids_ref[i, 1]
    hop = ids_ref[i, 2]
    my = jax.lax.axis_index(axis_name)
    n = axis_size(axis_name)
    target = jax.lax.rem(my + hop + n, n)
    slot = jax.lax.rem(i, PIPELINE_DEPTH)

    @pl.when(src >= 0)
    def _():
        rdma = pltpu.make_async_remote_copy(
            src_ref.at[src], dst_ref.at[dst],
            send_sem=send_sems.at[slot], recv_sem=recv_sems.at[slot],
            device_id=(target,),
            device_id_type=pltpu.DeviceIdType.MESH,
        )
        rdma.start()
        # wait the transfer PIPELINE_DEPTH behind us, keeping that many
        # in flight — the paper's overlapped READ/WRITE pipelining
        rdma.wait()


@functools.partial(jax.jit, static_argnames=("axis_name",),
                   donate_argnums=(0,))
def psm_transfer_pallas(pool_slab, ids, *, axis_name: str = "model"):
    """pool_slab: this device's (nblk_local, ...) slab (inside shard_map);
    ids: (m, 3) int32 [src_local, dst_local_on_target, hop]; src=-1 skips.

    Returns the updated slab (receives remote writes via aliasing)."""
    return pl.pallas_call(
        functools.partial(_psm_kernel, axis_name=axis_name),
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=1,
            grid=(ids.shape[0],),
            in_specs=[pl.BlockSpec(memory_space=pl.ANY),
                      pl.BlockSpec(memory_space=pl.ANY)],
            out_specs=pl.BlockSpec(memory_space=pl.ANY),
            scratch_shapes=[
                pltpu.SemaphoreType.DMA((PIPELINE_DEPTH,)),
                pltpu.SemaphoreType.DMA((PIPELINE_DEPTH,)),
            ],
        ),
        out_shape=jax.ShapeDtypeStruct(pool_slab.shape, pool_slab.dtype),
        input_output_aliases={2: 0},
        compiler_params=tpu_compiler_params(collective_id=13),
    )(ids, pool_slab, pool_slab)
