"""BuZ kernel — RowClone bulk-zero via the reserved zero row.

The paper (§3.1) reserves one all-zero row per subarray and FPM-copies it
into any row to be zeroed, so zeroing never streams zeros from the CPU.  The
TPU analogue: a reserved zero *block* per device slab; ``meminit`` is a pure
HBM→HBM DMA broadcast of that block into every target block.  No zeros are
generated in VREGs and no vector-unit cycle is spent.

With RowClone-ZI (core/zero.py) most calls never reach this kernel at all —
the lazy-zero bit makes the zeroing metadata-only, the analogue of
clean-zero cache-line insertion.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _zero_init_kernel(ids_ref, zero_ref, _dst_in, dst_ref, sem0, sem1):
    i = pl.program_id(0)
    d = ids_ref[i]

    @pl.when(d >= 0)
    def _():
        @pl.when(i % 2 == 0)
        def _():
            cp = pltpu.make_async_copy(zero_ref.at[0], dst_ref.at[d], sem0)
            cp.start()
            cp.wait()

        @pl.when(i % 2 == 1)
        def _():
            cp = pltpu.make_async_copy(zero_ref.at[0], dst_ref.at[d], sem1)
            cp.start()
            cp.wait()


@functools.partial(jax.jit, static_argnames=("interpret",), donate_argnums=(0,))
def zero_init_pallas(pool, zero_block, ids, *, interpret: bool = False):
    """pool: (nblk, ...); zero_block: (1, ...) reserved row (same block
    shape); ids: (m,) int32 target blocks, -1 skips."""
    return pl.pallas_call(
        _zero_init_kernel,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=1,
            grid=(ids.shape[0],),
            in_specs=[pl.BlockSpec(memory_space=pl.ANY),
                      pl.BlockSpec(memory_space=pl.ANY)],
            out_specs=pl.BlockSpec(memory_space=pl.ANY),
            scratch_shapes=[pltpu.SemaphoreType.DMA,
                            pltpu.SemaphoreType.DMA],
        ),
        out_shape=jax.ShapeDtypeStruct(pool.shape, pool.dtype),
        input_output_aliases={2: 0},
        interpret=interpret,
    )(ids, zero_block, pool)
