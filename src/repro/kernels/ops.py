"""Public jit'd wrappers for the Pallas kernels.

Every op takes ``impl``/platform into account: on TPU the Pallas kernel runs
compiled; on CPU the *reference* implementation runs by default (fast,
HLO-small — important inside the 512-device dry-run), while tests force
``interpret=True`` to execute the actual kernel bodies on CPU.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.kernels import ref as kref
from repro.kernels.flash_attention import flash_attention_pallas
from repro.kernels.fpm_copy import fpm_copy_cross_pallas, fpm_copy_pallas
from repro.kernels.fused_dispatch import (fused_dispatch_pallas,
                                          notify_launch,
                                          sharded_fused_dispatch)
from repro.kernels.paged_attention import paged_attention_slab_pallas
from repro.kernels.ssd_chunk import ssd_intra_chunk_pallas
from repro.kernels.zero_init import zero_init_pallas


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def _interpret() -> bool:
    return not _on_tpu()


def _resolve_use_pallas(use_pallas: Optional[bool]) -> bool:
    """The one resolution rule for every op: ``None`` means "Pallas on TPU,
    reference elsewhere"; an explicit bool always wins (tests pass ``True``
    with interpret mode to execute the kernel bodies on CPU)."""
    return _on_tpu() if use_pallas is None else bool(use_pallas)


# ---------------------------------------------------------------------------
# RowClone primitives
# ---------------------------------------------------------------------------

def fpm_copy(pool, ids, *, use_pallas: Optional[bool] = None):
    """In-pool FPM block copy.  ids: (m,2) [src,dst], dst=-1 skips."""
    if _resolve_use_pallas(use_pallas):
        return fpm_copy_pallas(pool, ids, interpret=_interpret())
    return kref.fpm_copy(pool, ids[:, 0], ids[:, 1])


def fpm_copy_cross(dst_pool, src_pool, ids, *, use_pallas: Optional[bool] = None):
    """Pool-to-pool FPM block copy (dst_pool[dst] = src_pool[src])."""
    if _resolve_use_pallas(use_pallas):
        return fpm_copy_cross_pallas(dst_pool, src_pool, ids,
                                     interpret=_interpret())
    return kref.fpm_copy_cross(dst_pool, src_pool, ids[:, 0], ids[:, 1])


def meminit_zero(pool, zero_block, ids, *, use_pallas: Optional[bool] = None):
    """BuZ: DMA-broadcast the reserved zero block into ``ids``."""
    if _resolve_use_pallas(use_pallas):
        return zero_init_pallas(pool, zero_block, ids, interpret=_interpret())
    return kref.zero_init(pool, ids)


@functools.partial(jax.jit, static_argnames=("block_axis", "primary"),
                   donate_argnums=(2,))
def _fused_ref_jit(cmds, zero_blocks, pools, *, block_axis, primary=None):
    return kref.fused_dispatch(pools, zero_blocks, cmds,
                               block_axis=block_axis, primary=primary)


def fused_dispatch(pools, zero_blocks, cmds, *, block_axis: int = 0,
                   use_pallas: Optional[bool] = None,
                   primary: Optional[tuple] = None,
                   overlap: bool = True):
    """One launch for a whole flushed command table over every pool.

    See kernels/fused_dispatch.py for the opcode table and contract.  On
    CPU the jit'd reference executes (one dispatch, HLO-small); tests force
    ``use_pallas=True`` to run the kernel body in interpret mode.
    ``primary`` is the per-pool role vector (True = plain opcodes move the
    block there); pools may carry different block counts — cross-pool rows
    use global prefix-sum-base ids.  ``overlap`` selects the kernel's
    overlapped vs serial DMA drain (a tuned-profile knob; the jnp
    reference has no DMA pipeline, so it ignores it).
    """
    from repro.kernels.fused_dispatch import _as_primary
    primary = _as_primary(primary, len(pools))
    if _resolve_use_pallas(use_pallas):
        return fused_dispatch_pallas(pools, zero_blocks, cmds,
                                     block_axis=block_axis,
                                     interpret=_interpret(),
                                     primary=primary, overlap=overlap)
    out = _fused_ref_jit(cmds, tuple(zero_blocks), tuple(pools),
                         block_axis=block_axis, primary=primary)
    notify_launch(int(cmds.shape[0]), len(out), "fused")
    return tuple(out)


def fused_dispatch_sharded(pools, zero_blocks, plan, *, mesh, pool_axes,
                           block_axis: int = 0,
                           use_pallas: Optional[bool] = None,
                           primary: Optional[tuple] = None,
                           replicated: Optional[tuple] = None):
    """One collective launch for a whole flushed command table across the
    mesh: per-slab fused sub-tables + the cross-slab send/recv plan
    (cmdqueue.ShardPlan; every pool partitions by its own shard size).
    Resolution matches every other op: the per-shard drain runs the Pallas
    kernel body on TPU (or in interpret mode when forced) and the jnp
    reference elsewhere; the inter-slab hops are ppermute collectives
    either way.  ``primary`` as in :func:`fused_dispatch`; ``replicated``
    marks pools held whole on every device (must match the plan)."""
    return sharded_fused_dispatch(pools, zero_blocks, plan, mesh=mesh,
                                  pool_axes=pool_axes, block_axis=block_axis,
                                  use_pallas=_resolve_use_pallas(use_pallas),
                                  interpret=_interpret(),
                                  primary=primary, replicated=replicated)


def baseline_copy(pool, ids):
    """The mechanism RowClone replaces: blocks round-trip the compute
    pipeline.  Used by benchmarks for the Table-1 comparison."""
    return kref.baseline_copy(pool, ids[:, 0], ids[:, 1])


def psm_transfer(pool_slab, ids, *, axis_name: str = "model"):
    """PSM cross-chip RDMA block transfer (TARGET TPU kernel; on CPU the
    engine routes cross-slab copies through the collective path instead —
    see kernels/psm_transfer.py)."""
    from repro.kernels.psm_transfer import psm_transfer_pallas
    return psm_transfer_pallas(pool_slab, ids, axis_name=axis_name)


# ---------------------------------------------------------------------------
# attention / ssd
# ---------------------------------------------------------------------------

def paged_attention_slab(q, k_slab, v_slab, share_mask, base, seq_lens, *,
                         page: int, use_pallas: Optional[bool] = None):
    """Partial decode attention over one pool slab (see kernels/ref.py
    ``paged_attention_slab`` for the full contract)."""
    if _resolve_use_pallas(use_pallas):
        return paged_attention_slab_pallas(q, k_slab, v_slab, share_mask,
                                           base, seq_lens, page=page,
                                           interpret=_interpret())
    return kref.paged_attention_slab(q, k_slab, v_slab, share_mask, base,
                                     seq_lens, page=page)


def flash_attention(q, k, v, *, causal=True, prefix_len=0,
                    use_pallas: Optional[bool] = None):
    """q: (B,H,S,D); k/v: (B,KVH,S,D)."""
    if _resolve_use_pallas(use_pallas):
        return flash_attention_pallas(q, k, v, causal=causal,
                                      prefix_len=prefix_len,
                                      interpret=_interpret())
    B, H, S, D = q.shape
    pos = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32), (B, S))
    out = kref.flash_attention_ref(
        q.transpose(0, 2, 1, 3), k.transpose(0, 2, 1, 3),
        v.transpose(0, 2, 1, 3), pos, pos, jnp.ones((B, S), bool),
        causal=causal, prefix_len=prefix_len)
    return out.transpose(0, 2, 1, 3)


def ssd_intra_chunk(xb, dtb, cum, Bb, Cb, *, use_pallas: Optional[bool] = None):
    """Mamba2 SSD intra-chunk quadratic term (kernels/ssd_chunk.py)."""
    if _resolve_use_pallas(use_pallas):
        return ssd_intra_chunk_pallas(xb, dtb, cum, Bb, Cb,
                                      interpret=_interpret())
    from repro.models.mamba2 import _ssd_intra_chunk_jnp
    return _ssd_intra_chunk_jnp(xb, dtb, cum, Bb, Cb)
