"""SSD intra-chunk kernel (Mamba2 block decomposition, quadratic term).

Grid = (batch, head).  Per step the kernel materializes the (Q, Q) masked
decay matrix for one head in VMEM — the piece that would explode to
(B, H, Q, Q) in pure-jnp — and contracts it with the chunk inputs on the
MXU.  Q defaults to 256 so the tile is 256×256 fp32 = 256 KiB.

All exp() arguments are within-chunk cumulative-sum differences ≤ 0.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _ssd_intra_kernel(x_ref, dt_ref, cum_ref, b_ref, c_ref, y_ref, *, Q):
    x = x_ref[0, :, 0, :].astype(jnp.float32)                    # (Q,P)
    dt = dt_ref[0, :, 0].astype(jnp.float32)                     # (Q,)
    cum = cum_ref[0, :, 0].astype(jnp.float32)                   # (Q,)
    Bm = b_ref[0].astype(jnp.float32)                            # (Q,N)
    Cm = c_ref[0].astype(jnp.float32)                            # (Q,N)

    scores = jax.lax.dot_general(Cm, Bm, (((1,), (1,)), ((), ())),
                                 preferred_element_type=jnp.float32)  # (Q,Q)
    seg = cum[:, None] - cum[None, :]                            # (Qi,Qj)
    ii = jax.lax.broadcasted_iota(jnp.int32, (Q, Q), 0)
    jj = jax.lax.broadcasted_iota(jnp.int32, (Q, Q), 1)
    L = jnp.where(ii >= jj, jnp.exp(seg), 0.0)
    W = scores * L * dt[None, :]
    y = jax.lax.dot_general(W, x, (((1,), (0,)), ((), ())),
                            preferred_element_type=jnp.float32)  # (Q,P)
    y_ref[0, :, 0, :] = y


@functools.partial(jax.jit, static_argnames=("interpret",))
def ssd_intra_chunk_pallas(xb, dtb, cum, Bb, Cb, *, interpret: bool = False):
    """Same contract as models/mamba2.py::_ssd_intra_chunk_jnp.

    xb: (B,Q,H,P); dtb: (B,Q,H); cum: (B,Q,H); Bb/Cb: (B,Q,N) -> (B,Q,H,P).
    """
    B, Q, H, P = xb.shape
    N = Bb.shape[-1]
    return pl.pallas_call(
        functools.partial(_ssd_intra_kernel, Q=Q),
        grid=(B, H),
        in_specs=[
            pl.BlockSpec((1, Q, 1, P), lambda b, h: (b, 0, h, 0)),
            pl.BlockSpec((1, Q, 1), lambda b, h: (b, 0, h)),
            pl.BlockSpec((1, Q, 1), lambda b, h: (b, 0, h)),
            pl.BlockSpec((1, Q, N), lambda b, h: (b, 0, 0)),
            pl.BlockSpec((1, Q, N), lambda b, h: (b, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, Q, 1, P), lambda b, h: (b, 0, h, 0)),
        out_shape=jax.ShapeDtypeStruct((B, Q, H, P), jnp.float32),
        interpret=interpret,
    )(xb, dtb, cum, Bb, Cb)
