"""Paged decode-attention kernel: one slab sweep, flash accumulation.

Grid = chunks of pool blocks.  Per step, a ``(chunk, page, KVH, D)`` K/V tile
streams HBM→VMEM via BlockSpec; base/seq_len metadata sits in SMEM (scalar
prefetch) and the CoW ``share_mask`` tile rides in VMEM.  Scores are computed
for all (sequence, block) pairs and masked by the share mask — decode
attention is HBM-bound (every KV byte is read exactly once), so the extra
MXU work hides under the memory stream while making CoW prefix sharing
exact.  Flash (m, l, acc) accumulators persist in the output refs across the
sequential grid; step 0 initializes them.

VMEM at default tiling (chunk=8, page=64, KVH=8, D=128, B≤16, bf16):
K/V tiles 2 MiB + score tile (B·chunk·KVH·group·page fp32 ≤ 2 MiB) — inside
the ~16 MiB/core VMEM of TPU v5e.  Matmul dims are (8,128)-aligned after the
head-group reshape.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _paged_attn_kernel(base_ref, lens_ref, q_ref, k_ref, v_ref, mask_ref,
                       acc_ref, l_ref, m_ref, *, page, chunk, B, KVH, group,
                       D):
    i = pl.program_id(0)

    @pl.when(i == 0)
    def _():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        l_ref[...] = jnp.zeros_like(l_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)

    bb = base_ref[pl.ds(i * chunk, chunk)]                        # (c,)
    lens = lens_ref[...]                                          # (B,)
    mask = mask_ref[...]                                          # (c,B)

    q = q_ref[...].astype(jnp.float32)                            # (B,H,D)
    k = k_ref[...].astype(jnp.float32)                            # (c,pg,KVH,D)
    v = v_ref[...].astype(jnp.float32)

    # all-pairs scores: (B, c, KVH, group, page)
    s = jax.lax.dot_general(
        q.reshape(B, KVH, group, D).transpose(1, 0, 2, 3)
         .reshape(KVH, B * group, D),
        k.transpose(2, 0, 1, 3).reshape(KVH, chunk * page, D),
        (((2,), (2,)), ((0,), (0,))),
        preferred_element_type=jnp.float32,
    ).reshape(KVH, B, group, chunk, page).transpose(1, 3, 0, 2, 4) \
        * (D ** -0.5)                                             # (B,c,KVH,g,p)

    pos = bb[:, None] + jax.lax.broadcasted_iota(jnp.int32, (chunk, page), 1)
    valid = (mask.T[:, :, None] > 0) & (pos[None] < lens[:, None, None])
    s = jnp.where(valid[:, :, None, None, :], s, NEG_INF)
    m_c = s.max(axis=(1, 4))                                      # (B,KVH,g)
    p = jnp.exp(s - m_c[:, None, :, :, None])
    p = jnp.where(valid[:, :, None, None, :], p, 0.0)
    l_c = p.sum(axis=(1, 4))                                      # (B,KVH,g)
    acc_c = jax.lax.dot_general(
        p.transpose(2, 0, 3, 1, 4).reshape(KVH, B * group, chunk * page),
        v.transpose(2, 0, 1, 3).reshape(KVH, chunk * page, D),
        (((2,), (1,)), ((0,), (0,))),
        preferred_element_type=jnp.float32,
    ).reshape(KVH, B, group, D).transpose(1, 0, 2, 3)             # (B,KVH,g,D)

    m_prev = m_ref[...].reshape(B, KVH, group)
    l_prev = l_ref[...].reshape(B, KVH, group)
    acc_prev = acc_ref[...].reshape(B, KVH, group, D)
    m_new = jnp.maximum(m_prev, m_c)
    c1 = jnp.exp(m_prev - m_new)
    c2 = jnp.exp(m_c - m_new)
    m_ref[...] = m_new.reshape(B, KVH * group)
    l_ref[...] = (l_prev * c1 + l_c * c2).reshape(B, KVH * group)
    acc_ref[...] = (acc_prev * c1[..., None] + acc_c * c2[..., None]) \
        .reshape(B, KVH * group, D)


@functools.partial(jax.jit,
                   static_argnames=("page", "block_chunk", "interpret"))
def paged_attention_slab_pallas(q, k_slab, v_slab, share_mask, base,
                                seq_lens, *, page: int, block_chunk: int = 8,
                                interpret: bool = False):
    """Same contract as kernels/ref.py::paged_attention_slab."""
    nblk, pg, KVH, D = k_slab.shape
    B, H, _ = q.shape
    group = H // KVH
    chunk = min(block_chunk, nblk)
    n_chunks = nblk // chunk
    assert nblk % chunk == 0, (nblk, chunk)

    kv_spec = pl.BlockSpec((chunk, pg, KVH, D), lambda i, *_: (i, 0, 0, 0))
    acc, l, m = pl.pallas_call(
        functools.partial(_paged_attn_kernel, page=pg, chunk=chunk, B=B,
                          KVH=KVH, group=group, D=D),
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=2,
            grid=(n_chunks,),
            in_specs=[
                pl.BlockSpec((B, H, D), lambda i, *_: (0, 0, 0)),
                kv_spec, kv_spec,
                pl.BlockSpec((chunk, B), lambda i, *_: (i, 0)),
            ],
            out_specs=[
                pl.BlockSpec((B, H, D), lambda i, *_: (0, 0, 0)),
                pl.BlockSpec((B, H), lambda i, *_: (0, 0)),
                pl.BlockSpec((B, H), lambda i, *_: (0, 0)),
            ],
        ),
        out_shape=[
            jax.ShapeDtypeStruct((B, H, D), jnp.float32),
            jax.ShapeDtypeStruct((B, H), jnp.float32),
            jax.ShapeDtypeStruct((B, H), jnp.float32),
        ],
        interpret=interpret,
    )(base, seq_lens, q, k_slab, v_slab, share_mask)
    return acc, l, m
