"""Flash attention kernel for training / prefill (causal + prefix-LM).

Grid = (batch, q_head, q_blocks, kv_blocks); the kv_blocks axis is innermost
so flash (m, l, acc) accumulators live in VMEM scratch across it.  GQA is
handled in the index map (q head h reads kv head h // group).  Fully-masked
(q_blk, kv_blk) tiles in the causal region are skipped via ``pl.when`` —
upper-triangle tiles cost a predicate, not a matmul.

Default tiles: bq = bk = 512, D ≤ 256 → q/k/v tiles ≤ 512×256×4B = 512 KiB.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr, *,
                  bq, bk, D, causal, prefix_len, n_k):
    qi = pl.program_id(2)
    ki = pl.program_id(3)

    @pl.when(ki == 0)
    def _():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q0 = qi * bq
    k0 = ki * bk
    # causal tile skip: tile fully masked iff q_end < k_start and no prefix
    if causal:
        run = q0 + bq - 1 >= k0
        if prefix_len:
            run = run | (k0 < prefix_len)
    else:
        run = jnp.bool_(True)

    @pl.when(run)
    def _():
        q = q_ref[0, 0].astype(jnp.float32)                       # (bq,D)
        k = k_ref[0, 0].astype(jnp.float32)                       # (bk,D)
        v = v_ref[0, 0].astype(jnp.float32)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32)
        s = s * (D ** -0.5)
        if causal:
            rows = q0 + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
            cols = k0 + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
            ok = rows >= cols
            if prefix_len:
                ok = ok | (cols < prefix_len)
            s = jnp.where(ok, s, NEG_INF)
        m_prev = m_scr[...]
        l_prev = l_scr[...]
        m_cur = jnp.maximum(m_prev, s.max(axis=-1, keepdims=True))
        p = jnp.exp(s - m_cur)
        corr = jnp.exp(m_prev - m_cur)
        l_scr[...] = l_prev * corr + p.sum(axis=-1, keepdims=True)
        m_scr[...] = m_cur
        acc_scr[...] = acc_scr[...] * corr + jax.lax.dot_general(
            p.astype(v.dtype), v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)

    @pl.when(ki == n_k - 1)
    def _():
        o_ref[0, 0] = (acc_scr[...] /
                       jnp.maximum(l_scr[...], 1e-30)).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("causal", "prefix_len", "bq",
                                             "bk", "interpret"))
def flash_attention_pallas(q, k, v, *, causal: bool = True,
                           prefix_len: int = 0, bq: int = 512, bk: int = 512,
                           interpret: bool = False):
    """q: (B,H,S,D); k,v: (B,KVH,S,D).  Returns (B,H,S,D) in q.dtype.

    Positions are implicit (iota over S — contiguous sequences).
    """
    B, H, Sq, D = q.shape
    KVH, Sk = k.shape[1], k.shape[2]
    group = H // KVH
    bq = min(bq, Sq)
    bk = min(bk, Sk)
    assert Sq % bq == 0 and Sk % bk == 0
    n_q, n_k = Sq // bq, Sk // bk

    return pl.pallas_call(
        functools.partial(_flash_kernel, bq=bq, bk=bk, D=D, causal=causal,
                          prefix_len=prefix_len, n_k=n_k),
        grid=(B, H, n_q, n_k),
        in_specs=[
            pl.BlockSpec((1, 1, bq, D), lambda b, h, i, j: (b, h, i, 0)),
            pl.BlockSpec((1, 1, bk, D),
                         lambda b, h, i, j, g=group: (b, h // g, j, 0)),
            pl.BlockSpec((1, 1, bk, D),
                         lambda b, h, i, j, g=group: (b, h // g, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, bq, D), lambda b, h, i, j: (b, h, i, 0)),
        out_shape=jax.ShapeDtypeStruct((B, H, Sq, D), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq, 1), jnp.float32),
            pltpu.VMEM((bq, 1), jnp.float32),
            pltpu.VMEM((bq, D), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v)
