"""FPM block-copy kernel — RowClone Fast Parallel Mode on TPU.

The DRAM mechanism: two back-to-back ACTIVATEs short source row → row buffer
→ destination row; data never leaves the subarray, never touches the channel
or the CPU.  The TPU analogue implemented here: a *pure DMA* kernel.  Block
refs live in ``pl.ANY`` (HBM); each grid step issues an HBM→HBM
``make_async_copy`` for one (src, dst) block pair.  Nothing is ever loaded
into VMEM/VREGs and no vector/matrix unit cycle is spent — the analogue of
"the data never crosses the memory channel".

Requests are (m, 2) int32 ``[src, dst]`` pairs, scalar-prefetched into SMEM
so the DMA targets are known before the grid body runs (RowClone's
"peripheral logic" — the memory controller computing row addresses).
``dst == -1`` disables a pair (the engine pads request lists to a static
length).  Two DMA semaphores alternate so copy *i+1* is in flight while *i*
completes — the back-to-back ACTIVATE pipelining.

CONTRACT: destination blocks must be disjoint from source blocks (the
engine guarantees this — CoW destinations are freshly allocated).  Sources
are read from the pre-copy pool state; chained copies are NOT supported.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _fpm_copy_kernel(ids_ref, src_ref, _dst_in, dst_ref, sem0, sem1):
    i = pl.program_id(0)
    s = ids_ref[i, 0]
    d = ids_ref[i, 1]
    # semaphores alternate by parity so consecutive DMAs overlap

    @pl.when(d >= 0)
    def _():
        @pl.when(i % 2 == 0)
        def _():
            cp = pltpu.make_async_copy(src_ref.at[s], dst_ref.at[d], sem0)
            cp.start()
            cp.wait()

        @pl.when(i % 2 == 1)
        def _():
            cp = pltpu.make_async_copy(src_ref.at[s], dst_ref.at[d], sem1)
            cp.start()
            cp.wait()


@functools.partial(jax.jit, static_argnames=("interpret",), donate_argnums=(0,))
def fpm_copy_pallas(pool, ids, *, interpret: bool = False):
    """pool: (nblk, ...); ids: (m, 2) int32 [src, dst] pairs, dst=-1 skips.

    In-pool copy (same "subarray"); the pool buffer is donated and aliased so
    the operation is in-place at the XLA level.
    """
    return pl.pallas_call(
        _fpm_copy_kernel,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=1,
            grid=(ids.shape[0],),
            in_specs=[pl.BlockSpec(memory_space=pl.ANY),
                      pl.BlockSpec(memory_space=pl.ANY)],
            out_specs=pl.BlockSpec(memory_space=pl.ANY),
            scratch_shapes=[pltpu.SemaphoreType.DMA,
                            pltpu.SemaphoreType.DMA],
        ),
        out_shape=jax.ShapeDtypeStruct(pool.shape, pool.dtype),
        input_output_aliases={2: 0},
        interpret=interpret,
    )(ids, pool, pool)


def _fpm_copy_cross_kernel(ids_ref, src_ref, _dst_in, dst_ref, sem0, sem1):
    i = pl.program_id(0)
    s = ids_ref[i, 0]
    d = ids_ref[i, 1]

    @pl.when(d >= 0)
    def _():
        @pl.when(i % 2 == 0)
        def _():
            cp = pltpu.make_async_copy(src_ref.at[s], dst_ref.at[d], sem0)
            cp.start()
            cp.wait()

        @pl.when(i % 2 == 1)
        def _():
            cp = pltpu.make_async_copy(src_ref.at[s], dst_ref.at[d], sem1)
            cp.start()
            cp.wait()


@functools.partial(jax.jit, static_argnames=("interpret",), donate_argnums=(0,))
def fpm_copy_cross_pallas(dst_pool, src_pool, ids, *, interpret: bool = False):
    """Copy src_pool[ids[:,0]] -> dst_pool[ids[:,1]] (pool-to-pool, same
    device slab — e.g. prefill staging pool into the serving pool)."""
    return pl.pallas_call(
        _fpm_copy_cross_kernel,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=1,
            grid=(ids.shape[0],),
            in_specs=[pl.BlockSpec(memory_space=pl.ANY),
                      pl.BlockSpec(memory_space=pl.ANY)],
            out_specs=pl.BlockSpec(memory_space=pl.ANY),
            scratch_shapes=[pltpu.SemaphoreType.DMA,
                            pltpu.SemaphoreType.DMA],
        ),
        out_shape=jax.ShapeDtypeStruct(dst_pool.shape, dst_pool.dtype),
        input_output_aliases={2: 0},
        interpret=interpret,
    )(ids, src_pool, dst_pool)
