"""Fused command-queue dispatch kernel — the MC's serialized command stream.

RowClone's memory controller accepts a stream of copy/init commands and
executes them back-to-back inside DRAM with no per-command CPU involvement
(§2.3).  The seed engine betrayed that: one device dispatch per mechanism
per pool (up to 8 launches for one mixed request batch).  This kernel is the
TPU analogue of the MC's command queue drain: **one** ``pallas_call`` whose
scalar-prefetched SMEM table is ``(m, 3)`` int32 ``[opcode, src, dst]`` rows;
the grid body switches on the opcode and issues the corresponding HBM→HBM
``make_async_copy`` (copies) or zero-row broadcast DMA (init) on
alternating semaphore slots.  The drain is **overlapped**: each step
starts its DMAs and the wait trails one step behind (the previous step's
descriptors are reconstructed and waited after the current step issues),
so two adjacent commands' DMAs pipeline — the MC keeping its command bus
busy while a copy completes.  Safety is adjacency-local and guaranteed by
the CommandQueue's source-hazard tracking: flushed tables never carry
RAW/WAW pairs at all, and WAR pairs (a row overwriting an earlier row's
source) are kept non-adjacent by spacer rows (``cmdqueue.space_war_rows``).
Multi-pool engines (K and V pages of one KV block) pass every pool to the
same launch; each grid step moves the block in all of them.

Opcodes (also the ``CommandQueue`` tags, core/cmdqueue.py):

  ======================  ==  ==================================================
  ``OP_FPM_COPY``          0  same-slab block copy (FPM — subarray-local DMA)
  ``OP_PSM_COPY``          1  cross-slab copy (PSM; same DMA on a single slab)
  ``OP_BASELINE_COPY``     2  RowClone-disabled copy (mechanism modeling only)
  ``OP_ZERO_INIT``         3  BuZ — broadcast the reserved zero block into dst
  ``OP_CROSS_POOL_COPY``   4  pool-to-pool copy; src/dst are *global* ids
                              ``base[pool] + block`` where ``base`` is the
                              prefix sum of per-pool block counts (the
                              PoolGroup address space, core/poolspec.py) —
                              pools may have DIFFERENT block counts but must
                              share block shape and dtype
  ``OP_AND``               5  in-memory bulk bitwise AND (Ambit TRA analogue):
                              ``src`` packs TWO global ids ``a * total + b``
                              (``total`` = sum of pool block counts), ``dst``
                              is a global id; ``dst = a & b`` bit-for-bit
  ``OP_OR``                6  in-memory bulk bitwise OR, same two-source packing
  ``OP_NOT``               7  in-memory bitwise NOT (``b`` packs equal to ``a``)
  ``OP_NOP``              -1  padding row (bucketed table), also ``dst == -1``
  ======================  ==  ==================================================

Pools carry a per-pool **role vector** (``primary`` tuple of bools): plain
opcodes (0-3) move the named block in every primary pool (all primary pools
share one block count — the allocator's address space); staging pools are
reachable only through ``OP_CROSS_POOL_COPY`` rows that name them in a
global id, and may be any size (e.g. a small staging ring).  The base
offsets are derived from the pool shapes at trace time, so the table
encoding and the kernel always agree.

``block_axis=1`` handles layer-stacked serving pools ``(L, nblk, ...)``: the
grid grows a layer dimension and each command becomes L independent DMAs, as
in the seed's axis-1 path.

CONTRACT (same as the per-mechanism kernels, now per *flush*): within one
table, no row may read a block that an earlier row writes, and no two rows
may write the same block — the CommandQueue's hazard guards auto-flush
before either can occur.  Under that contract sources observe the
pre-flush pool state (the kernel actually reads in place during the
serial drain, which the guards make indistinguishable — and which lets
the pools be aliased in-place with no snapshot copy).
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Callable, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu
from jax.sharding import PartitionSpec as P

from repro.compat import shard_map
# the opcode table is DECLARED once, in the core/opcodes.py registry; the
# kernel (like the CommandQueue and the jnp reference) derives its switch
# sets from it.  The names are re-exported here for the long-standing
# import surface (cmdqueue/tests import OP_* from this module).
from repro.core.opcodes import (BITWISE_OPS, OP_AND, OP_BASELINE_COPY,
                                OP_CROSS_POOL_COPY, OP_FPM_COPY, OP_NOP,
                                OP_NOT, OP_OR, OP_PSM_COPY, OP_ZERO_INIT,
                                OPCODE_NAMES, PLAIN_COPY_OPS,
                                pack_bitwise_src, unpack_bitwise_src)

_UINTS = {1: jnp.uint8, 2: jnp.uint16, 4: jnp.uint32, 8: jnp.uint64}


def _op_in(op, values):
    """Fold a registry-derived opcode set into one traced predicate —
    the kernel/reference switch tables stay in lockstep with the
    ``core/opcodes.py`` registry instead of hand-listing members."""
    pred = op == values[0]
    for v in values[1:]:
        pred = pred | (op == v)
    return pred


def _bitcast_uint(arr):
    """Reinterpret ``arr`` as the same-itemsize unsigned-int dtype (a pure
    bitcast): the bitwise opcodes AND/OR/NOT raw bit patterns, so float
    pools combine bytes exactly like the DRAM rows they model."""
    dt = np.dtype(arr.dtype)
    if np.issubdtype(dt, np.unsignedinteger):
        return arr
    return jax.lax.bitcast_convert_type(arr, _UINTS[dt.itemsize])


# ---------------------------------------------------------------------------
# launch accounting — the hook tests and benchmarks use to assert "one
# kernel launch per flush".  Every device dispatch of bulk-movement work
# (fused or legacy per-op) reports here.
# ---------------------------------------------------------------------------

_LAUNCH_HOOKS: List[Callable[[int, int, str], None]] = []
_LAUNCH_COUNT = 0


def add_launch_hook(fn: Callable[[int, int, str], None]) -> None:
    """Register ``fn(n_commands, n_pools, mechanism)`` to fire per launch."""
    _LAUNCH_HOOKS.append(fn)


def remove_launch_hook(fn: Callable[[int, int, str], None]) -> None:
    """Unregister a hook added with :func:`add_launch_hook`."""
    _LAUNCH_HOOKS.remove(fn)


def launch_count() -> int:
    """Cumulative bulk-movement launches this process."""
    return _LAUNCH_COUNT


def notify_launch(n_commands: int, n_pools: int, mechanism: str) -> None:
    """Record one bulk-movement device dispatch (launch accounting).

    Every path that issues device work for queued commands — the fused
    drains, the legacy per-op fan-out, and the seed staging scatter —
    reports here so tests and benchmarks can assert launches/flush."""
    global _LAUNCH_COUNT
    _LAUNCH_COUNT += 1
    for fn in _LAUNCH_HOOKS:
        fn(n_commands, n_pools, mechanism)


# ---------------------------------------------------------------------------
# drain guards — the abort-safe pre-dispatch hook.  The engine's drain loop
# calls check_drain() for every chunk BEFORE the donating dispatch, so a
# guard that raises (fault injection, admission control, backpressure)
# aborts the flush while every pool buffer is still valid — the engine
# stashes the undispatched suffix and recover() can re-drain it.
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class DrainInfo:
    """One chunk of a flush, about to dispatch.

    ``flush`` is the engine-wide flush index (``engine.next_flush_index``
    names the upcoming one), ``chunk`` the 0-based overflow-chunk ordinal
    within that flush; ``engine`` identifies which engine is draining so
    guards bound to one engine ignore the rest."""

    flush: int        #: engine-wide flush index
    chunk: int        #: overflow-chunk ordinal within the flush (0-based)
    n_commands: int   #: live (non-NOP) rows in this chunk
    n_pools: int      #: pools the dispatch will move
    engine: object = dataclasses.field(default=None, repr=False)


_DRAIN_GUARDS: List[Callable[[DrainInfo], None]] = []


def add_drain_guard(fn: Callable[[DrainInfo], None]) -> None:
    """Register ``fn(DrainInfo)`` to run before every chunk dispatch; a
    guard that raises aborts the flush with pool buffers intact (the
    fault-injection and admission-control hook — runtime/fault.py)."""
    _DRAIN_GUARDS.append(fn)


def remove_drain_guard(fn: Callable[[DrainInfo], None]) -> None:
    """Unregister a guard added with :func:`add_drain_guard`."""
    _DRAIN_GUARDS.remove(fn)


def check_drain(info: DrainInfo) -> None:
    """Run every registered drain guard against one pending chunk
    (called by the engine's drain loop before the donating dispatch)."""
    for fn in list(_DRAIN_GUARDS):
        fn(info)


# ---------------------------------------------------------------------------
# the kernel
# ---------------------------------------------------------------------------

def _make_kernel(n_pools: int, block_axis: int, sizes: Tuple[int, ...],
                 primary: Tuple[bool, ...], overlap: bool):
    """Build the grid body for ``n_pools`` pools with per-pool block counts
    ``sizes`` and role vector ``primary``.  Plain opcodes (FPM/PSM/baseline
    copy, zero-init) move the block in every primary pool; *staging* pools
    (``primary[p] == False``) are reachable only through
    ``OP_CROSS_POOL_COPY`` global ids — bulk movement never touches staged
    bytes it wasn't asked to move.  Cross-pool ids decode against the
    prefix-sum ``bases`` of ``sizes`` (the PoolGroup address space).

    ``overlap=True`` is the OVERLAPPED drain: each step starts its DMAs on
    the parity semaphore slot and the *wait* trails one step behind — the
    previous step's copies are reconstructed (same src/dst/semaphore, the
    standard deferred-wait idiom) and waited only after the current step
    has issued, so up to two steps' DMAs are in flight at once.  The
    safety contract is adjacency-local: consecutive rows must touch
    disjoint blocks.  RAW/WAW never co-exist in one flushed table (the
    CommandQueue guards), and WAR pairs — a row overwriting an earlier
    row's *source* — are kept non-adjacent by the queue's spacer rows
    (cmdqueue.space_war_rows): at the spacer step nothing issues but the
    trailing wait still fires, so the in-flight read completes before the
    write starts.  ``overlap=False`` keeps the serial per-step
    start-then-wait drain (A/B and debugging)."""
    bases = []
    run = 0
    for n in sizes:
        bases.append(run)
        run += n
    total = run

    def kernel(cmds_ref, *refs):
        zeros = refs[:n_pools]
        # refs[n:2n] are the aliased (donated) pool inputs — never touched;
        # both reads and writes go through ``outs`` (in place).  The
        # CommandQueue excludes read-after-write and write-after-write
        # within a table, so in-place source reads equal pre-flush state
        # reads — and no snapshot copy of the pools is ever materialized.
        outs = refs[2 * n_pools:3 * n_pools]
        sem = refs[3 * n_pools]          # DMA semaphore pair, shape (2,)
        va = refs[3 * n_pools + 1]       # VMEM compute scratch (source A)
        vb = refs[3 * n_pools + 2]       # VMEM compute scratch (source B)
        reads = outs

        i = pl.program_id(0)
        if block_axis == 1:
            l = pl.program_id(1)
            L = pl.num_programs(1)
            step = i * L + l
            n_steps = pl.num_programs(0) * L
        else:
            l = None
            L = 1
            step = i
            n_steps = pl.num_programs(0)

        def blk(ref, b, lay):
            return ref.at[lay, b] if block_axis == 1 else ref.at[b]

        def visit(ci, lay, slot, act, issue=True):
            """Apply ``act`` (start / wait / both) to every DMA descriptor
            of command ``ci`` at layer ``lay``, tracked by semaphore slot
            ``slot``.  Reconstructing the descriptors from the SMEM table
            makes the deferred wait possible: the waiting step rebuilds
            the exact copies the issuing step started.

            ``issue=False`` marks the deferred-WAIT phase: bitwise compute
            rows (``OP_AND``/``OP_OR``/``OP_NOT``) run fully synchronously
            at their own step — load both sources into VMEM, combine,
            write back — so they leave NO in-flight descriptors for the
            wait phase to reconstruct and are skipped there."""
            op = cmds_ref[ci, 0]
            s = cmds_ref[ci, 1]
            d = cmds_ref[ci, 2]
            sm = sem.at[slot]

            if issue:
                @pl.when(_op_in(op, BITWISE_OPS) & (d >= 0))
                def _():
                    # two-source compute row: src packs a*total+b; dst is a
                    # global id.  Synchronous DMA round-trip through VMEM —
                    # the deferred-wait overlap skips these rows entirely.
                    a = s // total
                    b = s - a * total
                    for ps in range(n_pools):
                        @pl.when((a >= bases[ps])
                                 & (a < bases[ps] + sizes[ps]))
                        def _(ps=ps):
                            cp = pltpu.make_async_copy(
                                blk(reads[ps], a - bases[ps], lay), va, sm)
                            cp.start()
                            cp.wait()

                        @pl.when((b >= bases[ps])
                                 & (b < bases[ps] + sizes[ps]))
                        def _(ps=ps):
                            cp = pltpu.make_async_copy(
                                blk(reads[ps], b - bases[ps], lay), vb, sm)
                            cp.start()
                            cp.wait()
                    au = _bitcast_uint(va[...])
                    bu = _bitcast_uint(vb[...])
                    ru = jnp.where(op == OP_AND, au & bu,
                                   jnp.where(op == OP_OR, au | bu, ~au))
                    va[...] = jax.lax.bitcast_convert_type(ru, va.dtype)
                    for pd in range(n_pools):
                        @pl.when((d >= bases[pd])
                                 & (d < bases[pd] + sizes[pd]))
                        def _(pd=pd):
                            cp = pltpu.make_async_copy(
                                va, blk(outs[pd], d - bases[pd], lay), sm)
                            cp.start()
                            cp.wait()

            @pl.when((op >= 0) & (d >= 0))
            def _():
                @pl.when(_op_in(op, PLAIN_COPY_OPS))
                def _():
                    for p in range(n_pools):
                        if primary[p]:
                            act(pltpu.make_async_copy(
                                blk(reads[p], s, lay), blk(outs[p], d, lay),
                                sm))

                @pl.when(op == OP_ZERO_INIT)
                def _():
                    for p in range(n_pools):
                        if primary[p]:
                            act(pltpu.make_async_copy(
                                zeros[p].at[0], blk(outs[p], d, lay), sm))

                @pl.when(op == OP_CROSS_POOL_COPY)
                def _():
                    for ps in range(n_pools):
                        for pd in range(n_pools):
                            @pl.when((s >= bases[ps])
                                     & (s < bases[ps] + sizes[ps])
                                     & (d >= bases[pd])
                                     & (d < bases[pd] + sizes[pd]))
                            def _(ps=ps, pd=pd):
                                act(pltpu.make_async_copy(
                                    blk(reads[ps], s - bases[ps], lay),
                                    blk(outs[pd], d - bases[pd], lay), sm))

        if not overlap:
            # serial drain: per-step start+wait back to back (seed shape)
            visit(i, l, step % 2, lambda cp: (cp.start(), cp.wait()))
            return

        # Overlapped drain — issue now, wait one step behind:
        #   step k   : start(k) on sem[k%2]; wait(k-1) on sem[(k-1)%2]
        #   last step: additionally wait(last)
        # Slot k%2 is reused by step k+2, which runs only after step k+1
        # waited step k — so two slots bound the in-flight window to the
        # adjacent pair the spacing contract protects.
        visit(i, l, step % 2, lambda cp: cp.start())
        if block_axis == 1:
            prev_i = (step - 1) // L
            prev_l = (step - 1) % L
        else:
            prev_i, prev_l = i - 1, None

        @pl.when(step > 0)
        def _():
            visit(prev_i, prev_l, (step - 1) % 2, lambda cp: cp.wait(),
                  issue=False)

        @pl.when(step == n_steps - 1)
        def _():
            visit(i, l, step % 2, lambda cp: cp.wait(), issue=False)

    return kernel


def _as_primary(primary: Optional[Tuple[bool, ...]],
                n_pools: int) -> Tuple[bool, ...]:
    """Normalize the per-pool role vector: ``None`` means every pool is
    primary (single-address-space engines); an explicit tuple is validated
    against the pool count.  (The pre-PoolGroup ``n_primary`` int shim is
    gone — callers pass the role vector.)"""
    if primary is None:
        return tuple([True] * n_pools)
    assert len(primary) == n_pools, (primary, n_pools)
    return tuple(bool(p) for p in primary)


def _fused_dispatch_call(cmds, zero_blocks, pools, *, block_axis: int,
                         interpret: bool,
                         primary: Optional[Tuple[bool, ...]] = None,
                         overlap: bool = True):
    """The raw pallas_call — shared by the single-slab jit entry and the
    per-shard body of the sharded entry (already inside a jit there).
    Per-pool block counts (and the global-id base offsets) come from the
    pool shapes, so the call works unchanged on full pools and on
    per-shard slabs.

    ``overlap``: overlapped DMA drain (wait trails one step behind issue).
    Tables must then keep adjacent rows disjoint — tables produced by
    ``CommandQueue.flush`` / ``partition_commands`` are WAR-spaced; direct
    callers handing in raw tables with adjacent write-after-read pairs
    must pass ``overlap=False``."""
    n_pools = len(pools)
    sizes = tuple(int(p.shape[block_axis]) for p in pools)
    primary = _as_primary(primary, n_pools)
    grid = ((cmds.shape[0],) if block_axis == 0
            else (cmds.shape[0], pools[0].shape[0]))
    # one block's worth of VMEM ×2 for the bitwise compute rows (all pools
    # share block shape + dtype — the cross-pool/global-id contract)
    blk_shape = pools[0].shape[block_axis + 1:]
    return pl.pallas_call(
        _make_kernel(n_pools, block_axis, sizes, primary, overlap),
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=1,
            grid=grid,
            in_specs=[pl.BlockSpec(memory_space=pl.ANY)] * (2 * n_pools),
            out_specs=[pl.BlockSpec(memory_space=pl.ANY)] * n_pools,
            # one DMA semaphore per in-flight slot: the overlapped drain
            # alternates parity, the serial drain just alternates
            scratch_shapes=[pltpu.SemaphoreType.DMA((2,)),
                            pltpu.VMEM(blk_shape, pools[0].dtype),
                            pltpu.VMEM(blk_shape, pools[0].dtype)],
        ),
        out_shape=[jax.ShapeDtypeStruct(p.shape, p.dtype) for p in pools],
        # operand order: cmds, zeros (n), donated pools (n); pools are
        # passed ONCE and aliased — the kernel works in place, so no
        # full-pool snapshot copy is inserted by XLA
        input_output_aliases={1 + n_pools + p: p for p in range(n_pools)},
        interpret=interpret,
    )(cmds, *zero_blocks, *pools)


@functools.partial(jax.jit,
                   static_argnames=("block_axis", "interpret", "primary",
                                    "overlap"),
                   donate_argnums=(2,))
def _fused_dispatch_jit(cmds, zero_blocks, pools, *, block_axis: int,
                        interpret: bool,
                        primary: Optional[Tuple[bool, ...]] = None,
                        overlap: bool = True):
    return _fused_dispatch_call(cmds, zero_blocks, pools,
                                block_axis=block_axis, interpret=interpret,
                                primary=primary, overlap=overlap)


def fused_dispatch_pallas(pools: Sequence, zero_blocks: Sequence, cmds, *,
                          block_axis: int = 0, interpret: bool = False,
                          primary: Optional[Tuple[bool, ...]] = None,
                          overlap: bool = True) -> Tuple:
    """Execute one flushed command table over every pool in ONE launch.

    pools:       sequence of (nblk_p, ...) or (L, nblk_p, ...) arrays
                 (donated); block counts may differ per pool — cross-pool
                 ids decode against the prefix-sum bases of those counts
    zero_blocks: per-pool reserved zero row, shape (1,) + block_shape
    cmds:        (m, 3) int32 [opcode, src, dst]; OP_NOP/-1 rows are padding
    primary:     per-pool role vector (True = plain opcodes move the block
                 there; every primary pool shares one block count).  None =
                 every pool is primary.
    overlap:     overlapped DMA drain — the wait trails one step behind
                 issue.  Requires adjacent rows disjoint (queue-flushed
                 tables are WAR-spaced; see ``_fused_dispatch_call``).
    """
    out = _fused_dispatch_jit(
        cmds, tuple(zero_blocks), tuple(pools), block_axis=block_axis,
        interpret=interpret, primary=_as_primary(primary, len(pools)),
        overlap=overlap)
    notify_launch(int(cmds.shape[0]), len(out), "fused")
    return tuple(out)


# ---------------------------------------------------------------------------
# sharded entry — ONE shard_map'd launch drains a whole flush across the mesh
# ---------------------------------------------------------------------------
#
# Each shard scalar-prefetches ITS slab's sub-table (same kernel, same opcode
# switch — the ids are just slab-local) and drains it in place; cross-slab
# commands ride the same launch as a send/recv plan: every shard gathers its
# outgoing blocks from the pre-drain slab state, the buffers hop the mesh via
# ppermute (one permute per hop distance — the LISA fast-inter-slab-link
# analogue), and land with a scatter on the destination shard.  The
# CommandQueue hazard guards make this interleaving exact: transfer sources
# are never written earlier in the table (gather reads pre-flush state),
# transfer destinations are disjoint from every other destination and are
# only read by rows enqueued before the transfer (which drain locally before
# the scatter lands).

def _gather_rows(slab, rows, block_axis):
    cl = jnp.clip(rows, 0, slab.shape[block_axis] - 1)
    return slab[cl] if block_axis == 0 else slab[:, cl]


def _scatter_rows(slab, data, dst, valid, block_axis):
    safe = jnp.where(valid, dst, slab.shape[block_axis])
    if block_axis == 0:
        return slab.at[safe].set(data, mode="drop")
    return slab.at[:, safe].set(data, mode="drop")


@functools.lru_cache(maxsize=256)
def _sharded_runner(mesh, pool_axes: Tuple[str, ...], deltas: Tuple[int, ...],
                    n_pools: int, block_axis: int, use_pallas: bool,
                    interpret: bool, primary: Tuple[bool, ...],
                    replicated: Tuple[bool, ...]):
    """Build (and cache) the jit'd shard_map'd drain for one static plan
    structure.  The jit layer further caches per array shape; table shapes
    are bucketed (cmdqueue.BUCKETS) and decode-round flushes are local-only
    (``deltas=()``).  Adversarial streams churning distinct delta subsets
    are bounded by the signature fold in :func:`sharded_fused_dispatch`:
    past :data:`MAX_DELTA_SIGNATURES` distinct ``(deltas, t)`` signatures,
    plans fold to the full delta set so the compile count stays O(1).

    ``replicated[p]`` marks pools whose block axis is NOT sharded (the
    ``PoolSpec.sharding == ()`` hint — e.g. a small staging ring held
    whole on every device): their in/out specs replicate, each shard sees
    the full pool as its slab, and cross-pool reads from them are always
    slab-local (``partition_commands`` classifies them by the sharded
    side)."""
    n_shards = int(np.prod([mesh.shape[a] for a in pool_axes]))
    axis = pool_axes if len(pool_axes) > 1 else pool_axes[0]
    pspec = P(*([None] * block_axis), axis)
    pool_specs = tuple(P() if replicated[p] else pspec
                       for p in range(n_pools))
    lspec = P(axis, None, None)             # local tables   (S, m, 3)
    sspec = P(None, axis, None)             # send rows      (K, S, t)
    rspec = P(None, axis, None, None)       # recv tables    (K, S, t, 4)

    def body(local_tbl, send_rows, recv_tbl, zeros, pools):
        tbl = local_tbl[0]                  # this shard's (m, 3) sub-table
        slabs = list(pools)
        # 1) gather every transfer source from the PRE-drain slab state
        #    (each pool gathered at the same row; the recv side picks the
        #    buffer that matters)
        bufs = [jnp.stack([_gather_rows(p, send_rows[k, 0], block_axis)
                           for p in slabs])
                for k in range(len(deltas))]
        # 2) drain this slab's sub-table — same kernel, slab-local ids
        #    (cross-pool ids re-stacked against the SLAB shapes' prefix
        #    sums, which is exactly how partition_commands encoded them)
        if use_pallas:
            slabs = list(_fused_dispatch_call(
                tbl, tuple(zeros), tuple(slabs), block_axis=block_axis,
                interpret=interpret, primary=primary))
        else:
            from repro.kernels import ref as kref
            slabs = list(kref.fused_dispatch(slabs, zeros, tbl,
                                             block_axis=block_axis,
                                             primary=primary))
        # 3) hop the buffers, then scatter in TWO phases: phase 0 lands
        #    every overwrite entry (plain transfers, and OP_NOT entries
        #    which invert the buffer in flight), phase 1 folds the
        #    AND/OR combine entries into the phase-0 result.  A two-source
        #    bitwise row whose sources live on different shards ships ONE
        #    entry per source: srcA overwrites dst (phase 0), srcB combines
        #    into it (phase 1) — the phase split orders them even when the
        #    two sources arrive on different hop distances.
        def expand(cond, data):
            shape = [1] * data.ndim
            shape[block_axis] = cond.shape[0]
            return cond.reshape(shape)

        recvs = [jax.lax.ppermute(
                     bufs[k],
                     axis, [(i, (i + delta) % n_shards)
                            for i in range(n_shards)])
                 for k, delta in enumerate(deltas)]
        for phase in (0, 1):
            for k in range(len(deltas)):
                recvd = recvs[k]
                rt = recv_tbl[k, 0]         # (t, 4)
                buf_pool, dst_pool = rt[:, 0], rt[:, 1]
                dst_row, comb = rt[:, 2], rt[:, 3]
                t = rt.shape[0]
                is_comb = (comb == OP_AND) | (comb == OP_OR)
                phase_sel = is_comb if phase else ~is_comb
                for pd in range(n_pools):
                    sel = jnp.where(buf_pool < 0, pd, buf_pool)
                    idx_shape = ((1, t) + (1,) * (recvd.ndim - 2)
                                 if block_axis == 0
                                 else (1, 1, t) + (1,) * (recvd.ndim - 3))
                    picked = jnp.take_along_axis(
                        recvd, sel.reshape(idx_shape), axis=0)[0]
                    picked = picked.astype(slabs[pd].dtype)
                    # whole-block rows (dst_pool < 0) came from plain
                    # opcodes: they land in every PRIMARY pool only —
                    # staging pools take transfers naming them explicitly
                    valid = (dst_row >= 0) & phase_sel & (
                        ((dst_pool < 0) | (dst_pool == pd)) if primary[pd]
                        else (dst_pool == pd))
                    if phase == 0:
                        pu = _bitcast_uint(picked)
                        inv = jax.lax.bitcast_convert_type(~pu,
                                                           picked.dtype)
                        data = jnp.where(expand(comb == OP_NOT, picked),
                                         inv, picked)
                    else:
                        cur = _gather_rows(
                            slabs[pd], jnp.where(valid, dst_row, 0),
                            block_axis)
                        cu = _bitcast_uint(cur)
                        pu = _bitcast_uint(picked)
                        ru = jnp.where(expand(comb == OP_AND, cu),
                                       cu & pu, cu | pu)
                        data = jax.lax.bitcast_convert_type(ru, picked.dtype)
                    slabs[pd] = _scatter_rows(slabs[pd], data, dst_row,
                                              valid, block_axis)
        return tuple(slabs)

    mapped = shard_map(
        body, mesh=mesh,
        # P() replicates the zero rows; per-pool specs shard or replicate
        # each pool leaf according to its PoolSpec.sharding hint
        in_specs=(lspec, sspec, rspec, P(), pool_specs),
        out_specs=pool_specs,
        check_vma=False)
    return jax.jit(mapped, donate_argnums=(4,))


#: the hand-picked jit-cache bound (:func:`set_max_delta_signatures`
#: restores it on None)
DEFAULT_MAX_DELTA_SIGNATURES = 8

#: distinct (deltas, t) collective signatures compiled per (mesh, pool
#: structure) before plans fold to the full delta set (jit-cache bound)
MAX_DELTA_SIGNATURES = DEFAULT_MAX_DELTA_SIGNATURES

_DELTA_SIGS: dict = {}


def set_max_delta_signatures(n: Optional[int]) -> int:
    """Retarget the process-wide delta-signature jit-cache bound (``None``
    restores :data:`DEFAULT_MAX_DELTA_SIGNATURES`) — the autotuner's
    knob: a larger bound compiles more collective bodies before folding;
    a smaller one folds (and pads) sooner.  Clears the per-(mesh, pools)
    signature memory so the new bound applies from a clean slate.
    Returns the installed bound."""
    global MAX_DELTA_SIGNATURES
    if n is None:
        MAX_DELTA_SIGNATURES = DEFAULT_MAX_DELTA_SIGNATURES
    else:
        n = int(n)
        if n < 1:
            raise ValueError(f"max_delta_signatures must be >= 1, got {n}")
        MAX_DELTA_SIGNATURES = n
    _DELTA_SIGS.clear()
    return MAX_DELTA_SIGNATURES


def max_delta_signatures() -> int:
    """The current delta-signature bound (see
    :func:`set_max_delta_signatures`)."""
    return MAX_DELTA_SIGNATURES


def _bound_delta_signatures(plan, key):
    """Jit-cache bound for the collective drain: every distinct
    ``(deltas, t)`` plan signature compiles its own shard_map body, and an
    adversarial stream can churn up to ``2^(S-1)`` delta subsets.  Past
    :data:`MAX_DELTA_SIGNATURES` distinct signatures per (mesh, pool
    structure), fold the plan onto the FULL delta set (cmdqueue
    ``fold_shard_plan``) — the folded signature is one shape per slot
    bucket, so the compile count stays O(1) while unseen subsets keep
    draining correctly (their extra ppermutes carry all-padding tables)."""
    if not plan.deltas:
        return plan                 # local-only drain: one signature
    sigs = _DELTA_SIGS.setdefault(key, set())
    sig = (plan.deltas, int(plan.send_rows.shape[2]))
    if sig in sigs:
        return plan
    if len(sigs) < MAX_DELTA_SIGNATURES:
        sigs.add(sig)
        return plan
    from repro.core.cmdqueue import fold_shard_plan
    return fold_shard_plan(plan)


def sharded_fused_dispatch(pools: Sequence, zero_blocks: Sequence, plan, *,
                           mesh, pool_axes: Tuple[str, ...],
                           block_axis: int = 0, use_pallas: bool = False,
                           interpret: bool = False,
                           primary: Optional[Tuple[bool, ...]] = None,
                           replicated: Optional[Tuple[bool, ...]] = None
                           ) -> Tuple:
    """Drain one partitioned flush (a cmdqueue.ShardPlan) as ONE collective
    launch over every pool: per-slab fused sub-table drains + the
    cross-slab send/recv plan, all inside a single shard_map'd dispatch.
    Pools may carry different block counts (each partitions by its own
    shard size — ``plan.shard_sizes``); ``primary`` is the per-pool role
    vector exactly as in :func:`fused_dispatch_pallas`; ``replicated``
    marks pools held whole on every device (``PoolSpec.sharding == ()``
    hints), which must match the plan's partitioning."""
    primary = _as_primary(primary, len(pools))
    if replicated is None:
        replicated = tuple([False] * len(pools))
    plan = _bound_delta_signatures(
        plan, (mesh, tuple(pool_axes), len(pools), block_axis, primary,
               replicated))
    if plan.deltas:
        send = jnp.asarray(plan.send_rows)
        recv = jnp.asarray(plan.recv_tables)
    else:  # no cross-slab traffic: zero-length transfer tables, no permutes
        s = plan.n_shards
        send = jnp.zeros((0, s, 1), jnp.int32)
        recv = jnp.full((0, s, 1, 4), -1, jnp.int32)
    runner = _sharded_runner(mesh, tuple(pool_axes), tuple(plan.deltas),
                             len(pools), block_axis, use_pallas, interpret,
                             primary, tuple(replicated))
    out = runner(jnp.asarray(plan.local_tables), send, recv,
                 tuple(zero_blocks), tuple(pools))
    notify_launch(int(plan.local_tables.shape[1]), len(out), "fused_mesh")
    return tuple(out)
