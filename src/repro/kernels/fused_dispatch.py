"""Fused command-queue dispatch kernel — the MC's serialized command stream.

RowClone's memory controller accepts a stream of copy/init commands and
executes them back-to-back inside DRAM with no per-command CPU involvement
(§2.3).  The seed engine betrayed that: one device dispatch per mechanism
per pool (up to 8 launches for one mixed request batch).  This kernel is the
TPU analogue of the MC's command queue drain: **one** ``pallas_call`` whose
scalar-prefetched SMEM table is ``(m, 3)`` int32 ``[opcode, src, dst]`` rows;
the grid body switches on the opcode and issues the corresponding HBM→HBM
``make_async_copy`` (copies) or zero-row broadcast DMA (init), reusing the
alternating-semaphore structure of the single-mechanism kernels it
replaces (the drain itself is serial — each DMA completes before the
next; see the note in the kernel body).  Multi-pool engines (K and V
pages of one KV block) pass every pool to the same launch; each grid step
moves the block in all of them.

Opcodes (also the ``CommandQueue`` tags, core/cmdqueue.py):

  ======================  ==  ==================================================
  ``OP_FPM_COPY``          0  same-slab block copy (FPM — subarray-local DMA)
  ``OP_PSM_COPY``          1  cross-slab copy (PSM; same DMA on a single slab)
  ``OP_BASELINE_COPY``     2  RowClone-disabled copy (mechanism modeling only)
  ``OP_ZERO_INIT``         3  BuZ — broadcast the reserved zero block into dst
  ``OP_CROSS_POOL_COPY``   4  pool-to-pool copy; src/dst are *stacked* global
                              ids ``pool_index * nblk + block`` (pools must
                              share block shape and dtype)
  ``OP_NOP``              -1  padding row (bucketed table), also ``dst == -1``
  ======================  ==  ==================================================

``block_axis=1`` handles layer-stacked serving pools ``(L, nblk, ...)``: the
grid grows a layer dimension and each command becomes L independent DMAs, as
in the seed's axis-1 path.

CONTRACT (same as the per-mechanism kernels, now per *flush*): within one
table, no row may read a block that an earlier row writes, and no two rows
may write the same block — the CommandQueue's hazard guards auto-flush
before either can occur.  Under that contract sources observe the
pre-flush pool state (the kernel actually reads in place during the
serial drain, which the guards make indistinguishable — and which lets
the pools be aliased in-place with no snapshot copy).
"""
from __future__ import annotations

import functools
from typing import Callable, List, Sequence, Tuple

import jax
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

OP_NOP = -1
OP_FPM_COPY = 0
OP_PSM_COPY = 1
OP_BASELINE_COPY = 2
OP_ZERO_INIT = 3
OP_CROSS_POOL_COPY = 4

OPCODE_NAMES = {
    OP_NOP: "nop",
    OP_FPM_COPY: "fpm_copy",
    OP_PSM_COPY: "psm_copy",
    OP_BASELINE_COPY: "baseline_copy",
    OP_ZERO_INIT: "zero_init",
    OP_CROSS_POOL_COPY: "cross_pool_copy",
}

# ---------------------------------------------------------------------------
# launch accounting — the hook tests and benchmarks use to assert "one
# kernel launch per flush".  Every device dispatch of bulk-movement work
# (fused or legacy per-op) reports here.
# ---------------------------------------------------------------------------

_LAUNCH_HOOKS: List[Callable[[int, int, str], None]] = []
_LAUNCH_COUNT = 0


def add_launch_hook(fn: Callable[[int, int, str], None]) -> None:
    """Register ``fn(n_commands, n_pools, mechanism)`` to fire per launch."""
    _LAUNCH_HOOKS.append(fn)


def remove_launch_hook(fn: Callable[[int, int, str], None]) -> None:
    _LAUNCH_HOOKS.remove(fn)


def launch_count() -> int:
    """Cumulative bulk-movement launches this process."""
    return _LAUNCH_COUNT


def notify_launch(n_commands: int, n_pools: int, mechanism: str) -> None:
    global _LAUNCH_COUNT
    _LAUNCH_COUNT += 1
    for fn in _LAUNCH_HOOKS:
        fn(n_commands, n_pools, mechanism)


# ---------------------------------------------------------------------------
# the kernel
# ---------------------------------------------------------------------------

def _make_kernel(n_pools: int, block_axis: int, nblk: int):
    def kernel(cmds_ref, *refs):
        zeros = refs[:n_pools]
        # refs[n:2n] are the aliased (donated) pool inputs — never touched;
        # both reads and writes go through ``outs`` (in place).  The drain
        # is serial and the CommandQueue excludes read-after-write and
        # write-after-write within a table, so in-place source reads equal
        # pre-flush state reads — and no snapshot copy of the pools is
        # ever materialized.
        outs = refs[2 * n_pools:3 * n_pools]
        sems = refs[3 * n_pools:3 * n_pools + 2]
        reads = outs

        i = pl.program_id(0)
        op = cmds_ref[i, 0]
        s = cmds_ref[i, 1]
        d = cmds_ref[i, 2]
        if block_axis == 1:
            l = pl.program_id(1)
            step = i * pl.num_programs(1) + l
        else:
            l = None
            step = i

        def blk(ref, b):
            return ref.at[l, b] if block_axis == 1 else ref.at[b]

        def issue(src, dst, sem):
            cp = pltpu.make_async_copy(src, dst, sem)
            cp.start()
            cp.wait()

        def dispatch(sem):
            @pl.when((op == OP_FPM_COPY) | (op == OP_PSM_COPY) |
                     (op == OP_BASELINE_COPY))
            def _():
                for p in range(n_pools):
                    issue(blk(reads[p], s), blk(outs[p], d), sem)

            @pl.when(op == OP_ZERO_INIT)
            def _():
                for p in range(n_pools):
                    issue(zeros[p].at[0], blk(outs[p], d), sem)

            @pl.when(op == OP_CROSS_POOL_COPY)
            def _():
                for ps in range(n_pools):
                    for pd in range(n_pools):
                        @pl.when((s // nblk == ps) & (d // nblk == pd))
                        def _(ps=ps, pd=pd):
                            issue(blk(reads[ps], s % nblk),
                                  blk(outs[pd], d % nblk), sem)

        # Semaphores alternate by grid-step parity, mirroring the seed
        # per-mechanism kernels.  NOTE: with start() immediately followed
        # by wait() the drain is fully serial — the parity split is the
        # slot structure for a future overlapped drain (wait one step
        # behind), which would also need source-hazard tracking in the
        # CommandQueue (it guards pending *destinations* only).
        @pl.when((op >= 0) & (d >= 0))
        def _():
            @pl.when(step % 2 == 0)
            def _():
                dispatch(sems[0])

            @pl.when(step % 2 == 1)
            def _():
                dispatch(sems[1])

    return kernel


@functools.partial(jax.jit, static_argnames=("block_axis", "interpret"),
                   donate_argnums=(2,))
def _fused_dispatch_jit(cmds, zero_blocks, pools, *, block_axis: int,
                        interpret: bool):
    n_pools = len(pools)
    nblk = pools[0].shape[block_axis]
    grid = ((cmds.shape[0],) if block_axis == 0
            else (cmds.shape[0], pools[0].shape[0]))
    return pl.pallas_call(
        _make_kernel(n_pools, block_axis, nblk),
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=1,
            grid=grid,
            in_specs=[pl.BlockSpec(memory_space=pl.ANY)] * (2 * n_pools),
            out_specs=[pl.BlockSpec(memory_space=pl.ANY)] * n_pools,
            scratch_shapes=[pltpu.SemaphoreType.DMA,
                            pltpu.SemaphoreType.DMA],
        ),
        out_shape=[jax.ShapeDtypeStruct(p.shape, p.dtype) for p in pools],
        # operand order: cmds, zeros (n), donated pools (n); pools are
        # passed ONCE and aliased — the kernel works in place, so no
        # full-pool snapshot copy is inserted by XLA
        input_output_aliases={1 + n_pools + p: p for p in range(n_pools)},
        interpret=interpret,
    )(cmds, *zero_blocks, *pools)


def fused_dispatch_pallas(pools: Sequence, zero_blocks: Sequence, cmds, *,
                          block_axis: int = 0,
                          interpret: bool = False) -> Tuple:
    """Execute one flushed command table over every pool in ONE launch.

    pools:       sequence of (nblk, ...) or (L, nblk, ...) arrays (donated)
    zero_blocks: per-pool reserved zero row, shape (1,) + block_shape
    cmds:        (m, 3) int32 [opcode, src, dst]; OP_NOP/-1 rows are padding
    """
    out = _fused_dispatch_jit(cmds, tuple(zero_blocks), tuple(pools),
                              block_axis=block_axis, interpret=interpret)
    notify_launch(int(cmds.shape[0]), len(out), "fused")
    return tuple(out)
