"""Pure-jnp oracles for every Pallas kernel in this package.

These are the semantic ground truth: each kernel's test sweeps shapes/dtypes
and asserts allclose against the function here.  They are also the execution
path on CPU (and inside the 512-device dry-run, where interpret-mode Pallas
would bloat the HLO).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

NEG_INF = -1e30


# ---------------------------------------------------------------------------
# FPM — in-pool block gather-copy (RowClone Fast Parallel Mode analogue)
# ---------------------------------------------------------------------------

def fpm_copy(pool, src_ids, dst_ids):
    """Copy pool[src_ids[i]] -> pool[dst_ids[i]] for all i.

    pool: (nblk, ...) array; src_ids/dst_ids: (m,) int32.  dst ids must be
    disjoint from each other; a dst id of -1 disables that copy (the engine
    pads request lists to a fixed length with -1).
    """
    rows = pool[jnp.clip(src_ids, 0, pool.shape[0] - 1)]
    safe_dst = jnp.where(dst_ids >= 0, dst_ids, pool.shape[0])  # OOB drops
    return pool.at[safe_dst].set(rows, mode="drop")


def fpm_copy_cross(dst_pool, src_pool, src_ids, dst_ids):
    """Pool-to-pool variant (same 'subarray' = same device slab)."""
    rows = src_pool[jnp.clip(src_ids, 0, src_pool.shape[0] - 1)]
    safe_dst = jnp.where(dst_ids >= 0, dst_ids, dst_pool.shape[0])
    return dst_pool.at[safe_dst].set(rows, mode="drop")


# ---------------------------------------------------------------------------
# BuZ — bulk zero via reserved zero row (meminit)
# ---------------------------------------------------------------------------

def zero_init(pool, ids, fill_value=0.0):
    """Zero (or fill) the listed blocks.  ids: (m,) int32, -1 disables."""
    safe = jnp.where(ids >= 0, ids, pool.shape[0])
    fill = jnp.full((ids.shape[0],) + pool.shape[1:], fill_value, pool.dtype)
    return pool.at[safe].set(fill, mode="drop")


# ---------------------------------------------------------------------------
# Fused command-queue dispatch — one call applies a whole flushed command
# table (kernels/fused_dispatch.py) to every pool.  Semantics: gather every
# source row from the PRE-flush pool state, then scatter — equivalent to the
# kernel's sequential DMA drain under the CommandQueue's hazard guards (no
# row reads or rewrites a block an earlier row writes).
# ---------------------------------------------------------------------------

def fused_dispatch(pools, zero_blocks, cmds, block_axis=0, primary=None):
    """pools: sequence of (nblk_p, ...) or (L, nblk_p, ...) — block counts
    may DIFFER per pool; zero_blocks: per-pool (1,) + block_shape; cmds:
    (m, 3) int32 [opcode, src, dst].

    ``primary``: per-pool role vector — plain opcodes (copies, zero-init)
    move the block in every primary pool (all primary pools share one
    block count); *staging* pools only receive ``OP_CROSS_POOL_COPY`` rows
    that name them in a global ``base[pool] + block`` id, where ``base``
    is the prefix sum of the pool block counts (the PoolGroup address
    space).  None = every pool is primary.

    Bitwise compute rows (``OP_AND``/``OP_OR``/``OP_NOT``) carry TWO
    sources packed into the src field — ``src = a * total + b`` over the
    same global-id space (``total`` = sum of the pool block counts;
    ``OP_NOT`` packs ``b == a``) — and a *global-id* dst, so fingerprint
    rows can land in staging pools.  Sources are gathered from the
    pre-flush state and combined through a same-width unsigned-int
    bitcast, so float pools AND/OR/NOT their raw bit patterns."""
    from repro.core.opcodes import (BITWISE_OPS, OP_AND, OP_CROSS_POOL_COPY,
                                    OP_OR, OP_ZERO_INIT)
    from repro.kernels.fused_dispatch import (_as_primary, _bitcast_uint,
                                              _op_in)
    pools = list(pools)
    n = len(pools)
    primary = _as_primary(primary, n)
    ba = block_axis
    sizes = [p.shape[ba] for p in pools]
    bases = []
    run = 0
    for nb in sizes:
        bases.append(run)
        run += nb
    total = run
    op, s, d = cmds[:, 0], cmds[:, 1], cmds[:, 2]
    is_cross = op == OP_CROSS_POOL_COPY
    # membership derives from the core/opcodes.py registry — adding a
    # compute opcode updates this switch without touching the reference
    is_bitwise = _op_in(op, BITWISE_OPS)

    def pool_of(ids):
        """Per-row (base, in_pool[p]) decode of global cross-pool ids."""
        base = jnp.zeros_like(ids)
        inp = []
        for p in range(n):
            m = (ids >= bases[p]) & (ids < bases[p] + sizes[p])
            inp.append(m)
            base = jnp.where(m, bases[p], base)
        return base, inp

    # two-source decode: a/b are plain global ids once unpacked (clamped to
    # zero on non-bitwise rows so the masks below stay well-formed)
    a_g = jnp.where(is_bitwise, s // total, 0)
    b_g = jnp.where(is_bitwise, s % total, 0)
    s_base, s_in = pool_of(s)
    d_base, d_in = pool_of(d)
    a_base, a_in = pool_of(a_g)
    b_base, b_in = pool_of(b_g)
    glb_dst = is_cross | is_bitwise          # rows whose dst is a global id
    s_loc = jnp.where(is_cross, s - s_base, s)
    d_loc = jnp.where(glb_dst, d - d_base, d)
    a_loc = a_g - a_base
    b_loc = b_g - b_base

    def gather(arr, idx):
        cl = jnp.clip(idx, 0, arr.shape[ba] - 1)
        return arr[cl] if ba == 0 else arr[:, cl]

    def expand(cond, rows):
        shape = [1] * rows.ndim
        shape[ba] = cond.shape[0]
        return cond.reshape(shape)

    def gather_global(loc, in_masks, pd):
        """Gather per-row blocks addressed by a global id decoded to
        ``(loc, in_masks)`` — start from the dst pool, override from every
        other pool the id actually names (the cross-pool select idiom)."""
        rows = gather(pools[pd], loc)
        for ps in range(n):
            if ps == pd:
                continue
            rows = jnp.where(expand(in_masks[ps], rows),
                             gather(pools[ps], loc).astype(rows.dtype), rows)
        return rows

    out = []
    for pd in range(n):
        pool = pools[pd]
        rows = gather(pool, s_loc)
        for ps in range(n):
            if ps == pd:
                continue
            sel = is_cross & s_in[ps]
            rows = jnp.where(expand(sel, rows), gather(pools[ps], s_loc),
                             rows)
        zb = zero_blocks[pd].astype(pool.dtype)
        if ba == 0:
            zrows = jnp.broadcast_to(zb, (cmds.shape[0],) + pool.shape[1:])
        else:
            zrows = jnp.broadcast_to(
                zb.reshape((1, 1) + zb.shape[1:]),
                (pool.shape[0], cmds.shape[0]) + pool.shape[2:])
        rows = jnp.where(expand(op == OP_ZERO_INIT, rows), zrows, rows)
        # bitwise compute rows: combine both sources bit-for-bit
        au = _bitcast_uint(gather_global(a_loc, a_in, pd))
        bu = _bitcast_uint(gather_global(b_loc, b_in, pd))
        ru = jnp.where(expand(op == OP_AND, au), au & bu,
                       jnp.where(expand(op == OP_OR, au), au | bu, ~au))
        brows = jax.lax.bitcast_convert_type(ru, pool.dtype)
        rows = jnp.where(expand(is_bitwise, rows), brows, rows)
        if primary[pd]:
            valid = (op >= 0) & (d >= 0) & (~glb_dst | d_in[pd])
        else:   # staging pool: only global-id rows addressed to it land
            valid = glb_dst & (d >= 0) & d_in[pd]
        safe = jnp.where(valid, d_loc, sizes[pd])
        out.append(pool.at[safe].set(rows, mode="drop") if ba == 0
                   else pool.at[:, safe].set(rows, mode="drop"))
    return tuple(out)


# ---------------------------------------------------------------------------
# Baseline copy — what RowClone replaces: stream blocks through the compute
# pipeline (HBM -> VMEM -> VREG -> VMEM -> HBM).  Numerically identical to
# fpm_copy; exists so benchmarks can compare mechanisms.
# ---------------------------------------------------------------------------

def baseline_copy(pool, src_ids, dst_ids):
    """RowClone-disabled copy: same result as fpm_copy, but the bytes
    round-trip the compute pipeline (identity VPU op keeps it honest)."""
    rows = pool[jnp.clip(src_ids, 0, pool.shape[0] - 1)]
    # force a VPU round-trip: identity arithmetic the compiler must keep
    rows = (rows.astype(jnp.float32) * 1.0).astype(pool.dtype)
    safe_dst = jnp.where(dst_ids >= 0, dst_ids, pool.shape[0])
    return pool.at[safe_dst].set(rows, mode="drop")


# ---------------------------------------------------------------------------
# Paged decode attention — one device slab, flash partials
# ---------------------------------------------------------------------------

def _merge(m, l, acc, m2, l2, acc2):
    m_new = jnp.maximum(m, m2)
    c1 = jnp.exp(m - m_new)
    c2 = jnp.exp(m2 - m_new)
    return m_new, l * c1 + l2 * c2, acc * c1[..., None] + acc2 * c2[..., None]


def _auto_chunk(nblk, B, KVH, group, pg, budget_floats=2 * 1024 * 1024):
    """Largest power-of-two divisor of nblk whose score tile fits budget."""
    per_block = max(B * KVH * group * pg, 1)
    cap = max(budget_floats // per_block, 1)
    chunk = 1
    while chunk * 2 <= min(cap, nblk) and nblk % (chunk * 2) == 0:
        chunk *= 2
    return chunk


def paged_attention_slab(q, k_slab, v_slab, share_mask, base, seq_lens, *,
                         page: int, block_chunk: int = 0,
                         exclusive: bool = False):
    """Partial paged attention over one slab (see models/attention.py doc).

    ``share_mask``: (nblk, B) {0,1} — block readable by sequence b.  CoW
    forks set several columns per block; free blocks have an all-zero row.

    Two modes:
      * all-pairs (default): scores for every (sequence, block) pair, then
        masked — exact for arbitrary CoW sharing; B× extra MXU work hides
        under the HBM-bound KV stream.
      * ``exclusive=True``: every block has ≤1 reader (no sharing active —
        the serving engine knows from refcounts).  Queries are gathered
        per block via a one-hot matmul; score tile shrinks B×
        (EXPERIMENTS.md §Perf iteration 4).

    Returns (acc (B,H,D) fp32, l (B,H) fp32, m (B,H) fp32).
    """
    nblk, pg, KVH, D = k_slab.shape
    B, H, _ = q.shape
    group = H // KVH
    scale = D ** -0.5
    eff_b = 1 if exclusive else B
    chunk = block_chunk or _auto_chunk(nblk, eff_b, KVH, group, pg)
    n_chunks = max(nblk // chunk, 1)
    chunk = nblk // n_chunks

    kc = k_slab.reshape(n_chunks, chunk, pg, KVH, D)
    vc = v_slab.reshape(n_chunks, chunk, pg, KVH, D)
    mc_ = share_mask.reshape(n_chunks, chunk, B)
    bc = base.reshape(n_chunks, chunk)

    qg = q.reshape(B, KVH, group, D).astype(jnp.float32)
    lens_f = seq_lens.astype(jnp.float32)

    def body_allpairs(carry, inp):
        m, l, acc = carry
        kb, vb, mk, bb = inp
        # keep K/V in storage dtype; accumulate in fp32 via the MXU
        s = jnp.einsum("bkgd,cpkd->bckgp", qg.astype(kb.dtype), kb,
                       preferred_element_type=jnp.float32) * scale
        pos = bb[:, None] + jnp.arange(pg, dtype=bb.dtype)[None, :]  # (c,p)
        valid = (mk.T[:, :, None] > 0) & (pos[None] < seq_lens[:, None, None])
        s = jnp.where(valid[:, :, None, None, :], s, NEG_INF)
        m_c = s.max(axis=(1, 4))                                 # (B,KVH,g)
        p = jnp.exp(s - m_c[:, None, :, :, None])
        p = jnp.where(valid[:, :, None, None, :], p, 0.0)
        l_c = p.sum(axis=(1, 4))
        acc_c = jnp.einsum("bckgp,cpkd->bkgd", p.astype(vb.dtype), vb,
                           preferred_element_type=jnp.float32)
        return _merge(m, l, acc, m_c, l_c, acc_c), None

    def body_owner(carry, inp):
        m, l, acc = carry
        kb, vb, mk, bb = inp
        oh = mk.astype(jnp.float32)                              # (c,B)
        qb = (oh @ qg.reshape(B, KVH * group * D)) \
            .reshape(chunk, KVH, group, D)                       # q[owner]
        s = jnp.einsum("ckgd,cpkd->ckgp", qb.astype(kb.dtype), kb,
                       preferred_element_type=jnp.float32) * scale
        pos = bb[:, None] + jnp.arange(pg, dtype=bb.dtype)[None, :]
        own_len = (oh @ lens_f[:, None])[:, 0].astype(jnp.int32)
        valid = (mk.sum(-1) > 0)[:, None] & (pos < own_len[:, None])
        s = jnp.where(valid[:, None, None, :], s, NEG_INF)
        m_blk = jnp.where((mk.sum(-1) > 0)[:, None, None],
                          s.max(axis=-1), NEG_INF)               # (c,KVH,g)
        m_c = jnp.max(jnp.where(oh.T[:, :, None, None] > 0, m_blk[None],
                                NEG_INF), axis=1)                # (B,KVH,g)
        m_back = (oh @ m_c.reshape(B, KVH * group)) \
            .reshape(chunk, KVH, group)
        p = jnp.exp(s - m_back[..., None])
        p = jnp.where(valid[:, None, None, :], p, 0.0)
        l_c = jnp.einsum("cb,ckg->bkg", oh, p.sum(axis=-1))
        pv = jnp.einsum("ckgp,cpkd->ckgd", p.astype(vb.dtype), vb,
                        preferred_element_type=jnp.float32)
        acc_c = jnp.einsum("cb,ckgd->bkgd", oh, pv)
        return _merge(m, l, acc, m_c, l_c, acc_c), None

    body = body_owner if exclusive else body_allpairs
    m0 = jnp.full((B, KVH, group), NEG_INF, jnp.float32)
    l0 = jnp.zeros((B, KVH, group), jnp.float32)
    a0 = jnp.zeros((B, KVH, group, D), jnp.float32)
    (m, l, acc), _ = jax.lax.scan(body, (m0, l0, a0), (kc, vc, mc_, bc))
    return (acc.reshape(B, H, D), l.reshape(B, H), m.reshape(B, H))


def paged_attention_dense_ref(q, k, v, seq_lens):
    """Oracle-of-the-oracle: dense attention with per-seq valid lengths.

    q: (B,H,D); k,v: (B,S,KVH,D) contiguous caches.  Returns (B,H,D).
    """
    B, H, D = q.shape
    KVH = k.shape[2]
    group = H // KVH
    qg = q.reshape(B, KVH, group, D).astype(jnp.float32)
    s = jnp.einsum("bkgd,bskd->bkgs", qg, k.astype(jnp.float32)) * D ** -0.5
    pos = jnp.arange(k.shape[1])[None, :]
    s = jnp.where((pos < seq_lens[:, None])[:, None, None, :], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bkgs,bskd->bkgd", p, v.astype(jnp.float32))
    return o.reshape(B, H, D)


# ---------------------------------------------------------------------------
# Flash attention oracle (naive full-matrix attention)
# ---------------------------------------------------------------------------

def flash_attention_ref(q, k, v, pos_q, pos_kv, kv_valid, causal=True,
                        prefix_len=0):
    """Naive full-matrix attention oracle for the flash kernel.

    q: (B,Sq,H,D); k/v: (B,Skv,KVH,D); masks by position + validity."""
    B, Sq, H, D = q.shape
    KVH = k.shape[2]
    group = H // KVH
    qg = q.reshape(B, Sq, KVH, group, D).astype(jnp.float32)
    s = jnp.einsum("bqkgd,bskd->bqkgs", qg, k.astype(jnp.float32)) * D ** -0.5
    m = kv_valid[:, None, :]
    if causal:
        allowed = pos_q[:, :, None] >= pos_kv[:, None, :]
        if prefix_len:
            allowed |= (pos_kv < prefix_len)[:, None, :]
        m = m & allowed
    s = jnp.where(m[:, :, None, None, :], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bqkgs,bskd->bqkgd", p, v.astype(jnp.float32))
    return o.reshape(B, Sq, H, D).astype(q.dtype)


# ---------------------------------------------------------------------------
# SSD (Mamba2) oracle — naive recurrence
# ---------------------------------------------------------------------------

def ssd_ref(x, dt, A, B_mat, C_mat, D_skip):
    """Naive sequential state-space recurrence.

    x:     (B, S, H, P)   inner activations per head
    dt:    (B, S, H)      softplus'd timestep (>0)
    A:     (H,)           negative per-head decay (A = -exp(A_log))
    B_mat: (B, S, N)      input projection (shared across heads, G=1)
    C_mat: (B, S, N)      output projection
    D_skip:(H,)           skip connection
    Returns y: (B, S, H, P)
    """
    Bb, S, H, P = x.shape
    N = B_mat.shape[-1]

    def step(h, inp):
        xt, dtt, bt, ct = inp                       # (B,H,P),(B,H),(B,N),(B,N)
        decay = jnp.exp(dtt * A[None, :])           # (B,H)
        dbx = jnp.einsum("bhp,bn,bh->bhpn", xt, bt, dtt)
        h = h * decay[..., None, None] + dbx
        y = jnp.einsum("bhpn,bn->bhp", h, ct)
        return h, y

    h0 = jnp.zeros((Bb, H, P, N), jnp.float32)
    xs = (x.swapaxes(0, 1).astype(jnp.float32), dt.swapaxes(0, 1),
          B_mat.swapaxes(0, 1).astype(jnp.float32),
          C_mat.swapaxes(0, 1).astype(jnp.float32))
    _, ys = jax.lax.scan(step, h0, xs)
    y = ys.swapaxes(0, 1) + x.astype(jnp.float32) * D_skip[None, None, :, None]
    return y.astype(x.dtype)
