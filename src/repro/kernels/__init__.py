"""Pallas TPU kernels for the perf-critical layers, each with a pure-jnp
oracle in ref.py and a jit'd public wrapper in ops.py:

  fused_dispatch  — ONE launch per CommandQueue flush: scalar-prefetched
                    [opcode,src,dst] table drained as back-to-back DMAs
                    over every pool (the MC command-serialization analogue)
  fpm_copy        — RowClone FPM: HBM->HBM DMA block copy (no compute)
  psm_transfer    — RowClone PSM: cross-chip RDMA block transfer (ICI),
                    pipelined; TARGET code (RDMA needs real TPU)
  zero_init       — RowClone BuZ: zero-row DMA broadcast
  paged_attention — decode attention slab sweep (flash, CoW share mask)
  flash_attention — train/prefill attention (causal + prefix-LM)
  ssd_chunk       — Mamba2 SSD intra-chunk quadratic term

See docs/ARCHITECTURE.md for the paper-mechanism → module map."""
