"""paligemma-3b — VLM: SigLIP frontend (stub) + gemma decoder backbone.

[arXiv:2407.07726; hf]  18L d_model=2048 8H (GQA kv=1, MQA) d_ff=16384
vocab=257216.  Vision frontend is a STUB — input_specs() provides 256
precomputed patch embeddings prepended to the text sequence with a
bidirectional prefix-LM mask (PaliGemma's attention pattern).
"""
from repro.configs.base import ModelConfig
from repro.configs.registry import register

CONFIG = register(
    ModelConfig(
        arch_id="paligemma-3b",
        family="vlm",
        num_layers=18,
        d_model=2048,
        num_heads=8,
        num_kv_heads=1,
        head_dim=256,
        d_ff=16384,
        vocab_size=257216,
        vision_tokens=256,
        rope_theta=10000.0,
        tie_embeddings=True,
    )
)
