"""seamless-m4t-medium — encoder-decoder multimodal backbone.

[arXiv:2308.11596; hf]  12L d_model=1024 16H (kv=16, MHA) d_ff=4096
vocab=256206.  Enc-dec: 12 encoder + 12 decoder layers; the audio frontend
is a STUB — input_specs() provides precomputed frame embeddings
(src_len = seq_len // 4, emulating 4x-downsampled speech frames).
"""
from repro.configs.base import ModelConfig
from repro.configs.registry import register

CONFIG = register(
    ModelConfig(
        arch_id="seamless-m4t-medium",
        family="encdec",
        num_layers=12,
        d_model=1024,
        num_heads=16,
        num_kv_heads=16,
        head_dim=64,
        d_ff=4096,
        vocab_size=256206,
        encoder_layers=12,
        src_frames_ratio=4,
        rope_theta=10000.0,
    )
)
