"""deepseek-moe-16b — fine-grained MoE: 2 shared + 64 routed, top-6.

[arXiv:2401.06066; hf]  28L d_model=2048 16H (kv=16, i.e. MHA) d_ff=1408
(per expert) vocab=102400, MoE 64e top-6.
"""
from repro.configs.base import ModelConfig
from repro.configs.registry import register

CONFIG = register(
    ModelConfig(
        arch_id="deepseek-moe-16b",
        family="moe",
        num_layers=28,
        d_model=2048,
        num_heads=16,
        num_kv_heads=16,
        head_dim=128,
        d_ff=1408,
        vocab_size=102400,
        num_experts=64,
        top_k=6,
        num_shared_experts=2,
        rope_theta=10000.0,
    )
)
