"""Registry mapping public arch ids to ModelConfigs."""
from __future__ import annotations

from typing import Dict

from repro.configs.base import ModelConfig

_REGISTRY: Dict[str, ModelConfig] = {}


def register(cfg: ModelConfig) -> ModelConfig:
    if cfg.arch_id in _REGISTRY:
        raise ValueError(f"duplicate arch id {cfg.arch_id}")
    _REGISTRY[cfg.arch_id] = cfg
    return cfg


def get_config(arch_id: str) -> ModelConfig:
    _ensure_loaded()
    try:
        return _REGISTRY[arch_id]
    except KeyError:
        raise KeyError(
            f"unknown arch {arch_id!r}; available: {sorted(_REGISTRY)}"
        ) from None


def list_archs():
    _ensure_loaded()
    return sorted(_REGISTRY)


def _ensure_loaded() -> None:
    # import side-effect registration
    from repro.configs import (  # noqa: F401
        zamba2_2p7b,
        llama3p2_3b,
        qwen2_72b,
        yi_6b,
        mistral_nemo_12b,
        phi3p5_moe_42b,
        deepseek_moe_16b,
        mamba2_780m,
        seamless_m4t_medium,
        paligemma_3b,
    )
