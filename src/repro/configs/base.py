"""Configuration system: model architectures, input shapes, and run settings.

Every assigned architecture gets one module in this package defining a
``ModelConfig`` with the exact published hyper-parameters, registered under
its public arch id (e.g. ``qwen2-72b``).  Reduced smoke-test variants are
derived mechanically via :func:`ModelConfig.reduced`.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Optional, Tuple

VOCAB_PAD_MULTIPLE = 256  # pad vocab so embedding/vocab axes shard over 16-way TP


def pad_to(x: int, m: int) -> int:
    return ((x + m - 1) // m) * m


@dataclass(frozen=True)
class ModelConfig:
    """Architecture hyper-parameters (single source of truth).

    ``family`` is one of: dense | moe | ssm | hybrid | encdec | vlm.
    """

    arch_id: str
    family: str
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    head_dim: int
    d_ff: int
    vocab_size: int

    # --- attention details ---
    qkv_bias: bool = False
    rope_theta: float = 500000.0

    # --- MoE ---
    num_experts: int = 0
    top_k: int = 0
    num_shared_experts: int = 0          # deepseek-style shared experts
    moe_d_ff: int = 0                    # per-expert hidden size (0 => d_ff)

    # --- SSM (mamba2 / SSD) ---
    ssm_state: int = 0
    ssm_heads: int = 0                   # number of SSD heads
    ssm_head_dim: int = 64
    ssm_expand: int = 2
    ssm_conv_width: int = 4
    ssm_chunk: int = 256                 # SSD chunk length

    # --- hybrid (zamba2) ---
    shared_attn_every: int = 0           # shared attention block every N core layers

    # --- encoder/decoder (seamless-m4t) ---
    encoder_layers: int = 0              # 0 => decoder-only
    src_frames_ratio: int = 4            # src_len = seq_len // ratio (audio stub)

    # --- vlm (paligemma) ---
    vision_tokens: int = 0               # prefix patch embeddings (stub frontend)

    dtype: str = "bfloat16"
    tie_embeddings: bool = False
    norm_eps: float = 1e-5

    # ------------------------------------------------------------------
    @property
    def padded_vocab(self) -> int:
        return pad_to(self.vocab_size, VOCAB_PAD_MULTIPLE)

    @property
    def q_dim(self) -> int:
        return self.num_heads * self.head_dim

    @property
    def kv_dim(self) -> int:
        return self.num_kv_heads * self.head_dim

    @property
    def ssm_d_inner(self) -> int:
        return self.ssm_expand * self.d_model

    @property
    def is_attention_free(self) -> bool:
        return self.family == "ssm"

    @property
    def has_subquadratic_path(self) -> bool:
        """True if long-context decode (long_500k) is runnable: the sequence-
        length-dependent state is O(1) (SSM) or attention is confined to a
        small number of shared blocks (hybrid)."""
        return self.family in ("ssm", "hybrid")

    @property
    def num_attn_layers(self) -> int:
        """Layers that own a KV cache."""
        if self.family == "ssm":
            return 0
        if self.family == "hybrid":
            return self.num_layers // max(self.shared_attn_every, 1)
        if self.family == "encdec":
            return self.num_layers  # decoder self-attn layers
        return self.num_layers

    # ------------------------------------------------------------------
    def reduced(self) -> "ModelConfig":
        """Tiny same-family variant for CPU smoke tests."""
        return dataclasses.replace(
            self,
            arch_id=self.arch_id + "-smoke",
            num_layers=min(self.num_layers, 4 if self.shared_attn_every == 0 else 4),
            d_model=128,
            num_heads=4,
            num_kv_heads=min(self.num_kv_heads, 4) if self.num_kv_heads > 1 else 1,
            head_dim=32,
            d_ff=256,
            moe_d_ff=64 if self.moe_d_ff else 0,
            vocab_size=512,
            num_experts=min(self.num_experts, 4),
            top_k=min(self.top_k, 2),
            num_shared_experts=min(self.num_shared_experts, 1),
            ssm_state=min(self.ssm_state, 16) if self.ssm_state else 0,
            # keep ssm_heads * ssm_head_dim == ssm_expand * d_model
            ssm_heads=8 if self.ssm_heads else 0,
            ssm_head_dim=32 if self.ssm_heads else 64,
            ssm_chunk=32,
            shared_attn_every=2 if self.shared_attn_every else 0,
            encoder_layers=2 if self.encoder_layers else 0,
            vision_tokens=16 if self.vision_tokens else 0,
            dtype="float32",
        )

    def param_count(self) -> int:
        """Analytic parameter count (used for 6ND model-FLOPs)."""
        d, V = self.d_model, self.padded_vocab
        n = V * d  # embedding
        if not self.tie_embeddings:
            n += V * d
        if self.family == "ssm":
            n += self.num_layers * _mamba2_layer_params(self)
            n += self.num_layers * d  # norms
            return n
        if self.family == "hybrid":
            n += self.num_layers * _mamba2_layer_params(self)
            n += self.num_layers * d
            n += _attn_block_params(self) + _mlp_params(self, self.d_ff)  # shared block
            return n
        per_layer = _attn_block_params(self)
        if self.family == "moe":
            e_ff = self.moe_d_ff or self.d_ff
            per_layer += self.num_experts * 3 * d * e_ff
            per_layer += self.num_shared_experts * 3 * d * e_ff
            per_layer += d * self.num_experts  # router
        else:
            per_layer += _mlp_params(self, self.d_ff)
        per_layer += 2 * d  # norms
        n += self.num_layers * per_layer
        if self.family == "encdec":
            # encoder layers + decoder cross-attention
            enc_per = _attn_block_params(self) + _mlp_params(self, self.d_ff) + 2 * d
            n += self.encoder_layers * enc_per
            n += self.num_layers * (_attn_block_params(self) + d)  # cross attn + norm
        return n

    def active_param_count(self) -> int:
        """Active params per token (MoE: only top_k + shared experts)."""
        if self.family != "moe":
            return self.param_count()
        d = self.d_model
        e_ff = self.moe_d_ff or self.d_ff
        inactive = self.num_layers * (self.num_experts - self.top_k) * 3 * d * e_ff
        return self.param_count() - inactive


def _mlp_params(cfg: ModelConfig, d_ff: int) -> int:
    return 3 * cfg.d_model * d_ff  # SwiGLU: gate, up, down


def _attn_block_params(cfg: ModelConfig) -> int:
    d = cfg.d_model
    n = d * cfg.q_dim + 2 * d * cfg.kv_dim + cfg.q_dim * d
    if cfg.qkv_bias:
        n += cfg.q_dim + 2 * cfg.kv_dim
    return n


def _mamba2_layer_params(cfg: ModelConfig) -> int:
    d, di = cfg.d_model, cfg.ssm_d_inner
    n_h, st = cfg.ssm_heads, cfg.ssm_state
    n = d * (2 * di + 2 * st + n_h)      # in_proj -> [x, z, B, C, dt]
    n += di * cfg.ssm_conv_width         # depthwise conv
    n += 2 * n_h                         # A_log, D
    n += di * d                          # out_proj
    return n


# ---------------------------------------------------------------------------
# Input shapes (assigned set; identical across LM-family archs)
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # "train" | "prefill" | "decode"


SHAPES = {
    "train_4k": ShapeConfig("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524288, 1, "decode"),
}


def shape_applicable(cfg: ModelConfig, shape: ShapeConfig) -> Tuple[bool, str]:
    """(runnable, reason).  long_500k only for sub-quadratic archs (DESIGN.md)."""
    if shape.name == "long_500k" and not cfg.has_subquadratic_path:
        return False, "pure full-attention arch: 500k context skipped per spec"
    return True, ""


# ---------------------------------------------------------------------------
# Run-level config (training hyper-parameters, rowclone settings)
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class RowCloneConfig:
    """Settings for the in-memory copy/init engine (the paper's technique)."""
    enable_fpm: bool = True        # HBM-local DMA block copy
    enable_psm: bool = True        # cross-shard pipelined transfer
    enable_zi: bool = True         # lazy-zero + alias-copy (RowClone-ZI)
    page_size: int = 64            # tokens per KV block ("row" granularity)
    zero_blocks_per_slab: int = 1  # reserved zero rows per subarray (paper §3.1)
    psm_chunk_blocks: int = 8      # pipelining depth for PSM transfers


@dataclass(frozen=True)
class TrainConfig:
    learning_rate: float = 3e-4
    warmup_steps: int = 100
    total_steps: int = 1000
    weight_decay: float = 0.1
    b1: float = 0.9
    b2: float = 0.95
    grad_clip: float = 1.0
    microbatches: int = 1          # gradient accumulation
    remat_policy: str = "minimal"  # none | minimal | full
    sharding: str = "fsdp"         # fsdp | tp  (see EXPERIMENTS.md §Perf)
    grad_compress: bool = False    # int8 error-feedback DP all-reduce
    seed: int = 0
