"""mistral-nemo-12b — dense GQA decoder, 128k context.

[hf:mistralai/Mistral-Nemo-Base-2407; hf]  40L d_model=5120 32H (GQA kv=8)
d_ff=14336 vocab=131072.  Nemo uses head_dim=128 (q_dim 4096 != d_model).
"""
from repro.configs.base import ModelConfig
from repro.configs.registry import register

CONFIG = register(
    ModelConfig(
        arch_id="mistral-nemo-12b",
        family="dense",
        num_layers=40,
        d_model=5120,
        num_heads=32,
        num_kv_heads=8,
        head_dim=128,
        d_ff=14336,
        vocab_size=131072,
        rope_theta=1000000.0,
    )
)
