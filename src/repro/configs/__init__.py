from repro.configs.base import (
    ModelConfig,
    RowCloneConfig,
    ShapeConfig,
    SHAPES,
    TrainConfig,
    shape_applicable,
)
from repro.configs.registry import get_config, list_archs

__all__ = [
    "ModelConfig",
    "RowCloneConfig",
    "ShapeConfig",
    "SHAPES",
    "TrainConfig",
    "shape_applicable",
    "get_config",
    "list_archs",
]
