"""mamba2-780m — attention-free SSD (state-space duality) stack.

[arXiv:2405.21060; unverified]  48L d_model=1536 vocab=50280, ssm_state=128.
d_inner = 2*1536 = 3072, 48 SSD heads of head_dim 64.
"""
from repro.configs.base import ModelConfig
from repro.configs.registry import register

CONFIG = register(
    ModelConfig(
        arch_id="mamba2-780m",
        family="ssm",
        num_layers=48,
        d_model=1536,
        num_heads=0,
        num_kv_heads=0,
        head_dim=0,
        d_ff=0,
        vocab_size=50280,
        ssm_state=128,
        ssm_heads=48,
        ssm_head_dim=64,
        ssm_expand=2,
        tie_embeddings=True,
    )
)
