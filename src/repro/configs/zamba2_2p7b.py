"""zamba2-2.7b — hybrid Mamba2 backbone + shared attention block.

[arXiv:2411.15242; hf]  54L d_model=2560 32H (GQA kv=32) d_ff=10240
vocab=32000, ssm_state=64.  The shared transformer (attn+MLP) block is
invoked every 6 core mamba2 layers with shared weights (Zamba design).
"""
from repro.configs.base import ModelConfig
from repro.configs.registry import register

CONFIG = register(
    ModelConfig(
        arch_id="zamba2-2.7b",
        family="hybrid",
        num_layers=54,
        d_model=2560,
        num_heads=32,
        num_kv_heads=32,
        head_dim=80,
        d_ff=10240,
        vocab_size=32000,
        ssm_state=64,
        ssm_heads=80,          # d_inner 5120 / head_dim 64
        ssm_head_dim=64,
        ssm_expand=2,
        shared_attn_every=6,
        rope_theta=10000.0,
    )
)
