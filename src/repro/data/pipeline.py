"""Deterministic synthetic data pipeline with document packing.

Batches are a pure function of (seed, step, arch) — the property that makes
checkpoint/restart and elastic re-sharding replay *identical* data, which
the fault-tolerance layer relies on (runtime/fault.py).

Documents are sampled with zipf-ish lengths from a synthetic "corpus"
(hash-mixed token ids), packed into fixed-length rows with EOS separators;
labels are next-token targets, mask zeroes out padding and the final
position of each row.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Iterator, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig, ShapeConfig

EOS = 1


@dataclasses.dataclass(frozen=True)
class DataConfig:
    seed: int = 0
    mean_doc_len: int = 512
    eos_id: int = EOS


def _rng_for(seed: int, step: int) -> np.random.Generator:
    return np.random.default_rng(np.random.SeedSequence([seed, step]))


def make_batch(cfg: ModelConfig, batch: int, seq_len: int, step: int,
               data_cfg: Optional[DataConfig] = None) -> Dict[str, np.ndarray]:
    """One packed training batch (host numpy)."""
    dc = data_cfg or DataConfig()
    rng = _rng_for(dc.seed, step)
    V = cfg.vocab_size
    tokens = np.empty((batch, seq_len + 1), np.int32)
    for b in range(batch):
        row, fill = [], 0
        while fill < seq_len + 1:
            dlen = int(np.clip(rng.pareto(1.5) * dc.mean_doc_len, 8, 4096))
            # learnable structure: noisy affine successor chain — an LM can
            # reduce CE well below ln(V) by learning t -> (7t+3) mod V'
            doc = np.empty(dlen, np.int32)
            doc[0] = rng.integers(2, V)
            noise = rng.random(dlen) < 0.1
            rand = rng.integers(2, V, size=dlen)
            for t in range(1, dlen):
                doc[t] = rand[t] if noise[t] else \
                    (doc[t - 1] * 7 + 3) % (V - 2) + 2
            row.append(doc)
            row.append(np.array([dc.eos_id], np.int32))
            fill += dlen + 1
        tokens[b] = np.concatenate(row)[: seq_len + 1]
    out = {
        "tokens": tokens[:, :-1],
        "labels": tokens[:, 1:].astype(np.int32),
        "mask": np.ones((batch, seq_len), np.float32),
    }
    if cfg.family == "vlm":
        # stub frontend: deterministic patch embeddings; text shortened so
        # total decoder length stays seq_len
        p = cfg.vision_tokens
        text = seq_len - p
        out["tokens"] = out["tokens"][:, :text]
        out["labels"] = out["labels"][:, :text]
        out["mask"] = out["mask"][:, :text]
        out["patch_embeds"] = rng.standard_normal(
            (batch, p, cfg.d_model), np.float32) * 0.02
    if cfg.family == "encdec":
        s_src = max(seq_len // cfg.src_frames_ratio, 1)
        out["src_embeds"] = rng.standard_normal(
            (batch, s_src, cfg.d_model), np.float32) * 0.02
    return out


def batch_specs(cfg: ModelConfig, batch: int, seq_len: int):
    """ShapeDtypeStructs matching make_batch (for input_specs/dry-run)."""
    s: Dict[str, jax.ShapeDtypeStruct] = {}
    text = seq_len - cfg.vision_tokens if cfg.family == "vlm" else seq_len
    s["tokens"] = jax.ShapeDtypeStruct((batch, text), jnp.int32)
    s["labels"] = jax.ShapeDtypeStruct((batch, text), jnp.int32)
    s["mask"] = jax.ShapeDtypeStruct((batch, text), jnp.float32)
    if cfg.family == "vlm":
        s["patch_embeds"] = jax.ShapeDtypeStruct(
            (batch, cfg.vision_tokens, cfg.d_model), jnp.float32)
    if cfg.family == "encdec":
        s_src = max(seq_len // cfg.src_frames_ratio, 1)
        s["src_embeds"] = jax.ShapeDtypeStruct(
            (batch, s_src, cfg.d_model), jnp.float32)
    return s


def batch_logical_axes(cfg: ModelConfig):
    ax = {"tokens": ("batch", None), "labels": ("batch", None),
          "mask": ("batch", None)}
    if cfg.family == "vlm":
        ax["patch_embeds"] = ("batch", None, None)
    if cfg.family == "encdec":
        ax["src_embeds"] = ("batch", None, None)
    return ax


def data_iterator(cfg: ModelConfig, batch: int, seq_len: int,
                  start_step: int = 0,
                  data_cfg: Optional[DataConfig] = None
                  ) -> Iterator[Dict[str, np.ndarray]]:
    step = start_step
    while True:
        yield make_batch(cfg, batch, seq_len, step, data_cfg)
        step += 1
