from repro.data.pipeline import (
    DataConfig, batch_logical_axes, batch_specs, data_iterator, make_batch,
)
