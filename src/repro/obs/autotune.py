"""Profiler-driven autotuning: persisted per-backend ``TunedProfile``.

The engine's throughput constants — bucket set (``cmdqueue.BUCKETS``),
overlapped-drain toggle, staging-ring capacity, and the sharded jit-cache
bound (``fused_dispatch.MAX_DELTA_SIGNATURES``) — were hand-picked.
``benchmarks/bench_autotune.py`` sweeps them MEF-style (a parameterized
experiment matrix per machine/backend) against representative command
streams, measures ``us_per_flush``/launches with the shared obs timer,
picks winners via :func:`pick_winner`, and persists the result as a JSON
:class:`TunedProfile` under ``configs/tuned/<backend>.json``.

``RowCloneEngine`` / ``ServingEngine`` call :func:`load_profile` at
startup; precedence is **explicit kwarg > tuned profile > built-in
default**.  A missing profile file (or ``REPRO_NO_TUNED=1``) means
today's defaults, exactly as before.  :func:`pick_winner` keeps the
default configuration unless a candidate beats it by a clear margin
(default 3%), so a committed profile can never encode a noise-level
"win" that regresses other workloads.
"""
from __future__ import annotations

import dataclasses
import json
import os
import pathlib
from typing import Dict, List, Optional, Sequence, Tuple

#: profile JSON schema version (bump on incompatible field changes)
PROFILE_SCHEMA = 1

#: required margin (fractional) before a candidate unseats the default
DEFAULT_MARGIN = 0.03

_LOGGED: set = set()


@dataclasses.dataclass(frozen=True)
class TunedProfile:
    """One backend's tuned engine constants + the measurements behind
    them.  ``ring_capacity=None`` keeps the serving layer's
    policy-derived staging ring; every field falls back to the built-in
    default when an engine kwarg overrides it."""

    backend: str                              #: jax backend key ("cpu", "tpu")
    buckets: Tuple[int, ...] = (8, 32, 128, 512)   #: table bucket sizes
    overlap: bool = True                      #: overlapped DMA drain
    max_delta_signatures: int = 8             #: sharded jit-cache fold bound
    ring_capacity: Optional[int] = None       #: staging ring slots (None = policy)
    us_per_flush: float = 0.0                 #: winner's measured median
    baseline_us_per_flush: float = 0.0        #: defaults' measured median
    swept: Dict = dataclasses.field(default_factory=dict)  #: sweep summary
    schema: int = PROFILE_SCHEMA              #: profile format version

    def to_dict(self) -> Dict:
        """JSON-ready dict (tuples become lists)."""
        d = dataclasses.asdict(self)
        d["buckets"] = list(self.buckets)
        return d

    @classmethod
    def from_dict(cls, d: Dict) -> "TunedProfile":
        """Rebuild from :meth:`to_dict` output (unknown keys ignored so
        newer files load under older code)."""
        names = {f.name for f in dataclasses.fields(cls)}
        kw = {k: v for k, v in d.items() if k in names}
        kw["buckets"] = tuple(int(b) for b in kw.get("buckets",
                                                     (8, 32, 128, 512)))
        if kw.get("ring_capacity") is not None:
            kw["ring_capacity"] = int(kw["ring_capacity"])
        return cls(**kw)


def tuned_dir() -> pathlib.Path:
    """Directory holding per-backend profile JSONs: ``$REPRO_TUNED_DIR``
    when set, else ``configs/tuned/`` at the repo root."""
    env = os.environ.get("REPRO_TUNED_DIR")
    if env:
        return pathlib.Path(env)
    return pathlib.Path(__file__).resolve().parents[3] / "configs" / "tuned"


def backend_key() -> str:
    """The profile key for this process: ``jax.default_backend()``
    ("cpu", "tpu", "gpu"); "cpu" when jax is unavailable."""
    try:
        import jax
        return str(jax.default_backend())
    except Exception:
        return "cpu"


def profile_path(backend: Optional[str] = None,
                 directory: Optional[pathlib.Path] = None) -> pathlib.Path:
    """Path of ``backend``'s profile file (default: this process's
    backend under :func:`tuned_dir`)."""
    backend = backend or backend_key()
    directory = pathlib.Path(directory) if directory else tuned_dir()
    return directory / f"{backend}.json"


def save_profile(profile: TunedProfile,
                 directory: Optional[pathlib.Path] = None) -> pathlib.Path:
    """Persist ``profile`` as ``<dir>/<backend>.json`` (dir created);
    returns the written path."""
    path = profile_path(profile.backend, directory)
    path.parent.mkdir(parents=True, exist_ok=True)
    path.write_text(json.dumps(profile.to_dict(), indent=2,
                               sort_keys=True) + "\n")
    return path


def load_profile(backend: Optional[str] = None,
                 directory: Optional[pathlib.Path] = None
                 ) -> Optional[TunedProfile]:
    """Load the backend's :class:`TunedProfile`, or None when no file
    exists (or ``REPRO_NO_TUNED=1`` opts out).  Logs one startup line
    per (backend, path) the first time a profile loads in a process —
    the "engine demonstrably loaded it" breadcrumb."""
    if os.environ.get("REPRO_NO_TUNED"):
        return None
    path = profile_path(backend, directory)
    if not path.is_file():
        return None
    try:
        prof = TunedProfile.from_dict(json.loads(path.read_text()))
    except (ValueError, TypeError, KeyError):
        return None       # malformed file degrades to defaults
    tag = (prof.backend, str(path))
    if tag not in _LOGGED:
        _LOGGED.add(tag)
        print(f"[obs] tuned profile loaded: backend={prof.backend} "
              f"buckets={list(prof.buckets)} overlap={prof.overlap} "
              f"max_delta_signatures={prof.max_delta_signatures} "
              f"ring_capacity={prof.ring_capacity} ({path})")
    return prof


def apply_profile(profile: TunedProfile) -> Dict[str, object]:
    """Install the profile's PROCESS-WIDE knobs: the cmdqueue bucket set
    and the sharded-dispatch delta-signature bound.  (Per-engine knobs —
    ``overlap``, ``ring_capacity`` — resolve inside engine ``__init__``
    where explicit kwargs can win.)  Returns the applied values."""
    from repro.core import cmdqueue
    from repro.kernels import fused_dispatch
    cmdqueue.set_buckets(profile.buckets)
    fused_dispatch.set_max_delta_signatures(profile.max_delta_signatures)
    return {"buckets": tuple(profile.buckets),
            "max_delta_signatures": profile.max_delta_signatures}


def pick_winner(rows: Sequence[Dict], default_cfg: Dict,
                margin: float = DEFAULT_MARGIN) -> Dict:
    """Choose the sweep's winning configuration.

    ``rows`` are sweep results ``{"cfg": {...}, "us_per_flush": float}``;
    ``default_cfg`` names the hand-picked configuration's cfg dict.  The
    fastest candidate wins ONLY if it beats the default's measured
    ``us_per_flush`` by more than ``margin`` (fractional) — otherwise
    the default is kept, so noise can never flip a committed constant.
    Returns the winning row (the default's row when it holds)."""
    if not rows:
        raise ValueError("pick_winner needs at least one sweep row")
    default_rows = [r for r in rows if r["cfg"] == default_cfg]
    if not default_rows:
        raise ValueError("sweep must include the default configuration")
    default_row = min(default_rows, key=lambda r: r["us_per_flush"])
    best = min(rows, key=lambda r: r["us_per_flush"])
    if best["us_per_flush"] < default_row["us_per_flush"] * (1.0 - margin):
        return best
    return default_row


__all__ = [
    "TunedProfile",
    "PROFILE_SCHEMA",
    "DEFAULT_MARGIN",
    "tuned_dir",
    "backend_key",
    "profile_path",
    "save_profile",
    "load_profile",
    "apply_profile",
    "pick_winner",
]
