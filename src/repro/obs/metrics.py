"""Process-local metrics: counters, gauges, histograms — and the one
sanctioned timing clock.

The engine's bulk movement is instrumented with labeled series cheap
enough to stay ON in production: ``CommandQueue`` counts enqueues and
hazard flushes per stream, the fused drain counts rows per opcode and
observes per-flush wall-clock, ``ServingEngine`` gauges staging-ring
occupancy, and the scheduler counts per-lane admission/preemption
traffic.  Everything lands in one :class:`MetricsRegistry` (the process
registry, :func:`registry`), keyed by ``(name, sorted(labels))`` —
plain dict increments, no locks, no device work.

This module is also the repo's ONE home for raw wall-clock reads:
:func:`now`, :class:`Stopwatch`, and :func:`time_us` wrap
``time.perf_counter`` so every engine path and every benchmark reports
the same statistic (:func:`percentile` / :func:`summarize`).  rowlint
rule RC105 rejects ``time.perf_counter()`` / ``time.time()`` calls
anywhere else (waivable per line at documented sites).

Metrics can be disabled wholesale (:func:`set_metrics_enabled`) — the
bitwise-parity contract: pools and launch accounting are identical
metrics-on vs metrics-off (``tests/test_obs.py``), because nothing here
ever touches device buffers.
"""
from __future__ import annotations

import time
from typing import Callable, Dict, Iterable, List, Optional, Tuple

#: a series key: (metric name, sorted (label, value) pairs)
SeriesKey = Tuple[str, Tuple[Tuple[str, str], ...]]


def now() -> float:
    """Monotonic wall-clock seconds (``time.perf_counter``) — the repo's
    single sanctioned timing source (rowlint RC105 enforces this)."""
    return time.perf_counter()


def _key(name: str, labels: Dict[str, object]) -> SeriesKey:
    return (name, tuple(sorted((k, str(v)) for k, v in labels.items())))


class MetricsRegistry:
    """One process's metric store: counters, gauges, and histograms with
    labeled series.

    Series are keyed ``(name, sorted(labels))``; emission is a dict
    increment (always-on cheap).  ``enabled=False`` turns every
    emission into a no-op without touching callers — the registry is
    host-side only, so enabling/disabling can never change pool bytes
    or launch accounting."""

    def __init__(self) -> None:
        self.enabled = True
        self.counters: Dict[SeriesKey, float] = {}
        self.gauges: Dict[SeriesKey, float] = {}
        self.hists: Dict[SeriesKey, List[float]] = {}
        #: histogram sample cap per series (oldest samples drop)
        self.hist_cap = 4096

    # -- emission ------------------------------------------------------
    def inc(self, name: str, value: float = 1.0, **labels) -> None:
        """Add ``value`` to the counter series ``name{labels}``."""
        if not self.enabled:
            return
        k = _key(name, labels)
        self.counters[k] = self.counters.get(k, 0.0) + value

    def set_gauge(self, name: str, value: float, **labels) -> None:
        """Set the gauge series ``name{labels}`` to ``value``."""
        if not self.enabled:
            return
        self.gauges[_key(name, labels)] = float(value)

    def observe(self, name: str, value: float, **labels) -> None:
        """Append ``value`` to the histogram series ``name{labels}``
        (bounded at ``hist_cap`` samples; oldest drop)."""
        if not self.enabled:
            return
        h = self.hists.setdefault(_key(name, labels), [])
        h.append(float(value))
        if len(h) > self.hist_cap:
            del h[:len(h) - self.hist_cap]

    # -- reads ---------------------------------------------------------
    def get(self, name: str, **labels) -> float:
        """Counter value of ``name{labels}`` (0.0 when never emitted)."""
        return self.counters.get(_key(name, labels), 0.0)

    def gauge_value(self, name: str, **labels) -> Optional[float]:
        """Gauge value of ``name{labels}``, or None when never set."""
        return self.gauges.get(_key(name, labels))

    def hist(self, name: str, **labels) -> List[float]:
        """Histogram samples of ``name{labels}`` (copy; [] when empty)."""
        return list(self.hists.get(_key(name, labels), ()))

    def series(self, name: str) -> Dict[Tuple[Tuple[str, str], ...], float]:
        """Every counter series under ``name``: label tuple -> value."""
        return {k[1]: v for k, v in self.counters.items() if k[0] == name}

    def snapshot(self) -> Dict[str, Dict]:
        """Plain-dict dump of every series (counters/gauges/hist
        summaries) — the ``FlushTicket``-level stats export."""
        def fmt(k: SeriesKey) -> str:
            name, labels = k
            if not labels:
                return name
            inner = ",".join(f"{a}={b}" for a, b in labels)
            return f"{name}{{{inner}}}"
        return {
            "counters": {fmt(k): v for k, v in self.counters.items()},
            "gauges": {fmt(k): v for k, v in self.gauges.items()},
            "histograms": {fmt(k): summarize(v)
                           for k, v in self.hists.items()},
        }

    def reset(self) -> None:
        """Drop every series (tests and sweep harness isolation)."""
        self.counters.clear()
        self.gauges.clear()
        self.hists.clear()


#: the process registry every instrumented module emits into
_REGISTRY = MetricsRegistry()


def registry() -> MetricsRegistry:
    """The process-local :class:`MetricsRegistry` (one per process)."""
    return _REGISTRY


def inc(name: str, value: float = 1.0, **labels) -> None:
    """Increment a counter on the process registry (see
    :meth:`MetricsRegistry.inc`)."""
    _REGISTRY.inc(name, value, **labels)


def set_gauge(name: str, value: float, **labels) -> None:
    """Set a gauge on the process registry (see
    :meth:`MetricsRegistry.set_gauge`)."""
    _REGISTRY.set_gauge(name, value, **labels)


def observe(name: str, value: float, **labels) -> None:
    """Observe a histogram sample on the process registry (see
    :meth:`MetricsRegistry.observe`)."""
    _REGISTRY.observe(name, value, **labels)


def metrics_enabled() -> bool:
    """Is the process registry currently recording emissions?"""
    return _REGISTRY.enabled


def set_metrics_enabled(flag: bool) -> bool:
    """Enable/disable the process registry; returns the PREVIOUS state.
    Off turns every emission into a no-op — pool bytes and launch
    accounting are identical either way (host-side only)."""
    prev = _REGISTRY.enabled
    _REGISTRY.enabled = bool(flag)
    return prev


# ---------------------------------------------------------------------------
# timing helpers — the shared statistic every bench reports
# ---------------------------------------------------------------------------

class Stopwatch:
    """Context-manager wall-clock timer over :func:`now`.

    >>> with Stopwatch() as sw:
    ...     work()
    >>> sw.us       # elapsed microseconds
    """

    def __init__(self) -> None:
        self.start = 0.0
        self.end: Optional[float] = None

    def __enter__(self) -> "Stopwatch":
        self.start = now()
        return self

    def __exit__(self, *exc) -> None:
        self.end = now()

    @property
    def s(self) -> float:
        """Elapsed seconds (running total until the context exits)."""
        return (self.end if self.end is not None else now()) - self.start

    @property
    def us(self) -> float:
        """Elapsed microseconds."""
        return self.s * 1e6


def time_us(fn: Callable[[], object], *, warmup: int = 2,
            reps: int = 5) -> List[float]:
    """Run ``fn`` ``warmup`` times untimed, then ``reps`` timed — returns
    the per-rep wall-clock in MICROSECONDS.  The shared bench timing
    loop: feed the result to :func:`percentile` / :func:`summarize` so
    every benchmark reports the same statistic."""
    for _ in range(warmup):
        fn()
    out = []
    for _ in range(reps):
        t0 = now()
        fn()
        out.append((now() - t0) * 1e6)
    return out


def percentile(xs: Iterable[float], q: float) -> float:
    """The ``q``-th percentile of ``xs`` (linear interpolation; 0.0 on an
    empty input) — numpy-free so the linter and tooling can import it."""
    data = sorted(float(x) for x in xs)
    if not data:
        return 0.0
    if len(data) == 1:
        return data[0]
    pos = (len(data) - 1) * (q / 100.0)
    lo = int(pos)
    hi = min(lo + 1, len(data) - 1)
    frac = pos - lo
    return data[lo] * (1.0 - frac) + data[hi] * frac


def summarize(xs: Iterable[float]) -> Dict[str, float]:
    """p50/p90/p99 + mean/min/max/n summary of a sample list — the one
    percentile summary every bench and RoundReport uses."""
    data = [float(x) for x in xs]
    if not data:
        return {"n": 0, "p50": 0.0, "p90": 0.0, "p99": 0.0,
                "mean": 0.0, "min": 0.0, "max": 0.0}
    return {
        "n": len(data),
        "p50": percentile(data, 50),
        "p90": percentile(data, 90),
        "p99": percentile(data, 99),
        "mean": sum(data) / len(data),
        "min": min(data),
        "max": max(data),
    }


__all__ = [
    "MetricsRegistry",
    "registry",
    "inc",
    "set_gauge",
    "observe",
    "metrics_enabled",
    "set_metrics_enabled",
    "now",
    "Stopwatch",
    "time_us",
    "percentile",
    "summarize",
]
