"""Named spans over the flush lifecycle — wall-clock always, profiler
sections when a profile is active.

The engine's hot path is wrapped in nested spans
(``enqueue -> flush -> drain -> ticket-wait``), Levanter-style: every
span emits a ``jax.profiler.TraceAnnotation`` (a TraceMe — visible in a
captured profile's timeline, near-free when no profile is active) AND
appends a host-side :class:`Span` record with wall-clock start/end and
its nesting depth, so span data exists even without a profiler attached.

Records live in a bounded ring (:func:`spans` reads, :func:`reset_spans`
clears).  :class:`FlushTiming` is the per-flush timing quad the engine
stashes and ``FlushTicket.timing`` carries: queue residency (first
enqueue -> flush call), drain wall-clock, bucket-padded table length,
and launches.
"""
from __future__ import annotations

import contextlib
import dataclasses
from typing import Dict, Iterator, List, Optional, Tuple

from repro.obs.metrics import now

#: bounded span-record ring size (oldest records drop past this)
MAX_SPANS = 4096

_RECORDS: List["Span"] = []
_STACK: List[int] = []
_ENABLED = True


@dataclasses.dataclass
class Span:
    """One recorded span: name, wall-clock bounds, nesting, labels."""

    name: str                      #: span name (e.g. "flush", "drain")
    start: float                   #: perf_counter seconds at entry
    end: float                     #: perf_counter seconds at exit
    depth: int                     #: nesting depth (0 = root)
    parent: int                    #: index of the enclosing span, -1 = root
    labels: Tuple[Tuple[str, str], ...] = ()   #: sorted label pairs

    @property
    def us(self) -> float:
        """Span duration in microseconds."""
        return (self.end - self.start) * 1e6


@dataclasses.dataclass(frozen=True)
class FlushTiming:
    """Per-flush timing carried by ``FlushTicket.timing``: how long rows
    sat queued, how long the drain took, how big the padded table was,
    and how many launches it cost."""

    queue_residency_us: float      #: first enqueue -> flush call
    drain_us: float                #: _drain_rows wall-clock
    table_len: int                 #: bucket-padded rows dispatched (all chunks)
    launches: int                  #: device launches the flush issued


def _annotation(name: str):
    try:
        import jax.profiler
        return jax.profiler.TraceAnnotation(name)
    except Exception:       # profiler unavailable: wall-clock only
        return contextlib.nullcontext()


@contextlib.contextmanager
def span(name: str, **labels) -> Iterator[None]:
    """Open a named span: a ``jax.profiler.TraceAnnotation`` section when
    a profile is active, and a wall-clock :class:`Span` record always
    (bounded ring; see :func:`spans`).  Spans nest — the record keeps
    its depth and parent index, so capture/adopt call trees are visible
    in the record list."""
    if not _ENABLED:
        yield
        return
    parent = _STACK[-1] if _STACK else -1
    depth = len(_STACK)
    idx = len(_RECORDS)
    rec = Span(name=name, start=now(), end=0.0, depth=depth, parent=parent,
               labels=tuple(sorted((k, str(v)) for k, v in labels.items())))
    _RECORDS.append(rec)
    _STACK.append(idx)
    try:
        with _annotation(name):
            yield
    finally:
        rec.end = now()
        _STACK.pop()
        if len(_RECORDS) > MAX_SPANS:
            drop = len(_RECORDS) - MAX_SPANS
            del _RECORDS[:drop]
            # re-anchor parent indices after the ring dropped a prefix
            for r in _RECORDS:
                r.parent = r.parent - drop if r.parent >= drop else -1
            _STACK[:] = [i - drop for i in _STACK if i >= drop]


def spans(name: Optional[str] = None) -> List[Span]:
    """Recorded spans (optionally filtered by name), oldest first."""
    if name is None:
        return list(_RECORDS)
    return [r for r in _RECORDS if r.name == name]


def reset_spans() -> None:
    """Clear the span record ring (test isolation)."""
    _RECORDS.clear()
    _STACK.clear()


def tracing_enabled() -> bool:
    """Is span recording currently on?"""
    return _ENABLED


def set_tracing(flag: bool) -> bool:
    """Enable/disable span recording; returns the PREVIOUS state.  Off
    skips both the record append and the profiler annotation — the
    engine's behavior is unchanged either way (host-side only)."""
    global _ENABLED
    prev = _ENABLED
    _ENABLED = bool(flag)
    return prev


def span_tree(records: Optional[List[Span]] = None) -> List[Dict]:
    """Render span records as a nested dict tree (children inline) — the
    debugging view of one round's ``flush -> drain`` hierarchy."""
    records = _RECORDS if records is None else records
    nodes = [{"name": r.name, "us": r.us, "labels": dict(r.labels),
              "children": []} for r in records]
    roots: List[Dict] = []
    for i, r in enumerate(records):
        if 0 <= r.parent < len(nodes):
            nodes[r.parent]["children"].append(nodes[i])
        else:
            roots.append(nodes[i])
    return roots


__all__ = [
    "Span",
    "FlushTiming",
    "span",
    "spans",
    "reset_spans",
    "tracing_enabled",
    "set_tracing",
    "span_tree",
    "MAX_SPANS",
]
