"""Observability + autotuning for the RowClone engine (MEF x Levanter).

Three pieces:

* :mod:`repro.obs.metrics` — process-local counters/gauges/histograms
  with labeled series (stream, opcode, pool, tenant lane), plus the one
  sanctioned timing clock and the shared timer/percentile helpers every
  benchmark uses (rowlint RC105 enforces the monopoly).
* :mod:`repro.obs.trace` — named spans over the flush lifecycle
  (``flush -> drain -> ticket-wait``): ``jax.profiler`` trace sections
  when a profile is active, wall-clock :class:`~repro.obs.trace.Span`
  records always; :class:`~repro.obs.trace.FlushTiming` rides on
  ``FlushTicket.timing``.
* :mod:`repro.obs.autotune` — per-backend
  :class:`~repro.obs.autotune.TunedProfile` (JSON under
  ``configs/tuned/``) written by ``benchmarks/bench_autotune.py`` and
  loaded by the engines at startup; explicit kwargs always win,
  missing profile means today's defaults.

This package imports nothing from ``repro.core``/``repro.kernels`` at
module scope (only lazily inside ``apply_profile``), so the core can
emit into it without an import cycle.
"""
from repro.obs.autotune import (TunedProfile, apply_profile, backend_key,
                                load_profile, pick_winner, profile_path,
                                save_profile, tuned_dir)
from repro.obs.metrics import (MetricsRegistry, Stopwatch, inc,
                               metrics_enabled, now, observe, percentile,
                               registry, set_gauge, set_metrics_enabled,
                               summarize, time_us)
from repro.obs.trace import (FlushTiming, Span, reset_spans, set_tracing,
                             span, span_tree, spans, tracing_enabled)

__all__ = [
    "MetricsRegistry",
    "registry",
    "inc",
    "set_gauge",
    "observe",
    "metrics_enabled",
    "set_metrics_enabled",
    "now",
    "Stopwatch",
    "time_us",
    "percentile",
    "summarize",
    "Span",
    "FlushTiming",
    "span",
    "spans",
    "reset_spans",
    "tracing_enabled",
    "set_tracing",
    "span_tree",
    "TunedProfile",
    "tuned_dir",
    "backend_key",
    "profile_path",
    "save_profile",
    "load_profile",
    "apply_profile",
    "pick_winner",
]
