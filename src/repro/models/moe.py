"""Mixture-of-Experts FFN: top-k routing with capacity-buffer dispatch.

Dispatch uses the scatter/gather (fixed-capacity) formulation: tokens are
scattered into a ``(B, E, C, d)`` buffer (experts sharded over ``model``,
batch over ``data``), each expert runs a SwiGLU matmul on its buffer, and
outputs are gathered back with the renormalized top-k weights.  Overflowing
tokens are dropped (standard Switch/GShard semantics).  A load-balance aux
loss and router z-loss are returned alongside.

DeepSeek-style *shared experts* are a dense SwiGLU with hidden size
``num_shared_experts * moe_d_ff`` applied to every token.
"""
from __future__ import annotations

import functools
import math
from typing import Dict, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from repro.compat import shard_map
from repro.configs.base import ModelConfig
from repro.models.common import Param, dense_init, init_mlp, swiglu_mlp
from repro.sharding import constrain

CAPACITY_FACTOR = 1.25


def init_moe_ffn(key, cfg: ModelConfig) -> Dict[str, Param]:
    d = cfg.d_model
    E = cfg.num_experts
    f = cfg.moe_d_ff or cfg.d_ff
    k1, k2, k3, k4, k5 = jax.random.split(key, 5)
    params = {
        "router": dense_init(k1, d, E, ("embed", "experts"), scale=0.02),
        "w_gate": Param(jax.random.normal(k2, (E, d, f)) * d ** -0.5,
                        ("experts", "embed", "ffn")),
        "w_up": Param(jax.random.normal(k3, (E, d, f)) * d ** -0.5,
                      ("experts", "embed", "ffn")),
        "w_down": Param(jax.random.normal(k4, (E, f, d)) * f ** -0.5,
                        ("experts", "ffn", "embed")),
    }
    if cfg.num_shared_experts:
        params["shared"] = init_mlp(k5, d, cfg.num_shared_experts * f)
    return params


def capacity(cfg: ModelConfig, seq_len: int) -> int:
    c = int(math.ceil(seq_len * cfg.top_k / cfg.num_experts * CAPACITY_FACTOR))
    return max(8, -(-c // 8) * 8)  # round up to 8 for TPU tiling


def moe_ffn(params, x, cfg: ModelConfig, mesh) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """x: (B,S,d).  Returns (y, aux_loss).

    Dispatches to the explicit all-to-all shard_map path on a multi-device
    mesh (GSPMD lowers the scatter/gather formulation to per-layer
    replicate+all-reduce — ~200 GB/layer at deepseek scale; see
    EXPERIMENTS.md §Perf iteration 1), else the local dense-dispatch path.
    """
    from repro.sharding.rules import active_rules
    if mesh is None or int(np.prod(mesh.devices.shape)) == 1:
        return _moe_ffn_local(params, x, cfg, mesh)
    tp_mode = active_rules().get("act_seq_tp", (None,))[0] is not None
    if tp_mode and "model" in mesh.axis_names:
        return _moe_ffn_a2a(params, x, cfg, mesh)
    # FSDP: tokens are device-local — run the dispatch inside shard_map
    # (GSPMD's scatter partitioner would otherwise replicate the capacity
    # buffer; see EXPERIMENTS.md §Perf iteration 3).
    return _moe_ffn_fsdp(params, x, cfg, mesh)


def _moe_ffn_local(params, x, cfg: ModelConfig, mesh):
    """Single-device / test path: dense capacity-buffer dispatch."""
    B, S, d = x.shape
    E, k = cfg.num_experts, cfg.top_k
    C = capacity(cfg, S)
    dtype = x.dtype

    logits = x @ params["router"].astype(dtype)                    # (B,S,E)
    logits = logits.astype(jnp.float32)
    probs = jax.nn.softmax(logits, axis=-1)
    gate_k, idx_k = jax.lax.top_k(probs, k)                        # (B,S,k)
    gate_k = gate_k / jnp.maximum(gate_k.sum(-1, keepdims=True), 1e-9)

    # position-in-expert via sequential cumsum over the k routing choices
    counts = jnp.zeros((B, 1, E), jnp.int32)
    pos_list, keep_list = [], []
    for j in range(k):
        onehot = jax.nn.one_hot(idx_k[..., j], E, dtype=jnp.int32)  # (B,S,E)
        pos_in_e = jnp.cumsum(onehot, axis=1) - onehot + counts     # (B,S,E)
        pos_j = jnp.sum(pos_in_e * onehot, axis=-1)                 # (B,S)
        keep_list.append(pos_j < C)
        pos_list.append(jnp.minimum(pos_j, C - 1))
        counts = counts + onehot.sum(axis=1, keepdims=True)
    pos_k = jnp.stack(pos_list, -1)                                 # (B,S,k)
    keep_k = jnp.stack(keep_list, -1)                               # (B,S,k)

    # scatter tokens into the capacity buffer
    bidx = jnp.arange(B)[:, None, None] + jnp.zeros_like(idx_k)
    buf = jnp.zeros((B, E, C, d), dtype)
    xb = jnp.broadcast_to(x[:, :, None, :], (B, S, k, d))
    xb = jnp.where(keep_k[..., None], xb, 0)
    buf = buf.at[bidx, idx_k, pos_k].add(xb)
    buf = constrain(buf, mesh, "batch", "act_experts", None, None)

    # per-expert SwiGLU
    g = jnp.einsum("becd,edf->becf", buf, params["w_gate"].astype(dtype))
    u = jnp.einsum("becd,edf->becf", buf, params["w_up"].astype(dtype))
    g = constrain(g, mesh, "batch", "act_experts", None, "act_ffn")
    h = jax.nn.silu(g) * u
    out_buf = jnp.einsum("becf,efd->becd", h, params["w_down"].astype(dtype))
    out_buf = constrain(out_buf, mesh, "batch", "act_experts", None, None)

    # gather back with combine weights
    picked = out_buf[bidx, idx_k, pos_k]                            # (B,S,k,d)
    w = (gate_k * keep_k).astype(dtype)
    y = jnp.einsum("bskd,bsk->bsd", picked, w)

    if cfg.num_shared_experts:
        y = y + swiglu_mlp(x, params["shared"]["w_gate"],
                           params["shared"]["w_up"],
                           params["shared"]["w_down"], mesh)

    # aux losses: switch load-balance + router z-loss
    frac_tokens = jnp.mean(
        (jax.nn.one_hot(idx_k, E).sum(-2) > 0).astype(jnp.float32), axis=(0, 1))
    mean_prob = probs.mean(axis=(0, 1))
    aux = E * jnp.sum(frac_tokens * mean_prob)
    zloss = jnp.mean(jnp.square(jax.nn.logsumexp(logits, axis=-1)))
    return y, aux + 1e-3 * zloss


def _moe_ffn_fsdp(params, x, cfg: ModelConfig, mesh):
    """FSDP path: batch is sharded over every mesh axis; each device routes
    and runs its own tokens against the (boundary-gathered) expert weights.
    Zero collectives inside; the only wire cost is the ZeRO-3 weight gather.
    """
    from repro.models.paged import batch_shard_axes
    B = x.shape[0]
    bs = batch_shard_axes(mesh, B)
    # fall back when the batch can't shard (decode with tiny batch)
    all_axes = tuple(a for a in ("pod", "data", "model")
                     if a in mesh.axis_names)
    bspec = None
    for cand in (all_axes, tuple(a for a in all_axes if a != "model"),
                 ("data",)):
        present = tuple(a for a in cand if a in mesh.axis_names)
        size = int(np.prod([mesh.shape[a] for a in present])) if present else 1
        if present and B % size == 0:
            bspec = present if len(present) > 1 else present[0]
            break
    if bspec is None:
        return _moe_ffn_local(params, x, cfg, mesh)

    def local_fn(wr, wg, wu, wd, shared, xl):
        p = {"router": wr, "w_gate": wg, "w_up": wu, "w_down": wd}
        if shared is not None:
            p["shared"] = shared
        y, aux = _moe_ffn_local(p, xl, cfg, None)
        return y, jax.lax.pmean(aux, all_axes)

    shared = params.get("shared")
    mapped = shard_map(
        local_fn, mesh=mesh,
        in_specs=(P(), P(), P(), P(),
                  None if shared is None else jax.tree_util.tree_map(
                      lambda _: P(), shared),
                  P(bspec, None, None)),
        out_specs=(P(bspec, None, None), P()),
        check_vma=False)
    y, aux = mapped(params["router"], params["w_gate"], params["w_up"],
                    params["w_down"], shared, x)
    # aux was computed per shard on identical-statistics local tokens; it is
    # already a mean — no further normalization needed for the loss scale.
    return y, aux


# ---------------------------------------------------------------------------
# explicit all-to-all dispatch (multi-device path)
# ---------------------------------------------------------------------------

def _route_local(xf, wr, E, k, C):
    """Route N local tokens.  xf: (N,d).  Returns (gate_k, idx_k, pos_k,
    keep_k, probs, logits) with capacity C per expert."""
    logits = (xf @ wr.astype(xf.dtype)).astype(jnp.float32)      # (N,E)
    probs = jax.nn.softmax(logits, axis=-1)
    gate_k, idx_k = jax.lax.top_k(probs, k)
    gate_k = gate_k / jnp.maximum(gate_k.sum(-1, keepdims=True), 1e-9)
    counts = jnp.zeros((1, E), jnp.int32)
    pos_list, keep_list = [], []
    for j in range(k):
        onehot = jax.nn.one_hot(idx_k[:, j], E, dtype=jnp.int32)
        pos_in_e = jnp.cumsum(onehot, axis=0) - onehot + counts
        pos_j = jnp.sum(pos_in_e * onehot, axis=-1)
        keep_list.append(pos_j < C)
        pos_list.append(jnp.minimum(pos_j, C - 1))
        counts = counts + onehot.sum(axis=0, keepdims=True)
    return (gate_k, idx_k, jnp.stack(pos_list, -1),
            jnp.stack(keep_list, -1), probs, logits)


def _moe_ffn_a2a(params, x, cfg: ModelConfig, mesh):
    """shard_map MoE: local routing → one all-to-all to expert shards →
    local expert FFN → all-to-all back → local combine.

    Wire cost per layer ≈ 2 × (token bytes × k × capacity_factor) over the
    model axis — versus GSPMD's replicate+all-reduce lowering of the
    scatter formulation (~200 GB/layer at deepseek-moe scale).
    """
    dp = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
    tp = "model"
    T = int(mesh.shape[tp])
    dps = int(np.prod([mesh.shape[a] for a in dp])) if dp else 1
    B, S, d = x.shape
    E, k = cfg.num_experts, cfg.top_k
    if S % T or (dp and B % dps) or E % T:
        return _moe_ffn_local(params, x, cfg, mesh)   # decode / odd shapes
    E_l = E // T
    N_loc = (B // dps) * (S // T)
    C = max(8, -(-int(math.ceil(N_loc * k / E * CAPACITY_FACTOR)) // 8) * 8)

    bspec = dp if len(dp) > 1 else (dp[0] if dp else None)
    n_dev = T * dps

    def local_fn(wr, wg, wu, wd, xl):
        B_l, S_l, _ = xl.shape
        N = B_l * S_l
        xf = xl.reshape(N, d)
        gate_k, idx_k, pos_k, keep_k, probs, logits = _route_local(
            xf, wr, E, k, C)
        # pack local send buffer (E, C, d)
        buf = jnp.zeros((E, C, d), xl.dtype)
        xk = jnp.where(keep_k[..., None], xf[:, None, :], 0)     # (N,k,d)
        buf = buf.at[idx_k, pos_k].add(xk)
        # exchange: peer t receives my slice for its experts
        send = buf.reshape(T, E_l, C, d)
        recv = jax.lax.all_to_all(send, tp, split_axis=0, concat_axis=0,
                                  tiled=True)                    # (T,E_l,C,d)
        tokens = recv.swapaxes(0, 1).reshape(E_l, T * C, d)
        # local expert FFN (weights fully materialized at shard boundary)
        g = jnp.einsum("etd,edf->etf", tokens, wg.astype(tokens.dtype))
        u = jnp.einsum("etd,edf->etf", tokens, wu.astype(tokens.dtype))
        h = jax.nn.silu(g) * u
        out = jnp.einsum("etf,efd->etd", h, wd.astype(tokens.dtype))
        # return to owners
        back = out.reshape(E_l, T, C, d).swapaxes(0, 1)          # (T,E_l,C,d)
        mine = jax.lax.all_to_all(back, tp, split_axis=0, concat_axis=0,
                                  tiled=True).reshape(E, C, d)
        # local combine
        picked = mine[idx_k, pos_k]                              # (N,k,d)
        w = (gate_k * keep_k).astype(xl.dtype)
        y = jnp.einsum("nkd,nk->nd", picked, w).reshape(B_l, S_l, d)
        # aux (global mean via psum)
        frac = jnp.mean((jax.nn.one_hot(idx_k, E).sum(-2) > 0)
                        .astype(jnp.float32), axis=0)
        mean_p = probs.mean(axis=0)
        aux_l = E * jnp.sum(frac * mean_p)
        z_l = jnp.mean(jnp.square(jax.nn.logsumexp(logits, axis=-1)))
        aux = jax.lax.psum(aux_l + 1e-3 * z_l, dp + (tp,)) / n_dev
        return y, aux

    mapped = shard_map(
        local_fn, mesh=mesh,
        in_specs=(P(), P(tp), P(tp), P(tp), P(bspec, tp, None)),
        out_specs=(P(bspec, tp, None), P()),
        check_vma=False)
    y, aux = mapped(params["router"], params["w_gate"], params["w_up"],
                    params["w_down"], x)
    if cfg.num_shared_experts:
        y = y + swiglu_mlp(x, params["shared"]["w_gate"],
                           params["shared"]["w_up"],
                           params["shared"]["w_down"], mesh)
    return y, aux
