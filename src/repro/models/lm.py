"""LanguageModel facade: one interface over all six architecture families.

* ``init_params``  — Param pytree (values + logical sharding axes)
* ``loss_fn``      — training loss for (tokens, labels, mask) batches
* ``prefill``      — full-sequence forward that seeds the serve state
* ``decode_step``  — one-token step over the paged/recurrent state
* ``make_serve_state`` / state sharding specs — used by serving + dry-run
"""
from __future__ import annotations

import functools
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig, RowCloneConfig, ShapeConfig
from repro.models import mamba2 as m2
from repro.models import transformer as tfm
from repro.models.attention import MaskInfo
from repro.models.common import (
    Param, chunked_softmax_xent, embed_init, is_param, rms_norm,
    split_params, zeros_init,
)
from repro.models.paged import identity_layout
from repro.sharding import constrain


def _stack_layers(init_fn, key, n):
    keys = jax.random.split(key, n)
    stacked = jax.vmap(init_fn)(keys)
    return jax.tree_util.tree_map(
        lambda p: Param(p.value, ("layers",) + tuple(p.axes)),
        stacked, is_leaf=is_param)


class LanguageModel:
    def __init__(self, cfg: ModelConfig, rc: Optional[RowCloneConfig] = None):
        self.cfg = cfg
        self.rc = rc or RowCloneConfig()

    # ------------------------------------------------------------------
    # init
    # ------------------------------------------------------------------
    def init_params(self, key):
        cfg = self.cfg
        kE, kL, kH, kS, kX = jax.random.split(key, 5)
        params: Dict = {
            "embed": embed_init(kE, cfg.padded_vocab, cfg.d_model),
            "final_norm": zeros_init((cfg.d_model,), ("norm",)),
        }
        if not cfg.tie_embeddings:
            params["lm_head"] = Param(
                jax.random.normal(kH, (cfg.d_model, cfg.padded_vocab)) * 0.02,
                ("embed", "vocab"))
        fam = cfg.family
        if fam in ("dense", "moe", "vlm"):
            params["layers"] = _stack_layers(
                lambda k: tfm.init_decoder_layer(k, cfg), kL, cfg.num_layers)
        elif fam == "ssm":
            params["layers"] = _stack_layers(
                lambda k: m2.init_mamba2_layer(k, cfg), kL, cfg.num_layers)
        elif fam == "hybrid":
            params["layers"] = _stack_layers(
                lambda k: m2.init_mamba2_layer(k, cfg), kL, cfg.num_layers)
            params["shared"] = tfm.init_decoder_layer(kS, cfg)
        elif fam == "encdec":
            params["layers"] = _stack_layers(
                lambda k: tfm.init_decoder_layer(k, cfg, cross=True),
                kL, cfg.num_layers)
            params["enc_layers"] = _stack_layers(
                lambda k: tfm.init_decoder_layer(k, cfg), kX,
                cfg.encoder_layers)
            params["enc_norm"] = zeros_init((cfg.d_model,), ("norm",))
        else:
            raise ValueError(fam)
        return params

    # ------------------------------------------------------------------
    # shared pieces
    # ------------------------------------------------------------------
    def _embed(self, params, tokens, mesh):
        table = params["embed"]
        x = jnp.take(table, tokens, axis=0).astype(jnp.bfloat16
                     if self.cfg.dtype == "bfloat16" else jnp.float32)
        return constrain(x, mesh, "batch", None, None)

    def _lm_head(self, params):
        if self.cfg.tie_embeddings:
            return params["embed"].T
        return params["lm_head"]

    def _logits(self, params, x, mesh):
        w = self._lm_head(params)
        logits = x.astype(jnp.bfloat16) @ w.astype(jnp.bfloat16)
        logits = constrain(logits, mesh, "batch", "act_vocab")
        return logits.astype(jnp.float32)

    # ------------------------------------------------------------------
    # training forward (full sequence)
    # ------------------------------------------------------------------
    def _backbone_train(self, params, batch, mesh, remat, return_kv=False):
        """Returns (hidden, aux, kv, xkv, text_offset)."""
        cfg = self.cfg
        tokens = batch["tokens"]
        B, S_text = tokens.shape
        x = self._embed(params, tokens, mesh)
        prefix = 0
        if cfg.family == "vlm":
            patches = batch["patch_embeds"].astype(x.dtype)
            x = jnp.concatenate([patches, x], axis=1)
            prefix = patches.shape[1]
        B, S, _ = x.shape
        pos = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32), (B, S))
        info = MaskInfo(causal=True, prefix_len=prefix)

        if cfg.family in ("dense", "moe", "vlm"):
            x, aux, kv, _ = tfm.decoder_stack_train(
                params["layers"], x, pos, cfg, mesh, info, remat=remat,
                return_kv=return_kv)
            return x, aux, kv, None, prefix
        if cfg.family == "ssm":
            x, states = self._mamba_stack_train(params, x, mesh, return_kv)
            return x, jnp.float32(0), states, None, 0
        if cfg.family == "hybrid":
            x, aux, kv, states = self._hybrid_stack_train(
                params, x, pos, mesh, info, remat, return_kv)
            return x, aux, (kv, states), None, 0
        if cfg.family == "encdec":
            enc = batch["src_embeds"].astype(x.dtype)
            B_e, S_src, _ = enc.shape
            pos_e = jnp.broadcast_to(jnp.arange(S_src, dtype=jnp.int32),
                                     (B_e, S_src))
            enc, _, _, _ = tfm.decoder_stack_train(
                params["enc_layers"], enc, pos_e, cfg, mesh,
                MaskInfo(causal=False), remat=remat)
            enc = rms_norm(enc, params["enc_norm"].astype(jnp.float32),
                           cfg.norm_eps)
            x, aux, kv, xkv = tfm.decoder_stack_train(
                params["layers"], x, pos, cfg, mesh, info, enc_out=enc,
                remat=remat, return_kv=return_kv)
            return x, aux, kv, xkv, 0
        raise ValueError(cfg.family)

    def _mamba_stack_train(self, params, x, mesh, return_states):
        cfg = self.cfg

        def body(h, lp):
            h, h_final, conv_tail = m2.mamba2_layer(lp, h, cfg, mesh)
            ys = (h_final, conv_tail) if return_states else None
            return h, ys

        body_ck = jax.checkpoint(
            body, policy=jax.checkpoint_policies.nothing_saveable)
        x, states = jax.lax.scan(body_ck, x, params["layers"])
        return x, states

    def _hybrid_stack_train(self, params, x, pos, mesh, info, remat,
                            return_kv):
        cfg = self.cfg
        k = cfg.shared_attn_every
        n_seg = cfg.num_layers // k
        seg_params = jax.tree_util.tree_map(
            lambda a: a.reshape((n_seg, k) + a.shape[1:]), params["layers"])
        shared = params["shared"]
        strategy = "heads"

        def segment(carry, seg_lp):
            h, aux = carry

            def inner(hc, lp):
                hc, hf, ct = m2.mamba2_layer(lp, hc, cfg, mesh)
                return hc, (hf, ct) if return_kv else None

            h, states = jax.lax.scan(inner, h, seg_lp)
            h, a, kv, _ = tfm.decoder_layer_train(
                shared, h, pos, cfg, mesh, info, strategy,
                return_kv=return_kv)
            return (h, aux + a), (kv, states) if return_kv else None

        seg_ck = jax.checkpoint(
            segment, policy=tfm.REMAT_POLICIES.get(remat)) \
            if remat != "none" else segment
        (x, aux), ys = jax.lax.scan(seg_ck, (x, jnp.float32(0)), seg_params)
        if return_kv:
            kv, states = ys
            return x, aux, kv, states
        return x, aux, None, None

    # ------------------------------------------------------------------
    # loss
    # ------------------------------------------------------------------
    def loss_fn(self, params, batch, mesh, remat: str = "minimal"):
        cfg = self.cfg
        x, aux, _, _, prefix = self._backbone_train(params, batch, mesh, remat)
        x = rms_norm(x, params["final_norm"].astype(jnp.float32), cfg.norm_eps)
        if prefix:
            x = x[:, prefix:, :]
        w = self._lm_head(params)
        loss = chunked_softmax_xent(x, w, batch["labels"], batch["mask"],
                                    mesh)
        total = loss + 1e-2 * aux
        return total, {"loss": loss, "aux": aux}

    # ------------------------------------------------------------------
    # serve state
    # ------------------------------------------------------------------
    def make_serve_state(self, batch: int, seq_len: int, mesh=None,
                         filled: Optional[int] = None, dtype=jnp.bfloat16):
        """Zero-initialized serve state with identity block layout.

        ``filled`` — tokens already present per sequence (decode_* cells set
        seq_len - 1 so the next append lands in the final slot).
        """
        cfg, page = self.cfg, self.rc.page_size
        filled = seq_len - 1 if filled is None else filled
        state: Dict = {"seq_lens": jnp.full((batch,), filled, jnp.int32)}
        dp = 1
        if mesh is not None:
            dp_axes = [a for a in ("pod", "data") if a in mesh.axis_names]
            dp = int(np.prod([mesh.shape[a] for a in dp_axes])) if dp_axes else 1
            if batch % dp:
                dp = 1
        if cfg.family in ("dense", "moe", "vlm", "encdec", "hybrid"):
            L = cfg.num_attn_layers
            table, mask, base = identity_layout(batch, seq_len, page, dp)
            nblk = base.shape[0]
            state["block_table"] = jnp.asarray(table)
            state["share_mask"] = jnp.asarray(mask)
            state["base"] = jnp.asarray(base)
            state["k_pools"] = jnp.zeros(
                (L, nblk, page, cfg.num_kv_heads, cfg.head_dim), dtype)
            state["v_pools"] = jnp.zeros_like(state["k_pools"])
        if cfg.family in ("ssm", "hybrid"):
            L, W = cfg.num_layers, cfg.ssm_conv_width
            C = cfg.ssm_d_inner + 2 * cfg.ssm_state
            shp_conv = (L, batch, W - 1, C)
            shp_ssm = (L, batch, cfg.ssm_heads, cfg.ssm_head_dim,
                       cfg.ssm_state)
            if cfg.family == "hybrid":
                k = cfg.shared_attn_every
                n_seg = L // k
                shp_conv = (n_seg, k) + shp_conv[1:]
                shp_ssm = (n_seg, k) + shp_ssm[1:]
            state["conv_state"] = jnp.zeros(shp_conv, jnp.float32)
            state["ssm_state"] = jnp.zeros(shp_ssm, jnp.float32)
        if cfg.family == "encdec":
            S_src = max(seq_len // cfg.src_frames_ratio, 1)
            state["cross_k"] = jnp.zeros(
                (cfg.num_layers, batch, S_src, cfg.num_kv_heads,
                 cfg.head_dim), dtype)
            state["cross_v"] = jnp.zeros_like(state["cross_k"])
        return state

    def state_logical_axes(self, state):
        """Logical sharding axes for each serve-state leaf."""
        cfg = self.cfg
        ax = {"seq_lens": ("batch",)}
        if "k_pools" in state:
            pool = ("layers", "kv_blocks", None, None, None)
            ax.update(block_table=("batch", None),
                      share_mask=("kv_blocks", None),
                      base=("kv_blocks",), k_pools=pool, v_pools=pool)
        if "conv_state" in state:
            nd = state["conv_state"].ndim
            lead = (None,) * (nd - 3)
            ax["conv_state"] = lead + ("batch", None, "act_ffn")
            ax["ssm_state"] = lead + ("batch", "act_heads", None, None)
        if "cross_k" in state:
            ax["cross_k"] = (None, "batch", None, None, None)
            ax["cross_v"] = (None, "batch", None, None, None)
        return ax

    # ------------------------------------------------------------------
    # prefill
    # ------------------------------------------------------------------
    def prefill(self, params, batch, mesh, remat: str = "minimal",
                margin_tokens: Optional[int] = None):
        """Full forward; returns (last_logits, serve_state).

        ``margin_tokens`` — extra decode capacity beyond the prompt
        (default: one page)."""
        cfg, page = self.cfg, self.rc.page_size
        x, aux, kv, xkv, prefix = self._backbone_train(
            params, batch, mesh, remat, return_kv=True)
        B, S, _ = x.shape
        margin = page if margin_tokens is None else margin_tokens
        nper = (S + margin + page - 1) // page
        xn = rms_norm(x[:, -1, :], params["final_norm"].astype(jnp.float32),
                      cfg.norm_eps)
        logits = self._logits(params, xn, mesh)
        kv_dtype = jnp.bfloat16 if cfg.dtype == "bfloat16" else jnp.float32
        state = self.make_serve_state(B, nper * page, mesh, filled=S,
                                      dtype=kv_dtype)
        if cfg.family in ("dense", "moe", "vlm", "encdec"):
            k, v = kv  # (L,B,S,KVH,D)
            state["k_pools"] = _kv_to_pools(k, page, kv_dtype, nper)
            state["v_pools"] = _kv_to_pools(v, page, kv_dtype, nper)
        elif cfg.family == "hybrid":
            (k, v), (hf, ct) = kv[0], kv[1]
            state["k_pools"] = _kv_to_pools(k, page, kv_dtype, nper)
            state["v_pools"] = _kv_to_pools(v, page, kv_dtype, nper)
            state["ssm_state"] = hf
            state["conv_state"] = ct
        elif cfg.family == "ssm":
            hf, ct = kv
            state["ssm_state"] = hf
            state["conv_state"] = ct
        if cfg.family == "encdec" and xkv is not None:
            state["cross_k"], state["cross_v"] = xkv
        return logits, state

    # ------------------------------------------------------------------
    # decode
    # ------------------------------------------------------------------
    def decode_step(self, params, state, tokens, mesh, impl: str = "ref",
                    exclusive: bool = False):
        """tokens: (B,) int32 — the token just sampled; returns logits for
        the next position and the updated state."""
        cfg, page = self.cfg, self.rc.page_size
        B = tokens.shape[0]
        pos = state["seq_lens"]                       # (B,) position of token
        x = self._embed(params, tokens, mesh)          # (B,d)
        seq_incl = pos + 1

        if cfg.family in ("dense", "moe", "vlm", "encdec"):
            ids = jnp.take_along_axis(
                state["block_table"], (pos // page)[:, None], axis=1)[:, 0]
            cross_kvs = None
            if cfg.family == "encdec":
                cross_kvs = (state["cross_k"], state["cross_v"])
            x, kp, vp = tfm.decoder_stack_decode(
                params["layers"], x, pos, state["k_pools"], state["v_pools"],
                ids, pos % page, state["share_mask"], state["base"],
                seq_incl, cfg, mesh, cross_kvs=cross_kvs, impl=impl,
                exclusive=exclusive)
            state = dict(state, k_pools=kp, v_pools=vp)
        elif cfg.family == "ssm":
            def body(h, inp):
                lp, cs, ss = inp
                h, cs, ss = m2.mamba2_decode_step(lp, h, cs, ss, cfg, mesh)
                return h, (cs, ss)
            x, (cs, ss) = jax.lax.scan(
                body, x, (params["layers"], state["conv_state"],
                          state["ssm_state"]))
            state = dict(state, conv_state=cs, ssm_state=ss)
        elif cfg.family == "hybrid":
            x, state = self._hybrid_decode(params, state, x, pos, seq_incl,
                                           mesh, impl, exclusive)
        else:
            raise ValueError(cfg.family)

        xn = rms_norm(x, params["final_norm"].astype(jnp.float32),
                      cfg.norm_eps)
        logits = self._logits(params, xn, mesh)
        state = dict(state, seq_lens=seq_incl)
        return logits, state

    def _hybrid_decode(self, params, state, x, pos, seq_incl, mesh, impl,
                       exclusive=False):
        cfg, page = self.cfg, self.rc.page_size
        B = x.shape[0]
        k = cfg.shared_attn_every
        n_seg = cfg.num_layers // k
        seg_params = jax.tree_util.tree_map(
            lambda a: a.reshape((n_seg, k) + a.shape[1:]), params["layers"])
        ids = jnp.take_along_axis(
            state["block_table"], (pos // page)[:, None], axis=1)[:, 0]
        shared = params["shared"]

        def segment(h, inp):
            lp, cs, ss, kp, vp = inp

            def inner(hc, s_inp):
                l, c, s = s_inp
                hc, c, s = m2.mamba2_decode_step(l, hc, c, s, cfg, mesh)
                return hc, (c, s)

            h, (cs, ss) = jax.lax.scan(inner, h, (lp, cs, ss))
            h, (kp, vp), _ = tfm.decoder_layer_decode(
                shared, h, pos, (kp, vp), ids, pos % page,
                state["share_mask"], state["base"], seq_incl, cfg, mesh,
                impl=impl, exclusive=exclusive)
            return h, (cs, ss, kp, vp)

        x, (cs, ss, kp, vp) = jax.lax.scan(
            segment, x, (seg_params, state["conv_state"], state["ssm_state"],
                         state["k_pools"], state["v_pools"]))
        return x, dict(state, conv_state=cs, ssm_state=ss, k_pools=kp,
                       v_pools=vp)


def _kv_to_pools(kv, page, dtype, nper):
    """(L, B, S, KVH, D) -> (L, B*nper, page, KVH, D) identity layout with
    per-sequence capacity ``nper`` blocks.  Slots beyond seq_lens are masked
    by the paged-attention validity check, so zero padding is safe."""
    L, B, S, KVH, D = kv.shape
    cap = nper * page
    if S < cap:
        kv = jnp.pad(kv, ((0, 0), (0, 0), (0, cap - S), (0, 0), (0, 0)))
    return kv.reshape(L, B * nper, page, KVH, D).astype(dtype)


def build_model(cfg: ModelConfig, rc: Optional[RowCloneConfig] = None):
    return LanguageModel(cfg, rc)
