"""Shared building blocks: params-with-axes, norms, embeddings, rotary, MLP.

Parameters are plain pytrees of jnp arrays.  At init time every leaf is a
:class:`Param` carrying its *logical sharding axes*; :func:`split_params`
separates values from axes so the launcher can build NamedShardings without a
parallel hand-maintained tree.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.sharding import constrain


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class Param:
    """A parameter leaf + its logical sharding axes."""
    value: Any
    axes: Tuple[Optional[str], ...]

    def tree_flatten(self):
        return (self.value,), self.axes

    @classmethod
    def tree_unflatten(cls, axes, children):
        return cls(children[0], axes)


def is_param(x) -> bool:
    return isinstance(x, Param)


def split_params(tree):
    """Param tree -> (value tree, axes tree)."""
    values = jax.tree_util.tree_map(lambda p: p.value, tree, is_leaf=is_param)
    axes = jax.tree_util.tree_map(lambda p: p.axes, tree, is_leaf=is_param)
    return values, axes


def stack_param_axes(axes_tree):
    """Prepend the 'layers' logical axis (for scan-stacked params)."""
    return jax.tree_util.tree_map(
        lambda a: ("layers",) + tuple(a),
        axes_tree,
        is_leaf=lambda x: isinstance(x, tuple),
    )


# ---------------------------------------------------------------------------
# initializers
# ---------------------------------------------------------------------------

def dense_init(key, in_dim: int, out_dim: int, axes, scale: Optional[float] = None,
               dtype=jnp.float32) -> Param:
    scale = scale if scale is not None else in_dim ** -0.5
    w = jax.random.normal(key, (in_dim, out_dim), dtype) * jnp.asarray(scale, dtype)
    return Param(w, axes)


def zeros_init(shape, axes, dtype=jnp.float32) -> Param:
    return Param(jnp.zeros(shape, dtype), axes)


def ones_init(shape, axes, dtype=jnp.float32) -> Param:
    return Param(jnp.ones(shape, dtype), axes)


def embed_init(key, vocab: int, d: int, dtype=jnp.float32) -> Param:
    w = jax.random.normal(key, (vocab, d), dtype) * 0.02
    return Param(w, ("vocab", "embed"))


# ---------------------------------------------------------------------------
# math blocks (functional)
# ---------------------------------------------------------------------------

def rms_norm(x, scale, eps: float = 1e-5):
    dtype = x.dtype
    x32 = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x32), axis=-1, keepdims=True)
    y = x32 * jax.lax.rsqrt(var + eps)
    return (y * (1.0 + scale.astype(jnp.float32))).astype(dtype)


def swiglu_mlp(x, w_gate, w_up, w_down, mesh=None):
    """SwiGLU MLP.  Activations constrained ffn-sharded over the model axis."""
    dtype = x.dtype
    g = x @ w_gate.astype(dtype)
    u = x @ w_up.astype(dtype)
    if mesh is not None:
        g = constrain(g, mesh, "batch", None, "act_ffn")
        u = constrain(u, mesh, "batch", None, "act_ffn")
    h = jax.nn.silu(g) * u
    return h @ w_down.astype(dtype)


def init_mlp(key, d_model: int, d_ff: int):
    k1, k2, k3 = jax.random.split(key, 3)
    return {
        "w_gate": dense_init(k1, d_model, d_ff, ("embed", "ffn")),
        "w_up": dense_init(k2, d_model, d_ff, ("embed", "ffn")),
        "w_down": dense_init(k3, d_ff, d_model, ("ffn", "embed")),
    }


# ---------------------------------------------------------------------------
# rotary embeddings
# ---------------------------------------------------------------------------

def rope_frequencies(head_dim: int, theta: float) -> jnp.ndarray:
    half = head_dim // 2
    return 1.0 / (theta ** (jnp.arange(0, half, dtype=jnp.float32) / half))


def apply_rope(x, positions, theta: float):
    """x: (..., seq, heads, head_dim); positions: broadcastable (..., seq)."""
    head_dim = x.shape[-1]
    freqs = rope_frequencies(head_dim, theta)                    # (half,)
    angles = positions[..., :, None].astype(jnp.float32) * freqs  # (..., s, half)
    cos = jnp.cos(angles)[..., :, None, :]                        # (..., s, 1, half)
    sin = jnp.sin(angles)[..., :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# cross-entropy (seq-chunked so full fp32 logits never materialize)
# ---------------------------------------------------------------------------

def chunked_softmax_xent(x_final, w_out, labels, mask, mesh=None,
                         chunk: int = 512, z_loss: float = 1e-4):
    """x_final: (B,S,D) final hidden; w_out: (D,V); labels/mask: (B,S).

    Computes mean CE over masked positions by scanning over sequence chunks;
    vocab axis sharded over the model mesh axis via constraint.
    """
    B, S, D = x_final.shape
    V = w_out.shape[1]
    n_chunks = max(S // chunk, 1)
    if S % n_chunks:  # pad to a chunk multiple; pad positions are masked
        pad = n_chunks - S % n_chunks
        x_final = jnp.pad(x_final, ((0, 0), (0, pad), (0, 0)))
        labels = jnp.pad(labels, ((0, 0), (0, pad)))
        mask = jnp.pad(mask, ((0, 0), (0, pad)))
        S += pad
    chunk = S // n_chunks
    xc = x_final.reshape(B, n_chunks, chunk, D).swapaxes(0, 1)
    lc = labels.reshape(B, n_chunks, chunk).swapaxes(0, 1)
    mc = mask.reshape(B, n_chunks, chunk).swapaxes(0, 1)

    def body(carry, inp):
        loss_sum, z_sum, count = carry
        xb, lb, mb = inp
        logits = xb.astype(jnp.bfloat16) @ w_out.astype(jnp.bfloat16)
        if mesh is not None:
            logits = constrain(logits, mesh, "batch", None, "act_vocab")
        logits = logits.astype(jnp.float32)
        lse = jax.nn.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(logits, lb[..., None], axis=-1)[..., 0]
        ce = (lse - gold) * mb
        zl = jnp.square(lse) * mb
        return (loss_sum + ce.sum(), z_sum + zl.sum(), count + mb.sum()), None

    # checkpoint: backward recomputes each chunk's logits instead of saving
    # (B, chunk, V) fp32 residuals for every chunk
    body = jax.checkpoint(body,
                          policy=jax.checkpoint_policies.nothing_saveable)
    (loss_sum, z_sum, count), _ = jax.lax.scan(
        body, (jnp.float32(0), jnp.float32(0), jnp.float32(0)), (xc, lc, mc))
    denom = jnp.maximum(count, 1.0)
    return loss_sum / denom + z_loss * z_sum / denom


def compute_positions(seq_len: int, batch: int):
    return jnp.broadcast_to(jnp.arange(seq_len, dtype=jnp.int32), (batch, seq_len))
