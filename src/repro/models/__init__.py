from repro.models.common import Param, is_param, split_params
from repro.models.lm import LanguageModel, build_model

__all__ = ["Param", "is_param", "split_params", "LanguageModel", "build_model"]
