"""Attention: flash-style training/prefill attention + paged decode attention.

Three execution regimes (DESIGN.md §4):

* train/prefill — pure-JAX flash attention (online softmax over KV chunks),
  sharded by the ``heads`` strategy when q-heads divide the model axis, else
  the ``seq`` strategy (q-sequence sharded, KV gathered).  On TPU the Pallas
  ``flash_attention`` kernel replaces the scan (kernels/ops.py).

* decode — paged attention over the block pool.  The pool's block axis is
  sharded over ``model`` ("subarray slabs"); each device sweeps its local
  slab once using the inverse block map (owner sequence / base position per
  block), reduces per-sequence with segment ops, and the final combine is a
  log-sum-exp psum across the model axis.  No page gathers, no all-to-alls:
  bytes touched = exactly the live KV bytes on the device.
"""
from __future__ import annotations

import functools
from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.sharding import constrain

NEG_INF = -1e30


class MaskInfo(NamedTuple):
    """Describes the attention mask pattern.

    causal: bool — causal LM mask
    prefix_len: int — positions < prefix_len attend bidirectionally
                      (PaliGemma prefix-LM); 0 for pure causal
    """
    causal: bool = True
    prefix_len: int = 0


def _mask(pos_q, pos_kv, kv_valid, info: MaskInfo):
    """pos_q: (B,Sq), pos_kv: (B,Skv), kv_valid: (B,Skv) bool."""
    m = kv_valid[:, None, :]
    if info.causal:
        allowed = pos_q[:, :, None] >= pos_kv[:, None, :]
        if info.prefix_len:
            allowed = jnp.logical_or(allowed, (pos_kv < info.prefix_len)[:, None, :])
        m = jnp.logical_and(m, allowed)
    return m  # (B, Sq, Skv)


def flash_attention(q, k, v, pos_q, pos_kv, kv_valid, info: MaskInfo,
                    kv_chunk: int = 512):
    """Online-softmax attention, memory O(Sq * kv_chunk).

    q: (B,Sq,H,D); k,v: (B,Skv,KVH,D) with H % KVH == 0.
    Returns (B,Sq,H,D) in q.dtype.
    """
    B, Sq, H, D = q.shape
    Skv, KVH = k.shape[1], k.shape[2]
    group = H // KVH
    scale = D ** -0.5

    n_chunks = max(Skv // kv_chunk, 1)
    kv_chunk = Skv // n_chunks
    kc = k.reshape(B, n_chunks, kv_chunk, KVH, D).swapaxes(0, 1)
    vc = v.reshape(B, n_chunks, kv_chunk, KVH, D).swapaxes(0, 1)
    pc = pos_kv.reshape(B, n_chunks, kv_chunk).swapaxes(0, 1)
    valc = kv_valid.reshape(B, n_chunks, kv_chunk).swapaxes(0, 1)

    qg = q.reshape(B, Sq, KVH, group, D)

    def body(carry, inp):
        m, l, acc = carry
        kb, vb, pb, vb_valid = inp
        s = jnp.einsum("bqkgd,bckd->bqkgc", qg, kb,
                       preferred_element_type=jnp.float32) * scale
        msk = _mask(pos_q, pb, vb_valid, info)                  # (B,Sq,c)
        s = jnp.where(msk[:, :, None, None, :], s, NEG_INF)
        m_new = jnp.maximum(m, s.max(axis=-1))
        corr = jnp.exp(m - m_new)
        p = jnp.exp(s - m_new[..., None])
        l_new = l * corr + p.sum(axis=-1)
        pv = jnp.einsum("bqkgc,bckd->bqkgd", p.astype(vb.dtype), vb,
                        preferred_element_type=jnp.float32)
        acc_new = acc * corr[..., None] + pv
        return (m_new, l_new, acc_new), None

    m0 = jnp.full((B, Sq, KVH, group), NEG_INF, jnp.float32)
    l0 = jnp.zeros((B, Sq, KVH, group), jnp.float32)
    a0 = jnp.zeros((B, Sq, KVH, group, D), jnp.float32)
    # checkpoint the chunk body: backward recomputes scores/p per chunk
    # instead of saving O(Sq*Skv) softmax residuals (flash backward).
    body = jax.checkpoint(body,
                          policy=jax.checkpoint_policies.nothing_saveable)
    (m, l, acc), _ = jax.lax.scan(body, (m0, l0, a0), (kc, vc, pc, valc))
    out = acc / jnp.maximum(l, 1e-30)[..., None]
    return out.reshape(B, Sq, H, D).astype(q.dtype)


def attention_train(q, k, v, pos, info: MaskInfo, mesh, strategy: str,
                    kv_chunk: int = 512):
    """Full-sequence attention for train/prefill with sharding constraints.

    q: (B,S,H,D), k/v: (B,S,KVH,D), pos: (B,S).
    strategy: 'heads' (shard q&kv heads over model when divisible, kv heads
    replicated if not) or 'seq' (shard q-seq over model, gather kv).
    """
    tp_ok_kv = mesh is not None and k.shape[2] % max(
        np.prod([mesh.shape[a] for a in mesh.axis_names if a == "model"] or [1]), 1) == 0
    if strategy == "heads":
        q = constrain(q, mesh, "batch", None, "act_heads", None)
        kv_axis = "act_kv_heads" if tp_ok_kv else None
        k = constrain(k, mesh, "batch", None, kv_axis, None)
        v = constrain(v, mesh, "batch", None, kv_axis, None)
    else:  # 'seq': q rows sharded, kv replicated over model (XLA all-gathers)
        q = constrain(q, mesh, "batch", "act_seq_tp", None, None)
        k = constrain(k, mesh, "batch", None, None, None)
        v = constrain(v, mesh, "batch", None, None, None)
    kv_valid = jnp.ones(pos.shape, bool)
    out = flash_attention(q, k, v, pos, pos, kv_valid, info, kv_chunk)
    return constrain(out, mesh, "batch", None, None, None)


# ---------------------------------------------------------------------------
# Paged decode attention — per-slab partial pass (runs inside shard_map)
# ---------------------------------------------------------------------------

def paged_attention_slab(q, k_slab, v_slab, share_mask, base, seq_lens,
                         *, page: int, impl: str = "ref",
                         exclusive: bool = False):
    """Partial attention of new-token queries against one local slab.

    q:        (B, H, D)       — one new token per sequence (post-RoPE)
    k_slab:   (nblk, page, KVH, D) — this device's pool slab
    v_slab:   (nblk, page, KVH, D)
    share_mask: (nblk, B) int8 — block readable by sequence b (CoW sharing
                sets several columns; all-zero row = free block)
    base:     (nblk,) int32   — token offset of the block within its sequence
    seq_lens: (B,) int32      — tokens valid per sequence INCLUDING current

    Returns (acc, l, m): un-normalized output (B,H,D) fp32, softmax partial
    sums (B,H) and running max (B,H) for cross-device LSE combine.
    """
    if impl == "pallas":
        from repro.kernels import ops as kops
        return kops.paged_attention_slab(q, k_slab, v_slab, share_mask, base,
                                         seq_lens, page=page)
    from repro.kernels import ref as kref
    return kref.paged_attention_slab(q, k_slab, v_slab, share_mask, base,
                                     seq_lens, page=page,
                                     exclusive=exclusive)


def lse_combine(acc, l, m, axis_name: str):
    """Combine flash partials across a mesh axis: (B,H,D),(B,H),(B,H)."""
    m_g = jax.lax.pmax(m, axis_name)
    corr = jnp.exp(m - m_g)
    l_g = jax.lax.psum(l * corr, axis_name)
    acc_g = jax.lax.psum(acc * corr[..., None], axis_name)
    return acc_g / jnp.maximum(l_g, 1e-30)[..., None]
