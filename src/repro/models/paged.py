"""Paged KV-cache device math: append + partial attention + LSE combine.

Pool layout ("subarray slabs", DESIGN.md §2): every attention layer owns K/V
pools of shape ``(nblk, page, KVH, D)``.  The block axis is sharded jointly
over ``(pod, data, model)``: each device holds one *slab* — the RowClone
subarray analogue.  The allocator (core/allocator.py) is placement-aware so a
sequence's blocks live in the mesh row that owns the sequence; decode
attention then needs **zero page movement** — each device sweeps its own slab
and partial results are LSE-combined over the model axis only.

When the batch is too small to shard (long_500k, B=1) the sequence's blocks
spread over the whole mesh and the combine spans all axes — turning the
entire pod into one flash-decoding ring for a single 500k-token sequence.
"""
from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.compat import axis_size as compat_axis_size, shard_map
from repro.models.attention import lse_combine, paged_attention_slab


# ---------------------------------------------------------------------------
# spec helpers
# ---------------------------------------------------------------------------

def pool_shard_axes(mesh: Mesh) -> Tuple[str, ...]:
    """Mesh axes (in shard order) that a pool's block axis shards over."""
    return tuple(a for a in ("pod", "data", "model") if a in mesh.axis_names)


def pool_shard_count(mesh: Optional[Mesh]) -> int:
    """Device shards of a pool's block axis: joint size of every
    pool-sharding axis present; 1 with no mesh.  The single owner of this
    arithmetic — the engine's sharded dispatch gates on it and the serving
    layer rounds pool sizes with it (``nblk % shards == 0``)."""
    if mesh is None:
        return 1
    axes = pool_shard_axes(mesh)
    return int(np.prod([mesh.shape[a] for a in axes])) if axes else 1


def batch_shard_axes(mesh: Mesh, batch: int) -> Tuple[str, ...]:
    """Mesh axes the decode batch shards over: the (pod, data) subset when
    it divides ``batch``, else () (replicated batch — e.g. B=1 long-context
    where the whole pod sweeps for one sequence)."""
    dp = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
    size = int(np.prod([mesh.shape[a] for a in dp])) if dp else 1
    return dp if dp and batch % size == 0 else ()


def batch_shard_count(mesh: Optional[Mesh], batch: int) -> int:
    """Device groups the decode batch splits into (1 = replicated batch).
    The single owner of this arithmetic for the serving layer: the
    PagedCoWCache uses it to emit LOCAL share-mask columns and to pin each
    sequence's blocks inside its group's slabs."""
    if mesh is None:
        return 1
    axes = batch_shard_axes(mesh, batch)
    return int(np.prod([mesh.shape[a] for a in axes])) if axes else 1


def combine_axes(mesh: Mesh, batch_axes: Tuple[str, ...]) -> Tuple[str, ...]:
    """Pool axes over which decode partials must be LSE-combined, given
    the axes the batch ACTUALLY shards over (which may be () even for a
    divisible batch — the share-mask column count is the contract, see
    :func:`paged_attend_append`)."""
    bs = set(batch_axes)
    return tuple(a for a in pool_shard_axes(mesh) if a not in bs)


def pool_spec(mesh: Mesh) -> P:
    """PartitionSpec sharding a flat pool's leading block axis."""
    axes = pool_shard_axes(mesh)
    return P(axes if len(axes) > 1 else (axes[0] if axes else None))


def pool_partition_spec(mesh: Mesh, spec=None, block_axis: int = 0) -> P:
    """PartitionSpec for one pool honoring its ``PoolSpec.sharding`` hint.

    ``spec`` may be a :class:`~repro.core.poolspec.PoolSpec`, a raw hint
    tuple, or None.  Hint semantics: ``None`` (or no spec) = the default
    joint pool axes (``pool_shard_axes``); ``()`` = **replicated** — the
    pool's block axis is held whole on every device (what a small staging
    ring wants: slots stay addressable without rounding the ring up to
    the shard count); a non-empty tuple = exactly those mesh axes (absent
    axes are dropped).  ``block_axis`` positions the sharded dimension
    (serving pools are layer-stacked, block axis 1)."""
    hint = getattr(spec, "sharding", spec)
    if hint is None:
        axes = pool_shard_axes(mesh)
    else:
        axes = tuple(a for a in hint if a in mesh.axis_names)
    return P(*([None] * block_axis),
             axes if len(axes) > 1 else (axes[0] if axes else None))


def _maybe(axes: Tuple[str, ...]):
    if not axes:
        return None
    return axes if len(axes) > 1 else axes[0]


# ---------------------------------------------------------------------------
# pool construction — K/V pools and their staging pools are ONE layout
# decision (same block shape, same dtype, same (pod, data, model) sharding
# of the block axis), so cross-pool promotion commands are always legal
# ---------------------------------------------------------------------------

def make_serving_pools(num_layers: int, nblk: int, page: int, kv_heads: int,
                       head_dim: int, dtype,
                       staging: bool = True,
                       stage_nblk: Optional[int] = None,
                       replicate_staging: bool = False,
                       ckpt_nblk: int = 0,
                       replicate_ckpt: bool = False):
    """Build the serving engine's pools: layer-stacked ``(L, nblk, page,
    KVH, D)`` K/V pools plus (by default) their staging pools.

    The staging pools are where prefill writes land; staged pages promote
    into allocator-owned K/V blocks via ``OP_CROSS_POOL_COPY`` through the
    command queue (RowCloneEngine ``promote_staged``), so every byte of
    bulk movement in a serving round rides one fused launch.

    ``stage_nblk`` sizes the staging pools INDEPENDENTLY of their KV
    twins: ``None`` keeps the full-size twin (every KV block has a staging
    slot), while a small value builds a staging *ring* — just enough slots
    to park the admissions between two flushes — which is what cuts the
    serving engine's resident pool bytes by ~2x (slots recycle every
    round; see launch/serve.py ``max_admit_pages``).  Under a mesh it
    either divides by the same ``pool_shard_count`` as ``nblk`` or sets
    ``replicate_staging=True``: the staging specs get the ``()`` sharding
    hint, the ring is held whole on every device
    (:func:`pool_partition_spec`), and promotions out of it are always
    slab-local in the collective drain — the placement override that
    keeps an oddly-sized ring from rounding up to the shard count.

    ``ckpt_nblk > 0`` adds ``k_spill``/``v_spill`` pools of that many
    blocks (``role="spill"``, paired with K/V): the background checkpoint
    stream's copy window — primary blocks spill into them as cross-pool
    traffic overlapping decode, then stream to disk
    (checkpoint/pool_checkpoint.py).  ``replicate_ckpt`` is the same
    placement override as ``replicate_staging``, for spill windows that
    don't divide the shard count.

    Returns ``(pools, group)``: the name -> array dict plus the
    :class:`~repro.core.poolspec.PoolGroup` describing the engine's
    address space (per-pool block counts, roles, sharding hint) — both go
    straight into the RowCloneEngine constructor.
    """
    from repro.core.poolspec import PoolGroup, PoolSpec
    if stage_nblk is None:
        stage_nblk = nblk
    block_shape = (num_layers, page, kv_heads, head_dim)
    shape = (num_layers, nblk, page, kv_heads, head_dim)
    sshape = (num_layers, stage_nblk, page, kv_heads, head_dim)
    hint = ("pod", "data", "model")
    pools = {"k": jnp.zeros(shape, dtype), "v": jnp.zeros(shape, dtype)}
    specs = [PoolSpec("k", nblk, block_shape, dtype, sharding=hint),
             PoolSpec("v", nblk, block_shape, dtype, sharding=hint)]
    if staging:
        shint = () if replicate_staging else hint
        pools["k_stage"] = jnp.zeros(sshape, dtype)
        pools["v_stage"] = jnp.zeros(sshape, dtype)
        specs += [PoolSpec("k_stage", stage_nblk, block_shape, dtype,
                           role="staging", paired="k", sharding=shint),
                  PoolSpec("v_stage", stage_nblk, block_shape, dtype,
                           role="staging", paired="v", sharding=shint)]
    if ckpt_nblk > 0:
        chint = () if replicate_ckpt else hint
        cshape = (num_layers, ckpt_nblk, page, kv_heads, head_dim)
        pools["k_spill"] = jnp.zeros(cshape, dtype)
        pools["v_spill"] = jnp.zeros(cshape, dtype)
        specs += [PoolSpec("k_spill", ckpt_nblk, block_shape, dtype,
                           role="spill", paired="k", sharding=chint),
                  PoolSpec("v_spill", ckpt_nblk, block_shape, dtype,
                           role="spill", paired="v", sharding=chint)]
    return pools, PoolGroup(specs)


# ---------------------------------------------------------------------------
# the per-layer decode step
# ---------------------------------------------------------------------------

def paged_attend_append(mesh: Optional[Mesh], q, k_new, v_new, k_pool, v_pool,
                        blk_ids, offsets, share_mask, base, seq_lens,
                        impl: str = "ref", exclusive: bool = False):
    """Append this step's K/V then attend over the paged cache.

    q:        (B, H, D)      new-token queries, post-RoPE
    k_new/v_new: (B, KVH, D) new-token keys/values, post-RoPE
    k_pool/v_pool: (nblk, page, KVH, D) — block axis sharded (pod,data,model)
    blk_ids:  (B,) int32     GLOBAL pool block id receiving this token
    offsets:  (B,) int32     slot within that block
    share_mask: block-readable-by-sequence bitmap, int8.  Its COLUMN COUNT
                is the batch-sharding contract: ``(nblk, B // dp)`` means
                local columns — the batch shards over (pod, data) and row
                ``b``'s columns index the batch group owning block ``b``'s
                shard (every sequence's blocks must live in its own group's
                slabs); ``(nblk, B)`` means global columns — the batch
                stays replicated and partials combine over every pool axis
                (correct for any block placement).
    base:     (nblk,) int32  token offset of block within its sequence
    seq_lens: (B,) int32     sequence length INCLUDING the new token

    Returns (out (B,H,D), k_pool', v_pool').
    """
    page = k_pool.shape[1]
    if mesh is None or int(np.prod(mesh.devices.shape)) == 1:
        return _attend_append_local(q, k_new, v_new, k_pool, v_pool, blk_ids,
                                    offsets, share_mask, base, seq_lens,
                                    page=page, impl=impl,
                                    exclusive=exclusive)

    B = q.shape[0]
    b_axes = batch_shard_axes(mesh, B)
    dp = int(np.prod([mesh.shape[a] for a in b_axes])) if b_axes else 1
    if b_axes and share_mask.shape[1] != B // dp:
        # mask columns are GLOBAL batch numbering: the caller's placement
        # isn't group-aligned, so replicate the batch instead of sharding
        # it (every slab serves every sequence; combine spans all axes)
        b_axes = ()
    bspec = _maybe(b_axes)
    pspec = pool_spec(mesh)
    mspec = P(pspec[0], None)
    comb = combine_axes(mesh, b_axes)

    fn = functools.partial(_attend_append_local, combine=comb,
                           pool_axes=pool_shard_axes(mesh), page=page,
                           impl=impl, exclusive=exclusive)
    mapped = shard_map(
        fn, mesh=mesh,
        in_specs=(P(bspec), P(bspec), P(bspec), pspec, pspec,
                  P(bspec), P(bspec), mspec, pspec, P(bspec)),
        out_specs=(P(bspec), pspec, pspec),
        check_vma=False,
    )
    return mapped(q, k_new, v_new, k_pool, v_pool, blk_ids, offsets,
                  share_mask, base, seq_lens)


def _attend_append_local(q, k_new, v_new, k_slab, v_slab, blk_ids, offsets,
                         share_mask, base, seq_lens, combine=(),
                         pool_axes=(), page=64, impl="ref",
                         exclusive=False):
    slab = k_slab.shape[0]
    # blk_ids are global pool row numbers; this device's slab starts at the
    # shard-order offset over ALL axes sharding the pool.
    my0 = _slab_offset(pool_axes, slab) if pool_axes else jnp.int32(0)
    local = blk_ids - my0
    ok = (local >= 0) & (local < slab)
    safe = jnp.where(ok, local, slab)
    k_slab = k_slab.at[safe, offsets].set(k_new.astype(k_slab.dtype),
                                          mode="drop")
    v_slab = v_slab.at[safe, offsets].set(v_new.astype(v_slab.dtype),
                                          mode="drop")
    acc, l, m = paged_attention_slab(q, k_slab, v_slab, share_mask, base,
                                     seq_lens, page=page, impl=impl,
                                     exclusive=exclusive)
    if combine:
        out = lse_combine(acc, l, m, combine)
    else:
        out = acc / jnp.maximum(l, 1e-30)[..., None]
    return out.astype(q.dtype), k_slab, v_slab


def _slab_offset(pool_axes: Tuple[str, ...], slab: int):
    """Global row offset of this device's slab, given the axes sharding the
    block dimension *in shard order*."""
    idx = jnp.int32(0)
    for a in pool_axes:
        idx = idx * compat_axis_size(a) + jax.lax.axis_index(a)
    return idx * slab


# ---------------------------------------------------------------------------
# contiguous "identity" allocation used by prefill and the dry-run
# ---------------------------------------------------------------------------

def identity_layout(batch: int, seq_len: int, page: int, dp: int = 1):
    """Block table/share-mask/base for the contiguous layout where sequence
    b's j-th block is pool row b*nblk_per_seq + j.  With the
    (pod,data,model) pool sharding this lands every sequence's blocks in its
    own mesh row — the subarray-aware placement from the paper, as layout
    math.

    Returns (block_table (B, nper), share_mask (nblk, B//dp) int8,
    base (nblk,)).  The mask columns use LOCAL batch numbering when the
    batch will be sharded ``dp`` ways (identity layout shards contiguous
    batch groups, so local index = b % (B/dp))."""
    nper = (seq_len + page - 1) // page
    nblk = batch * nper
    table = np.arange(nblk, dtype=np.int32).reshape(batch, nper)
    owner = np.repeat(np.arange(batch, dtype=np.int32), nper)
    base = np.tile(np.arange(nper, dtype=np.int32) * page, batch)
    b_local = batch // dp if dp > 1 and batch % dp == 0 else batch
    mask = np.zeros((nblk, b_local), np.int8)
    mask[np.arange(nblk), owner % b_local] = 1
    return table, mask, base
