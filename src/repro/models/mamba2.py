"""Mamba2 / SSD (state-space duality) blocks — chunked train path + O(1) decode.

Training/prefill uses the SSD block decomposition (arXiv:2405.21060 §6):
intra-chunk quadratic term + inter-chunk recurrent state passed through a
``lax.scan``.  All per-chunk decay factors are differences of within-chunk
cumulative sums, so every ``exp`` argument is ≤ 0 (numerically safe).

Decode carries (conv_state, ssm_state) and costs O(1) per token.
"""
from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.common import Param, dense_init, ones_init, rms_norm, zeros_init
from repro.sharding import constrain


# ---------------------------------------------------------------------------
# init
# ---------------------------------------------------------------------------

def init_mamba2_layer(key, cfg: ModelConfig) -> Dict[str, Param]:
    d, di = cfg.d_model, cfg.ssm_d_inner
    H, N, W = cfg.ssm_heads, cfg.ssm_state, cfg.ssm_conv_width
    conv_ch = di + 2 * N
    k1, k2, k3, k4 = jax.random.split(key, 4)
    dt_init = jnp.log(jnp.expm1(jnp.exp(jax.random.uniform(
        k4, (H,), minval=jnp.log(1e-3), maxval=jnp.log(1e-1)))))
    return {
        "norm": zeros_init((d,), ("norm",)),
        # in_proj -> [z(di), xBC(di+2N), dt(H)]
        "w_in": dense_init(k1, d, 2 * di + 2 * N + H, ("embed", "ssm_inner")),
        # conv params are tiny (W x C ~ 84 KB) — their own logical axis so
        # FSDP keeps them replicated (sharding them forces GSPMD to
        # channel-reshard the batch-sharded conv activations; §Perf iter 6)
        "conv_w": Param(jax.random.normal(k2, (W, conv_ch)) * (W ** -0.5),
                        ("conv_w", "conv_ch")),
        "conv_b": zeros_init((conv_ch,), ("conv_ch",)),
        "dt_bias": Param(dt_init, ("ssm_heads_p",)),
        "A_log": Param(jnp.log(jnp.arange(1, H + 1, dtype=jnp.float32)),
                       ("ssm_heads_p",)),
        "D": ones_init((H,), ("ssm_heads_p",)),
        "gate_norm": zeros_init((di,), ("ssm_inner",)),
        "w_out": dense_init(k3, di, d, ("ssm_inner", "embed")),
    }


# ---------------------------------------------------------------------------
# SSD chunked scan (training / prefill)
# ---------------------------------------------------------------------------

def ssd_chunked(x, dt, A, B_mat, C_mat, D_skip, chunk: int, impl: str = "jax"):
    """x: (B,S,H,P); dt: (B,S,H) >0; A: (H,) <0; B/C: (B,S,N); D: (H,).

    Returns y: (B,S,H,P).  ``impl='pallas'`` routes the intra-chunk term to
    the Pallas kernel on TPU (kernels/ssd_chunk.py).
    """
    Bb, S, H, P = x.shape
    N = B_mat.shape[-1]
    S_orig = S
    if S % chunk and S > chunk:  # pad to a chunk multiple (dt=0 is a no-op)
        pad = chunk - S % chunk
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        B_mat = jnp.pad(B_mat, ((0, 0), (0, pad), (0, 0)))
        C_mat = jnp.pad(C_mat, ((0, 0), (0, pad), (0, 0)))
        S = S + pad
    n_chunks = max(S // chunk, 1)
    Q = S // n_chunks

    xc = x.reshape(Bb, n_chunks, Q, H, P).swapaxes(0, 1)
    dtc = dt.reshape(Bb, n_chunks, Q, H).swapaxes(0, 1)
    Bc = B_mat.reshape(Bb, n_chunks, Q, N).swapaxes(0, 1)
    Cc = C_mat.reshape(Bb, n_chunks, Q, N).swapaxes(0, 1)

    if impl == "pallas":
        from repro.kernels import ops as kops
        intra_fn = kops.ssd_intra_chunk
    else:
        from repro.models import mamba2 as _self
        intra_fn = _self._ssd_intra_chunk_jnp

    def body(h, inp):
        xb, dtb, Bb_, Cb = inp                     # (B,Q,H,P),(B,Q,H),(B,Q,N)
        a = dtb.astype(jnp.float32) * A[None, None, :]            # (B,Q,H) <0
        cum = jnp.cumsum(a, axis=1)                               # inclusive
        y_intra = intra_fn(xb, dtb, cum, Bb_, Cb)                 # (B,Q,H,P)
        # inter-chunk: contribution of the carried state
        decay_i = jnp.exp(cum)                                    # <=1
        y_inter = jnp.einsum("bqn,bhpn,bqh->bqhp",
                             Cb.astype(jnp.float32), h, decay_i)
        # state update
        w = jnp.exp(cum[:, -1:, :] - cum) * dtb.astype(jnp.float32)  # (B,Q,H)
        S_c = jnp.einsum("bqh,bqhp,bqn->bhpn", w, xb.astype(jnp.float32),
                         Bb_.astype(jnp.float32))
        h = h * jnp.exp(cum[:, -1, :])[:, :, None, None] + S_c
        return h, (y_intra + y_inter)

    h0 = jnp.zeros((Bb, H, P, N), jnp.float32)
    # checkpoint: backward recomputes the (Q,Q) decay matrix per chunk
    body = jax.checkpoint(body,
                          policy=jax.checkpoint_policies.nothing_saveable)
    h_final, ys = jax.lax.scan(body, h0, (xc, dtc, Bc, Cc))
    y = ys.swapaxes(0, 1).reshape(Bb, S, H, P)
    y = y + x.astype(jnp.float32) * D_skip[None, None, :, None]
    y = y[:, :S_orig]
    return y.astype(x.dtype), h_final


def _ssd_intra_chunk_jnp(xb, dtb, cum, Bb_, Cb):
    """Intra-chunk quadratic term (the Pallas-kernel oracle).

    xb: (B,Q,H,P); dtb: (B,Q,H); cum: (B,Q,H) fp32 inclusive cumsum of dt*A;
    Bb_/Cb: (B,Q,N).  Returns (B,Q,H,P) fp32.
    """
    Q = xb.shape[1]
    scores = jnp.einsum("bin,bjn->bij", Cb.astype(jnp.float32),
                        Bb_.astype(jnp.float32))                  # (B,Q,Q)
    seg = cum[:, :, None, :] - cum[:, None, :, :]                 # (B,Qi,Qj,H)
    mask = (jnp.arange(Q)[:, None] >= jnp.arange(Q)[None, :])[None, :, :, None]
    L = jnp.where(mask, jnp.exp(seg), 0.0)
    W = scores[:, :, :, None] * L * dtb.astype(jnp.float32)[:, None, :, :]
    return jnp.einsum("bijh,bjhp->bihp", W, xb.astype(jnp.float32))


# ---------------------------------------------------------------------------
# causal depthwise conv
# ---------------------------------------------------------------------------

def causal_conv1d(x, w, b):
    """x: (B,S,C); w: (W,C); b: (C,).  Causal depthwise conv + silu.
    Runs in fp32 regardless of activation dtype."""
    W = w.shape[0]
    pad = jnp.pad(x.astype(jnp.float32), ((0, 0), (W - 1, 0), (0, 0)))
    out = jax.lax.conv_general_dilated(
        pad, w.astype(jnp.float32)[:, None, :],
        window_strides=(1,), padding="VALID",
        dimension_numbers=("NWC", "WIO", "NWC"),
        feature_group_count=x.shape[-1])
    return jax.nn.silu(out + b.astype(jnp.float32)[None, None, :])


def conv_step(conv_state, x_new, w, b):
    """One decode step.  conv_state: (B,W-1,C); x_new: (B,C)."""
    window = jnp.concatenate([conv_state, x_new[:, None, :]], axis=1)  # (B,W,C)
    y = jnp.einsum("bwc,wc->bc", window, w) + b[None, :]
    return jax.nn.silu(y), window[:, 1:, :]


# ---------------------------------------------------------------------------
# full layer: train + decode
# ---------------------------------------------------------------------------

def _split_proj(proj, cfg: ModelConfig):
    di, N, H = cfg.ssm_d_inner, cfg.ssm_state, cfg.ssm_heads
    z = proj[..., :di]
    xBC = proj[..., di:2 * di + 2 * N]
    dt_raw = proj[..., 2 * di + 2 * N:]
    return z, xBC, dt_raw


def mamba2_layer(params, x, cfg: ModelConfig, mesh, impl: str = "jax"):
    """Training/prefill forward.  x: (B,S,d_model).  Returns (y, h_final,
    conv_tail) so prefill can seed decode state."""
    B, S, d = x.shape
    di, N, H, P = cfg.ssm_d_inner, cfg.ssm_state, cfg.ssm_heads, cfg.ssm_head_dim
    h = rms_norm(x, params["norm"].astype(jnp.float32), cfg.norm_eps)
    proj = h @ params["w_in"].astype(h.dtype)
    proj = constrain(proj, mesh, "batch", None, "act_ffn")
    z, xBC, dt_raw = _split_proj(proj, cfg)
    xBC = causal_conv1d(xBC, params["conv_w"].astype(jnp.float32),
                        params["conv_b"].astype(jnp.float32)).astype(h.dtype)
    xs = xBC[..., :di].reshape(B, S, H, P)
    B_mat = xBC[..., di:di + N]
    C_mat = xBC[..., di + N:]
    dt = jax.nn.softplus(dt_raw.astype(jnp.float32)
                         + params["dt_bias"][None, None, :])
    A = -jnp.exp(params["A_log"].astype(jnp.float32))
    xs = constrain(xs, mesh, "batch", None, "act_heads", None)
    y, h_final = ssd_chunked(xs, dt, A, B_mat, C_mat,
                             params["D"].astype(jnp.float32),
                             cfg.ssm_chunk, impl)
    y = y.reshape(B, S, di)
    y = rms_norm(y * jax.nn.silu(z.astype(jnp.float32)).astype(y.dtype),
                 params["gate_norm"].astype(jnp.float32), cfg.norm_eps)
    out = y @ params["w_out"].astype(y.dtype)
    conv_tail = xBC_tail(x, params, cfg)  # last W-1 pre-conv channels
    return x + out, h_final, conv_tail


def xBC_tail(x, params, cfg: ModelConfig):
    """Recompute the last (W-1) pre-conv activations to seed decode."""
    W = cfg.ssm_conv_width
    h = rms_norm(x[:, -(W - 1):, :], params["norm"].astype(jnp.float32),
                 cfg.norm_eps)
    proj = h @ params["w_in"].astype(h.dtype)
    _, xBC, _ = _split_proj(proj, cfg)
    return xBC.astype(jnp.float32)


def mamba2_decode_step(params, x, conv_state, ssm_state, cfg: ModelConfig, mesh):
    """One-token decode.  x: (B,d_model); conv_state: (B,W-1,di+2N);
    ssm_state: (B,H,P,N) fp32.  Returns (y, conv_state', ssm_state')."""
    B, d = x.shape
    di, N, H, P = cfg.ssm_d_inner, cfg.ssm_state, cfg.ssm_heads, cfg.ssm_head_dim
    h = rms_norm(x, params["norm"].astype(jnp.float32), cfg.norm_eps)
    proj = h @ params["w_in"].astype(h.dtype)
    z, xBC_new, dt_raw = _split_proj(proj, cfg)
    xBC, conv_state = conv_step(conv_state, xBC_new.astype(jnp.float32),
                                params["conv_w"].astype(jnp.float32),
                                params["conv_b"].astype(jnp.float32))
    xt = xBC[..., :di].reshape(B, H, P)
    B_t = xBC[..., di:di + N]
    C_t = xBC[..., di + N:]
    dt = jax.nn.softplus(dt_raw.astype(jnp.float32) + params["dt_bias"][None, :])
    A = -jnp.exp(params["A_log"].astype(jnp.float32))
    decay = jnp.exp(dt * A[None, :])                                 # (B,H)
    dbx = jnp.einsum("bhp,bn,bh->bhpn", xt, B_t, dt)
    ssm_state = ssm_state * decay[..., None, None] + dbx
    y = jnp.einsum("bhpn,bn->bhp", ssm_state, C_t)
    y = y + xt * params["D"].astype(jnp.float32)[None, :, None]
    y = y.reshape(B, di)
    y = rms_norm(y * jax.nn.silu(z.astype(jnp.float32)),
                 params["gate_norm"].astype(jnp.float32), cfg.norm_eps)
    out = y.astype(x.dtype) @ params["w_out"].astype(x.dtype)
    return x + out, conv_state, ssm_state
