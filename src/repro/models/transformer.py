"""Decoder transformer stack: GQA attention blocks, scan-over-layers, remat.

One code path serves the dense / moe / vlm families; hybrid and encdec reuse
the same attention block.  Layers are scanned (params stacked on a leading
``layers`` axis) so HLO size — and hence 512-device dry-run compile time —
is O(1) in depth.
"""
from __future__ import annotations

import functools
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import moe as moe_mod
from repro.models.attention import MaskInfo, attention_train, flash_attention
from repro.models.common import (
    Param, apply_rope, dense_init, init_mlp, rms_norm, swiglu_mlp, zeros_init,
)
from repro.models.paged import paged_attend_append
from repro.sharding import attn_strategy, constrain


# ---------------------------------------------------------------------------
# init
# ---------------------------------------------------------------------------

def init_attn(key, cfg: ModelConfig) -> Dict[str, Param]:
    d = cfg.d_model
    k1, k2, k3, k4 = jax.random.split(key, 4)
    p = {
        "wq": dense_init(k1, d, cfg.q_dim, ("embed", "qkv")),
        "wk": dense_init(k2, d, cfg.kv_dim, ("embed", "qkv")),
        "wv": dense_init(k3, d, cfg.kv_dim, ("embed", "qkv")),
        "wo": dense_init(k4, cfg.q_dim, d, ("qkv", "embed")),
    }
    if cfg.qkv_bias:
        p["bq"] = zeros_init((cfg.q_dim,), ("qkv",))
        p["bk"] = zeros_init((cfg.kv_dim,), ("qkv",))
        p["bv"] = zeros_init((cfg.kv_dim,), ("qkv",))
    return p


def init_decoder_layer(key, cfg: ModelConfig, cross: bool = False):
    ks = jax.random.split(key, 4)
    p = {
        "ln1": zeros_init((cfg.d_model,), ("norm",)),
        "attn": init_attn(ks[0], cfg),
        "ln2": zeros_init((cfg.d_model,), ("norm",)),
    }
    if cfg.family == "moe":
        p["moe"] = moe_mod.init_moe_ffn(ks[1], cfg)
    else:
        p["mlp"] = init_mlp(ks[1], cfg.d_model, cfg.d_ff)
    if cross:
        p["ln_x"] = zeros_init((cfg.d_model,), ("norm",))
        p["xattn"] = init_attn(ks[2], cfg)
    return p


# ---------------------------------------------------------------------------
# qkv projection helpers
# ---------------------------------------------------------------------------

def _qkv(p, h, cfg: ModelConfig):
    dtype = h.dtype
    q = h @ p["wq"].astype(dtype)
    k = h @ p["wk"].astype(dtype)
    v = h @ p["wv"].astype(dtype)
    if cfg.qkv_bias:
        q = q + p["bq"].astype(dtype)
        k = k + p["bk"].astype(dtype)
        v = v + p["bv"].astype(dtype)
    return q, k, v


def _heads(x, n, d):
    return x.reshape(x.shape[:-1] + (n, d))


# ---------------------------------------------------------------------------
# train / prefill layer
# ---------------------------------------------------------------------------

def attn_block_train(p, x, pos, cfg: ModelConfig, mesh, info: MaskInfo,
                     strategy: str, return_kv: bool = False):
    B, S, d = x.shape
    h = rms_norm(x, p["ln1"].astype(jnp.float32), cfg.norm_eps)
    q, k, v = _qkv(p["attn"], h, cfg)
    q = _heads(q, cfg.num_heads, cfg.head_dim)
    k = _heads(k, cfg.num_kv_heads, cfg.head_dim)
    v = _heads(v, cfg.num_kv_heads, cfg.head_dim)
    q = apply_rope(q, pos, cfg.rope_theta)
    k = apply_rope(k, pos, cfg.rope_theta)
    o = attention_train(q, k, v, pos, info, mesh, strategy)
    o = o.reshape(B, S, cfg.q_dim) @ p["attn"]["wo"].astype(x.dtype)
    x = x + o
    return (x, (k, v)) if return_kv else (x, None)


def cross_block_train(p, x, enc_out, cfg: ModelConfig, mesh,
                      return_kv: bool = False):
    """Cross-attention (decoder → encoder output). No RoPE, full mask."""
    B, S, d = x.shape
    h = rms_norm(x, p["ln_x"].astype(jnp.float32), cfg.norm_eps)
    dtype = h.dtype
    q = _heads(h @ p["xattn"]["wq"].astype(dtype), cfg.num_heads, cfg.head_dim)
    k = _heads(enc_out @ p["xattn"]["wk"].astype(dtype),
               cfg.num_kv_heads, cfg.head_dim)
    v = _heads(enc_out @ p["xattn"]["wv"].astype(dtype),
               cfg.num_kv_heads, cfg.head_dim)
    S_src = enc_out.shape[1]
    pos_q = jnp.zeros((B, S), jnp.int32)
    pos_kv = jnp.zeros((B, S_src), jnp.int32)
    o = flash_attention(q, k, v, pos_q, pos_kv,
                        jnp.ones((B, S_src), bool), MaskInfo(causal=False))
    o = o.reshape(B, S, cfg.q_dim) @ p["xattn"]["wo"].astype(x.dtype)
    x = x + o
    return (x, (k, v)) if return_kv else (x, None)


def ffn_block_train(p, x, cfg: ModelConfig, mesh):
    h = rms_norm(x, p["ln2"].astype(jnp.float32), cfg.norm_eps)
    if cfg.family == "moe":
        y, aux = moe_mod.moe_ffn(p["moe"], h, cfg, mesh)
    else:
        y = swiglu_mlp(h, p["mlp"]["w_gate"], p["mlp"]["w_up"],
                       p["mlp"]["w_down"], mesh)
        aux = jnp.float32(0)
    return x + y, aux


def decoder_layer_train(p, x, pos, cfg: ModelConfig, mesh, info: MaskInfo,
                        strategy: str, enc_out=None, return_kv: bool = False):
    x = constrain(x, mesh, "batch", "act_seq_tp", None)
    x, kv = attn_block_train(p, x, pos, cfg, mesh, info, strategy, return_kv)
    xkv = None
    if enc_out is not None:
        x, xkv = cross_block_train(p, x, enc_out, cfg, mesh, return_kv)
    x, aux = ffn_block_train(p, x, cfg, mesh)
    x = constrain(x, mesh, "batch", "act_seq_tp", None)
    return x, aux, kv, xkv


REMAT_POLICIES = {
    "none": None,
    "minimal": jax.checkpoint_policies.nothing_saveable,
    "dots": jax.checkpoint_policies.checkpoint_dots_with_no_batch_dims,
}


def decoder_stack_train(stacked, x, pos, cfg: ModelConfig, mesh,
                        info: MaskInfo, enc_out=None,
                        remat: str = "minimal", return_kv: bool = False,
                        num_layers: Optional[int] = None):
    """Scan the layer stack.  stacked: params with leading layer axis.

    Returns (x, aux_sum, kv_stack|None, xkv_stack|None).
    """
    strategy = attn_strategy(cfg.num_heads, mesh) if mesh is not None else "heads"

    def body(carry, layer_params):
        h, aux = carry
        h, a, kv, xkv = decoder_layer_train(
            layer_params, h, pos, cfg, mesh, info, strategy, enc_out,
            return_kv)
        ys = (kv, xkv) if return_kv else None
        return (h, aux + a), ys

    policy = REMAT_POLICIES.get(remat)
    if remat != "none":
        body = jax.checkpoint(body, policy=policy)
    (x, aux), ys = jax.lax.scan(body, (x, jnp.float32(0)), stacked,
                                length=num_layers)
    kv = ys[0] if return_kv else None
    xkv = ys[1] if return_kv else None
    return x, aux, kv, xkv


# ---------------------------------------------------------------------------
# decode layer (single token, paged KV)
# ---------------------------------------------------------------------------

def decoder_layer_decode(p, x, pos, pools, table_ids, offsets, share_mask, base,
                         seq_lens_incl, cfg: ModelConfig, mesh,
                         cross_kv=None, impl: str = "ref",
                         exclusive: bool = False):
    """x: (B, d); pools: (k_pool, v_pool) for THIS layer; pos: (B,).

    Returns (x', (k_pool', v_pool'), aux).
    """
    B, d = x.shape
    k_pool, v_pool = pools
    h = rms_norm(x, p["ln1"].astype(jnp.float32), cfg.norm_eps)
    q, k, v = _qkv(p["attn"], h[:, None, :], cfg)   # (B,1,*)
    q = apply_rope(_heads(q, cfg.num_heads, cfg.head_dim),
                   pos[:, None], cfg.rope_theta)[:, 0]
    k = apply_rope(_heads(k, cfg.num_kv_heads, cfg.head_dim),
                   pos[:, None], cfg.rope_theta)[:, 0]
    v = _heads(v, cfg.num_kv_heads, cfg.head_dim)[:, 0]
    o, k_pool, v_pool = paged_attend_append(
        mesh, q, k, v, k_pool, v_pool, table_ids, offsets, share_mask, base,
        seq_lens_incl, impl=impl, exclusive=exclusive)
    x = x + o.reshape(B, cfg.q_dim) @ p["attn"]["wo"].astype(x.dtype)

    if cross_kv is not None:
        xk, xv = cross_kv                            # (B,Ssrc,KVH,D)
        hx = rms_norm(x, p["ln_x"].astype(jnp.float32), cfg.norm_eps)
        qx = _heads(hx[:, None, :] @ p["xattn"]["wq"].astype(x.dtype),
                    cfg.num_heads, cfg.head_dim)
        S_src = xk.shape[1]
        ox = flash_attention(qx, xk, xv, jnp.zeros((B, 1), jnp.int32),
                             jnp.zeros((B, S_src), jnp.int32),
                             jnp.ones((B, S_src), bool), MaskInfo(causal=False))
        x = x + ox.reshape(B, cfg.q_dim) @ p["xattn"]["wo"].astype(x.dtype)

    h2 = rms_norm(x, p["ln2"].astype(jnp.float32), cfg.norm_eps)
    if cfg.family == "moe":
        y, aux = moe_mod.moe_ffn(p["moe"], h2[:, None, :], cfg, mesh)
        y = y[:, 0]
    else:
        y = swiglu_mlp(h2[:, None, :], p["mlp"]["w_gate"], p["mlp"]["w_up"],
                       p["mlp"]["w_down"], mesh)[:, 0]
        aux = jnp.float32(0)
    return x + y, (k_pool, v_pool), aux


def decoder_stack_decode(stacked, x, pos, k_pools, v_pools, table_ids,
                         offsets, share_mask, base, seq_lens_incl,
                         cfg: ModelConfig, mesh, cross_kvs=None,
                         impl: str = "ref", exclusive: bool = False):
    """Scan decode over layers; pools are scan xs/ys (updated in place at the
    XLA level via donation).  k_pools/v_pools: (L, nblk, page, KVH, D)."""

    def body(carry, inp):
        h = carry
        if cross_kvs is not None:
            lp, kp, vp, xkv = inp
        else:
            lp, kp, vp = inp
            xkv = None
        h, (kp, vp), _ = decoder_layer_decode(
            lp, h, pos, (kp, vp), table_ids, offsets, share_mask, base,
            seq_lens_incl, cfg, mesh, cross_kv=xkv, impl=impl,
            exclusive=exclusive)
        return h, (kp, vp)

    xs = (stacked, k_pools, v_pools)
    if cross_kvs is not None:
        xs = xs + (cross_kvs,)
    x, (k_pools, v_pools) = jax.lax.scan(body, x, xs)
    return x, k_pools, v_pools
