from repro.checkpoint.manager import CheckpointManager
from repro.checkpoint.pool_checkpoint import PoolCheckpoint
