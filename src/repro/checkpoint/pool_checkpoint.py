"""Incremental KV-pool checkpoints riding a background command stream.

RowClone §3.1 frames process checkpointing as a bulk-copy workload: the
bytes to persist are copied *inside memory* first, so the running process
never stops for the slow half (host I/O).  :class:`PoolCheckpoint` is
that shape for the serving engine's KV pools:

* Each :meth:`step` call copies the next **window** of primary-pool
  blocks into a small ``spill`` pool (``PoolSpec(role="spill")`` — the
  checkpoint destination, reachable only through cross-pool commands) as
  ordinary ``OP_CROSS_POOL_COPY`` traffic on a dedicated ``"ckpt"``
  :class:`~repro.core.stream.CommandStream`.  The copies ride the fused
  dispatch path like any other bulk movement — one launch per window.
* The window copied at step *N* is harvested to a host mirror at step
  *N+1* (FlushTicket pipelining: the device copy overlaps the decode
  rounds in between).  Tickets are **write-scoped** (``FlushTicket.wait``
  blocks on the pools the flush touched — here, the spill pools only),
  so harvesting never serializes against the decode path's donated
  primary buffers.
* When the cursor completes a full pass over the pool, the assembled
  mirror persists through the :class:`~repro.checkpoint.manager.
  CheckpointManager` (atomic tmp→rename, background thread) as one
  restorable :class:`~repro.core.journal.PoolSnapshot`.

Consistency: a pass assembled while decode keeps mutating the pools is a
*fuzzy* snapshot — blocks were captured at different flush indices.  The
serving recovery path (launch/serve.py) therefore uses these snapshots
only to restore DEAD pools and reproduces in-flight sequences by
eviction + re-admission; the bitwise snapshot+replay contract
(core/journal.py) applies when the pass ran quiesced.  The snapshot's
``index`` is stamped with the ckpt flush index of the pass's last
window.
"""
from __future__ import annotations

from typing import Dict, Optional

import numpy as np

from repro.checkpoint.manager import CheckpointManager
from repro.core.journal import PoolSnapshot
from repro.core.poolspec import BlockRef


class PoolCheckpoint:
    """Windowed, stream-backed checkpointing of an engine's primary pools.

    ``engine`` must carry at least one ``spill``-role pool (its
    ``paired`` primary is what gets checkpointed; serving builds them via
    ``make_serving_pools(ckpt_nblk=...)``).  ``window`` bounds blocks
    copied per step (default: the spill pool's capacity).  Drive it with
    one :meth:`step` per decode round; call :meth:`latest` at recovery
    time and :meth:`reset` after a recovery invalidated in-flight
    state."""

    def __init__(self, engine, manager: CheckpointManager,
                 window: Optional[int] = None):
        spill = {spec.paired: spec.name for spec in engine.group
                 if spec.role == "spill"}
        if not spill:
            raise ValueError(
                "PoolCheckpoint needs spill pools (PoolSpec(role='spill') "
                "paired with the primaries to checkpoint); serving builds "
                "them with make_serving_pools(ckpt_nblk=...)")
        self.engine = engine
        self.manager = manager
        self.spill: Dict[str, str] = spill   # primary name -> spill name
        self.nblk = engine.num_blocks
        cap = min(engine.group[s].nblk for s in spill.values())
        self.window = min(int(window), cap) if window else cap
        #: the background checkpoint stream — its flushes are ordinary
        #: engine drains (journaled, hazard-tracked, fused)
        self.stream = engine.stream("ckpt")
        self._cursor = 0
        self._passes = 0          # completed full passes (= save steps)
        self._inflight = None     # (ticket, start, count)
        self._pass_index = -1     # last harvested ckpt flush index
        self._mirror: Dict[str, np.ndarray] = {
            name: np.zeros(*engine._pool_layouts[name][:2])
            for name in spill}

    # ------------------------------------------------------------------
    @property
    def passes(self) -> int:
        """Completed full passes over the pools (one save each)."""
        return self._passes

    def _harvest(self) -> None:
        """Pull the previous window's spill bytes into the host mirror."""
        if self._inflight is None:
            return
        ticket, start, w = self._inflight
        self._inflight = None
        try:
            # write-scoped wait: blocks on the SPILL pools only, so a
            # decode step that donated the primaries in between does not
            # expire this ticket
            ticket.wait()
        except RuntimeError:
            # a later flush donated the spill buffers too (pool-churn
            # rounds re-launch the fused drain over every pool); the
            # bytes were carried forward — np.asarray below synchronizes
            pass
        ba = self.engine.block_axis
        for pname, sname in self.spill.items():
            spill_arr = np.asarray(self.engine.pools[sname])
            got = spill_arr[:w] if ba == 0 else spill_arr[:, :w]
            if ba == 0:
                self._mirror[pname][start:start + w] = got
            else:
                self._mirror[pname][:, start:start + w] = got
        self._pass_index = ticket.index

    def _save_pass(self) -> None:
        self.manager.save(self._passes, {
            "index": np.asarray(self._pass_index, np.int64),
            "pools": {k: v.copy() for k, v in self._mirror.items()}})
        self._passes += 1
        self._cursor = 0

    def step(self) -> Optional[object]:
        """One checkpoint tick: harvest the in-flight window, persist the
        pass if it just completed, enqueue + flush the next window on the
        ckpt stream.  Returns the window's
        :class:`~repro.core.stream.FlushTicket` (None when the engine has
        no blocks to copy this tick)."""
        self._harvest()
        if self._cursor >= self.nblk:
            self._save_pass()
        start = self._cursor
        w = min(self.window, self.nblk - start)
        if w <= 0:
            return None
        pairs = [(BlockRef(pname, start + j), BlockRef(sname, j))
                 for pname, sname in self.spill.items()
                 for j in range(w)]
        self.stream.memcopy_cross(pairs)
        ticket = self.stream.flush()
        self._inflight = (ticket, start, w)
        self._cursor = start + w
        return ticket

    def drain(self) -> None:
        """Finish the current pass synchronously (harvest + copy the
        remaining windows + persist) — the quiesced, exact-snapshot path
        used by tests and orderly shutdown."""
        while self._cursor < self.nblk:
            self.step()
        self._harvest()
        self._save_pass()
        self.manager.wait()

    # ------------------------------------------------------------------
    def latest(self) -> Optional[PoolSnapshot]:
        """Most recent persisted pass as a
        :class:`~repro.core.journal.PoolSnapshot` (None before the first
        full pass).  Covers the checkpointed primaries only — recovery
        resurrects staging/spill pools as zeros and re-admits."""
        self.manager.wait()
        step = self.manager.latest_step()
        if step is None:
            return None
        example = {
            "index": np.asarray(0, np.int64),
            "pools": {name: np.zeros(*self.engine._pool_layouts[name][:2])
                      for name in self.spill}}
        tree, _ = self.manager.restore(example, step)
        return PoolSnapshot(index=int(tree["index"]),
                            arrays=dict(tree["pools"]))

    def reset(self) -> None:
        """Drop in-flight window state after a recovery (the spill pools
        may have been resurrected; the interrupted pass restarts from
        block 0).  Persisted passes are untouched."""
        self._inflight = None
        self._cursor = 0


__all__ = ["PoolCheckpoint"]
