"""Async, versioned, atomic checkpointing with CoW snapshot semantics.

RowClone connection (§3.1 process checkpointing): a checkpoint is a CoW
snapshot — mark pages read-only, copy lazily.  JAX arrays are immutable, so
the snapshot *is* the pytree of array handles: taking it costs zero bytes
(the in-cache-copy analogue); a background thread then streams device→host
→disk while the donated training step writes fresh buffers.  The training
loop never blocks on I/O.

Durability protocol: write to ``step_N.tmp/`` then ``os.replace`` to
``step_N/`` (atomic on POSIX); a ``manifest.json`` carries tree structure +
shapes; ``latest`` is resolved by scanning complete directories, so a crash
mid-write can never yield a half checkpoint.
"""
from __future__ import annotations

import json
import os
import shutil
import threading
import time
from typing import Any, Dict, List, Optional, Tuple

import jax
import numpy as np


def _flatten(tree) -> Tuple[Dict[str, np.ndarray], Any]:
    flat, tdef = jax.tree_util.tree_flatten(tree)
    keys = [f"a{i}" for i in range(len(flat))]
    return dict(zip(keys, flat)), tdef


class CheckpointManager:
    def __init__(self, directory: str, keep: int = 3, async_save: bool = True):
        self.dir = directory
        self.keep = keep
        self.async_save = async_save
        os.makedirs(directory, exist_ok=True)
        self._thread: Optional[threading.Thread] = None
        self._error: Optional[BaseException] = None

    # ------------------------------------------------------------------
    def save(self, step: int, state, blocking: bool = False) -> None:
        """Snapshot ``state`` (pytree of jax/np arrays) at ``step``.

        The training loop donates its state buffers into the next step, so
        the snapshot takes a *device-side copy* first (on TPU this is an
        HBM→HBM DMA — the FPM-style row copy; it never blocks on host I/O).
        The disk write then runs on a background thread.
        """
        self.wait()  # one in-flight save at a time
        flat, tdef = _flatten(state)
        flat = {k: (v.copy() if isinstance(v, jax.Array) else np.asarray(v))
                for k, v in flat.items()}
        treedef_repr = jax.tree_util.tree_structure(state)
        if self.async_save and not blocking:
            self._thread = threading.Thread(
                target=self._write, args=(step, flat, str(treedef_repr)),
                daemon=True)
            self._thread.start()
        else:
            self._write(step, flat, str(treedef_repr))

    def _write(self, step: int, flat: Dict[str, Any], treedef: str) -> None:
        try:
            tmp = os.path.join(self.dir, f"step_{step}.tmp")
            final = os.path.join(self.dir, f"step_{step}")
            if os.path.exists(tmp):
                shutil.rmtree(tmp)
            os.makedirs(tmp)
            host = {k: np.asarray(v) for k, v in flat.items()}
            np.savez(os.path.join(tmp, "arrays.npz"), **host)
            manifest = {
                "step": step,
                "time": time.time(),  # rowlint: disable=RC105 (manifest time-of-day stamp)
                "keys": sorted(host),
                "shapes": {k: list(v.shape) for k, v in host.items()},
                "dtypes": {k: str(v.dtype) for k, v in host.items()},
            }
            with open(os.path.join(tmp, "manifest.json"), "w") as f:
                json.dump(manifest, f)
            if os.path.exists(final):
                shutil.rmtree(final)
            os.replace(tmp, final)
            self._gc()
        except BaseException as e:  # surfaced on next wait()
            self._error = e

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        if self._error is not None:
            err, self._error = self._error, None
            raise err

    # ------------------------------------------------------------------
    def steps(self) -> List[int]:
        out = []
        for name in os.listdir(self.dir):
            if name.startswith("step_") and not name.endswith(".tmp"):
                path = os.path.join(self.dir, name, "manifest.json")
                if os.path.exists(path):
                    out.append(int(name.split("_")[1]))
        return sorted(out)

    def latest_step(self) -> Optional[int]:
        s = self.steps()
        return s[-1] if s else None

    def restore(self, example_state, step: Optional[int] = None,
                shardings=None):
        """Rebuild the pytree; ``example_state`` provides the structure.
        ``shardings``: optional matching pytree of NamedShardings for
        device placement (elastic restore passes the NEW mesh's)."""
        self.wait()
        step = self.latest_step() if step is None else step
        if step is None:
            raise FileNotFoundError(f"no checkpoints in {self.dir}")
        path = os.path.join(self.dir, f"step_{step}", "arrays.npz")
        data = np.load(path)
        flat, tdef = _flatten(example_state)
        loaded = [data[k] for k in (f"a{i}" for i in range(len(flat)))]
        tree = jax.tree_util.tree_unflatten(
            jax.tree_util.tree_structure(example_state), loaded)
        if shardings is not None:
            tree = jax.tree_util.tree_map(
                lambda x, s: jax.device_put(x, s), tree, shardings)
        return tree, step

    def _gc(self) -> None:
        steps = self.steps()
        for s in steps[: -self.keep]:
            shutil.rmtree(os.path.join(self.dir, f"step_{s}"),
                          ignore_errors=True)
