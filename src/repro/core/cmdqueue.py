"""CommandQueue — the memory-controller command buffer for bulk movement.

Paper §2.3: software issues ``memcopy``/``meminit``; the *memory controller*
serializes the commands and drains them inside DRAM with no per-command CPU
involvement.  The seed engine inverted that: every request batch ran
host-side partitioning and then one device dispatch per mechanism per pool.
This queue restores the paper's shape:

* callers **enqueue** tagged commands (``OP_FPM_COPY``, ``OP_PSM_COPY``,
  ``OP_BASELINE_COPY``, ``OP_ZERO_INIT``, ``OP_CROSS_POOL_COPY`` — see
  kernels/fused_dispatch.py for the opcode table);
* the device sees work only at **flush** boundaries (an attention step, a
  benchmark tick, or an explicit ``flush()``) — one fused kernel launch per
  flushed table, every pool moved in the same launch.

Padding is **power-of-two bucketed** (8/32/128/512): a 3-command flush pads
to 8, not to the seed's fixed 256, so small batches stop paying full-length
gathers while the jit cache stays bounded (4 table shapes per pool
structure).  Tables longer than the largest bucket are drained in overflow
chunks instead of raising.

Hazard guards (the MC's ordering rules) track BOTH sides of every pending
command — sources and destinations, keyed as ``(pool, block)`` pairs
(plain opcodes touch the block in every *primary* pool; an
``OP_CROSS_POOL_COPY`` names one pool on each side, so a staging→KV
promotion of block ``d`` and a later staging write of the same numeric
block id in a *different* pool never falsely serialize).  The full hazard
matrix:

* **RAW** — a command *reading* a pending destination: auto-flush (the
  gather-then-scatter reference would see stale bytes otherwise).
* **WAW** — a command *rewriting* a pending destination: auto-flush (two
  writes to one block in a table have order-dependent results).
* **WAR** — a command *overwriting* a pending SOURCE: stays in the table
  (every drain path reads sources before the later write lands), only
  counted in ``stats.war_hazards``.  What it costs instead is adjacency:
  the fused kernel's overlapped DMA drain keeps the previous step's copy
  in flight while the current step issues, so :func:`space_war_rows`
  inserts an ``OP_NOP`` spacer between the two rows at flush time — the
  spacer step's trailing wait retires the read before the write starts.

Two-source bitwise rows (``OP_AND``/``OP_OR``/``OP_NOT`` — src packs BOTH
global source ids as ``a * group.total_blocks + b``) apply the same matrix
to EITHER source: RAW/WAW on srcA *or* srcB auto-flush, WAR on either
source is admitted + counted + spaced, and ``retire``/journal replay
rebuild both pending-source entries.

Invariant for writers of new opcodes: every command must name its written
block in ``dst`` (and its read block in ``src`` — global
``group.base(pool) + block`` ids for cross-pool ops, see
core/poolspec.py) so the hazard keys here, the WAR spacing, and
:func:`partition_commands` see every read and write.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Iterable, List, Optional, Sequence, Set, Tuple

import numpy as np

from repro.core.opcodes import (ALL_PRIMARY, BITWISE_OPS, OP_AND,
                                OP_BASELINE_COPY, OP_CROSS_POOL_COPY,
                                OP_FPM_COPY, OP_NOP, OP_NOT, OP_OR,
                                OP_PSM_COPY, OP_ZERO_INIT, OPCODE_NAMES,
                                keys_clash, opspec, pack_bitwise_src,
                                row_rw, unpack_bitwise_src)
from repro.core.poolspec import PoolGroup
from repro.obs import metrics as obs_metrics

#: the hand-picked bucket set (what :func:`set_buckets` restores on None)
DEFAULT_BUCKETS: Tuple[int, ...] = (8, 32, 128, 512)

#: padding buckets — the only command-table lengths ever jit-compiled.
#: Module-global so a tuned profile can retarget it process-wide
#: (:func:`set_buckets`); read through :func:`get_buckets`/
#: :func:`top_bucket` rather than a from-import, which would freeze the
#: import-time value.
BUCKETS: Tuple[int, ...] = DEFAULT_BUCKETS


def set_buckets(buckets: Optional[Sequence[int]]) -> Tuple[int, ...]:
    """Retarget the process-wide bucket set (``None`` restores
    :data:`DEFAULT_BUCKETS`).  The autotuner's knob: buckets must be
    strictly increasing positive ints; every later flush pads to the new
    set (pool bytes are unaffected — padding rows are ``OP_NOP``).
    Returns the installed tuple."""
    global BUCKETS
    if buckets is None:
        BUCKETS = DEFAULT_BUCKETS
        return BUCKETS
    bs = tuple(int(b) for b in buckets)
    if not bs or any(b <= 0 for b in bs) or list(bs) != sorted(set(bs)):
        raise ValueError(f"buckets must be strictly increasing positive "
                         f"ints, got {buckets!r}")
    BUCKETS = bs
    return BUCKETS


def get_buckets() -> Tuple[int, ...]:
    """The current process-wide bucket set (see :func:`set_buckets`)."""
    return BUCKETS


def top_bucket() -> int:
    """The largest bucket — the overflow chunk size every drain path
    splits long tables at."""
    return BUCKETS[-1]


def bucket_size(n: int) -> int:
    """Smallest bucket holding ``n`` commands (callers chunk above the top
    bucket)."""
    for b in BUCKETS:
        if n <= b:
            return b
    return BUCKETS[-1]


# hazard-key decode + clash rules live in the core/opcodes.py registry
# (one source of truth shared with the sanitizer and the engine); the
# seed-era private names survive as aliases for in-tree callers
_row_rw = row_rw
_keys_clash = keys_clash


def space_war_rows(rows: Sequence[Tuple[int, int, int]], locate,
                   primary: Tuple[bool, ...], total: Optional[int] = None
                   ) -> List[Tuple[int, int, int]]:
    """Insert ``OP_NOP`` spacer rows so no row writes a ``(pool, block)``
    the IMMEDIATELY preceding row reads.

    The fused kernel's overlapped drain keeps exactly one prior step's
    DMAs in flight while the current step issues (the wait trails one step
    behind), so adjacency is the whole safety contract: RAW/WAW pairs
    never co-exist in a flushed table (the queue guards), and this pass
    breaks up adjacent WAR pairs — at the spacer step nothing issues but
    the trailing wait still retires the in-flight read, so the write that
    follows can never race it.  Applied by :meth:`CommandQueue.flush` to
    the global table and by :func:`partition_commands` to every slab
    sub-table (adjacency is per drained table, not per enqueue order).

    ``total`` is the packed-src address-space size, forwarded to
    :func:`_row_rw` so two-source bitwise rows space on EITHER source."""
    out: List[Tuple[int, int, int]] = []
    prev_reads: Tuple = ()
    for row in rows:
        op, s, d = row
        if op < 0:
            out.append(row)
            prev_reads = ()
            continue
        reads, writes = _row_rw(op, s, d, locate, total)
        if any(_keys_clash(r, w, primary)
               for r in prev_reads for w in writes):
            out.append((OP_NOP, -1, -1))
        out.append(row)
        prev_reads = reads
    return out


@dataclasses.dataclass(frozen=True)
class ShardPlan:
    """A flushed command table, partitioned for one collective sharded drain.

    Produced host-side by :func:`partition_commands`; consumed by the
    sharded fused-dispatch entry (kernels/fused_dispatch.py).  Every shard
    sees the SAME static shapes — sub-tables pad to the max shard occupancy
    (bucketed 8/32/128/512), so the whole flush is one shard_map'd launch.

    Each pool partitions by its **own** shard size (``nblk_p // S`` — the
    per-pool block counts come from the engine's PoolGroup, so a small
    staging ring and a large KV pool split into the same shard count with
    different per-shard slab sizes):

    * ``local_tables`` (S, m, 3) int32 ``[opcode, src, dst]`` rows with
      **slab-local** block ids; ``CROSS_POOL_COPY`` ids re-stack with the
      slab-local prefix-sum bases (``local_base[p] + local``, where
      ``local_base`` runs over ``shard_sizes``) so the per-shard drain
      decodes them from its own slab shapes; ``OP_NOP`` rows pad.
    * The send/recv plan covers every cross-slab command, grouped by hop
      distance ``delta = (dst_shard - src_shard) mod S`` (the LISA-style
      inter-slab link): sender ``i``'s slot ``j`` for a given delta pairs
      with receiver ``(i + delta) mod S``'s slot ``j``.
      - ``send_rows`` (K, S, t): *pool-local* slab row each sender gathers
        (every pool is gathered at that row; the receiver picks the buffer
        that matters; -1 pads).
      - ``recv_tables`` (K, S, t, 4): ``[buf_pool, dst_pool, dst_row,
        combine_op]`` — ``buf_pool``/``dst_pool`` are -1 for whole-block
        copies (each pool scatters its own buffer slot); a cross-pool
        transfer names the source-pool buffer and destination pool;
        ``dst_row`` is pool-local in the destination slab; -1 pads.
        ``combine_op`` orders two-source bitwise rows whose sources are
        not resident on the destination shard: -1 is a plain overwrite
        (phase 0 of the scatter), ``OP_NOT`` overwrites with the inverted
        buffer (phase 0), and ``OP_AND``/``OP_OR`` fold the buffer into
        the already-landed destination block (phase 1) — such a row ships
        one entry per non-resident source (srcA as the overwrite, srcB as
        the combine, hop distance 0 allowed when only one side travels).
    """
    n_shards: int
    shard_sizes: Tuple[int, ...]  # per-pool slab size (nblk_p / S)
    n_local: int                 # commands drained inside their own slab
    n_transfer: int              # commands crossing a slab boundary
    n_spacers: int               # per-slab WAR spacer rows inserted
    local_tables: np.ndarray     # (S, m, 3) int32
    deltas: Tuple[int, ...]      # static ppermute hop distances, sorted
    send_rows: np.ndarray        # (K, S, t) int32
    recv_tables: np.ndarray      # (K, S, t, 4) int32


def partition_commands(rows: Iterable[Tuple[int, int, int]], *,
                       n_shards: int, group: PoolGroup,
                       replicated: Optional[Tuple[bool, ...]] = None
                       ) -> ShardPlan:
    """Split one flushed (hazard-free) command table into per-slab
    sub-tables plus a cross-slab send/recv plan.

    Classification is by **device shard** (``block_id // shard_size``,
    with each pool's own shard size — a staging ring shards into smaller
    slabs than its KV pool), not by the opcode's mechanism tag: an
    ``OP_FPM_COPY`` whose allocator slabs are finer than the device
    sharding may still cross a shard boundary, and an ``OP_PSM_COPY``
    between allocator slabs co-resident on one device drains locally.
    Plain-opcode ids live in the primary address space (every primary pool
    shares one block count); ``OP_CROSS_POOL_COPY`` ids are global
    ``group.base(pool) + block`` and are resolved through ``group``.
    Enqueue order is preserved within each shard's sub-table (each
    sub-table is then WAR-spaced for the overlapped per-shard drain —
    :func:`space_war_rows`); the flush hazard guards make the cross-shard
    interleaving — gather transfer sources, drain local tables, permute
    and scatter — equivalent to the sequential drain.

    ``replicated[p]`` marks pools whose block axis is NOT device-sharded
    (``PoolSpec.sharding == ()`` — e.g. a staging ring held whole on
    every device): their slab is the full pool (``shard_sizes[p] ==
    nblk_p``), a cross-pool read from them is always local to the
    destination's shard, and a replicated→replicated copy lands in EVERY
    shard's sub-table so the replicas stay consistent.  A cross-pool
    WRITE into a replicated pool from a sharded source would need a
    broadcast hop and raises — the engine degrades that flush to the
    legacy fan-out (GSPMD inserts the gather)."""
    if replicated is None:
        replicated = tuple([False] * len(group))
    for i, spec in enumerate(group):
        if replicated[i]:
            if spec.role == "primary":
                raise ValueError(
                    f"primary pool {spec.name!r} cannot be replicated: "
                    "plain opcodes partition by the primary shard size")
            continue
        if spec.nblk % n_shards:
            raise ValueError(f"pool {spec.name!r}: nblk={spec.nblk} not "
                             f"divisible by {n_shards} shards")
    ss = tuple(spec.nblk if replicated[i] else spec.nblk // n_shards
               for i, spec in enumerate(group))
    # slab-local prefix-sum bases: the per-shard stacked address space
    local_base = []
    run = 0
    for s_p in ss:
        local_base.append(run)
        run += s_p
    p0 = group.primary.index(True)  # plain ops address the primary space
    ss0 = ss[p0]
    lt = run                        # slab-local stacked total (bitwise pack)
    local: List[List[Tuple[int, int, int]]] = [[] for _ in range(n_shards)]
    # delta -> per-src-shard slot lists of (src_row, buf_pool, dst_pool,
    # dst_row, combine_op)
    xfer: Dict[int, List[List[Tuple[int, int, int, int, int]]]] = {}
    n_transfer = 0

    def _side(p: int, blk: int, sh_d: int) -> Tuple[int, int, int]:
        """Resolve one source of a bitwise row against the dst shard:
        ``(shard, slab_local_gid, slab_pool_row)`` — replicated pools are
        resident everywhere, so they count as the dst shard."""
        if replicated[p]:
            return sh_d, local_base[p] + blk, blk
        return blk // ss[p], local_base[p] + blk % ss[p], blk % ss[p]

    def _xfer_entry(delta: int, sh_s: int, entry: Tuple[int, int, int,
                                                        int, int]) -> None:
        slots = xfer.setdefault(delta, [[] for _ in range(n_shards)])
        slots[sh_s].append(entry)

    for op, s, d in rows:
        if op < 0:
            continue
        # classification derives from the opcode's registry contract
        # (core/opcodes.py): source-less rows are always slab-local,
        # two-source compute rows split per travelling source, global-id
        # rows resolve through the group, primary-space rows through ss0
        sp = opspec(op)
        if sp.src_kind == "none":
            local[d // ss0].append((op, -1, d % ss0))
            continue
        if sp.is_compute:
            a, b = unpack_bitwise_src(s, group.total_blocks)
            pa, ab = group.locate(a)
            pb, bb = group.locate(b)
            pd, bd = group.locate(d)
            if replicated[pd]:
                if not (replicated[pa] and replicated[pb]):
                    raise ValueError(
                        f"bitwise write into replicated pool "
                        f"{group[pd].name!r} from a sharded source needs "
                        "a broadcast hop (unsupported in the sharded "
                        "drain)")
                row = (op, pack_bitwise_src(local_base[pa] + ab,
                                            local_base[pb] + bb, lt),
                       local_base[pd] + bd)
                for sh in range(n_shards):
                    local[sh].append(row)
                continue
            sh_d = bd // ss[pd]
            ld = bd % ss[pd]
            sh_a, la, ra = _side(pa, ab, sh_d)
            sh_b, lb, rb = _side(pb, bb, sh_d)
            if sh_a == sh_d and sh_b == sh_d:
                local[sh_d].append(
                    (op, pack_bitwise_src(la, lb, lt), local_base[pd] + ld))
                continue
            # a two-source row with any non-resident source ships ONE
            # transfer entry per travelling source: srcA lands first
            # (overwrite / inverted overwrite), srcB folds in during the
            # combine phase — a resident srcA instead becomes a local
            # cross-pool copy (drained before any scatter), a resident
            # srcB a hop-distance-0 combine entry
            if op == OP_NOT:
                _xfer_entry((sh_d - sh_a) % n_shards, sh_a,
                            (ra, pa, pd, ld, OP_NOT))
                n_transfer += 1
                continue
            if sh_a == sh_d:
                local[sh_d].append((OP_CROSS_POOL_COPY, la,
                                    local_base[pd] + ld))
            else:
                _xfer_entry((sh_d - sh_a) % n_shards, sh_a,
                            (ra, pa, pd, ld, -1))
                n_transfer += 1
            _xfer_entry((sh_d - sh_b) % n_shards, sh_b,
                        (rb, pb, pd, ld, op))
            n_transfer += 1
            continue
        if sp.src_kind == "global":
            ps, bs = group.locate(s)
            pd, bd = group.locate(d)
            if replicated[pd]:
                if not replicated[ps]:
                    raise ValueError(
                        f"cross-pool write into replicated pool "
                        f"{group[pd].name!r} from sharded "
                        f"{group[ps].name!r} needs a broadcast hop "
                        "(unsupported in the sharded drain)")
                # replicated→replicated: every shard applies the same
                # copy to its replica
                row = (op, local_base[ps] + bs, local_base[pd] + bd)
                for sh in range(n_shards):
                    local[sh].append(row)
                continue
            if replicated[ps]:
                # replicated source: the bytes are resident on the
                # destination's shard — always a local row there
                local[bd // ss[pd]].append(
                    (op, local_base[ps] + bs,
                     local_base[pd] + bd % ss[pd]))
                continue
            sh_s, sh_d = bs // ss[ps], bd // ss[pd]
            if sh_s == sh_d:
                local[sh_d].append((op, local_base[ps] + bs % ss[ps],
                                    local_base[pd] + bd % ss[pd]))
                continue
            entry = (bs % ss[ps], ps, pd, bd % ss[pd], -1)
        else:
            sh_s, sh_d = s // ss0, d // ss0
            if sh_s == sh_d:
                local[sh_d].append((op, s % ss0, d % ss0))
                continue
            entry = (s % ss0, -1, -1, d % ss0, -1)
        _xfer_entry((sh_d - sh_s) % n_shards, sh_s, entry)
        n_transfer += 1

    n_local = sum(len(l) for l in local)

    # per-slab WAR spacing for the overlapped per-shard kernel drain:
    # adjacency is a property of each drained sub-table, so the spacing
    # re-runs here against the slab-local stacked address space
    def _local_locate(gid: int) -> Tuple[int, int]:
        for i in range(len(ss) - 1, -1, -1):
            if gid >= local_base[i]:
                return i, gid - local_base[i]
        raise AssertionError("unreachable")

    pre_spacing = sum(len(l) for l in local)
    local = [space_war_rows(l, _local_locate, group.primary, lt)
             for l in local]
    n_spacers = sum(len(l) for l in local) - pre_spacing
    longest = max((len(l) for l in local), default=0) or 1
    m = bucket_size(longest)
    while m < longest:   # spacers can push a dense slab past the top
        m *= 2           # bucket; grow by powers of two (rare, still one
    # static shape per flush)
    local_tables = np.full((n_shards, m, 3), OP_NOP, np.int32)
    for sh, cmds in enumerate(local):
        if cmds:
            local_tables[sh, :len(cmds)] = np.asarray(cmds, np.int32)

    deltas = tuple(sorted(xfer))
    t = bucket_size(max((len(per_src)
                         for slots in xfer.values() for per_src in slots),
                        default=0) or 1) if deltas else 0
    send_rows = np.full((len(deltas), n_shards, max(t, 1)), -1, np.int32)
    recv_tables = np.full((len(deltas), n_shards, max(t, 1), 4), -1, np.int32)
    for k, delta in enumerate(deltas):
        for sh_s, entries in enumerate(xfer[delta]):
            sh_d = (sh_s + delta) % n_shards
            for j, (src_row, ps, pd, dst_row, comb) in enumerate(entries):
                send_rows[k, sh_s, j] = src_row
                recv_tables[k, sh_d, j] = (ps, pd, dst_row, comb)
    return ShardPlan(n_shards=n_shards, shard_sizes=ss, n_local=n_local,
                     n_transfer=n_transfer, n_spacers=n_spacers,
                     local_tables=local_tables, deltas=deltas,
                     send_rows=send_rows, recv_tables=recv_tables)


def fold_shard_plan(plan: ShardPlan) -> ShardPlan:
    """Re-express a plan over the FULL delta set ``(1 .. S-1)``.

    Every hop distance gets a (possibly all-padding) send/recv table of
    the plan's existing slot bucket, so the sharded drain's static
    signature collapses to one shape per ``t`` bucket regardless of which
    delta subset a flush actually uses.  The jit-cache bound
    (kernels/fused_dispatch.py) applies this past a threshold of distinct
    ``(deltas, t)`` signatures: adversarial streams churning delta subsets
    stop compiling new collective bodies, at the cost of ``S-2`` extra
    (empty) ppermutes per folded flush."""
    S = plan.n_shards
    # hop distance 0 (a resident srcB folding into a travelled srcA) only
    # exists when a flush used it — fold onto 1..S-1 plus 0 when present
    full = tuple(sorted(set(range(1, S)) | set(plan.deltas)))
    if plan.deltas == full or not plan.deltas:
        return plan
    idx = {delta: k for k, delta in enumerate(full)}
    t = plan.send_rows.shape[2]
    send = np.full((len(full), S, t), -1, np.int32)
    recv = np.full((len(full), S, t, 4), -1, np.int32)
    for k, delta in enumerate(plan.deltas):
        send[idx[delta]] = plan.send_rows[k]
        recv[idx[delta]] = plan.recv_tables[k]
    return dataclasses.replace(plan, deltas=full, send_rows=send,
                               recv_tables=recv)


@dataclasses.dataclass
class QueueStats:
    enqueued: int = 0
    flushes: int = 0           # explicit + boundary flushes that moved work
    hazard_flushes: int = 0    # forced early by a RAW/WAW ordering hazard
    war_hazards: int = 0       # WAR-on-source commands admitted (no flush)
    spacer_rows: int = 0       # OP_NOP spacers inserted for the overlap
    launches: int = 0          # device dispatches issued for flushed tables
    retired: int = 0           # pending rows cancelled pre-flush (retire)
    max_pending: int = 0


class CommandQueue:
    """Accumulates ``(opcode, src, dst)`` commands for a RowCloneEngine and
    drains them through the engine's fused dispatch at flush time.

    One engine may own several queues — every
    :class:`~repro.core.stream.CommandStream` wraps its own — and the
    queue tracks BOTH pending sources and pending destinations, so the
    engine can serialize cross-stream overlap and reason about in-flight
    reads (e.g. staging-ring slot lifetime) without draining everything.
    """

    #: pool index standing for "every primary pool" in a hazard key (plain
    #: opcodes move the block in all primary pools at once)
    ALL_PRIMARY = ALL_PRIMARY

    def __init__(self, engine):
        self.engine = engine
        self.stats = QueueStats()
        #: display name for journal records (CommandStream sets its own)
        self.name = "anon"
        self._cmds: List[Tuple[int, int, int]] = []
        # pending destination writes / source reads: block id -> set of
        # pool indices (ALL_PRIMARY = the block in every primary pool)
        self._pending_dsts: Dict[int, Set[int]] = {}
        self._pending_srcs: Dict[int, Set[int]] = {}
        # wall-clock of the oldest pending row (queue-residency metric);
        # None while empty — armed on first enqueue, popped by the drain
        self._first_enqueue_t: Optional[float] = None

    def pop_residency_us(self) -> float:
        """Microseconds the OLDEST pending row sat queued (0.0 when the
        residency clock is unarmed) — read-and-reset, called once per
        drain so ``FlushTicket.timing.queue_residency_us`` measures
        first-enqueue -> flush for each flush independently."""
        t0, self._first_enqueue_t = self._first_enqueue_t, None
        return 0.0 if t0 is None else (obs_metrics.now() - t0) * 1e6

    def __len__(self) -> int:
        return len(self._cmds)

    @property
    def pending(self) -> List[Tuple[int, int, int]]:
        """Copy of the not-yet-flushed ``(opcode, src, dst)`` rows."""
        return list(self._cmds)

    # ------------------------------------------------------------------
    def _hazard_keys(self, opcode: int, src: int, dst: int) -> Tuple[
            Tuple[Tuple[int, int], ...], Tuple[int, int]]:
        """``(source_keys, dst_key)`` — the ``(pool, block)`` keys used for
        ordering hazards, the same read/write mapping :func:`_row_rw`
        gives the WAR spacing pass (one source of truth for what a row
        touches).  ``source_keys`` is a tuple because two-source bitwise
        rows (``OP_AND``/``OP_OR``) read two blocks: every hazard rule
        applies to EITHER source.

        Plain opcodes (FPM/PSM/baseline copy, zero-init) read and write the
        block in EVERY primary pool → pool key :data:`ALL_PRIMARY`.
        ``OP_CROSS_POOL_COPY`` and the bitwise opcodes carry global
        ``group.base(pool) + block`` ids resolved through the engine's
        PoolGroup, so their keys name the exact (pool index, local block)
        touched — a staging→KV promotion of block ``d`` does not serialize
        against an unrelated command on the same numeric block id in
        another pool."""
        reads, writes = _row_rw(opcode, src, dst, self.engine.group.locate,
                                self.engine.group.total_blocks)
        return reads, writes[0]

    def _overlaps(self, key: Tuple[int, int],
                  pending: Dict[int, Set[int]]) -> bool:
        pool, block = key
        hit = pending.get(block)
        if hit is None:
            return False
        primary = self.engine.group.primary
        return any(_keys_clash(key, (p, block), primary) for p in hit)

    def has_pending_write(self, key: Tuple[int, int]) -> bool:
        """Does ``(pool, block)`` overlap any pending destination write?
        ALL_PRIMARY expands to the primary pool set on either side; a
        staging-pool key only collides with an exact pool match."""
        return self._overlaps(key, self._pending_dsts)

    def has_pending_read(self, key: Tuple[int, int]) -> bool:
        """Does ``(pool, block)`` overlap any pending SOURCE read?  The
        source-hazard side of the tracking: a block with a pending read
        must not be rewritten out of band (e.g. a staging-ring slot whose
        promotion is still queued — the engine keeps such slots out of
        the free list until this turns False)."""
        return self._overlaps(key, self._pending_srcs)

    def enqueue(self, opcode: int, src: int, dst: int) -> None:
        """Append one tagged command.

        RAW/WAW — reading or rewriting a pending destination — auto-flush
        first (either would make gather-scatter and the in-place drain
        diverge).  WAR — overwriting a pending *source* — is admitted and
        counted (``stats.war_hazards``): every drain path reads sources
        before the later write lands, and :meth:`flush` spaces the pair
        apart for the overlapped kernel.  Overlap with ANOTHER stream's
        pending commands serializes that stream first (the engine's
        cross-stream guard)."""
        skeys, dkey = self._hazard_keys(opcode, src, dst)
        guard = getattr(self.engine, "_cross_stream_guard", None)
        if guard is not None:
            guard(self, skeys, dkey)
        if any(self.has_pending_write(k) for k in skeys) \
                or self.has_pending_write(dkey):
            self.stats.hazard_flushes += 1
            obs_metrics.inc("queue.hazard_flushes", stream=self.name)
            self.flush()
        elif self.has_pending_read(dkey):
            self.stats.war_hazards += 1
            obs_metrics.inc("queue.war_hazards", stream=self.name)
        if self._first_enqueue_t is None:
            self._first_enqueue_t = obs_metrics.now()
        self._cmds.append((int(opcode), int(src), int(dst)))
        self._pending_dsts.setdefault(dkey[1], set()).add(dkey[0])
        for skey in skeys:
            self._pending_srcs.setdefault(skey[1], set()).add(skey[0])
        note = getattr(self.engine, "_note_pending", None)
        if note is not None:
            note(self)      # engine tracks queues with pending work only
        self.stats.enqueued += 1
        obs_metrics.inc("queue.enqueued", stream=self.name,
                        opcode=OPCODE_NAMES.get(int(opcode), str(opcode)))
        self.stats.max_pending = max(self.stats.max_pending, len(self._cmds))

    def enqueue_copy(self, opcode: int,
                     pairs: Sequence[Tuple[int, int]]) -> None:
        """Enqueue one copy command per (src, dst) pair under ``opcode``."""
        for s, d in pairs:
            self.enqueue(opcode, s, d)

    def enqueue_zero(self, ids: Sequence[int]) -> None:
        """Enqueue a BuZ zero-init (reserved-zero-row broadcast) per id."""
        for b in ids:
            self.enqueue(OP_ZERO_INIT, -1, b)

    # ------------------------------------------------------------------
    def flush(self) -> int:
        """Drain every pending command.  Returns the number of device
        launches issued (0 when the queue was empty, 1 per bucket-padded
        chunk otherwise).  WAR-spacing, chunking, dispatch, and the
        journal record live in the engine's ``_drain_rows`` — one drain
        path shared with journal replay and aborted-flush re-drains."""
        if not self._cmds:
            return 0
        cmds, self._cmds = self._cmds, []
        self._pending_dsts = {}
        self._pending_srcs = {}
        drained = getattr(self.engine, "_note_drained", None)
        if drained is not None:
            drained(self)   # empty again: leave the engine's live set
        launches = self.engine._drain_rows(cmds, queue=self)
        self.stats.flushes += 1
        self.stats.launches += launches
        after = getattr(self.engine, "_after_flush", None)
        if after is not None:
            after(self)
        return launches

    def retire(self, rows: Sequence[Tuple[int, int, int]]) -> int:
        """Cancel specific pending rows WITHOUT dispatching them.

        The sequence-lifecycle primitive: a serving layer freeing a
        sequence *before* the round's flush must void the queued
        ``OP_CROSS_POOL_COPY`` promotions that still target the freed
        blocks — the allocator may re-issue those blocks immediately, and
        a stale promotion draining later would overwrite the new owner's
        bytes.  Each requested ``(opcode, src, dst)`` row is removed at
        most once (duplicates retire one occurrence per request); rows
        already drained are simply not found.  The hazard maps are
        rebuilt from the surviving rows, so pending-read tracking (e.g.
        staging-slot lifetime) immediately reflects the cancellation.
        Returns the number of rows removed."""
        want: Dict[Tuple[int, int, int], int] = {}
        for r in rows:
            r = (int(r[0]), int(r[1]), int(r[2]))
            want[r] = want.get(r, 0) + 1
        kept: List[Tuple[int, int, int]] = []
        removed = 0
        for row in self._cmds:
            if want.get(row, 0) > 0:
                want[row] -= 1
                removed += 1
            else:
                kept.append(row)
        if not removed:
            return 0
        self._cmds = kept
        self._pending_dsts = {}
        self._pending_srcs = {}
        for op, s, d in kept:
            skeys, dkey = self._hazard_keys(op, s, d)
            self._pending_dsts.setdefault(dkey[1], set()).add(dkey[0])
            for skey in skeys:
                self._pending_srcs.setdefault(skey[1], set()).add(skey[0])
        self.stats.retired += removed
        obs_metrics.inc("queue.retired", removed, stream=self.name)
        if not kept:
            self._first_enqueue_t = None
            drained = getattr(self.engine, "_note_drained", None)
            if drained is not None:
                drained(self)
        return removed

    def abort(self) -> List[Tuple[int, int, int]]:
        """Discard every pending command WITHOUT dispatching — the
        recovery path's eviction primitive (``RowCloneEngine.recover``
        drops queued work whose inputs died, e.g. promotions out of a
        poisoned staging ring).  Clears the hazard maps and leaves the
        engine's live set; returns the dropped rows so the caller can
        account for (or selectively re-enqueue) them."""
        cmds, self._cmds = self._cmds, []
        self._pending_dsts = {}
        self._pending_srcs = {}
        self._first_enqueue_t = None
        drained = getattr(self.engine, "_note_drained", None)
        if drained is not None:
            drained(self)
        return cmds


__all__ = [
    "BUCKETS",
    "DEFAULT_BUCKETS",
    "set_buckets",
    "get_buckets",
    "top_bucket",
    "ALL_PRIMARY",
    "bucket_size",
    "space_war_rows",
    "partition_commands",
    "fold_shard_plan",
    "ShardPlan",
    "CommandQueue",
    "QueueStats",
    "OP_FPM_COPY",
    "OP_PSM_COPY",
    "OP_BASELINE_COPY",
    "OP_ZERO_INIT",
    "OP_CROSS_POOL_COPY",
    "OP_AND",
    "OP_OR",
    "OP_NOT",
    "OP_NOP",
    "BITWISE_OPS",
    "pack_bitwise_src",
    "unpack_bitwise_src",
]
