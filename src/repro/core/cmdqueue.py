"""CommandQueue — the memory-controller command buffer for bulk movement.

Paper §2.3: software issues ``memcopy``/``meminit``; the *memory controller*
serializes the commands and drains them inside DRAM with no per-command CPU
involvement.  The seed engine inverted that: every request batch ran
host-side partitioning and then one device dispatch per mechanism per pool.
This queue restores the paper's shape:

* callers **enqueue** tagged commands (``OP_FPM_COPY``, ``OP_PSM_COPY``,
  ``OP_BASELINE_COPY``, ``OP_ZERO_INIT``, ``OP_CROSS_POOL_COPY`` — see
  kernels/fused_dispatch.py for the opcode table);
* the device sees work only at **flush** boundaries (an attention step, a
  benchmark tick, or an explicit ``flush()``) — one fused kernel launch per
  flushed table, every pool moved in the same launch.

Padding is **power-of-two bucketed** (8/32/128/512): a 3-command flush pads
to 8, not to the seed's fixed 256, so small batches stop paying full-length
gathers while the jit cache stays bounded (4 table shapes per pool
structure).  Tables longer than the largest bucket are drained in overflow
chunks instead of raising.

Hazard guards (the MC's ordering rules): a command whose source was written
by a pending command, or whose destination is already pending, triggers an
automatic flush first — so within one table, gather-then-scatter semantics
and the kernel's sequential DMA drain agree exactly.
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional, Sequence, Set, Tuple

import numpy as np

from repro.kernels.fused_dispatch import (OP_BASELINE_COPY, OP_CROSS_POOL_COPY,
                                          OP_FPM_COPY, OP_NOP, OP_PSM_COPY,
                                          OP_ZERO_INIT)

#: padding buckets — the only command-table lengths ever jit-compiled
BUCKETS: Tuple[int, ...] = (8, 32, 128, 512)


def bucket_size(n: int) -> int:
    """Smallest bucket holding ``n`` commands (callers chunk above the top
    bucket)."""
    for b in BUCKETS:
        if n <= b:
            return b
    return BUCKETS[-1]


@dataclasses.dataclass
class QueueStats:
    enqueued: int = 0
    flushes: int = 0           # explicit + boundary flushes that moved work
    hazard_flushes: int = 0    # forced early by an ordering hazard
    launches: int = 0          # device dispatches issued for flushed tables
    max_pending: int = 0


class CommandQueue:
    """Accumulates ``(opcode, src, dst)`` commands for a RowCloneEngine and
    drains them through the engine's fused dispatch at flush time."""

    def __init__(self, engine):
        self.engine = engine
        self.stats = QueueStats()
        self._cmds: List[Tuple[int, int, int]] = []
        self._pending_dsts: Set[int] = set()

    def __len__(self) -> int:
        return len(self._cmds)

    @property
    def pending(self) -> List[Tuple[int, int, int]]:
        return list(self._cmds)

    # ------------------------------------------------------------------
    def _hazard_keys(self, opcode: int, src: int,
                     dst: int) -> Tuple[Optional[int], int]:
        """Block-id keys used for ordering hazards.  CROSS_POOL ids are
        stacked (pool*nblk + block); they fold back to plain block ids,
        which is conservative (a same-id block in another pool also
        flushes) but never unsafe."""
        nblk = self.engine.num_blocks
        if opcode == OP_CROSS_POOL_COPY:
            return src % nblk, dst % nblk
        if opcode == OP_ZERO_INIT:
            return None, dst
        return src, dst

    def enqueue(self, opcode: int, src: int, dst: int) -> None:
        skey, dkey = self._hazard_keys(opcode, src, dst)
        if (skey is not None and skey in self._pending_dsts) \
                or dkey in self._pending_dsts:
            # read-after-write / write-after-write within one table would
            # make gather-scatter and sequential drain diverge — drain first
            self.stats.hazard_flushes += 1
            self.flush()
        self._cmds.append((int(opcode), int(src), int(dst)))
        self._pending_dsts.add(dkey)
        self.stats.enqueued += 1
        self.stats.max_pending = max(self.stats.max_pending, len(self._cmds))

    def enqueue_copy(self, opcode: int,
                     pairs: Sequence[Tuple[int, int]]) -> None:
        for s, d in pairs:
            self.enqueue(opcode, s, d)

    def enqueue_zero(self, ids: Sequence[int]) -> None:
        for b in ids:
            self.enqueue(OP_ZERO_INIT, -1, b)

    # ------------------------------------------------------------------
    def flush(self) -> int:
        """Drain every pending command.  Returns the number of device
        launches issued (0 when the queue was empty, 1 per bucket-padded
        chunk otherwise)."""
        if not self._cmds:
            return 0
        cmds, self._cmds = self._cmds, []
        self._pending_dsts = set()
        launches = 0
        top = BUCKETS[-1]
        for lo in range(0, len(cmds), top):
            chunk = cmds[lo:lo + top]
            table = np.full((bucket_size(len(chunk)), 3), OP_NOP, np.int32)
            table[:len(chunk)] = np.asarray(chunk, np.int32)
            launches += self.engine._dispatch_table(table, len(chunk))
        self.stats.flushes += 1
        self.stats.launches += launches
        return launches


__all__ = [
    "BUCKETS",
    "bucket_size",
    "CommandQueue",
    "QueueStats",
    "OP_FPM_COPY",
    "OP_PSM_COPY",
    "OP_BASELINE_COPY",
    "OP_ZERO_INIT",
    "OP_CROSS_POOL_COPY",
    "OP_NOP",
]
