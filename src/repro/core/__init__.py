"""The paper's primary contribution: the RowClone engine — in-memory bulk
copy (FPM/PSM), bulk init via reserved zero rows + lazy-zero (ZI), the
subarray-aware allocator, and the CoW paged KV cache built on them.

See docs/ARCHITECTURE.md for the paper-mechanism → module map."""
from repro.core.allocator import AllocStats, OutOfBlocks, SubarrayAllocator
from repro.core.cmdqueue import (BUCKETS, CommandQueue, QueueStats,
                                 ShardPlan, bucket_size, fold_shard_plan,
                                 partition_commands)
from repro.core.cow_cache import PagedCoWCache, Sequence
from repro.core.journal import (AbortedFlush, JournalRecord, PoolSnapshot,
                                RecoveryError, RecoveryReport, TicketJournal)
from repro.core.opcodes import (BITWISE_OPS, MAX_PACK_BLOCKS, OPCODES,
                                OpSpec, UnknownOpcodeError, opspec)
from repro.core.poolspec import BlockRef, PoolGroup, PoolSpec
from repro.core.rowclone import EngineStats, RowCloneEngine
from repro.core.sanitizer import (DrainSanitizer, Finding, SanitizerError,
                                  SanitizerReport, sanitize_enabled)
from repro.core.stream import CommandStream, FlushTicket

__all__ = [
    "CommandStream",
    "FlushTicket",
    "AllocStats",
    "OutOfBlocks",
    "SubarrayAllocator",
    "BUCKETS",
    "bucket_size",
    "partition_commands",
    "fold_shard_plan",
    "ShardPlan",
    "CommandQueue",
    "QueueStats",
    "PagedCoWCache",
    "Sequence",
    "PoolSpec",
    "BlockRef",
    "PoolGroup",
    "EngineStats",
    "RowCloneEngine",
    "TicketJournal",
    "JournalRecord",
    "PoolSnapshot",
    "AbortedFlush",
    "RecoveryError",
    "RecoveryReport",
    "OPCODES",
    "OpSpec",
    "opspec",
    "UnknownOpcodeError",
    "BITWISE_OPS",
    "MAX_PACK_BLOCKS",
    "DrainSanitizer",
    "Finding",
    "SanitizerError",
    "SanitizerReport",
    "sanitize_enabled",
]
