"""Ticket journal — the engine's replayable flush log.

RowClone §1 names checkpointing and VM cloning as killer workloads for
bulk in-DRAM movement: both are *restore* problems — the bytes must be
reproducible after a failure, not just fast to move.  The engine's flush
path is already deterministic (a drained command table maps pool state to
pool state with no host randomness), so fault tolerance reduces to
logging what was drained: every successful flush appends one
:class:`JournalRecord` — the exact (WAR-spaced) rows the dispatch loop
consumed, the flush's engine-wide index, its ShardPlan signature, and
launch accounting — to a bounded :class:`TicketJournal` ring.

Recovery composes two primitives:

* :class:`PoolSnapshot` — host copies of the pool arrays, stamped with
  the last flush index they include (``RowCloneEngine.snapshot()``, or
  assembled incrementally by the background checkpoint stream —
  checkpoint/pool_checkpoint.py).
* :meth:`TicketJournal.replay` — re-drains every record after a
  snapshot's index onto the restored pools.  Because records hold the
  spaced rows verbatim (replay passes them through pre-spaced), the
  replayed drains build bitwise-identical tables and hence
  bitwise-identical block state.

What the journal does NOT cover: out-of-band pool writes that bypass the
command queue — the serving engine's decode-step jit and the prefill
staging scatter assign ``engine.pools[...]`` directly.  Those bytes are
reproduced by re-running their producers (recovery evicts and re-admits
the affected sequences), never by replay; a snapshot taken at a quiesced
flush boundary is exact.  See docs/ARCHITECTURE.md "Failure model and
recovery".
"""
from __future__ import annotations

import collections
import dataclasses
from typing import Any, Dict, List, Optional, Tuple

from repro.core.opcodes import OP_NOP, row_rw


@dataclasses.dataclass(frozen=True)
class JournalRecord:
    """One drained flush, as the dispatch loop actually consumed it.

    ``rows`` are the WAR-spaced ``(opcode, src, dst)`` rows (spacer
    ``OP_NOP`` rows included) — replay feeds them back pre-spaced, so the
    rebuilt tables are bitwise-identical to the original drain.  An
    ``aborted`` record holds only the chunks that dispatched before a
    mid-flush failure; the undispatched suffix is stashed on the engine
    (``RowCloneEngine.recover`` re-drains it as a fresh record)."""

    stream: str                       #: name of the draining stream/queue
    index: int                        #: engine-wide flush index
    rows: Tuple[Tuple[int, int, int], ...]  #: spaced rows, as dispatched
    plan_sig: Optional[Tuple] = None  #: (n_shards, deltas, slot bucket) of
    #: the sharded drain's ShardPlan; None for single-device flushes
    launches: int = 0                 #: device launches the drain issued
    war_hazards: int = 0              #: queue's cumulative WAR admissions
    spacer_rows: int = 0              #: queue's cumulative spacer rows
    aborted: bool = False             #: True = prefix of a failed flush


@dataclasses.dataclass(frozen=True)
class PoolSnapshot:
    """Host copies of pool arrays, consistent through flush ``index``.

    ``arrays`` maps pool name -> np.ndarray; a snapshot need not cover
    every pool (the checkpoint stream snapshots primary pools only —
    staging bytes are reproduced by re-admission, not restore).  Replay
    applies journal records with ``record.index > index``."""

    index: int
    arrays: Dict[str, Any]


@dataclasses.dataclass(frozen=True)
class AbortedFlush:
    """The undispatched remainder of a flush that failed mid-drain.

    Stashed by the engine's drain loop (pool buffers are still valid —
    the per-chunk drain guard fires *before* the donating dispatch);
    ``RowCloneEngine.recover`` re-drains ``suffix`` (already WAR-spaced)
    with retry/backoff."""

    queue: str                        #: name of the flushing queue
    index: int                        #: the failed flush's index
    rows: Tuple[Tuple[int, int, int], ...]    #: full raw rows, pre-spacing
    suffix: Tuple[Tuple[int, int, int], ...]  #: spaced rows not dispatched


class RecoveryError(RuntimeError):
    """Recovery exhausted its retries (or had nothing left to restore
    from) — the engine could not be returned to a serviceable state."""


@dataclasses.dataclass(frozen=True)
class RecoveryReport:
    """What one ``RowCloneEngine.recover()`` pass did."""

    evicted_rows: int         #: queued commands dropped from live streams
    evicted_promotions: int   #: of those, staging→primary promotions
    pools_restored: Tuple[str, ...]  #: pools restored from the snapshot
    pools_lost: Tuple[str, ...]      #: dead pools resurrected as zeros
    replayed_flushes: int     #: journal records re-drained
    redrained_flushes: int    #: aborted-flush suffixes re-drained
    retries: int              #: failed re-drain attempts before success
    degraded: bool            #: True = staging ring in degraded capacity


class TicketJournal:
    """Bounded in-engine log of drained flushes.

    A deque ring of :class:`JournalRecord`\\ s: every successful
    ``_drain_rows`` appends one (aborted flushes append their dispatched
    prefix), oldest records fall off past ``capacity``.  Restore-time
    contract: a :class:`PoolSnapshot` is replayable only while every
    record after its index is still in the ring — size the capacity to
    cover at least one full checkpoint interval."""

    def __init__(self, capacity: int = 256):
        self.capacity = capacity
        self._records: collections.deque = collections.deque(
            maxlen=capacity)

    def __len__(self) -> int:
        return len(self._records)

    def append(self, record: JournalRecord) -> None:
        """Append one flush record (oldest falls off past capacity)."""
        self._records.append(record)

    @property
    def records(self) -> Tuple[JournalRecord, ...]:
        """The retained records, oldest first."""
        return tuple(self._records)

    @property
    def head_index(self) -> int:
        """Flush index of the oldest retained record (-1 when empty) —
        a snapshot older than this is no longer replayable."""
        return self._records[0].index if self._records else -1

    @property
    def last_index(self) -> int:
        """Flush index of the newest retained record (-1 when empty)."""
        return self._records[-1].index if self._records else -1

    def since(self, index: int) -> List[JournalRecord]:
        """Records with ``record.index > index``, oldest first."""
        return [r for r in self._records if r.index > index]

    def replay(self, engine, after: int = -1) -> int:
        """Re-drain every record after flush ``after`` onto the engine's
        (restored) pools, in order.  Records carry the spaced rows as
        dispatched, so the rebuilt tables — and the resulting block
        state — are bitwise-identical to the original drains.  Returns
        the number of flushes replayed.

        Every record's rows are validated against the opcode contract
        registry (core/opcodes.py) BEFORE anything re-drains: opcodes
        must have :class:`~repro.core.opcodes.OpSpec` entries and every
        operand must decode under its contract — including the int32
        two-source packing bound, which is enforced on the replay path
        exactly as at engine construction.  A journal restored against a
        mismatched engine (different pool group, truncated rows, a
        corrupted record) fails here with a descriptive error instead of
        scattering into the wrong blocks."""
        todo = self.since(after)
        group = engine.group
        for rec in todo:
            for i, (op, s, d) in enumerate(rec.rows):
                try:
                    if op < 0:
                        if (op, s, d) != (OP_NOP, -1, -1):
                            raise ValueError(
                                f"padding row must be (OP_NOP, -1, -1), "
                                f"got ({op}, {s}, {d})")
                        continue
                    # registry-driven decode: raises UnknownOpcodeError
                    # for unregistered opcodes, ValueError for operands
                    # outside the engine's address space or packing bound
                    row_rw(op, s, d, group.locate, group.total_blocks)
                except ValueError as e:
                    raise RecoveryError(
                        f"journal record {rec.index} (stream "
                        f"{rec.stream!r}) row {i} fails the opcode "
                        f"contract: {e}") from e
        for rec in todo:
            engine._drain_rows(list(rec.rows), record=False,
                               pre_spaced=True)
        return len(todo)


__all__ = [
    "JournalRecord",
    "PoolSnapshot",
    "AbortedFlush",
    "RecoveryError",
    "RecoveryReport",
    "TicketJournal",
]
