"""Drain sanitizer — TSAN-style dynamic validation of every flushed table.

The CommandQueue's hazard guards and the WAR spacing pass are *supposed*
to guarantee a set of invariants about every table the drain loop hands
to the fused kernel (docs/ARCHITECTURE.md "Invariants and enforcement").
This module checks them at runtime, the way a thread sanitizer checks a
locking discipline: ``RowCloneEngine(sanitize=True)`` (or env var
``REPRO_SANITIZE=1``) attaches a :class:`DrainSanitizer`, and every chunk
that reaches ``_dispatch_table`` is validated BEFORE the donating launch:

* every opcode has a core/opcodes.py :class:`~repro.core.opcodes.OpSpec`
  registry entry, and every operand decodes under its contract — primary
  ids in range, global ids locatable, packed two-source ids inside the
  ``total²`` square with the int32 packing bound honoured;
* staging-pool legality: a destination resolving to a non-primary pool is
  only legal when the opcode's ``staging_dst_ok`` says so;
* padding rows are well-formed: anything with ``opcode < 0`` must be
  exactly ``(OP_NOP, -1, -1)`` (a spacer carrying operands would still be
  skipped by the kernel — but it means someone built a corrupt table);
* no RAW/WAW pair coexists anywhere in one table (the queue must have
  split them across flushes);
* no adjacent WAR pair: the overlapped DMA drain's trailing wait is one
  step behind issue, so a row writing what the IMMEDIATELY preceding row
  reads is a race — the spacer contract (``space_war_rows``) must have
  separated them;
* under a mesh, the :class:`~repro.core.cmdqueue.ShardPlan` exactly
  partitions the flushed rows: the per-slab sub-tables plus the transfer
  plan reproduce the same global read and write sets, and each sub-table
  independently honours the WAR adjacency contract;
* (sampled) shadow execution: the pre-dispatch pool bytes run through the
  pure-jnp oracle (kernels/ref.py ``fused_dispatch``) on HOST copies and
  the result is compared bitwise against the pools the real dispatch
  produced.  The oracle path issues no ``notify_launch`` and no engine
  stats, so launch accounting is identical with the sanitizer on.

Failures raise :class:`SanitizerError` carrying a structured
:class:`SanitizerReport`; the drain loop's abort machinery stashes the
undispatched suffix exactly as for any mid-flush failure, so a sanitized
engine fails *stopped*, with pool buffers intact, not corrupted.
"""
from __future__ import annotations

import dataclasses
import os
from typing import Dict, List, Optional, Sequence, Set, Tuple

import numpy as np

from repro.core.opcodes import (ALL_PRIMARY, OP_NOP, UnknownOpcodeError,
                                keys_clash, opspec, row_rw,
                                unpack_bitwise_src)


def sanitize_enabled() -> bool:
    """Is drain sanitizing requested by the environment?  True when
    ``REPRO_SANITIZE`` is set to anything but ``""``/``"0"`` — the hook
    the sanitized CI leg uses to run existing test streams unmodified."""
    return os.environ.get("REPRO_SANITIZE", "") not in ("", "0")


@dataclasses.dataclass(frozen=True)
class Finding:
    """One invariant violation in one flushed table.

    ``check`` is the stable check id (e.g. ``"war-adjacency"``,
    ``"shadow-diff"`` — the ids docs/ARCHITECTURE.md's enforcement table
    references); ``row`` is the table row index it anchors to (-1 for
    whole-table findings like a plan mismatch or a shadow diff)."""

    check: str
    message: str
    row: int = -1


@dataclasses.dataclass(frozen=True)
class SanitizerReport:
    """The structured result of sanitizing one dispatched chunk.

    ``flush``/``chunk`` locate the table in the engine's drain sequence
    (the same indices the journal and the drain guards carry); ``rows``
    counts real (non-padding) command rows; ``checks`` names every check
    that ran; ``findings`` is empty for a clean table."""

    flush: int
    chunk: int
    rows: int
    checks: Tuple[str, ...]
    findings: Tuple[Finding, ...]

    @property
    def ok(self) -> bool:
        """True when every check passed."""
        return not self.findings


class SanitizerError(RuntimeError):
    """A sanitized drain found an invariant violation pre-launch (or a
    shadow-execution diff post-launch).  Carries the structured
    :class:`SanitizerReport` as ``.report``; the drain loop aborts the
    flush with the standard stash-and-recover machinery."""

    def __init__(self, report: SanitizerReport):
        self.report = report
        lines = [f"drain sanitizer: {len(report.findings)} finding(s) in "
                 f"flush {report.flush} chunk {report.chunk}:"]
        lines += [f"  [{f.check}] row {f.row}: {f.message}"
                  for f in report.findings]
        super().__init__("\n".join(lines))


#: checks run on every table (check_table)
_TABLE_CHECKS = ("opcode-registry", "nop-well-formed", "operand-contract",
                 "staging-legality", "raw-waw-free", "war-adjacency")
#: checks run on every sharded plan (check_plan)
_PLAN_CHECKS = ("plan-partition", "plan-war-adjacency")


class DrainSanitizer:
    """Validates every flushed table an engine dispatches (see the module
    docstring for the check list).  One instance per engine, attached by
    ``RowCloneEngine(sanitize=True)``; keeps the last ``max_reports``
    :class:`SanitizerReport` receipts on ``reports`` and running totals
    (``tables_checked``/``plans_checked``/``shadow_runs``) so tests can
    assert coverage, not just absence of raises.

    ``shadow_every`` samples the shadow execution: 1 (default) shadows
    every chunk, ``n`` shadows every n-th — the static checks always run.
    Sampling is a deterministic counter, never wall-clock or RNG, so a
    sanitized replay shadows the same chunks as the original drain."""

    def __init__(self, engine, shadow_every: int = 1,
                 max_reports: int = 256):
        self.engine = engine
        self.shadow_every = max(int(shadow_every), 1)
        self.max_reports = max_reports
        self.reports: List[SanitizerReport] = []
        self.tables_checked = 0
        self.plans_checked = 0
        self.shadow_runs = 0
        self._chunk_counter = 0
        self._ctx: Tuple[int, int] = (-1, -1)

    # ------------------------------------------------------------------
    def _emit(self, findings: List[Finding], checks: Tuple[str, ...],
              n_rows: int) -> None:
        flush, chunk = self._ctx
        report = SanitizerReport(flush=flush, chunk=chunk, rows=n_rows,
                                 checks=checks, findings=tuple(findings))
        self.reports.append(report)
        if len(self.reports) > self.max_reports:
            del self.reports[:-self.max_reports]
        if findings:
            raise SanitizerError(report)

    def _locate(self, gid: int) -> Tuple[int, int]:
        return self.engine.group.locate(int(gid))

    # ------------------------------------------------------------------
    def check_table(self, table: np.ndarray, flush: int, chunk: int) -> None:
        """Run every static per-table check against the opcode registry;
        raises :class:`SanitizerError` on the first failing table.  Called
        by the drain loop on the bucket-padded chunk, after the drain
        guards and before the donating dispatch."""
        self._ctx = (flush, chunk)
        self.tables_checked += 1
        group = self.engine.group
        total = group.total_blocks
        nblk = self.engine.num_blocks
        primary = group.primary
        findings: List[Finding] = []
        decoded: List[Optional[Tuple[Tuple, Tuple]]] = []
        n_rows = 0
        for i, (op, s, d) in enumerate(np.asarray(table).tolist()):
            if op < 0:
                if (op, s, d) != (OP_NOP, -1, -1):
                    findings.append(Finding(
                        "nop-well-formed",
                        f"padding row must be (OP_NOP, -1, -1), got "
                        f"({op}, {s}, {d})", i))
                decoded.append(None)
                continue
            n_rows += 1
            try:
                sp = opspec(op)
            except UnknownOpcodeError as e:
                findings.append(Finding("opcode-registry", str(e), i))
                decoded.append(None)
                continue
            rw = self._check_row(sp, op, s, d, nblk, total, findings, i)
            decoded.append(rw)
            if rw is None:
                continue
            _, writes = rw
            for p, _b in writes:
                if p != ALL_PRIMARY and not primary[p] \
                        and not sp.staging_dst_ok:
                    findings.append(Finding(
                        "staging-legality",
                        f"{sp.constant_name} dst resolves to non-primary "
                        f"pool {group.names[p]!r} but its contract "
                        "forbids staging destinations", i))
        self._check_order(decoded, primary, findings)
        self._emit(findings, _TABLE_CHECKS, n_rows)

    def _check_row(self, sp, op: int, s: int, d: int, nblk: int,
                   total: int, findings: List[Finding], i: int):
        """Validate one row's operands under ``sp``'s contract; returns
        the decoded ``(reads, writes)`` keys or None when undecodable."""
        name = sp.constant_name
        ok = True
        if sp.src_kind == "none" and s != -1:
            findings.append(Finding(
                "operand-contract",
                f"{name} takes no source but src={s} (must be -1)", i))
        elif sp.src_kind == "primary" and not 0 <= s < nblk:
            findings.append(Finding(
                "operand-contract",
                f"{name} src {s} outside the primary address space "
                f"[0, {nblk})", i))
            ok = False
        elif sp.src_kind == "global" and not 0 <= s < total:
            findings.append(Finding(
                "operand-contract",
                f"{name} src {s} outside the global id space "
                f"[0, {total})", i))
            ok = False
        elif sp.src_kind == "packed":
            try:
                unpack_bitwise_src(s, total)
            except ValueError as e:
                findings.append(Finding("operand-contract",
                                        f"{name}: {e}", i))
                ok = False
        if sp.dst_kind == "primary" and not 0 <= d < nblk:
            findings.append(Finding(
                "operand-contract",
                f"{name} dst {d} outside the primary address space "
                f"[0, {nblk}) — the written block must be named in dst",
                i))
            ok = False
        elif sp.dst_kind == "global" and not 0 <= d < total:
            findings.append(Finding(
                "operand-contract",
                f"{name} dst {d} outside the global id space [0, {total})"
                " — the written block must be named in dst", i))
            ok = False
        if not ok:
            return None
        return row_rw(op, s, d, self._locate, total)

    def _check_order(self, decoded, primary, findings: List[Finding],
                     check_prefix: str = "") -> None:
        """Whole-table RAW/WAW absence + adjacent-row WAR disjointness
        over pre-decoded ``(reads, writes)`` per row (None = padding or
        undecodable; padding resets the adjacency window exactly like the
        spacer the overlapped drain relies on)."""
        written: List[Tuple[Tuple[int, int], int]] = []
        prev_reads: Tuple = ()
        for i, rw in enumerate(decoded):
            if rw is None:
                prev_reads = ()
                continue
            reads, writes = rw
            for r in reads:
                for w, j in written:
                    if keys_clash(r, w, primary):
                        findings.append(Finding(
                            check_prefix + "raw-waw-free",
                            f"row reads {r} written by row {j} in the "
                            "same table (RAW must flush-split)", i))
            for wk in writes:
                for w, j in written:
                    if keys_clash(wk, w, primary):
                        findings.append(Finding(
                            check_prefix + "raw-waw-free",
                            f"row rewrites {wk} written by row {j} in "
                            "the same table (WAW must flush-split)", i))
            if any(keys_clash(r, w, primary)
                   for r in prev_reads for w in writes):
                findings.append(Finding(
                    check_prefix + "war-adjacency",
                    "row writes a block the immediately preceding row "
                    "reads — the overlapped drain's trailing wait races "
                    "this (missing OP_NOP spacer)", i))
            written.extend((w, i) for w in writes)
            prev_reads = reads

    # ------------------------------------------------------------------
    def check_plan(self, rows: Sequence[Tuple[int, int, int]], plan,
                   replicated: Tuple[bool, ...]) -> None:
        """Validate a :class:`~repro.core.cmdqueue.ShardPlan` against the
        rows it partitions: the per-slab sub-tables plus the transfer
        plan must reproduce exactly the global read and write key sets of
        the flushed rows, and every sub-table must independently honour
        the WAR adjacency contract.  Called by ``_dispatch_sharded``
        between partitioning and the collective launch."""
        self.plans_checked += 1
        group = self.engine.group
        primary = group.primary
        ss = plan.shard_sizes
        local_base: List[int] = []
        run = 0
        for s_p in ss:
            local_base.append(run)
            run += s_p
        lt = run
        p0 = primary.index(True)
        ss0 = ss[p0]

        def _local_locate(gid: int) -> Tuple[int, int]:
            for p in range(len(ss) - 1, -1, -1):
                if gid >= local_base[p]:
                    return p, gid - local_base[p]
            raise ValueError(f"slab-local id {gid} below every pool base")

        def _expand(key: Tuple[int, int]) -> Set[Tuple[int, int]]:
            p, b = key
            if p == ALL_PRIMARY:
                return {(q, b) for q, is_p in enumerate(primary) if is_p}
            return {(p, b)}

        def _globalize(key: Tuple[int, int], sh: int) -> Tuple[int, int]:
            p, b = key
            if p == ALL_PRIMARY:
                return (p, sh * ss0 + b)
            if replicated[p]:
                return (p, b)
            return (p, sh * ss[p] + b)

        findings: List[Finding] = []
        want_reads: Set[Tuple[int, int]] = set()
        want_writes: Set[Tuple[int, int]] = set()
        for op, s, d in rows:
            if op < 0:
                continue
            reads, writes = row_rw(op, s, d, self._locate,
                                   group.total_blocks)
            for r in reads:
                want_reads |= _expand(r)
            for w in writes:
                want_writes |= _expand(w)

        got_reads: Set[Tuple[int, int]] = set()
        got_writes: Set[Tuple[int, int]] = set()
        for sh in range(plan.n_shards):
            decoded = []
            for op, s, d in np.asarray(plan.local_tables[sh]).tolist():
                if op < 0:
                    decoded.append(None)
                    continue
                rw = row_rw(op, s, d, _local_locate, lt)
                decoded.append(rw)
                reads, writes = rw
                for r in reads:
                    got_reads |= _expand(_globalize(r, sh))
                for w in writes:
                    got_writes |= _expand(_globalize(w, sh))
            self._check_order(decoded, primary, findings,
                              check_prefix="plan-")
        S = plan.n_shards
        for k, delta in enumerate(plan.deltas):
            for sh_d in range(S):
                sh_s = (sh_d - delta) % S
                for j in range(plan.recv_tables.shape[2]):
                    bp, dp, dr, _comb = (
                        int(x) for x in plan.recv_tables[k, sh_d, j])
                    if dr < 0:
                        continue
                    src_row = int(plan.send_rows[k, sh_s, j])
                    got_reads |= _expand(_globalize(
                        (ALL_PRIMARY if bp < 0 else bp, src_row), sh_s))
                    got_writes |= _expand(_globalize(
                        (ALL_PRIMARY if dp < 0 else dp, dr), sh_d))

        for label, want, got in (("write", want_writes, got_writes),
                                 ("read", want_reads, got_reads)):
            missing = sorted(want - got)[:4]
            extra = sorted(got - want)[:4]
            if missing or extra:
                findings.append(Finding(
                    "plan-partition",
                    f"ShardPlan {label} set diverges from the flushed "
                    f"rows: missing {missing}, extra {extra} "
                    "((pool, block) keys, truncated)"))
        self._emit(findings, _PLAN_CHECKS,
                   sum(1 for op, _s, _d in rows if op >= 0))

    # ------------------------------------------------------------------
    def shadow_snapshot(self) -> Optional[Dict[str, np.ndarray]]:
        """Host copies of every pool for the shadow diff, or None when
        this chunk is not sampled (``shadow_every``).  Must be taken
        BEFORE the dispatch: the fused launch donates the pool buffers."""
        self._chunk_counter += 1
        if (self._chunk_counter - 1) % self.shadow_every:
            return None
        return {n: np.asarray(p) for n, p in self.engine.pools.items()}

    def check_shadow(self, pre: Dict[str, np.ndarray],
                     table: np.ndarray) -> None:
        """Shadow-execute ``table`` on the pre-dispatch host copies with
        the pure-jnp oracle and compare every pool bitwise against what
        the real dispatch produced.  Any differing block is a finding:
        the kernel (or the sharded plan execution) diverged from the
        reference semantics on live traffic."""
        import jax.numpy as jnp

        from repro.kernels import ref as _ref
        eng = self.engine
        self.shadow_runs += 1
        zeros = tuple(jnp.asarray(np.asarray(z))
                      for z in eng._get_zero_blocks())
        want = _ref.fused_dispatch(
            tuple(jnp.asarray(pre[n]) for n in eng.pools),
            zeros, jnp.asarray(np.asarray(table, np.int32)),
            block_axis=eng.block_axis, primary=eng.group.primary)
        findings: List[Finding] = []
        ba = eng.block_axis
        for name, w in zip(eng.pools, want):
            got = np.asarray(eng.pools[name])
            w = np.asarray(w)
            if got.tobytes() == w.tobytes():
                continue
            diff = (np.moveaxis(got, ba, 0).reshape(got.shape[ba], -1)
                    != np.moveaxis(w, ba, 0).reshape(w.shape[ba], -1))
            bad = np.nonzero(diff.any(axis=1))[0]
            findings.append(Finding(
                "shadow-diff",
                f"pool {name!r}: {len(bad)} block(s) differ from the jnp "
                f"oracle after dispatch (first: {bad[:8].tolist()})"))
        self._emit(findings, ("shadow-diff",),
                   int((np.asarray(table)[:, 0] >= 0).sum()))


__all__ = [
    "DrainSanitizer",
    "Finding",
    "SanitizerError",
    "SanitizerReport",
    "sanitize_enabled",
]
