"""Declarative opcode contract registry — ONE source of truth per opcode.

RowClone's correctness rests on the memory controller never issuing a
command that violates the row/bank hazard rules (paper §2.3).  In this
reproduction those rules used to live as prose — "every command must name
its written block in ``dst``", the WAR spacer contract, the two-source
packing bound — duplicated across the CommandQueue's hazard keys, the
ShardPlan partitioner, ``retire()``/journal replay, and the kernel/ref
opcode switch tables.  Every new opcode (Ambit bitwise rows today,
gather/scatter descriptors next) multiplied the ways a mis-declared
read/write set could silently corrupt pools.

This module makes the contract *data*: an :class:`OpSpec` per opcode
declares its mnemonic, source arity, operand addressing (how ``src``/
``dst`` decode — primary-space id, global ``base[pool] + block`` id, or
the two-source ``a * total + b`` packing), whether its destination may
name a non-primary (staging/spill) pool, and whether it is compute or
padding.  Everything else *derives* from the registry:

* :func:`row_rw` — the ``(reads, writes)`` hazard keys of one table row
  (CommandQueue ``_hazard_keys``, WAR spacing, ``retire()`` rebuilds).
* :data:`BITWISE_OPS` / :data:`PLAIN_COPY_OPS` / :data:`OPCODE_NAMES` —
  the switch sets the Pallas kernel, the jnp reference, the ShardPlan
  partitioner, and the legacy fan-out branch on.
* :func:`pack_bitwise_src` / :func:`unpack_bitwise_src` — the canonical
  home of the two-source packing, with the int32 bound
  (:data:`MAX_PACK_BLOCKS`) enforced on EVERY decode — engine
  construction, ``retire()``, and journal replay alike.

The registry is enforced twice over: statically by ``tools/rowlint.py``
(an ``OP_*`` constant without an entry here fails the lint) and
dynamically by the drain sanitizer (core/sanitizer.py), which validates
every flushed table against these specs pre-launch.

This module is dependency-free (stdlib only) so the linter can load it
without pulling in jax.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Dict, Optional, Tuple

#: opcode values — the ``(m, 3)`` table's first column (see the table in
#: kernels/fused_dispatch.py's module docstring)
OP_NOP = -1
OP_FPM_COPY = 0
OP_PSM_COPY = 1
OP_BASELINE_COPY = 2
OP_ZERO_INIT = 3
OP_CROSS_POOL_COPY = 4
OP_AND = 5
OP_OR = 6
OP_NOT = 7

#: hazard-key pool index standing for "every primary pool" (plain opcodes
#: move the named block in all of them at once)
ALL_PRIMARY = -1

#: largest address-space size whose two-source packing fits int32
#: (``MAX_PACK_BLOCKS ** 2 - 1 <= 2**31 - 1``)
MAX_PACK_BLOCKS = 46340

_INT32_MAX = 2 ** 31 - 1


class UnknownOpcodeError(ValueError):
    """An opcode value with no :data:`OPCODES` registry entry reached a
    decode path — a new opcode was added without declaring its contract
    (or a table row was corrupted)."""


@dataclasses.dataclass(frozen=True)
class OpSpec:
    """The declarative contract of ONE opcode.

    ``src_kind`` / ``dst_kind`` name the operand addressing rule:

    * ``"none"`` — the field is unused (``-1`` by convention).
    * ``"primary"`` — a primary-address-space block id; the command
      touches that block in EVERY primary pool (hazard pool key
      :data:`ALL_PRIMARY`).
    * ``"global"`` — a PoolGroup global id ``base[pool] + block``
      (core/poolspec.py), naming exactly one ``(pool, block)``.
    * ``"packed"`` — TWO global ids packed ``a * total + b``
      (:func:`pack_bitwise_src`); the row reads both.

    ``staging_dst_ok`` is the staging-pool legality rule: may ``dst``
    resolve to a non-primary (staging/spill) pool?  Plain opcodes may
    not — staged bytes enter and leave staging pools exclusively through
    global-id rows.  ``arity`` counts source operands (0 for zero-init
    and padding, 1 for copies, 2 for the bitwise compute rows).
    ``is_padding`` rows (``OP_NOP``) carry no operands at all: a
    well-formed NOP row is exactly ``(-1, -1, -1)`` — also the WAR
    spacer the overlapped drain relies on.  ``is_compute`` marks the
    Ambit-style rows that combine sources instead of moving one."""

    value: int
    mnemonic: str
    arity: int
    src_kind: str          # "none" | "primary" | "global" | "packed"
    dst_kind: str          # "none" | "primary" | "global"
    staging_dst_ok: bool
    is_compute: bool = False
    is_padding: bool = False

    def __post_init__(self):
        assert self.src_kind in ("none", "primary", "global", "packed")
        assert self.dst_kind in ("none", "primary", "global")
        assert (self.arity == 2) == (self.src_kind == "packed")

    @property
    def constant_name(self) -> str:
        """The ``OP_*`` constant naming this opcode in source."""
        return "OP_" + self.mnemonic.upper()


#: the registry: opcode value -> contract.  EVERY decode path in the tree
#: derives from this dict; adding an opcode starts here.
OPCODES: Dict[int, OpSpec] = {s.value: s for s in (
    OpSpec(OP_NOP, "nop", 0, "none", "none", False, is_padding=True),
    OpSpec(OP_FPM_COPY, "fpm_copy", 1, "primary", "primary", False),
    OpSpec(OP_PSM_COPY, "psm_copy", 1, "primary", "primary", False),
    OpSpec(OP_BASELINE_COPY, "baseline_copy", 1, "primary", "primary",
           False),
    OpSpec(OP_ZERO_INIT, "zero_init", 0, "none", "primary", False),
    OpSpec(OP_CROSS_POOL_COPY, "cross_pool_copy", 1, "global", "global",
           True),
    OpSpec(OP_AND, "and", 2, "packed", "global", True, is_compute=True),
    OpSpec(OP_OR, "or", 2, "packed", "global", True, is_compute=True),
    OpSpec(OP_NOT, "not", 2, "packed", "global", True, is_compute=True),
)}

#: opcode value -> mnemonic (derived; display + benchmarks)
OPCODE_NAMES: Dict[int, str] = {v: s.mnemonic for v, s in OPCODES.items()}

#: ``OP_*`` constant name -> value (derived; what tools/rowlint.py checks
#: source identifiers against)
CONSTANT_NAMES: Dict[str, int] = {s.constant_name: v
                                  for v, s in OPCODES.items()}

#: two-source compute rows (Ambit triple-row activation analogue) —
#: derived from the registry's ``is_compute`` flag
BITWISE_OPS: Tuple[int, ...] = tuple(sorted(
    v for v, s in OPCODES.items() if s.is_compute))

#: single-source primary-space copies (FPM/PSM/baseline) — the kernel and
#: reference switch on this set as one branch
PLAIN_COPY_OPS: Tuple[int, ...] = tuple(sorted(
    v for v, s in OPCODES.items()
    if s.arity == 1 and s.src_kind == "primary"))


def opspec(op: int) -> OpSpec:
    """Look up the :class:`OpSpec` contract for opcode ``op`` (raises
    :class:`UnknownOpcodeError` for values outside the registry)."""
    try:
        return OPCODES[int(op)]
    except KeyError:
        raise UnknownOpcodeError(
            f"opcode {op} has no OpSpec registry entry — declare its "
            "contract in core/opcodes.py before issuing it") from None


def check_pack_total(total: int) -> None:
    """Validate an address-space size against the int32 packing bound.

    Enforced on EVERY pack/unpack — engine construction, the
    CommandQueue's hazard decodes (``enqueue``/``retire``), journal
    replay, and the ShardPlan partitioner — not just at engine
    construction."""
    if total > MAX_PACK_BLOCKS:
        raise ValueError(
            f"bitwise srcB packing overflows int32: address space has "
            f"{total} blocks (> {MAX_PACK_BLOCKS}, whose square is the "
            "int32 ceiling) — shrink the pool group or split it")


def pack_bitwise_src(a: int, b: int, total: int) -> int:
    """Pack two global source ids into one int32 src field: ``a*total+b``.

    ``total`` is the address-space size the packing runs over (the
    PoolGroup's ``total_blocks`` globally, a slab-local stacked total
    inside a ShardPlan) and is bound-checked on every call — see
    :func:`check_pack_total`."""
    check_pack_total(total)
    return a * total + b


def unpack_bitwise_src(src: int, total: int) -> Tuple[int, int]:
    """Invert :func:`pack_bitwise_src` → ``(a, b)`` global ids, validating
    both the packing bound and that ``src`` lies inside the ``total²`` id
    square (a corrupted row fails here with a descriptive error instead
    of silently aliasing another block)."""
    check_pack_total(total)
    src = int(src)
    if not 0 <= src < total * total:
        raise ValueError(
            f"packed bitwise src {src} outside the {total}x{total} "
            "two-source id space — mis-packed or corrupted row")
    return src // total, src % total


def row_rw(op: int, s: int, d: int,
           locate: Callable[[int], Tuple[int, int]],
           total: Optional[int] = None
           ) -> Tuple[Tuple[Tuple[int, int], ...],
                      Tuple[Tuple[int, int], ...]]:
    """The ``(reads, writes)`` hazard keys of one table row, each a tuple
    of ``(pool, block)`` with :data:`ALL_PRIMARY` meaning every primary
    pool — derived entirely from the opcode's :class:`OpSpec`.

    ``locate`` decodes global ids for whatever address space the row
    lives in (the PoolGroup's global ids, or a ShardPlan slab's local
    prefix-sum ids); ``total`` is that space's size, required whenever a
    packed two-source row can appear.  Padding rows carry no operands
    and raise — callers skip ``op < 0`` rows before decoding."""
    sp = opspec(op)
    if sp.is_padding:
        raise ValueError("padding rows (OP_NOP) carry no hazard keys")
    if sp.src_kind == "packed":
        if total is None:
            raise ValueError("bitwise row needs the packing total to "
                             "decode its two sources")
        a, b = unpack_bitwise_src(s, total)
        reads = (locate(a),) if a == b else (locate(a), locate(b))
    elif sp.src_kind == "global":
        reads = (locate(s),)
    elif sp.src_kind == "primary":
        reads = ((ALL_PRIMARY, s),)
    else:
        reads = ()
    if sp.dst_kind == "global":
        writes = (locate(d),)
    else:
        writes = ((ALL_PRIMARY, d),)
    return reads, writes


def keys_clash(a: Tuple[int, int], b: Tuple[int, int],
               primary: Tuple[bool, ...]) -> bool:
    """Do two ``(pool, block)`` hazard keys touch overlapping bytes?
    :data:`ALL_PRIMARY` expands to the primary pool set on either side; a
    staging-pool key only collides with an exact pool match."""
    pa, ba = a
    pb, bb = b
    if ba != bb:
        return False
    if pa == pb:
        return True
    if pa == ALL_PRIMARY:
        return primary[pb]
    if pb == ALL_PRIMARY:
        return primary[pa]
    return False


__all__ = [
    "OP_NOP", "OP_FPM_COPY", "OP_PSM_COPY", "OP_BASELINE_COPY",
    "OP_ZERO_INIT", "OP_CROSS_POOL_COPY", "OP_AND", "OP_OR", "OP_NOT",
    "ALL_PRIMARY", "MAX_PACK_BLOCKS", "OPCODES", "OPCODE_NAMES",
    "CONSTANT_NAMES", "BITWISE_OPS", "PLAIN_COPY_OPS", "OpSpec",
    "UnknownOpcodeError", "opspec", "check_pack_total",
    "pack_bitwise_src", "unpack_bitwise_src", "row_rw", "keys_clash",
]
