"""Asynchronous command streams — ``CommandStream`` and ``FlushTicket``.

RowClone's memory controller does not stop the world at every bulk
operation: copy/init commands queue behind ongoing requests and drain
while the CPU keeps issuing (paper §2.3; LISA pipelines inter-subarray
hops the same way).  The engine API used to hide that asynchrony —
``batch()``/``flush()`` was an implicit global barrier on one anonymous
queue.  This module names it:

* :class:`CommandStream` — an **ordered** stream of bulk-movement
  commands on one engine.  ``engine.stream()`` mints one; callers enqueue
  ``memcopy``/``meminit``/``materialize_zeros``/``memcopy_cross``/
  ``promote_staged`` onto it (no implicit flush on return — asynchrony is
  explicit), or :meth:`CommandStream.capture` an arbitrary code region so
  every engine call inside lands on the stream (how the serving engine
  routes the paged cache's CoW splits into its round stream).
* :class:`FlushTicket` — the receipt ``stream.flush()`` returns: launch
  accounting, drained command count, hazard counters, and post-drain
  block state **on demand** (a zero-copy reference to the post-drain
  pool arrays; nothing is fetched until asked, and the bytes stay
  readable until a LATER flush donates the buffers — ``expired`` /
  a descriptive error mark that boundary, metadata never expires).

Ordering model: commands on ONE stream execute in enqueue order, with the
CommandQueue's hazard matrix (RAW/WAW auto-flush, WAR admitted + spaced
for the overlapped kernel drain — core/cmdqueue.py).  Streams are
unordered against each other until they touch: enqueueing a command that
overlaps ANOTHER stream's pending reads or writes first drains that
stream (the engine's cross-stream guard), so inter-stream conflicts
serialize at (pool, block) granularity instead of a global barrier.

The engine's seed-era surface survives as a compatibility layer: every
``RowCloneEngine`` owns a *default* stream; ``engine.memcopy(...)`` etc.
enqueue there (eager flush-on-return unless inside ``engine.batch()``),
and ``engine.flush()`` drains it — thin wrappers, same semantics.
"""
from __future__ import annotations

import contextlib
import dataclasses
from typing import Any, Dict, Iterator, Optional, Sequence, Tuple, Union

import numpy as np

from repro.core.cmdqueue import CommandQueue
from repro.core.poolspec import BlockRef
from repro.obs.trace import FlushTiming, span


@dataclasses.dataclass(frozen=True)
class FlushTicket:
    """Receipt for one :meth:`CommandStream.flush`.

    Holds the launch accounting of the drain and a zero-copy reference to
    the post-drain pool arrays; block contents transfer from device only
    when :meth:`block_state` asks.  A ticket with ``commands == 0``
    records an empty flush (no device work).

    **Validity window**: the engine's dispatch paths DONATE the pool
    buffers (that is what keeps a flush snapshot-free), so a ticket's
    block state stays readable only until a later flush — or the serving
    decode step — consumes those buffers.  Metadata (``launches``,
    ``commands``, counters) never expires; :attr:`expired` reports
    whether the bytes are still resident, and an expired
    :meth:`block_state`/:meth:`wait` raises a descriptive error instead
    of surfacing jax's deleted-array failure."""

    stream: str                 #: name of the stream that flushed
    seq: int                    #: flush sequence number on that stream
    commands: int               #: command rows drained by this flush
    launches: int               #: device launches the drain issued
    war_hazards: int            #: cumulative WAR commands admitted so far
    spacer_rows: int            #: cumulative overlap spacers inserted
    index: int                  #: engine-wide flush index (-1: empty flush)
    touched: Tuple[str, ...]    #: pools this flush WROTE — wait() blocks
    #: on exactly these, so e.g. a checkpoint-stream ticket (spill-pool
    #: writes only) never serializes against decode's primary traffic
    _engine: Any = dataclasses.field(repr=False)
    _pools: Dict[str, Any] = dataclasses.field(repr=False)
    #: drain timing for this flush (queue residency, drain wall-clock,
    #: padded table length, launches) — None for an empty flush
    timing: Optional[FlushTiming] = None

    @property
    def moved(self) -> bool:
        """Did this flush issue any device work?"""
        return self.launches > 0

    @property
    def expired(self) -> bool:
        """True once a later flush (or decode step) has donated the
        ticket's pool buffers — block state is no longer readable."""
        return any(getattr(p, "is_deleted", lambda: False)()
                   for p in self._pools.values())

    def _check_live(self, names: Optional[Sequence[str]] = None) -> None:
        pools = self._pools if names is None else \
            {n: self._pools[n] for n in names}
        if any(getattr(p, "is_deleted", lambda: False)()
               for p in pools.values()):
            raise RuntimeError(
                f"FlushTicket(stream={self.stream!r}, seq={self.seq}) "
                "expired: a later flush donated the pool buffers it "
                "references — read block_state()/wait() before the next "
                "flush (ticket metadata never expires)")

    def wait(self) -> "FlushTicket":
        """Block until the pools this flush WROTE are resident (the
        explicit synchronization point callers opt into — jax dispatch is
        asynchronous underneath).  Per-ticket wait events are scoped to
        ``touched``: waiting on a checkpoint-stream ticket synchronizes
        the spill pools only, not the decode path's primary pools — and
        stays valid even after decode donates the primaries."""
        import jax
        self._check_live(self.touched)
        with span("ticket-wait", stream=self.stream, seq=self.seq):
            jax.block_until_ready([self._pools[n] for n in self.touched])
        return self

    def block_state(self, ref: Union[BlockRef, int]
                    ) -> Union[np.ndarray, Dict[str, np.ndarray]]:
        """Post-drain contents of one block, fetched on demand (valid
        until a later flush donates the buffers — see the class
        docstring; only the pools actually READ here must still be
        resident).

        A :class:`BlockRef` returns that pool's block; a bare int (a
        primary-address-space id) returns ``{pool name: block}`` over
        every primary pool — the shape a plain opcode moves."""
        ba = self._engine.block_axis
        if isinstance(ref, BlockRef):
            self._check_live([ref.pool])
            pool = self._pools[ref.pool]
            b = int(ref.block)
            return np.asarray(pool[b] if ba == 0 else pool[:, b])
        self._check_live(self._engine.primary_names)
        b = int(ref)
        return {name: np.asarray(self._pools[name][b] if ba == 0
                                 else self._pools[name][:, b])
                for name in self._engine.primary_names}


class CommandStream:
    """An ordered bulk-movement command stream on one RowCloneEngine.

    Mint with ``engine.stream(name)``.  Enqueue calls mirror the engine's
    public API but do NOT flush on return — the device sees the stream's
    work when :meth:`flush` is called (returning a :class:`FlushTicket`),
    when a RAW/WAW hazard inside the stream forces an early drain, or
    when another stream's conflicting enqueue serializes this one.
    """

    def __init__(self, engine, name: str,
                 queue: Optional[CommandQueue] = None):
        self.engine = engine
        self.name = name
        self.queue = queue if queue is not None else CommandQueue(engine)
        self.queue.name = name   # journal records carry the stream name
        self._seq = 0

    def __len__(self) -> int:
        return len(self.queue)

    def __repr__(self) -> str:
        return (f"CommandStream({self.name!r}, pending={len(self.queue)}, "
                f"flushed={self._seq})")

    @property
    def pending(self):
        """Copy of the not-yet-flushed ``(opcode, src, dst)`` rows."""
        return self.queue.pending

    # ------------------------------------------------------------------
    @contextlib.contextmanager
    def capture(self) -> Iterator["CommandStream"]:
        """Route every engine enqueue inside the block onto THIS stream,
        deferred (no flush-on-return).  The serving engine wraps a whole
        round's cache work in one capture so promotions + CoW splits +
        tail inits accumulate on its round stream and drain as one
        launch at ``flush()``."""
        eng = self.engine
        prev_q, prev_d = eng._cur_queue, eng.deferred
        eng._cur_queue, eng.deferred = self.queue, True
        try:
            yield self
        finally:
            eng._cur_queue, eng.deferred = prev_q, prev_d

    # ------------------------------------------------------------------
    # enqueue surface — the engine's public verbs, routed onto this stream
    # ------------------------------------------------------------------
    def memcopy(self, pairs: Sequence[Tuple[object, object]],
                dst_is_fresh: bool = False):
        """Enqueue block copies (``RowCloneEngine.memcopy`` semantics)."""
        with self.capture():
            return self.engine.memcopy(pairs, dst_is_fresh=dst_is_fresh)

    def memcopy_cross(self, pairs: Sequence[Tuple[BlockRef, BlockRef]]):
        """Enqueue pool-to-pool copies (``memcopy_cross`` semantics)."""
        with self.capture():
            return self.engine.memcopy_cross(pairs)

    def meminit(self, ids: Sequence[object], lazy: Optional[bool] = None):
        """Enqueue zero-inits (``RowCloneEngine.meminit`` semantics —
        with ZI this is metadata-only and enqueues nothing)."""
        with self.capture():
            return self.engine.meminit(ids, lazy=lazy)

    def memand(self, triples):
        """Enqueue bitwise ANDs (``RowCloneEngine.memand`` semantics)."""
        with self.capture():
            return self.engine.memand(triples)

    def memor(self, triples):
        """Enqueue bitwise ORs (``RowCloneEngine.memor`` semantics)."""
        with self.capture():
            return self.engine.memor(triples)

    def memnot(self, pairs):
        """Enqueue bitwise NOTs (``RowCloneEngine.memnot`` semantics)."""
        with self.capture():
            return self.engine.memnot(pairs)

    def materialize_zeros(self, ids: Sequence[object]):
        """Enqueue BuZ zero-row broadcasts (``materialize_zeros``)."""
        with self.capture():
            return self.engine.materialize_zeros(ids)

    def promote_staged(self, pairs: Sequence[Tuple[int, object]]):
        """Enqueue staging→primary promotions (``promote_staged``)."""
        with self.capture():
            return self.engine.promote_staged(pairs)

    def demote_to_spill(self, blocks: Sequence[object]):
        """Enqueue primary→spill demotions (``demote_to_spill``
        semantics — preemption parks the blocks' bytes in spill slots;
        returns the slot ids)."""
        with self.capture():
            return self.engine.demote_to_spill(blocks)

    def promote_spilled(self, pairs: Sequence[Tuple[int, object]]):
        """Enqueue spill→primary resume promotions (``promote_spilled``
        semantics)."""
        with self.capture():
            return self.engine.promote_spilled(pairs)

    # ------------------------------------------------------------------
    def adopt(self, other: "CommandStream") -> int:
        """Transfer another stream's pending rows onto THIS stream.

        The QoS *lane merge*: a scheduler keeps per-tenant lanes as
        dedicated streams, then adopts them into the round's serve stream
        in priority order — adoption order is enqueue order is DMA issue
        order in the fused table, so one flush drains every lane's work
        as ONE launch while higher-priority traffic still issues first.
        Rows leave ``other`` atomically (its queue empties without
        dispatching) and re-enqueue here one by one, re-running the full
        hazard matrix — ordering guarantees survive the transfer.
        Returns the number of rows adopted."""
        if other is self:
            return 0
        rows = other.queue.abort()
        for op, s, d in rows:
            self.queue.enqueue(op, s, d)
        return len(rows)

    # ------------------------------------------------------------------
    def flush(self) -> FlushTicket:
        """Drain the stream's pending commands and return the
        :class:`FlushTicket` receipt (commands drained, launches issued,
        post-drain block state on demand)."""
        rows = self.queue.pending
        n = len(rows)
        index = self.engine.next_flush_index if n else -1
        with span("flush", stream=self.name, seq=self._seq):
            launches = self.queue.flush()
        timing = getattr(self.engine, "last_drain_timing", None) if n else None
        ticket = FlushTicket(
            stream=self.name, seq=self._seq, commands=n, launches=launches,
            war_hazards=self.queue.stats.war_hazards,
            spacer_rows=self.queue.stats.spacer_rows,
            index=index, touched=self.engine._touched_pools(rows),
            _engine=self.engine, _pools=dict(self.engine.pools),
            timing=timing)
        self._seq += 1
        return ticket


__all__ = ["CommandStream", "FlushTicket"]
