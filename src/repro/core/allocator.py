"""Subarray-aware block allocator — the paper's OS-level contribution.

RowClone §2.3/§3.1: to maximize FPM use, the system software must be aware of
subarrays and allocate copy *destinations in the same subarray as the
source*.  Here a "subarray" is one device slab of a sharded block pool; the
allocator keeps a free list per slab, reference counts for CoW sharing, and
the lazy-zero bit used by RowClone-ZI.

This is host-side metadata (numpy) — the data-plane ops (FPM/PSM/zero
kernels) consume the id lists this allocator produces.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np


class OutOfBlocks(RuntimeError):
    """Raised when an allocation cannot be satisfied from the allowed
    slabs (the pool — or a batch group's slab subset — is exhausted)."""


@dataclasses.dataclass
class AllocStats:
    allocs: int = 0
    frees: int = 0
    cow_shares: int = 0
    fpm_eligible: int = 0      # destination landed in the source's slab
    psm_fallback: int = 0      # had to place cross-slab
    lazy_zero: int = 0         # zero requests satisfied by metadata only
    materialized_zero: int = 0


class SubarrayAllocator:
    """Free-list allocator over ``num_blocks`` partitioned into ``num_slabs``
    equal slabs (= device shards of the pool's block axis)."""

    def __init__(self, num_blocks: int, num_slabs: int,
                 reserved_zero_per_slab: int = 1):
        assert num_blocks % num_slabs == 0
        self.num_blocks = num_blocks
        self.num_slabs = num_slabs
        self.slab_size = num_blocks // num_slabs
        self.refcount = np.zeros(num_blocks, np.int32)
        self.is_zero = np.zeros(num_blocks, bool)   # ZI lazy-zero bit
        self.stats = AllocStats()
        self._free: List[List[int]] = []
        self.zero_rows: List[int] = []              # reserved per-slab rows
        for s in range(num_slabs):
            lo, hi = s * self.slab_size, (s + 1) * self.slab_size
            rows = list(range(lo, hi))
            reserved = rows[:reserved_zero_per_slab]
            self.zero_rows.extend(reserved)
            self.refcount[reserved] = 1             # pinned forever
            self.is_zero[reserved] = True
            self._free.append(rows[reserved_zero_per_slab:])

    # ------------------------------------------------------------------
    def slab_of(self, block_id: int) -> int:
        """Slab ("subarray") index holding ``block_id``."""
        return block_id // self.slab_size

    def free_in_slab(self, slab: int) -> int:
        """Free blocks remaining in one slab."""
        return len(self._free[slab])

    def total_free(self) -> int:
        """Free blocks remaining across every slab."""
        return sum(len(f) for f in self._free)

    # ------------------------------------------------------------------
    def alloc(self, n: int = 1, prefer_slab: Optional[int] = None,
              zeroed: bool = False,
              allowed_slabs: Optional[Sequence[int]] = None) -> List[int]:
        """Allocate ``n`` blocks, preferring ``prefer_slab`` (subarray-aware
        placement).  Falls back to the least-loaded slab.

        ``allowed_slabs`` restricts the fallback set — the sharded-batch
        serving tables use it to pin a sequence's blocks inside the device
        group that owns the sequence's batch slot, so share-mask columns
        can use local numbering.  Raises :class:`OutOfBlocks` when the
        allowed slabs are exhausted rather than silently crossing the
        group boundary."""
        out: List[int] = []
        pool = (list(allowed_slabs) if allowed_slabs is not None
                else list(range(self.num_slabs)))
        for _ in range(n):
            slab = prefer_slab
            if slab is None or slab not in pool or not self._free[slab]:
                if prefer_slab is not None:
                    self.stats.psm_fallback += 1
                slab = pool[int(np.argmax([len(self._free[s])
                                           for s in pool]))]
                if not self._free[slab]:
                    # roll back this request's partial grab: group
                    # exhaustion is a routine, recoverable event for the
                    # sharded-batch serving tables, and leaked blocks
                    # would permanently shrink the group
                    self.free(out)
                    self.stats.allocs -= len(out)
                    self.stats.frees -= len(out)
                    raise OutOfBlocks(
                        f"pool exhausted ({self.num_blocks} blocks, "
                        f"slabs {pool})")
            elif prefer_slab is not None:
                self.stats.fpm_eligible += 1
            bid = self._free[slab].pop()
            self.refcount[bid] = 1
            self.is_zero[bid] = bool(zeroed)
            out.append(bid)
            self.stats.allocs += 1
        return out

    def alloc_near(self, src_block: int, zeroed: bool = False,
                   allowed_slabs: Optional[Sequence[int]] = None) -> int:
        """CoW destination placement: same slab as the source when possible
        (paper §3.1 — enables FPM for the copy)."""
        return self.alloc(1, prefer_slab=self.slab_of(src_block),
                          zeroed=zeroed, allowed_slabs=allowed_slabs)[0]

    def share(self, ids: Sequence[int]) -> None:
        """CoW share (fork): bump refcounts — the ZI 'in-cache copy': no
        bytes move."""
        for b in ids:
            assert self.refcount[b] > 0, f"share of unallocated block {b}"
            self.refcount[b] += 1
            self.stats.cow_shares += 1

    def free(self, ids: Sequence[int]) -> None:
        """Drop one reference per id; blocks return to their slab's free
        list when the last sharer releases them."""
        for b in ids:
            assert self.refcount[b] > 0, f"double free of block {b}"
            self.refcount[b] -= 1
            self.stats.frees += 1
            if self.refcount[b] == 0:
                self._free[self.slab_of(b)].append(int(b))

    def is_shared(self, block_id: int) -> bool:
        """More than one sequence references the block (CoW pending)."""
        return self.refcount[block_id] > 1

    # ------------------------------------------------------------------
    def mark_zero(self, ids: Sequence[int]) -> None:
        """Set the ZI lazy-zero bit: the blocks are LOGICALLY zero in
        every primary pool while physically holding stale bytes."""
        self.is_zero[list(ids)] = True
        self.stats.lazy_zero += len(ids)

    def mark_written(self, ids: Sequence[int]) -> None:
        """Clear the lazy-zero bit: the blocks now hold real data."""
        self.is_zero[list(ids)] = False

    def pending_zero(self, ids: Sequence[int]) -> List[int]:
        """Blocks that must be physically zeroed before a non-masking
        consumer touches them."""
        return [int(b) for b in ids if self.is_zero[b]]

    def zero_row_of(self, slab: int) -> int:
        """The slab's reserved all-zero row (the BuZ broadcast source)."""
        return self.zero_rows[slab]
