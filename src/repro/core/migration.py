"""PSM migration planner — RowClone's page-migration application (§3.2).

Plans block moves between slabs (devices) for load-balancing / elastic
scaling / defragmentation, batched by (src_slab, dst_slab) pair and issued
in pipelined chunks through the engine's PSM path (ICI collectives — the
DRAM internal-bus TRANSFER analogue, with the pipelining done by chunking).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.core.allocator import SubarrayAllocator
from repro.core.cow_cache import PagedCoWCache
from repro.core.rowclone import RowCloneEngine


@dataclasses.dataclass
class MigrationPlan:
    moves: List[Tuple[int, int]]            # (src_block, dst_block)
    pair_batches: Dict[Tuple[int, int], List[Tuple[int, int]]]
    seq_updates: Dict[int, Dict[int, int]]  # seq_id -> {old_block: new_block}


def plan_rebalance(cache: PagedCoWCache,
                   target_load: Optional[np.ndarray] = None) -> MigrationPlan:
    """Move blocks from overloaded slabs to underloaded ones.

    Load = allocated blocks per slab.  Sequences keep their slab_home so the
    planner only migrates *whole sequences* whose home slab is overloaded —
    keeping the FPM locality invariant after migration.
    """
    alloc = cache.alloc
    used = np.zeros(alloc.num_slabs, np.int64)
    for seq in cache.seqs.values():
        for b in seq.blocks:
            used[alloc.slab_of(b)] += 1
    if target_load is None:
        target_load = np.full(alloc.num_slabs, used.mean())

    overloaded = [s for s in range(alloc.num_slabs)
                  if used[s] > target_load[s] + 1]

    moves: List[Tuple[int, int]] = []
    seq_updates: Dict[int, Dict[int, int]] = {}
    for s_over in overloaded:
        # pick sequences homed on the overloaded slab, smallest first
        victims = sorted((q for q in cache.seqs.values()
                          if q.slab_home == s_over and
                          not any(alloc.is_shared(b) for b in q.blocks)),
                         key=lambda q: len(q.blocks))
        for seq in victims:
            if used[s_over] <= target_load[s_over] + 1:
                break
            need = len(seq.blocks)
            # re-pick the least-loaded destination with room, every move
            candidates = [s for s in range(alloc.num_slabs)
                          if s != s_over and used[s] + need <=
                          target_load[s] + 1 and
                          alloc.free_in_slab(s) >= need]
            if not candidates:
                break
            dst = min(candidates, key=lambda s: used[s])
            new_blocks = alloc.alloc(need, prefer_slab=dst)
            upd = {}
            for old, new in zip(seq.blocks, new_blocks):
                moves.append((old, new))
                upd[old] = new
            seq_updates[seq.seq_id] = upd
            used[s_over] -= need
            used[dst] += need

    batches: Dict[Tuple[int, int], List[Tuple[int, int]]] = {}
    for s, d in moves:
        key = (alloc.slab_of(s), alloc.slab_of(d))
        batches.setdefault(key, []).append((s, d))
    return MigrationPlan(moves, batches, seq_updates)


def execute(plan: MigrationPlan, cache: PagedCoWCache,
            chunk_blocks: int = 8) -> Dict[str, int]:
    """Issue the plan through the engine (PSM), pipelined in chunks, then
    commit table updates and free the old blocks.  The commit is a single
    metadata flip per sequence — the paper's MC-serialized command
    semantics: readers never observe a half-migrated sequence."""
    eng: RowCloneEngine = cache.engine
    alloc = cache.alloc
    issued = 0
    for pair, pairs in plan.pair_batches.items():
        for i in range(0, len(pairs), chunk_blocks):
            eng.memcopy(pairs[i: i + chunk_blocks])
            issued += len(pairs[i: i + chunk_blocks])
    # commit: swap ids in sequence tables, free sources
    for sid, upd in plan.seq_updates.items():
        seq = cache.seqs[sid]
        seq.blocks = [upd.get(b, b) for b in seq.blocks]
        alloc.free(list(upd.keys()))
        seq.slab_home = alloc.slab_of(seq.blocks[0]) if seq.blocks \
            else seq.slab_home
    cache._dirty = True
    return {"moved_blocks": issued, "psm": eng.stats.psm_copies}
