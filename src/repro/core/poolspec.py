"""First-class pool address space — ``PoolSpec``, ``BlockRef``, ``PoolGroup``.

RowClone's mechanisms are *addressed* operations: FPM/PSM/BuZ each name a
source and a destination row in a concrete bank layout, and Seshadri's
thesis argues the system software should sit behind an explicit addressing
abstraction rather than hard-coding the layout into every caller.  The
engine's original API did exactly that hard-coding: pools were a positional
list, every pool shared one block count, and cross-pool commands carried
stacked ``pool_index * nblk + block`` ids — which forced staging pools to be
exact-size twins of their KV pools and doubled serving memory.

This module is the explicit abstraction:

* :class:`PoolSpec` — one pool's layout descriptor: name, per-pool block
  count (``nblk``), block shape/dtype, role (``primary`` | ``staging``),
  the primary twin a staging pool promotes into, and a sharding hint.
* :class:`BlockRef` — a ``(pool, block)`` address.  The engine's public
  calls accept these; int-only forms remain as one-release shims.
* :class:`PoolGroup` — an ordered set of specs with **prefix-sum base
  offsets**: the global id of ``BlockRef(p, b)`` is ``base[p] + b``, where
  ``base`` is the running sum of earlier pools' block counts.  With equal
  block counts this degenerates to the old stacked arithmetic; with
  unequal counts, pools of different sizes coexist in one opcode table —
  a staging *ring* of a few blocks rides the same fused launch as a large
  KV pool.

Every consumer of the old arithmetic (CommandQueue hazard keys,
``partition_commands``, the fused-dispatch kernel and its jnp reference,
the legacy fan-out) now routes through a ``PoolGroup``.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Iterator, Optional, Sequence, Tuple, Union


@dataclasses.dataclass(frozen=True)
class PoolSpec:
    """Layout descriptor for one block pool.

    ``nblk`` is *per pool* — staging pools may be much smaller than the
    primary pools they promote into (the staging-ring configuration that
    halves serving memory).  ``block_shape``/``dtype`` describe one block
    (every axis except the block axis) and are metadata: the arrays
    themselves live in the engine's pool dict.  ``role`` is ``"primary"``
    (plain opcodes move the named block here), ``"staging"`` (reachable
    only through cross-pool commands; prefill pages park here before
    promotion), or ``"spill"`` (also cross-pool-only; the background
    checkpoint stream's snapshot destination — see
    checkpoint/pool_checkpoint.py).  Staging and spill specs name their
    primary twin in ``paired``.  ``sharding`` is an optional hint naming
    the mesh axes the block axis shards over (the serving layout uses
    ``("pod", "data", "model")``)."""

    name: str
    nblk: int
    block_shape: Tuple[int, ...] = ()
    dtype: Optional[object] = None
    role: str = "primary"
    paired: Optional[str] = None
    sharding: Optional[Tuple[str, ...]] = None

    def __post_init__(self):
        if self.nblk <= 0:
            raise ValueError(f"pool {self.name!r}: nblk={self.nblk} <= 0")
        if self.role not in ("primary", "staging", "spill"):
            raise ValueError(f"pool {self.name!r}: unknown role "
                             f"{self.role!r}")
        if self.role in ("staging", "spill") and not self.paired:
            raise ValueError(f"{self.role} pool {self.name!r} must name "
                             "its primary twin in `paired`")


@dataclasses.dataclass(frozen=True, order=True)
class BlockRef:
    """An addressed block: ``(pool name, block id local to that pool)``.

    The canonical operand of the engine's copy/init calls — resolved to a
    global table id through the engine's :class:`PoolGroup`."""

    pool: str
    block: int


class PoolGroup:
    """Ordered pool specs + the prefix-sum base-offset table.

    The group is the single owner of global-id arithmetic: a command table
    row addressing ``BlockRef(p, b)`` encodes it as ``base(p) + b``; the
    inverse (:meth:`locate`) recovers ``(pool index, local block)`` from a
    global id.  Order matters — it is the pool-argument order of every
    fused launch, and the base offsets are the running sums of ``nblk`` in
    that order."""

    def __init__(self, specs: Sequence[PoolSpec]):
        specs = tuple(specs)
        if not specs:
            raise ValueError("PoolGroup needs at least one PoolSpec")
        names = [s.name for s in specs]
        if len(set(names)) != len(names):
            raise ValueError(f"duplicate pool names: {names}")
        for s in specs:
            if s.role in ("staging", "spill"):
                twin = next((p for p in specs if p.name == s.paired), None)
                if twin is None or twin.role != "primary":
                    raise ValueError(
                        f"{s.role} pool {s.name!r} pairs with "
                        f"{s.paired!r}, which is not a primary pool")
        # plain opcodes carry ONE block id for every primary pool, so the
        # primary pools must share a single address space; enforcing it
        # here protects every bare-group consumer (partition_commands,
        # the kernels), not just the engine constructor
        primary_nblks = {s.nblk for s in specs if s.role == "primary"}
        if len(primary_nblks) > 1:
            raise ValueError(
                "primary pools must share one block count (plain opcodes "
                "address them with a single id): "
                f"{[(s.name, s.nblk) for s in specs if s.role == 'primary']}")
        self.specs = specs
        self._index: Dict[str, int] = {s.name: i for i, s in
                                       enumerate(specs)}
        bases = []
        run = 0
        for s in specs:
            bases.append(run)
            run += s.nblk
        self._bases: Tuple[int, ...] = tuple(bases)
        self._total = run

    # ------------------------------------------------------------------
    def __len__(self) -> int:
        return len(self.specs)

    def __iter__(self) -> Iterator[PoolSpec]:
        return iter(self.specs)

    def __getitem__(self, key: Union[int, str]) -> PoolSpec:
        if isinstance(key, str):
            return self.specs[self._index[key]]
        return self.specs[key]

    @property
    def names(self) -> Tuple[str, ...]:
        """Pool names in table order."""
        return tuple(s.name for s in self.specs)

    @property
    def bases(self) -> Tuple[int, ...]:
        """Per-pool global-id base offsets (prefix sums of ``nblk``)."""
        return self._bases

    @property
    def nblks(self) -> Tuple[int, ...]:
        """Per-pool block counts, in table order."""
        return tuple(s.nblk for s in self.specs)

    @property
    def total_blocks(self) -> int:
        """Size of the global id space (sum of every pool's ``nblk``)."""
        return self._total

    @property
    def primary(self) -> Tuple[bool, ...]:
        """Per-pool role vector: True where plain opcodes land."""
        return tuple(s.role == "primary" for s in self.specs)

    @property
    def n_primary(self) -> int:
        """Number of primary pools."""
        return sum(self.primary)

    @property
    def primary_names(self) -> Tuple[str, ...]:
        """Names of the primary pools, in table order."""
        return tuple(s.name for s in self.specs if s.role == "primary")

    @property
    def staging_map(self) -> Dict[str, str]:
        """staging pool name -> its paired primary pool name."""
        return {s.name: s.paired for s in self.specs
                if s.role == "staging"}

    def index(self, name: str) -> int:
        """Table position of pool ``name``."""
        return self._index[name]

    # ------------------------------------------------------------------
    def base(self, pool: Union[int, str]) -> int:
        """Global-id base offset of one pool."""
        if isinstance(pool, str):
            pool = self._index[pool]
        return self._bases[pool]

    def gid(self, ref: BlockRef) -> int:
        """Encode a :class:`BlockRef` as a global table id, validating the
        block against the pool's own ``nblk``."""
        i = self._index[ref.pool]
        b = int(ref.block)
        if not 0 <= b < self.specs[i].nblk:
            raise ValueError(
                f"block {b} out of range for pool {ref.pool!r} "
                f"(nblk={self.specs[i].nblk})")
        return self._bases[i] + b

    def locate(self, gid: int) -> Tuple[int, int]:
        """Inverse of :meth:`gid`: global id -> (pool index, local block)."""
        gid = int(gid)
        if not 0 <= gid < self._total:
            raise ValueError(f"global id {gid} outside the group's "
                             f"{self._total}-block address space")
        # linear scan: pool counts are tiny (2-8), and this is host-side
        for i in range(len(self.specs) - 1, -1, -1):
            if gid >= self._bases[i]:
                return i, gid - self._bases[i]
        raise AssertionError("unreachable")

    def ref(self, gid: int) -> BlockRef:
        """Global id -> :class:`BlockRef`."""
        i, b = self.locate(gid)
        return BlockRef(self.specs[i].name, b)

    # ------------------------------------------------------------------
    @classmethod
    def from_pools(cls, pools: Dict[str, object], *, block_axis: int = 0,
                   staging: Optional[Dict[str, str]] = None,
                   sharding: Optional[Tuple[str, ...]] = None
                   ) -> "PoolGroup":
        """Build a group from a name -> array dict (the engine's legacy
        constructor input): per-pool ``nblk`` from each array's block
        axis, roles from the ``staging`` map."""
        staging = staging or {}
        specs = []
        for name, arr in pools.items():
            shape = list(arr.shape)
            nblk = shape.pop(block_axis)
            specs.append(PoolSpec(
                name=name, nblk=int(nblk), block_shape=tuple(shape),
                dtype=arr.dtype,
                role="staging" if name in staging else "primary",
                paired=staging.get(name), sharding=sharding))
        return cls(specs)


__all__ = ["PoolSpec", "BlockRef", "PoolGroup"]
