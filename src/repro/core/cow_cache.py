"""Copy-on-Write paged KV cache — the paper's killer app, as a serving engine.

RowClone §3.1 CoW: the OS points both virtual pages at one physical page and
copies only on the first write, placing the destination in the source's
subarray so FPM applies.  The serving analogue: ``fork()`` of a sequence
(parallel sampling, beam search, prefix sharing) shares KV blocks by
refcount; the first *append* to a shared block triggers a block copy through
the RowCloneEngine — FPM when the allocator kept the destination in the same
slab, which it does by construction via ``alloc_near``.

Bulk zeroing (§3.1 BuZ): fresh blocks are "zeroed" via the ZI lazy-zero bit
(paged attention masks invalid slots, so zeroing is metadata-only — the
clean-zero-insertion analogue).

Host-side object; device arrays live in the engine's pools and the
block-table/owner/base arrays this cache rebuilds incrementally.
"""
from __future__ import annotations

import dataclasses
# NB: no typing.Sequence import — the Sequence dataclass below would
# shadow it (annotations here use List/Tuple instead)
from typing import Dict, List, Optional, Tuple

import jax.numpy as jnp
import numpy as np

from repro.core.allocator import SubarrayAllocator
from repro.core.rowclone import RowCloneEngine


@dataclasses.dataclass
class Sequence:
    seq_id: int
    length: int
    blocks: List[int]          # pool block ids, in order
    slab_home: int             # preferred slab ("subarray" affinity)


class PagedCoWCache:
    """Block-table manager with CoW fork over a RowCloneEngine."""

    def __init__(self, engine: RowCloneEngine, page: int,
                 max_blocks_per_seq: int, max_seqs: int):
        self.engine = engine
        self.alloc: SubarrayAllocator = engine.alloc
        self.page = page
        self.max_blocks_per_seq = max_blocks_per_seq
        self.max_seqs = max_seqs
        self.seqs: Dict[int, Sequence] = {}
        self._next_id = 0
        # device-visible tables (rebuilt lazily)
        self._dirty = True
        self._table = np.full((max_seqs, max_blocks_per_seq), -1, np.int32)
        self._mask = np.zeros((self.alloc.num_blocks, max_seqs), np.int8)
        self._base = np.zeros(self.alloc.num_blocks, np.int32)
        self._slot_of: Dict[int, int] = {}      # seq_id -> table row
        self._free_slots = list(range(max_seqs - 1, -1, -1))

    # ------------------------------------------------------------------
    def new_sequence(self, prompt_len: int = 0,
                     prefer_slab: Optional[int] = None) -> int:
        sid = self._next_id
        self._next_id += 1
        nblk = (prompt_len + self.page - 1) // self.page
        if prefer_slab is None:
            prefer_slab = sid % self.alloc.num_slabs
        blocks = self.alloc.alloc(nblk, prefer_slab=prefer_slab, zeroed=False)
        if blocks:
            # fresh blocks logically zeroed via ZI (BuZ, metadata-only)
            self.engine.meminit(blocks)
        self.seqs[sid] = Sequence(sid, prompt_len, blocks, prefer_slab)
        slot = self._free_slots.pop()
        self._slot_of[sid] = slot
        self._dirty = True
        return sid

    def fork(self, parent_id: int, n_children: int = 1,
             eager_copy: bool = False) -> List[int]:
        """CoW fork: children share every parent block (refcount bump — the
        in-cache-copy: zero bytes move now).

        ``eager_copy=True`` physically clones every block instead (callers
        that know the children diverge immediately, e.g. beam search with
        per-beam sampling state): destinations are allocated in the
        source's slab (FPM placement) and all copies for all children
        enqueue into the engine's command queue, draining as ONE fused
        launch at the end of the fork."""
        parent = self.seqs[parent_id]
        out = []
        with self.engine.batch():
            for _ in range(n_children):
                sid = self._next_id
                self._next_id += 1
                if eager_copy and parent.blocks:
                    blocks = [self.alloc.alloc_near(b)
                              for b in parent.blocks]
                    self.engine.memcopy(list(zip(parent.blocks, blocks)))
                else:
                    self.alloc.share(parent.blocks)
                    blocks = list(parent.blocks)
                self.seqs[sid] = Sequence(sid, parent.length, blocks,
                                          parent.slab_home)
                slot = self._free_slots.pop()
                self._slot_of[sid] = slot
                out.append(sid)
        self._dirty = True
        return out

    def append_token(self, seq_id: int) -> Tuple[int, int]:
        """Reserve the slot for one new token; performs CoW block split
        and/or block allocation as needed.  Returns (block_id, offset)."""
        seq = self.seqs[seq_id]
        pos = seq.length
        j = pos // self.page
        off = pos % self.page
        if j >= self.max_blocks_per_seq:
            raise ValueError("sequence exceeds max_blocks_per_seq")
        if j >= len(seq.blocks):
            # new tail block — ZI-lazy-zeroed fresh block, FPM-local
            nb = self.alloc.alloc(1, prefer_slab=seq.slab_home,
                                  zeroed=False)[0]
            self.engine.meminit([nb])
            seq.blocks.append(nb)
            self._dirty = True
        else:
            b = seq.blocks[j]
            if self.alloc.is_shared(b):
                # CoW write to a shared block: allocate in the SAME slab
                # (subarray-aware placement) and copy via the engine — FPM.
                nb = self.alloc.alloc_near(b)
                self.engine.memcopy([(b, nb)])
                self.alloc.free([b])
                seq.blocks[j] = nb
                self._dirty = True
        seq.length = pos + 1
        return seq.blocks[j], off

    def append_tokens(self, seq_ids: List[int]) -> List[Tuple[int, int]]:
        """One decode step for a batch of sequences: every CoW split and
        tail-block init enqueues into the engine's command queue, and the
        device sees exactly ONE fused launch at the flush boundary (the
        seed path issued up to one launch per mechanism per pool *per
        sequence*).  Returns [(block_id, offset), ...] in input order."""
        with self.engine.batch():
            return [self.append_token(sid) for sid in seq_ids]

    def free_sequence(self, seq_id: int) -> None:
        seq = self.seqs.pop(seq_id)
        self.alloc.free(seq.blocks)
        self._free_slots.append(self._slot_of.pop(seq_id))
        self._dirty = True

    # ------------------------------------------------------------------
    # device-visible views
    # ------------------------------------------------------------------
    def rebuild_tables(self) -> None:
        self._table.fill(-1)
        self._mask.fill(0)
        self._base.fill(0)
        for sid, seq in self.seqs.items():
            slot = self._slot_of[sid]
            for j, b in enumerate(seq.blocks):
                self._table[slot, j] = b
                # CoW-shared blocks simply set several share-mask columns —
                # the slab-sweep attention serves every sharer from the one
                # physical block (the in-memory dedup the paper's VM-clone
                # application relies on).
                self._mask[b, slot] = 1
                self._base[b] = j * self.page
        self._dirty = False

    def device_tables(self):
        if self._dirty:
            self.rebuild_tables()
        return (jnp.asarray(self._table), jnp.asarray(self._mask),
                jnp.asarray(self._base))

    def seq_lens(self) -> np.ndarray:
        lens = np.zeros(self.max_seqs, np.int32)
        for sid, seq in self.seqs.items():
            lens[self._slot_of[sid]] = seq.length
        return lens

    def slot_of(self, seq_id: int) -> int:
        return self._slot_of[seq_id]

    # convenience for tests/benchmarks
    def blocks_of(self, seq_id: int) -> List[int]:
        return list(self.seqs[seq_id].blocks)
