"""Copy-on-Write paged KV cache — the paper's killer app, as a serving engine.

RowClone §3.1 CoW: the OS points both virtual pages at one physical page and
copies only on the first write, placing the destination in the source's
subarray so FPM applies.  The serving analogue: ``fork()`` of a sequence
(parallel sampling, beam search, prefix sharing) shares KV blocks by
refcount; the first *append* to a shared block triggers a block copy through
the RowCloneEngine — FPM when the allocator kept the destination in the same
slab, which it does by construction via ``alloc_near``.

Bulk zeroing (§3.1 BuZ): fresh blocks are "zeroed" via the ZI lazy-zero bit
(paged attention masks invalid slots, so zeroing is metadata-only — the
clean-zero-insertion analogue).

Host-side object; device arrays live in the engine's pools and the
block-table/owner/base arrays this cache rebuilds incrementally.
"""
from __future__ import annotations

import dataclasses
# NB: no typing.Sequence import — the Sequence dataclass below would
# shadow it (annotations here use List/Tuple instead)
from typing import Dict, List, Optional, Tuple

import jax.numpy as jnp
import numpy as np

from repro.core.allocator import OutOfBlocks, SubarrayAllocator
from repro.core.rowclone import RowCloneEngine


@dataclasses.dataclass
class Sequence:
    seq_id: int
    length: int
    blocks: List[int]          # pool block ids, in order
    slab_home: int             # preferred slab ("subarray" affinity)
    group: int = 0             # batch group owning the sequence's slot


class PagedCoWCache:
    """Block-table manager with CoW fork over a RowCloneEngine.

    ``batch_groups`` > 1 enables the sharded-batch serving tables: the
    decode batch shards over the mesh's (pod, data) axes into that many
    device groups, so ``device_tables`` emits share-mask columns in LOCAL
    batch numbering (``max_seqs // batch_groups`` columns; column = slot %
    local batch) and every sequence's blocks are pinned inside its group's
    slabs — the placement that lets each device group serve its own
    sequences from its own slab sweep.  ``batch_groups=1`` (default) keeps
    the seed's global columns and unconstrained placement.
    """

    def __init__(self, engine: RowCloneEngine, page: int,
                 max_blocks_per_seq: int, max_seqs: int,
                 batch_groups: int = 1):
        self.engine = engine
        self.alloc: SubarrayAllocator = engine.alloc
        self.page = page
        self.max_blocks_per_seq = max_blocks_per_seq
        self.max_seqs = max_seqs
        if batch_groups > 1:
            if max_seqs % batch_groups or \
                    self.alloc.num_blocks % batch_groups or \
                    self.alloc.num_slabs % batch_groups:
                raise ValueError(
                    f"batch_groups={batch_groups} must divide max_seqs="
                    f"{max_seqs}, nblk={self.alloc.num_blocks} and "
                    f"num_slabs={self.alloc.num_slabs}")
        self.batch_groups = batch_groups
        self.b_local = max_seqs // batch_groups
        self.seqs: Dict[int, Sequence] = {}
        self._next_id = 0
        # device-visible tables (rebuilt lazily)
        self._dirty = True
        self._table = np.full((max_seqs, max_blocks_per_seq), -1, np.int32)
        self._mask = np.zeros((self.alloc.num_blocks, self.b_local), np.int8)
        self._base = np.zeros(self.alloc.num_blocks, np.int32)
        self._slot_of: Dict[int, int] = {}      # seq_id -> table row
        # per-group slot free lists (one global group when unsharded)
        self._free_slots: List[List[int]] = [
            list(range((g + 1) * self.b_local - 1, g * self.b_local - 1, -1))
            for g in range(batch_groups)]

    # ------------------------------------------------------------------
    # group arithmetic (no-ops when batch_groups == 1)
    # ------------------------------------------------------------------
    def group_of_block(self, block_id: int) -> int:
        """Batch group owning the device shard that holds ``block_id``."""
        return block_id // (self.alloc.num_blocks // self.batch_groups)

    def group_slabs(self, group: int) -> Optional[List[int]]:
        """Allocator slabs inside ``group``'s block range (None = any)."""
        if self.batch_groups == 1:
            return None
        spg = self.alloc.num_slabs // self.batch_groups
        return list(range(group * spg, (group + 1) * spg))

    def _pick_group(self) -> int:
        """Group with a free slot and the most headroom."""
        best, best_key = -1, None
        for g in range(self.batch_groups):
            if not self._free_slots[g]:
                continue
            free_blocks = sum(self.alloc.free_in_slab(s)
                              for s in (self.group_slabs(g) or
                                        range(self.alloc.num_slabs)))
            key = (len(self._free_slots[g]), free_blocks)
            if best_key is None or key > best_key:
                best, best_key = g, key
        if best < 0:
            raise RuntimeError("no free sequence slots")
        return best

    # ------------------------------------------------------------------
    def new_sequence(self, prompt_len: int = 0,
                     prefer_slab: Optional[int] = None) -> int:
        """Admit a sequence: reserve a batch slot, allocate its prompt
        blocks (inside the slot's group slabs when the batch shards), and
        BuZ-lazy-zero them.  Returns the sequence id."""
        sid = self._next_id
        self._next_id += 1
        nblk = (prompt_len + self.page - 1) // self.page
        group = self._pick_group()
        slabs = self.group_slabs(group)
        if prefer_slab is None or (slabs is not None
                                   and prefer_slab not in slabs):
            prefer_slab = (slabs or list(range(self.alloc.num_slabs)))[
                sid % (len(slabs) if slabs else self.alloc.num_slabs)]
        blocks = self.alloc.alloc(nblk, prefer_slab=prefer_slab,
                                  zeroed=False, allowed_slabs=slabs)
        if blocks:
            # fresh blocks logically zeroed via ZI (BuZ, metadata-only)
            self.engine.meminit(blocks)
        self.seqs[sid] = Sequence(sid, prompt_len, blocks, prefer_slab,
                                  group)
        slot = self._free_slots[group].pop()
        self._slot_of[sid] = slot
        self._dirty = True
        return sid

    def fork(self, parent_id: int, n_children: int = 1,
             eager_copy: bool = False) -> List[int]:
        """CoW fork: children share every parent block (refcount bump — the
        in-cache-copy: zero bytes move now).

        ``eager_copy=True`` physically clones every block instead (callers
        that know the children diverge immediately, e.g. beam search with
        per-beam sampling state): destinations are allocated in the
        source's slab (FPM placement) and all copies for all children
        enqueue into the engine's command queue, draining as ONE fused
        launch at the end of the fork."""
        parent = self.seqs[parent_id]
        out = []
        with self.engine.batch():
            for _ in range(n_children):
                sid = self._next_id
                self._next_id += 1
                # a CoW share is only visible to readers in the block's own
                # group: a child landing in another group must eager-copy
                # its blocks across (PSM transfers through the queue)
                if self._free_slots[parent.group]:
                    group = parent.group
                    eager = eager_copy
                else:
                    group = self._pick_group()
                    eager = True
                slabs = self.group_slabs(group)
                if eager and parent.blocks:
                    blocks = []
                    try:
                        for b in parent.blocks:
                            blocks.append(self.alloc.alloc_near(
                                b, allowed_slabs=slabs))
                    except OutOfBlocks:
                        # group exhaustion is recoverable: roll back this
                        # child's partial clone (already-created children
                        # stand; the caller sees the shortfall)
                        self.alloc.free(blocks)
                        raise
                    self.engine.memcopy(list(zip(parent.blocks, blocks)))
                else:
                    self.alloc.share(parent.blocks)
                    blocks = list(parent.blocks)
                home = parent.slab_home if slabs is None or \
                    parent.slab_home in slabs else slabs[0]
                self.seqs[sid] = Sequence(sid, parent.length, blocks,
                                          home, group)
                slot = self._free_slots[group].pop()
                self._slot_of[sid] = slot
                out.append(sid)
        self._dirty = True
        return out

    def append_token(self, seq_id: int) -> Tuple[int, int]:
        """Reserve the slot for one new token; performs CoW block split
        and/or block allocation as needed.  Returns (block_id, offset)."""
        seq = self.seqs[seq_id]
        pos = seq.length
        j = pos // self.page
        off = pos % self.page
        if j >= self.max_blocks_per_seq:
            raise ValueError("sequence exceeds max_blocks_per_seq")
        if j >= len(seq.blocks):
            # new tail block — ZI-lazy-zeroed fresh block, FPM-local
            nb = self.alloc.alloc(1, prefer_slab=seq.slab_home, zeroed=False,
                                  allowed_slabs=self.group_slabs(seq.group)
                                  )[0]
            self.engine.meminit([nb])
            seq.blocks.append(nb)
            self._dirty = True
        else:
            b = seq.blocks[j]
            if self.alloc.is_shared(b):
                # CoW write to a shared block: allocate in the SAME slab
                # (subarray-aware placement) and copy via the engine — FPM.
                nb = self.alloc.alloc_near(
                    b, allowed_slabs=self.group_slabs(seq.group))
                self.engine.memcopy([(b, nb)])
                self.alloc.free([b])
                seq.blocks[j] = nb
                self._dirty = True
        seq.length = pos + 1
        return seq.blocks[j], off

    def append_tokens(self, seq_ids: List[int]) -> List[Tuple[int, int]]:
        """One decode step for a batch of sequences: every CoW split and
        tail-block init enqueues into the engine's command queue, and the
        device sees exactly ONE fused launch at the flush boundary (the
        seed path issued up to one launch per mechanism per pool *per
        sequence*).  Returns [(block_id, offset), ...] in input order."""
        with self.engine.batch():
            return [self.append_token(sid) for sid in seq_ids]

    def remap_blocks(self, seq_id: int, blocks: List[int]) -> None:
        """Replace a sequence's block list with caller-allocated blocks.

        The public surface for relocation workloads (benchmark baseline
        paths, defragmenters): the caller allocates destinations and
        copies bytes through the engine, then hands the new list over
        here — the cache takes ownership of ``blocks`` (refcounts as
        allocated), releases the OLD list refcount-aware, and rebuilds
        the device tables.  Poking ``seqs[sid].blocks`` directly instead
        would bypass the refcount/share-mask bookkeeping and corrupt CoW
        state.  Positions where the new id equals the old are kept
        without a free/retain cycle.  Length must match the current list
        (relocation, not truncation), and under sharded batches every
        new block must sit in the sequence's own group."""
        seq = self.seqs[seq_id]
        blocks = [int(b) for b in blocks]
        if len(blocks) != len(seq.blocks):
            raise ValueError(
                f"remap_blocks: {len(blocks)} blocks for a sequence "
                f"holding {len(seq.blocks)} (relocation must preserve "
                "the block count)")
        if self.batch_groups > 1:
            for b in blocks:
                if self.group_of_block(b) != seq.group:
                    raise ValueError(
                        f"remap_blocks: block {b} lives in group "
                        f"{self.group_of_block(b)}, sequence {seq_id} "
                        f"is pinned to group {seq.group}")
        stale = [old for old, new in zip(seq.blocks, blocks) if old != new]
        if stale:
            self.alloc.free(stale)
        seq.blocks = blocks
        self._dirty = True

    def free_sequence(self, seq_id: int) -> None:
        """Release a sequence's blocks (refcount-aware) and its slot."""
        seq = self.seqs.pop(seq_id)
        self.alloc.free(seq.blocks)
        self._free_slots[seq.group].append(self._slot_of.pop(seq_id))
        self._dirty = True

    # ------------------------------------------------------------------
    # device-visible views
    # ------------------------------------------------------------------
    def rebuild_tables(self) -> None:
        """Recompute the block table, share mask, and base offsets from the
        live sequences.  With ``batch_groups > 1`` the mask columns are
        LOCAL (slot % b_local) — valid because every block of a sequence
        lives in the sequence's own group (asserted here: a violation would
        silently attach the block to the wrong sequence on-device)."""
        self._table.fill(-1)
        self._mask.fill(0)
        self._base.fill(0)
        for sid, seq in self.seqs.items():
            slot = self._slot_of[sid]
            for j, b in enumerate(seq.blocks):
                self._table[slot, j] = b
                # CoW-shared blocks simply set several share-mask columns —
                # the slab-sweep attention serves every sharer from the one
                # physical block (the in-memory dedup the paper's VM-clone
                # application relies on).
                if self.batch_groups > 1:
                    assert self.group_of_block(b) == seq.group, \
                        (b, self.group_of_block(b), seq.group, sid)
                self._mask[b, slot % self.b_local] = 1
                self._base[b] = j * self.page
        self._dirty = False

    def device_tables(self):
        """(block_table (B, nper), share_mask, base) as device arrays.
        The share mask has ``max_seqs // batch_groups`` columns — global
        batch numbering when unsharded, local numbering when the batch
        shards (see class docstring)."""
        if self._dirty:
            self.rebuild_tables()
        return (jnp.asarray(self._table), jnp.asarray(self._mask),
                jnp.asarray(self._base))

    def seq_lens(self) -> np.ndarray:
        """(max_seqs,) int32 sequence lengths, indexed by batch slot."""
        lens = np.zeros(self.max_seqs, np.int32)
        for sid, seq in self.seqs.items():
            lens[self._slot_of[sid]] = seq.length
        return lens

    def slot_of(self, seq_id: int) -> int:
        """The sequence's batch-table row (slot // b_local = its group)."""
        return self._slot_of[seq_id]

    # convenience for tests/benchmarks
    def blocks_of(self, seq_id: int) -> List[int]:
        """The sequence's pool block ids, in sequence order."""
        return list(self.seqs[seq_id].blocks)
