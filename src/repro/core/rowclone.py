"""RowCloneEngine — the ``memcopy``/``meminit`` "ISA" and its dispatcher.

Paper §2.3: software issues ``memcopy``/``meminit``; the microarchitecture
decides per request whether FPM, PSM, or the ordinary path applies, and the
MC serializes the commands.  Here:

* ``memcopy(pairs)``  — partitions (src, dst) block pairs by placement:
    - ``alias``  : dst unwritten + ZI enabled → refcount bump only
                   (in-cache copy: zero bytes move)
    - ``fpm``    : same slab → subarray-local DMA copy
    - ``psm``    : cross-slab → serialized transfer (ICI path)
    - ``baseline``: RowClone disabled → copy through the compute pipeline
* ``meminit(ids)``    — ZI lazy-zero bit when possible, else the zero-row
                        DMA broadcast.

Dispatch is **queued and fused** (core/cmdqueue.py): classification tags
each request with an opcode and enqueues it; at a flush boundary the whole
table drains as ONE fused kernel launch moving every pool
(kernels/fused_dispatch.py) — the MC command-drain analogue, with the
DMA wait trailing one step behind issue (the overlapped drain; the
queue's source-hazard tracking keeps adjacent table rows disjoint).

Asynchrony is a first-class surface (core/stream.py): ``engine.stream()``
mints an ordered :class:`~repro.core.stream.CommandStream`; commands
enqueued on it drain only at ``stream.flush()``, which returns a
:class:`~repro.core.stream.FlushTicket` (launch accounting, drained
command count, post-drain block state on demand).  Streams serialize
against each other only when they touch the same ``(pool, block)`` (the
cross-stream guard).  The seed-era surface is a thin wrapper over the
engine's DEFAULT stream: each public call flushes on return (eager,
seed-compatible semantics); inside ``with engine.batch():`` commands
accumulate and the device sees a single launch at exit — the
attention-step / benchmark-tick boundary.

Tables pad to power-of-two buckets (8/32/128/512, overflow chunked), not the
seed's fixed ``max_requests`` length.  Under a multi-device mesh the flush
drains as ONE shard_map'd collective launch: the table is partitioned into
per-slab sub-tables (slab-local ids, same kernel) plus a cross-slab
send/recv plan executed with ppermute inside the same launch
(core/cmdqueue.py ``partition_commands``).  ``use_fused=False`` keeps the
seed's per-mechanism, per-pool fan-out (one jit'd call per pool per
mechanism, padded to ``max_requests``) for A/B benchmarking; on sharded
arrays those global gather/scatters compile through GSPMD.

Addressing is the engine's :class:`~repro.core.poolspec.PoolGroup`: every
pool has its OWN block count, cross-pool commands carry global
``base[pool] + block`` ids (prefix-sum bases), and public calls accept
:class:`~repro.core.poolspec.BlockRef` operands — which is what lets a
serving engine size its staging pools as a small recycling ring instead of
full-size KV twins (~2x less resident pool memory, see launch/serve.py).
"""
from __future__ import annotations

import contextlib
import dataclasses
import functools
import time
import warnings
from typing import Dict, Iterator, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh

from repro.core.allocator import SubarrayAllocator
from repro.core.cmdqueue import (BITWISE_OPS, CommandQueue, OP_AND,
                                 OP_BASELINE_COPY, OP_CROSS_POOL_COPY,
                                 OP_FPM_COPY, OP_NOP, OP_NOT, OP_OR,
                                 OP_PSM_COPY, OP_ZERO_INIT, bucket_size,
                                 pack_bitwise_src, partition_commands,
                                 space_war_rows, top_bucket,
                                 unpack_bitwise_src)
from repro.core.journal import (AbortedFlush, JournalRecord, PoolSnapshot,
                                RecoveryError, RecoveryReport, TicketJournal)
from repro.core.opcodes import (ALL_PRIMARY, OPCODE_NAMES, check_pack_total,
                                opspec, row_rw)
from repro.core.poolspec import BlockRef, PoolGroup
from repro.core.sanitizer import DrainSanitizer, sanitize_enabled
from repro.core.stream import CommandStream
from repro.kernels import ops as kops
from repro.kernels.fused_dispatch import (DrainInfo, _bitcast_uint,
                                          check_drain, notify_launch)
from repro.models.paged import pool_shard_axes, pool_shard_count
from repro.obs import metrics as obs_metrics
from repro.obs.autotune import load_profile
from repro.obs.trace import FlushTiming, span


@dataclasses.dataclass
class EngineStats:
    fpm_copies: int = 0
    psm_copies: int = 0
    alias_copies: int = 0
    baseline_copies: int = 0
    cross_pool_copies: int = 0
    stage_promotions: int = 0   # staged blocks promoted into primary pools
    retired_promotions: int = 0  # queued promotions cancelled pre-flush
    demotions: int = 0          # primary blocks parked in spill slots
    spill_promotions: int = 0   # spill slots promoted back into primaries
    zero_lazy: int = 0
    zero_materialized: int = 0
    bytes_fpm: int = 0
    bytes_psm: int = 0
    bytes_baseline: int = 0
    bytes_cross: int = 0
    bytes_avoided: int = 0      # alias + lazy zero
    cross_stream_flushes: int = 0  # streams serialized by an overlap
    launches: int = 0           # device dispatches issued for bulk movement
    bitwise_ops: int = 0        # AND/OR/NOT compute rows enqueued
    bytes_bitwise: int = 0      # destination bytes written by bitwise rows


class RowCloneEngine:
    """Owns block pools + allocator; dispatches copy/init requests.

    ``pools`` is a dict name -> jnp array (nblk_p, ...) — e.g. {"k":
    k_pools, "v": v_pools} sharing one allocator (paired pools: a request
    applies to every pool, like K and V pages of one KV block).  The
    engine's address space is its :class:`~repro.core.poolspec.PoolGroup`
    (``engine.group``): per-pool block counts with prefix-sum base
    offsets, so staging pools may be sized independently of their KV
    twins (a small staging *ring* instead of a full-size twin).  Public
    copy calls address blocks with :class:`~repro.core.poolspec.BlockRef`;
    bare ints remain accepted as primary-address-space ids.
    ``memcopy_cross`` takes (BlockRef, BlockRef) pairs only — the
    pool-name keyword shim is gone.
    """

    def __init__(self, pools: Dict[str, jnp.ndarray],
                 allocator: SubarrayAllocator,
                 mesh: Optional[Mesh] = None,
                 enable_fpm: bool = True, enable_psm: bool = True,
                 enable_zi: bool = True, max_requests: int = 256,
                 block_axis: int = 0, use_fused: bool = True,
                 staging: Optional[Dict[str, str]] = None,
                 group: Optional[PoolGroup] = None,
                 sanitize: Optional[bool] = None,
                 overlap: Optional[bool] = None):
        """``block_axis``: which pool axis indexes blocks.  0 = flat pools
        (nblk, ...); 1 = layer-stacked serving pools (L, nblk, ...) where a
        logical block is L physical pages moved together (L independent
        DMAs per request on TPU).

        ``use_fused``: drain flushed command tables through the single
        fused-dispatch launch (default) — under a multi-device mesh, one
        shard_map'd collective launch over per-slab sub-tables.  False
        restores the seed's per-mechanism, per-pool fan-out padded to
        ``max_requests``, kept for A/B benchmarking.

        ``group``: the engine's :class:`PoolGroup` address space.  When
        omitted, one is built from the arrays + the ``staging`` map (a
        staging pool name -> paired primary pool dict, e.g.
        ``{"k_stage": "k", "v_stage": "v"}``), with each pool's ``nblk``
        read off its block axis.  Primary pools must match the allocator's
        block count; staging pools may be ANY size (all staging pools
        share one size — the promotion slot space) but must mirror their
        twin's block shape and dtype.  Plain opcodes (memcopy/meminit)
        move blocks in primary pools only; staged bytes enter and leave a
        staging pool exclusively through ``OP_CROSS_POOL_COPY``
        (``promote_staged``), so allocator metadata (ZI bits, refcounts)
        keeps describing primary blocks.  Staging slot ids are
        engine-managed (``stage_blocks``), disjoint from the allocator's
        free lists.

        ``sanitize``: attach the TSAN-style drain sanitizer
        (core/sanitizer.py) — every flushed chunk is validated against
        the opcode contract registry before its donating launch (operand
        decode, staging legality, NOP well-formedness, RAW/WAW absence,
        WAR adjacency, ShardPlan partitioning) and shadow-executed
        through the jnp oracle on host copies with a bitwise diff.
        ``None`` (the default) reads the ``REPRO_SANITIZE`` env var.  The
        sanitizer issues no extra device launches, so launch accounting
        (and the 1-launch-per-flush gates) is unchanged.

        ``overlap``: the fused Pallas drain's overlapped-DMA toggle.
        ``None`` (the default) resolves through this backend's
        :class:`~repro.obs.autotune.TunedProfile` when one is committed
        under ``configs/tuned/`` (kwarg > profile > built-in True) —
        per-engine autotuned knobs apply here; process-wide ones
        (bucket set, delta-signature bound) only via the explicit
        ``repro.obs.autotune.apply_profile``."""
        self.alloc = allocator
        self.mesh = mesh
        self.enable_fpm = enable_fpm
        self.enable_psm = enable_psm
        self.enable_zi = enable_zi
        self.max_requests = max_requests
        self.block_axis = block_axis
        self.use_fused = use_fused
        #: this backend's committed TunedProfile, or None (obs/autotune.py)
        self.profile = load_profile()
        if overlap is None:
            overlap = self.profile.overlap if self.profile is not None \
                else True
        self.overlap = bool(overlap)
        #: FlushTiming of the most recent drain (FlushTicket.timing source)
        self.last_drain_timing: Optional[FlushTiming] = None
        if group is None:
            group = PoolGroup.from_pools(pools, block_axis=block_axis,
                                         staging=staging)
        self.group = group
        self.staging = dict(group.staging_map)
        assert set(group.names) == set(pools), (group.names, list(pools))
        # group order is the table order everywhere — realign the dict
        self.pools = {name: pools[name] for name in group.names}
        self.stats = EngineStats()
        if sanitize is None:
            sanitize = sanitize_enabled()
        #: the attached drain sanitizer, or None (core/sanitizer.py)
        self.sanitizer: Optional[DrainSanitizer] = \
            DrainSanitizer(self) if sanitize else None
        # every engine owns a DEFAULT CommandStream: the seed-era public
        # calls (memcopy/flush/batch) are thin wrappers over it; callers
        # wanting explicit asynchrony mint more with stream().  The
        # engine tracks only queues with PENDING work (registered on
        # enqueue, dropped when drained), so minting streams is free:
        # no registry growth, and the cross-stream guard scans only
        # queues that could actually conflict.
        self._live_queues: Dict[int, CommandQueue] = {}
        self._stream_count = 0
        self._default_stream = CommandStream(self, "default")
        self._cur_queue = self._default_stream.queue
        self.deferred = False
        self._warned_unshardable = False
        self._zero_blocks: Optional[Tuple[jnp.ndarray, ...]] = None
        nblk = allocator.num_blocks
        for spec in group:
            p = self.pools[spec.name]
            assert p.shape[block_axis] == spec.nblk, \
                f"pool {spec.name!r}: {p.shape[block_axis]} blocks != " \
                f"spec nblk {spec.nblk}"
            if spec.role == "primary":
                assert spec.nblk == nblk, \
                    f"primary pool {spec.name!r}: {spec.nblk} blocks != " \
                    f"allocator's {nblk}"
        stage_cap = 0
        for sname, pname in self.staging.items():
            s, p = self.pools[sname], self.pools[pname]
            s_blk = list(s.shape)
            cap = s_blk.pop(block_axis)
            p_blk = list(p.shape)
            p_blk.pop(block_axis)
            assert s_blk == p_blk and s.dtype == p.dtype, \
                f"staging pool {sname!r} must mirror {pname!r}'s block " \
                "shape and dtype"
            assert stage_cap in (0, cap), \
                "staging pools must share one block count (the promotion " \
                f"slot space): {stage_cap} != {cap}"
            stage_cap = cap
        for spec in group:
            if spec.role != "spill":
                continue
            s, p = self.pools[spec.name], self.pools[spec.paired]
            s_blk = list(s.shape)
            s_blk.pop(block_axis)
            p_blk = list(p.shape)
            p_blk.pop(block_axis)
            assert s_blk == p_blk and s.dtype == p.dtype, \
                f"spill pool {spec.name!r} must mirror {spec.paired!r}'s " \
                "block shape and dtype"
        # staging slot free list + ids whose promotion is still queued
        # (reclaimed by _after_flush once no stream holds a pending READ
        # of the slot — the queues' source-hazard tracking)
        self._stage_free: List[int] = list(range(stage_cap - 1, -1, -1))
        self._stage_inflight: List[int] = []
        # slots parked above the adaptive ring limit (set_stage_limit):
        # excluded from stage_blocks until the limit is raised again
        self._stage_parked: List[int] = []
        # a degraded recover()'s sticky ring cap: the adaptive ring may
        # shrink below it but regrow-on-demand never exceeds it
        self._stage_degraded_cap: Optional[int] = None
        # preemption demotion: primary pool name -> its spill twin, plus
        # the engine-owned demotion slot space (a sub-range of the spill
        # pools handed over by enable_demotion — the rest of the spill
        # pools stays free for e.g. checkpoint windows)
        self._spill_map: Dict[str, str] = {
            spec.paired: spec.name for spec in group
            if spec.role == "spill"}
        self._spill_slots: Tuple[int, ...] = ()
        self._spill_free: List[int] = []
        self._spill_inflight: List[int] = []
        #: replayable flush log — every drained flush appends one record
        self.journal = TicketJournal()
        self._flush_index = 0
        self._last_plan_sig: Optional[Tuple] = None
        self._aborted: List[AbortedFlush] = []
        self._stage_limit: Optional[int] = None
        # frozen per-pool layout (shape, dtype, sharding) so recover()
        # can resurrect or restore buffers with the original placement;
        # uncommitted single-device pools record no sharding — pinning
        # them via device_put would commit the restored buffer and break
        # the mesh drain's shard_map placement
        self._pool_layouts = {
            name: (tuple(p.shape), p.dtype, self._pool_placement(p))
            for name, p in self.pools.items()}

    @staticmethod
    def _pool_placement(p):
        """The sharding recover() should restore ``p`` under, or None.
        Only committed multi-device placements are pinned: an uncommitted
        (or single-device) array must be restored uncommitted so jit/
        shard_map remains free to place it."""
        sh = getattr(p, "sharding", None)
        if sh is None or not getattr(p, "_committed", True):
            return None
        if len(getattr(sh, "device_set", ())) <= 1:
            return None
        return sh

    # ------------------------------------------------------------------
    # streams
    # ------------------------------------------------------------------
    def _note_pending(self, queue: CommandQueue) -> None:
        """A queue gained pending work: track it for the cross-stream
        guard, staging-slot reclaim, and engine-wide drains (called by
        CommandQueue.enqueue)."""
        self._live_queues[id(queue)] = queue

    def _note_drained(self, queue: CommandQueue) -> None:
        """A queue drained to empty: drop it from the live set (called by
        CommandQueue.flush) — drained streams cost nothing, however many
        a caller mints."""
        self._live_queues.pop(id(queue), None)

    def stream(self, name: Optional[str] = None) -> CommandStream:
        """Mint a new ordered :class:`CommandStream` on this engine.

        Commands enqueued on it do NOT flush on return; ``stream.flush()``
        drains them and returns a :class:`FlushTicket`.  Streams are
        unordered against each other until they touch the same
        ``(pool, block)`` — then the earlier stream drains first (the
        cross-stream guard), so conflicts serialize at block granularity
        instead of a global barrier.  Minting is cheap and streams need
        no close(): the engine only tracks queues while they hold
        pending commands."""
        self._stream_count += 1
        if name is None:
            name = f"stream{self._stream_count}"
        return CommandStream(self, name)

    @property
    def queue(self) -> CommandQueue:
        """The DEFAULT stream's command queue (seed-compatible surface —
        public engine calls enqueue here unless captured by a stream)."""
        return self._default_stream.queue

    @property
    def default_stream(self) -> CommandStream:
        """The engine's default :class:`CommandStream` (what ``batch()``/
        ``flush()`` wrap)."""
        return self._default_stream

    def _cross_stream_guard(self, queue: CommandQueue,
                            skeys, dkey) -> None:
        """Serialize streams that touch the same blocks: a command about
        to land on ``queue`` that reads or writes another stream's pending
        WRITE, or writes another stream's pending READ, drains that other
        stream first.  (Reading another stream's pending read is harmless
        — RAR.)  ``skeys`` is the tuple of read keys — two-source bitwise
        rows contribute both decoded sources, so a conflict on EITHER
        source drains the other stream.  Flush order between unrelated
        streams stays undefined, which is the asynchrony the API sells.
        Only queues with pending work are scanned (the live set)."""
        for q in list(self._live_queues.values()):
            if q is queue or not len(q):
                continue
            clash = q.has_pending_write(dkey) or q.has_pending_read(dkey) \
                or any(q.has_pending_write(k) for k in skeys)
            if clash:
                self.stats.cross_stream_flushes += 1
                q.flush()

    # ------------------------------------------------------------------
    @property
    def num_blocks(self) -> int:
        """Blocks per PRIMARY pool (the allocator's address space; staging
        pools size independently — see ``stage_capacity``)."""
        return self.alloc.num_blocks

    @property
    def stage_capacity(self) -> int:
        """Staging slot ids available per staging pool (0 = no staging)."""
        return self.group[next(iter(self.staging))].nblk if self.staging \
            else 0

    @property
    def stage_slots_free(self) -> int:
        """Staging slots currently on the free list (slots whose queued
        promotion has not drained are excluded — admission policy can
        pre-check capacity without forcing an early flush; slots parked
        above the adaptive ring limit are excluded too)."""
        return len(self._stage_free)

    @property
    def stage_limit(self) -> Optional[int]:
        """The adaptive staging-ring clamp (:meth:`set_stage_limit`):
        usable slots are ids ``< stage_limit``.  None = full capacity."""
        return self._stage_limit

    def set_stage_limit(self, limit: Optional[int]) -> int:
        """Clamp the staging ring to ``limit`` usable slots (ids below
        the limit); slots at or above it park until the limit is raised.

        The adaptive-ring primitive: the serving layer shrinks the ring
        under sustained low admission pressure (the occupancy gauge says
        most slots never fill) and regrows it on demand — in-flight and
        reserved slots are untouched either way, only FREE slots move
        between the usable and parked lists, so a shrink never invalidates
        outstanding reservations.  ``None`` (or ``limit >=``
        :attr:`stage_capacity`) restores the full ring.  A degraded
        ``recover()`` routes through here too.  Returns the effective
        usable-slot count."""
        cap = self.stage_capacity
        if limit is None or int(limit) >= cap:
            self._stage_limit = None
            self._stage_free.extend(self._stage_parked)
            self._stage_parked = []
            effective = cap
        else:
            lim = max(int(limit), 0)
            self._stage_limit = lim
            usable = [s for s in self._stage_free if s < lim] + \
                [s for s in self._stage_parked if s < lim]
            parked = [s for s in self._stage_free if s >= lim] + \
                [s for s in self._stage_parked if s >= lim]
            self._stage_free = usable
            self._stage_parked = parked
            effective = lim
        obs_metrics.set_gauge("engine.stage_limit", effective)
        return effective

    def _reclaim_stage_slots(self, slots: Sequence[int]) -> None:
        """Route freed staging slots to the free list, or to the parked
        list when the adaptive ring limit excludes their ids."""
        lim = self._stage_limit
        if lim is None:
            self._stage_free.extend(slots)
            return
        for s in slots:
            (self._stage_free if s < lim else self._stage_parked).append(s)

    @property
    def spill_capacity(self) -> int:
        """Demotion slots the engine owns (``enable_demotion``), per
        spill pool; 0 until demotion is enabled."""
        return len(self._spill_slots)

    @property
    def spill_slots_free(self) -> int:
        """Demotion slots not currently parking a demoted block and not
        awaiting reclaim from a queued resume promotion."""
        return len(self._spill_free)

    @property
    def n_primary(self) -> int:
        """Number of primary pools (plain opcodes touch exactly these;
        staging pools only see cross-pool commands)."""
        return self.group.n_primary

    @property
    def primary_names(self) -> Tuple[str, ...]:
        """Names of the primary pools, in table order."""
        return self.group.primary_names

    def _multi_device(self) -> bool:
        return self.mesh is not None and \
            int(np.prod(self.mesh.devices.shape)) > 1

    def _block_bytes(self) -> int:
        """Bytes one plain command moves = one block across every PRIMARY
        pool (staging pools never ride plain opcodes)."""
        total = 0
        for name in self.primary_names:
            p = self.pools[name]
            shape = list(p.shape)
            shape.pop(self.block_axis)
            total += int(np.prod(shape)) * p.dtype.itemsize
        return total

    def _pool_block_bytes(self, name: str) -> int:
        p = self.pools[name]
        shape = list(p.shape)
        shape.pop(self.block_axis)
        return int(np.prod(shape)) * p.dtype.itemsize

    def pool_bytes_resident(self) -> int:
        """Total bytes resident across every pool array (primary +
        staging).  The serving-memory headline number: sizing staging as a
        small ring instead of a full twin (per-pool ``nblk`` in the
        PoolGroup) roughly halves this for a k/v + staging engine —
        tracked per serve_round row in BENCH_dispatch.json (schema v4)."""
        return sum(int(np.prod(p.shape)) * p.dtype.itemsize
                   for p in self.pools.values())

    def _pad(self, pairs: Sequence[Tuple[int, int]]) -> np.ndarray:
        """Seed-style fixed-length padding (legacy fan-out path only)."""
        m = self.max_requests
        arr = np.full((m, 2), -1, np.int32)
        if pairs:
            a = np.asarray(pairs, np.int32)[:m]
            arr[: len(a)] = a
        return arr

    def _get_zero_blocks(self) -> Tuple[jnp.ndarray, ...]:
        """Per-pool reserved zero row for BuZ — allocated once."""
        if self._zero_blocks is None:
            zbs = []
            for p in self.pools.values():
                blk = p.shape[1:] if self.block_axis == 0 else p.shape[2:]
                zbs.append(jnp.zeros((1,) + blk, p.dtype))
            self._zero_blocks = tuple(zbs)
        return self._zero_blocks

    # ------------------------------------------------------------------
    # flush control
    # ------------------------------------------------------------------
    def flush(self) -> int:
        """Drain the DEFAULT stream's queue (seed-compatible surface).
        Returns device launches issued; other streams drain through their
        own ``flush()`` and return :class:`FlushTicket` receipts.  Always
        targets the default queue — even inside a ``stream.capture()``
        region, where captured commands stay queued until that stream's
        explicit flush (calling this mid-capture must not split the
        capturing stream's launch)."""
        return self._default_stream.queue.flush()

    def _flush_streams(self) -> None:
        """Drain EVERY queue with pending commands (the engine-wide
        barrier some internal paths need, e.g. staging-slot reclaim)."""
        for q in list(self._live_queues.values()):
            q.flush()

    def _autoflush(self) -> None:
        if not self.deferred:
            self._cur_queue.flush()

    @contextlib.contextmanager
    def batch(self) -> Iterator[CommandQueue]:
        """Defer flushing: commands enqueued inside the block drain as one
        fused launch at exit (the attention-step flush boundary).  Pool
        arrays are STALE inside the block — read them only after exit.
        Composes with stream capture: inside ``stream.capture()`` the
        commands land on that stream and its flush stays explicit."""
        prev = self.deferred
        self.deferred = True
        try:
            yield self._cur_queue
        finally:
            self.deferred = prev
            if not self.deferred:
                self._cur_queue.flush()

    # ------------------------------------------------------------------
    # drain path + journal — every flushed table passes through here
    # ------------------------------------------------------------------
    @property
    def next_flush_index(self) -> int:
        """Engine-wide index the NEXT drained flush will carry (every
        ``_drain_rows`` — flush, replay, or re-drain — takes one).  The
        handle fault plans use to target a specific upcoming flush."""
        return self._flush_index

    def _drain_rows(self, rows: Sequence[Tuple[int, int, int]],
                    queue: Optional[CommandQueue] = None,
                    record: bool = True, pre_spaced: bool = False) -> int:
        """Space, chunk, and dispatch one flush's rows; append the
        :class:`JournalRecord` on success.  The single drain path shared
        by ``CommandQueue.flush``, ``TicketJournal.replay``
        (``record=False, pre_spaced=True`` — records hold spaced rows),
        and ``recover()``'s aborted-suffix re-drains.

        Every chunk runs the drain guards (fused_dispatch ``check_drain``)
        BEFORE its donating dispatch, so a raising guard aborts the flush
        with pool buffers intact: the dispatched prefix is journaled as an
        ``aborted`` record and the undispatched suffix stashed for
        ``recover()``."""
        rows = [(int(op), int(s), int(d)) for op, s, d in rows]
        idx = self._flush_index
        self._flush_index += 1
        residency_us = queue.pop_residency_us() if queue is not None else 0.0
        t_drain = obs_metrics.now()
        if pre_spaced or not self._flush_spacing():
            spaced = rows
        else:
            spaced = space_war_rows(rows, self.group.locate,
                                    self.group.primary,
                                    self.group.total_blocks)
            if queue is not None:
                queue.stats.spacer_rows += len(spaced) - len(rows)
        self._last_plan_sig = None
        name = queue.name if queue is not None else "replay"
        launches = 0
        table_len = 0
        top = top_bucket()
        with span("drain", stream=name, flush=idx):
            for ci, lo in enumerate(range(0, len(spaced), top)):
                chunk = spaced[lo:lo + top]
                try:
                    check_drain(DrainInfo(
                        flush=idx, chunk=ci,
                        n_commands=sum(1 for r in chunk if r[0] >= 0),
                        n_pools=len(self.pools), engine=self))
                    table = np.full((bucket_size(len(chunk)), 3), OP_NOP,
                                    np.int32)
                    table[:len(chunk)] = np.asarray(chunk, np.int32)
                    table_len += len(table)
                    san = self.sanitizer
                    shadow_pre = None
                    if san is not None:
                        san.check_table(table, flush=idx, chunk=ci)
                        shadow_pre = san.shadow_snapshot()
                    launches += self._dispatch_table(table, len(chunk),
                                                     queue=queue)
                    if shadow_pre is not None:
                        san.check_shadow(shadow_pre, table)
                except Exception:
                    if record:
                        done = spaced[:lo]
                        if any(op >= 0 for op, _, _ in done):
                            # the chunks that DID dispatch mutated the
                            # pools: journal them so replay reproduces the
                            # partial state exactly (recover() re-drains
                            # the suffix as its own record)
                            self.journal.append(JournalRecord(
                                stream=name, index=idx, rows=tuple(done),
                                plan_sig=self._last_plan_sig,
                                launches=launches, aborted=True))
                        self._aborted.append(AbortedFlush(
                            queue=name, index=idx, rows=tuple(rows),
                            suffix=tuple(spaced[lo:])))
                    raise
        drain_us = (obs_metrics.now() - t_drain) * 1e6
        self.last_drain_timing = FlushTiming(
            queue_residency_us=residency_us, drain_us=drain_us,
            table_len=table_len, launches=launches)
        if obs_metrics.metrics_enabled():
            op_counts: Dict[int, int] = {}
            spacers = 0
            for op, _s, _d in spaced:
                if op < 0:
                    spacers += 1
                else:
                    op_counts[op] = op_counts.get(op, 0) + 1
            for op, cnt in op_counts.items():
                obs_metrics.inc("drain.rows", cnt, stream=name,
                                opcode=OPCODE_NAMES.get(op, str(op)))
            if spacers:
                obs_metrics.inc("drain.spacer_rows", spacers, stream=name)
            obs_metrics.inc("drain.launches", launches, stream=name)
            obs_metrics.observe("drain.flush_us", drain_us, stream=name)
            obs_metrics.observe("drain.table_len", table_len, stream=name)
        if record:
            self.journal.append(JournalRecord(
                stream=name, index=idx, rows=tuple(spaced),
                plan_sig=self._last_plan_sig, launches=launches,
                war_hazards=(queue.stats.war_hazards if queue else 0),
                spacer_rows=(queue.stats.spacer_rows if queue else 0)))
        return launches

    def _touched_pools(self, rows: Sequence[Tuple[int, int, int]]
                       ) -> Tuple[str, ...]:
        """Pool names a set of command rows WRITES — what a flush's
        :class:`FlushTicket` must wait on (plain opcodes write every
        primary pool; cross-pool rows write exactly their destination
        pool), so e.g. a checkpoint-stream ticket never serializes
        against decode's primary-pool traffic."""
        hit = set()
        for op, s, d in rows:
            if op < 0:
                continue
            _, writes = row_rw(op, s, d, self.group.locate,
                               self.group.total_blocks)
            for p, _b in writes:
                if p == ALL_PRIMARY:
                    hit.update(self.primary_names)
                else:
                    hit.add(self.group.names[p])
        return tuple(n for n in self.group.names if n in hit)

    # ------------------------------------------------------------------
    # snapshot + recovery
    # ------------------------------------------------------------------
    def snapshot(self) -> PoolSnapshot:
        """Host copies of EVERY pool, consistent through the last drained
        flush (quiesce in-flight streams first for an exact snapshot).
        The incremental, non-blocking alternative rides the checkpoint
        stream — checkpoint/pool_checkpoint.py."""
        return PoolSnapshot(
            index=self._flush_index - 1,
            arrays={n: np.asarray(p) for n, p in self.pools.items()})

    def _reads_lost(self, row: Tuple[int, int, int],
                    lost_idx: frozenset) -> bool:
        """Does a command row read (or write) a pool that died without a
        snapshot?  Such rows are unrecoverable — recover() drops them."""
        if not lost_idx:
            return False
        op, s, d = row
        # registry-driven decode: plain opcodes key ALL_PRIMARY (-1),
        # which is never a lost pool index, so only exact-pool operands
        # (cross-pool / bitwise rows) can make a row unrecoverable
        reads, writes = row_rw(op, s, d, self.group.locate,
                               self.group.total_blocks)
        return any(p in lost_idx for p, _b in reads + writes)

    def recover(self, snapshot: Optional[PoolSnapshot] = None,
                max_retries: int = 3, backoff: float = 0.05,
                degraded_stage_capacity: Optional[int] = None
                ) -> RecoveryReport:
        """Return the engine to a serviceable state after a failed flush
        or a donation error.  The recovery state machine:

        1. **Evict** — every live stream's queued commands are dropped
           (``CommandQueue.abort``); promotions out of the staging pools
           are counted separately so a serving layer can evict the
           affected admissions (their staged bytes never arrived).
        2. **Restore** — pools whose buffers died (donated into a failed
           call) come back from ``snapshot`` when it covers them, else as
           zeros (reported in ``pools_lost``).  Live pools are never
           touched: their bytes are ahead of any snapshot (decode writes
           bypass the journal) and must not be rolled back.
        3. **Reset staging** — all slots return to the free list (queued
           reads are gone); ``degraded_stage_capacity`` caps the ring
           (the degraded single-buffer mode when a shadow half is
           poisoned).
        4. **Replay** — when step 2 restored pools from the snapshot, the
           journal re-drains every record after ``snapshot.index``
           (bitwise-identical block state — core/journal.py).
        5. **Re-drain** — aborted flushes' undispatched suffixes re-drain
           with exponential backoff, up to ``max_retries`` attempts each;
           exhaustion raises :class:`RecoveryError`.  Rows reading pools
           lost without a snapshot are dropped (unrecoverable).
        """
        aborted, self._aborted = list(self._aborted), []
        evicted = 0
        evicted_promotions = 0
        staging_idx = frozenset(self.group.index(n) for n in self.staging)
        for q in list(self._live_queues.values()):
            for op, s, d in q.abort():
                if op < 0:
                    continue
                evicted += 1
                if op == OP_CROSS_POOL_COPY and \
                        self.group.locate(int(s))[0] in staging_idx:
                    evicted_promotions += 1
        restored: List[str] = []
        lost: List[str] = []
        for name in list(self.pools):
            p = self.pools[name]
            if not getattr(p, "is_deleted", lambda: False)():
                continue
            shape, dtype, sh = self._pool_layouts[name]
            if snapshot is not None and name in snapshot.arrays:
                arr = jnp.asarray(np.asarray(snapshot.arrays[name]),
                                  dtype=dtype)
                restored.append(name)
            else:
                arr = jnp.zeros(shape, dtype)
                lost.append(name)
            if sh is not None:
                arr = jax.device_put(arr, sh)
            self.pools[name] = arr
        # staging: every reservation and queued promotion is void now
        self._stage_inflight = []
        # in-flight resume promotions were aborted with the queues; their
        # slots revert to whoever demoted them (the serving layer either
        # re-promotes or releases via its demoted-sequence registry)
        self._spill_inflight = []
        cap = self.stage_capacity
        self._stage_free = list(range(cap - 1, -1, -1))
        self._stage_parked = []
        self._stage_limit = None
        if degraded_stage_capacity is not None:
            self._stage_degraded_cap = min(cap, int(degraded_stage_capacity))
            self.set_stage_limit(self._stage_degraded_cap)
        else:
            self._stage_degraded_cap = None
        replayed = 0
        if restored and snapshot is not None:
            replayed = self.journal.replay(self, after=snapshot.index)
        retries = 0
        lost_idx = frozenset(self.group.index(n) for n in lost)
        redrained = 0
        for ab in aborted:
            rows = [r for r in ab.suffix
                    if not self._reads_lost(r, lost_idx)]
            if not any(op >= 0 for op, _, _ in rows):
                continue
            for attempt in range(max_retries):
                try:
                    self._drain_rows(rows, record=True, pre_spaced=True)
                    redrained += 1
                    break
                except Exception as e:
                    self._aborted = []  # failed retries don't re-stash
                    retries += 1
                    if attempt == max_retries - 1:
                        raise RecoveryError(
                            f"re-drain of flush {ab.index} (stream "
                            f"{ab.queue!r}) still failing after "
                            f"{max_retries} attempts") from e
                    time.sleep(backoff * (2 ** attempt))
        return RecoveryReport(
            evicted_rows=evicted, evicted_promotions=evicted_promotions,
            pools_restored=tuple(restored), pools_lost=tuple(lost),
            replayed_flushes=replayed, redrained_flushes=redrained,
            retries=retries,
            degraded=degraded_stage_capacity is not None)

    # ------------------------------------------------------------------
    # memcopy
    # ------------------------------------------------------------------
    def _primary_id(self, b) -> int:
        """Resolve a primary-address-space operand: a bare int is an
        allocator block id; a :class:`BlockRef` must name a primary pool
        (plain opcodes move the block in EVERY primary pool, so the ref's
        pool only validates intent — the id is the address)."""
        if isinstance(b, BlockRef):
            if b.pool not in self.group.primary_names:
                raise ValueError(
                    f"plain copy/init addresses primary pools; "
                    f"{b.pool!r} is a staging pool (use memcopy_cross)")
            if not 0 <= int(b.block) < self.num_blocks:
                raise ValueError(f"block {b.block} out of range for "
                                 f"primary pools ({self.num_blocks})")
            return int(b.block)
        return int(b)

    def memcopy(self, pairs: Sequence[Tuple[object, object]],
                dst_is_fresh: bool = False) -> Dict[str, int]:
        """Copy block src -> dst for each pair.  Returns dispatch counts.

        Pairs may be bare ints (allocator block ids) or
        :class:`BlockRef`\\ s naming a primary pool — either way the copy
        moves the block in every primary pool (K and V pages travel
        together).

        ``dst_is_fresh``: destinations have never been written (e.g. CoW
        targets) — with ZI the engine may satisfy zero-source copies by
        aliasing at the cache layer instead; that path lives in
        cow_cache.fork() and never reaches here.
        """
        counts = {"fpm": 0, "psm": 0, "baseline": 0}
        bb = self._block_bytes()
        aliased = 0
        for s, d in pairs:
            s, d = self._primary_id(s), self._primary_id(d)
            # ZI "in-cache copy" fast path: copying a lazily-zero block is a
            # metadata move — mark dst zero, move no bytes.
            if self.enable_zi and self.alloc.is_zero[s]:
                self.alloc.mark_zero([d])
                self.stats.alias_copies += 1
                self.stats.bytes_avoided += bb
                aliased += 1
                continue
            # mark the dst written NOW, not after the loop: a later pair in
            # this same call may read it as a source (chained (a,b),(b,c))
            # and must see it as real data, not stale lazy-zero metadata
            self.alloc.mark_written([d])
            if not self.enable_fpm:
                op = OP_BASELINE_COPY
            elif self.alloc.slab_of(s) == self.alloc.slab_of(d):
                op = OP_FPM_COPY
            elif self.enable_psm:
                op = OP_PSM_COPY
            else:
                op = OP_BASELINE_COPY
            if op == OP_FPM_COPY:
                counts["fpm"] += 1
                self.stats.fpm_copies += 1
                self.stats.bytes_fpm += bb
            elif op == OP_PSM_COPY:
                counts["psm"] += 1
                self.stats.psm_copies += 1
                self.stats.bytes_psm += bb
            else:
                counts["baseline"] += 1
                self.stats.baseline_copies += 1
                self.stats.bytes_baseline += bb
            self._cur_queue.enqueue(op, s, d)
        if obs_metrics.metrics_enabled():
            for mech, c in counts.items():
                if c:
                    obs_metrics.inc("engine.bytes_moved", c * bb,
                                    mechanism=mech)
            if aliased:
                obs_metrics.inc("engine.bytes_avoided", aliased * bb,
                                mechanism="alias")
        self._autoflush()
        return counts

    def memcopy_cross(self, pairs: Sequence[Tuple[object, object]]) -> int:
        """Pool-to-pool block copy (e.g. prefill staging pool → serving
        pool) through the same queue: each pair becomes one
        ``CROSS_POOL_COPY`` command carrying global ``base[pool] + block``
        ids from the engine's :class:`PoolGroup`, so it rides the same
        fused launch as any pending copies/inits — and pools of DIFFERENT
        sizes (a staging ring vs a full KV pool) coexist in one table.
        Source and destination pools must share block shape and dtype.

        ``pairs`` are ``(BlockRef, BlockRef)`` — each pair names its own
        pools, so one call may mix pool pairs.  (The pre-stream
        ``(pairs, src_pool, dst_pool)`` int form is gone.)

        Staging and spill pools sit outside the allocator's metadata: a
        staging *source* always holds real bytes (the prefill wrote
        them), so the lazy-zero materialization below is skipped; a
        staging or spill *destination* is an engine- (or checkpoint-)
        managed slot, so no allocator block is marked written."""
        pairs = [(s if isinstance(s, BlockRef) else None,
                  d if isinstance(d, BlockRef) else None)
                 for s, d in pairs]
        if any(s is None or d is None for s, d in pairs):
            raise TypeError(
                "memcopy_cross pairs must be (BlockRef, BlockRef)")
        # validate every ref up front: the lazy-zero scan below indexes
        # allocator metadata, and a bad block id must fail cleanly before
        # any command or materialization side effect
        for s, d in pairs:
            self.group.gid(s), self.group.gid(d)
        # a lazily-zero PRIMARY source physically holds stale bytes; the ZI
        # bit is per *block* (primary pools jointly), so materialize it
        # before the pool-level copy (the hazard guard orders the zero
        # before the copy)
        lazy_srcs = [int(s.block) for s, _ in pairs
                     if s.pool in self.primary_names
                     and self.enable_zi and self.alloc.is_zero[s.block]]
        if lazy_srcs:
            self.materialize_zeros(lazy_srcs)
        for s, d in pairs:
            self._cur_queue.enqueue(OP_CROSS_POOL_COPY, self.group.gid(s),
                                    self.group.gid(d))
            self.stats.cross_pool_copies += 1
            self.stats.bytes_cross += self._pool_block_bytes(d.pool)
            obs_metrics.inc("engine.bytes_moved",
                            self._pool_block_bytes(d.pool),
                            mechanism="cross", pool=d.pool)
            if d.pool in self.primary_names:
                # dst now holds real data in dst_pool; a block can only
                # carry the lazy-zero bit when every primary pool's bytes
                # are logically zero.  Staging and spill destinations are
                # outside the allocator's metadata — a checkpoint copy
                # into a spill pool must NOT mark the primary block.
                self.alloc.mark_written([int(d.block)])
        self._autoflush()
        return len(pairs)

    # ------------------------------------------------------------------
    # bitwise compute rows — in-memory AND/OR/NOT (Ambit triple-row
    # activation analogue) through the same queue and fused launch
    # ------------------------------------------------------------------
    def _bitwise_rows(self, triples, verb: str):
        """Normalize ``(a, b, dst)`` operand triples to global-id rows.

        Each triple is either all :class:`BlockRef`\\ s (any pool,
        matching block shape/dtype assumed group-wide) or all bare ints
        (primary-space ids — the op fans out to every primary pool, the
        plain-opcode convention).  Lazily-zero PRIMARY sources hold stale
        bytes, so they materialize first, exactly like ``memcopy_cross``
        sources."""
        rows = []
        lazy = set()
        for t in triples:
            a, b, d = t
            refs = [isinstance(x, BlockRef) for x in (a, b, d)]
            if any(refs):
                if not all(refs):
                    raise TypeError(
                        f"{verb}: each triple must be all BlockRefs or "
                        f"all ints, got {t!r}")
                for x in (a, b):
                    if x.pool in self.primary_names and self.enable_zi \
                            and self.alloc.is_zero[int(x.block)]:
                        lazy.add(int(x.block))
                rows.append((self.group.gid(a), self.group.gid(b),
                             self.group.gid(d), d))
            else:
                ai = self._primary_id(a)
                bi = self._primary_id(b)
                di = self._primary_id(d)
                for x in (ai, bi):
                    if self.enable_zi and self.alloc.is_zero[x]:
                        lazy.add(x)
                for pname in self.primary_names:
                    base = self.group.base(pname)
                    rows.append((base + ai, base + bi, base + di,
                                 BlockRef(pname, di)))
        if lazy:
            # the RAW guard orders the zero-init ahead of the compute row
            self.materialize_zeros(sorted(lazy))
        return rows

    def _membitwise(self, op: int, rows) -> int:
        total = self.group.total_blocks
        # registry-enforced int32 bound — the same check runs on every
        # pack/unpack (enqueue, retire, journal replay), not just here
        check_pack_total(total)
        for a, b, d, dref in rows:
            self._cur_queue.enqueue(op, pack_bitwise_src(a, b, total), d)
            self.stats.bitwise_ops += 1
            self.stats.bytes_bitwise += self._pool_block_bytes(dref.pool)
            obs_metrics.inc("engine.bytes_moved",
                            self._pool_block_bytes(dref.pool),
                            mechanism="bitwise", pool=dref.pool)
            if dref.pool in self.primary_names:
                # dst now holds computed (generally non-zero) bytes
                self.alloc.mark_written([int(dref.block)])
        self._autoflush()
        return len(rows)

    def memand(self, triples) -> int:
        """Bitwise AND: ``dst = a & b`` block-wise for each ``(a, b,
        dst)`` triple, over the raw bit patterns (float pools combine via
        a same-width unsigned bitcast).  Triples are all-BlockRef (any
        pool mix, including staging) or all-int (primary space, fanned
        out to every primary pool).  ``dst`` may equal either source.
        Rides the current stream's queue like any copy — two-source
        hazards (RAW/WAW on either source) auto-flush, WAR is spaced."""
        return self._membitwise(OP_AND, self._bitwise_rows(triples,
                                                           "memand"))

    def memor(self, triples) -> int:
        """Bitwise OR: ``dst = a | b`` block-wise for each ``(a, b,
        dst)`` triple — same addressing, hazard, and bitcast semantics as
        :meth:`memand`."""
        return self._membitwise(OP_OR, self._bitwise_rows(triples,
                                                          "memor"))

    def memnot(self, pairs) -> int:
        """Bitwise NOT: ``dst = ~src`` block-wise for each ``(src,
        dst)`` pair (the packed second source repeats ``src``) — same
        addressing, hazard, and bitcast semantics as :meth:`memand`."""
        return self._membitwise(
            OP_NOT, self._bitwise_rows([(s, s, d) for s, d in pairs],
                                       "memnot"))

    # ------------------------------------------------------------------
    # staging — prefill pages park in a staging pool, then promote into
    # allocator-owned primary blocks through the SAME command queue
    # ------------------------------------------------------------------
    def stage_blocks(self, n: int) -> List[int]:
        """Reserve ``n`` staging slot ids for an incoming prefill write.

        Slot ids index the staging pools' OWN address space
        (``stage_capacity`` slots — a staging ring may be far smaller than
        the KV pools).  Slots with a pending READ on any stream are not
        reused (a queued ``CROSS_POOL_COPY`` promotion must see the bytes
        currently parked there — the queues' source-hazard tracking is
        the ground truth); when the free list runs short the engine
        drains every stream first, which reclaims the in-flight slots."""
        if not self.staging:
            raise RuntimeError("engine has no staging pools")
        if len(self._stage_free) < n:
            self._flush_streams()  # drains promotions -> reclaims inflight
        if len(self._stage_free) < n:
            raise RuntimeError(
                f"staging pool exhausted ({n} slots requested, "
                f"{len(self._stage_free)} free of {self.stage_capacity})")
        return [self._stage_free.pop() for _ in range(n)]

    def release_stage_blocks(self, ids: Sequence[int]) -> None:
        """Return reserved staging slots that were never promoted (e.g. an
        admission that failed after ``stage_blocks``)."""
        self._reclaim_stage_slots([int(b) for b in ids])

    def promote_staged(self, pairs: Sequence[Tuple[int, object]]) -> int:
        """Promote staged prefill pages into primary pool blocks.

        ``pairs``: (staging_slot, dst) — the slot is a ``stage_blocks``
        id; the destination is a primary block id (int) or a
        :class:`BlockRef` into a primary pool.  Every registered staging
        pool promotes into its paired primary pool (k_stage→k and
        v_stage→v move in the same table), one ``CROSS_POOL_COPY`` command
        per pool pair per block — with pool-aware hazard keys, the whole
        promotion plus the round's CoW splits and tail inits drain as ONE
        fused launch at the next flush boundary.  Staging slots are
        reclaimed automatically once the queue drains."""
        if not self.staging:
            raise RuntimeError("engine has no staging pools")
        pairs = [(int(s), self._primary_id(d)) for s, d in pairs]
        with self.batch():
            for sname, pname in self.staging.items():
                self.memcopy_cross([(BlockRef(sname, s), BlockRef(pname, d))
                                    for s, d in pairs])
            # inside the batch: slots must be in-flight BEFORE the exit
            # flush so _after_flush reclaims them with that drain
            self.stats.stage_promotions += len(pairs)
            self._stage_inflight.extend(s for s, _ in pairs)
        return len(pairs)

    def retire_promotions(self, pairs: Sequence[Tuple[int, object]]) -> int:
        """Cancel queued stage→primary promotions and recycle their slots.

        ``pairs`` mirrors :meth:`promote_staged`: (staging_slot, dst).
        The sequence-lifecycle primitive behind ``ServingEngine.free``: a
        sequence freed *before* the round's flush returns its blocks to
        the allocator while its promotions still sit on a stream — left
        queued, they would drain later and overwrite whatever the
        allocator re-issued those blocks for.  Every matching pending row
        is removed from every live queue
        (:meth:`~repro.core.cmdqueue.CommandQueue.retire`); promotions
        that already drained are simply not found (their bytes landed
        before the free — harmless, the blocks were still owned then).
        Slots whose pending reads disappeared return to the free list.
        Returns the number of command rows retired."""
        if not self.staging:
            return 0
        pairs = [(int(s), self._primary_id(d)) for s, d in pairs]
        rows = [(OP_CROSS_POOL_COPY,
                 self.group.base(sname) + s, self.group.base(pname) + d)
                for sname, pname in self.staging.items()
                for s, d in pairs]
        removed = 0
        for q in list(self._live_queues.values()):
            removed += q.retire(rows)
        self.stats.retired_promotions += removed
        # slots freed of their pending reads rejoin the ring now
        self._after_flush()
        return removed

    # ------------------------------------------------------------------
    # demotion — preemption parks primary blocks in spill slots (the
    # reverse of promotion), resumption promotes them back
    # ------------------------------------------------------------------
    def enable_demotion(self, slots: Sequence[int]) -> None:
        """Hand the engine a set of spill-pool slot ids for preemption.

        ``slots`` index the spill pools' own address space and become the
        engine-owned demotion slot space (:meth:`demote_to_spill` draws
        from it; resumed or released slots return to it).  Callers that
        also run windowed checkpoints over the same spill pools give the
        two users disjoint ranges — the serving engine reserves
        ``[ckpt_window, ckpt_window + spill_pages)`` for demotion."""
        if not self._spill_map:
            raise RuntimeError(
                "engine has no spill pools (PoolSpec(role='spill')); "
                "serving builds them via make_serving_pools")
        cap = min(self.group[n].nblk for n in self._spill_map.values())
        slots = [int(s) for s in slots]
        for s in slots:
            if not 0 <= s < cap:
                raise ValueError(f"spill slot {s} out of range ({cap})")
        self._spill_slots = tuple(slots)
        self._spill_free = list(reversed(slots))
        self._spill_inflight = []

    def demote_to_spill(self, blocks: Sequence[object]) -> List[int]:
        """Evict primary blocks into spill slots — preemption by demotion.

        The reverse of :meth:`promote_staged`: each block cross-pool-
        copies into one demotion slot per spill pool pair (k→k_spill and
        v→v_spill travel together), riding the current queue like any
        bulk movement — a whole preemption adds rows to the round's one
        fused launch.  Returns the slot ids parking each block's bytes,
        in block order; the caller owns them until
        :meth:`promote_spilled` (resumption) or
        :meth:`release_spill_slots` (the demoted sequence died).

        The copy reads the blocks' CURRENT pool bytes.  Callers whose
        pools are written out of band of the allocator's ZI metadata
        (e.g. decode steps appending tokens in-jit) must
        ``alloc.mark_written`` the blocks first, or a stale lazy-zero bit
        would materialize zeros over the real bytes."""
        if not self._spill_slots:
            raise RuntimeError("demotion not enabled (enable_demotion)")
        blocks = [self._primary_id(b) for b in blocks]
        if len(self._spill_free) < len(blocks):
            raise RuntimeError(
                f"spill slots exhausted ({len(blocks)} requested, "
                f"{len(self._spill_free)} free of {self.spill_capacity})")
        slots = [self._spill_free.pop() for _ in blocks]
        with self.batch():
            for pname, sname in self._spill_map.items():
                self.memcopy_cross(
                    [(BlockRef(pname, b), BlockRef(sname, s))
                     for b, s in zip(blocks, slots)])
            self.stats.demotions += len(blocks)
        return slots

    def promote_spilled(self, pairs: Sequence[Tuple[int, object]]) -> int:
        """Promote demoted bytes back into primary blocks — resumption.

        ``pairs``: (spill_slot, dst primary block).  Mirrors
        :meth:`promote_staged` with the spill pools as the source; the
        slots join the in-flight list and return to the demotion free
        list once no stream holds a pending read of them (the same
        source-hazard lifetime as staging slots)."""
        if not self._spill_slots:
            raise RuntimeError("demotion not enabled (enable_demotion)")
        pairs = [(int(s), self._primary_id(d)) for s, d in pairs]
        with self.batch():
            for pname, sname in self._spill_map.items():
                self.memcopy_cross(
                    [(BlockRef(sname, s), BlockRef(pname, d))
                     for s, d in pairs])
            self.stats.spill_promotions += len(pairs)
            self._spill_inflight.extend(s for s, _ in pairs)
        return len(pairs)

    def release_spill_slots(self, ids: Sequence[int]) -> None:
        """Return demotion slots whose parked bytes are no longer needed
        (the demoted sequence finished, was cancelled, or was evicted by
        a recovery) without promoting them back.  Idempotent: slots
        already free (or still in flight — a resume promotion that
        drained reclaims through ``_after_flush``) are skipped, so
        recovery paths can release conservatively."""
        for s in ids:
            s = int(s)
            if s not in self._spill_free and s not in self._spill_inflight:
                self._spill_free.append(s)

    def _after_flush(self, queue: Optional[CommandQueue] = None) -> None:
        """CommandQueue callback after any stream drains: a staging (or
        in-flight demotion) slot is reusable exactly when NO stream still
        holds a pending read of it (the source-hazard tracking) —
        promotions that drained free their slots, promotions still queued
        on another stream keep theirs."""
        if self._stage_inflight:
            sidx = [self.group.index(name) for name in self.staging]
            queues = list(self._live_queues.values())
            still: List[int] = []
            freed: List[int] = []
            for slot in self._stage_inflight:
                if any(q.has_pending_read((p, slot))
                       for q in queues for p in sidx):
                    still.append(slot)
                else:
                    freed.append(slot)
            self._reclaim_stage_slots(freed)
            self._stage_inflight = still
        if self._spill_inflight:
            pidx = [self.group.index(name)
                    for name in self._spill_map.values()]
            queues = list(self._live_queues.values())
            still = []
            freed = []
            for slot in self._spill_inflight:
                if any(q.has_pending_read((p, slot))
                       for q in queues for p in pidx):
                    still.append(slot)
                else:
                    freed.append(slot)
            self._spill_free.extend(freed)
            self._spill_inflight = still

    # ------------------------------------------------------------------
    # meminit
    # ------------------------------------------------------------------
    def meminit(self, ids: Sequence[object],
                lazy: Optional[bool] = None) -> int:
        """Zero blocks (ints or primary-pool :class:`BlockRef`\\ s).
        Returns number physically zeroed (0 with ZI)."""
        ids = [self._primary_id(b) for b in ids]
        if lazy is None:
            lazy = self.enable_zi
        if lazy:
            self.alloc.mark_zero(ids)
            self.stats.zero_lazy += len(ids)
            self.stats.bytes_avoided += len(ids) * self._block_bytes()
            obs_metrics.inc("engine.bytes_avoided",
                            len(ids) * self._block_bytes(),
                            mechanism="zero_lazy")
            return 0
        self.materialize_zeros(ids)
        return len(ids)

    def materialize_zeros(self, ids: Sequence[object]) -> None:
        """BuZ through the reserved zero row (FPM copy from zero block).
        ``ids`` are ints or primary-pool :class:`BlockRef`\\ s."""
        ids = [self._primary_id(b) for b in ids]
        if not ids:
            return
        self.stats.zero_materialized += len(ids)
        self._cur_queue.enqueue_zero(ids)
        self.alloc.mark_written(ids)  # physically zero: ordinary data now
        self._autoflush()

    # ------------------------------------------------------------------
    # dispatch — called by CommandQueue.flush with a bucket-padded table
    # ------------------------------------------------------------------
    def _flush_spacing(self) -> bool:
        """Should CommandQueue.flush WAR-space the global table?  Yes for
        every single-device drain (the fused kernel consumes the spacing;
        the legacy fan-out ignores NOP rows, keeping A/B stats aligned).
        No when the flush will be mesh-partitioned: _dispatch_sharded
        strips global NOPs and partition_commands re-spaces each slab
        sub-table, so global spacers would only eat 512-row chunk budget
        (risking an extra collective launch) for nothing."""
        return not (self.use_fused and self._multi_device()
                    and pool_shard_count(self.mesh) > 1)

    def _pool_replicated(self) -> Tuple[bool, ...]:
        """Per-pool replication vector from the ``PoolSpec.sharding``
        hints: ``()`` marks a pool held whole on every device (a small
        staging ring) — its block axis never partitions in the sharded
        drain."""
        return tuple(s.sharding == () for s in self.group)

    def _dispatch_table(self, table: np.ndarray, n_cmds: int,
                        queue: Optional[CommandQueue] = None) -> int:
        """Execute one flushed command table.  Returns launches issued.
        ``queue`` (the flushing CommandQueue, when called from a flush)
        receives accounting the dispatch path itself produces — e.g. the
        per-slab WAR spacers the mesh partitioner inserts."""
        if not int((np.asarray(table)[:, 0] >= 0).sum()):
            return 0        # all-NOP/empty table: no launch on ANY path
        if self.use_fused:
            n_shards = pool_shard_count(self.mesh)
            if self._multi_device() and n_shards > 1:
                replicated = self._pool_replicated()
                ragged = [s.name for i, s in enumerate(self.group)
                          if not replicated[i] and s.nblk % n_shards]
                if ragged:
                    # can't partition: slabs would be ragged.  Degrade to
                    # the fan-out, but loudly — the caller loses the
                    # one-launch-per-flush invariant (serving rounds every
                    # pool's nblk to the shard count exactly to avoid
                    # this).
                    if not self._warned_unshardable:
                        self._warned_unshardable = True
                        warnings.warn(
                            f"RowCloneEngine: pools {ragged} have block "
                            f"counts not divisible by {n_shards} device "
                            "shards; mesh flushes fall back to the "
                            "multi-launch legacy fan-out")
                    return self._dispatch_legacy(table)
                if any(replicated) and self._writes_replicated(table,
                                                               replicated):
                    # a sharded→replicated cross write needs a broadcast
                    # hop the collective drain doesn't model; GSPMD's
                    # global gather/scatter handles it on the fan-out
                    return self._dispatch_legacy(table)
                return self._dispatch_sharded(table, n_shards, replicated,
                                              queue)
            if not self._multi_device():
                pools = tuple(self.pools.values())
                new = kops.fused_dispatch(pools, self._get_zero_blocks(),
                                          jnp.asarray(table),
                                          block_axis=self.block_axis,
                                          primary=self.group.primary,
                                          overlap=self.overlap)
                for name, arr in zip(self.pools, new):
                    self.pools[name] = arr
                self.stats.launches += 1
                return 1
        return self._dispatch_legacy(table)

    def _writes_replicated(self, table: np.ndarray,
                           replicated: Tuple[bool, ...]) -> bool:
        """Does any cross-pool row write a replicated pool from a SHARDED
        source?  (Replicated→replicated writes drain collectively — every
        shard applies them to its replica.)"""
        for op, s, d in table:
            op = int(op)
            # only global-dst rows (cross-pool / bitwise, per the
            # registry) can write a replicated pool from a sharded source
            if op < 0 or opspec(op).dst_kind != "global":
                continue
            reads, writes = row_rw(op, int(s), int(d), self.group.locate,
                                   self.group.total_blocks)
            pd = writes[0][0]
            if replicated[pd] and any(not replicated[p]
                                      for p, _b in reads):
                return True
        return False

    def _dispatch_sharded(self, table: np.ndarray, n_shards: int,
                          replicated: Tuple[bool, ...],
                          queue: Optional[CommandQueue] = None) -> int:
        """One collective launch for the whole table: per-slab sub-tables
        (slab-local ids, each pool partitioned by its OWN shard size;
        replicated pools ride whole on every shard) drain inside
        shard_map, cross-slab commands ride the same launch as a ppermute
        send/recv plan.  The partitioner's per-slab WAR spacers are
        credited to the flushing ``queue``'s stats (global spacing is
        skipped on this path — _flush_spacing)."""
        rows = [(int(op), int(s), int(d)) for op, s, d in table if op >= 0]
        plan = partition_commands(rows, n_shards=n_shards, group=self.group,
                                  replicated=replicated)
        if self.sanitizer is not None:
            self.sanitizer.check_plan(rows, plan, replicated)
        # journal the plan shape (not the tables — rows reproduce those):
        # a replayed drain rebuilding a different signature would compile
        # a new collective, which the plan_sig makes observable
        self._last_plan_sig = (plan.n_shards, plan.deltas,
                               int(plan.send_rows.shape[2]))
        if queue is not None:
            queue.stats.spacer_rows += plan.n_spacers
        new = kops.fused_dispatch_sharded(
            tuple(self.pools.values()), self._get_zero_blocks(), plan,
            mesh=self.mesh, pool_axes=pool_shard_axes(self.mesh),
            block_axis=self.block_axis, primary=self.group.primary,
            replicated=replicated)
        for name, arr in zip(self.pools, new):
            self.pools[name] = arr
        self.stats.launches += 1
        return 1

    def _dispatch_legacy(self, table: np.ndarray) -> int:
        """Seed-shaped fan-out: one device call per mechanism per pool,
        padded to ``max_requests``.  Kept for A/B benchmarking
        (``use_fused=False``); on sharded pools the global gather/scatters
        compile through GSPMD — the mesh fast path is _dispatch_sharded.

        Commands are batched per *consecutive run* of one opcode, in
        enqueue order — NOT grouped across the whole table.  The hazard
        guard permits write-after-read (a later command overwriting an
        earlier command's source); whole-table grouping would reorder
        those and diverge from the fused drain.  Within one run the
        gather-then-scatter helpers read pre-run state, which the RAW/WAW
        guards make equal to in-order semantics."""
        rows = [(int(op), int(s), int(d)) for op, s, d in table if op >= 0]
        launches = 0
        i = 0
        while i < len(rows):
            op = rows[i][0]
            j = i
            while j < len(rows) and rows[j][0] == op:
                j += 1
            run = [(s, d) for _, s, d in rows[i:j]]
            if op == OP_FPM_COPY:
                launches += self._legacy_fpm(run)
            elif op == OP_PSM_COPY:
                launches += self._legacy_psm(run)
            elif op == OP_BASELINE_COPY:
                launches += self._legacy_baseline(run)
            elif op == OP_ZERO_INIT:
                launches += self._legacy_zero([d for _, d in run])
            elif op == OP_CROSS_POOL_COPY:
                launches += self._legacy_cross(run)
            elif op in BITWISE_OPS:
                launches += self._legacy_bitwise(op, run)
            i = j
        self.stats.launches += launches
        return launches

    # -- legacy per-mechanism fan-out (seed A/B path) --------------------
    def _legacy_use_pallas(self) -> Optional[bool]:
        """Impl override for the legacy fan-out's block_axis=0 ops: under a
        mesh, force the jnp reference — a pallas_call has no SPMD
        partitioning rule, so only the plain gather/scatter compiles
        through GSPMD on sharded pools.  ``None`` = the standard
        resolution (Pallas on TPU) everywhere else."""
        return False if self._multi_device() else None

    def _legacy_fpm(self, pairs: List[Tuple[int, int]]) -> int:
        """Same-slab copies, one global gather/scatter per pool.  On
        sharded pools the reference op compiles through GSPMD (the seed's
        hand-rolled per-slab shard_map fan-out — and its per-slab overflow
        table — is retired; the mesh fast path is ``_dispatch_sharded``)."""
        launches = 0
        for chunk in _chunks(pairs, self.max_requests):
            ids = jnp.asarray(self._pad(chunk))
            for name in self.primary_names:
                if self.block_axis == 1:
                    self.pools[name] = _fpm_axis1_jit(self.pools[name],
                                                      ids)
                else:
                    self.pools[name] = kops.fpm_copy(
                        self.pools[name], ids,
                        use_pallas=self._legacy_use_pallas())
                notify_launch(self.max_requests, 1, "legacy_fpm")
                launches += 1
        return launches

    def _legacy_psm(self, pairs: List[Tuple[int, int]]) -> int:
        """Cross-slab transfer over the interconnect (DRAM internal bus →
        ICI).  Expressed as a global gather/scatter; XLA lowers the
        cross-shard movement to collective-permutes — the pipelined serial
        path — without any host round-trip."""
        launches = 0
        fn = _fpm_axis1_jit if self.block_axis == 1 else _psm_jit
        for chunk in _chunks(pairs, self.max_requests):
            ids = jnp.asarray(self._pad(chunk))
            for name in self.primary_names:
                self.pools[name] = fn(self.pools[name], ids)
                notify_launch(self.max_requests, 1, "legacy_psm")
                launches += 1
        return launches

    def _legacy_baseline(self, pairs: List[Tuple[int, int]]) -> int:
        launches = 0
        for chunk in _chunks(pairs, self.max_requests):
            ids = jnp.asarray(self._pad(chunk))
            for name in self.primary_names:
                if self.block_axis == 1:
                    self.pools[name] = _baseline_axis1_jit(self.pools[name],
                                                           ids)
                else:
                    self.pools[name] = kops.baseline_copy(self.pools[name],
                                                          ids)
                notify_launch(self.max_requests, 1, "legacy_baseline")
                launches += 1
        return launches

    def _legacy_zero(self, ids_list: List[int]) -> int:
        launches = 0
        m = self.max_requests
        for chunk in _chunks(ids_list, m):
            arr = np.full((m,), -1, np.int32)
            arr[: len(chunk)] = np.asarray(chunk, np.int32)
            idv = jnp.asarray(arr)
            for name in self.primary_names:
                pool = self.pools[name]
                if self.block_axis == 1:
                    self.pools[name] = _zero_axis1_jit(pool, idv)
                else:
                    zero_block = jnp.zeros((1,) + pool.shape[1:], pool.dtype)
                    self.pools[name] = kops.meminit_zero(
                        pool, zero_block, idv,
                        use_pallas=self._legacy_use_pallas())
                notify_launch(self.max_requests, 1, "legacy_zero")
                launches += 1
        return launches

    def _legacy_cross(self, stacked_pairs: List[Tuple[int, int]]) -> int:
        """Pool-pair sub-runs execute in ENQUEUE order, not grouped across
        the whole run: interleaved opposite-direction copies (k->v, v->k,
        k->v) may carry a write-after-read the hazard guard permits —
        whole-table grouping would reorder the later write ahead of the
        earlier read and diverge from the fused drain.  Global ids decode
        through the PoolGroup's prefix-sum bases (pools may differ in
        size)."""
        launches = 0
        names = list(self.pools)
        locate = self.group.locate
        loc = [(locate(s), locate(d)) for s, d in stacked_pairs]
        i = 0
        while i < len(stacked_pairs):
            key = (loc[i][0][0], loc[i][1][0])
            run: List[Tuple[int, int]] = []
            j = i
            while j < len(stacked_pairs) and \
                    (loc[j][0][0], loc[j][1][0]) == key:
                run.append((loc[j][0][1], loc[j][1][1]))
                j += 1
            ps, pd = key
            for chunk in _chunks(run, self.max_requests):
                ids = jnp.asarray(self._pad(chunk))
                if self.block_axis == 1:
                    self.pools[names[pd]] = _cross_axis1_jit(
                        self.pools[names[pd]], self.pools[names[ps]], ids)
                else:
                    self.pools[names[pd]] = kops.fpm_copy_cross(
                        self.pools[names[pd]], self.pools[names[ps]], ids,
                        use_pallas=self._legacy_use_pallas())
                notify_launch(self.max_requests, 1, "legacy_cross")
                launches += 1
            i = j
        return launches

    def _legacy_bitwise(self, op: int,
                        stacked_pairs: List[Tuple[int, int]]) -> int:
        """Bitwise compute rows on the fan-out path: pool-triple sub-runs
        execute in ENQUEUE order (same WAR-preserving discipline as
        ``_legacy_cross``), each as one gather-both-sources /
        bitcast-combine / scatter device call.  The packed ``srcB``
        decodes with the group's ``total_blocks``."""
        launches = 0
        names = list(self.pools)
        locate = self.group.locate
        total = self.group.total_blocks
        dec = []
        for s, d in stacked_pairs:
            a, b = unpack_bitwise_src(s, total)
            dec.append((locate(a), locate(b), locate(d)))
        i = 0
        while i < len(stacked_pairs):
            key = (dec[i][0][0], dec[i][1][0], dec[i][2][0])
            run: List[Tuple[int, int, int]] = []
            j = i
            while j < len(stacked_pairs) and \
                    (dec[j][0][0], dec[j][1][0], dec[j][2][0]) == key:
                run.append((dec[j][0][1], dec[j][1][1], dec[j][2][1]))
                j += 1
            pa, pb, pd = key
            m = self.max_requests
            for chunk in _chunks(run, m):
                arr = np.full((m, 3), -1, np.int32)
                arr[:len(chunk)] = np.asarray(chunk, np.int32)
                self.pools[names[pd]] = _bitwise_jit(
                    self.pools[names[pd]], self.pools[names[pa]],
                    self.pools[names[pb]], jnp.asarray(arr), op=int(op),
                    block_axis=self.block_axis)
                notify_launch(self.max_requests, 1, "legacy_bitwise")
                launches += 1
            i = j
        return launches


def _chunks(seq, n):
    for i in range(0, len(seq), n):
        yield seq[i:i + n]


@functools.partial(jax.jit, donate_argnums=(0,))
def _psm_jit(pool, ids):
    rows = pool[jnp.clip(ids[:, 0], 0, pool.shape[0] - 1)]
    safe_dst = jnp.where(ids[:, 1] >= 0, ids[:, 1], pool.shape[0])
    return pool.at[safe_dst].set(rows, mode="drop")


@functools.partial(jax.jit, donate_argnums=(0,))
def _fpm_axis1_jit(pool, ids):
    """Layer-stacked pools (L, nblk, ...): one gather/scatter over axis 1 —
    lowers to L independent local DMAs on TPU (no compute)."""
    rows = pool[:, jnp.clip(ids[:, 0], 0, pool.shape[1] - 1)]
    safe_dst = jnp.where(ids[:, 1] >= 0, ids[:, 1], pool.shape[1])
    return pool.at[:, safe_dst].set(rows, mode="drop")


@functools.partial(jax.jit, donate_argnums=(0,))
def _baseline_axis1_jit(pool, ids):
    rows = pool[:, jnp.clip(ids[:, 0], 0, pool.shape[1] - 1)]
    rows = (rows.astype(jnp.float32) * 1.0).astype(pool.dtype)
    safe_dst = jnp.where(ids[:, 1] >= 0, ids[:, 1], pool.shape[1])
    return pool.at[:, safe_dst].set(rows, mode="drop")


@functools.partial(jax.jit, donate_argnums=(0,))
def _cross_axis1_jit(dst_pool, src_pool, ids):
    """Layer-stacked pool→pool copy: gather/scatter over the block axis 1."""
    rows = src_pool[:, jnp.clip(ids[:, 0], 0, src_pool.shape[1] - 1)]
    safe_dst = jnp.where(ids[:, 1] >= 0, ids[:, 1], dst_pool.shape[1])
    return dst_pool.at[:, safe_dst].set(rows.astype(dst_pool.dtype),
                                        mode="drop")


# no donation: dst_pool may BE a_pool/b_pool (same-pool AND is common) and
# donating an aliased input would invalidate the surviving reference
@functools.partial(jax.jit, static_argnames=("op", "block_axis"))
def _bitwise_jit(dst_pool, a_pool, b_pool, ids, *, op, block_axis):
    """Legacy fan-out bitwise combine: gather both source rows, combine
    through a same-width unsigned bitcast, scatter to dst (``ids``:
    (m, 3) ``[a, b, dst]`` local rows, -1 disables a slot)."""
    ba = block_axis

    def gather(pool, idx):
        cl = jnp.clip(idx, 0, pool.shape[ba] - 1)
        return pool[cl] if ba == 0 else pool[:, cl]

    au = _bitcast_uint(gather(a_pool, ids[:, 0]))
    bu = _bitcast_uint(gather(b_pool, ids[:, 1]))
    if op == OP_AND:
        ru = au & bu
    elif op == OP_OR:
        ru = au | bu
    else:
        ru = ~au
    rows = jax.lax.bitcast_convert_type(ru, dst_pool.dtype)
    safe = jnp.where(ids[:, 2] >= 0, ids[:, 2], dst_pool.shape[ba])
    if ba == 0:
        return dst_pool.at[safe].set(rows, mode="drop")
    return dst_pool.at[:, safe].set(rows, mode="drop")


@functools.partial(jax.jit, donate_argnums=(0,))
def _zero_axis1_jit(pool, ids):
    safe = jnp.where(ids >= 0, ids, pool.shape[1])
    fill = jnp.zeros((pool.shape[0], ids.shape[0]) + pool.shape[2:],
                     pool.dtype)
    return pool.at[:, safe].set(fill, mode="drop")
