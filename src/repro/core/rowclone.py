"""RowCloneEngine — the ``memcopy``/``meminit`` "ISA" and its dispatcher.

Paper §2.3: software issues ``memcopy``/``meminit``; the microarchitecture
decides per request whether FPM, PSM, or the ordinary path applies, and the
MC serializes the commands.  Here:

* ``memcopy(pairs)``  — partitions (src, dst) block pairs by placement:
    - ``alias``  : dst unwritten + ZI enabled → refcount bump only
                   (in-cache copy: zero bytes move)
    - ``fpm``    : same slab → subarray-local DMA copy
    - ``psm``    : cross-slab → serialized transfer (ICI path)
    - ``baseline``: RowClone disabled → copy through the compute pipeline
* ``meminit(ids)``    — ZI lazy-zero bit when possible, else the zero-row
                        DMA broadcast.

Dispatch is **queued and fused** (core/cmdqueue.py): classification tags
each request with an opcode and enqueues it; at a flush boundary the whole
table drains as ONE fused kernel launch moving every pool
(kernels/fused_dispatch.py) — the MC command-drain analogue.  By default
each public call flushes on return (eager, seed-compatible semantics);
inside ``with engine.batch():`` commands accumulate and the device sees a
single launch at exit — the attention-step / benchmark-tick boundary.

Tables pad to power-of-two buckets (8/32/128/512, overflow chunked), not the
seed's fixed ``max_requests`` length.  Under a multi-device mesh the flush
drains as ONE shard_map'd collective launch: the table is partitioned into
per-slab sub-tables (slab-local ids, same kernel) plus a cross-slab
send/recv plan executed with ppermute inside the same launch
(core/cmdqueue.py ``partition_commands``).  ``use_fused=False`` keeps the
seed's per-mechanism, per-pool fan-out (one jit'd call per pool per
mechanism, padded to ``max_requests``) for A/B benchmarking; on sharded
arrays those global gather/scatters compile through GSPMD.

Addressing is the engine's :class:`~repro.core.poolspec.PoolGroup`: every
pool has its OWN block count, cross-pool commands carry global
``base[pool] + block`` ids (prefix-sum bases), and public calls accept
:class:`~repro.core.poolspec.BlockRef` operands — which is what lets a
serving engine size its staging pools as a small recycling ring instead of
full-size KV twins (~2x less resident pool memory, see launch/serve.py).
"""
from __future__ import annotations

import contextlib
import dataclasses
import functools
import warnings
from typing import Dict, Iterator, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh

from repro.core.allocator import SubarrayAllocator
from repro.core.cmdqueue import (CommandQueue, OP_BASELINE_COPY,
                                 OP_CROSS_POOL_COPY, OP_FPM_COPY, OP_PSM_COPY,
                                 OP_ZERO_INIT, partition_commands)
from repro.core.poolspec import BlockRef, PoolGroup
from repro.kernels import ops as kops
from repro.kernels.fused_dispatch import notify_launch
from repro.models.paged import pool_shard_axes, pool_shard_count

#: int-based public-API forms already warned about (one warning per form
#: per process — the shims stay one release, see ISSUE/ROADMAP)
_WARNED_SHIMS: set = set()


def _warn_int_shim(api: str, hint: str) -> None:
    """Emit the one-per-process DeprecationWarning for a legacy int-based
    calling convention (the BlockRef form is canonical)."""
    if api in _WARNED_SHIMS:
        return
    _WARNED_SHIMS.add(api)
    warnings.warn(f"{api}: {hint}", DeprecationWarning, stacklevel=3)


@dataclasses.dataclass
class EngineStats:
    fpm_copies: int = 0
    psm_copies: int = 0
    alias_copies: int = 0
    baseline_copies: int = 0
    cross_pool_copies: int = 0
    stage_promotions: int = 0   # staged blocks promoted into primary pools
    zero_lazy: int = 0
    zero_materialized: int = 0
    bytes_fpm: int = 0
    bytes_psm: int = 0
    bytes_baseline: int = 0
    bytes_cross: int = 0
    bytes_avoided: int = 0      # alias + lazy zero
    launches: int = 0           # device dispatches issued for bulk movement


class RowCloneEngine:
    """Owns block pools + allocator; dispatches copy/init requests.

    ``pools`` is a dict name -> jnp array (nblk_p, ...) — e.g. {"k":
    k_pools, "v": v_pools} sharing one allocator (paired pools: a request
    applies to every pool, like K and V pages of one KV block).  The
    engine's address space is its :class:`~repro.core.poolspec.PoolGroup`
    (``engine.group``): per-pool block counts with prefix-sum base
    offsets, so staging pools may be sized independently of their KV
    twins (a small staging *ring* instead of a full-size twin).  Public
    copy calls address blocks with :class:`~repro.core.poolspec.BlockRef`;
    bare ints remain accepted as primary-address-space ids (and the
    pool-name keyword form of ``memcopy_cross`` as a one-release shim).
    """

    def __init__(self, pools: Dict[str, jnp.ndarray],
                 allocator: SubarrayAllocator,
                 mesh: Optional[Mesh] = None,
                 enable_fpm: bool = True, enable_psm: bool = True,
                 enable_zi: bool = True, max_requests: int = 256,
                 block_axis: int = 0, use_fused: bool = True,
                 staging: Optional[Dict[str, str]] = None,
                 group: Optional[PoolGroup] = None):
        """``block_axis``: which pool axis indexes blocks.  0 = flat pools
        (nblk, ...); 1 = layer-stacked serving pools (L, nblk, ...) where a
        logical block is L physical pages moved together (L independent
        DMAs per request on TPU).

        ``use_fused``: drain flushed command tables through the single
        fused-dispatch launch (default) — under a multi-device mesh, one
        shard_map'd collective launch over per-slab sub-tables.  False
        restores the seed's per-mechanism, per-pool fan-out padded to
        ``max_requests``, kept for A/B benchmarking.

        ``group``: the engine's :class:`PoolGroup` address space.  When
        omitted, one is built from the arrays + the ``staging`` map (a
        staging pool name -> paired primary pool dict, e.g.
        ``{"k_stage": "k", "v_stage": "v"}``), with each pool's ``nblk``
        read off its block axis.  Primary pools must match the allocator's
        block count; staging pools may be ANY size (all staging pools
        share one size — the promotion slot space) but must mirror their
        twin's block shape and dtype.  Plain opcodes (memcopy/meminit)
        move blocks in primary pools only; staged bytes enter and leave a
        staging pool exclusively through ``OP_CROSS_POOL_COPY``
        (``promote_staged``), so allocator metadata (ZI bits, refcounts)
        keeps describing primary blocks.  Staging slot ids are
        engine-managed (``stage_blocks``), disjoint from the allocator's
        free lists."""
        self.alloc = allocator
        self.mesh = mesh
        self.enable_fpm = enable_fpm
        self.enable_psm = enable_psm
        self.enable_zi = enable_zi
        self.max_requests = max_requests
        self.block_axis = block_axis
        self.use_fused = use_fused
        if group is None:
            group = PoolGroup.from_pools(pools, block_axis=block_axis,
                                         staging=staging)
        self.group = group
        self.staging = dict(group.staging_map)
        assert set(group.names) == set(pools), (group.names, list(pools))
        # group order is the table order everywhere — realign the dict
        self.pools = {name: pools[name] for name in group.names}
        self.stats = EngineStats()
        self.queue = CommandQueue(self)
        self.deferred = False
        self._warned_unshardable = False
        self._zero_blocks: Optional[Tuple[jnp.ndarray, ...]] = None
        nblk = allocator.num_blocks
        for spec in group:
            p = self.pools[spec.name]
            assert p.shape[block_axis] == spec.nblk, \
                f"pool {spec.name!r}: {p.shape[block_axis]} blocks != " \
                f"spec nblk {spec.nblk}"
            if spec.role == "primary":
                assert spec.nblk == nblk, \
                    f"primary pool {spec.name!r}: {spec.nblk} blocks != " \
                    f"allocator's {nblk}"
        stage_cap = 0
        for sname, pname in self.staging.items():
            s, p = self.pools[sname], self.pools[pname]
            s_blk = list(s.shape)
            cap = s_blk.pop(block_axis)
            p_blk = list(p.shape)
            p_blk.pop(block_axis)
            assert s_blk == p_blk and s.dtype == p.dtype, \
                f"staging pool {sname!r} must mirror {pname!r}'s block " \
                "shape and dtype"
            assert stage_cap in (0, cap), \
                "staging pools must share one block count (the promotion " \
                f"slot space): {stage_cap} != {cap}"
            stage_cap = cap
        # staging slot free list + ids whose promotion is still queued
        # (reclaimed by _after_flush once the cross-pool copy has drained)
        self._stage_free: List[int] = list(range(stage_cap - 1, -1, -1))
        self._stage_inflight: List[int] = []

    # ------------------------------------------------------------------
    @property
    def num_blocks(self) -> int:
        """Blocks per PRIMARY pool (the allocator's address space; staging
        pools size independently — see ``stage_capacity``)."""
        return self.alloc.num_blocks

    @property
    def stage_capacity(self) -> int:
        """Staging slot ids available per staging pool (0 = no staging)."""
        return self.group[next(iter(self.staging))].nblk if self.staging \
            else 0

    @property
    def n_primary(self) -> int:
        """Number of primary pools (plain opcodes touch exactly these;
        staging pools only see cross-pool commands)."""
        return self.group.n_primary

    @property
    def primary_names(self) -> Tuple[str, ...]:
        """Names of the primary pools, in table order."""
        return self.group.primary_names

    def _multi_device(self) -> bool:
        return self.mesh is not None and \
            int(np.prod(self.mesh.devices.shape)) > 1

    def _block_bytes(self) -> int:
        """Bytes one plain command moves = one block across every PRIMARY
        pool (staging pools never ride plain opcodes)."""
        total = 0
        for name in self.primary_names:
            p = self.pools[name]
            shape = list(p.shape)
            shape.pop(self.block_axis)
            total += int(np.prod(shape)) * p.dtype.itemsize
        return total

    def _pool_block_bytes(self, name: str) -> int:
        p = self.pools[name]
        shape = list(p.shape)
        shape.pop(self.block_axis)
        return int(np.prod(shape)) * p.dtype.itemsize

    def pool_bytes_resident(self) -> int:
        """Total bytes resident across every pool array (primary +
        staging).  The serving-memory headline number: sizing staging as a
        small ring instead of a full twin (per-pool ``nblk`` in the
        PoolGroup) roughly halves this for a k/v + staging engine —
        tracked per serve_round row in BENCH_dispatch.json (schema v4)."""
        return sum(int(np.prod(p.shape)) * p.dtype.itemsize
                   for p in self.pools.values())

    def _pad(self, pairs: Sequence[Tuple[int, int]]) -> np.ndarray:
        """Seed-style fixed-length padding (legacy fan-out path only)."""
        m = self.max_requests
        arr = np.full((m, 2), -1, np.int32)
        if pairs:
            a = np.asarray(pairs, np.int32)[:m]
            arr[: len(a)] = a
        return arr

    def _get_zero_blocks(self) -> Tuple[jnp.ndarray, ...]:
        """Per-pool reserved zero row for BuZ — allocated once."""
        if self._zero_blocks is None:
            zbs = []
            for p in self.pools.values():
                blk = p.shape[1:] if self.block_axis == 0 else p.shape[2:]
                zbs.append(jnp.zeros((1,) + blk, p.dtype))
            self._zero_blocks = tuple(zbs)
        return self._zero_blocks

    # ------------------------------------------------------------------
    # flush control
    # ------------------------------------------------------------------
    def flush(self) -> int:
        """Drain the command queue.  Returns device launches issued."""
        return self.queue.flush()

    def _autoflush(self) -> None:
        if not self.deferred:
            self.queue.flush()

    @contextlib.contextmanager
    def batch(self) -> Iterator[CommandQueue]:
        """Defer flushing: commands enqueued inside the block drain as one
        fused launch at exit (the attention-step flush boundary).  Pool
        arrays are STALE inside the block — read them only after exit."""
        prev = self.deferred
        self.deferred = True
        try:
            yield self.queue
        finally:
            self.deferred = prev
            if not self.deferred:
                self.queue.flush()

    # ------------------------------------------------------------------
    # memcopy
    # ------------------------------------------------------------------
    def _primary_id(self, b) -> int:
        """Resolve a primary-address-space operand: a bare int is an
        allocator block id; a :class:`BlockRef` must name a primary pool
        (plain opcodes move the block in EVERY primary pool, so the ref's
        pool only validates intent — the id is the address)."""
        if isinstance(b, BlockRef):
            if b.pool not in self.group.primary_names:
                raise ValueError(
                    f"plain copy/init addresses primary pools; "
                    f"{b.pool!r} is a staging pool (use memcopy_cross)")
            if not 0 <= int(b.block) < self.num_blocks:
                raise ValueError(f"block {b.block} out of range for "
                                 f"primary pools ({self.num_blocks})")
            return int(b.block)
        return int(b)

    def memcopy(self, pairs: Sequence[Tuple[object, object]],
                dst_is_fresh: bool = False) -> Dict[str, int]:
        """Copy block src -> dst for each pair.  Returns dispatch counts.

        Pairs may be bare ints (allocator block ids) or
        :class:`BlockRef`\\ s naming a primary pool — either way the copy
        moves the block in every primary pool (K and V pages travel
        together).

        ``dst_is_fresh``: destinations have never been written (e.g. CoW
        targets) — with ZI the engine may satisfy zero-source copies by
        aliasing at the cache layer instead; that path lives in
        cow_cache.fork() and never reaches here.
        """
        counts = {"fpm": 0, "psm": 0, "baseline": 0}
        bb = self._block_bytes()
        for s, d in pairs:
            s, d = self._primary_id(s), self._primary_id(d)
            # ZI "in-cache copy" fast path: copying a lazily-zero block is a
            # metadata move — mark dst zero, move no bytes.
            if self.enable_zi and self.alloc.is_zero[s]:
                self.alloc.mark_zero([d])
                self.stats.alias_copies += 1
                self.stats.bytes_avoided += bb
                continue
            # mark the dst written NOW, not after the loop: a later pair in
            # this same call may read it as a source (chained (a,b),(b,c))
            # and must see it as real data, not stale lazy-zero metadata
            self.alloc.mark_written([d])
            if not self.enable_fpm:
                op = OP_BASELINE_COPY
            elif self.alloc.slab_of(s) == self.alloc.slab_of(d):
                op = OP_FPM_COPY
            elif self.enable_psm:
                op = OP_PSM_COPY
            else:
                op = OP_BASELINE_COPY
            if op == OP_FPM_COPY:
                counts["fpm"] += 1
                self.stats.fpm_copies += 1
                self.stats.bytes_fpm += bb
            elif op == OP_PSM_COPY:
                counts["psm"] += 1
                self.stats.psm_copies += 1
                self.stats.bytes_psm += bb
            else:
                counts["baseline"] += 1
                self.stats.baseline_copies += 1
                self.stats.bytes_baseline += bb
            self.queue.enqueue(op, s, d)
        self._autoflush()
        return counts

    def memcopy_cross(self, pairs: Sequence[Tuple[object, object]],
                      src_pool: Optional[str] = None,
                      dst_pool: Optional[str] = None) -> int:
        """Pool-to-pool block copy (e.g. prefill staging pool → serving
        pool) through the same queue: each pair becomes one
        ``CROSS_POOL_COPY`` command carrying global ``base[pool] + block``
        ids from the engine's :class:`PoolGroup`, so it rides the same
        fused launch as any pending copies/inits — and pools of DIFFERENT
        sizes (a staging ring vs a full KV pool) coexist in one table.
        Source and destination pools must share block shape and dtype.

        Canonical form: ``pairs`` of ``(BlockRef, BlockRef)`` — each pair
        names its own pools, so one call may mix pool pairs.  The legacy
        form (int pairs + ``src_pool``/``dst_pool`` keywords) is a
        one-release shim and emits a DeprecationWarning.

        Staging pools sit outside the allocator's metadata: a staging
        *source* always holds real bytes (the prefill wrote them), so the
        lazy-zero materialization below is skipped; a staging *destination*
        is an engine-managed slot, so no allocator block is marked
        written."""
        if src_pool is not None or dst_pool is not None:
            if src_pool is None or dst_pool is None:
                raise TypeError(
                    "memcopy_cross legacy form needs BOTH src_pool and "
                    f"dst_pool (got src_pool={src_pool!r}, "
                    f"dst_pool={dst_pool!r}); pass (BlockRef, BlockRef) "
                    "pairs instead")
            _warn_int_shim(
                "RowCloneEngine.memcopy_cross(pairs, src_pool, dst_pool)",
                "pass (BlockRef, BlockRef) pairs instead; the pool-name "
                "keywords are a one-release shim")
            pairs = [(BlockRef(src_pool, int(s)), BlockRef(dst_pool, int(d)))
                     for s, d in pairs]
        else:
            pairs = [(s if isinstance(s, BlockRef) else None,
                      d if isinstance(d, BlockRef) else None)
                     for s, d in pairs]
            if any(s is None or d is None for s, d in pairs):
                raise TypeError(
                    "memcopy_cross pairs must be (BlockRef, BlockRef) "
                    "(or pass src_pool/dst_pool with int pairs — "
                    "deprecated)")
        # validate every ref up front: the lazy-zero scan below indexes
        # allocator metadata, and a bad block id must fail cleanly before
        # any command or materialization side effect
        for s, d in pairs:
            self.group.gid(s), self.group.gid(d)
        # a lazily-zero PRIMARY source physically holds stale bytes; the ZI
        # bit is per *block* (primary pools jointly), so materialize it
        # before the pool-level copy (the hazard guard orders the zero
        # before the copy)
        lazy_srcs = [int(s.block) for s, _ in pairs
                     if s.pool not in self.staging
                     and self.enable_zi and self.alloc.is_zero[s.block]]
        if lazy_srcs:
            self.materialize_zeros(lazy_srcs)
        for s, d in pairs:
            self.queue.enqueue(OP_CROSS_POOL_COPY, self.group.gid(s),
                               self.group.gid(d))
            self.stats.cross_pool_copies += 1
            self.stats.bytes_cross += self._pool_block_bytes(d.pool)
            if d.pool not in self.staging:
                # dst now holds real data in dst_pool; a block can only
                # carry the lazy-zero bit when every primary pool's bytes
                # are logically zero
                self.alloc.mark_written([int(d.block)])
        self._autoflush()
        return len(pairs)

    # ------------------------------------------------------------------
    # staging — prefill pages park in a staging pool, then promote into
    # allocator-owned primary blocks through the SAME command queue
    # ------------------------------------------------------------------
    def stage_blocks(self, n: int) -> List[int]:
        """Reserve ``n`` staging slot ids for an incoming prefill write.

        Slot ids index the staging pools' OWN address space
        (``stage_capacity`` slots — a staging ring may be far smaller than
        the KV pools).  Slots whose promotion is still queued are not
        reused (the pending ``CROSS_POOL_COPY`` must read the bytes
        currently parked there); when the free list runs short the engine
        drains the queue first, which reclaims every in-flight slot."""
        if not self.staging:
            raise RuntimeError("engine has no staging pools")
        if len(self._stage_free) < n:
            self.flush()           # drains promotions -> reclaims inflight
        if len(self._stage_free) < n:
            raise RuntimeError(
                f"staging pool exhausted ({n} slots requested, "
                f"{len(self._stage_free)} free of {self.stage_capacity})")
        return [self._stage_free.pop() for _ in range(n)]

    def release_stage_blocks(self, ids: Sequence[int]) -> None:
        """Return reserved staging slots that were never promoted (e.g. an
        admission that failed after ``stage_blocks``)."""
        self._stage_free.extend(int(b) for b in ids)

    def promote_staged(self, pairs: Sequence[Tuple[int, object]]) -> int:
        """Promote staged prefill pages into primary pool blocks.

        ``pairs``: (staging_slot, dst) — the slot is a ``stage_blocks``
        id; the destination is a primary block id (int) or a
        :class:`BlockRef` into a primary pool.  Every registered staging
        pool promotes into its paired primary pool (k_stage→k and
        v_stage→v move in the same table), one ``CROSS_POOL_COPY`` command
        per pool pair per block — with pool-aware hazard keys, the whole
        promotion plus the round's CoW splits and tail inits drain as ONE
        fused launch at the next flush boundary.  Staging slots are
        reclaimed automatically once the queue drains."""
        if not self.staging:
            raise RuntimeError("engine has no staging pools")
        pairs = [(int(s), self._primary_id(d)) for s, d in pairs]
        with self.batch():
            for sname, pname in self.staging.items():
                self.memcopy_cross([(BlockRef(sname, s), BlockRef(pname, d))
                                    for s, d in pairs])
            # inside the batch: slots must be in-flight BEFORE the exit
            # flush so _after_flush reclaims them with that drain
            self.stats.stage_promotions += len(pairs)
            self._stage_inflight.extend(s for s, _ in pairs)
        return len(pairs)

    def _after_flush(self) -> None:
        """CommandQueue callback: queued promotions have drained, so their
        staging slots hold dead bytes and may be reused."""
        if self._stage_inflight:
            self._stage_free.extend(self._stage_inflight)
            self._stage_inflight = []

    # ------------------------------------------------------------------
    # meminit
    # ------------------------------------------------------------------
    def meminit(self, ids: Sequence[object],
                lazy: Optional[bool] = None) -> int:
        """Zero blocks (ints or primary-pool :class:`BlockRef`\\ s).
        Returns number physically zeroed (0 with ZI)."""
        ids = [self._primary_id(b) for b in ids]
        if lazy is None:
            lazy = self.enable_zi
        if lazy:
            self.alloc.mark_zero(ids)
            self.stats.zero_lazy += len(ids)
            self.stats.bytes_avoided += len(ids) * self._block_bytes()
            return 0
        self.materialize_zeros(ids)
        return len(ids)

    def materialize_zeros(self, ids: Sequence[object]) -> None:
        """BuZ through the reserved zero row (FPM copy from zero block).
        ``ids`` are ints or primary-pool :class:`BlockRef`\\ s."""
        ids = [self._primary_id(b) for b in ids]
        if not ids:
            return
        self.stats.zero_materialized += len(ids)
        self.queue.enqueue_zero(ids)
        self.alloc.mark_written(ids)  # physically zero: ordinary data now
        self._autoflush()

    # ------------------------------------------------------------------
    # dispatch — called by CommandQueue.flush with a bucket-padded table
    # ------------------------------------------------------------------
    def _dispatch_table(self, table: np.ndarray, n_cmds: int) -> int:
        """Execute one flushed command table.  Returns launches issued."""
        if not int((np.asarray(table)[:, 0] >= 0).sum()):
            return 0        # all-NOP/empty table: no launch on ANY path
        if self.use_fused:
            n_shards = pool_shard_count(self.mesh)
            if self._multi_device() and n_shards > 1:
                ragged = [s.name for s in self.group if s.nblk % n_shards]
                if ragged:
                    # can't partition: slabs would be ragged.  Degrade to
                    # the fan-out, but loudly — the caller loses the
                    # one-launch-per-flush invariant (serving rounds every
                    # pool's nblk to the shard count exactly to avoid
                    # this).
                    if not self._warned_unshardable:
                        self._warned_unshardable = True
                        warnings.warn(
                            f"RowCloneEngine: pools {ragged} have block "
                            f"counts not divisible by {n_shards} device "
                            "shards; mesh flushes fall back to the "
                            "multi-launch legacy fan-out")
                    return self._dispatch_legacy(table)
                return self._dispatch_sharded(table, n_shards)
            if not self._multi_device():
                pools = tuple(self.pools.values())
                new = kops.fused_dispatch(pools, self._get_zero_blocks(),
                                          jnp.asarray(table),
                                          block_axis=self.block_axis,
                                          primary=self.group.primary)
                for name, arr in zip(self.pools, new):
                    self.pools[name] = arr
                self.stats.launches += 1
                return 1
        return self._dispatch_legacy(table)

    def _dispatch_sharded(self, table: np.ndarray, n_shards: int) -> int:
        """One collective launch for the whole table: per-slab sub-tables
        (slab-local ids, each pool partitioned by its OWN shard size)
        drain inside shard_map, cross-slab commands ride the same launch
        as a ppermute send/recv plan."""
        rows = [(int(op), int(s), int(d)) for op, s, d in table if op >= 0]
        plan = partition_commands(rows, n_shards=n_shards, group=self.group)
        new = kops.fused_dispatch_sharded(
            tuple(self.pools.values()), self._get_zero_blocks(), plan,
            mesh=self.mesh, pool_axes=pool_shard_axes(self.mesh),
            block_axis=self.block_axis, primary=self.group.primary)
        for name, arr in zip(self.pools, new):
            self.pools[name] = arr
        self.stats.launches += 1
        return 1

    def _dispatch_legacy(self, table: np.ndarray) -> int:
        """Seed-shaped fan-out: one device call per mechanism per pool,
        padded to ``max_requests``.  Kept for A/B benchmarking
        (``use_fused=False``); on sharded pools the global gather/scatters
        compile through GSPMD — the mesh fast path is _dispatch_sharded.

        Commands are batched per *consecutive run* of one opcode, in
        enqueue order — NOT grouped across the whole table.  The hazard
        guard permits write-after-read (a later command overwriting an
        earlier command's source); whole-table grouping would reorder
        those and diverge from the fused drain.  Within one run the
        gather-then-scatter helpers read pre-run state, which the RAW/WAW
        guards make equal to in-order semantics."""
        rows = [(int(op), int(s), int(d)) for op, s, d in table if op >= 0]
        launches = 0
        i = 0
        while i < len(rows):
            op = rows[i][0]
            j = i
            while j < len(rows) and rows[j][0] == op:
                j += 1
            run = [(s, d) for _, s, d in rows[i:j]]
            if op == OP_FPM_COPY:
                launches += self._legacy_fpm(run)
            elif op == OP_PSM_COPY:
                launches += self._legacy_psm(run)
            elif op == OP_BASELINE_COPY:
                launches += self._legacy_baseline(run)
            elif op == OP_ZERO_INIT:
                launches += self._legacy_zero([d for _, d in run])
            elif op == OP_CROSS_POOL_COPY:
                launches += self._legacy_cross(run)
            i = j
        self.stats.launches += launches
        return launches

    # -- legacy per-mechanism fan-out (seed A/B path) --------------------
    def _legacy_use_pallas(self) -> Optional[bool]:
        """Impl override for the legacy fan-out's block_axis=0 ops: under a
        mesh, force the jnp reference — a pallas_call has no SPMD
        partitioning rule, so only the plain gather/scatter compiles
        through GSPMD on sharded pools.  ``None`` = the standard
        resolution (Pallas on TPU) everywhere else."""
        return False if self._multi_device() else None

    def _legacy_fpm(self, pairs: List[Tuple[int, int]]) -> int:
        """Same-slab copies, one global gather/scatter per pool.  On
        sharded pools the reference op compiles through GSPMD (the seed's
        hand-rolled per-slab shard_map fan-out — and its per-slab overflow
        table — is retired; the mesh fast path is ``_dispatch_sharded``)."""
        launches = 0
        for chunk in _chunks(pairs, self.max_requests):
            ids = jnp.asarray(self._pad(chunk))
            for name in self.primary_names:
                if self.block_axis == 1:
                    self.pools[name] = _fpm_axis1_jit(self.pools[name],
                                                      ids)
                else:
                    self.pools[name] = kops.fpm_copy(
                        self.pools[name], ids,
                        use_pallas=self._legacy_use_pallas())
                notify_launch(self.max_requests, 1, "legacy_fpm")
                launches += 1
        return launches

    def _legacy_psm(self, pairs: List[Tuple[int, int]]) -> int:
        """Cross-slab transfer over the interconnect (DRAM internal bus →
        ICI).  Expressed as a global gather/scatter; XLA lowers the
        cross-shard movement to collective-permutes — the pipelined serial
        path — without any host round-trip."""
        launches = 0
        fn = _fpm_axis1_jit if self.block_axis == 1 else _psm_jit
        for chunk in _chunks(pairs, self.max_requests):
            ids = jnp.asarray(self._pad(chunk))
            for name in self.primary_names:
                self.pools[name] = fn(self.pools[name], ids)
                notify_launch(self.max_requests, 1, "legacy_psm")
                launches += 1
        return launches

    def _legacy_baseline(self, pairs: List[Tuple[int, int]]) -> int:
        launches = 0
        for chunk in _chunks(pairs, self.max_requests):
            ids = jnp.asarray(self._pad(chunk))
            for name in self.primary_names:
                if self.block_axis == 1:
                    self.pools[name] = _baseline_axis1_jit(self.pools[name],
                                                           ids)
                else:
                    self.pools[name] = kops.baseline_copy(self.pools[name],
                                                          ids)
                notify_launch(self.max_requests, 1, "legacy_baseline")
                launches += 1
        return launches

    def _legacy_zero(self, ids_list: List[int]) -> int:
        launches = 0
        m = self.max_requests
        for chunk in _chunks(ids_list, m):
            arr = np.full((m,), -1, np.int32)
            arr[: len(chunk)] = np.asarray(chunk, np.int32)
            idv = jnp.asarray(arr)
            for name in self.primary_names:
                pool = self.pools[name]
                if self.block_axis == 1:
                    self.pools[name] = _zero_axis1_jit(pool, idv)
                else:
                    zero_block = jnp.zeros((1,) + pool.shape[1:], pool.dtype)
                    self.pools[name] = kops.meminit_zero(
                        pool, zero_block, idv,
                        use_pallas=self._legacy_use_pallas())
                notify_launch(self.max_requests, 1, "legacy_zero")
                launches += 1
        return launches

    def _legacy_cross(self, stacked_pairs: List[Tuple[int, int]]) -> int:
        """Pool-pair sub-runs execute in ENQUEUE order, not grouped across
        the whole run: interleaved opposite-direction copies (k->v, v->k,
        k->v) may carry a write-after-read the hazard guard permits —
        whole-table grouping would reorder the later write ahead of the
        earlier read and diverge from the fused drain.  Global ids decode
        through the PoolGroup's prefix-sum bases (pools may differ in
        size)."""
        launches = 0
        names = list(self.pools)
        locate = self.group.locate
        loc = [(locate(s), locate(d)) for s, d in stacked_pairs]
        i = 0
        while i < len(stacked_pairs):
            key = (loc[i][0][0], loc[i][1][0])
            run: List[Tuple[int, int]] = []
            j = i
            while j < len(stacked_pairs) and \
                    (loc[j][0][0], loc[j][1][0]) == key:
                run.append((loc[j][0][1], loc[j][1][1]))
                j += 1
            ps, pd = key
            for chunk in _chunks(run, self.max_requests):
                ids = jnp.asarray(self._pad(chunk))
                if self.block_axis == 1:
                    self.pools[names[pd]] = _cross_axis1_jit(
                        self.pools[names[pd]], self.pools[names[ps]], ids)
                else:
                    self.pools[names[pd]] = kops.fpm_copy_cross(
                        self.pools[names[pd]], self.pools[names[ps]], ids,
                        use_pallas=self._legacy_use_pallas())
                notify_launch(self.max_requests, 1, "legacy_cross")
                launches += 1
            i = j
        return launches


def _chunks(seq, n):
    for i in range(0, len(seq), n):
        yield seq[i:i + n]


@functools.partial(jax.jit, donate_argnums=(0,))
def _psm_jit(pool, ids):
    rows = pool[jnp.clip(ids[:, 0], 0, pool.shape[0] - 1)]
    safe_dst = jnp.where(ids[:, 1] >= 0, ids[:, 1], pool.shape[0])
    return pool.at[safe_dst].set(rows, mode="drop")


@functools.partial(jax.jit, donate_argnums=(0,))
def _fpm_axis1_jit(pool, ids):
    """Layer-stacked pools (L, nblk, ...): one gather/scatter over axis 1 —
    lowers to L independent local DMAs on TPU (no compute)."""
    rows = pool[:, jnp.clip(ids[:, 0], 0, pool.shape[1] - 1)]
    safe_dst = jnp.where(ids[:, 1] >= 0, ids[:, 1], pool.shape[1])
    return pool.at[:, safe_dst].set(rows, mode="drop")


@functools.partial(jax.jit, donate_argnums=(0,))
def _baseline_axis1_jit(pool, ids):
    rows = pool[:, jnp.clip(ids[:, 0], 0, pool.shape[1] - 1)]
    rows = (rows.astype(jnp.float32) * 1.0).astype(pool.dtype)
    safe_dst = jnp.where(ids[:, 1] >= 0, ids[:, 1], pool.shape[1])
    return pool.at[:, safe_dst].set(rows, mode="drop")


@functools.partial(jax.jit, donate_argnums=(0,))
def _cross_axis1_jit(dst_pool, src_pool, ids):
    """Layer-stacked pool→pool copy: gather/scatter over the block axis 1."""
    rows = src_pool[:, jnp.clip(ids[:, 0], 0, src_pool.shape[1] - 1)]
    safe_dst = jnp.where(ids[:, 1] >= 0, ids[:, 1], dst_pool.shape[1])
    return dst_pool.at[:, safe_dst].set(rows.astype(dst_pool.dtype),
                                        mode="drop")


@functools.partial(jax.jit, donate_argnums=(0,))
def _zero_axis1_jit(pool, ids):
    safe = jnp.where(ids >= 0, ids, pool.shape[1])
    fill = jnp.zeros((pool.shape[0], ids.shape[0]) + pool.shape[2:],
                     pool.dtype)
    return pool.at[:, safe].set(fill, mode="drop")
