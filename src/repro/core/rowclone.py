"""RowCloneEngine — the ``memcopy``/``meminit`` "ISA" and its dispatcher.

Paper §2.3: software issues ``memcopy``/``meminit``; the microarchitecture
decides per request whether FPM, PSM, or the ordinary path applies, and the
MC serializes the commands.  Here:

* ``memcopy(pairs)``  — partitions (src, dst) block pairs by placement:
    - ``alias``  : dst unwritten + ZI enabled → refcount bump only
                   (in-cache copy: zero bytes move)
    - ``fpm``    : same slab → per-slab DMA copy kernel under shard_map
    - ``psm``    : cross-slab → collective transfer (ICI path)
    - ``baseline``: RowClone disabled → copy through the compute pipeline
* ``meminit(ids)``    — ZI lazy-zero bit when possible, else the zero-row
                        DMA broadcast kernel.

The engine owns the (possibly sharded) pool arrays and mirrors the
allocator's placement metadata.  All jit'd data-plane calls use fixed-length
id lists padded with -1 so shapes stay static.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.core.allocator import SubarrayAllocator
from repro.kernels import ops as kops
from repro.models.paged import pool_shard_axes, pool_spec


@dataclasses.dataclass
class EngineStats:
    fpm_copies: int = 0
    psm_copies: int = 0
    alias_copies: int = 0
    baseline_copies: int = 0
    zero_lazy: int = 0
    zero_materialized: int = 0
    bytes_fpm: int = 0
    bytes_psm: int = 0
    bytes_baseline: int = 0
    bytes_avoided: int = 0      # alias + lazy zero


class RowCloneEngine:
    """Owns block pools + allocator; dispatches copy/init requests.

    ``pools`` is a dict name -> jnp array (nblk, ...) — e.g. {"k": k_pools,
    "v": v_pools} sharing one allocator (paired pools: a request applies to
    every pool, like K and V pages of one KV block).
    """

    def __init__(self, pools: Dict[str, jnp.ndarray],
                 allocator: SubarrayAllocator,
                 mesh: Optional[Mesh] = None,
                 enable_fpm: bool = True, enable_psm: bool = True,
                 enable_zi: bool = True, max_requests: int = 256,
                 block_axis: int = 0):
        """``block_axis``: which pool axis indexes blocks.  0 = flat pools
        (nblk, ...); 1 = layer-stacked serving pools (L, nblk, ...) where a
        logical block is L physical pages moved together (L independent
        DMAs per request on TPU)."""
        self.pools = dict(pools)
        self.alloc = allocator
        self.mesh = mesh
        self.enable_fpm = enable_fpm
        self.enable_psm = enable_psm
        self.enable_zi = enable_zi
        self.max_requests = max_requests
        self.block_axis = block_axis
        self.stats = EngineStats()
        nblk = next(iter(pools.values())).shape[block_axis]
        assert nblk == allocator.num_blocks

    # ------------------------------------------------------------------
    def _block_bytes(self) -> int:
        total = 0
        for p in self.pools.values():
            shape = list(p.shape)
            shape.pop(self.block_axis)
            total += int(np.prod(shape)) * p.dtype.itemsize
        return total

    def _pad(self, pairs: Sequence[Tuple[int, int]]) -> np.ndarray:
        m = self.max_requests
        arr = np.full((m, 2), -1, np.int32)
        if pairs:
            a = np.asarray(pairs, np.int32)[:m]
            arr[: len(a)] = a
        return arr

    # ------------------------------------------------------------------
    # memcopy
    # ------------------------------------------------------------------
    def memcopy(self, pairs: Sequence[Tuple[int, int]],
                dst_is_fresh: bool = False) -> Dict[str, int]:
        """Copy block src -> dst for each pair.  Returns dispatch counts.

        ``dst_is_fresh``: destinations have never been written (e.g. CoW
        targets) — with ZI the engine may satisfy zero-source copies by
        aliasing at the cache layer instead; that path lives in
        cow_cache.fork() and never reaches here.
        """
        fpm, psm, baseline, written = [], [], [], []
        for s, d in pairs:
            # ZI "in-cache copy" fast path: copying a lazily-zero block is a
            # metadata move — mark dst zero, move no bytes.
            if self.enable_zi and self.alloc.is_zero[s]:
                self.alloc.mark_zero([d])
                self.stats.alias_copies += 1
                self.stats.bytes_avoided += self._block_bytes()
                continue
            written.append(d)
            if not self.enable_fpm:
                baseline.append((s, d))
            elif self.alloc.slab_of(s) == self.alloc.slab_of(d):
                fpm.append((s, d))
            elif self.enable_psm:
                psm.append((s, d))
            else:
                baseline.append((s, d))
        if fpm:
            self._fpm_copy(fpm)
        if psm:
            self._psm_copy(psm)
        if baseline:
            self._baseline_copy(baseline)
        self.alloc.mark_written(written)
        return {"fpm": len(fpm), "psm": len(psm), "baseline": len(baseline)}

    # ------------------------------------------------------------------
    def _fpm_copy(self, pairs: List[Tuple[int, int]]) -> None:
        """Same-slab copies: per-slab DMA kernel.  Under a mesh the id lists
        are grouped per slab and the kernel runs inside shard_map with local
        ids; on one device it runs directly."""
        self.stats.fpm_copies += len(pairs)
        self.stats.bytes_fpm += len(pairs) * self._block_bytes()
        if self.mesh is None or int(np.prod(self.mesh.devices.shape)) == 1:
            ids = jnp.asarray(self._pad(pairs))
            for name in self.pools:
                if self.block_axis == 1:
                    self.pools[name] = _fpm_axis1_jit(self.pools[name], ids)
                else:
                    self.pools[name] = kops.fpm_copy(self.pools[name], ids)
            return
        n_slabs = self.alloc.num_slabs
        per_slab = np.full((n_slabs, self.max_requests, 2), -1, np.int32)
        fill = np.zeros(n_slabs, np.int32)
        ss = self.alloc.slab_size
        for s, d in pairs:
            sl = self.alloc.slab_of(s)
            i = fill[sl]
            if i >= self.max_requests:
                raise ValueError("request list overflow; raise max_requests")
            per_slab[sl, i] = (s % ss, d % ss)   # slab-local ids
            fill[sl] += 1
        ids = jnp.asarray(per_slab.reshape(n_slabs * self.max_requests, 2))
        pspec = pool_spec(self.mesh)
        idspec = pool_spec(self.mesh)

        def run(pool_slab, ids_slab):
            return kops.fpm_copy(pool_slab, ids_slab)

        mapped = jax.shard_map(run, mesh=self.mesh,
                               in_specs=(pspec, idspec), out_specs=pspec,
                               check_vma=False)
        for name in self.pools:
            self.pools[name] = mapped(self.pools[name], ids)

    # ------------------------------------------------------------------
    def _psm_copy(self, pairs: List[Tuple[int, int]]) -> None:
        """Cross-slab transfer over the interconnect (DRAM internal bus →
        ICI).  Expressed as a global gather/scatter; XLA lowers the
        cross-shard movement to collective-permutes — the pipelined serial
        path — without any host round-trip."""
        self.stats.psm_copies += len(pairs)
        self.stats.bytes_psm += len(pairs) * self._block_bytes()
        ids = jnp.asarray(self._pad(pairs))
        fn = _fpm_axis1_jit if self.block_axis == 1 else _psm_jit
        for name in self.pools:
            self.pools[name] = fn(self.pools[name], ids)

    def _baseline_copy(self, pairs: List[Tuple[int, int]]) -> None:
        self.stats.baseline_copies += len(pairs)
        self.stats.bytes_baseline += len(pairs) * self._block_bytes()
        ids = jnp.asarray(self._pad(pairs))
        for name in self.pools:
            if self.block_axis == 1:
                self.pools[name] = _baseline_axis1_jit(self.pools[name], ids)
            else:
                self.pools[name] = kops.baseline_copy(self.pools[name], ids)

    # ------------------------------------------------------------------
    # meminit
    # ------------------------------------------------------------------
    def meminit(self, ids: Sequence[int], lazy: Optional[bool] = None) -> int:
        """Zero blocks.  Returns number physically zeroed (0 with ZI)."""
        ids = [int(b) for b in ids]
        if lazy is None:
            lazy = self.enable_zi
        if lazy:
            self.alloc.mark_zero(ids)
            self.stats.zero_lazy += len(ids)
            self.stats.bytes_avoided += len(ids) * self._block_bytes()
            return 0
        self.materialize_zeros(ids)
        return len(ids)

    def materialize_zeros(self, ids: Sequence[int]) -> None:
        """BuZ through the reserved zero row (FPM copy from zero block)."""
        ids = [int(b) for b in ids]
        if not ids:
            return
        self.stats.zero_materialized += len(ids)
        m = self.max_requests
        arr = np.full((m,), -1, np.int32)
        arr[: len(ids)] = np.asarray(ids[:m], np.int32)
        idv = jnp.asarray(arr)
        for name in self.pools:
            pool = self.pools[name]
            if self.block_axis == 1:
                self.pools[name] = _zero_axis1_jit(pool, idv)
            else:
                zero_block = jnp.zeros((1,) + pool.shape[1:], pool.dtype)
                self.pools[name] = kops.meminit_zero(pool, zero_block, idv)
        self.alloc.mark_written(ids)  # physically zero: ordinary data now


@functools.partial(jax.jit, donate_argnums=(0,))
def _psm_jit(pool, ids):
    rows = pool[jnp.clip(ids[:, 0], 0, pool.shape[0] - 1)]
    safe_dst = jnp.where(ids[:, 1] >= 0, ids[:, 1], pool.shape[0])
    return pool.at[safe_dst].set(rows, mode="drop")


@functools.partial(jax.jit, donate_argnums=(0,))
def _fpm_axis1_jit(pool, ids):
    """Layer-stacked pools (L, nblk, ...): one gather/scatter over axis 1 —
    lowers to L independent local DMAs on TPU (no compute)."""
    rows = pool[:, jnp.clip(ids[:, 0], 0, pool.shape[1] - 1)]
    safe_dst = jnp.where(ids[:, 1] >= 0, ids[:, 1], pool.shape[1])
    return pool.at[:, safe_dst].set(rows, mode="drop")


@functools.partial(jax.jit, donate_argnums=(0,))
def _baseline_axis1_jit(pool, ids):
    rows = pool[:, jnp.clip(ids[:, 0], 0, pool.shape[1] - 1)]
    rows = (rows.astype(jnp.float32) * 1.0).astype(pool.dtype)
    safe_dst = jnp.where(ids[:, 1] >= 0, ids[:, 1], pool.shape[1])
    return pool.at[:, safe_dst].set(rows, mode="drop")


@functools.partial(jax.jit, donate_argnums=(0,))
def _zero_axis1_jit(pool, ids):
    safe = jnp.where(ids >= 0, ids, pool.shape[1])
    fill = jnp.zeros((pool.shape[0], ids.shape[0]) + pool.shape[2:],
                     pool.dtype)
    return pool.at[:, safe].set(fill, mode="drop")
