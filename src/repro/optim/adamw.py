"""AdamW with decoupled weight decay, global-norm clipping, cosine schedule.

Pure-pytree implementation (no optax dependency).  Optimizer state inherits
each parameter's sharding (ZeRO-3: params are sharded over data×model, so m
and v are too — per-device optimizer memory is params/Ndev × 3).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, NamedTuple, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import TrainConfig


class AdamWState(NamedTuple):
    step: jnp.ndarray      # ()
    m: Any                 # like params
    v: Any                 # like params


def init_state(params) -> AdamWState:
    zeros = jax.tree_util.tree_map(jnp.zeros_like, params)
    return AdamWState(jnp.zeros((), jnp.int32), zeros,
                      jax.tree_util.tree_map(jnp.zeros_like, params))


def cosine_schedule(cfg: TrainConfig, step):
    warm = jnp.minimum(step / jnp.maximum(cfg.warmup_steps, 1), 1.0)
    prog = jnp.clip((step - cfg.warmup_steps) /
                    jnp.maximum(cfg.total_steps - cfg.warmup_steps, 1), 0, 1)
    cos = 0.5 * (1 + jnp.cos(jnp.pi * prog))
    return cfg.learning_rate * warm * (0.1 + 0.9 * cos)


def global_norm(tree):
    leaves = jax.tree_util.tree_leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                        for x in leaves))


def clip_by_global_norm(grads, max_norm):
    norm = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-9))
    return jax.tree_util.tree_map(lambda g: g * scale, grads), norm


_NO_DECAY_SUBSTR = ("norm", "bias", "A_log", "dt_bias", "D")


def _decay_mask(params):
    def mask_path(path, _):
        name = "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                        for p in path)
        return not any(s in name for s in _NO_DECAY_SUBSTR)
    return jax.tree_util.tree_map_with_path(mask_path, params)


def apply_updates(params, grads, state: AdamWState, cfg: TrainConfig
                  ) -> Tuple[Any, AdamWState, Dict[str, jnp.ndarray]]:
    grads, gnorm = clip_by_global_norm(grads, cfg.grad_clip)
    step = state.step + 1
    lr = cosine_schedule(cfg, step.astype(jnp.float32))
    b1, b2 = cfg.b1, cfg.b2
    bc1 = 1 - b1 ** step.astype(jnp.float32)
    bc2 = 1 - b2 ** step.astype(jnp.float32)
    decay = _decay_mask(params)

    def upd(p, g, m, v, dec):
        g = g.astype(jnp.float32)
        p32 = p.astype(jnp.float32)
        m = b1 * m + (1 - b1) * g
        v = b2 * v + (1 - b2) * jnp.square(g)
        mh = m / bc1
        vh = v / bc2
        delta = mh / (jnp.sqrt(vh) + 1e-8)
        if dec:
            delta = delta + cfg.weight_decay * p32
        return (p32 - lr * delta).astype(p.dtype), m, v

    flat_p, tdef = jax.tree_util.tree_flatten(params)
    flat_g = tdef.flatten_up_to(grads)
    flat_m = tdef.flatten_up_to(state.m)
    flat_v = tdef.flatten_up_to(state.v)
    flat_d = tdef.flatten_up_to(decay)
    out = [upd(p, g, m, v, d) for p, g, m, v, d in
           zip(flat_p, flat_g, flat_m, flat_v, flat_d)]
    new_p = tdef.unflatten([o[0] for o in out])
    new_m = tdef.unflatten([o[1] for o in out])
    new_v = tdef.unflatten([o[2] for o in out])
    metrics = {"grad_norm": gnorm, "lr": lr}
    return new_p, AdamWState(step, new_m, new_v), metrics
