"""Error-feedback gradient compression for the DP all-reduce.

Two levels:

* ``bf16`` (default when enabled): gradients cross the ICI as bfloat16 —
  halves all-reduce bytes.  Error feedback keeps the fp32 residual on-device
  and re-injects it next step, making the compression *unbiased over time*.
* ``int8``: reduce-scatter in int8 with a globally-agreed per-tensor scale,
  local fp32 accumulation, all-gather int8 — ~3.2× fewer ICI bytes.

These run inside a shard_map whose manual axes are the DP axes only (model
axis stays automatic/GSPMD), so they compose with the TP-sharded model.
The train driver enables this path with ``TrainConfig.grad_compress``.
"""
from __future__ import annotations

import functools
from typing import Any, Tuple

import jax
import jax.numpy as jnp


def compress_psum_bf16(grads, err, dp_axes: Tuple[str, ...], dp_size: int):
    """grads/err: pytrees (per-DP-shard partial grads + feedback residual).
    Returns (mean_grads fp32, new_err)."""

    def one(g, e):
        g32 = g.astype(jnp.float32) + e
        gc = g32.astype(jnp.bfloat16)
        new_e = g32 - gc.astype(jnp.float32)
        s = jax.lax.psum(gc, dp_axes)
        return s.astype(jnp.float32) / dp_size, new_e

    flat_g, tdef = jax.tree_util.tree_flatten(grads)
    flat_e = tdef.flatten_up_to(err)
    out = [one(g, e) for g, e in zip(flat_g, flat_e)]
    return (tdef.unflatten([o[0] for o in out]),
            tdef.unflatten([o[1] for o in out]))


def compress_psum_int8(grads, err, dp_axes: Tuple[str, ...], dp_size: int):
    """int8 wire format with a global per-tensor scale (one scalar psum)."""

    def one(g, e):
        g32 = g.astype(jnp.float32) + e
        amax = jax.lax.pmax(jnp.max(jnp.abs(g32)), dp_axes)
        scale = jnp.maximum(amax, 1e-12) / 127.0
        q = jnp.clip(jnp.round(g32 / scale), -127, 127).astype(jnp.int8)
        new_e = g32 - q.astype(jnp.float32) * scale
        # int8 on the wire; accumulate in int32 locally after transfer.
        # psum of int8 would wrap, so ship int8 via psum on int32 views of
        # the *scattered* shards: reduce_scatter int8 is the honest wire
        # format — approximate with psum(int32) when the axis is small.
        s = jax.lax.psum(q.astype(jnp.int32), dp_axes)
        return s.astype(jnp.float32) * scale / dp_size, new_e

    flat_g, tdef = jax.tree_util.tree_flatten(grads)
    flat_e = tdef.flatten_up_to(err)
    out = [one(g, e) for g, e in zip(flat_g, flat_e)]
    return (tdef.unflatten([o[0] for o in out]),
            tdef.unflatten([o[1] for o in out]))


def init_error_state(params):
    return jax.tree_util.tree_map(
        lambda p: jnp.zeros(p.shape, jnp.float32), params)
