from repro.optim.adamw import (
    AdamWState, apply_updates, clip_by_global_norm, cosine_schedule,
    global_norm, init_state,
)
