"""Elastic scaling: re-mesh on membership change, reshard from checkpoint.

When the healthy host set changes, the driver (a) picks the largest valid
mesh from the survivors (model axis preserved — TP degree is baked into the
weight layout; DP shrinks/grows), (b) restores the last checkpoint with the
new shardings, (c) rescales the data pipeline so the *global* batch is
preserved when possible (microbatch accumulation absorbs the difference).

Scale-UP re-uses the paper's fork semantics: new replicas are "forked" from
a live one — parameters stream once over ICI (PSM-style pipelined transfer,
here: the device_put resharding collective), not from the host.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Optional, Tuple

import jax
import numpy as np
from jax.sharding import Mesh

from repro.checkpoint.manager import CheckpointManager


@dataclasses.dataclass
class ElasticDecision:
    mesh_shape: Tuple[int, ...]
    axis_names: Tuple[str, ...]
    dp_size: int
    microbatches: int          # to preserve global batch


def plan_remesh(n_devices: int, model_parallel: int,
                global_batch: int, old_dp: int,
                multi_pod: bool = False) -> ElasticDecision:
    """Choose the largest (dp, tp) grid with tp == model_parallel that fits
    the surviving device count."""
    if n_devices < model_parallel:
        raise ValueError(
            f"cannot keep TP={model_parallel} with {n_devices} devices")
    dp = n_devices // model_parallel
    # keep global batch: if dp shrank, accumulate more microbatches
    micro = max(1, math.ceil(old_dp / dp))
    if multi_pod and dp % 2 == 0:
        return ElasticDecision((2, dp // 2, model_parallel),
                               ("pod", "data", "model"), dp, micro)
    return ElasticDecision((dp, model_parallel), ("data", "model"), dp, micro)


def build_mesh(decision: ElasticDecision,
               devices: Optional[np.ndarray] = None) -> Mesh:
    if devices is None:
        n = int(np.prod(decision.mesh_shape))
        devices = np.asarray(jax.devices()[:n])
    return Mesh(devices.reshape(decision.mesh_shape), decision.axis_names)


def elastic_restore(ckpt: CheckpointManager, example_state, new_mesh: Mesh,
                    sharding_fn):
    """Restore the latest checkpoint resharded for ``new_mesh``.

    ``sharding_fn(mesh) -> pytree of NamedSharding`` matching the state."""
    shardings = sharding_fn(new_mesh)
    return ckpt.restore(example_state, shardings=shardings)
