from repro.runtime.fault import (
    FaultPlan, HeartbeatLedger, InjectedFault, NodeFailure, RestartPolicy,
    StragglerReport, run_with_restarts,
)
from repro.runtime.elastic import (
    ElasticDecision, build_mesh, elastic_restore, plan_remesh,
)
