"""Fault tolerance: failure injection, heartbeat ledger, restart driver.

At 1000+ nodes the failure model is: (a) hard node loss — detected by
missed heartbeats / collective timeout, recovered by checkpoint restore
(possibly elastic, runtime/elastic.py); (b) stragglers — detected from the
step-time ledger, mitigated by flagging the slow host for the elastic layer
and (optionally) shrinking its microbatch share.

The deterministic data pipeline (data/pipeline.py) is keyed by step, so a
restarted run replays the exact token stream — restart is bitwise-replayable
modulo hardware nondeterminism.

**Serving-side failure injection** lives here too: :class:`FaultPlan`
plugs into the engine's per-chunk drain guards
(kernels/fused_dispatch.py ``add_drain_guard``) and raises
:class:`InjectedFault` at chosen engine flush indices —

* *launch failures* fire before a flush's FIRST chunk dispatches (the
  whole flush aborts cleanly; nothing moved);
* *mid-flush aborts* fire before a LATER chunk (the dispatched prefix is
  journaled as an aborted record, the suffix stashed — the partial-flush
  case ``RowCloneEngine.recover`` re-drains);
* *donation errors* simulate a staging buffer dying mid-admission
  (:meth:`FaultPlan.check_admission` deletes the staging pool arrays the
  prefill jit was about to donate, then raises).

A plan binds to ONE engine (``install(engine)``): the guard ignores other
engines' drains, so an A/B benchmark's reference engine runs clean while
the fault engine takes the injections.  Each injection fires at most
once.  See docs/ARCHITECTURE.md "Failure model and recovery".
"""
from __future__ import annotations

import contextlib
import dataclasses
from typing import Callable, Dict, Iterator, List, Optional, Set, Tuple

import numpy as np

from repro.checkpoint.manager import CheckpointManager
from repro.obs import metrics as obs_metrics
from repro.kernels.fused_dispatch import (DrainInfo, add_drain_guard,
                                          remove_drain_guard)


class NodeFailure(RuntimeError):
    """Raised (or injected in tests) when a node is lost mid-step."""


class InjectedFault(RuntimeError):
    """A :class:`FaultPlan` injection fired — the deliberate failure the
    recovery path is being exercised against."""


@dataclasses.dataclass
class FaultPlan:
    """Deterministic failure injections against ONE engine's drain path.

    ``launch_failures`` / ``midflush_aborts`` name engine flush indices
    (``engine.next_flush_index`` before the targeted flush): a launch
    failure raises before chunk 0 dispatches, a mid-flush abort raises
    before the SECOND chunk (flushes with one chunk — under 512 spaced
    rows — never see it).  ``donation_errors`` name admission ordinals
    checked by :meth:`check_admission` between staging and the prefill
    jit's donating call.  Every injection fires at most once; ``fired``
    records what actually triggered.

    Use :meth:`active` (or ``install``/``remove``) to scope the plan::

        plan = FaultPlan(launch_failures=(eng.next_flush_index,))
        with plan.active(eng):
            ...   # the targeted flush raises InjectedFault
        eng.recover()
    """

    launch_failures: Tuple[int, ...] = ()
    midflush_aborts: Tuple[int, ...] = ()
    donation_errors: Tuple[int, ...] = ()

    def __post_init__(self):
        self.fired: List[Tuple[str, int]] = []
        self._engine: Optional[object] = None
        self._seen: Set[Tuple[str, int]] = set()

    def install(self, engine) -> "FaultPlan":
        """Bind to ``engine`` and hook its drain path.  Only this
        engine's flushes can trigger the plan."""
        if self._engine is not None:
            raise RuntimeError("FaultPlan already installed")
        self._engine = engine
        add_drain_guard(self._guard)
        return self

    def remove(self) -> None:
        """Unhook from the drain path (idempotent)."""
        if self._engine is None:
            return
        self._engine = None
        remove_drain_guard(self._guard)

    @contextlib.contextmanager
    def active(self, engine) -> Iterator["FaultPlan"]:
        """``install`` on entry, ``remove`` on exit — the scoped form."""
        self.install(engine)
        try:
            yield self
        finally:
            self.remove()

    def _fire(self, kind: str, index: int) -> None:
        key = (kind, index)
        if key in self._seen:
            return
        self._seen.add(key)
        self.fired.append(key)
        raise InjectedFault(f"injected {kind} at flush {index}")

    def _guard(self, info: DrainInfo) -> None:
        if info.engine is not self._engine:
            return
        if info.chunk == 0 and info.flush in self.launch_failures:
            self._fire("launch_failure", info.flush)
        if info.chunk >= 1 and info.flush in self.midflush_aborts:
            self._fire("midflush_abort", info.flush)

    def check_admission(self, ordinal: int, engine) -> None:
        """Admission-path hook: when ``ordinal`` is scheduled for a
        donation error, delete the engine's staging pool arrays (as a
        failed donating prefill launch would have consumed them) and
        raise :class:`InjectedFault`.  The serving layer's recovery must
        then resurrect the staging ring and evict the admission."""
        if ordinal not in self.donation_errors or \
                engine is not self._engine:
            return
        key = ("donation_error", ordinal)
        if key in self._seen:
            return
        self._seen.add(key)
        self.fired.append(key)
        for name in engine.staging:
            p = engine.pools[name]
            if hasattr(p, "delete"):
                p.delete()
        raise InjectedFault(f"injected donation_error at admission "
                            f"{ordinal}")


@dataclasses.dataclass
class StragglerReport:
    step: int
    step_time: float
    median: float
    ratio: float


class HeartbeatLedger:
    """Rolling per-step wall-time record with straggler detection."""

    def __init__(self, window: int = 50, threshold: float = 2.0):
        self.window = window
        self.threshold = threshold
        self.times: List[float] = []
        self.reports: List[StragglerReport] = []
        self._t0: Optional[float] = None

    def step_start(self) -> None:
        self._t0 = obs_metrics.now()

    def step_end(self, step: int) -> Optional[StragglerReport]:
        if self._t0 is None:
            # step_end without a matching step_start (e.g. a monitor
            # thread observing a step it didn't open): no timing to
            # record, not an error
            return None
        dt = obs_metrics.now() - self._t0
        self._t0 = None
        self.times.append(dt)
        hist = self.times[-self.window:]
        med = float(np.median(hist))
        if len(hist) >= 5 and dt > self.threshold * med:
            rep = StragglerReport(step, dt, med, dt / med)
            self.reports.append(rep)
            return rep
        return None


@dataclasses.dataclass
class RestartPolicy:
    max_restarts: int = 3
    checkpoint_every: int = 50


def run_with_restarts(train_loop: Callable[[int, object], object],
                      init_state, ckpt: CheckpointManager,
                      policy: RestartPolicy,
                      shardings=None) -> object:
    """Drive ``train_loop(start_step, state) -> state`` with restart-on-
    failure.  ``train_loop`` is expected to checkpoint via ``ckpt``
    internally every ``checkpoint_every`` steps and raise NodeFailure (or
    any exception) on fault."""
    state = init_state
    start = 0
    restarts = 0
    while True:
        try:
            return train_loop(start, state)
        except NodeFailure as e:
            restarts += 1
            if restarts > policy.max_restarts:
                raise RuntimeError(
                    f"exceeded {policy.max_restarts} restarts") from e
            step = ckpt.latest_step()
            if step is None:
                state, start = init_state, 0
            else:
                state, start = ckpt.restore(init_state, step,
                                            shardings=shardings)
                start = step
