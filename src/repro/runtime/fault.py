"""Fault tolerance: heartbeat ledger, straggler detection, restart driver.

At 1000+ nodes the failure model is: (a) hard node loss — detected by
missed heartbeats / collective timeout, recovered by checkpoint restore
(possibly elastic, runtime/elastic.py); (b) stragglers — detected from the
step-time ledger, mitigated by flagging the slow host for the elastic layer
and (optionally) shrinking its microbatch share.

The deterministic data pipeline (data/pipeline.py) is keyed by step, so a
restarted run replays the exact token stream — restart is bitwise-replayable
modulo hardware nondeterminism.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Callable, Dict, List, Optional

import numpy as np

from repro.checkpoint.manager import CheckpointManager


class NodeFailure(RuntimeError):
    """Raised (or injected in tests) when a node is lost mid-step."""


@dataclasses.dataclass
class StragglerReport:
    step: int
    step_time: float
    median: float
    ratio: float


class HeartbeatLedger:
    """Rolling per-step wall-time record with straggler detection."""

    def __init__(self, window: int = 50, threshold: float = 2.0):
        self.window = window
        self.threshold = threshold
        self.times: List[float] = []
        self.reports: List[StragglerReport] = []
        self._t0: Optional[float] = None

    def step_start(self) -> None:
        self._t0 = time.monotonic()

    def step_end(self, step: int) -> Optional[StragglerReport]:
        dt = time.monotonic() - self._t0
        self.times.append(dt)
        hist = self.times[-self.window:]
        med = float(np.median(hist))
        if len(hist) >= 5 and dt > self.threshold * med:
            rep = StragglerReport(step, dt, med, dt / med)
            self.reports.append(rep)
            return rep
        return None


@dataclasses.dataclass
class RestartPolicy:
    max_restarts: int = 3
    checkpoint_every: int = 50


def run_with_restarts(train_loop: Callable[[int, object], object],
                      init_state, ckpt: CheckpointManager,
                      policy: RestartPolicy,
                      shardings=None) -> object:
    """Drive ``train_loop(start_step, state) -> state`` with restart-on-
    failure.  ``train_loop`` is expected to checkpoint via ``ckpt``
    internally every ``checkpoint_every`` steps and raise NodeFailure (or
    any exception) on fault."""
    state = init_state
    start = 0
    restarts = 0
    while True:
        try:
            return train_loop(start, state)
        except NodeFailure as e:
            restarts += 1
            if restarts > policy.max_restarts:
                raise RuntimeError(
                    f"exceeded {policy.max_restarts} restarts") from e
            step = ckpt.latest_step()
            if step is None:
                state, start = init_state, 0
            else:
                state, start = ckpt.restore(init_state, step,
                                            shardings=shardings)
                start = step
