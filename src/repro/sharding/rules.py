"""Logical-axis sharding rules (MaxText-style) → PartitionSpec/NamedSharding.

The production mesh is ``("data","model")`` single-pod or
``("pod","data","model")`` multi-pod.  Model code annotates arrays with
*logical* axis names; this module resolves them against whatever mesh is
current, dropping mesh axes that don't exist (so the same model code runs
single-pod, multi-pod, or on the 1-device CPU test mesh).

Attention strategy selection (see DESIGN.md §4):
  * ``heads``    — q heads divisible by |model|: shard heads, attention local.
  * ``seq``      — otherwise (llama3.2-3b 24H, paligemma 8H): shard q-sequence
                   over model, all-gather KV per layer.
  * decode always shards the paged KV pool's *block* axis over model
    ("subarray slabs"), combining partial attention with LSE-psum.
"""
from __future__ import annotations

from typing import Dict, List, Optional, Sequence, Tuple

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

# logical axis -> preferred mesh axes (first match present in mesh is used;
# tuples mean "shard over all of these jointly")
DEFAULT_RULES: Dict[str, Sequence] = {
    # activations
    "batch": (("pod", "data"),),
    "act_seq": (None,),            # sequence: unsharded by default
    "act_seq_tp": ("model",),      # sequence-parallel attention segments
    "act_heads": ("model",),
    "act_kv_heads": ("model",),
    "act_embed": (None,),
    "act_ffn": ("model",),
    "act_experts": ("model",),
    "act_vocab": ("model",),
    # parameters (ZeRO-3: the non-TP dim shards over data)
    "embed": ("data",),
    "vocab": ("model",),
    "qkv": ("model",),
    "heads": ("model",),
    "ffn": ("model",),
    "experts": ("model",),
    "ssm_inner": ("model",),
    "ssm_heads_p": ("model",),
    "layers": (None,),
    "norm": ("data",),
    "conv_w": (None,),
    "conv_ch": ("model",),
    "ssm_state_p": (None,),
    # paged pools: block axis over every mesh axis = "subarray slabs"
    # (DESIGN.md §2) — matches models/paged.py::pool_spec
    "kv_blocks": (("pod", "data", "model"),),
    "kv_seq": ("model",),
    "replicated": (None,),
}


# FSDP-dominant rules for TRAINING (activated via use_rules()): batch over
# every mesh axis (pure data parallel — activations never cross devices),
# params ZeRO-sharded over all axes on their d_model-ish dim.  Ordered
# fallbacks let each dim pick the largest mesh-axis group that divides it.
FSDP_RULES: Dict[str, Sequence] = {
    "batch": (("pod", "data", "model"), ("data", "model"), ("pod", "data"),
              ("data",)),
    "act_seq": (None,),
    "act_seq_tp": (None,),
    "act_heads": (None,),
    "act_kv_heads": (None,),
    "act_embed": (None,),
    "act_ffn": (None,),
    "act_experts": (None,),
    "act_vocab": (None,),
    "embed": (("pod", "data", "model"), ("data", "model"), ("data",)),
    "vocab": (None,),
    "qkv": (None,),
    "heads": (None,),
    "ffn": (None,),
    "experts": (None,),
    "ssm_inner": (("pod", "data", "model"), ("data", "model"), ("data",)),
    "ssm_heads_p": (None,),
    "layers": (None,),
    "norm": (("pod", "data", "model"), ("data", "model"), ("data",)),
    "conv_w": (None,),
    "conv_ch": (None,),     # replicated: see models/mamba2.py init comment
    "ssm_state_p": (None,),
    "kv_blocks": (("pod", "data", "model"),),
    "kv_seq": ("model",),
    "replicated": (None,),
}

_ACTIVE_RULES: List[Dict] = []


class use_rules:
    """Context manager activating an alternative rule set (e.g. FSDP_RULES
    while tracing a train step)."""

    def __init__(self, rules: Dict):
        self.rules = rules

    def __enter__(self):
        _ACTIVE_RULES.append(self.rules)
        return self.rules

    def __exit__(self, *exc):
        _ACTIVE_RULES.pop()


def active_rules() -> Dict:
    return _ACTIVE_RULES[-1] if _ACTIVE_RULES else DEFAULT_RULES


def mesh_axis_names(mesh: Mesh) -> Tuple[str, ...]:
    return tuple(mesh.axis_names)


def _resolve_entry(entry, axis_names, dim: Optional[int], mesh,
                   used) -> Optional[object]:
    """Resolve one rule entry against available mesh axes (+divisibility
    when the dim size is known).  Tuple entries resolve to the subset of
    their axes present in the mesh (e.g. ("pod","data") -> ("data",) on a
    single-pod mesh)."""
    if entry is None:
        return None
    flat = entry if isinstance(entry, tuple) else (entry,)
    present = tuple(a for a in flat if a in axis_names and a not in used)
    if not present:
        return None
    if dim is not None:
        size = int(np.prod([mesh.shape[a] for a in present]))
        if dim % size != 0:
            return None
    return present if len(present) > 1 else present[0]


def logical_to_spec(logical_axes: Sequence[Optional[str]], mesh: Mesh,
                    rules: Optional[Dict] = None,
                    dims: Optional[Sequence[Optional[int]]] = None) -> P:
    """Map logical axis names (or None) to a PartitionSpec.

    ``dims`` (optional, parallel to logical_axes): array dim sizes — rule
    fallbacks are tried in order until one divides the dim.
    """
    rules = rules or active_rules()
    axis_names = mesh_axis_names(mesh)
    out, used = [], set()
    for i, name in enumerate(logical_axes):
        if name is None:
            out.append(None)
            continue
        dim = dims[i] if dims is not None else None
        resolved = None
        for cand in rules.get(name, (None,)):
            resolved = _resolve_entry(cand, axis_names, dim, mesh, used)
            if resolved is not None:
                break
        if resolved is None:
            out.append(None)
        else:
            flat = resolved if isinstance(resolved, tuple) else (resolved,)
            used.update(flat)
            out.append(resolved)
    return P(*out)


def named_sharding(mesh: Mesh, *logical_axes, rules=None) -> NamedSharding:
    return NamedSharding(mesh, logical_to_spec(logical_axes, mesh, rules))


def constrain(x, mesh: Mesh, *logical_axes, rules=None):
    """with_sharding_constraint by logical axes; no-op off-mesh.

    Divisibility-aware: rule fallbacks are tried in order until one divides
    the dim (e.g. batch=1 in long_500k stays replicated)."""
    if mesh is None or np.prod(mesh.devices.shape) == 1:
        return x
    logical_axes = tuple(logical_axes)[: x.ndim]
    dims = tuple(x.shape[: len(logical_axes)])
    spec = logical_to_spec(logical_axes, mesh, rules, dims=dims)
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))


def divisible(n: int, mesh: Mesh, axis: str) -> bool:
    if axis not in mesh.axis_names:
        return True
    return n % mesh.shape[axis] == 0


def axis_size(mesh: Mesh, axis) -> int:
    if isinstance(axis, tuple):
        s = 1
        for a in axis:
            s *= axis_size(mesh, a)
        return s
    return mesh.shape[axis] if axis in mesh.axis_names else 1


def batch_spec_axes(global_batch: int, mesh: Mesh):
    """Pick the batch logical mapping: shard over (pod,data) when divisible,
    else replicate (long_500k batch=1)."""
    dp = axis_size(mesh, ("pod", "data"))
    return "batch" if global_batch % dp == 0 else None


def attn_strategy(num_q_heads: int, mesh: Mesh) -> str:
    """'heads' if q heads shard cleanly over the model axis, else 'seq'."""
    tp = axis_size(mesh, "model")
    return "heads" if num_q_heads % tp == 0 else "seq"
