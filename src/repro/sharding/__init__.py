from repro.sharding.rules import (
    DEFAULT_RULES,
    attn_strategy,
    axis_size,
    batch_spec_axes,
    constrain,
    divisible,
    logical_to_spec,
    named_sharding,
)

__all__ = [
    "DEFAULT_RULES",
    "attn_strategy",
    "axis_size",
    "batch_spec_axes",
    "constrain",
    "divisible",
    "logical_to_spec",
    "named_sharding",
    "constrain",
]
