"""Version-compat shims for the moving parts of the jax API.

``shard_map`` has lived in three places across jax releases:

* jax >= 0.6:   ``jax.shard_map(f, mesh=..., check_vma=...)``
* 0.4.x-0.5.x:  ``jax.experimental.shard_map.shard_map(f, mesh, ...,
                check_rep=...)`` — same knob, pre-rename (``check_vma``
                replaced ``check_rep`` when varying-manual-axes tracking
                landed; for our usage — disabling the replication check —
                the two are interchangeable).

Everything in this repo imports ``shard_map`` from here and always passes
``check_vma=``; the shim forwards to whichever spelling the installed jax
understands.
"""
from __future__ import annotations

import inspect

import jax

try:  # modern spelling
    _shard_map = jax.shard_map
except AttributeError:  # jax <= 0.5.x
    from jax.experimental.shard_map import shard_map as _shard_map

_PARAMS = inspect.signature(_shard_map).parameters
_HAS_CHECK_VMA = "check_vma" in _PARAMS


def shard_map(f, *, mesh, in_specs, out_specs, check_vma: bool = True, **kw):
    """Uniform front-end: accepts ``check_vma`` on every jax version."""
    if _HAS_CHECK_VMA:
        kw["check_vma"] = check_vma
    else:
        kw["check_rep"] = check_vma
    return _shard_map(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                      **kw)


def axis_size(axis_name):
    """``jax.lax.axis_size`` (jax >= 0.6); older jax spells it as a psum of
    ones over the named axis (identical value inside shard_map/pmap)."""
    fn = getattr(jax.lax, "axis_size", None)
    if fn is not None:
        return fn(axis_name)
    return jax.lax.psum(1, axis_name)


def tpu_compiler_params(**kw):
    """``pltpu.CompilerParams`` (jax >= 0.7) / ``pltpu.TPUCompilerParams``
    (older).  Import is deferred so CPU-only code never pulls Pallas in."""
    from jax.experimental.pallas import tpu as pltpu
    cls = getattr(pltpu, "CompilerParams", None) or pltpu.TPUCompilerParams
    return cls(**kw)
