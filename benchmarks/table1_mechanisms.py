"""Table 1 analogue — latency & energy of copy/zero mechanisms.

Paper Table 1 compares 4 KB copy/zero latency+energy for Baseline / FPM /
inter-bank PSM / intra-bank PSM.  Here the "row" is one KV block and the
mechanisms are:

  copy-baseline  — blocks round-trip the compute pipeline (HBM→VMEM→VREG→
                   VMEM→HBM), the memcpy-through-CPU analogue
  copy-fpm       — HBM→HBM DMA kernel (no compute units touched)
  copy-zi-alias  — RowClone-ZI in-cache copy: refcount bump, zero bytes
  copy-psm       — cross-slab transfer (ICI path, pipelined)
  zero-baseline  — stream zeros from VREGs
  zero-buz       — DMA-broadcast the reserved zero row (BuZ)
  zero-zi        — lazy-zero metadata bit (clean-zero insertion)

Two readouts per mechanism: measured µs/call on this host (relative,
CPU-interpreted kernels) and a derived TPU-v5e latency/energy from the bytes
each mechanism moves on each path (constants below, documented in
EXPERIMENTS.md).
"""
from __future__ import annotations

from typing import Dict, List

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import RowCloneEngine, SubarrayAllocator
from repro.obs import metrics as obs_metrics
from repro.kernels import ops as kops

# --- TPU v5e path model (per byte) ---
HBM_BW = 819e9
ICI_BW = 50e9
VPU_PIPE_BW = 400e9         # effective copy-through-registers bandwidth
DMA_SETUP_S = 1e-6
E_HBM = 40e-12              # J/byte touched in HBM
E_SRAM = 25e-12             # J/byte through VMEM/VREG
E_ICI = 90e-12              # J/byte crossing ICI

BLOCK = (64, 8, 128)        # page x KVH x head_dim  (bf16: 128 KiB -> per-
                            # chip share of a 4 KB DRAM row's role)


def _time(fn, *args, n=20):
    fn(*args)  # compile/warm
    with obs_metrics.Stopwatch() as sw:
        for _ in range(n):
            out = fn(*args)
        jax.block_until_ready(out)
    return sw.us / n


def run() -> List[Dict]:
    nblk = 64
    key = jax.random.key(0)
    pool = jax.random.normal(key, (nblk,) + BLOCK, jnp.float32)
    block_bytes = int(np.prod(BLOCK)) * 4
    ids = jnp.asarray([[i, 32 + i] for i in range(8)], jnp.int32)
    zids = jnp.asarray(list(range(32, 40)), jnp.int32)
    zero_block = jnp.zeros((1,) + BLOCK, jnp.float32)
    m = 8  # blocks per call

    rows = []

    def derived(bytes_hbm, bytes_sram, bytes_ici, setup=DMA_SETUP_S):
        lat = max(bytes_hbm / HBM_BW, bytes_sram / VPU_PIPE_BW,
                  bytes_ici / ICI_BW) + setup
        energy = bytes_hbm * E_HBM + bytes_sram * E_SRAM + bytes_ici * E_ICI
        occupancy = bytes_sram / VPU_PIPE_BW  # compute-pipeline time stolen
        return lat * 1e6, energy * 1e6, occupancy * 1e6  # us, uJ, us

    # --- copy mechanisms ---
    us = _time(lambda: kops.baseline_copy(pool, ids))
    lat, en, occ = derived(2 * m * block_bytes, 2 * m * block_bytes, 0, 0)
    rows.append(dict(mech="copy-baseline", measured_us=us, derived_us=lat,
                     energy_uJ=en, occupancy_us=occ,
                     bytes_compute=2 * m * block_bytes, bytes_ici=0))

    us = _time(lambda: kops.fpm_copy(pool.copy(), ids, use_pallas=True))
    lat, en, occ = derived(2 * m * block_bytes, 0, 0)
    rows.append(dict(mech="copy-fpm", measured_us=us, derived_us=lat,
                     energy_uJ=en, occupancy_us=occ, bytes_compute=0,
                     bytes_ici=0))

    # ZI alias copy: pure metadata (host refcount) — measure engine call
    alloc = SubarrayAllocator(nblk, 4)
    eng = RowCloneEngine({"k": pool}, alloc, max_requests=16)
    srcs = alloc.alloc(m, prefer_slab=0)
    eng.meminit(srcs)             # lazy-zero so copies alias
    dsts = alloc.alloc(m, prefer_slab=0)
    with obs_metrics.Stopwatch() as sw:
        eng.memcopy(list(zip(srcs, dsts)))
    us = sw.us / m
    rows.append(dict(mech="copy-zi-alias", measured_us=us, derived_us=0.0,
                     energy_uJ=0.0, occupancy_us=0.0, bytes_compute=0,
                     bytes_ici=0))

    # PSM: cross-slab — ICI path
    us = _time(lambda: kops.baseline_copy(pool, ids))  # CPU proxy timing
    lat, en, occ = derived(2 * m * block_bytes, 0, m * block_bytes)
    rows.append(dict(mech="copy-psm", measured_us=us, derived_us=lat,
                     energy_uJ=en, occupancy_us=occ, bytes_compute=0,
                     bytes_ici=m * block_bytes))

    # --- zero mechanisms ---
    def zero_baseline(p):
        upd = jnp.zeros((m,) + BLOCK, p.dtype)
        return p.at[zids].set(upd)

    us = _time(jax.jit(zero_baseline), pool)
    lat, en, occ = derived(m * block_bytes, m * block_bytes, 0, 0)
    rows.append(dict(mech="zero-baseline", measured_us=us, derived_us=lat,
                     energy_uJ=en, occupancy_us=occ,
                     bytes_compute=m * block_bytes, bytes_ici=0))

    us = _time(lambda: kops.meminit_zero(pool.copy(), zero_block, zids,
                                         use_pallas=True))
    # writes m blocks; the reserved zero row is read once (stays in cache)
    lat, en, occ = derived(m * block_bytes + block_bytes, 0, 0)
    rows.append(dict(mech="zero-buz", measured_us=us, derived_us=lat,
                     energy_uJ=en, occupancy_us=occ, bytes_compute=0,
                     bytes_ici=0))

    b2 = alloc.alloc(m, prefer_slab=1)
    with obs_metrics.Stopwatch() as sw:
        eng.meminit(b2)
    us = sw.us / m
    rows.append(dict(mech="zero-zi", measured_us=us, derived_us=0.0,
                     energy_uJ=0.0, occupancy_us=0.0, bytes_compute=0,
                     bytes_ici=0))

    base_lat = rows[0]["derived_us"]
    base_en = rows[0]["energy_uJ"]
    zbase_lat = rows[4]["derived_us"]
    zbase_en = rows[4]["energy_uJ"]
    for r in rows:
        is_zero = r["mech"].startswith("zero")
        bl = zbase_lat if is_zero else base_lat
        be = zbase_en if is_zero else base_en
        r["speedup_x"] = bl / r["derived_us"] if r["derived_us"] else float(
            "inf")
        r["energy_x"] = be / r["energy_uJ"] if r["energy_uJ"] else float(
            "inf")
    return rows
