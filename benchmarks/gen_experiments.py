"""Regenerate the generated tables inside EXPERIMENTS.md from the dry-run
JSONL artifacts (results/dryrun.jsonl = paper-faithful baseline,
results/dryrun_v2.jsonl = optimized).  Hand-written narrative outside the
markers is preserved.

    PYTHONPATH=src:. python -m benchmarks.gen_experiments
"""
from __future__ import annotations

import json
import os
import re

ROOT = os.path.join(os.path.dirname(__file__), "..")
EXP = os.path.join(ROOT, "EXPERIMENTS.md")


def load(path):
    best = {}
    p = os.path.join(ROOT, "results", path)
    if not os.path.exists(p):
        return best
    with open(p) as f:
        for line in f:
            r = json.loads(line)
            best[(r["arch"], r["shape"], r["mesh"])] = r
    return best


def fmt_row(r):
    if r["status"] == "skip":
        return (f"| {r['arch']} | {r['shape']} | — | — | — | skip | — | — | "
                f"{r.get('reason','')[:45]} |")
    if r["status"] != "ok":
        return (f"| {r['arch']} | {r['shape']} | — | — | — | ERROR | — | — | "
                f"{r.get('error','')[:45]} |")
    return ("| {arch} | {shape} | {tc:.0f} | {tm:.0f} | {tl:.0f} | {dom} | "
            "{uf:.2f} | {rf:.3f} | temp {tg:.1f} GiB |").format(
        arch=r["arch"], shape=r["shape"],
        tc=r["t_compute_s"] * 1e3, tm=r["t_memory_s"] * 1e3,
        tl=r["t_collective_s"] * 1e3, dom=r["dominant"],
        uf=r["useful_flop_ratio"], rf=r["roofline_fraction"],
        tg=r["memory"]["temp_size_in_bytes"] / 2 ** 30)


HEADER = ("| arch | shape | t_comp (ms) | t_mem (ms) | t_coll (ms) | "
          "dominant | useful | roofline | notes |\n"
          "|---|---|---|---|---|---|---|---|---|")


def table_for(rows, mesh):
    lines = [HEADER]
    for key in sorted(rows):
        if key[2] != mesh:
            continue
        lines.append(fmt_row(rows[key]))
    return "\n".join(lines)


def dryrun_summary(base, opt):
    merged = dict(base)
    merged.update(opt)
    n_ok = sum(1 for r in merged.values() if r["status"] == "ok")
    n_skip = sum(1 for r in merged.values() if r["status"] == "skip")
    n_err = sum(1 for r in merged.values() if r["status"] == "error")
    fits = [r for r in merged.values() if r["status"] == "ok" and
            r["memory"]["temp_size_in_bytes"] < 14 * 2 ** 30]
    lines = [
        f"* cells: **{n_ok} compile OK**, {n_skip} documented skips, "
        f"{n_err} errors",
        f"* per-device temp under 14 GiB (v5e HBM 16 GiB minus weights): "
        f"{len(fits)}/{n_ok}",
        "* multi-pod (2×16×16): every non-skip cell lowers + compiles — the "
        "`pod` axis shards (batch for train, pool blocks for decode)",
    ]
    over = [(k, r["memory"]["temp_size_in_bytes"] / 2 ** 30)
            for k, r in merged.items() if r["status"] == "ok" and
            r["memory"]["temp_size_in_bytes"] >= 14 * 2 ** 30]
    if over:
        over.sort(key=lambda kv: -kv[1])
        lines.append("* cells above 14 GiB temp (CPU-backend buffer "
                     "assignment overestimates; mitigations in §Perf): " +
                     ", ".join(f"{a}/{s}@{m} {g:.0f}GiB"
                               for (a, s, m), g in over[:6]))
    return "\n".join(lines)


def replace_section(text, marker, content):
    pat = re.compile(rf"(<!-- {marker}:begin -->).*?(<!-- {marker}:end -->)",
                     re.S)
    return pat.sub(rf"\1\n{content}\n\2", text)


def main():
    base = load("dryrun.jsonl")
    opt = load("dryrun_v2.jsonl")
    merged = dict(base)
    merged.update(opt)
    text = open(EXP).read()
    text = replace_section(text, "dryrun-summary", dryrun_summary(base, opt))
    text = replace_section(text, "roofline-baseline",
                           table_for(base, "16x16"))
    text = replace_section(text, "roofline-optimized",
                           table_for(merged, "16x16"))
    text = replace_section(text, "multipod-optimized",
                           table_for(merged, "2x16x16"))
    open(EXP, "w").write(text)
    print("EXPERIMENTS.md regenerated "
          f"(baseline cells: {len(base)}, optimized: {len(opt)})")


if __name__ == "__main__":
    main()
