# One function per paper table/figure. Prints ``name,us_per_call,derived``
# CSV rows plus the full per-benchmark tables.
import argparse
import csv
import io
import sys


def _emit(rows, title):
    print(f"\n## {title}")
    if not rows:
        print("(no rows)")
        return
    keys = sorted({k for r in rows for k in r})
    w = csv.DictWriter(sys.stdout, fieldnames=keys)
    w.writeheader()
    for r in rows:
        w.writerow({k: (f"{v:.4g}" if isinstance(v, float) else v)
                    for k, v in r.items()})


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None,
                    help="table1|fig2|fig34|roofline")
    args = ap.parse_args()

    # summary CSV (name,us_per_call,derived) required by the harness contract
    summary = []

    if args.only in (None, "table1"):
        from benchmarks import table1_mechanisms
        rows = table1_mechanisms.run()
        _emit(rows, "Table 1 analogue — copy/zero mechanism latency+energy")
        for r in rows:
            summary.append((f"table1/{r['mech']}", r["measured_us"],
                            r["derived_us"]))

    if args.only in (None, "fig2"):
        from benchmarks import fig2_applications
        rows = fig2_applications.run()
        _emit(rows, "Fig 2 analogue — application-level speedups")
        for r in rows:
            if r.get("rowclone") == "speedup":
                summary.append((f"fig2/{r['app']}", r["wall_s"] * 1e6,
                                r["wall_s"]))

    if args.only in (None, "fig34"):
        from benchmarks import fig34_multitenant
        rows = fig34_multitenant.run()
        _emit(rows, "Fig 3/4 analogue — multi-tenant weighted speedup")
        for r in rows:
            summary.append((f"fig34/{r['mix']}", 0.0, r["improvement"]))

    if args.only in (None, "roofline"):
        from benchmarks import roofline
        rows = roofline.run()
        _emit(rows, "Roofline terms per (arch x shape), single-pod 16x16")
        for r in rows:
            if r.get("status") == "ok":
                summary.append((f"roofline/{r['arch']}/{r['shape']}",
                                r["t_compute_ms"] * 1e3,
                                r["roofline_frac"]))

    print("\n## summary (name,us_per_call,derived)")
    for name, us, derived in summary:
        print(f"{name},{us:.3f},{derived:.6g}")


if __name__ == "__main__":
    main()
