"""Dispatch-path benchmark: fused command-queue flush vs seed per-op fan-out.

Measures, for mixed copy+zero batches over a {"k","v"} pool pair:

* launches per flush (via the kernels/fused_dispatch.py launch hook),
* wall-clock per flushed batch (median of repeated flushes, post-warmup),
* bytes physically moved (identical across paths — the win is dispatch).

Emits ``BENCH_dispatch.json``:

{
  "schema": "bench_dispatch/v1",
  "backend": "cpu" | "tpu",
  "block": [page, KVH, D], "nblk": int, "pools": ["k", "v"],
  "rows": [{
      "batch": int,            # commands per flush (copies + zeros)
      "path": "fused"|"seed",  # queue+fused launch vs per-op fan-out
      "launches_per_flush": float,
      "table_len": int,        # padded table length (bucket vs max_requests)
      "us_per_flush": float,   # median wall-clock
      "bytes_moved": int       # bytes one flush moves (per-flush, not
                               # cumulative over the measurement loop)
  }],
  "summary": {"speedup_small_batch": float}   # seed/fused us at batch<=8
}

CLI: PYTHONPATH=src python benchmarks/bench_dispatch.py [--out PATH]
"""
from __future__ import annotations

import argparse
import json
import time
from typing import Dict, List

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import RowCloneEngine, SubarrayAllocator
from repro.kernels import fused_dispatch as fd

BLOCK = (16, 2, 64)          # page x KVH x head_dim
NBLK = 1024
NSLABS = 4
BATCHES = (2, 4, 8, 32, 128)
REPS = 30


def _mk_engine(use_fused: bool) -> RowCloneEngine:
    alloc = SubarrayAllocator(NBLK, NSLABS, reserved_zero_per_slab=1)
    key = jax.random.key(0)
    pools = {
        "k": jax.random.normal(key, (NBLK,) + BLOCK, jnp.float32),
        "v": jax.random.normal(jax.random.key(1), (NBLK,) + BLOCK,
                               jnp.float32),
    }
    # max_requests=256 is the seed default the fan-out path pads to
    return RowCloneEngine(pools, alloc, mesh=None, max_requests=256,
                          use_fused=use_fused)


def _flush_once(eng: RowCloneEngine, batch: int, round_i: int) -> None:
    """One mixed flush: ~3/4 copies (FPM+PSM mix), ~1/4 zero-inits.
    Source/dest ids rotate per round so jit caches stay warm but data
    differs."""
    n_zero = max(batch // 4, 1)
    n_copy = batch - n_zero
    base = (round_i * batch) % (NBLK // 4)
    srcs = [1 + (base + i) % (NBLK // 4) for i in range(n_copy)]
    dsts = [NBLK // 2 + (base + i) % (NBLK // 4) for i in range(n_copy)]
    zeros = [3 * NBLK // 4 + (base + i) % (NBLK // 8) for i in range(n_zero)]
    eng.alloc.mark_written(srcs)
    with eng.batch():
        eng.memcopy(list(zip(srcs, dsts)))
        eng.materialize_zeros(zeros)


def _bench_path(use_fused: bool, batch: int) -> Dict:
    eng = _mk_engine(use_fused)
    events: List = []
    hook = lambda n, p, mech: events.append((n, p, mech))
    fd.add_launch_hook(hook)
    try:
        # warmup (compile) flushes
        for r in range(3):
            _flush_once(eng, batch, r)
        events.clear()
        eng.stats = type(eng.stats)()   # per-flush byte accounting below
        times = []
        for r in range(REPS):
            t0 = time.perf_counter()
            _flush_once(eng, batch, 100 + r)
            jax.block_until_ready(list(eng.pools.values()))
            times.append(time.perf_counter() - t0)
    finally:
        fd.remove_launch_hook(hook)
    bytes_moved = eng.stats.bytes_fpm + eng.stats.bytes_psm + \
        eng.stats.bytes_baseline
    bytes_moved += eng.stats.zero_materialized * eng._block_bytes()
    bytes_moved //= REPS
    return {
        "batch": batch,
        "path": "fused" if use_fused else "seed",
        "launches_per_flush": len(events) / REPS,
        "table_len": max((e[0] for e in events), default=0),
        "us_per_flush": float(np.median(times) * 1e6),
        "bytes_moved": int(bytes_moved),
    }


def run() -> Dict:
    rows = []
    for batch in BATCHES:
        for use_fused in (True, False):
            rows.append(_bench_path(use_fused, batch))
    small_f = [r for r in rows if r["path"] == "fused" and r["batch"] <= 8]
    small_s = [r for r in rows if r["path"] == "seed" and r["batch"] <= 8]
    speedup = (np.mean([r["us_per_flush"] for r in small_s]) /
               np.mean([r["us_per_flush"] for r in small_f]))
    return {
        "schema": "bench_dispatch/v1",
        "backend": jax.default_backend(),
        "block": list(BLOCK),
        "nblk": NBLK,
        "pools": ["k", "v"],
        "rows": rows,
        "summary": {"speedup_small_batch": float(speedup)},
    }


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="BENCH_dispatch.json")
    args = ap.parse_args()
    result = run()
    with open(args.out, "w") as f:
        json.dump(result, f, indent=2)
    print(f"{'batch':>6} {'path':>6} {'launches':>9} {'table':>6} "
          f"{'us/flush':>10} {'MB moved':>9}")
    for r in result["rows"]:
        print(f"{r['batch']:>6} {r['path']:>6} "
              f"{r['launches_per_flush']:>9.2f} {r['table_len']:>6} "
              f"{r['us_per_flush']:>10.1f} "
              f"{r['bytes_moved'] / 1e6:>9.1f}")
    print(f"\nsmall-batch (<=8) dispatch speedup: "
          f"{result['summary']['speedup_small_batch']:.2f}x  "
          f"-> {args.out}")


if __name__ == "__main__":
    main()
