"""Dispatch-path benchmark: fused command-queue flush vs seed per-op fan-out.

Measures, for mixed copy+zero batches over a {"k","v"} pool pair:

* launches per flush (via the kernels/fused_dispatch.py launch hook),
* wall-clock per flushed batch (median of repeated flushes, post-warmup),
* bytes physically moved (identical across paths — the win is dispatch).

Emits ``BENCH_dispatch.json``:

{
  "schema": "bench_dispatch/v2",
  "backend": "cpu" | "tpu",
  "block": [page, KVH, D], "nblk": int, "pools": ["k", "v"],
  "rows": [{
      "batch": int,            # commands per flush (copies + zeros)
      "path": "fused"|"seed",  # queue+fused launch vs per-op fan-out
      "launches_per_flush": float,
      "table_len": int,        # padded table length (bucket vs max_requests)
      "us_per_flush": float,   # median wall-clock
      "bytes_moved": int       # bytes one flush moves (per-flush, not
                               # cumulative over the measurement loop)
  }],
  "summary": {"speedup_small_batch": float},  # seed/fused us at batch<=8
  "mesh": {                    # multi-device A/B (8 forced host devices,
                               # measured in a subprocess; null if it failed)
      "devices": 8, "mesh_shape": [2, 4],
      "rows": [... same row schema, paths "fused"|"seed" ...],
      "summary": {"speedup": float,          # seed/fused wall-clock
                  "launches_fused": float,   # per flush (the "1" this PR
                  "launches_seed": float}    # buys vs the fan-out)
  }
}

CLI: PYTHONPATH=src python benchmarks/bench_dispatch.py [--out PATH]
                                                        [--skip-mesh]
"""
from __future__ import annotations

import argparse
import json
import os
import subprocess
import sys
import time
from typing import Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import RowCloneEngine, SubarrayAllocator
from repro.kernels import fused_dispatch as fd

BLOCK = (16, 2, 64)          # page x KVH x head_dim
NBLK = 1024
NSLABS = 4
BATCHES = (2, 4, 8, 32, 128)
REPS = 30
MESH_SHAPE = (2, 4)          # 8 forced host devices in the subprocess
MESH_BATCHES = (8, 32)
MESH_REPS = 10


def _mk_engine(use_fused: bool, mesh=None) -> RowCloneEngine:
    alloc = SubarrayAllocator(NBLK, NSLABS, reserved_zero_per_slab=1)
    key = jax.random.key(0)
    pools = {
        "k": jax.random.normal(key, (NBLK,) + BLOCK, jnp.float32),
        "v": jax.random.normal(jax.random.key(1), (NBLK,) + BLOCK,
                               jnp.float32),
    }
    # max_requests=256 is the seed default the fan-out path pads to
    return RowCloneEngine(pools, alloc, mesh=mesh, max_requests=256,
                          use_fused=use_fused)


def _flush_once(eng: RowCloneEngine, batch: int, round_i: int) -> None:
    """One mixed flush: ~3/4 copies (FPM+PSM mix), ~1/4 zero-inits.
    Source/dest ids rotate per round so jit caches stay warm but data
    differs."""
    n_zero = max(batch // 4, 1)
    n_copy = batch - n_zero
    base = (round_i * batch) % (NBLK // 4)
    srcs = [1 + (base + i) % (NBLK // 4) for i in range(n_copy)]
    dsts = [NBLK // 2 + (base + i) % (NBLK // 4) for i in range(n_copy)]
    zeros = [3 * NBLK // 4 + (base + i) % (NBLK // 8) for i in range(n_zero)]
    eng.alloc.mark_written(srcs)
    with eng.batch():
        eng.memcopy(list(zip(srcs, dsts)))
        eng.materialize_zeros(zeros)


def _bench_path(use_fused: bool, batch: int, mesh=None,
                reps: int = REPS) -> Dict:
    eng = _mk_engine(use_fused, mesh=mesh)
    events: List = []
    hook = lambda n, p, mech: events.append((n, p, mech))
    fd.add_launch_hook(hook)
    try:
        # warmup (compile) flushes
        for r in range(3):
            _flush_once(eng, batch, r)
        events.clear()
        eng.stats = type(eng.stats)()   # per-flush byte accounting below
        times = []
        for r in range(reps):
            t0 = time.perf_counter()
            _flush_once(eng, batch, 100 + r)
            jax.block_until_ready(list(eng.pools.values()))
            times.append(time.perf_counter() - t0)
    finally:
        fd.remove_launch_hook(hook)
    bytes_moved = eng.stats.bytes_fpm + eng.stats.bytes_psm + \
        eng.stats.bytes_baseline
    bytes_moved += eng.stats.zero_materialized * eng._block_bytes()
    bytes_moved //= reps
    return {
        "batch": batch,
        "path": "fused" if use_fused else "seed",
        "launches_per_flush": len(events) / reps,
        "table_len": max((e[0] for e in events), default=0),
        "us_per_flush": float(np.median(times) * 1e6),
        "bytes_moved": int(bytes_moved),
    }


# ---------------------------------------------------------------------------
# mesh A/B — runs in a subprocess with 8 forced host devices (jax locks the
# device count at first init, so the parent process can't host it)
# ---------------------------------------------------------------------------

def _mesh_child() -> None:
    from jax.sharding import Mesh
    mesh = Mesh(np.asarray(jax.devices()).reshape(MESH_SHAPE),
                ("data", "model"))
    rows = [_bench_path(use_fused, batch, mesh=mesh, reps=MESH_REPS)
            for batch in MESH_BATCHES for use_fused in (True, False)]
    print("MESHROWS:" + json.dumps(rows))


def _run_mesh_section() -> Optional[Dict]:
    n_dev = int(np.prod(MESH_SHAPE))
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={n_dev}"
    env["JAX_PLATFORMS"] = "cpu"
    src = os.path.abspath(os.path.join(os.path.dirname(__file__), "..",
                                       "src"))
    env["PYTHONPATH"] = src + (os.pathsep + env["PYTHONPATH"]
                               if env.get("PYTHONPATH") else "")
    out = subprocess.run(
        [sys.executable, os.path.abspath(__file__), "--mesh-child"],
        env=env, capture_output=True, text=True, timeout=1200)
    lines = [l for l in out.stdout.splitlines() if l.startswith("MESHROWS:")]
    if out.returncode != 0 or not lines:
        print(f"[bench_dispatch] mesh section failed:\n{out.stderr[-2000:]}")
        return None
    rows = json.loads(lines[0][len("MESHROWS:"):])
    f = [r for r in rows if r["path"] == "fused"]
    s = [r for r in rows if r["path"] == "seed"]
    return {
        "devices": n_dev,
        "mesh_shape": list(MESH_SHAPE),
        "rows": rows,
        "summary": {
            "speedup": float(np.mean([r["us_per_flush"] for r in s]) /
                             np.mean([r["us_per_flush"] for r in f])),
            "launches_fused": float(np.mean(
                [r["launches_per_flush"] for r in f])),
            "launches_seed": float(np.mean(
                [r["launches_per_flush"] for r in s])),
        },
    }


def run(skip_mesh: bool = False) -> Dict:
    rows = []
    for batch in BATCHES:
        for use_fused in (True, False):
            rows.append(_bench_path(use_fused, batch))
    small_f = [r for r in rows if r["path"] == "fused" and r["batch"] <= 8]
    small_s = [r for r in rows if r["path"] == "seed" and r["batch"] <= 8]
    speedup = (np.mean([r["us_per_flush"] for r in small_s]) /
               np.mean([r["us_per_flush"] for r in small_f]))
    return {
        "schema": "bench_dispatch/v2",
        "backend": jax.default_backend(),
        "block": list(BLOCK),
        "nblk": NBLK,
        "pools": ["k", "v"],
        "rows": rows,
        "summary": {"speedup_small_batch": float(speedup)},
        "mesh": None if skip_mesh else _run_mesh_section(),
    }


def _print_rows(rows) -> None:
    for r in rows:
        print(f"{r['batch']:>6} {r['path']:>6} "
              f"{r['launches_per_flush']:>9.2f} {r['table_len']:>6} "
              f"{r['us_per_flush']:>10.1f} "
              f"{r['bytes_moved'] / 1e6:>9.1f}")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="BENCH_dispatch.json")
    ap.add_argument("--skip-mesh", action="store_true",
                    help="skip the 8-device subprocess A/B section")
    ap.add_argument("--mesh-child", action="store_true",
                    help=argparse.SUPPRESS)
    args = ap.parse_args()
    if args.mesh_child:
        _mesh_child()
        return
    result = run(skip_mesh=args.skip_mesh)
    with open(args.out, "w") as f:
        json.dump(result, f, indent=2)
    print(f"{'batch':>6} {'path':>6} {'launches':>9} {'table':>6} "
          f"{'us/flush':>10} {'MB moved':>9}")
    _print_rows(result["rows"])
    print(f"\nsmall-batch (<=8) dispatch speedup: "
          f"{result['summary']['speedup_small_batch']:.2f}x")
    if result["mesh"]:
        m = result["mesh"]
        print(f"\nmesh ({m['devices']} host devices, "
              f"{'x'.join(map(str, m['mesh_shape']))}):")
        _print_rows(m["rows"])
        print(f"mesh flush speedup: {m['summary']['speedup']:.2f}x  "
              f"(launches/flush {m['summary']['launches_fused']:.2f} fused "
              f"vs {m['summary']['launches_seed']:.2f} seed)")
    print(f"-> {args.out}")


if __name__ == "__main__":
    main()
