"""Dispatch-path benchmark: fused command-queue flush vs seed per-op fan-out.

Measures, for mixed copy+zero batches over a {"k","v"} pool pair:

* launches per flush (via the kernels/fused_dispatch.py launch hook),
* wall-clock per flushed batch (median of repeated flushes, post-warmup),
* bytes physically moved (identical across paths — the win is dispatch).

Since schema v3 it also A/Bs full SERVING ROUNDS (admission prefill
staging + CoW fork splits + decode) through the real ServingEngine:
``fused_staging`` (staging pools + cross-pool promotion through the
queue — ONE bulk-movement launch per round) vs the seed ``_stage_legacy``
scatter path (one ad-hoc dispatch per pool per admission).  Schema v4
adds the ``ring_staging`` path — staging pools sized as a
``max_admit_pages`` RING through the PoolGroup per-pool block counts —
and tracks ``pool_bytes_resident`` per serve_round row, so the ~2x
serving-memory reduction is recorded alongside launches/round and
wall-clock (greedy tokens are asserted bitwise-identical to the
full-twin path in ``summary.ring_tokens_match``).  Schema v5 adds the
``burst_admission`` serve_round leg: rounds admitting MORE staged pages
than the ring's nominal capacity, single-buffered (early-flush launch)
vs double-buffered (shadow half absorbs the burst at 1.0 launches/round,
the CommandStream/source-hazard redesign headline).  Schema v6 adds the
``fault_recovery`` leg: a reference serve run vs one with an injected
launch failure + donated-admission error, auto-recovered from the ticket
journal and the background checkpoint stream — greedy tokens must stay
bitwise-identical (in admission order) and the serve flush must return
to <= 1 launch/round within 2 rounds.

Schema v7 adds the ``serve_traffic`` section: closed-loop traffic through
the :class:`~repro.launch.scheduler.RequestScheduler` (continuous
batching, per-tenant QoS lanes on dedicated command streams, preemption
by demotion to the spill pools) under Poisson and bursty arrivals — the
gate holds launches/round at <= 1.0 WITH churn and preemption active,
and preempted-then-resumed sequences must produce bitwise-identical
greedy tokens vs an unpreempted run (CPU and the 8-device mesh leg).

Schema v8 adds two legs for the in-memory bitwise opcodes
(OP_AND/OP_OR/OP_NOT, the Ambit triple-row-activation analogue):
``bitwise`` A/Bs mixed memand/memor/memnot flushes through the fused
table vs the seed per-pool fan-out — the gate holds the fused path at
1.0 launch/flush AND asserts the two paths' final pool bytes are
bit-identical — and ``dedup_admit`` drives the duplicated-prompt
serving leg (fig34_multitenant.run_dedup): fingerprint-matched prompt
pages collapse into shared CoW blocks on admission, so peak resident KV
bytes drop while greedy tokens stay bitwise-equal to a dedup-off twin
at <= 1.0 launches/round.

Schema v9 adds the ``autotune`` section: a summary of the committed
per-backend TunedProfile (``configs/tuned/<backend>.json``, written by
``benchmarks/bench_autotune.py``) — the constants the profiler-driven
sweep picked and the measured ``us_per_flush`` win vs the hand-picked
defaults — and all wall-clock loops now time through the shared
``repro.obs`` stopwatch instead of raw ``time.perf_counter()``.

Emits ``BENCH_dispatch.json``:

{
  "schema": "bench_dispatch/v9",
  "backend": "cpu" | "tpu",
  "block": [page, KVH, D], "nblk": int, "pools": ["k", "v"],
  "rows": [{
      "batch": int,            # commands per flush (copies + zeros)
      "path": "fused"|"seed",  # queue+fused launch vs per-op fan-out
      "launches_per_flush": float,
      "table_len": int,        # padded table length (bucket vs max_requests)
      "us_per_flush": float,   # median wall-clock
      "bytes_moved": int       # bytes one flush moves (per-flush, not
                               # cumulative over the measurement loop)
  }],
  "summary": {"speedup_small_batch": float},  # seed/fused us at batch<=8
  "mesh": {                    # multi-device A/B (8 forced host devices,
                               # measured in a subprocess; null if it failed)
      "devices": 8, "mesh_shape": [2, 4],
      "rows": [... same row schema, paths "fused"|"seed" ...],
      "summary": {"speedup": float,          # seed/fused wall-clock
                  "launches_fused": float,   # per flush (the "1" this PR
                  "launches_seed": float}    # buys vs the fan-out)
  },
  "serve_round": {             # full serving rounds through ServingEngine
      "arch": str, "max_seqs": int, "rounds": int, "admit_rounds": int,
      "rows": [{
          "path": "fused_staging"|"ring_staging"|"seed_staging",
          "launches_admit_round": float, # bulk-movement launches in rounds
                                         # that admit (1.0 fused: prefill
                                         # staging rides the round's flush)
          "launches_per_round": float,   # mean over ALL measured rounds
          "us_per_round": float,         # median post-warmup wall-clock
          "stage_promotions": int,       # blocks promoted via the queue
          "pool_bytes_resident": int,    # engine pool bytes (KV + staging)
          "stage_capacity": int          # staging slots (ring vs twin)
      }],
      "summary": {"speedup": float, "launches_fused": float,
                  "launches_seed": float,
                  "staging_memory_reduction": float,  # twin/ring resident
                  "ring_tokens_match": bool},  # greedy tokens bitwise ==
      "burst_admission": {     # admissions/round x pages > ring capacity
          "ring_pages": int, "admits_per_round": int, "rounds": int,
          "rows": [{
              "path": "single_ring"|"double_ring",
              "launches_per_round": float,  # 1.0 double vs >1.0 single
              "us_per_round": float,
              "stage_capacity": int         # ring slots (2x when double)
          }],
          "summary": {"launches_single": float, "launches_double": float,
                      "tokens_match": bool}  # double == single, bitwise
      },
      "fault_recovery": {      # injected failures + in-place recovery
          "rounds": int, "fault_round": int, "readmit_round": int,
          "ckpt_pages": int,   # spill blocks per pool (background ckpt)
          "injections": ["launch_failure", "donation_error"],
          "serve_launches_ref": [int],    # per-round serve-flush launches
          "serve_launches_fault": [int],  # -1 = flush failed + recovered
          "summary": {"tokens_match": bool,      # vs the reference run
                      "rounds_to_recover": int,  # <= 2 gated by smoke
                      "evicted": int,            # admissions re-admitted
                      "max_launches_post_recovery": int,
                      "ckpt_active": bool}  # ckpt stream kept ticking
      },
      "mesh": {"devices": 8, "mesh_shape": [2, 4],    # sharded-batch leg
               "rows": [...], "summary": {...}} | null
  },
  "serve_traffic": {           # RequestScheduler under closed-loop load
      "rounds": int, "tenants": {"gold": 2, "silver": 1, "free": 0},
      "legs": {"poisson"|"bursty": {
          "max_launches_per_round": float,  # gate: <= 1.0 under churn
          "mean_launches_per_round": float,
          "submitted": int, "completed": int,
          "preempted_requests": int,        # demoted at least once
          "per_tenant": {tenant: {"submitted", "completed",
              "goodput_tok_s", "p50_token_latency_rounds",
              "p99_token_latency_rounds", "p50_ttft_rounds",
              "preemptions"}}}},
      "preempt_parity": {      # demote -> resume vs unpreempted run
          "tokens_match": bool,             # bitwise greedy parity
          "preempted": int,                 # victims actually demoted
          "max_launches_per_round": float},
      "mesh": {"devices": 8, "mesh_shape": [2, 4],
               "preempt_parity": {...}} | null
  },
  "bitwise": {                 # OP_AND/OP_OR/OP_NOT dispatch A/B
      "rows": [{
          "batch": int,            # bitwise rows per flush (AND+OR+NOT mix)
          "path": "fused"|"seed",
          "launches_per_flush": float,  # 1.0 fused vs per-opcode-chunk
          "us_per_flush": float,
          "bytes_bitwise": int     # dst bytes one flush computes
      }],
      "summary": {"speedup": float, "launches_fused": float,
                  "launches_seed": float,
                  "bitwise_match": bool}  # final pool bytes identical
  },
  "dedup_admit": {             # duplicated-prompt admission dedup leg
      "tenants": int, "rounds": int,
      "kv_bytes_live_on": int,   # peak resident KV bytes, dedup on
      "kv_bytes_live_off": int,  # ... and the dedup-off twin
      "resident_reduction": float,  # 1 - on/off (> 0 gated by smoke)
      "dedup_hits": int, "pages_shared": int, "bytes_saved": int,
      "tokens_match": bool,      # greedy tokens bitwise == dedup-off
      "max_launches_per_round": float   # gate: <= 1.0
  },
  "autotune": {                # committed TunedProfile summary (v9)
      "profile": {...} | null, # TunedProfile.to_dict() minus sweep rows
      "path": str,             # configs/tuned/<backend>.json
      "tuned_vs_default_us_ratio": float  # < 1.0 = tuned wins
  }
}

CLI: PYTHONPATH=src python benchmarks/bench_dispatch.py [--out PATH]
                         [--skip-mesh] [--skip-serve] [--serve-smoke]
                         [--traffic-smoke]
"""
from __future__ import annotations

import argparse
import json
import os
import subprocess
import sys
from typing import Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import RowCloneEngine, SubarrayAllocator
from repro.kernels import fused_dispatch as fd
from repro.obs import metrics as obs_metrics

BLOCK = (16, 2, 64)          # page x KVH x head_dim
NBLK = 1024
NSLABS = 4
BATCHES = (2, 4, 8, 32, 128)
REPS = 30
MESH_SHAPE = (2, 4)          # 8 forced host devices in the subprocess
MESH_BATCHES = (8, 32)
MESH_REPS = 10


def _mk_engine(use_fused: bool, mesh=None) -> RowCloneEngine:
    alloc = SubarrayAllocator(NBLK, NSLABS, reserved_zero_per_slab=1)
    key = jax.random.key(0)
    pools = {
        "k": jax.random.normal(key, (NBLK,) + BLOCK, jnp.float32),
        "v": jax.random.normal(jax.random.key(1), (NBLK,) + BLOCK,
                               jnp.float32),
    }
    # max_requests=256 is the seed default the fan-out path pads to
    return RowCloneEngine(pools, alloc, mesh=mesh, max_requests=256,
                          use_fused=use_fused)


def _flush_once(eng: RowCloneEngine, batch: int, round_i: int) -> None:
    """One mixed flush: ~3/4 copies (FPM+PSM mix), ~1/4 zero-inits.
    Source/dest ids rotate per round so jit caches stay warm but data
    differs."""
    n_zero = max(batch // 4, 1)
    n_copy = batch - n_zero
    base = (round_i * batch) % (NBLK // 4)
    srcs = [1 + (base + i) % (NBLK // 4) for i in range(n_copy)]
    dsts = [NBLK // 2 + (base + i) % (NBLK // 4) for i in range(n_copy)]
    zeros = [3 * NBLK // 4 + (base + i) % (NBLK // 8) for i in range(n_zero)]
    eng.alloc.mark_written(srcs)
    with eng.batch():
        eng.memcopy(list(zip(srcs, dsts)))
        eng.materialize_zeros(zeros)


def _bench_path(use_fused: bool, batch: int, mesh=None,
                reps: int = REPS) -> Dict:
    eng = _mk_engine(use_fused, mesh=mesh)
    events: List = []
    hook = lambda n, p, mech: events.append((n, p, mech))
    fd.add_launch_hook(hook)
    try:
        # warmup (compile) flushes
        for r in range(3):
            _flush_once(eng, batch, r)
        events.clear()
        eng.stats = type(eng.stats)()   # per-flush byte accounting below
        times = []
        for r in range(reps):
            with obs_metrics.Stopwatch() as sw:
                _flush_once(eng, batch, 100 + r)
                jax.block_until_ready(list(eng.pools.values()))
            times.append(sw.s)
    finally:
        fd.remove_launch_hook(hook)
    bytes_moved = eng.stats.bytes_fpm + eng.stats.bytes_psm + \
        eng.stats.bytes_baseline
    bytes_moved += eng.stats.zero_materialized * eng._block_bytes()
    bytes_moved //= reps
    return {
        "batch": batch,
        "path": "fused" if use_fused else "seed",
        "launches_per_flush": len(events) / reps,
        "table_len": max((e[0] for e in events), default=0),
        "us_per_flush": float(np.median(times) * 1e6),
        "bytes_moved": int(bytes_moved),
    }


# ---------------------------------------------------------------------------
# bitwise A/B — in-memory OP_AND/OP_OR/OP_NOT rows through the same flush
# ---------------------------------------------------------------------------

BITWISE_BATCHES = (8, 32)


def _flush_bitwise(eng: RowCloneEngine, batch: int, round_i: int) -> None:
    """One mixed bitwise flush: ~1/3 each AND/OR/NOT over disjoint id
    ranges (no RAW/WAW, so the fused path drains as exactly one launch).
    Ids rotate per round so jit caches stay warm but data differs."""
    third = max(batch // 3, 1)
    span = NBLK // 8
    base = (round_i * batch) % span
    a = [1 + (base + i) % span for i in range(third)]
    b = [NBLK // 4 + (base + i) % span for i in range(third)]
    d = [NBLK // 2 + (base + i) % span for i in range(third)]
    eng.alloc.mark_written(a + b)
    with eng.batch():
        eng.memand(list(zip(a, b, d)))
        eng.memor(list(zip(b, a, [x + span for x in d])))
        eng.memnot(list(zip(a, [x + 2 * span for x in d])))


def _bench_bitwise_path(use_fused: bool, batch: int, reps: int = REPS):
    """Measure one bitwise path; returns (engine, row) so the caller can
    compare final pool bytes across paths."""
    eng = _mk_engine(use_fused)
    events: List = []
    hook = lambda n, p, mech: events.append((n, p, mech))
    fd.add_launch_hook(hook)
    try:
        for r in range(3):
            _flush_bitwise(eng, batch, r)
        events.clear()
        eng.stats = type(eng.stats)()
        times = []
        for r in range(reps):
            with obs_metrics.Stopwatch() as sw:
                _flush_bitwise(eng, batch, 100 + r)
                jax.block_until_ready(list(eng.pools.values()))
            times.append(sw.s)
    finally:
        fd.remove_launch_hook(hook)
    return eng, {
        "batch": batch,
        "path": "fused" if use_fused else "seed",
        "launches_per_flush": len(events) / reps,
        "us_per_flush": float(np.median(times) * 1e6),
        "bytes_bitwise": int(eng.stats.bytes_bitwise // reps),
    }


def _run_bitwise_section() -> Dict:
    """A/B the bitwise opcodes fused vs seed and assert both paths left
    bit-identical pool contents (compared through uint views — float
    equality would miss NaN-pattern divergence)."""
    rows = []
    match = True
    for batch in BITWISE_BATCHES:
        engs = {}
        for use_fused in (True, False):
            eng, row = _bench_bitwise_path(use_fused, batch)
            engs[row["path"]] = eng
            rows.append(row)
        for name in engs["fused"].pools:
            fa = np.asarray(engs["fused"].pools[name]).view(np.uint32)
            sa = np.asarray(engs["seed"].pools[name]).view(np.uint32)
            if not np.array_equal(fa, sa):
                match = False
    f = [r for r in rows if r["path"] == "fused"]
    s = [r for r in rows if r["path"] == "seed"]
    return {
        "rows": rows,
        "summary": {
            "speedup": float(np.mean([r["us_per_flush"] for r in s]) /
                             np.mean([r["us_per_flush"] for r in f])),
            "launches_fused": float(np.mean(
                [r["launches_per_flush"] for r in f])),
            "launches_seed": float(np.mean(
                [r["launches_per_flush"] for r in s])),
            "bitwise_match": match,
        },
    }


def _print_bitwise(section: Dict) -> None:
    for r in section["rows"]:
        print(f"  bitwise {r['batch']:>4} {r['path']:>6} "
              f"{r['launches_per_flush']:>6.2f} launches/flush "
              f"{r['us_per_flush']:>10.1f} us/flush "
              f"{r['bytes_bitwise'] / 1e6:>6.1f} MB computed")
    s = section["summary"]
    print(f"  bitwise flush speedup {s['speedup']:.2f}x  (launches "
          f"{s['launches_fused']:.2f} fused vs {s['launches_seed']:.2f} "
          f"seed, pools bit-identical: {s['bitwise_match']})")


# ---------------------------------------------------------------------------
# serve_round A/B — full serving rounds through the real ServingEngine
# ---------------------------------------------------------------------------

SERVE_ARCH = "llama3.2-3b"
SERVE_ROUNDS = 8
SERVE_ADMIT_ROUNDS = 4
SERVE_WARMUP = 2             # rounds excluded from the median (compiles)
SERVE_MAX_BLOCKS = 16        # KV nblk = 8 * 16 = 128 blocks
SERVE_RING_PAGES = 8         # staging-ring slots (vs the 128-slot twin)

#: (row label, fused_staging, max_admit_pages) serve_round legs — 0 is
#: ServingEngine.FULL_TWIN (max_admit_pages defaults to the policy-derived
#: ring since v5, so the twin baseline opts out explicitly)
SERVE_PATHS = (("fused_staging", True, 0),
               ("ring_staging", True, SERVE_RING_PAGES),
               ("seed_staging", False, 0))

#: burst_admission leg: rounds park BURST_ADMITS x 1 page into a
#: BURST_RING_PAGES-slot ring — past nominal capacity, so the
#: single-buffered ring early-flushes while the double-buffered shadow
#: half keeps the round at one launch
BURST_RING_PAGES = 2
BURST_ADMITS = 3
BURST_ROUNDS = 4

#: fault_recovery leg: a reference serve run vs one with an injected
#: launch failure (FAULT_ROUND) and a donated-admission error
#: (FAULT_READMIT_ROUND), auto-recovered in place with a background
#: checkpoint stream of FAULT_CKPT_PAGES spill blocks per pool
FAULT_ROUNDS = 6
FAULT_ROUND = 1
FAULT_READMIT_ROUND = 3
FAULT_CKPT_PAGES = 8


def _bench_serve_path(path: str, fused_staging: bool,
                      max_admit_pages: Optional[int], mesh=None) -> Dict:
    """One serving-round A/B leg: admit a request per round for the first
    ``SERVE_ADMIT_ROUNDS`` rounds, fork once, decode every round.  Reports
    bulk-movement launches/round (hook), median wall-clock/round, and the
    engine's resident pool bytes (the staging-ring headline).  The row
    carries the greedy token streams under a private ``_tokens`` key so
    ``_serve_summary`` can assert ring-vs-twin bitwise parity (stripped
    before the row is written)."""
    from repro.configs import get_config
    from repro.launch.serve import ServingEngine
    from repro.models import build_model, split_params
    cfg = get_config(SERVE_ARCH).reduced()
    model = build_model(cfg)
    params, _ = split_params(model.init_params(jax.random.key(0)))
    eng = ServingEngine(cfg, params, mesh=mesh, max_seqs=8,
                        max_blocks_per_seq=SERVE_MAX_BLOCKS,
                        fused_staging=fused_staging,
                        max_admit_pages=max_admit_pages)
    rng = np.random.default_rng(0)
    events: List = []
    hook = lambda n, p, mech: events.append(mech)
    fd.add_launch_hook(hook)
    launches, times, admitted = [], [], []
    sids: List[int] = []
    try:
        for r in range(SERVE_ROUNDS):
            n0 = len(events)
            with obs_metrics.Stopwatch() as sw:
                if r < SERVE_ADMIT_ROUNDS:
                    sids.append(eng.add_request(rng.integers(
                        2, cfg.vocab_size, size=24).astype(np.int32)))
                if r == SERVE_ADMIT_ROUNDS:
                    eng.fork(sids[0], 1)     # CoW splits on later appends
                eng.decode_round()
                jax.block_until_ready([eng.engine.pools["k"],
                                       eng.engine.pools["v"]])
            times.append(sw.s)
            launches.append(len(events) - n0)
            admitted.append(r < SERVE_ADMIT_ROUNDS)
    finally:
        fd.remove_launch_hook(hook)
    meas = slice(SERVE_WARMUP, None)
    admit_launches = [l for l, a in zip(launches[meas], admitted[meas]) if a]
    return {
        "path": path,
        # admission rounds exercise the staging path: prefill + promotion
        # + decode.  1.0 fused (ONE launch covers it) vs 2+ for the seed's
        # per-pool ad-hoc scatters.
        "launches_admit_round": float(np.mean(admit_launches)),
        "launches_per_round": float(np.mean(launches[meas])),
        "us_per_round": float(np.median(times[meas]) * 1e6),
        "stage_promotions": int(eng.engine.stats.stage_promotions),
        "pool_bytes_resident": int(eng.engine.pool_bytes_resident()),
        "stage_capacity": int(eng.engine.stage_capacity),
        "_tokens": {str(s): t for s, t in eng.tokens.items()},
    }


def _bench_burst_path(path: str, double_buffer: bool) -> Dict:
    """One burst-admission leg (CPU): every round admits ``BURST_ADMITS``
    one-page prompts into a ``BURST_RING_PAGES``-slot staging ring, then
    decodes.  The single-buffered ring must early-flush mid-round (extra
    launch); the double-buffered ring's shadow half keeps the round at
    one launch.  Rows carry ``_tokens`` for the cross-path parity check
    (stripped by ``_burst_summary``).  (The mesh burst leg lives in the
    test suite — tests/test_serving_staging.py MESH_SERVE_CHILD.)"""
    from repro.configs import get_config
    from repro.launch.serve import ServingEngine
    from repro.models import build_model, split_params
    cfg = get_config(SERVE_ARCH).reduced()
    model = build_model(cfg)
    params, _ = split_params(model.init_params(jax.random.key(0)))
    eng = ServingEngine(cfg, params,
                        max_seqs=BURST_ADMITS * BURST_ROUNDS,
                        max_blocks_per_seq=SERVE_MAX_BLOCKS,
                        max_admit_pages=BURST_RING_PAGES,
                        double_buffer=double_buffer)
    rng = np.random.default_rng(0)
    events: List = []
    hook = lambda n, p, mech: events.append(mech)
    fd.add_launch_hook(hook)
    launches, times = [], []
    try:
        for r in range(BURST_ROUNDS):
            n0 = len(events)
            with obs_metrics.Stopwatch() as sw:
                for _ in range(BURST_ADMITS):
                    eng.add_request(rng.integers(
                        2, cfg.vocab_size, size=24).astype(np.int32))
                eng.decode_round()
                jax.block_until_ready([eng.engine.pools["k"],
                                       eng.engine.pools["v"]])
            times.append(sw.s)
            launches.append(len(events) - n0)
    finally:
        fd.remove_launch_hook(hook)
    meas = slice(SERVE_WARMUP, None)
    return {
        "path": path,
        "launches_per_round": float(np.mean(launches[meas])),
        "us_per_round": float(np.median(times[meas]) * 1e6),
        "stage_capacity": int(eng.engine.stage_capacity),
        "_tokens": {str(s): t for s, t in eng.tokens.items()},
    }


def _burst_summary(rows: List[Dict]) -> Dict:
    """Cross-path burst summary; strips ``_tokens`` in place."""
    s = next(r for r in rows if r["path"] == "single_ring")
    d = next(r for r in rows if r["path"] == "double_ring")
    tokens = {r["path"]: r.pop("_tokens") for r in rows}
    return {
        "launches_single": s["launches_per_round"],
        "launches_double": d["launches_per_round"],
        "tokens_match": tokens["single_ring"] == tokens["double_ring"],
    }


def _run_burst_section() -> Dict:
    rows = [_bench_burst_path("single_ring", False),
            _bench_burst_path("double_ring", True)]
    return {
        "ring_pages": BURST_RING_PAGES,
        "admits_per_round": BURST_ADMITS,
        "rounds": BURST_ROUNDS,
        "rows": rows,
        "summary": _burst_summary(rows),
    }


def _drive_fault_rounds(eng, prompts, plan=None):
    """Drive FAULT_ROUNDS serving rounds, injecting the plan's failures
    at FAULT_ROUND (launch failure on the round's next drain) and
    FAULT_READMIT_ROUND (donation error on the third admission, then
    re-admission of the evicted prompt).  Returns (tokens in admission
    order, per-round serve-flush launches with -1 marking a round whose
    flush failed and recovered)."""
    from repro.runtime.fault import InjectedFault
    order, serve_launches = [], []
    for p in prompts[:2]:
        order.append(eng.add_request(p))
    for r in range(FAULT_ROUNDS):
        if plan is not None and r == FAULT_ROUND:
            plan.launch_failures += (eng.engine.next_flush_index,)
        if r == FAULT_READMIT_ROUND:
            if plan is not None:
                plan.donation_errors += (eng._admission_ordinal,)
                try:
                    eng.add_request(prompts[2])
                except InjectedFault:
                    pass        # evicted; re-admitted below
            order.append(eng.add_request(prompts[2]))
        eng.decode_round()
        t = eng.last_ticket     # None = the round's flush failed and
        # recover() ran (recovery resets the ticket); its launches are
        # the round's serve-stream accounting otherwise
        serve_launches.append(int(t.launches) if t is not None else -1)
    return ([eng.tokens[s] for s in order if s in eng.tokens],
            serve_launches)


def _run_fault_section() -> Dict:
    """fault_recovery serve leg (CPU): greedy tokens under injected
    failures + auto-recovery must match the failure-free run bitwise (in
    admission order — the evicted admission re-admits under a new sid),
    and the serve flush must return to <= 1 launch/round within
    ``rounds_to_recover`` rounds of each fault.  Both engines run the
    background checkpoint stream so the rows stay comparable."""
    import tempfile

    from repro.configs import get_config
    from repro.launch.serve import ServingEngine
    from repro.models import build_model, split_params
    from repro.runtime.fault import FaultPlan
    cfg = get_config(SERVE_ARCH).reduced()
    model = build_model(cfg)
    params, _ = split_params(model.init_params(jax.random.key(0)))
    rng = np.random.default_rng(0)
    prompts = [rng.integers(2, cfg.vocab_size, size=24).astype(np.int32)
               for _ in range(3)]

    def mk(plan):
        return ServingEngine(
            cfg, params, max_seqs=8, max_blocks_per_seq=SERVE_MAX_BLOCKS,
            fault_plan=plan, auto_recover=plan is not None,
            ckpt_pages=FAULT_CKPT_PAGES,
            ckpt_dir=tempfile.mkdtemp(prefix="bench_fault_ckpt_"))

    ref_tokens, ref_launches = _drive_fault_rounds(mk(None), prompts)
    plan = FaultPlan()
    eng = mk(plan)
    tokens, launches = _drive_fault_rounds(eng, prompts, plan)
    # rounds after the fault round until the serve flush succeeds again
    # at <= 1 launch (0 = the fault round itself still flushed cleanly)
    rounds_to_recover = next(
        (i for i, l in enumerate(launches[FAULT_ROUND:])
         if 0 <= l <= 1), len(launches))
    return {
        "rounds": FAULT_ROUNDS,
        "fault_round": FAULT_ROUND,
        "readmit_round": FAULT_READMIT_ROUND,
        "ckpt_pages": FAULT_CKPT_PAGES,
        "injections": [k for k, _ in plan.fired],
        "serve_launches_ref": ref_launches,
        "serve_launches_fault": launches,
        "summary": {
            "tokens_match": tokens == ref_tokens,
            "rounds_to_recover": int(rounds_to_recover),
            "evicted": len(eng.evicted_sids),
            "max_launches_post_recovery": int(
                max(launches[FAULT_ROUND + 1:])),
            "ckpt_active": bool(eng.pool_ckpt._cursor > 0
                                or eng.pool_ckpt.passes > 0),
        },
    }


def _serve_summary(rows: List[Dict]) -> Dict:
    """Cross-path summary; strips the private ``_tokens`` keys in place."""
    f = next(r for r in rows if r["path"] == "fused_staging")
    g = next(r for r in rows if r["path"] == "ring_staging")
    s = next(r for r in rows if r["path"] == "seed_staging")
    tokens = {r["path"]: r.pop("_tokens") for r in rows}
    return {
        "speedup": float(s["us_per_round"] / f["us_per_round"]),
        "launches_fused": f["launches_admit_round"],
        "launches_seed": s["launches_admit_round"],
        # the v4 headline: ring staging vs full twin, same tokens
        "staging_memory_reduction": float(f["pool_bytes_resident"]
                                          / g["pool_bytes_resident"]),
        "ring_tokens_match": tokens["ring_staging"]
        == tokens["fused_staging"],
    }


def _serve_child() -> None:
    from jax.sharding import Mesh
    mesh = Mesh(np.asarray(jax.devices()).reshape(MESH_SHAPE),
                ("data", "model"))
    rows = [_bench_serve_path(*p, mesh=mesh) for p in SERVE_PATHS]
    summary = _serve_summary(rows)          # strips _tokens (unserializable
    print("SERVEROWS:" + json.dumps({"rows": rows,      # sets aside)
                                     "summary": summary}))


def _run_serve_section(skip_mesh: bool) -> Optional[Dict]:
    rows = [_bench_serve_path(*p) for p in SERVE_PATHS]
    section = {
        "arch": f"{SERVE_ARCH} (reduced)",
        "max_seqs": 8,
        "rounds": SERVE_ROUNDS,
        "admit_rounds": SERVE_ADMIT_ROUNDS,
        "rows": rows,
        "summary": _serve_summary(rows),
        "burst_admission": _run_burst_section(),
        "fault_recovery": _run_fault_section(),
        "mesh": None,
    }
    if skip_mesh:
        return section
    out = _run_child("--serve-mesh-child")
    lines = [] if out is None or out.returncode != 0 else [
        l for l in out.stdout.splitlines() if l.startswith("SERVEROWS:")]
    if not lines:
        err = "timeout" if out is None else out.stderr[-2000:]
        print(f"[bench_dispatch] serve mesh leg failed:\n{err}")
        return section
    payload = json.loads(lines[0][len("SERVEROWS:"):])
    section["mesh"] = {
        "devices": int(np.prod(MESH_SHAPE)),
        "mesh_shape": list(MESH_SHAPE),
        "rows": payload["rows"],
        "summary": payload["summary"],
    }
    return section


# ---------------------------------------------------------------------------
# serve_traffic — RequestScheduler under closed-loop Poisson/bursty load
# ---------------------------------------------------------------------------

TRAFFIC_ROUNDS = 32
TRAFFIC_PATTERNS = ("poisson", "bursty")
TRAFFIC_PARITY_TOKENS = 8


def _traffic_driver():
    """Import the traffic driver from the sibling benchmark module."""
    sys.path.insert(0, os.path.dirname(os.path.abspath(__file__)))
    try:
        import fig34_multitenant
    finally:
        sys.path.pop(0)
    return fig34_multitenant


DEDUP_ROUNDS = 4
DEDUP_TENANTS = 4


def _run_dedup_section() -> Dict:
    """Duplicated-prompt admission leg — fig34_multitenant.run_dedup
    (dedup-on vs dedup-off twin at the same seed)."""
    mt = _traffic_driver()
    return mt.run_dedup(rounds=DEDUP_ROUNDS, seed=0, arch=SERVE_ARCH,
                        tenants=DEDUP_TENANTS)


def _print_dedup(row: Dict) -> None:
    print(f"  dedup_admit ({row['tenants']} tenants, {row['rounds']} "
          f"rounds): resident KV {row['kv_bytes_live_on'] / 1e6:.1f} vs "
          f"{row['kv_bytes_live_off'] / 1e6:.1f} MB "
          f"({row['resident_reduction']:.0%} saved), "
          f"{row['pages_shared']} pages shared / {row['dedup_hits']} "
          f"admission hits, tokens match: {row['tokens_match']}, max "
          f"{row['max_launches_per_round']:.1f} launches/round")


def _traffic_parity(mesh=None) -> Dict:
    """Preempt→demote→resume greedy-token parity vs an unpreempted run.

    A deliberately tiny engine (2 batch slots) runs two free-tenant
    requests; a gold request arrives mid-flight and must preempt one.
    Every request's token stream must match, bitwise, the same prompts
    decoded on a roomy engine that never preempts — the demoted bytes
    parked in the spill slots ARE the KV pages.  Also reports the worst
    round's launch count (preemption must not cost extra launches)."""
    from repro.configs import get_config
    from repro.launch.scheduler import RequestScheduler, TenantSpec
    from repro.launch.serve import ServingEngine
    from repro.models import build_model, split_params
    cfg = get_config(SERVE_ARCH).reduced()
    model = build_model(cfg)
    params, _ = split_params(model.init_params(jax.random.key(0)))
    rng = np.random.default_rng(0)
    prompts = [rng.integers(2, cfg.vocab_size, size=16).astype(np.int32)
               for _ in range(3)]
    tenants = [TenantSpec("gold", 2), TenantSpec("free", 0)]

    def drive(eng):
        sched = RequestScheduler(eng, tenants)
        rids = [sched.submit("free", prompts[0],
                             max_new_tokens=TRAFFIC_PARITY_TOKENS),
                sched.submit("free", prompts[1],
                             max_new_tokens=TRAFFIC_PARITY_TOKENS)]
        sched.step()
        sched.step()
        rids.append(sched.submit("gold", prompts[2],
                                 max_new_tokens=TRAFFIC_PARITY_TOKENS))
        sched.drain(max_rounds=120)
        return ([sched.requests[r].tokens_out for r in rids],
                sum(q.preemptions for q in sched.requests.values()),
                max(r.launches for r in sched.reports))

    roomy = ServingEngine(cfg, params, mesh=mesh, max_seqs=8,
                          max_blocks_per_seq=8, max_admit_pages=8,
                          double_buffer=True)
    ref_tokens, ref_preempted, _ = drive(roomy)
    tight = ServingEngine(cfg, params, mesh=mesh, max_seqs=2,
                          max_blocks_per_seq=8, num_slabs=2,
                          max_admit_pages=8, double_buffer=True,
                          spill_pages=8)
    tokens, preempted, max_launches = drive(tight)
    return {
        "tokens_match": tokens == ref_tokens,
        "preempted": int(preempted),
        "ref_preempted": int(ref_preempted),   # must be 0 (roomy engine)
        "max_launches_per_round": float(max_launches),
    }


def _traffic_mesh_child() -> None:
    from jax.sharding import Mesh
    mesh = Mesh(np.asarray(jax.devices()).reshape(MESH_SHAPE),
                ("data", "model"))
    print("TRAFFICPARITY:" + json.dumps(_traffic_parity(mesh=mesh)))


def _run_traffic_section(skip_mesh: bool) -> Dict:
    mt = _traffic_driver()
    legs = {}
    for pattern in TRAFFIC_PATTERNS:
        res = mt.run_traffic(pattern, rounds=TRAFFIC_ROUNDS, seed=0)
        legs[pattern] = {
            "max_launches_per_round": res.max_launches_per_round(),
            "mean_launches_per_round": float(np.mean(res.launches)),
            "submitted": res.submitted,
            "completed": res.completed,
            "preempted_requests": len(res.preempted_rids),
            "per_tenant": res.per_tenant,
        }
    section = {
        "rounds": TRAFFIC_ROUNDS,
        "tenants": {t.name: t.priority for t in mt.TENANTS},
        "legs": legs,
        "preempt_parity": _traffic_parity(),
        "mesh": None,
    }
    if skip_mesh:
        return section
    out = _run_child("--traffic-mesh-child")
    lines = [] if out is None or out.returncode != 0 else [
        l for l in out.stdout.splitlines()
        if l.startswith("TRAFFICPARITY:")]
    if not lines:
        err = "timeout" if out is None else out.stderr[-2000:]
        print(f"[bench_dispatch] traffic mesh leg failed:\n{err}")
        return section
    section["mesh"] = {
        "devices": int(np.prod(MESH_SHAPE)),
        "mesh_shape": list(MESH_SHAPE),
        "preempt_parity": json.loads(lines[0][len("TRAFFICPARITY:"):]),
    }
    return section


def _print_traffic(section: Dict) -> None:
    for pattern, leg in section["legs"].items():
        print(f"  {pattern:>8}: {leg['submitted']} arrived, "
              f"{leg['completed']} completed, "
              f"{leg['preempted_requests']} preempted, max "
              f"{leg['max_launches_per_round']:.1f} launches/round")
        for t, m in leg["per_tenant"].items():
            print(f"    {t:>6}: p50/p99 tok-lat "
                  f"{m['p50_token_latency_rounds']:.1f}/"
                  f"{m['p99_token_latency_rounds']:.1f} rounds  "
                  f"goodput {m['goodput_tok_s']:.1f} tok/s  "
                  f"preemptions {m['preemptions']}")
    p = section["preempt_parity"]
    print(f"  preempt parity: tokens match {p['tokens_match']} "
          f"({p['preempted']} demotions, max "
          f"{p['max_launches_per_round']:.1f} launches/round)")
    if section.get("mesh"):
        mp = section["mesh"]["preempt_parity"]
        print(f"  preempt parity (mesh, {section['mesh']['devices']} "
              f"devices): tokens match {mp['tokens_match']} "
              f"({mp['preempted']} demotions)")


def traffic_smoke(baseline_path: str = "BENCH_dispatch.json") -> int:
    """CI gate (``make bench-traffic``): FAIL (exit 1) if

    * any traffic leg's launches/round exceeds 1.0 under churn (the
      continuous-batching + preemption traffic must still drain each
      round as at most one fused launch),
    * no preemption actually happened (the leg stopped exercising the
      demotion path),
    * preempted-then-resumed sequences' greedy tokens diverge from the
      unpreempted run (CPU leg; the mesh leg runs under ``--skip-mesh``-
      less full benchmarks), or
    * a tenant's p99 token latency regresses > 1.5x against the
      committed ``BENCH_dispatch.json`` baseline (arrivals and the
      scheduler are deterministic at a fixed seed, so this is a real
      regression, not noise; skipped when no baseline has the section).
    """
    section = _run_traffic_section(skip_mesh=True)
    _print_traffic(section)
    ok = True
    for pattern, leg in section["legs"].items():
        if leg["max_launches_per_round"] > 1.0:
            print(f"FAIL: {pattern} leg hit "
                  f"{leg['max_launches_per_round']:.2f} launches/round "
                  "> 1.0 (churn or preemption now forces extra drains)")
            ok = False
        if leg["preempted_requests"] == 0:
            print(f"FAIL: {pattern} leg preempted nothing — the traffic "
                  "no longer exercises demotion")
            ok = False
    parity = section["preempt_parity"]
    if not parity["tokens_match"]:
        print("FAIL: preempted-then-resumed sequences' greedy tokens "
              "diverged from the unpreempted run")
        ok = False
    if parity["preempted"] == 0:
        print("FAIL: parity scenario demoted nothing")
        ok = False
    if parity["max_launches_per_round"] > 1.0:
        print(f"FAIL: preemption cost extra launches "
              f"({parity['max_launches_per_round']:.2f}/round > 1.0)")
        ok = False
    baseline = None
    if os.path.exists(baseline_path):
        try:
            with open(baseline_path) as f:
                baseline = json.load(f).get("serve_traffic")
        except (OSError, ValueError):
            baseline = None
    if baseline:
        for pattern, leg in section["legs"].items():
            base_leg = baseline.get("legs", {}).get(pattern)
            if not base_leg:
                continue
            for t, m in leg["per_tenant"].items():
                bm = base_leg["per_tenant"].get(t)
                if not bm:
                    continue
                base_p99 = bm["p99_token_latency_rounds"]
                if base_p99 > 0 and \
                        m["p99_token_latency_rounds"] > 1.5 * base_p99:
                    print(f"FAIL: {pattern}/{t} p99 token latency "
                          f"{m['p99_token_latency_rounds']:.1f} rounds "
                          f"> 1.5x baseline {base_p99:.1f}")
                    ok = False
    if ok:
        print("bench-traffic smoke OK: continuous batching + preemption "
              "hold 1.0 launches/round with bitwise resume parity")
    return 0 if ok else 1


# ---------------------------------------------------------------------------
# mesh A/B — runs in a subprocess with 8 forced host devices (jax locks the
# device count at first init, so the parent process can't host it)
# ---------------------------------------------------------------------------

def _mesh_child() -> None:
    from jax.sharding import Mesh
    mesh = Mesh(np.asarray(jax.devices()).reshape(MESH_SHAPE),
                ("data", "model"))
    rows = [_bench_path(use_fused, batch, mesh=mesh, reps=MESH_REPS)
            for batch in MESH_BATCHES for use_fused in (True, False)]
    print("MESHROWS:" + json.dumps(rows))


def _run_child(flag: str):
    """Run this file in a fresh interpreter with 8 forced host devices."""
    n_dev = int(np.prod(MESH_SHAPE))
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={n_dev}"
    env["JAX_PLATFORMS"] = "cpu"
    src = os.path.abspath(os.path.join(os.path.dirname(__file__), "..",
                                       "src"))
    env["PYTHONPATH"] = src + (os.pathsep + env["PYTHONPATH"]
                               if env.get("PYTHONPATH") else "")
    try:
        return subprocess.run(
            [sys.executable, os.path.abspath(__file__), flag],
            env=env, capture_output=True, text=True, timeout=1200)
    except subprocess.TimeoutExpired:
        return None


def _run_mesh_section() -> Optional[Dict]:
    out = _run_child("--mesh-child")
    lines = [] if out is None else [
        l for l in out.stdout.splitlines() if l.startswith("MESHROWS:")]
    if out is None or out.returncode != 0 or not lines:
        err = "timeout" if out is None else out.stderr[-2000:]
        print(f"[bench_dispatch] mesh section failed:\n{err}")
        return None
    rows = json.loads(lines[0][len("MESHROWS:"):])
    f = [r for r in rows if r["path"] == "fused"]
    s = [r for r in rows if r["path"] == "seed"]
    return {
        "devices": int(np.prod(MESH_SHAPE)),
        "mesh_shape": list(MESH_SHAPE),
        "rows": rows,
        "summary": {
            "speedup": float(np.mean([r["us_per_flush"] for r in s]) /
                             np.mean([r["us_per_flush"] for r in f])),
            "launches_fused": float(np.mean(
                [r["launches_per_flush"] for r in f])),
            "launches_seed": float(np.mean(
                [r["launches_per_flush"] for r in s])),
        },
    }


def _autotune_section() -> Dict:
    """Summarize the committed TunedProfile for this backend (schema v9):
    which constants the autotuner picked and the measured win vs the
    hand-picked defaults.  ``profile`` is null when nothing is committed
    (run ``make bench-autotune`` to produce one)."""
    from repro.obs.autotune import load_profile, profile_path
    prof = load_profile()
    path = str(profile_path())
    repo_root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    if path.startswith(repo_root + os.sep):        # keep committed JSON
        path = os.path.relpath(path, repo_root)    # machine-independent
    if prof is None:
        return {"profile": None, "path": path}
    ratio = (prof.us_per_flush / prof.baseline_us_per_flush
             if prof.baseline_us_per_flush else None)
    out = prof.to_dict()
    out.pop("swept", None)           # full sweep rows live in the profile
    return {"profile": out, "path": path,
            "tuned_vs_default_us_ratio": ratio}


def run(skip_mesh: bool = False, skip_serve: bool = False) -> Dict:
    """Full benchmark: single-device dispatch A/B, the mesh leg, the
    serve_round/serve_traffic sections, the v8 bitwise/dedup legs, and
    the v9 autotune summary.  Returns the schema-v9 result dict."""
    rows = []
    for batch in BATCHES:
        for use_fused in (True, False):
            rows.append(_bench_path(use_fused, batch))
    small_f = [r for r in rows if r["path"] == "fused" and r["batch"] <= 8]
    small_s = [r for r in rows if r["path"] == "seed" and r["batch"] <= 8]
    speedup = (np.mean([r["us_per_flush"] for r in small_s]) /
               np.mean([r["us_per_flush"] for r in small_f]))
    return {
        "schema": "bench_dispatch/v9",
        "backend": jax.default_backend(),
        "block": list(BLOCK),
        "nblk": NBLK,
        "pools": ["k", "v"],
        "rows": rows,
        "summary": {"speedup_small_batch": float(speedup)},
        "mesh": None if skip_mesh else _run_mesh_section(),
        "serve_round": None if skip_serve else _run_serve_section(skip_mesh),
        "serve_traffic": None if skip_serve
        else _run_traffic_section(skip_mesh),
        "bitwise": _run_bitwise_section(),
        "dedup_admit": None if skip_serve else _run_dedup_section(),
        "autotune": _autotune_section(),
    }


def _print_rows(rows) -> None:
    for r in rows:
        print(f"{r['batch']:>6} {r['path']:>6} "
              f"{r['launches_per_flush']:>9.2f} {r['table_len']:>6} "
              f"{r['us_per_flush']:>10.1f} "
              f"{r['bytes_moved'] / 1e6:>9.1f}")


def _print_serve(section: Dict) -> None:
    for r in section["rows"]:
        print(f"  {r['path']:>14} {r['launches_admit_round']:>8.2f} "
              f"launches/admit-round {r['us_per_round']:>12.1f} us/round "
              f"({r['stage_promotions']} promotions, "
              f"{r['pool_bytes_resident'] / 1e6:.1f} MB resident, "
              f"{r['stage_capacity']} staging slots)")
    s = section["summary"]
    print(f"  round speedup {s['speedup']:.2f}x  (admit-round launches "
          f"{s['launches_fused']:.2f} fused vs {s['launches_seed']:.2f} "
          f"seed)")
    red = s["staging_memory_reduction"]
    print(f"  staging-ring memory reduction {red:.2f}x  "
          f"(tokens bitwise-identical: {s['ring_tokens_match']})")
    burst = section.get("burst_admission")
    if burst:
        for r in burst["rows"]:
            print(f"  burst {r['path']:>12} "
                  f"{r['launches_per_round']:>6.2f} launches/round "
                  f"{r['us_per_round']:>12.1f} us/round "
                  f"({r['stage_capacity']} staging slots)")
        b = burst["summary"]
        print(f"  burst ({burst['admits_per_round']} admits/round, "
              f"{burst['ring_pages']}-slot ring): "
              f"{b['launches_double']:.2f} double vs "
              f"{b['launches_single']:.2f} single launches/round "
              f"(tokens match: {b['tokens_match']})")
    fault = section.get("fault_recovery")
    if fault:
        fs = fault["summary"]
        print(f"  fault recovery ({', '.join(fault['injections'])}): "
              f"tokens match {fs['tokens_match']}, recovered in "
              f"{fs['rounds_to_recover']} round(s), {fs['evicted']} "
              f"evicted/re-admitted, post-recovery serve launches "
              f"<= {fs['max_launches_post_recovery']}, ckpt stream "
              f"active: {fs['ckpt_active']}")


def serve_smoke() -> int:
    """CI gate (``make bench-serve``): run the CPU serve_round legs and
    FAIL (exit 1) if the fused paths regress above 1.0 bulk-movement
    launch per round — the one-launch-per-flush invariant this repo is
    built around — or if ring staging stops matching the full twin's
    greedy tokens.  Since schema v8 it also gates the bitwise-opcode leg
    (fused must stay at 1.0 launch/flush with bit-identical pools vs the
    seed fan-out) and the dedup_admit leg (resident KV must shrink while
    greedy tokens stay bitwise-equal to the dedup-off twin at <= 1.0
    launches/round).  Returns the process exit code."""
    section = _run_serve_section(skip_mesh=True)
    _print_serve(section)
    ok = True
    for row in section["rows"]:
        if row["path"] in ("fused_staging", "ring_staging"):
            for key in ("launches_admit_round", "launches_per_round"):
                if row[key] > 1.0:
                    print(f"FAIL: {row['path']} {key} = {row[key]:.2f} "
                          "> 1.0 (serving round no longer drains as one "
                          "fused launch)")
                    ok = False
    if not section["summary"]["ring_tokens_match"]:
        print("FAIL: ring_staging greedy tokens diverged from "
              "fused_staging")
        ok = False
    burst = section["burst_admission"]
    for row in burst["rows"]:
        if row["path"] == "double_ring" and \
                row["launches_per_round"] > 1.0:
            print(f"FAIL: double-buffered ring burst rounds = "
                  f"{row['launches_per_round']:.2f} launches/round > 1.0 "
                  "(the shadow half no longer absorbs admission bursts)")
            ok = False
    if not burst["summary"]["tokens_match"]:
        print("FAIL: double-buffered burst greedy tokens diverged from "
              "single-buffered")
        ok = False
    fault = section["fault_recovery"]["summary"]
    if not fault["tokens_match"]:
        print("FAIL: fault-injected serve run's greedy tokens diverged "
              "from the failure-free run")
        ok = False
    if fault["rounds_to_recover"] > 2:
        print(f"FAIL: recovery took {fault['rounds_to_recover']} rounds "
              "to restore a clean serve flush (> 2)")
        ok = False
    if fault["max_launches_post_recovery"] > 1:
        print(f"FAIL: post-recovery serve rounds issue "
              f"{fault['max_launches_post_recovery']} bulk-movement "
              "launches (> 1.0/round)")
        ok = False
    bitwise = _run_bitwise_section()
    _print_bitwise(bitwise)
    bw = bitwise["summary"]
    if bw["launches_fused"] > 1.0:
        print(f"FAIL: fused bitwise flushes = {bw['launches_fused']:.2f} "
              "launches/flush > 1.0 (AND/OR/NOT rows no longer ride the "
              "fused table)")
        ok = False
    if not bw["bitwise_match"]:
        print("FAIL: fused bitwise pool bytes diverged from the seed "
              "fan-out path")
        ok = False
    dedup = _run_dedup_section()
    _print_dedup(dedup)
    if not dedup["tokens_match"]:
        print("FAIL: dedup-on-admit greedy tokens diverged from the "
              "dedup-off twin")
        ok = False
    if dedup["resident_reduction"] <= 0:
        print(f"FAIL: dedup_admit saved no resident KV bytes "
              f"(reduction = {dedup['resident_reduction']:.2%})")
        ok = False
    if dedup["max_launches_per_round"] > 1.0:
        print(f"FAIL: dedup serving rounds hit "
              f"{dedup['max_launches_per_round']:.2f} launches/round "
              "> 1.0")
        ok = False
    if ok:
        print("bench-serve smoke OK: fused serve rounds still drain as "
              "one launch")
    return 0 if ok else 1


def main() -> None:
    """CLI entry — see the module docstring for the output schema."""
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="BENCH_dispatch.json")
    ap.add_argument("--skip-mesh", action="store_true",
                    help="skip the 8-device subprocess A/B sections")
    ap.add_argument("--skip-serve", action="store_true",
                    help="skip the serving-round A/B section")
    ap.add_argument("--serve-smoke", action="store_true",
                    help="CI gate: CPU serve_round legs only; exit 1 if "
                         "fused launches/round regress above 1.0")
    ap.add_argument("--traffic-smoke", action="store_true",
                    help="CI gate: serve_traffic legs only; exit 1 if "
                         "churn/preemption rounds exceed 1.0 launches, "
                         "resume parity breaks, or p99 regresses vs the "
                         "committed baseline")
    ap.add_argument("--mesh-child", action="store_true",
                    help=argparse.SUPPRESS)
    ap.add_argument("--serve-mesh-child", action="store_true",
                    help=argparse.SUPPRESS)
    ap.add_argument("--traffic-mesh-child", action="store_true",
                    help=argparse.SUPPRESS)
    args = ap.parse_args()
    if args.mesh_child:
        _mesh_child()
        return
    if args.serve_mesh_child:
        _serve_child()
        return
    if args.traffic_mesh_child:
        _traffic_mesh_child()
        return
    if args.serve_smoke:
        sys.exit(serve_smoke())
    if args.traffic_smoke:
        sys.exit(traffic_smoke())
    result = run(skip_mesh=args.skip_mesh, skip_serve=args.skip_serve)
    with open(args.out, "w") as f:
        json.dump(result, f, indent=2)
    print(f"{'batch':>6} {'path':>6} {'launches':>9} {'table':>6} "
          f"{'us/flush':>10} {'MB moved':>9}")
    _print_rows(result["rows"])
    print(f"\nsmall-batch (<=8) dispatch speedup: "
          f"{result['summary']['speedup_small_batch']:.2f}x")
    if result["mesh"]:
        m = result["mesh"]
        print(f"\nmesh ({m['devices']} host devices, "
              f"{'x'.join(map(str, m['mesh_shape']))}):")
        _print_rows(m["rows"])
        print(f"mesh flush speedup: {m['summary']['speedup']:.2f}x  "
              f"(launches/flush {m['summary']['launches_fused']:.2f} fused "
              f"vs {m['summary']['launches_seed']:.2f} seed)")
    if result["serve_round"]:
        sr = result["serve_round"]
        print(f"\nserve_round ({sr['arch']}, {sr['rounds']} rounds, "
              f"{sr['admit_rounds']} admissions):")
        _print_serve(sr)
        if sr["mesh"]:
            print(f"serve_round mesh ({sr['mesh']['devices']} host "
                  f"devices):")
            _print_serve(sr["mesh"])
    if result.get("serve_traffic"):
        st = result["serve_traffic"]
        print(f"\nserve_traffic ({st['rounds']} rounds, tenants "
              f"{st['tenants']}):")
        _print_traffic(st)
    if result.get("bitwise"):
        print("\nbitwise opcodes (AND/OR/NOT):")
        _print_bitwise(result["bitwise"])
    if result.get("dedup_admit"):
        print("\ndedup_admit:")
        _print_dedup(result["dedup_admit"])
    print(f"-> {args.out}")


if __name__ == "__main__":
    main()
