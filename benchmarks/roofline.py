"""§Roofline table generator — reads the dry-run JSONL artifacts."""
from __future__ import annotations

import json
import os
from typing import Dict, List

RESULTS_DIR = os.path.join(os.path.dirname(__file__), "..", "results")


def load(path: str = None) -> List[Dict]:
    """Baseline rows overlaid with the optimized (v2) rows when present."""
    best: Dict = {}
    paths = [path] if path else [
        os.path.join(RESULTS_DIR, "dryrun.jsonl"),
        os.path.join(RESULTS_DIR, "dryrun_v2.jsonl"),
    ]
    for p in paths:
        if not p or not os.path.exists(p):
            continue
        with open(p) as f:
            for line in f:
                r = json.loads(line)
                best[(r["arch"], r["shape"], r["mesh"])] = r
    return sorted(best.values(), key=lambda r: (r["arch"], r["shape"],
                                                r["mesh"]))


def table(rows: List[Dict], mesh: str = "16x16") -> List[Dict]:
    out = []
    for r in rows:
        if r["mesh"] != mesh:
            continue
        if r["status"] == "skip":
            out.append(dict(arch=r["arch"], shape=r["shape"], status="skip",
                            reason=r.get("reason", "")))
            continue
        if r["status"] != "ok":
            out.append(dict(arch=r["arch"], shape=r["shape"],
                            status="error", reason=r.get("error", "")[:80]))
            continue
        out.append(dict(
            arch=r["arch"], shape=r["shape"], status="ok",
            t_compute_ms=r["t_compute_s"] * 1e3,
            t_memory_ms=r["t_memory_s"] * 1e3,
            t_collective_ms=r["t_collective_s"] * 1e3,
            dominant=r["dominant"],
            useful_flops=r["useful_flop_ratio"],
            roofline_frac=r["roofline_fraction"],
            temp_gib=r["memory"]["temp_size_in_bytes"] / 2 ** 30,
        ))
    return out


def run() -> List[Dict]:
    return table(load())
