"""Profiler-driven autotuner: sweep the engine's throughput constants,
persist the winners as a per-backend TunedProfile.

The engine's hand-picked constants — the command-table bucket set
(``cmdqueue.BUCKETS``), the fused kernel's overlapped-DMA toggle, the
serving staging-ring capacity, and the sharded jit-cache bound
(``fused_dispatch.MAX_DELTA_SIGNATURES``) — are exactly the knobs a MEF-
style experiment matrix tunes per machine.  This benchmark runs that
matrix against representative command streams:

* **flush matrix** — bucket set x overlap over mixed copy+zero flushes
  at several batch sizes (the same workload shape as
  ``bench_dispatch.py``), scoring each configuration by the mean of the
  per-batch median ``us_per_flush`` (measured with the shared obs
  stopwatch) and asserting the fused 1-launch-per-flush invariant holds
  under every configuration;
* **ring sweep** — staging-ring capacities over short serving runs
  (admissions + decode rounds through the real ``ServingEngine``),
  scoring by median ``us_per_round``;
* **delta-signature sweep** — ``MAX_DELTA_SIGNATURES`` candidates over
  repeated sharded-plan signature folds (the jit-cache bound only
  matters under a mesh; the sweep runs in the 8-host-device subprocess
  and is skipped with ``--quick``).

Winners are chosen by :func:`repro.obs.autotune.pick_winner`: a
candidate unseats the default only by beating it by a clear margin
(3%), so noise can never flip a committed constant.  The result is
saved as ``configs/tuned/<backend>.json`` — which
``RowCloneEngine``/``ServingEngine`` load at startup (explicit kwargs
always win; delete the file or set ``REPRO_NO_TUNED=1`` to opt out).

``--check`` is the CI gate wired into ``make bench-serve``: re-measure
the committed profile's configuration against the built-in defaults and
FAIL (exit 1) if the profile is slower than the defaults by more than
15% on the swept flush workload — a committed profile must never
regress the engine it claims to tune.

CLI: PYTHONPATH=src python benchmarks/bench_autotune.py
         [--out-dir DIR] [--quick] [--check] [--skip-ring] [--skip-mesh]
"""
from __future__ import annotations

import argparse
import json
import os
import subprocess
import sys
from typing import Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import RowCloneEngine, SubarrayAllocator
from repro.core import cmdqueue
from repro.kernels import fused_dispatch as fd
from repro.obs import metrics as obs_metrics
from repro.obs.autotune import (DEFAULT_MARGIN, TunedProfile, backend_key,
                                load_profile, pick_winner, save_profile)

BLOCK = (16, 2, 64)          # page x KVH x head_dim (bench_dispatch shape)
NBLK = 1024
NSLABS = 4

#: bucket-set candidates (first = the hand-picked default)
BUCKET_SETS: Tuple[Tuple[int, ...], ...] = (
    cmdqueue.DEFAULT_BUCKETS,
    (4, 16, 64, 256),
    (16, 64, 256, 1024),
    (8, 64, 512),
)
OVERLAPS = (True, False)
BATCHES = (4, 16, 64, 256)
REPS = 15

#: staging-ring candidates (None = the serving layer's policy derivation)
RING_CANDIDATES: Tuple[Optional[int], ...] = (None, 4, 8, 16)
RING_ROUNDS = 6
RING_ADMITS = 3

#: sharded jit-cache bound candidates (first = default)
DELTA_SIG_CANDIDATES = (fd.DEFAULT_MAX_DELTA_SIGNATURES, 4, 16)
MESH_SHAPE = (2, 4)
MESH_REPS = 8


def _mk_engine(overlap: bool) -> RowCloneEngine:
    alloc = SubarrayAllocator(NBLK, NSLABS, reserved_zero_per_slab=1)
    pools = {
        "k": jax.random.normal(jax.random.key(0), (NBLK,) + BLOCK,
                               jnp.float32),
        "v": jax.random.normal(jax.random.key(1), (NBLK,) + BLOCK,
                               jnp.float32),
    }
    return RowCloneEngine(pools, alloc, overlap=overlap)


def _flush_once(eng: RowCloneEngine, batch: int, round_i: int) -> None:
    """One mixed flush: ~3/4 copies, ~1/4 zero-inits, ids rotating per
    round (jit caches stay warm, data differs) — bench_dispatch's
    workload shape."""
    n_zero = max(batch // 4, 1)
    n_copy = batch - n_zero
    base = (round_i * batch) % (NBLK // 4)
    srcs = [1 + (base + i) % (NBLK // 4) for i in range(n_copy)]
    dsts = [NBLK // 2 + (base + i) % (NBLK // 4) for i in range(n_copy)]
    zeros = [3 * NBLK // 4 + (base + i) % (NBLK // 8) for i in range(n_zero)]
    eng.alloc.mark_written(srcs)
    with eng.batch():
        eng.memcopy(list(zip(srcs, dsts)))
        eng.materialize_zeros(zeros)


def measure_flush_cfg(buckets: Sequence[int], overlap: bool,
                      batches: Sequence[int] = BATCHES,
                      reps: int = REPS) -> Dict:
    """Score one (bucket set, overlap) configuration: mean over batch
    sizes of the median flush wall-clock (us), with launch accounting.
    The bucket set installs process-wide for the measurement and is
    restored by the caller's sweep loop."""
    cmdqueue.set_buckets(buckets)
    per_batch: List[float] = []
    launches = 0
    flushes = 0
    try:
        for batch in batches:
            eng = _mk_engine(overlap)
            for r in range(3):                      # compile warmup
                _flush_once(eng, batch, r)
            times: List[float] = []
            l0 = eng.stats.launches
            for r in range(reps):
                with obs_metrics.Stopwatch() as sw:
                    _flush_once(eng, batch, 100 + r)
                    jax.block_until_ready(list(eng.pools.values()))
                times.append(sw.us)
            launches += eng.stats.launches - l0
            flushes += reps
            per_batch.append(obs_metrics.percentile(times, 50))
    finally:
        cmdqueue.set_buckets(None)
    return {
        "cfg": {"buckets": list(buckets), "overlap": bool(overlap)},
        "us_per_flush": float(np.mean(per_batch)),
        "us_per_batch": {str(b): round(v, 1)
                         for b, v in zip(batches, per_batch)},
        "launches_per_flush": launches / max(flushes, 1),
    }


def sweep_flush(batches: Sequence[int] = BATCHES,
                reps: int = REPS,
                bucket_sets: Sequence[Sequence[int]] = BUCKET_SETS,
                overlaps: Sequence[bool] = OVERLAPS) -> List[Dict]:
    """The bucket-set x overlap experiment matrix."""
    rows = []
    for buckets in bucket_sets:
        for overlap in overlaps:
            row = measure_flush_cfg(buckets, overlap, batches, reps)
            rows.append(row)
            print(f"  flush buckets={list(buckets)!s:>20} "
                  f"overlap={overlap!s:>5}: "
                  f"{row['us_per_flush']:>9.1f} us/flush "
                  f"({row['launches_per_flush']:.2f} launches)")
    return rows


def measure_ring(ring: Optional[int], rounds: int = RING_ROUNDS,
                 admits: int = RING_ADMITS) -> Dict:
    """Score one staging-ring capacity over a short serving run (admit a
    prompt for the first ``admits`` rounds, decode every round)."""
    from repro.configs import get_config
    from repro.launch.serve import ServingEngine
    from repro.models import build_model, split_params
    cfg = get_config("llama3.2-3b").reduced()
    model = build_model(cfg)
    params, _ = split_params(model.init_params(jax.random.key(0)))
    eng = ServingEngine(cfg, params, max_seqs=8, max_blocks_per_seq=16,
                        max_admit_pages=ring, adaptive_ring=False)
    rng = np.random.default_rng(0)
    times: List[float] = []
    for r in range(rounds):
        with obs_metrics.Stopwatch() as sw:
            if r < admits:
                eng.add_request(rng.integers(2, cfg.vocab_size, size=24)
                                .astype(np.int32))
            eng.decode_round()
            jax.block_until_ready([eng.engine.pools["k"],
                                   eng.engine.pools["v"]])
        times.append(sw.us)
    meas = times[2:] if len(times) > 2 else times   # drop compile rounds
    return {
        "cfg": {"ring": ring},
        "us_per_flush": float(obs_metrics.percentile(meas, 50)),
        "stage_capacity": int(eng.engine.stage_capacity),
    }


def sweep_ring(rounds: int = RING_ROUNDS,
               candidates: Sequence[Optional[int]] = RING_CANDIDATES
               ) -> List[Dict]:
    rows = []
    for ring in candidates:
        row = measure_ring(ring, rounds=rounds)
        rows.append(row)
        print(f"  ring={str(ring):>6}: {row['us_per_flush']:>10.1f} "
              f"us/round ({row['stage_capacity']} slots)")
    return rows


# ---------------------------------------------------------------------------
# delta-signature sweep — sharded plans in the 8-host-device subprocess
# ---------------------------------------------------------------------------

def _delta_child() -> None:
    """Child process (8 forced host devices): time mesh flushes whose
    cross-slab delta signatures rotate, for each MAX_DELTA_SIGNATURES
    candidate — a small bound folds distant deltas into one compiled
    collective (fewer compiles, more padding); a large bound compiles
    more variants."""
    from jax.sharding import Mesh
    mesh = Mesh(np.asarray(jax.devices()).reshape(MESH_SHAPE),
                ("data", "model"))
    rows = []
    for cand in DELTA_SIG_CANDIDATES:
        fd.set_max_delta_signatures(cand)
        try:
            alloc = SubarrayAllocator(NBLK, NSLABS, reserved_zero_per_slab=1)
            pools = {
                "k": jax.random.normal(jax.random.key(0), (NBLK,) + BLOCK,
                                       jnp.float32),
                "v": jax.random.normal(jax.random.key(1), (NBLK,) + BLOCK,
                                       jnp.float32),
            }
            eng = RowCloneEngine(pools, alloc, mesh=mesh)
            shard = NBLK // int(np.prod(MESH_SHAPE))
            for r in range(2):                      # warmup compiles
                _flush_once(eng, 16, r)
            times = []
            for r in range(MESH_REPS):
                with obs_metrics.Stopwatch() as sw:
                    # rotate a cross-slab pair per rep so the plan's
                    # delta signature changes and the bound matters
                    s = 1 + r % (shard - 1)
                    d = NBLK - 1 - r % (shard - 1)
                    eng.alloc.mark_written([s])
                    eng.memcopy([(s, d)])
                    jax.block_until_ready(list(eng.pools.values()))
                times.append(sw.us)
            rows.append({"cfg": {"max_delta_signatures": cand},
                         "us_per_flush":
                         obs_metrics.percentile(times, 50)})
        finally:
            fd.set_max_delta_signatures(None)
    print("DELTAROWS:" + json.dumps(rows))


def sweep_delta_signatures() -> Optional[List[Dict]]:
    """Run the delta-signature sweep in a fresh 8-host-device process
    (jax pins the device count at first init).  None when it fails."""
    n_dev = int(np.prod(MESH_SHAPE))
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={n_dev}"
    env["JAX_PLATFORMS"] = "cpu"
    src = os.path.abspath(os.path.join(os.path.dirname(__file__), "..",
                                       "src"))
    env["PYTHONPATH"] = src + (os.pathsep + env["PYTHONPATH"]
                               if env.get("PYTHONPATH") else "")
    try:
        out = subprocess.run(
            [sys.executable, os.path.abspath(__file__), "--delta-child"],
            env=env, capture_output=True, text=True, timeout=900)
    except subprocess.TimeoutExpired:
        return None
    lines = [l for l in out.stdout.splitlines()
             if l.startswith("DELTAROWS:")]
    if out.returncode != 0 or not lines:
        print(f"[bench_autotune] delta-signature sweep failed:\n"
              f"{out.stderr[-2000:]}")
        return None
    rows = json.loads(lines[0][len("DELTAROWS:"):])
    for r in rows:
        print(f"  max_delta_signatures={r['cfg']['max_delta_signatures']:>3}"
              f": {r['us_per_flush']:>10.1f} us/flush")
    return rows


# ---------------------------------------------------------------------------
# tune + check
# ---------------------------------------------------------------------------

def tune(out_dir: Optional[str] = None, quick: bool = False,
         skip_ring: bool = False, skip_mesh: bool = False) -> TunedProfile:
    """Run the sweeps, pick winners (margin rule), save and reload the
    per-backend profile.  Returns the saved :class:`TunedProfile`."""
    prev_no_tuned = os.environ.get("REPRO_NO_TUNED")
    os.environ["REPRO_NO_TUNED"] = "1"      # sweeps measure raw configs
    try:
        backend = backend_key()
        batches = (4, 32) if quick else BATCHES
        reps = 5 if quick else REPS
        bucket_sets = BUCKET_SETS[:2] if quick else BUCKET_SETS
        print(f"[bench_autotune] backend={backend} flush matrix "
              f"({len(bucket_sets)} bucket sets x {len(OVERLAPS)} overlap)")
        flush_rows = sweep_flush(batches, reps, bucket_sets)
        default_cfg = {"buckets": list(cmdqueue.DEFAULT_BUCKETS),
                       "overlap": True}
        flush_win = pick_winner(flush_rows, default_cfg)
        flush_default = next(r for r in flush_rows
                             if r["cfg"] == default_cfg)
        swept: Dict = {
            "flush": {"rows": flush_rows,
                      "winner": flush_win["cfg"],
                      "margin": DEFAULT_MARGIN},
        }
        ring: Optional[int] = None
        if not skip_ring:
            print("[bench_autotune] staging-ring sweep")
            ring_rows = sweep_ring(rounds=4 if quick else RING_ROUNDS)
            ring_win = pick_winner(ring_rows, {"ring": None})
            ring = ring_win["cfg"]["ring"]
            swept["ring"] = {"rows": ring_rows, "winner": ring_win["cfg"]}
        delta = fd.DEFAULT_MAX_DELTA_SIGNATURES
        if not (quick or skip_mesh):
            print("[bench_autotune] delta-signature sweep (mesh child)")
            delta_rows = sweep_delta_signatures()
            if delta_rows:
                d_win = pick_winner(
                    delta_rows,
                    {"max_delta_signatures":
                     fd.DEFAULT_MAX_DELTA_SIGNATURES})
                delta = int(d_win["cfg"]["max_delta_signatures"])
                swept["delta_signatures"] = {"rows": delta_rows,
                                             "winner": d_win["cfg"]}
        profile = TunedProfile(
            backend=backend,
            buckets=tuple(flush_win["cfg"]["buckets"]),
            overlap=bool(flush_win["cfg"]["overlap"]),
            max_delta_signatures=delta,
            ring_capacity=ring,
            us_per_flush=float(flush_win["us_per_flush"]),
            baseline_us_per_flush=float(flush_default["us_per_flush"]),
            swept=swept)
    finally:
        if prev_no_tuned is None:
            os.environ.pop("REPRO_NO_TUNED", None)
        else:
            os.environ["REPRO_NO_TUNED"] = prev_no_tuned
    path = save_profile(profile, directory=out_dir)
    print(f"[bench_autotune] wrote {path}")
    # reload through the startup path — the engine's "profile loaded"
    # breadcrumb should print right here
    loaded = load_profile(directory=out_dir)
    assert loaded is not None and loaded.backend == profile.backend
    return profile


def check(margin: float = 1.15, quick: bool = True) -> int:
    """CI gate: the committed profile must not be slower than the
    built-in defaults by more than ``margin`` on the swept flush
    workload.  Exit 0 when no profile is committed (nothing to gate).

    Replays the SAME batch sizes the full tune scored (``BATCHES``) —
    a bucket set is tuned for that batch mix, and measuring a different
    mix (e.g. only small batches, where coarse buckets over-pad) would
    flag a genuinely faster profile as a regression.  ``quick`` only
    drops the rep count."""
    prof = load_profile()
    if prof is None:
        print("[bench_autotune] no committed profile for backend "
              f"{backend_key()!r}: nothing to check")
        return 0
    batches = BATCHES
    reps = 5 if quick else REPS
    prev_no_tuned = os.environ.get("REPRO_NO_TUNED")
    os.environ["REPRO_NO_TUNED"] = "1"
    try:
        default_row = measure_flush_cfg(cmdqueue.DEFAULT_BUCKETS, True,
                                        batches, reps)
        tuned_row = measure_flush_cfg(prof.buckets, prof.overlap,
                                      batches, reps)
    finally:
        if prev_no_tuned is None:
            os.environ.pop("REPRO_NO_TUNED", None)
        else:
            os.environ["REPRO_NO_TUNED"] = prev_no_tuned
    d, t = default_row["us_per_flush"], tuned_row["us_per_flush"]
    print(f"[bench_autotune] check: defaults {d:.1f} us/flush, "
          f"tuned profile {t:.1f} us/flush ({t / d:.2f}x)")
    if t > d * margin:
        print(f"FAIL: committed tuned profile is {t / d:.2f}x slower "
              f"than the defaults (> {margin:.2f}x) — retune or delete "
              "configs/tuned/" + prof.backend + ".json")
        return 1
    print("bench-autotune check OK: committed profile does not regress "
          "the defaults")
    return 0


def main() -> None:
    """CLI entry — sweep and persist (default), or ``--check`` gate."""
    ap = argparse.ArgumentParser()
    ap.add_argument("--out-dir", default=None,
                    help="profile directory (default configs/tuned/, or "
                         "$REPRO_TUNED_DIR)")
    ap.add_argument("--quick", action="store_true",
                    help="tiny matrix/reps (smoke tests)")
    ap.add_argument("--skip-ring", action="store_true",
                    help="skip the serving staging-ring sweep")
    ap.add_argument("--skip-mesh", action="store_true",
                    help="skip the 8-device delta-signature sweep")
    ap.add_argument("--check", action="store_true",
                    help="CI gate: committed profile must not regress "
                         "the defaults (exit 1 on regression)")
    ap.add_argument("--delta-child", action="store_true",
                    help=argparse.SUPPRESS)
    args = ap.parse_args()
    if args.delta_child:
        _delta_child()
        return
    if args.check:
        sys.exit(check())
    prof = tune(out_dir=args.out_dir, quick=args.quick,
                skip_ring=args.skip_ring, skip_mesh=args.skip_mesh)
    print(f"[bench_autotune] winner: buckets={list(prof.buckets)} "
          f"overlap={prof.overlap} ring={prof.ring_capacity} "
          f"max_delta_signatures={prof.max_delta_signatures} "
          f"({prof.us_per_flush:.1f} us/flush vs "
          f"{prof.baseline_us_per_flush:.1f} default)")


if __name__ == "__main__":
    main()
