"""Figure 3/4 analogue — multi-tenant interference, and the traffic driver.

Paper Figs. 3/4: multiprogrammed workloads (copy-intensive + memory-
intensive) show RowClone(-ZI) lifting weighted speedup by freeing the
shared memory bus; benefit grows with the number of copy-intensive tenants.

Serving analogue: N decode tenants share one pool/device.  Some tenants are
"copy-intensive" (fork+CoW every round — the paper's forkbench), others
plain decoders (the memory-intensive SPEC analogue: their decode reads the
KV pool at HBM speed).  With RowClone OFF the copy tenants' block copies run
through the compute pipeline and zeros are materialized, stealing the shared
bandwidth; ON they ride the DMA path / metadata bits.

Weighted speedup = mean over tenants of t_alone / t_shared (paper's metric),
reported for 1..3 copy-intensive tenants out of 4.

**Closed-loop traffic driver** (:func:`run_traffic`): the production-shaped
leg.  Requests arrive per round from a Poisson or bursty process onto
per-tenant QoS lanes (gold > silver > free) of a
:class:`~repro.launch.scheduler.RequestScheduler` over a deliberately
UNDERSIZED engine, so the round loop exercises continuous admission,
priority preemption by demotion, and resumption.  Reported per tenant:
p50/p99 token latency (rounds between consecutive tokens — preemption
stalls show up here), time-to-first-token, goodput (completed requests'
tokens/s), and preemption counts; plus the per-round launch series the
``serve_traffic`` gate holds at <= 1.0.

**Dedup traffic leg** (:func:`run_dedup`): multi-tenant duplicated-prompt
traffic — several tenants admit the same canonical prompts, and the leg
drives one engine with ``dedup_admit=True`` against an identical
dedup-off twin: resident KV bytes (``ServingEngine.kv_bytes_live``) drop
by the shared pages while greedy tokens stay bitwise-identical and each
round still drains <= 1 launch.  The ``BENCH_dispatch.json`` v8
``dedup_admit`` leg records the reduction.

CLI:  PYTHONPATH=src python benchmarks/fig34_multitenant.py \
          --traffic poisson --rounds 48
"""
from __future__ import annotations

import argparse
import dataclasses
from typing import Dict, List

import jax
import numpy as np

from repro.configs import RowCloneConfig, get_config
from repro.launch.scheduler import RequestScheduler, TenantSpec
from repro.obs import metrics as obs_metrics
from repro.launch.serve import ServingEngine
from repro.models import build_model, split_params

ROUNDS = 4


def _run_mix(cfg, params, n_copy: int, n_plain: int, on: bool) -> float:
    rc = RowCloneConfig(enable_fpm=on, enable_psm=on, enable_zi=on)
    eng = ServingEngine(cfg, params, max_seqs=32, rc=rc)
    rng = np.random.default_rng(0)
    plain, copyers = [], []
    for _ in range(n_plain):
        plain.append(eng.add_request(
            rng.integers(2, cfg.vocab_size, size=32).astype(np.int32)))
    for _ in range(n_copy):
        copyers.append(eng.add_request(
            rng.integers(2, cfg.vocab_size, size=32).astype(np.int32)))
    with obs_metrics.Stopwatch() as sw:
        for r in range(ROUNDS):
            # copy-intensive tenants fork every round (children freed
            # after one round — a churning CoW workload)
            kids = []
            for sid in copyers:
                kids.extend(eng.fork(sid, 1))
            if not on:
                # baseline: forks must physically copy every block up
                # front.  The remap goes through the cache's PUBLIC
                # resettlement API (remap_blocks frees the stale blocks
                # and rebuilds the device tables) — no reaching into
                # private cache state
                for sid in kids:
                    fresh = []
                    for b in eng.cache.blocks_of(sid):
                        nb = eng.engine.alloc.alloc_near(b)
                        eng.engine.memcopy([(b, nb)])
                        fresh.append(nb)
                    eng.cache.remap_blocks(sid, fresh)
            eng.decode_round()
            for sid in kids:
                eng.free(sid)
    return sw.s


def run() -> List[Dict]:
    cfg = get_config("yi-6b").reduced()
    model = build_model(cfg)
    params, _ = split_params(model.init_params(jax.random.key(0)))
    # alone baseline: one plain tenant
    t_alone = _run_mix(cfg, params, 0, 1, True) / ROUNDS
    rows = []
    for n_copy in (1, 2, 3):
        n_plain = 4 - n_copy
        res = {}
        for on in (False, True):
            t = _run_mix(cfg, params, n_copy, n_plain, on) / ROUNDS
            # weighted speedup proxy: per-round time normalized by tenant
            # count, vs running alone
            ws = t_alone * (n_plain + n_copy) / max(t, 1e-9)
            res["on" if on else "off"] = ws
        rows.append(dict(mix=f"{n_copy}copy+{n_plain}plain",
                         ws_baseline=res["off"], ws_rowclone=res["on"],
                         improvement=res["on"] / max(res["off"], 1e-9)))
    return rows


# ---------------------------------------------------------------------------
# closed-loop traffic driver (RequestScheduler under Poisson/bursty load)
# ---------------------------------------------------------------------------

#: tenant mix for the traffic legs: gold preempts silver preempts free
TENANTS = (TenantSpec("gold", priority=2),
           TenantSpec("silver", priority=1),
           TenantSpec("free", priority=0))

#: mean arrivals per round per tenant for the Poisson process
POISSON_RATES = {"gold": 0.15, "silver": 0.3, "free": 0.6}


def _arrivals(pattern: str, rng, round_index: int) -> Dict[str, int]:
    """Arrivals per tenant for one round.

    ``poisson``: independent Poisson counts at :data:`POISSON_RATES`.
    ``bursty``: the free tenant slams 3 requests every 8th round (the
    churn burst that over-commits the undersized pool), gold/silver
    trickle Poisson — the pattern that forces preemption."""
    if pattern == "poisson":
        return {t: int(rng.poisson(POISSON_RATES[t])) for t in POISSON_RATES}
    if pattern == "bursty":
        out = {"gold": int(rng.poisson(0.15)),
               "silver": int(rng.poisson(0.2)),
               "free": 3 if round_index % 8 == 0 else 0}
        return out
    raise ValueError(f"unknown arrival pattern {pattern!r}")


def _pct(xs: List[float], q: float) -> float:
    return obs_metrics.percentile(xs, q)


@dataclasses.dataclass
class TrafficResult:
    """Aggregated output of one :func:`run_traffic` leg."""

    pattern: str                   #: arrival pattern the leg ran
    rounds: int                    #: rounds driven
    launches: List[int]            #: per-round bulk-movement launches
    per_tenant: Dict[str, Dict]    #: tenant -> latency/goodput metrics
    preempted_rids: List[int]      #: requests that were demoted >= once
    completed: int                 #: requests that finished
    submitted: int                 #: requests that arrived

    def max_launches_per_round(self) -> float:
        """The serve_traffic gate metric: worst-round launch count."""
        return float(max(self.launches)) if self.launches else 0.0


def run_traffic(pattern: str = "poisson", rounds: int = 48, seed: int = 0,
                arch: str = "llama3.2-3b", max_new_tokens: int = 8,
                eng: ServingEngine = None) -> TrafficResult:
    """Drive a RequestScheduler closed-loop under ``pattern`` arrivals.

    The engine is deliberately undersized (4 batch slots over 2 slabs)
    relative to the offered load, so bursts queue, gold arrivals preempt
    free-tenant victims, and victims resume — while every round's bulk
    movement (admission promotions, demote/resume cross-pool copies, CoW
    splits, tail inits) must still drain as at most ONE fused launch.
    Pass ``eng`` to reuse a prebuilt engine (the smoke gate does, to
    keep its runtime down)."""
    if eng is None:
        cfg = get_config(arch).reduced()
        model = build_model(cfg)
        params, _ = split_params(model.init_params(jax.random.key(0)))
        eng = ServingEngine(cfg, params, max_seqs=4, max_blocks_per_seq=8,
                            num_slabs=2, max_admit_pages=8,
                            double_buffer=True, spill_pages=8)
    cfg = eng.cfg
    sched = RequestScheduler(eng, list(TENANTS))
    rng = np.random.default_rng(seed)
    launches: List[int] = []
    #: per-rid round index of the last emitted token (for inter-token
    #: latency); starts at the submit round
    last_emit: Dict[int, int] = {}
    tok_lat: Dict[str, List[float]] = {t.name: [] for t in TENANTS}
    ttft: Dict[str, List[float]] = {t.name: [] for t in TENANTS}
    round_times: List[float] = []
    prev_gen: Dict[int, int] = {}
    for r in range(rounds):
        for tenant, n in _arrivals(pattern, rng, r).items():
            for _ in range(n):
                plen = int(rng.integers(8, 17))
                rid = sched.submit(
                    tenant,
                    rng.integers(2, cfg.vocab_size, size=plen)
                    .astype(np.int32),
                    max_new_tokens=max_new_tokens)
                last_emit[rid] = r
        with obs_metrics.Stopwatch() as sw:
            rep = sched.step()
        round_times.append(sw.s)
        launches.append(rep.launches)
        for rid, req in sched.requests.items():
            new = req.generated - prev_gen.get(rid, 0)
            if new <= 0:
                continue
            first = prev_gen.get(rid, 0) == 0
            prev_gen[rid] = req.generated
            # inter-token latency in rounds: stalls (queueing and
            # preemption parking) stretch exactly this gap
            tok_lat[req.tenant].append(float(max(r - last_emit[rid], 1)))
            last_emit[rid] = r
            if first:
                ttft[req.tenant].append(
                    float(r - req.submitted_round + 1))
    # drain what's in flight so goodput counts whole requests
    extra = 0
    while not sched.idle and extra < 4 * rounds:
        rep = sched.step()
        launches.append(rep.launches)
        extra += 1
    wall = sum(round_times) if round_times else 1e-9
    per_tenant = {}
    for t in TENANTS:
        done = [q for q in sched.requests.values()
                if q.tenant == t.name and q.state == "done"]
        per_tenant[t.name] = dict(
            submitted=sum(1 for q in sched.requests.values()
                          if q.tenant == t.name),
            completed=len(done),
            goodput_tok_s=sum(q.generated for q in done) / wall,
            p50_token_latency_rounds=_pct(tok_lat[t.name], 50),
            p99_token_latency_rounds=_pct(tok_lat[t.name], 99),
            p50_ttft_rounds=_pct(ttft[t.name], 50),
            preemptions=sum(q.preemptions for q in done))
    return TrafficResult(
        pattern=pattern, rounds=rounds, launches=launches,
        per_tenant=per_tenant,
        preempted_rids=[q.rid for q in sched.requests.values()
                        if q.preemptions],
        completed=sum(1 for q in sched.requests.values()
                      if q.state == "done"),
        submitted=len(sched.requests))


# ---------------------------------------------------------------------------
# dedup-on-admit traffic leg (duplicated prompts across tenants)
# ---------------------------------------------------------------------------

def run_dedup(rounds: int = 4, seed: int = 0, arch: str = "llama3.2-3b",
              tenants: int = 4, cfg=None, params=None) -> Dict:
    """Duplicated-prompt traffic: ``tenants`` admissions drawn from TWO
    canonical prompts (so most admissions are exact dupes of an earlier
    tenant's), decoded for ``rounds`` greedy rounds with dedup-on-admit
    ON and then on an identical dedup-off twin.  Returns the
    ``BENCH_dispatch.json`` v8 ``dedup_admit`` leg row: peak resident KV
    bytes for both runs, the reduction, launches/round, and whether every
    tenant's greedy tokens matched bitwise."""
    if cfg is None:
        cfg = get_config(arch).reduced()
        model = build_model(cfg)
        params, _ = split_params(model.init_params(jax.random.key(0)))

    def drive(dedup: bool):
        eng = ServingEngine(cfg, params, max_seqs=max(tenants * 2, 8),
                            dedup_admit=dedup)
        rng = np.random.default_rng(seed)
        page = eng.cache.page
        canon = [rng.integers(2, cfg.vocab_size,
                              size=2 * page + page // 2).astype(np.int32)
                 for _ in range(2)]
        sids = [eng.add_request(canon[t % len(canon)].copy())
                for t in range(tenants)]
        peak = eng.kv_bytes_live()
        launches = []
        for _ in range(rounds):
            eng.decode_round()
            launches.append(eng.last_ticket.launches
                            if eng.last_ticket else 0)
            peak = max(peak, eng.kv_bytes_live())
        toks = [tuple(eng.tokens[s]) for s in sids]
        return eng, toks, peak, launches

    e_on, tok_on, peak_on, l_on = drive(True)
    e_off, tok_off, peak_off, l_off = drive(False)
    return dict(
        tenants=tenants, rounds=rounds,
        kv_bytes_live_on=int(peak_on), kv_bytes_live_off=int(peak_off),
        resident_reduction=1.0 - peak_on / max(peak_off, 1),
        dedup_hits=int(e_on.dedup_hits),
        pages_shared=int(e_on.dedup_pages_shared),
        bytes_saved=int(e_on.dedup_bytes_saved),
        tokens_match=bool(tok_on == tok_off),
        max_launches_per_round=float(max(l_on)) if l_on else 0.0)


def main():
    """CLI for the traffic driver (the fig 3/4 sweep stays importable)."""
    ap = argparse.ArgumentParser()
    ap.add_argument("--traffic", choices=("poisson", "bursty", "dedup"),
                    default="poisson")
    ap.add_argument("--rounds", type=int, default=48)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()
    if args.traffic == "dedup":
        row = run_dedup(rounds=min(args.rounds, 8), seed=args.seed)
        print(f"[traffic:dedup] {row['tenants']} tenants: resident KV "
              f"{row['kv_bytes_live_on']} vs {row['kv_bytes_live_off']} B "
              f"({row['resident_reduction']:.0%} saved), "
              f"{row['pages_shared']} pages shared, tokens_match="
              f"{row['tokens_match']}, max launches/round "
              f"{row['max_launches_per_round']:.1f}")
        return
    res = run_traffic(args.traffic, rounds=args.rounds, seed=args.seed)
    print(f"[traffic:{res.pattern}] {res.submitted} arrived, "
          f"{res.completed} completed, "
          f"max launches/round {res.max_launches_per_round():.1f}, "
          f"{len(res.preempted_rids)} requests preempted")
    for t, m in res.per_tenant.items():
        print(f"  {t:>6}: {m['completed']}/{m['submitted']} done  "
              f"p50/p99 tok-lat {m['p50_token_latency_rounds']:.1f}/"
              f"{m['p99_token_latency_rounds']:.1f} rounds  "
              f"goodput {m['goodput_tok_s']:.1f} tok/s  "
              f"preemptions {m['preemptions']}")


if __name__ == "__main__":
    main()
