"""Figure 3/4 analogue — multi-tenant interference.

Paper Figs. 3/4: multiprogrammed workloads (copy-intensive + memory-
intensive) show RowClone(-ZI) lifting weighted speedup by freeing the
shared memory bus; benefit grows with the number of copy-intensive tenants.

Serving analogue: N decode tenants share one pool/device.  Some tenants are
"copy-intensive" (fork+CoW every round — the paper's forkbench), others
plain decoders (the memory-intensive SPEC analogue: their decode reads the
KV pool at HBM speed).  With RowClone OFF the copy tenants' block copies run
through the compute pipeline and zeros are materialized, stealing the shared
bandwidth; ON they ride the DMA path / metadata bits.

Weighted speedup = mean over tenants of t_alone / t_shared (paper's metric),
reported for 1..3 copy-intensive tenants out of 4.
"""
from __future__ import annotations

import time
from typing import Dict, List

import jax
import numpy as np

from repro.configs import RowCloneConfig, get_config
from repro.launch.serve import ServingEngine
from repro.models import build_model, split_params

ROUNDS = 4


def _run_mix(cfg, params, n_copy: int, n_plain: int, on: bool) -> float:
    rc = RowCloneConfig(enable_fpm=on, enable_psm=on, enable_zi=on)
    eng = ServingEngine(cfg, params, max_seqs=32, rc=rc)
    rng = np.random.default_rng(0)
    plain, copyers = [], []
    for _ in range(n_plain):
        plain.append(eng.add_request(
            rng.integers(2, cfg.vocab_size, size=32).astype(np.int32)))
    for _ in range(n_copy):
        copyers.append(eng.add_request(
            rng.integers(2, cfg.vocab_size, size=32).astype(np.int32)))
    t0 = time.perf_counter()
    for r in range(ROUNDS):
        # copy-intensive tenants fork every round (children freed after one
        # round — a churning CoW workload)
        kids = []
        for sid in copyers:
            kids.extend(eng.fork(sid, 1))
        if not on:
            # baseline: forks must physically copy every block up front
            for sid in kids:
                blocks = eng.cache.blocks_of(sid)
                for j, b in enumerate(blocks):
                    nb = eng.engine.alloc.alloc_near(b)
                    eng.engine.memcopy([(b, nb)])
                    eng.engine.alloc.free([b])
                    eng.cache.seqs[sid].blocks[j] = nb
                eng.cache._dirty = True
        eng.decode_round()
        for sid in kids:
            eng.free(sid)
    return time.perf_counter() - t0


def run() -> List[Dict]:
    cfg = get_config("yi-6b").reduced()
    model = build_model(cfg)
    params, _ = split_params(model.init_params(jax.random.key(0)))
    # alone baseline: one plain tenant
    t_alone = _run_mix(cfg, params, 0, 1, True) / ROUNDS
    rows = []
    for n_copy in (1, 2, 3):
        n_plain = 4 - n_copy
        res = {}
        for on in (False, True):
            t = _run_mix(cfg, params, n_copy, n_plain, on) / ROUNDS
            # weighted speedup proxy: per-round time normalized by tenant
            # count, vs running alone
            ws = t_alone * (n_plain + n_copy) / max(t, 1e-9)
            res["on" if on else "off"] = ws
        rows.append(dict(mix=f"{n_copy}copy+{n_plain}plain",
                         ws_baseline=res["off"], ws_rowclone=res["on"],
                         improvement=res["on"] / max(res["off"], 1e-9)))
    return rows
