"""Figure 2 analogue — application-level benefit of RowClone(-ZI).

Paper Fig. 2: IPC improvement + DRAM energy reduction for six copy/init-
intensive benchmarks.  Serving/training analogues here, each run with
RowClone ON (FPM+PSM+ZI) vs OFF (baseline copies, materialized zeros):

  forkbench   — admission + fork(4) + divergent decode (CoW-heavy; paper's
                fork microbenchmark)
  buz-init    — bulk allocation/zeroing of fresh KV blocks (paper's shell/
                bootup zeroing profile)
  checkpoint  — training with per-N-step checkpoint: async CoW snapshot vs
                blocking write (paper's process checkpointing)
  migrate     — slab rebalance via PSM vs freeing+recomputing the moved
                sequences (paper's page-migration application)

Readouts: wall-clock on this host, plus bytes-through-each-path derived
deltas (the quantity the paper's energy numbers are made of).
"""
from __future__ import annotations

from typing import Dict, List

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import RowCloneConfig, get_config
from repro.core.migration import execute as migrate_execute, plan_rebalance
from repro.launch.serve import ServingEngine
from repro.obs import metrics as obs_metrics
from repro.launch.train import train_loop
from repro.models import build_model, split_params


def _mk_engine(cfg, params, on: bool, max_seqs=16):
    rc = RowCloneConfig(enable_fpm=on, enable_psm=on, enable_zi=on)
    return ServingEngine(cfg, params, max_seqs=max_seqs, rc=rc)


def _forkbench(cfg, params, on: bool) -> Dict:
    eng = _mk_engine(cfg, params, on)
    rng = np.random.default_rng(0)
    with obs_metrics.Stopwatch() as sw:
        sid = eng.add_request(rng.integers(2, cfg.vocab_size,
                                           size=48).astype(np.int32))
        eng.fork(sid, 4)
        for _ in range(6):
            eng.decode_round()
    dt = sw.s
    s = eng.engine.stats
    return dict(wall_s=dt,
                bytes_compute=s.bytes_baseline,
                bytes_dma=s.bytes_fpm,
                bytes_avoided=s.bytes_avoided,
                tokens=6 * len(eng.cache.seqs))


def _buz_init(cfg, params, on: bool) -> Dict:
    eng = _mk_engine(cfg, params, on, max_seqs=32)
    with obs_metrics.Stopwatch() as sw:
        sids = []
        for i in range(24):
            sids.append(eng.cache.new_sequence(prompt_len=64))
        if not on:
            # baseline must materialize zeros for every fresh block
            pend = eng.engine.alloc.pending_zero(
                [b for s in sids for b in eng.cache.blocks_of(s)])
            eng.engine.materialize_zeros(pend)
    dt = sw.s
    s = eng.engine.stats
    nblk = sum(len(eng.cache.blocks_of(s_)) for s_ in sids)
    return dict(wall_s=dt, blocks=nblk,
                bytes_avoided=s.bytes_avoided,
                zero_lazy=s.zero_lazy, zero_mat=s.zero_materialized)


def _checkpoint(on: bool) -> Dict:
    import tempfile
    d = tempfile.mkdtemp()
    with obs_metrics.Stopwatch() as sw:
        train_loop("yi-6b", steps=12, batch=2, seq_len=64, smoke=True,
                   ckpt_dir=d, checkpoint_every=3, log_every=100)
    dt = sw.s
    return dict(wall_s=dt, checkpoints=4)


def _checkpoint_blocking() -> Dict:
    import tempfile

    from repro.checkpoint.manager import CheckpointManager
    orig = CheckpointManager.__init__

    def patched(self, directory, keep=3, async_save=True):
        orig(self, directory, keep=keep, async_save=False)

    CheckpointManager.__init__ = patched
    try:
        return _checkpoint(False)
    finally:
        CheckpointManager.__init__ = orig


def _migrate(cfg, params, on: bool) -> Dict:
    eng = _mk_engine(cfg, params, on)
    rng = np.random.default_rng(1)
    for _ in range(4):
        sid = eng.cache.new_sequence(prompt_len=64, prefer_slab=0)
        eng.engine.alloc.mark_written(eng.cache.blocks_of(sid))
    with obs_metrics.Stopwatch() as sw:
        plan = plan_rebalance(eng.cache)
        stats = migrate_execute(plan, eng.cache, chunk_blocks=8)
    dt = sw.s
    return dict(wall_s=dt, moved=stats["moved_blocks"],
                bytes_ici=eng.engine.stats.bytes_psm,
                bytes_compute=eng.engine.stats.bytes_baseline)


def run() -> List[Dict]:
    cfg = get_config("llama3.2-3b").reduced()
    model = build_model(cfg)
    params, _ = split_params(model.init_params(jax.random.key(0)))
    rows = []
    for name, fn in [("forkbench", _forkbench), ("buz-init", _buz_init),
                     ("migrate", _migrate)]:
        off = fn(cfg, params, False)
        on = fn(cfg, params, True)
        rows.append(dict(app=name, rowclone="off", **off))
        rows.append(dict(app=name, rowclone="on", **on))
        rows.append(dict(app=name, rowclone="speedup",
                         wall_s=off["wall_s"] / max(on["wall_s"], 1e-9)))
    off = _checkpoint_blocking()
    on = _checkpoint(True)
    rows.append(dict(app="checkpoint", rowclone="off", **off))
    rows.append(dict(app="checkpoint", rowclone="on", **on))
    rows.append(dict(app="checkpoint", rowclone="speedup",
                     wall_s=off["wall_s"] / max(on["wall_s"], 1e-9)))
    return rows
