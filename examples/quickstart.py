"""Quickstart: the RowClone engine in five minutes.

Builds a block pool, exercises memcopy/meminit dispatch (FPM / PSM / ZI),
forks a sequence CoW-style, and shows the stats the paper's Table 1 is made
of.  Runs on CPU in seconds.

    PYTHONPATH=src python examples/quickstart.py
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core import (BlockRef, PagedCoWCache, RowCloneEngine,
                        SubarrayAllocator)
from repro.core.migration import execute as migrate_execute, plan_rebalance


def main():
    page, kvh, hd = 16, 2, 64
    nblk, nslabs = 64, 4

    print("=== 1. pools + subarray-aware allocator ===")
    alloc = SubarrayAllocator(nblk, nslabs, reserved_zero_per_slab=1)
    pools = {"k": jnp.zeros((nblk, page, kvh, hd), jnp.bfloat16),
             "v": jnp.zeros((nblk, page, kvh, hd), jnp.bfloat16)}
    engine = RowCloneEngine(pools, alloc, max_requests=16)
    print(f"pool: {nblk} blocks x {page}tok, {nslabs} slabs "
          f"(reserved zero rows: {alloc.zero_rows})")
    # the engine's address space: per-pool block counts + base offsets
    print("address space: " + "  ".join(
        f"{s.name}[nblk={s.nblk} base={engine.group.base(s.name)}]"
        for s in engine.group))

    print("\n=== 2. memcopy dispatch: FPM vs PSM (BlockRef addressing) ===")
    src = alloc.alloc(2, prefer_slab=0)
    alloc.mark_written(src)
    engine.pools["k"] = engine.pools["k"].at[src[0]].set(1.0)
    dst_near = alloc.alloc_near(src[0])        # same slab -> FPM
    dst_far = alloc.alloc(1, prefer_slab=3)[0]  # cross slab -> PSM
    counts = engine.memcopy([
        (BlockRef("k", src[0]), BlockRef("k", dst_near)),
        (BlockRef("k", src[1]), BlockRef("k", dst_far)),
    ])   # a plain copy moves the block in EVERY primary pool (k AND v)
    print(f"dispatch: {counts}  "
          f"(bytes: fpm={engine.stats.bytes_fpm} psm={engine.stats.bytes_psm})")

    print("\n=== 3. meminit: BuZ + ZI lazy zero ===")
    fresh = alloc.alloc(4, prefer_slab=1)
    engine.meminit([BlockRef("k", b) for b in fresh])     # metadata only
    print(f"lazy-zeroed {len(fresh)} blocks; bytes avoided so far: "
          f"{engine.stats.bytes_avoided}")
    engine.materialize_zeros(fresh[:1])        # zero-row DMA when required
    print(f"materialized 1 block via the reserved zero row")

    print("\n=== 4. CoW fork (the paper's killer app) ===")
    cache = PagedCoWCache(engine, page, max_blocks_per_seq=8, max_seqs=8)
    sid = cache.new_sequence(prompt_len=3 * page // 2)   # 1.5 blocks
    alloc.mark_written(cache.blocks_of(sid))
    kids = cache.fork(sid, 3)
    print(f"forked seq {sid} -> {kids}: cow_shares={alloc.stats.cow_shares}, "
          f"bytes moved by fork: 0")
    blk, off = cache.append_token(kids[0])     # divergence -> CoW split
    print(f"child {kids[0]} appended at block {blk} slot {off}: "
          f"fpm_copies={engine.stats.fpm_copies} "
          f"(same-slab dst: {alloc.stats.fpm_eligible > 0})")

    print("\n=== 5. PSM migration (page-migration application) ===")
    for _ in range(2):
        s = cache.new_sequence(prompt_len=2 * page, prefer_slab=0)
        alloc.mark_written(cache.blocks_of(s))
    plan = plan_rebalance(cache)
    stats = migrate_execute(plan, cache)
    print(f"rebalanced: {stats}")

    print("\n=== engine stats ===")
    for k, v in vars(engine.stats).items():
        print(f"  {k:20s} {v}")


if __name__ == "__main__":
    main()
