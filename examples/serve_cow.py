"""Serving example: batched requests + parallel sampling via CoW fork.

Demonstrates the full RowClone serving story: admission (prefill staged into
the pool with FPM copies), fork-heavy parallel sampling (CoW shares, lazy
zeros), decode over the shared paged pool driven by the engine's
**CommandStream** (each round's bulk movement drains as one launch whose
FlushTicket is printed), and the engine stats that mirror the paper's
Table 1 / Fig 2 quantities.

    PYTHONPATH=src python examples/serve_cow.py --arch yi-6b --requests 4
"""
import argparse

import jax
import numpy as np

from repro.configs import get_config
from repro.core import BlockRef
from repro.launch.serve import ServingEngine
from repro.obs import metrics as obs_metrics
from repro.models import build_model, split_params


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="yi-6b")
    ap.add_argument("--requests", type=int, default=4)
    ap.add_argument("--samples-per-request", type=int, default=3)
    ap.add_argument("--prompt-len", type=int, default=48)
    ap.add_argument("--new-tokens", type=int, default=24)
    ap.add_argument("--staging-ring", type=int, default=4,
                    help="staging slots (max_admit_pages): a small ring "
                         "instead of full-size staging twins halves the "
                         "engine's resident pool bytes; 0 = full twin, "
                         "-1 = derive from the admission policy")
    ap.add_argument("--double-buffer", action="store_true",
                    help="double-buffered ring: admission bursts past "
                         "the ring capacity stay at 1.0 launches/round")
    args = ap.parse_args()

    cfg = get_config(args.arch).reduced()
    model = build_model(cfg)
    params, _ = split_params(model.init_params(jax.random.key(0)))
    eng = ServingEngine(cfg, params,
                        max_seqs=args.requests * (args.samples_per_request
                                                  + 1) + 2,
                        max_admit_pages=(None if args.staging_ring < 0
                                         else args.staging_ring),
                        double_buffer=args.double_buffer)
    g = eng.engine.group
    print("[serve] pool address space: " + "  ".join(
        f"{s.name}[nblk={s.nblk} base={g.base(s.name)}]" for s in g))
    print(f"[serve] resident pool bytes: "
          f"{eng.engine.pool_bytes_resident() / 1e6:.1f} MB "
          f"(staging ring: {eng.engine.stage_capacity} slots)")
    rng = np.random.default_rng(0)

    print(f"[serve] admitting {args.requests} prompts "
          f"({args.prompt_len} tokens each)")
    parents = []
    for _ in range(args.requests):
        p = rng.integers(2, cfg.vocab_size,
                         size=args.prompt_len).astype(np.int32)
        parents.append(eng.add_request(p))

    print(f"[serve] forking {args.samples_per_request} samples per prompt "
          f"(CoW: zero bytes move)")
    for sid in parents:
        eng.fork(sid, args.samples_per_request)
    a = eng.engine.alloc.stats
    print(f"         cow_shares={a.cow_shares} "
          f"fpm_copies={eng.engine.stats.fpm_copies}")

    # temperature sampling so forks diverge
    def sampler(logits):
        z = logits / 1.0
        z = z - z.max()
        p = np.exp(z) / np.exp(z).sum()
        return int(rng.choice(len(p), p=p))

    # keep only the tickets' COUNTERS: a retained ticket pins its
    # post-drain pool snapshot alive on backends without donation
    rounds = moved_rounds = total_cmds = max_launches = 0
    with obs_metrics.Stopwatch() as sw:
        for step in range(args.new_tokens):
            eng.decode_round(sample_fn=sampler)
            t = eng.last_ticket
            rounds += 1
            if t is not None and t.moved:
                moved_rounds += 1
                total_cmds += t.commands
                max_launches = max(max_launches, t.launches)
    dt = sw.s
    n = len(eng.cache.seqs)
    print(f"[serve] generated {args.new_tokens} tokens x {n} sequences in "
          f"{dt:.1f}s ({args.new_tokens * n / dt:.1f} tok/s on CPU)")
    print(f"[serve] stream '{eng.stream.name}': {rounds} round flushes, "
          f"{moved_rounds} moved bulk bytes ({total_cmds} commands, max "
          f"{max_launches} launch/round)")

    # explicit-stream coda: post-hoc bulk movement through a minted
    # stream — enqueue, flush, read the ticket's post-drain state
    demo = eng.engine.stream("demo")
    src = BlockRef("k", int(eng.cache.blocks_of(parents[0])[0]))
    spare = int(eng.engine.alloc.alloc(1)[0])   # a free block to copy into
    demo.memcopy([(src, BlockRef("k", spare))])
    ticket = demo.flush()
    blk = ticket.block_state(BlockRef("k", spare))
    print(f"[serve] demo stream flush: {ticket.commands} command(s), "
          f"{ticket.launches} launch(es), copied block shape {blk.shape}")

    s = eng.engine.stats
    a = eng.engine.alloc.stats
    print("\n=== RowClone effect (paper Fig.2 quantities) ===")
    print(f"  CoW shares (fork, 0 bytes):        {a.cow_shares}")
    print(f"  FPM copies (divergence CoW):        {s.fpm_copies}")
    print(f"  FPM same-slab placement hits:       {a.fpm_eligible}")
    print(f"  lazy-zeroed blocks (ZI):            {s.zero_lazy}")
    print(f"  bytes moved through compute:        {s.bytes_baseline}")
    print(f"  bytes moved by DMA (FPM):           {s.bytes_fpm}")
    print(f"  bytes avoided entirely (ZI+alias):  {s.bytes_avoided}")
    sample = parents[0]
    print(f"\nfirst prompt's sampled continuations (token ids):")
    kids = [sid for sid in eng.cache.seqs
            if eng.tokens[sid][:args.prompt_len] ==
            eng.tokens[sample][:args.prompt_len]]
    for sid in kids[:4]:
        print(f"  seq {sid}: {eng.tokens[sid][args.prompt_len:][:12]}...")


if __name__ == "__main__":
    main()
