"""End-to-end training driver: ~100M-param llama-family model, a few hundred
steps on the synthetic pipeline, with checkpointing, straggler ledger, and
one injected failure + automatic restart.

    PYTHONPATH=src python examples/train_e2e.py --steps 300

(Reduce --steps for a quick look; the model is sized ~100M params so a CPU
step takes a few seconds — the same driver scales to the production mesh
via launch/train.py + launch/mesh.py.)
"""
import argparse
import dataclasses
import tempfile

import jax
import numpy as np

from repro.configs import get_config
from repro.configs.registry import _REGISTRY
from repro.launch.train import train_loop
from repro.runtime import NodeFailure


def register_100m():
    """A ~100M llama-family config (registered once)."""
    if "llama-100m" in _REGISTRY:
        return
    base = get_config("llama3.2-3b")
    cfg = dataclasses.replace(
        base, arch_id="llama-100m", num_layers=8, d_model=640, num_heads=10,
        num_kv_heads=2, head_dim=64, d_ff=1792, vocab_size=32000,
        dtype="float32", tie_embeddings=True)
    _REGISTRY["llama-100m"] = cfg
    n = cfg.param_count()
    print(f"[e2e] registered llama-100m: {n/1e6:.1f}M params")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq-len", type=int, default=512)
    ap.add_argument("--fail-at", type=int, default=None,
                    help="inject a node failure at this step to demo restart")
    args = ap.parse_args()

    register_100m()
    ckpt_dir = tempfile.mkdtemp(prefix="rowclone_e2e_")
    print(f"[e2e] checkpoints -> {ckpt_dir}")

    fail_at = args.fail_at
    if fail_at is None and args.steps >= 100:
        fail_at = args.steps // 2  # demo the restart path by default

    try:
        state, losses = train_loop(
            "llama-100m", steps=args.steps, batch=args.batch,
            seq_len=args.seq_len, smoke=False, ckpt_dir=ckpt_dir,
            checkpoint_every=50, log_every=10, inject_failure_at=fail_at,
            learning_rate=1e-3)
    except NodeFailure as e:
        print(f"[e2e] {e} — restarting from checkpoint (fault-tolerance "
              f"path)")
        state, losses = train_loop(
            "llama-100m", steps=args.steps, batch=args.batch,
            seq_len=args.seq_len, smoke=False, ckpt_dir=ckpt_dir,
            checkpoint_every=50, log_every=10, learning_rate=1e-3)
    print(f"[e2e] done: loss {losses[0]:.3f} -> {losses[-1]:.3f} over "
          f"{len(losses)} steps (resumed runs replay identical data)")


if __name__ == "__main__":
    main()
