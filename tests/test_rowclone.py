"""RowClone core invariants: allocator, engine dispatch, CoW cache,
ZI lazy-zero, migration.  Hypothesis drives the stateful properties."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypo import given, settings, st

from repro.core import (PagedCoWCache, RowCloneEngine, SubarrayAllocator)
from repro.core.allocator import OutOfBlocks
from repro.core.migration import execute as migrate_execute, plan_rebalance


def make_engine(nblk=64, nslabs=4, page=8, KVH=2, D=16, **kw):
    alloc = SubarrayAllocator(nblk, nslabs, reserved_zero_per_slab=1)
    pools = {"k": jnp.zeros((nblk, page, KVH, D), jnp.float32),
             "v": jnp.zeros((nblk, page, KVH, D), jnp.float32)}
    return RowCloneEngine(pools, alloc, mesh=None, max_requests=16, **kw)


# ---------------------------------------------------------------------------
# allocator
# ---------------------------------------------------------------------------

def test_allocator_reserves_zero_rows():
    a = SubarrayAllocator(32, 4, reserved_zero_per_slab=1)
    assert len(a.zero_rows) == 4
    for z in a.zero_rows:
        assert a.refcount[z] == 1 and a.is_zero[z]
    assert a.total_free() == 32 - 4


def test_allocator_prefers_requested_slab():
    a = SubarrayAllocator(32, 4)
    ids = a.alloc(3, prefer_slab=2)
    assert all(a.slab_of(b) == 2 for b in ids)
    assert a.stats.fpm_eligible == 3


def test_allocator_falls_back_when_slab_full():
    a = SubarrayAllocator(16, 4)  # 3 usable per slab
    a.alloc(3, prefer_slab=1)
    more = a.alloc(1, prefer_slab=1)   # slab 1 exhausted
    assert a.slab_of(more[0]) != 1
    assert a.stats.psm_fallback == 1


def test_allocator_exhaustion_raises():
    a = SubarrayAllocator(8, 2)
    a.alloc(6)
    with pytest.raises(OutOfBlocks):
        a.alloc(1)


@settings(max_examples=30, deadline=None)
@given(st.lists(st.sampled_from(["alloc", "free", "share"]), min_size=1,
                max_size=40))
def test_allocator_refcount_invariants(ops):
    """Stateful property: refcounts never negative; free list + live +
    reserved always partitions the pool; shared blocks survive one free."""
    a = SubarrayAllocator(32, 4)
    live = []
    for op in ops:
        if op == "alloc" and a.total_free() > 0:
            live.extend(a.alloc(1))
        elif op == "free" and live:
            b = live.pop()
            a.free([b])
        elif op == "share" and live:
            b = live[0]
            a.share([b])
            live.append(b)
        assert (a.refcount >= 0).all()
        n_live_refs = int(a.refcount.sum()) - len(a.zero_rows)
        assert n_live_refs == len(live)
        assert a.total_free() + len(set(live)) + len(a.zero_rows) == 32


# ---------------------------------------------------------------------------
# engine dispatch
# ---------------------------------------------------------------------------

def test_engine_fpm_for_same_slab_psm_for_cross():
    eng = make_engine()
    a = eng.alloc
    s1 = a.alloc(2, prefer_slab=0)
    d1 = a.alloc(1, prefer_slab=0)
    d2 = a.alloc(1, prefer_slab=3)
    a.mark_written(s1)
    # write data
    eng.pools["k"] = eng.pools["k"].at[s1[0]].set(1.5)
    eng.pools["k"] = eng.pools["k"].at[s1[1]].set(2.5)
    counts = eng.memcopy([(s1[0], d1[0]), (s1[1], d2[0])])
    assert counts["fpm"] == 1 and counts["psm"] == 1
    assert float(eng.pools["k"][d1[0]].min()) == 1.5
    assert float(eng.pools["k"][d2[0]].min()) == 2.5


def test_engine_zi_alias_for_zero_source():
    """Copying a lazily-zero block moves no bytes (in-cache copy)."""
    eng = make_engine()
    src = eng.alloc.alloc(1, prefer_slab=0)[0]
    dst = eng.alloc.alloc(1, prefer_slab=0)[0]
    eng.meminit([src])              # lazy zero
    before = eng.stats.bytes_fpm + eng.stats.bytes_psm
    eng.memcopy([(src, dst)])
    assert eng.stats.alias_copies == 1
    assert eng.stats.bytes_fpm + eng.stats.bytes_psm == before
    assert eng.alloc.is_zero[dst]


def test_engine_disabled_rowclone_uses_baseline():
    eng = make_engine(enable_fpm=False, enable_psm=False, enable_zi=False)
    s = eng.alloc.alloc(1, prefer_slab=0)[0]
    d = eng.alloc.alloc(1, prefer_slab=0)[0]
    eng.pools["k"] = eng.pools["k"].at[s].set(3.0)
    eng.alloc.mark_written([s])
    eng.memcopy([(s, d)])
    assert eng.stats.baseline_copies == 1
    assert eng.stats.fpm_copies == 0
    assert float(eng.pools["k"][d].min()) == 3.0


def test_engine_meminit_materialize():
    eng = make_engine()
    b = eng.alloc.alloc(1)[0]
    eng.pools["k"] = eng.pools["k"].at[b].set(7.0)
    eng.meminit([b])                      # lazy
    assert float(eng.pools["k"][b].max()) == 7.0  # bytes untouched
    eng.materialize_zeros([b])
    assert float(jnp.abs(eng.pools["k"][b]).max()) == 0.0


# ---------------------------------------------------------------------------
# CoW cache semantics
# ---------------------------------------------------------------------------

def make_cache(**kw):
    eng = make_engine(nblk=64, nslabs=4, page=8, **kw)
    return PagedCoWCache(eng, page=8, max_blocks_per_seq=8, max_seqs=8), eng


def test_fork_shares_then_cow_splits():
    cache, eng = make_cache()
    sid = cache.new_sequence(prompt_len=12)   # mid-block position
    blocks = cache.blocks_of(sid)
    kdata = jax.random.normal(jax.random.key(0), (len(blocks), 8, 2, 16))
    for j, b in enumerate(blocks):
        eng.pools["k"] = eng.pools["k"].at[b].set(kdata[j])
    eng.alloc.mark_written(blocks)

    child, = cache.fork(sid, 1)
    assert cache.blocks_of(child) == blocks
    assert eng.stats.fpm_copies == 0          # fork is free

    b_id, off = cache.append_token(child)
    assert off == 4
    assert b_id != blocks[1]                  # CoW split happened
    assert eng.stats.fpm_copies == 1          # via FPM (same slab)
    assert eng.alloc.slab_of(b_id) == eng.alloc.slab_of(blocks[1])
    np.testing.assert_allclose(np.asarray(eng.pools["k"][b_id]),
                               np.asarray(eng.pools["k"][blocks[1]]))
    # parent untouched
    assert cache.blocks_of(sid) == blocks
    assert eng.alloc.refcount[blocks[1]] == 1


def test_parent_append_after_fork_also_cows():
    cache, eng = make_cache()
    sid = cache.new_sequence(prompt_len=4)
    cache.fork(sid, 2)
    b_id, _ = cache.append_token(sid)  # parent writes shared block -> CoW
    assert eng.stats.fpm_copies + eng.stats.alias_copies == 1
    for kid in [s for s in cache.seqs if s != sid]:
        assert cache.blocks_of(kid)[0] != b_id


def test_free_sequence_releases_blocks():
    cache, eng = make_cache()
    sid = cache.new_sequence(prompt_len=16)
    child, = cache.fork(sid, 1)
    free0 = eng.alloc.total_free()
    cache.free_sequence(sid)
    assert eng.alloc.total_free() == free0    # child still holds them
    cache.free_sequence(child)
    assert eng.alloc.total_free() == free0 + 2


def test_device_tables_reflect_sharing():
    cache, eng = make_cache()
    sid = cache.new_sequence(prompt_len=8)
    kids = cache.fork(sid, 2)
    table, mask, base = cache.device_tables()
    b = cache.blocks_of(sid)[0]
    cols = [cache.slot_of(s) for s in (sid, *kids)]
    for c in cols:
        assert int(mask[b, c]) == 1
    assert int(np.asarray(mask[b]).sum()) == 3


@settings(max_examples=15, deadline=None)
@given(st.lists(st.sampled_from(["new", "fork", "append", "free"]),
                min_size=1, max_size=30))
def test_cache_stateful_property(ops):
    """Random op sequences keep: table/mask consistency, refcount = number
    of sequences referencing each block, no leaks after freeing all."""
    cache, eng = make_cache()
    rng = np.random.default_rng(0)
    for op in ops:
        sids = sorted(cache.seqs)
        try:
            if op == "new":
                if len(sids) < cache.max_seqs and eng.alloc.total_free() > 2:
                    cache.new_sequence(prompt_len=int(rng.integers(1, 20)))
            elif op == "fork" and sids and len(sids) < cache.max_seqs:
                cache.fork(int(rng.choice(sids)), 1)
            elif op == "append" and sids:
                cache.append_token(int(rng.choice(sids)))
            elif op == "free" and sids:
                cache.free_sequence(int(rng.choice(sids)))
        except OutOfBlocks:
            continue
        # invariant: refcount of every block = #sequences holding it
        counts = {}
        for s in cache.seqs.values():
            for b in s.blocks:
                counts[b] = counts.get(b, 0) + 1
        for b, c in counts.items():
            assert eng.alloc.refcount[b] == c, (b, c)
    for s in sorted(cache.seqs):
        cache.free_sequence(s)
    assert eng.alloc.total_free() == \
        eng.alloc.num_blocks - len(eng.alloc.zero_rows)


# ---------------------------------------------------------------------------
# migration (PSM application)
# ---------------------------------------------------------------------------

def test_migration_rebalances_and_preserves_content():
    cache, eng = make_cache()
    # overload slab 0 with 3 sequences
    sids = [cache.new_sequence(prompt_len=16, prefer_slab=0)
            for _ in range(3)]
    data = {}
    for sid in sids:
        for b in cache.blocks_of(sid):
            val = float(b) + 0.5
            eng.pools["k"] = eng.pools["k"].at[b].set(val)
            data[(sid, cache.blocks_of(sid).index(b))] = val
        eng.alloc.mark_written(cache.blocks_of(sid))
    plan = plan_rebalance(cache)
    assert plan.moves, "expected migration moves"
    migrate_execute(plan, cache)
    assert eng.stats.psm_copies > 0
    # content preserved under new ids
    for (sid, j), val in data.items():
        nb = cache.blocks_of(sid)[j]
        assert float(eng.pools["k"][nb].min()) == val
    # load is better balanced
    used = np.zeros(4, int)
    for s in cache.seqs.values():
        for b in s.blocks:
            used[eng.alloc.slab_of(b)] += 1
    assert used.max() - used.min() <= 3
