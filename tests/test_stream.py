"""CommandStream / FlushTicket unit suite.

The API-redesign contract: ``engine.stream()`` mints ordered streams whose
commands drain only at ``stream.flush()`` (returning a FlushTicket with
launch accounting and on-demand post-drain block state); the seed surface
(``memcopy`` flush-on-return, ``batch()``, ``flush()``) is a thin wrapper
over the engine's default stream; streams serialize against each other
only when they touch the same ``(pool, block)``; and the queue's
source-hazard tracking (WAR admitted + spaced, not flushed) keeps the
overlapped fused drain bitwise-equal to the seed fan-out.
"""
import jax
import numpy as np
import pytest

from repro.core import (BlockRef, CommandStream, FlushTicket, RowCloneEngine,
                        SubarrayAllocator)
from repro.core.cmdqueue import (ALL_PRIMARY, OP_FPM_COPY, OP_NOP,
                                 OP_ZERO_INIT, space_war_rows)
from repro.kernels import fused_dispatch as fd


def mk_engine(use_fused=True, seed=0, nblk=32, snblk=8):
    alloc = SubarrayAllocator(nblk, 4, reserved_zero_per_slab=1)
    pools = {
        "k": jax.random.normal(jax.random.key(seed), (nblk, 4, 8)),
        "v": jax.random.normal(jax.random.key(seed + 1), (nblk, 4, 8)),
        "k_stage": jax.random.normal(jax.random.key(seed + 2), (snblk, 4, 8)),
        "v_stage": jax.random.normal(jax.random.key(seed + 3), (snblk, 4, 8)),
    }
    return RowCloneEngine(pools, alloc, max_requests=64, use_fused=use_fused,
                          staging={"k_stage": "k", "v_stage": "v"})


class Hook:
    def __enter__(self):
        self.mechs = []
        self._fn = lambda n, p, m: self.mechs.append(m)
        fd.add_launch_hook(self._fn)
        return self.mechs

    def __exit__(self, *exc):
        fd.remove_launch_hook(self._fn)


# ---------------------------------------------------------------------------
# stream lifecycle + tickets
# ---------------------------------------------------------------------------

def test_stream_defers_until_flush_and_tickets_account():
    """Commands on a minted stream never hit the device until flush();
    the ticket reports drained commands, launches, and sequences."""
    eng = mk_engine()
    eng.alloc.mark_written([1, 2])
    s = eng.stream("work")
    with Hook() as mechs:
        s.memcopy([(1, 5)])
        s.materialize_zeros([9])
        s.memcopy_cross([(BlockRef("k_stage", 2), BlockRef("k", 11))])
        assert mechs == []              # nothing launched yet
        assert len(s) == 3
        t = s.flush()
    assert mechs == ["fused"]
    assert isinstance(t, FlushTicket)
    assert (t.stream, t.seq, t.commands, t.launches) == ("work", 0, 3, 1)
    assert t.moved
    t2 = s.flush()                       # empty flush: a real ticket, no work
    assert t2.seq == 1 and t2.commands == 0 and not t2.moved


def test_ticket_block_state_on_demand():
    """block_state fetches post-drain bytes: a BlockRef returns one pool's
    block, a bare int returns the block across every primary pool."""
    eng = mk_engine(seed=4)
    eng.alloc.mark_written([3])
    want_k = np.asarray(eng.pools["k"][3])
    want_v = np.asarray(eng.pools["v"][3])
    s = eng.stream()
    s.memcopy([(3, 7)])
    t = s.flush().wait()
    np.testing.assert_array_equal(t.block_state(BlockRef("k", 7)), want_k)
    d = t.block_state(7)
    assert set(d) == {"k", "v"}
    np.testing.assert_array_equal(d["v"], want_v)


def test_ticket_expires_when_later_flush_donates():
    """The dispatch paths donate pool buffers, so a ticket's block state
    is readable until the NEXT flush — after that, expired turns True
    and reads raise a descriptive error (metadata survives)."""
    eng = mk_engine(seed=5)
    eng.alloc.mark_written([1, 2])
    s = eng.stream()
    s.memcopy([(1, 5)])
    t1 = s.flush()
    assert not t1.expired
    t1.block_state(BlockRef("k", 5))     # readable before the next flush
    s.memcopy([(2, 6)])
    s.flush()
    assert t1.expired
    with pytest.raises(RuntimeError, match="expired"):
        t1.block_state(BlockRef("k", 5))
    with pytest.raises(RuntimeError, match="expired"):
        t1.wait()
    assert t1.launches == 1 and t1.commands == 1


def test_engine_flush_inside_capture_targets_default_queue():
    """engine.flush() is the seed-compat barrier on the DEFAULT stream:
    calling it inside a capture must not split the capturing stream's
    launch."""
    eng = mk_engine(seed=7)
    eng.alloc.mark_written([1])
    s = eng.stream("round")
    with s.capture():
        eng.memcopy([(1, 5)])
        assert eng.flush() == 0          # captured commands stay queued
        assert len(s) == 1
    assert s.flush().launches == 1


def test_engine_surface_wraps_default_stream():
    """Seed semantics survive: engine.memcopy flushes on return through
    the default stream; batch() defers to one launch; engine.flush()
    drains the default queue and returns the launch count."""
    eng = mk_engine(seed=2)
    eng.alloc.mark_written([1, 2])
    with Hook() as mechs:
        eng.memcopy([(1, 5)])           # eager: one launch on return
    assert mechs == ["fused"]
    assert eng.queue is eng.default_stream.queue
    with Hook() as mechs, eng.batch():
        eng.memcopy([(2, 6)])
        eng.materialize_zeros([8])
        assert mechs == []
    assert mechs == ["fused"]
    assert eng.flush() == 0             # drained at batch exit


def test_streams_flush_independently():
    """Two streams on disjoint blocks drain on their own schedules — no
    global barrier."""
    eng = mk_engine(seed=6)
    eng.alloc.mark_written([1, 2])
    a, b = eng.stream("a"), eng.stream("b")
    a.memcopy([(1, 5)])
    b.memcopy([(2, 9)])
    ta = a.flush()
    assert ta.launches == 1 and len(b) == 1   # b untouched by a's flush
    tb = b.flush()
    assert tb.launches == 1
    assert eng.stats.cross_stream_flushes == 0


# ---------------------------------------------------------------------------
# cross-stream hazards
# ---------------------------------------------------------------------------

def test_cross_stream_conflict_serializes_writer_first():
    """Reading a block another stream will write drains that stream
    first, so the read observes the earlier stream's bytes."""
    eng = mk_engine(seed=8)
    eng.alloc.mark_written([3])
    w, r = eng.stream("writer"), eng.stream("reader")
    w.memcopy([(3, 8)])
    r.memcopy([(8, 10)])                 # reads writer's pending dst 8
    assert eng.stats.cross_stream_flushes == 1
    assert len(w) == 0 and len(r) == 1   # writer drained, reader pending
    r.flush()
    np.testing.assert_array_equal(np.asarray(eng.pools["k"][10]),
                                  np.asarray(eng.pools["k"][3]))


def test_cross_stream_war_serializes_reader_first():
    """Writing a block another stream will READ drains the reader first
    (its gather must see the old bytes)."""
    eng = mk_engine(seed=10)
    eng.alloc.mark_written([4, 6])
    old4 = np.asarray(eng.pools["k"][4])
    rd, wr = eng.stream("rd"), eng.stream("wr")
    rd.memcopy([(4, 12)])
    wr.memcopy([(6, 4)])                 # overwrites rd's pending source
    assert eng.stats.cross_stream_flushes == 1
    assert len(rd) == 0                  # reader drained before the write
    wr.flush()
    np.testing.assert_array_equal(np.asarray(eng.pools["k"][12]), old4)


def test_cross_stream_raf_does_not_serialize():
    """Two streams READING one block (RAR) stay independent."""
    eng = mk_engine(seed=12)
    eng.alloc.mark_written([5])
    a, b = eng.stream(), eng.stream()
    a.memcopy([(5, 11)])
    b.memcopy([(5, 13)])
    assert eng.stats.cross_stream_flushes == 0
    assert len(a) == 1 and len(b) == 1
    a.flush(), b.flush()


# ---------------------------------------------------------------------------
# source-hazard tracking + overlap spacing
# ---------------------------------------------------------------------------

def test_war_on_source_admitted_and_spaced():
    """A WAR pair shares one flush (no hazard flush), is counted, and the
    flushed table carries a spacer row for the overlapped drain —
    bitwise-identical to the seed fan-out."""
    fused, legacy = mk_engine(seed=14), mk_engine(seed=14, use_fused=False)
    for eng in (fused, legacy):
        eng.alloc.mark_written([2, 7])
        with Hook() as mechs, eng.batch():
            eng.memcopy([(2, 5), (7, 2)])    # (7, 2) rewrites source 2
        assert eng.queue.stats.hazard_flushes == 0
        assert eng.queue.stats.war_hazards == 1
        assert eng.queue.stats.spacer_rows == 1
        if eng.use_fused:
            assert mechs == ["fused"]    # the pair shares ONE launch
    for n in fused.pools:
        np.testing.assert_array_equal(np.asarray(fused.pools[n]),
                                      np.asarray(legacy.pools[n]),
                                      err_msg=n)


def test_pending_read_write_introspection():
    """has_pending_read/has_pending_write expose the tracked hazard keys,
    including cross-pool staging reads."""
    eng = mk_engine(seed=16)
    eng.alloc.mark_written([1])
    q = eng.queue
    with eng.batch():
        eng.memcopy([(1, 5)])
        eng.memcopy_cross([(BlockRef("k_stage", 3), BlockRef("k", 9))])
        ks = eng.group.index("k_stage")
        assert q.has_pending_read((ALL_PRIMARY, 1))
        assert q.has_pending_write((ALL_PRIMARY, 5))
        assert q.has_pending_read((ks, 3))
        assert not q.has_pending_read((ks, 2))
        assert not q.has_pending_write((ALL_PRIMARY, 1))
    assert not q.has_pending_read((ALL_PRIMARY, 1))   # cleared by flush


def test_space_war_rows_unit():
    """The spacer pass inserts exactly one NOP between an adjacent WAR
    pair and leaves independent neighbours alone."""
    locate = lambda gid: (0, gid)      # single-pool decode
    primary = (True,)
    rows = [(OP_FPM_COPY, 2, 5), (OP_FPM_COPY, 7, 2),   # WAR: adjacent
            (OP_FPM_COPY, 9, 11),                        # independent
            (OP_ZERO_INIT, -1, 9)]                       # WAR on 9: spaced
    spaced = space_war_rows(rows, locate, primary)
    assert spaced == [(OP_FPM_COPY, 2, 5), (OP_NOP, -1, -1),
                      (OP_FPM_COPY, 7, 2), (OP_FPM_COPY, 9, 11),
                      (OP_NOP, -1, -1), (OP_ZERO_INIT, -1, 9)]
    # already-spaced input is a fixed point
    assert space_war_rows(spaced, locate, primary) == spaced


# ---------------------------------------------------------------------------
# two-source bitwise hazard matrix — OP_AND/OP_OR/OP_NOT rows read TWO
# blocks (srcB packed into the src field), and every hazard rule must
# apply to EITHER source
# ---------------------------------------------------------------------------

def _u32(x):
    """Uint bit view — bitwise results on float pools must be compared
    to the exact bit, not through float equality."""
    return np.ascontiguousarray(np.asarray(x)).view(np.uint32)


def test_bitwise_raw_on_srcb_autoflushes():
    """A bitwise row whose SECOND source reads a pending destination is a
    RAW hazard: the queue flushes the earlier write before admitting it,
    so the AND gathers the copied bytes."""
    eng = mk_engine(seed=20)
    eng.alloc.mark_written([1, 2])
    with Hook() as mechs, eng.batch():
        eng.memcopy([(1, 5)])            # pending write on 5
        eng.memand([(2, 5, 9)])          # srcB = 5 -> auto-flush first
        assert eng.queue.stats.hazard_flushes == 1
        assert mechs == ["fused"]        # the copy drained early
    want = _u32(eng.pools["k"][2]) & _u32(eng.pools["k"][1])
    np.testing.assert_array_equal(_u32(eng.pools["k"][9]), want)


def test_bitwise_waw_on_dst_autoflushes():
    """Rewriting a bitwise row's pending destination is a WAW hazard —
    the compute row must land before the overwrite."""
    eng = mk_engine(seed=21)
    eng.alloc.mark_written([1, 2, 3])
    with eng.batch():
        eng.memor([(1, 2, 9)])
        eng.memcopy([(3, 9)])            # WAW on the OR's dst
        assert eng.queue.stats.hazard_flushes == 1
    np.testing.assert_array_equal(_u32(eng.pools["k"][9]),
                                  _u32(eng.pools["k"][3]))


def test_bitwise_war_on_srcb_admitted_and_spaced():
    """Rewriting a bitwise row's srcB in the same stream is WAR: admitted
    without a flush, counted, spaced for the overlapped drain — and the
    AND reads the OLD bytes on both dispatch paths, bitwise."""
    fused, legacy = mk_engine(seed=22), mk_engine(seed=22, use_fused=False)
    old2 = _u32(fused.pools["k"][2]).copy()
    old3 = _u32(fused.pools["k"][3]).copy()
    for eng in (fused, legacy):
        eng.alloc.mark_written([2, 3, 7])
        with Hook() as mechs, eng.batch():
            eng.memand([(2, 3, 9)])
            eng.memcopy([(7, 3)])        # rewrites srcB 3: WAR, admitted
        assert eng.queue.stats.hazard_flushes == 0
        assert eng.queue.stats.war_hazards == 1
        assert eng.queue.stats.spacer_rows >= 1
        if eng.use_fused:
            assert mechs == ["fused"]    # the pair shares ONE launch
    assert fused.queue.stats.spacer_rows == legacy.queue.stats.spacer_rows
    np.testing.assert_array_equal(_u32(fused.pools["k"][9]), old2 & old3)
    for n in fused.pools:
        np.testing.assert_array_equal(_u32(fused.pools[n]),
                                      _u32(legacy.pools[n]), err_msg=n)


def test_cross_stream_conflict_on_srcb_drains_other_stream():
    """Cross-stream hazards see both sources: a bitwise enqueue whose
    srcB another stream will WRITE drains the writer first (the gather
    must observe its bytes), and a write to a block a bitwise stream
    will READ drains the reader first (its gather must see the old
    bytes)."""
    eng = mk_engine(seed=24)
    eng.alloc.mark_written([3, 4])
    w, c = eng.stream("w"), eng.stream("c")
    w.memcopy([(3, 8)])
    c.memand([(4, 8, 12)])               # srcB 8 pending in w -> w drains
    assert eng.stats.cross_stream_flushes == 1
    assert len(w) == 0 and len(c) == 2   # two fanned rows still pending
    c.flush()
    want = _u32(eng.pools["k"][4]) & _u32(eng.pools["k"][3])
    np.testing.assert_array_equal(_u32(eng.pools["k"][12]), want)
    # WAR direction: a writer stream touching a pending bitwise SOURCE
    eng.alloc.mark_written([5, 6])
    r, w2 = eng.stream("r"), eng.stream("w2")
    r.memor([(5, 6, 14)])
    old6 = _u32(eng.pools["k"][6]).copy()
    w2.memcopy([(3, 6)])                 # rewrites r's pending srcB 6
    assert eng.stats.cross_stream_flushes == 2
    assert len(r) == 0                   # reader drained before the write
    w2.flush()
    np.testing.assert_array_equal(_u32(eng.pools["k"][14]),
                                  _u32(eng.pools["k"][5]) | old6)


def test_retire_bitwise_row_rebuilds_both_source_sets():
    """retire() of a queued two-source row rebuilds BOTH pending-source
    sets from the survivors — a stale srcB entry would pin staging slots
    (or trip later hazard checks) forever."""
    eng = mk_engine(seed=26)
    eng.alloc.mark_written([2, 3])
    s = eng.stream("bit")
    s.memand([(2, 3, 9)])                # fans out: one row per primary
    q = s.queue
    ki, vi = eng.group.index("k"), eng.group.index("v")
    for pi in (ki, vi):
        assert q.has_pending_read((pi, 2)) and q.has_pending_read((pi, 3))
        assert q.has_pending_write((pi, 9))
    locate = eng.group.locate
    k_row = [row for row in q.pending if locate(row[2])[0] == ki]
    assert len(k_row) == 1
    assert q.retire(k_row) == 1
    # the k row's reads AND write are gone; the v row's survive intact
    assert not q.has_pending_read((ki, 2))
    assert not q.has_pending_read((ki, 3))
    assert not q.has_pending_write((ki, 9))
    assert q.has_pending_read((vi, 2)) and q.has_pending_read((vi, 3))
    assert q.has_pending_write((vi, 9))
    assert q.stats.retired == 1
    old_k9 = _u32(eng.pools["k"][9]).copy()
    t = s.flush()
    assert t.commands == 1               # only the surviving v row drained
    np.testing.assert_array_equal(_u32(eng.pools["k"][9]), old_k9)
    np.testing.assert_array_equal(
        _u32(eng.pools["v"][9]),
        _u32(eng.pools["v"][2]) & _u32(eng.pools["v"][3]))


def test_stage_slots_guarded_by_pending_reads():
    """A staging slot whose promotion is queued on one stream stays out
    of the free list while OTHER streams flush; it recycles only when
    its own stream drains the pending read."""
    eng = mk_engine(seed=18)
    serve, other = eng.stream("serve"), eng.stream("other")
    eng.alloc.mark_written([1])
    slots = eng.stage_blocks(2)
    serve.promote_staged([(slots[0], 4), (slots[1], 6)])
    other.memcopy([(1, 9)])
    other.flush()                        # unrelated flush: slots still held
    assert all(s not in eng._stage_free for s in slots)
    serve.flush()
    assert all(s in eng._stage_free for s in slots)


def test_minting_streams_is_free():
    """The engine tracks only queues with PENDING work: minting many
    short-lived streams (a stream per request) leaves no registry
    growth, so per-enqueue guard cost stays bounded."""
    eng = mk_engine(seed=24)
    eng.alloc.mark_written([1])
    for i in range(50):
        s = eng.stream()
        s.memcopy([(1, 5)])
        assert len(eng._live_queues) >= 1
        s.flush()
    assert eng._live_queues == {}        # every drained queue dropped
    # a queue re-enters the live set on its next enqueue
    eng.memcopy([(1, 6)])                # eager default stream: in + out
    assert eng._live_queues == {}


def test_memcopy_cross_int_shim_is_gone():
    """The deprecated (pairs, src_pool, dst_pool) form no longer exists —
    BlockRef pairs are the only calling convention."""
    eng = mk_engine(seed=20)
    with pytest.raises(TypeError):
        eng.memcopy_cross([(1, 2)], "k", "v")
    with pytest.raises(TypeError):
        eng.memcopy_cross([(1, 2)])


def test_stream_capture_routes_engine_calls():
    """capture() redirects public engine calls onto the stream: the
    serving engine's pattern (cache-driven CoW work riding the round
    stream)."""
    eng = mk_engine(seed=22)
    eng.alloc.mark_written([2])
    s = eng.stream("round")
    with Hook() as mechs:
        with s.capture():
            eng.memcopy([(2, 6)])        # would flush eagerly outside
            eng.materialize_zeros([11])
        assert mechs == [] and len(s) == 2   # copy + zero land on stream
        t = s.flush()
    assert mechs == ["fused"] and t.commands == 2
