"""Multi-device semantics tested in a subprocess with 8 forced host devices
(jax locks the device count at first init, so the main pytest process stays
single-device)."""
import json
import os
import subprocess
import sys

import pytest

SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import json
import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs import get_config
from repro.models import build_model, split_params
from repro.models.common import rms_norm
from repro.launch.mesh import make_test_mesh, tree_shardings, sharding_for

results = {}
mesh = make_test_mesh((2, 2, 2), ("pod", "data", "model"))
results["n_devices"] = len(jax.devices())

# 1) sharded decode == single-device decode for a dense arch
cfg = get_config("yi-6b").reduced()
model = build_model(cfg)
params, axes = split_params(model.init_params(jax.random.key(0)))
B, S = 4, 64
tokens = jax.random.randint(jax.random.key(1), (B, S + 1), 0,
                            cfg.vocab_size)

# single-device reference
_, st_ref = model.prefill(params, {"tokens": tokens[:, :S]}, None)
lg_ref, _ = model.decode_step(params, st_ref, tokens[:, S], None)

# sharded: state built for the mesh, decode under the mesh
with mesh:
    st = model.make_serve_state(B, S + 64, mesh, filled=S)
    # fill pools from the reference state (identity layout, same nper)
    nper_ref = st_ref["k_pools"].shape[1] // B
    nper = st["k_pools"].shape[1] // B
    kp = np.zeros(st["k_pools"].shape, np.float32)
    vp = np.zeros(st["v_pools"].shape, np.float32)
    for b in range(B):
        for j in range(nper_ref):
            kp[:, b * nper + j] = np.asarray(st_ref["k_pools"][:, b * nper_ref + j])
            vp[:, b * nper + j] = np.asarray(st_ref["v_pools"][:, b * nper_ref + j])
    st["k_pools"] = jnp.asarray(kp)
    st["v_pools"] = jnp.asarray(vp)
    st_ax = model.state_logical_axes(st)
    st_sh = {k: sharding_for(mesh, v.shape, st_ax[k]) for k, v in st.items()}
    st = {k: jax.device_put(v, st_sh[k]) for k, v in st.items()}
    p_sh = tree_shardings(mesh, params, axes)
    params_d = jax.tree_util.tree_map(jax.device_put, params, p_sh)
    lg, st2 = jax.jit(
        lambda p, s, t: model.decode_step(p, s, t, mesh))(
            params_d, st, tokens[:, S])
results["decode_err"] = float(jnp.max(jnp.abs(lg - lg_ref)))

# 2) sharded train loss == single-device loss
batch = {
    "tokens": tokens[:, :S],
    "labels": tokens[:, 1:S + 1],
    "mask": jnp.ones((B, S), jnp.float32),
}
loss_ref, _ = model.loss_fn(params, batch, None)
with mesh:
    ba = {"tokens": ("batch", None), "labels": ("batch", None),
          "mask": ("batch", None)}
    b_sh = {k: sharding_for(mesh, v.shape, ba[k]) for k, v in batch.items()}
    batch_d = {k: jax.device_put(v, b_sh[k]) for k, v in batch.items()}
    loss_sh, _ = jax.jit(lambda p, b: model.loss_fn(p, b, mesh))(
        params_d, batch_d)
results["train_loss_err"] = abs(float(loss_sh) - float(loss_ref))

# 3) fault path: elastic remesh to 4 devices reproduces loss too
mesh2 = make_test_mesh((2, 2), ("data", "model"))
with mesh2:
    p_sh2 = tree_shardings(mesh2, params, axes)
    params_d2 = jax.tree_util.tree_map(jax.device_put, params, p_sh2)
    b_sh2 = {k: sharding_for(mesh2, v.shape, ba[k]) for k, v in batch.items()}
    batch_d2 = {k: jax.device_put(v, b_sh2[k]) for k, v in batch.items()}
    loss_sh2, _ = jax.jit(lambda p, b: model.loss_fn(p, b, mesh2))(
        params_d2, batch_d2)
results["elastic_loss_err"] = abs(float(loss_sh2) - float(loss_ref))

print("RESULTS:" + json.dumps(results))
"""


@pytest.mark.slow
def test_sharded_execution_matches_single_device(tmp_path):
    script = tmp_path / "multidev.py"
    script.write_text(SCRIPT)
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.abspath(
        os.path.join(os.path.dirname(__file__), "..", "src"))
    env.pop("JAX_PLATFORMS", None)
    env["JAX_PLATFORMS"] = "cpu"
    out = subprocess.run([sys.executable, str(script)], env=env,
                         capture_output=True, text=True, timeout=1200)
    assert out.returncode == 0, out.stderr[-3000:]
    line = [l for l in out.stdout.splitlines() if l.startswith("RESULTS:")]
    assert line, out.stdout
    res = json.loads(line[0][len("RESULTS:"):])
    assert res["n_devices"] == 8
    assert res["decode_err"] < 5e-2, res      # bf16 pools
    assert res["train_loss_err"] < 5e-3, res
    assert res["elastic_loss_err"] < 5e-3, res
