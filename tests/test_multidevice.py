"""Multi-device semantics tested in a subprocess with 8 forced host devices
(jax locks the device count at first init, so the main pytest process stays
single-device)."""
import pytest

from _meshproc import run_device_subprocess

SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import json
import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs import get_config
from repro.models import build_model, split_params
from repro.models.common import rms_norm
from repro.launch.mesh import make_test_mesh, tree_shardings, sharding_for

results = {}
mesh = make_test_mesh((2, 2, 2), ("pod", "data", "model"))
results["n_devices"] = len(jax.devices())

# 1) sharded decode == single-device decode for a dense arch
cfg = get_config("yi-6b").reduced()
model = build_model(cfg)
params, axes = split_params(model.init_params(jax.random.key(0)))
B, S = 4, 64
tokens = jax.random.randint(jax.random.key(1), (B, S + 1), 0,
                            cfg.vocab_size)

# single-device reference
_, st_ref = model.prefill(params, {"tokens": tokens[:, :S]}, None)
lg_ref, _ = model.decode_step(params, st_ref, tokens[:, S], None)

# sharded: state built for the mesh, decode under the mesh
with mesh:
    st = model.make_serve_state(B, S + 64, mesh, filled=S)
    # fill pools from the reference state (identity layout, same nper)
    nper_ref = st_ref["k_pools"].shape[1] // B
    nper = st["k_pools"].shape[1] // B
    kp = np.zeros(st["k_pools"].shape, np.float32)
    vp = np.zeros(st["v_pools"].shape, np.float32)
    for b in range(B):
        for j in range(nper_ref):
            kp[:, b * nper + j] = np.asarray(st_ref["k_pools"][:, b * nper_ref + j])
            vp[:, b * nper + j] = np.asarray(st_ref["v_pools"][:, b * nper_ref + j])
    st["k_pools"] = jnp.asarray(kp)
    st["v_pools"] = jnp.asarray(vp)
    st_ax = model.state_logical_axes(st)
    st_sh = {k: sharding_for(mesh, v.shape, st_ax[k]) for k, v in st.items()}
    st = {k: jax.device_put(v, st_sh[k]) for k, v in st.items()}
    p_sh = tree_shardings(mesh, params, axes)
    params_d = jax.tree_util.tree_map(jax.device_put, params, p_sh)
    lg, st2 = jax.jit(
        lambda p, s, t: model.decode_step(p, s, t, mesh))(
            params_d, st, tokens[:, S])
results["decode_err"] = float(jnp.max(jnp.abs(lg - lg_ref)))

# 2) sharded train loss == single-device loss
batch = {
    "tokens": tokens[:, :S],
    "labels": tokens[:, 1:S + 1],
    "mask": jnp.ones((B, S), jnp.float32),
}
loss_ref, _ = model.loss_fn(params, batch, None)
with mesh:
    ba = {"tokens": ("batch", None), "labels": ("batch", None),
          "mask": ("batch", None)}
    b_sh = {k: sharding_for(mesh, v.shape, ba[k]) for k, v in batch.items()}
    batch_d = {k: jax.device_put(v, b_sh[k]) for k, v in batch.items()}
    loss_sh, _ = jax.jit(lambda p, b: model.loss_fn(p, b, mesh))(
        params_d, batch_d)
results["train_loss_err"] = abs(float(loss_sh) - float(loss_ref))

# 3) fault path: elastic remesh to 4 devices reproduces loss too
mesh2 = make_test_mesh((2, 2), ("data", "model"))
with mesh2:
    p_sh2 = tree_shardings(mesh2, params, axes)
    params_d2 = jax.tree_util.tree_map(jax.device_put, params, p_sh2)
    b_sh2 = {k: sharding_for(mesh2, v.shape, ba[k]) for k, v in batch.items()}
    batch_d2 = {k: jax.device_put(v, b_sh2[k]) for k, v in batch.items()}
    loss_sh2, _ = jax.jit(lambda p, b: model.loss_fn(p, b, mesh2))(
        params_d2, batch_d2)
results["elastic_loss_err"] = abs(float(loss_sh2) - float(loss_ref))

print("RESULTS:" + json.dumps(results))
"""


MESH_DISPATCH_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
os.environ["JAX_PLATFORMS"] = "cpu"
import json
import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh

from repro.core import BlockRef, RowCloneEngine, SubarrayAllocator
from repro.kernels import fused_dispatch as fd

results = {}
mesh = Mesh(np.asarray(jax.devices()).reshape(2, 4), ("data", "model"))
results["n_devices"] = len(jax.devices())
nblk = 64           # 8 device shards of 8 blocks each

def build(seed=0, use_fused=True):
    alloc = SubarrayAllocator(nblk, 4)
    pools = {"k": jax.random.normal(jax.random.key(seed), (nblk, 4, 8)),
             "v": jax.random.normal(jax.random.key(seed + 1), (nblk, 4, 8))}
    return RowCloneEngine(pools, alloc, mesh=mesh, use_fused=use_fused)

events = []
fd.add_launch_hook(lambda n, p, m: events.append((n, p, m)))

# 1) mixed-opcode flush — FPM local, cross-shard copies over two hop
#    distances, zero-init, cross-pool local AND cross-shard — is exactly
#    ONE collective launch
eng = build()
want = {n: np.asarray(p) for n, p in eng.pools.items()}
eng.alloc.mark_written([2, 5, 17, 33, 12])
with eng.batch():
    eng.memcopy([(2, 3), (5, 60), (17, 26)])
    eng.materialize_zeros([40])
    eng.memcopy_cross([(BlockRef("k", 12), BlockRef("v", 13)),
                       (BlockRef("k", 33), BlockRef("v", 58))])
results["mixed_launches"] = len(events)
results["mixed_mechs"] = sorted(set(e[2] for e in events))
ref = {n: want[n].copy() for n in want}
for n in ("k", "v"):
    ref[n][3] = want[n][2]
    ref[n][60] = want[n][5]
    ref[n][26] = want[n][17]
    ref[n][40] = 0
ref["v"][13] = want["k"][12]
ref["v"][58] = want["k"][33]
results["mixed_ok"] = bool(all(
    np.array_equal(np.asarray(eng.pools[n]), ref[n]) for n in ref))

# 2) hazard auto-flush parity across a slab boundary: a->b crosses shards,
#    the dependent b->c forces an auto-flush; two launches, c holds a's bytes
events.clear()
eng2 = build(seed=7)
a, b, c = 2, 33, 50          # shards 0, 4, 6
olda = np.asarray(eng2.pools["k"][a])
eng2.alloc.mark_written([a])
with eng2.batch():
    eng2.memcopy([(a, b)])
    eng2.memcopy([(b, c)])
results["hazard_flushes"] = eng2.queue.stats.hazard_flushes
results["hazard_launches"] = len(events)
results["hazard_ok"] = bool(
    np.array_equal(np.asarray(eng2.pools["k"][b]), olda)
    and np.array_equal(np.asarray(eng2.pools["k"][c]), olda))

# 3) empty-slab flush: every command lands on shard 0; the other seven
#    shards drain all-NOP sub-tables inside the same single launch
events.clear()
eng3 = build(seed=11)
want3 = {n: np.asarray(p) for n, p in eng3.pools.items()}
eng3.alloc.mark_written([1, 2])
with eng3.batch():
    eng3.memcopy([(1, 4), (2, 5)])
    eng3.materialize_zeros([6])
results["empty_slab_launches"] = len(events)
ok = True
for n in ("k", "v"):
    r = want3[n].copy()
    r[4] = want3[n][1]
    r[5] = want3[n][2]
    r[6] = 0
    ok = ok and np.array_equal(np.asarray(eng3.pools[n]), r)
results["empty_slab_ok"] = bool(ok)

# 4) empty queue / all-NOP table: no launch on the mesh path either
events.clear()
flush_launches = eng3.flush()
nop = np.full((8, 3), -1, np.int32)
results["nop_launches"] = (flush_launches
                           + eng3._dispatch_table(nop, 0) + len(events))

# 5) serving engine picks the mesh up (layer-stacked block_axis=1 pools):
#    an eager CoW fork's block clones are CAPTURED onto the serve stream
#    and drain as one collective launch at the stream's flush (the round
#    boundary), whose FlushTicket carries the accounting
from repro.configs import get_config
from repro.launch.serve import ServingEngine
cfg = get_config("llama3.2-3b").reduced()
srv = ServingEngine(cfg, None, mesh=mesh, max_seqs=8, max_blocks_per_seq=8,
                    num_slabs=4)
results["serve_nblk_aligned"] = bool(srv.engine.num_blocks % 8 == 0)
results["serve_has_mesh"] = bool(srv.engine.mesh is mesh)
results["serve_batch_groups"] = srv.cache.batch_groups
sid = srv.cache.new_sequence(prompt_len=2 * srv.rc.page_size)
srv.engine.alloc.mark_written(srv.cache.blocks_of(sid))
events.clear()
with srv.stream.capture():
    srv.cache.fork(sid, 1, eager_copy=True)
results["serve_fork_prelaunches"] = len(events)   # captured: nothing yet
ticket = srv.stream.flush()                       # the round flush boundary
results["serve_fork_launches"] = len(events)
results["serve_fork_mechs"] = sorted(set(e[2] for e in events))
results["serve_ticket_launches"] = ticket.launches

# 6) staged admission promotions fuse into the SAME collective launch as
#    the round's other bulk movement: enqueue a promotion plus an eager
#    fork of the OLDER sequence (forking the just-admitted one would read
#    a pending promotion destination and correctly hazard-flush), then
#    flush the stream once.  The promotion itself crosses shards (staging
#    slots live on shard 0, the new sequence's group-1 blocks on shards
#    4-7), so the cross-pool rows ride the ppermute send/recv plan.
events.clear()
stage_ids = srv.engine.stage_blocks(2)
sid2 = srv.cache.new_sequence(prompt_len=2 * srv.rc.page_size)
with srv.stream.capture():
    srv.engine.promote_staged(list(zip(stage_ids,
                                       srv.cache.blocks_of(sid2))))
    srv.cache.fork(sid, 1, eager_copy=True)
results["stage_prelaunches"] = len(events)
srv.stream.flush()
results["stage_round_launches"] = len(events)
results["stage_round_mechs"] = sorted(set(e[2] for e in events))
results["stage_reclaimed"] = bool(
    all(s in srv.engine._stage_free for s in stage_ids))

print("RESULTS:" + json.dumps(results))
"""


@pytest.mark.mesh
def test_mesh_fused_dispatch_one_launch_per_flush(tmp_path):
    """Under a 2x4 host mesh the command queue drains every flush as ONE
    shard_map'd fused launch (launch-count hook), hazards auto-flush across
    slab boundaries exactly as on one device, and empty-slab / all-NOP
    flushes behave (no stray launches)."""
    res = run_device_subprocess(MESH_DISPATCH_SCRIPT, tmp_path=tmp_path)
    assert res["n_devices"] == 8
    assert res["mixed_launches"] == 1, res          # launches_per_flush == 1
    assert res["mixed_mechs"] == ["fused_mesh"], res
    assert res["mixed_ok"], res
    assert res["hazard_flushes"] == 1, res
    assert res["hazard_launches"] == 2, res         # one per flushed table
    assert res["hazard_ok"], res
    assert res["empty_slab_launches"] == 1, res
    assert res["empty_slab_ok"], res
    assert res["nop_launches"] == 0, res
    assert res["serve_nblk_aligned"], res
    assert res["serve_has_mesh"], res
    assert res["serve_batch_groups"] == 2, res      # (2, 4) mesh: data dp=2
    assert res["serve_fork_prelaunches"] == 0, res  # deferred until flush
    assert res["serve_fork_launches"] == 1, res
    assert res["serve_ticket_launches"] == 1, res   # the FlushTicket agrees
    assert res["serve_fork_mechs"] == ["fused_mesh"], res
    assert res["stage_prelaunches"] == 0, res
    assert res["stage_round_launches"] == 1, res    # promotions + fork fuse
    assert res["stage_round_mechs"] == ["fused_mesh"], res
    assert res["stage_reclaimed"], res


@pytest.mark.slow
def test_sharded_execution_matches_single_device(tmp_path):
    res = run_device_subprocess(SCRIPT, tmp_path=tmp_path)
    assert res["n_devices"] == 8
    assert res["decode_err"] < 5e-2, res      # bf16 pools
    assert res["train_loss_err"] < 5e-3, res
    assert res["elastic_loss_err"] < 5e-3, res
