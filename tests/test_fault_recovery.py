"""Fault-tolerant serving: ticket journal, checkpoint streams, recovery.

Covers the failure-injection matrix end to end:

* journal record contents and the bounded-ring replay contract;
* launch failures (flush aborts before chunk 0) and mid-flush aborts
  (multi-chunk flushes failing between chunks) recovered by suffix
  re-drain — final state bitwise-equal to the clean run;
* retry/backoff on flaky re-drains and RecoveryError on exhaustion;
* FaultPlan engine binding (a bound plan never fires on another engine);
* write-scoped FlushTickets (a checkpoint ticket survives donation of
  untouched pools);
* PoolCheckpoint quiesced save → restore bitwise;
* ServingEngine recovery: donated-admission errors evict + re-admit with
  greedy tokens bitwise-identical to the failure-free run, and a dead
  double-buffered ring degrades to single-buffer capacity.

Run with ``make test-fault`` (marker ``fault``; wired into ``make test``).
"""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.checkpoint import CheckpointManager, PoolCheckpoint
from repro.core import (BlockRef, PoolGroup, PoolSnapshot, PoolSpec,
                        RecoveryError, RowCloneEngine, SubarrayAllocator,
                        TicketJournal)
from repro.runtime.fault import FaultPlan, InjectedFault

pytestmark = pytest.mark.fault


def mk_engine(nblk=32, spill_nblk=0, stage_nblk=0, nslabs=4):
    """Flat (block_axis=0) k/v engine, optionally with staging and spill
    pools, over deterministic non-zero pool contents.  ZI off so every
    command physically moves bytes (the journal's replay target)."""
    blk = (4, 8)
    n = int(np.prod(blk))
    pools = {
        "k": jnp.arange(nblk * n, dtype=jnp.float32).reshape(
            (nblk,) + blk),
        "v": -jnp.arange(nblk * n, dtype=jnp.float32).reshape(
            (nblk,) + blk),
    }
    specs = [PoolSpec("k", nblk, blk, jnp.float32),
             PoolSpec("v", nblk, blk, jnp.float32)]
    if stage_nblk:
        for pn in ("k", "v"):
            pools[f"{pn}_stage"] = jnp.full((stage_nblk,) + blk, 7.0,
                                            jnp.float32)
            specs.append(PoolSpec(f"{pn}_stage", stage_nblk, blk,
                                  jnp.float32, role="staging", paired=pn))
    if spill_nblk:
        for pn in ("k", "v"):
            pools[f"{pn}_spill"] = jnp.zeros((spill_nblk,) + blk,
                                             jnp.float32)
            specs.append(PoolSpec(f"{pn}_spill", spill_nblk, blk,
                                  jnp.float32, role="spill", paired=pn))
    alloc = SubarrayAllocator(nblk, nslabs)
    return RowCloneEngine(pools, alloc, group=PoolGroup(specs),
                          enable_zi=False)


def pools_of(eng):
    return {n: np.asarray(p) for n, p in eng.pools.items()}


def assert_pools_equal(a, b):
    """Bitwise comparison via uint views: compute rows (AND/OR/NOT)
    manufacture arbitrary float bit patterns that float equality would
    conflate (distinct NaN encodings compare equal)."""
    assert sorted(a) == sorted(b)
    for name in a:
        np.testing.assert_array_equal(
            np.ascontiguousarray(a[name]).view(np.uint8),
            np.ascontiguousarray(b[name]).view(np.uint8), err_msg=name)


# ---------------------------------------------------------------------------
# journal contents
# ---------------------------------------------------------------------------

def test_journal_records_flushes():
    eng = mk_engine()
    eng.memcopy([(0, 1)])                       # flush 0 (default stream)
    s = eng.stream("aux")
    s.memcopy([(2, 3), (4, 5)])
    t = s.flush()                               # flush 1
    recs = eng.journal.records
    assert [r.index for r in recs] == [0, 1]
    assert recs[0].stream == "default" and recs[1].stream == "aux"
    assert recs[1].rows == ((0, 2, 3), (0, 4, 5))   # OP_FPM_COPY rows
    assert recs[1].launches == t.launches == 1
    assert not any(r.aborted for r in recs)
    assert eng.journal.head_index == 0
    assert eng.journal.last_index == t.index == 1
    assert [r.index for r in eng.journal.since(0)] == [1]


def test_journal_ring_bounds_capacity():
    eng = mk_engine()
    eng.journal = TicketJournal(capacity=4)
    for _ in range(8):
        eng.memcopy([(0, 1)])
    assert len(eng.journal) == 4
    assert eng.journal.head_index == 4          # oldest fell off


# ---------------------------------------------------------------------------
# injected failures + recovery, engine level
# ---------------------------------------------------------------------------

def test_launch_failure_recovers_bitwise():
    clean = mk_engine()
    eng = mk_engine()
    eng.memcopy([(0, 1)])
    clean.memcopy([(0, 1)])
    plan = FaultPlan(launch_failures=(eng.next_flush_index,))
    with plan.active(eng):
        with pytest.raises(InjectedFault):
            eng.memcopy([(2, 3), (4, 5)])
    assert plan.fired == [("launch_failure", 1)]
    # nothing dispatched: the aborted flush stashes the WHOLE row set
    assert len(eng._aborted) == 1
    assert eng._aborted[0].suffix == ((0, 2, 3), (0, 4, 5))
    rep = eng.recover()
    assert rep.redrained_flushes == 1 and rep.retries == 0
    clean.memcopy([(2, 3), (4, 5)])
    assert_pools_equal(pools_of(eng), pools_of(clean))
    # chunk 0 never dispatched, so no aborted prefix was journaled — the
    # re-drain is an ordinary record and replay covers the full history
    assert not any(r.aborted for r in eng.journal.records)


def test_midflush_abort_journals_prefix_and_redrains():
    # 600 rows in one flush -> two 512-row-bucket chunks; the abort
    # fires between them, so a 512-row prefix has already dispatched
    nblk = 2048
    pairs = [(2 * i, 2 * i + 1) for i in range(600)]
    clean = mk_engine(nblk=nblk)
    eng = mk_engine(nblk=nblk)
    init = pools_of(eng)                        # pre-history state
    plan = FaultPlan(midflush_aborts=(eng.next_flush_index,))
    with plan.active(eng):
        with pytest.raises(InjectedFault):
            eng.memcopy(pairs)
    assert plan.fired == [("midflush_abort", 0)]
    # the dispatched prefix is journaled as an aborted record; the
    # undispatched suffix is stashed for recover()
    assert eng.journal.records[-1].aborted
    assert len(eng.journal.records[-1].rows) == 512
    assert len(eng._aborted[0].suffix) == 600 - 512
    rep = eng.recover()
    assert rep.redrained_flushes == 1
    clean.memcopy(pairs)
    assert_pools_equal(pools_of(eng), pools_of(clean))
    # snapshot+replay across the aborted history is still bitwise exact:
    # the prefix record and the re-drain record replay in order
    want = pools_of(eng)
    for p in eng.pools.values():
        p.delete()
    rep2 = eng.recover(snapshot=PoolSnapshot(index=-1, arrays=init))
    assert set(rep2.pools_restored) == set(init) and not rep2.pools_lost
    assert rep2.replayed_flushes == len(eng.journal.records) == 2
    assert_pools_equal(pools_of(eng), want)


def test_midflush_abort_with_compute_rows_replays_bitwise():
    """Crash mid-flush on a table carrying two-source compute rows
    (AND/OR/NOT mixed with copies): the journaled prefix + recovered
    suffix re-drain, then snapshot+replay, both land bit-identical pools
    — journal records hold the packed srcB rows verbatim, so replay
    rebuilds the exact two-source tables."""
    nblk = 2048
    copies = [(i, 1000 + i) for i in range(200)]
    ands = [(200 + i, 400 + i, 1200 + i) for i in range(200)]
    nots = [(600 + i, 1400 + i) for i in range(100)]

    def drive(eng):
        eng.alloc.mark_written([s for s, _ in copies] +
                               [a for a, _, _ in ands] +
                               [b for _, b, _ in ands] +
                               [s for s, _ in nots])
        with eng.batch():
            eng.memcopy(copies)
            eng.memand(ands)      # fans out per primary pool: 400 rows
            eng.memnot(nots)      # 200 rows -> 800 total, two chunks

    clean = mk_engine(nblk=nblk)
    eng = mk_engine(nblk=nblk)
    init = pools_of(eng)
    plan = FaultPlan(midflush_aborts=(eng.next_flush_index,))
    with plan.active(eng):
        with pytest.raises(InjectedFault):
            drive(eng)
    # the 512-row dispatched prefix is journaled (aborted record), the
    # undispatched suffix — all compute rows — is stashed for recover()
    assert eng.journal.records[-1].aborted
    assert len(eng.journal.records[-1].rows) == 512
    assert len(eng._aborted[0].suffix) == 800 - 512
    rep = eng.recover()
    assert rep.redrained_flushes == 1
    drive(clean)
    assert_pools_equal(pools_of(eng), pools_of(clean))
    # crash again AFTER recovery: snapshot+journal replay across the
    # aborted-prefix record and the re-drain record stays bitwise exact
    want = pools_of(eng)
    for p in eng.pools.values():
        p.delete()
    rep2 = eng.recover(snapshot=PoolSnapshot(index=-1, arrays=init))
    assert set(rep2.pools_restored) == set(init) and not rep2.pools_lost
    assert rep2.replayed_flushes == len(eng.journal.records) == 2
    assert_pools_equal(pools_of(eng), want)


def test_launch_failure_on_bitwise_flush_recovers_bitwise():
    """A launch failure aborting a flush of ONLY compute rows: recover()
    re-drains the stashed rows and the pools match a failure-free twin
    to the exact bit."""
    clean = mk_engine()
    eng = mk_engine()
    for e in (clean, eng):
        e.alloc.mark_written([1, 2, 3])
    plan = FaultPlan(launch_failures=(eng.next_flush_index,))
    with plan.active(eng):
        with pytest.raises(InjectedFault):
            with eng.batch():
                eng.memand([(1, 2, 8)])
                eng.memor([(2, 3, 9)])
                eng.memnot([(3, 10)])
    assert plan.fired == [("launch_failure", 0)]
    rep = eng.recover()
    assert rep.redrained_flushes == 1
    with clean.batch():
        clean.memand([(1, 2, 8)])
        clean.memor([(2, 3, 9)])
        clean.memnot([(3, 10)])
    assert_pools_equal(pools_of(eng), pools_of(clean))


def test_redrain_retries_with_backoff_then_succeeds():
    eng = mk_engine()
    fails = {"n": 3}                 # initial abort + 2 failed retries

    def flaky(info):
        if info.engine is eng and fails["n"] > 0:
            fails["n"] -= 1
            raise InjectedFault("flaky")

    from repro.kernels.fused_dispatch import (add_drain_guard,
                                              remove_drain_guard)
    add_drain_guard(flaky)
    try:
        with pytest.raises(InjectedFault):
            eng.memcopy([(0, 1)])
        rep = eng.recover(max_retries=3, backoff=0.001)
    finally:
        remove_drain_guard(flaky)
    assert rep.retries == 2 and rep.redrained_flushes == 1
    np.testing.assert_array_equal(np.asarray(eng.pools["k"][1]),
                                  np.asarray(eng.pools["k"][0]))


def test_redrain_exhaustion_raises_recovery_error():
    eng = mk_engine()

    def always(info):
        if info.engine is eng:
            raise InjectedFault("always")

    from repro.kernels.fused_dispatch import (add_drain_guard,
                                              remove_drain_guard)
    add_drain_guard(always)
    try:
        with pytest.raises(InjectedFault):
            eng.memcopy([(0, 1)])
        with pytest.raises(RecoveryError):
            eng.recover(max_retries=2, backoff=0.001)
    finally:
        remove_drain_guard(always)


def test_fault_plan_binds_to_one_engine():
    a, b = mk_engine(), mk_engine()
    plan = FaultPlan(launch_failures=(0,))
    with plan.active(a):
        b.memcopy([(0, 1)])          # b's flush 0: must NOT fire
        with pytest.raises(InjectedFault):
            a.memcopy([(0, 1)])
    assert plan.fired == [("launch_failure", 0)]
    a.recover()
    assert_pools_equal(pools_of(a), pools_of(b))


def test_recover_evicts_queued_promotions_when_staging_dies():
    eng = mk_engine(stage_nblk=4)
    slots = eng.stage_blocks(2)
    s = eng.stream("serve")
    s.promote_staged(list(zip(slots, [0, 1])))
    assert len(s.queue) == 4         # 2 slots x k/v pool pairs, queued
    # donation death of the staging ring while promotions are queued
    for name in eng.staging:
        eng.pools[name].delete()
    rep = eng.recover()
    assert rep.evicted_promotions == 4
    assert set(rep.pools_lost) == {"k_stage", "v_stage"}
    assert len(s.queue) == 0
    assert len(eng._stage_free) == eng.stage_capacity == 4


# ---------------------------------------------------------------------------
# write-scoped tickets + the checkpoint stream
# ---------------------------------------------------------------------------

def test_ticket_wait_scoped_to_touched_pools():
    eng = mk_engine(spill_nblk=4)
    ck = eng.stream("ckpt")
    ck.memcopy_cross([(BlockRef("k", 0), BlockRef("k_spill", 0)),
                      (BlockRef("v", 0), BlockRef("v_spill", 0))])
    t = ck.flush()
    assert t.touched == ("k_spill", "v_spill")
    # a decode step donates the primaries; the ckpt ticket must survive
    want = np.asarray(eng.pools["k"][0])
    eng.pools["k"].delete()
    eng.pools["v"].delete()
    assert t.expired                     # conservatively: SOME pool died
    t.wait()                             # ...but the touched set is live
    np.testing.assert_array_equal(
        t.block_state(BlockRef("k_spill", 0)), want)
    with pytest.raises(RuntimeError, match="expired"):
        t.block_state(BlockRef("k", 0))


def test_pool_checkpoint_quiesced_roundtrip(tmp_path):
    eng = mk_engine(nblk=16, spill_nblk=8)
    pc = PoolCheckpoint(eng, CheckpointManager(str(tmp_path)), window=8)
    eng.memcopy([(0, 3)])
    want = {n: np.asarray(eng.pools[n]) for n in ("k", "v")}
    pc.drain()
    assert pc.passes == 1
    snap = pc.latest()
    assert snap is not None and sorted(snap.arrays) == ["k", "v"]
    # the persisted bytes match the quiesce point exactly...
    for n in ("k", "v"):
        np.testing.assert_array_equal(snap.arrays[n], want[n])
    # ...and the snapshot's index is the pass's last ckpt flush, so the
    # snapshot+replay contract holds across post-snapshot movement
    assert snap.index == eng.journal.last_index
    eng.memcopy([(3, 5)])
    want2 = {n: np.asarray(eng.pools[n]) for n in ("k", "v")}
    eng.pools["k"].delete()
    eng.pools["v"].delete()
    rep = eng.recover(snapshot=snap)
    assert set(rep.pools_restored) == {"k", "v"}
    assert rep.replayed_flushes == 1     # just the post-snapshot flush
    for n in ("k", "v"):
        np.testing.assert_array_equal(np.asarray(eng.pools[n]), want2[n])


def test_pool_checkpoint_requires_spill_pools(tmp_path):
    eng = mk_engine()
    with pytest.raises(ValueError, match="spill"):
        PoolCheckpoint(eng, CheckpointManager(str(tmp_path)))


# ---------------------------------------------------------------------------
# serving-level recovery (prefill donation, eviction + re-admission)
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def serving_setup():
    from repro.configs import get_config
    from repro.models import build_model, split_params
    cfg = get_config("llama3.2-3b").reduced()
    model = build_model(cfg)
    params, _ = split_params(model.init_params(jax.random.key(0)))
    return cfg, params


def _serve(cfg, params, **kw):
    from repro.launch.serve import ServingEngine
    return ServingEngine(cfg, params, max_seqs=8, max_blocks_per_seq=8,
                         **kw)


def test_serving_faults_recover_token_identical(serving_setup, tmp_path):
    """Launch failure mid-serve + donated-admission error: with
    auto-recovery and re-admission, greedy tokens are bitwise-identical
    to the failure-free run, and the background checkpoint stream keeps
    ticking."""
    cfg, params = serving_setup
    rng = np.random.default_rng(0)
    prompts = [rng.integers(2, cfg.vocab_size, size=16).astype(np.int32)
               for _ in range(3)]

    def drive(eng, plan=None):
        order = []                      # sids in admission order
        for p in prompts[:2]:
            order.append(eng.add_request(p))
        for r in range(5):
            if r == 1 and plan is not None:
                # target the round's next drain, whichever stream it is
                plan.launch_failures += (eng.engine.next_flush_index,)
            if r == 3:
                if plan is not None:
                    plan.donation_errors += (eng._admission_ordinal,)
                    with pytest.raises(InjectedFault):
                        eng.add_request(prompts[2])
                    # the failed admission was evicted for re-admission
                    assert len(eng.evicted_sids) == 1
                order.append(eng.add_request(prompts[2]))
            eng.decode_round()
        return [eng.tokens[s] for s in order if s in eng.tokens]

    ref = drive(_serve(cfg, params))
    plan = FaultPlan()
    eng = _serve(cfg, params, fault_plan=plan, auto_recover=True,
                 ckpt_pages=8, ckpt_dir=str(tmp_path))
    got = drive(eng, plan)
    assert [k for k, _ in plan.fired] == ["launch_failure",
                                          "donation_error"]
    assert eng.last_recovery is not None
    assert ref == got                   # bitwise greedy-token identity
    # the ckpt stream kept running after both recoveries
    assert eng.pool_ckpt._cursor > 0 or eng.pool_ckpt.passes > 0


def test_serving_double_buffer_degrades_on_dead_ring(serving_setup):
    """A donation error that kills a double-buffered staging ring brings
    it back at SINGLE-buffer capacity (degraded mode), and the evicted
    admission re-admits through the degraded ring."""
    cfg, params = serving_setup
    rng = np.random.default_rng(1)
    plan = FaultPlan(donation_errors=(0,))
    eng = _serve(cfg, params, double_buffer=True, max_admit_pages=8,
                 fault_plan=plan, auto_recover=True)
    assert eng.engine.stage_capacity == 16      # live + shadow halves
    p = rng.integers(2, cfg.vocab_size, size=16).astype(np.int32)
    with pytest.raises(InjectedFault):
        eng.add_request(p)
    assert eng.last_recovery is not None and eng.last_recovery.degraded
    assert len(eng.engine._stage_free) == eng.ring_capacity == 8
    sid = eng.add_request(p)
    toks = eng.decode_round()
    assert sid in toks
