"""Property-based dispatch parity: random command streams must produce
bitwise-identical pools across {seed fan-out, single-slab fused, mesh fused}
with consistent launch accounting.

Streams mix every opcode (FPM/PSM/baseline-adjacent copies, zero-init —
materialized and lazy — cross-pool copies, and the TWO-SOURCE bitwise
compute rows ``memand``/``memor``/``memnot`` — int fan-out and
cross-pool BlockRef triples, srcB packed into the src field), include
duplicate destinations (exercising the hazard auto-flush — a dup dst
against EITHER source of a bitwise row counts), **adjacent
WAR-on-source patterns** (copy out of a block, then rewrite it in the
same stream — the pattern the overlapped DMA drain's spacer rows must
keep safe; bitwise rows contribute two read sets), src==dst no-ops and
in-place bitwise rows (dst == srcA or srcB), lazy-zero sources (the ZI
alias fast path), overflow past the top 512 bucket, and both
``block_axis`` layouts.  Pool parity is asserted on UINT BIT VIEWS —
AND/OR/NOT over float pools manufacture arbitrary bit patterns
(including NaNs, which float equality would conflate).  Engines carry staging pools (k_stage/v_stage) of
INDEPENDENT size — full twins and staging rings smaller than the KV pools
(the PoolGroup prefix-sum address space) — so streams also drive
heterogeneous staging↔KV cross-pool traffic: promotions, demotions,
staging→staging moves, and dup-dst hazards that cross the primary/staging
address-space boundary (pool-aware hazard keys), with every global id
resolved through per-pool base offsets rather than uniform stacked
arithmetic.  The single-device pair runs in-process via ``tests/_hypo.py``;
the three-way comparison including the 8-device mesh fused path replays the
same generated streams in a subprocess (jax locks the host device count at
first init).
"""
import json
import os
import random

import jax
import numpy as np
import pytest

from _hypo import given, settings, st
from _meshproc import run_device_subprocess
from repro.core import BlockRef, RowCloneEngine, SubarrayAllocator
from repro.kernels import fused_dispatch as fd

# ---------------------------------------------------------------------------
# stream generation (shared by the in-process property and the subprocess
# replay — programs are plain JSON)
# ---------------------------------------------------------------------------

KINDS = ("copy", "copy", "zero", "lazy", "cross", "cross", "war",
         "bit", "bit")

#: all four pools a BlockRef bitwise row may draw sources/dst from
BIT_POOLS = ("k", "v", "k_stage", "v_stage")

#: cross-pool pool pairs: primary↔primary plus every staging flavour —
#: promotion (stage→primary), demotion (primary→stage), stage→stage
CROSS_POOL_PAIRS = (
    ("k", "v"), ("v", "k"),
    ("k_stage", "k"), ("v_stage", "v"),      # promotions
    ("k", "k_stage"), ("v", "v_stage"),      # demotions
    ("k_stage", "v"), ("k_stage", "v_stage"),
)


def gen_program(rng: random.Random, nblk: int, n_instr: int,
                stage_nblk=None):
    """A random instruction stream against the engine's public API.
    ``stage_nblk`` bounds the block ids drawn for staging pools (None =
    same as the KV pools — the full-twin layout)."""
    sizes = {"k": nblk, "v": nblk,
             "k_stage": stage_nblk or nblk, "v_stage": stage_nblk or nblk}
    prog = []
    for _ in range(n_instr):
        kind = rng.choice(KINDS)
        if kind == "copy":
            n = rng.randint(1, 6)
            # dup dsts and src==dst allowed on purpose: the former forces
            # hazard auto-flushes, the latter must be a harmless self-copy
            pairs = [[rng.randrange(nblk), rng.randrange(nblk)]
                     for _ in range(n)]
            prog.append(["copy", pairs])
        elif kind == "zero":
            ids = [rng.randrange(nblk) for _ in range(rng.randint(1, 4))]
            prog.append(["zero", ids])
        elif kind == "lazy":
            ids = [rng.randrange(nblk) for _ in range(rng.randint(1, 4))]
            prog.append(["lazy", ids])
        elif kind == "war":
            # WAR-on-source, ADJACENT by construction: copy out of block
            # a, then immediately rewrite a (plain copy or zero) in the
            # same batch — admitted without a hazard flush, and the
            # overlapped fused drain must space the pair (all three
            # dispatch paths stay bitwise-identical)
            a, b, c = (rng.randrange(nblk) for _ in range(3))
            if rng.random() < 0.5:
                prog.append(["war", [[a, b], [c, a]], None])
            else:
                prog.append(["war", [[a, b]], a])
        elif kind == "bit":
            # two-source compute rows: AND/OR (triples) or NOT (pairs),
            # either as primary-id fan-out or as cross-pool BlockRefs
            # over all four pools.  Dup dsts (vs either source) and
            # in-place rows (dst == srcA or srcB) arise by construction.
            op = rng.choice(["and", "or", "not"])
            n = rng.randint(1, 4)
            if rng.random() < 0.5:
                width = 2 if op == "not" else 3
                rows = [[rng.randrange(nblk) for _ in range(width)]
                        for _ in range(n)]
                prog.append(["bit", op, rows, "int"])
            else:
                rows = []
                for _ in range(n):
                    refs = [[p, rng.randrange(sizes[p])]
                            for p in (rng.choice(BIT_POOLS) for _ in
                                      range(2 if op == "not" else 3))]
                    rows.append(refs)
                prog.append(["bit", op, rows, "ref"])
        else:
            n = rng.randint(1, 4)
            sp, dp = rng.choice(CROSS_POOL_PAIRS)
            pairs = [[rng.randrange(sizes[sp]), rng.randrange(sizes[dp])]
                     for _ in range(n)]
            prog.append(["cross", pairs, sp, dp])
    return prog


def run_program(eng: RowCloneEngine, prog):
    """Drive one engine through a program inside one batch() (hazards may
    auto-flush mid-stream).  Returns the launch-hook events."""
    events = []
    hook = lambda n, p, mech: events.append((n, p, mech))
    fd.add_launch_hook(hook)
    try:
        with eng.batch():
            for instr in prog:
                if instr[0] == "copy":
                    eng.memcopy([tuple(p) for p in instr[1]])
                elif instr[0] == "zero":
                    eng.materialize_zeros(instr[1])
                elif instr[0] == "lazy":
                    eng.meminit(instr[1], lazy=True)
                elif instr[0] == "war":
                    # copy out of a block, then rewrite it right away
                    eng.memcopy([tuple(p) for p in instr[1]])
                    if instr[2] is not None:
                        eng.materialize_zeros([instr[2]])
                elif instr[0] == "bit":
                    op, rows, mode = instr[1], instr[2], instr[3]
                    if mode == "int":
                        args = [tuple(r) for r in rows]
                    else:
                        args = [tuple(BlockRef(p, i) for p, i in r)
                                for r in rows]
                    getattr(eng, "mem" + op)(args)
                else:
                    sp, dp = instr[2], instr[3]
                    eng.memcopy_cross([(BlockRef(sp, s), BlockRef(dp, d))
                                       for s, d in instr[1]])
    finally:
        fd.remove_launch_hook(hook)
    return events


def mk_engine(nblk, block_axis, use_fused, mesh=None, nslabs=4, seed=0,
              stage_nblk=None):
    """Build a 4-pool engine; ``stage_nblk`` sizes the staging pools
    independently of the KV pools (None = full twin)."""
    snblk = stage_nblk or nblk
    alloc = SubarrayAllocator(nblk, nslabs, reserved_zero_per_slab=1)
    shape = (nblk, 4, 8) if block_axis == 0 else (3, nblk, 4, 8)
    sshape = (snblk, 4, 8) if block_axis == 0 else (3, snblk, 4, 8)
    pools = {
        "k": jax.random.normal(jax.random.key(seed), shape),
        "v": jax.random.normal(jax.random.key(seed + 1), shape),
        "k_stage": jax.random.normal(jax.random.key(seed + 2), sshape),
        "v_stage": jax.random.normal(jax.random.key(seed + 3), sshape),
    }
    return RowCloneEngine(pools, alloc, mesh=mesh, max_requests=64,
                          block_axis=block_axis, use_fused=use_fused,
                          staging={"k_stage": "k", "v_stage": "v"})


def assert_pools_equal(a: RowCloneEngine, b: RowCloneEngine, ctx=""):
    """Bitwise pool parity through uint8 views: bitwise opcodes on float
    pools produce arbitrary bit patterns (incl. NaNs), and float equality
    would conflate distinct NaN encodings."""
    for name in a.pools:
        av = np.ascontiguousarray(np.asarray(a.pools[name]))
        bv = np.ascontiguousarray(np.asarray(b.pools[name]))
        np.testing.assert_array_equal(av.view(np.uint8), bv.view(np.uint8),
                                      err_msg=f"pool {name} {ctx}")


# ---------------------------------------------------------------------------
# in-process property: seed fan-out vs single-slab fused
# ---------------------------------------------------------------------------

@settings(max_examples=12, deadline=None)
@given(st.integers(0, 10**6), st.integers(0, 1), st.integers(1, 8),
       st.integers(0, 2))
def test_property_fused_matches_seed_fanout(seed, block_axis, n_instr,
                                            stage_shift):
    """Random streams over HETEROGENEOUS pools (staging rings of nblk,
    nblk/2, nblk/4 slots): fused flush == seed per-op fan-out, bitwise,
    with every fused flush exactly one launch."""
    rng = random.Random(seed)
    nblk = rng.choice([32, 64])
    stage_nblk = nblk >> stage_shift
    prog = gen_program(rng, nblk, n_instr, stage_nblk=stage_nblk)
    fused = mk_engine(nblk, block_axis, use_fused=True,
                      stage_nblk=stage_nblk)
    legacy = mk_engine(nblk, block_axis, use_fused=False,
                       stage_nblk=stage_nblk)
    ev_f = run_program(fused, prog)
    ev_l = run_program(legacy, prog)
    assert_pools_equal(fused, legacy, f"(seed={seed} prog={prog})")
    # accounting: every fused event is the fused mechanism, one per flushed
    # chunk, and the stats agree with the hook
    assert all(e[2] == "fused" for e in ev_f), ev_f
    assert len(ev_f) == fused.stats.launches
    assert fused.queue.stats.launches == fused.stats.launches
    # hazard auto-flush boundaries are path-independent (queue-level), and
    # so are the WAR-on-source admissions (tracked, never flushed)
    assert fused.queue.stats.hazard_flushes == legacy.queue.stats.hazard_flushes
    assert fused.queue.stats.war_hazards == legacy.queue.stats.war_hazards
    assert fused.queue.stats.spacer_rows == legacy.queue.stats.spacer_rows
    if ev_l:
        assert len(ev_f) <= len(ev_l)
    # identical ZI metadata: the alias fast path took the same decisions
    np.testing.assert_array_equal(fused.alloc.is_zero, legacy.alloc.is_zero)


def test_property_overflow_chunks_match():
    """>512 commands in one flush drain in identical chunks on both paths."""
    nblk = 2048
    fused = mk_engine(nblk, 0, use_fused=True)
    legacy = mk_engine(nblk, 0, use_fused=False)
    pairs = [(i, 1024 + i) for i in range(600)]
    for eng in (fused, legacy):
        eng.alloc.mark_written([s for s, _ in pairs])
        with eng.batch():
            eng.memcopy(pairs)
            eng.materialize_zeros(list(range(700, 720)))
    assert_pools_equal(fused, legacy, "(overflow)")
    assert fused.stats.launches == 2           # 512 + 108 -> two buckets


# ---------------------------------------------------------------------------
# crash-replay determinism: kill the pools after a random cut point, then
# snapshot-restore + journal-replay must rebuild them bitwise
# ---------------------------------------------------------------------------

@settings(max_examples=10, deadline=None)
@given(st.integers(0, 10**6), st.integers(0, 1), st.integers(1, 8))
def test_property_crash_replay_bitwise(seed, block_axis, n_instr):
    """Run a random stream, snapshot at a random flush boundary, keep
    running, then simulate donation death of EVERY pool: recover() must
    restore the snapshot and replay the journal suffix to pools that are
    bitwise-identical to the pre-crash state (records hold the spaced
    rows verbatim, so replay rebuilds the exact tables)."""
    rng = random.Random(seed)
    nblk = rng.choice([32, 64])
    stage_nblk = nblk // 2
    prog = gen_program(rng, nblk, n_instr, stage_nblk=stage_nblk)
    eng = mk_engine(nblk, block_axis, use_fused=True,
                    stage_nblk=stage_nblk)
    cut = rng.randint(0, len(prog))
    run_program(eng, prog[:cut])
    snap = eng.snapshot()
    run_program(eng, prog[cut:])
    want = {n: np.asarray(p) for n, p in eng.pools.items()}
    replayable = len(eng.journal.since(snap.index))
    for p in eng.pools.values():
        p.delete()                      # the crash: every buffer donated
    rep = eng.recover(snapshot=snap)
    assert set(rep.pools_restored) == set(eng.pools)
    assert rep.pools_lost == ()
    assert rep.replayed_flushes == replayable
    for name in eng.pools:
        # uint view: replayed compute rows must match to the exact bit
        np.testing.assert_array_equal(
            np.ascontiguousarray(np.asarray(eng.pools[name])).view(np.uint8),
            np.ascontiguousarray(want[name]).view(np.uint8),
            err_msg=f"pool {name} after replay (seed={seed} cut={cut})")


# ---------------------------------------------------------------------------
# three-way parity incl. the sharded mesh path (8 host devices, subprocess)
# ---------------------------------------------------------------------------

MESH_CHILD = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
os.environ["JAX_PLATFORMS"] = "cpu"
import json, sys
import jax, numpy as np
from jax.sharding import Mesh

sys.path.insert(0, __TEST_DIR__)
from test_dispatch_properties import (assert_pools_equal, mk_engine,
                                      run_program)

spec = json.load(open(sys.argv[1]))
mesh = Mesh(np.asarray(jax.devices()).reshape(2, 4), ("data", "model"))
results = []
for case in spec["cases"]:
    nblk, ba, prog = case["nblk"], case["block_axis"], case["prog"]
    snblk = case.get("stage_nblk")
    seed_eng = mk_engine(nblk, ba, use_fused=False, stage_nblk=snblk)
    single = mk_engine(nblk, ba, use_fused=True, stage_nblk=snblk)
    sharded = mk_engine(nblk, ba, use_fused=True, mesh=mesh,
                        stage_nblk=snblk)
    ev_seed = run_program(seed_eng, prog)
    ev_single = run_program(single, prog)
    ev_mesh = run_program(sharded, prog)
    assert_pools_equal(single, seed_eng, f"single-vs-seed case={case}")
    assert_pools_equal(sharded, seed_eng, f"mesh-vs-seed case={case}")
    assert all(e[2] == "fused_mesh" for e in ev_mesh), ev_mesh
    # launches_per_flush accounting identical across the two fused drains
    assert len(ev_mesh) == len(ev_single) == sharded.stats.launches, (
        ev_mesh, ev_single)
    assert sharded.queue.stats.hazard_flushes == \
        single.queue.stats.hazard_flushes
    # WAR admissions are queue-level and path-independent; spacer counts
    # legitimately differ (global adjacency vs per-slab adjacency) but
    # must be credited on the mesh path whenever a slab pair was spaced
    assert sharded.queue.stats.war_hazards == \
        single.queue.stats.war_hazards
    results.append({"launches": len(ev_mesh),
                    "seed_launches": len(ev_seed),
                    "mesh_spacers": sharded.queue.stats.spacer_rows})

# the sharded drain's Pallas branch (kernel body in interpret mode inside
# shard_map) on the first stream — the TPU code path must not only exist
# in CPU CI as the jnp reference
import functools
from repro.kernels import ops as kops
orig = kops.fused_dispatch_sharded
kops.fused_dispatch_sharded = functools.partial(orig, use_pallas=True)
try:
    case = spec["cases"][0]
    forced = mk_engine(case["nblk"], case["block_axis"], use_fused=True,
                       mesh=mesh, stage_nblk=case.get("stage_nblk"))
    plain = mk_engine(case["nblk"], case["block_axis"], use_fused=True,
                      stage_nblk=case.get("stage_nblk"))
    run_program(forced, case["prog"])
    run_program(plain, case["prog"])
    assert_pools_equal(forced, plain, "pallas-interpret sharded drain")
finally:
    kops.fused_dispatch_sharded = orig

print("RESULTS:" + json.dumps(results))
"""


@pytest.mark.slow
@pytest.mark.mesh
def test_property_mesh_fused_three_way_parity(tmp_path):
    """The generated streams replayed under a 2x4 host mesh: seed fan-out,
    single-slab fused, and the sharded mesh drain agree bitwise, and both
    fused paths issue exactly one launch per flushed chunk.  Engines mix
    full-twin and staging-ring layouts (per-pool shard sizes in the
    ShardPlan — a ring's 8-block slab partitions alongside a 64-block KV
    slab in the same collective launch)."""
    rng = random.Random(0xC10E)
    cases = []
    for i in range(5):
        nblk = rng.choice([32, 64])            # 8 shards of 4 or 8 blocks
        # ring sizes stay divisible by the 8 mesh shards (8 minimum)
        snblk = rng.choice([nblk, nblk // 2, nblk // 4])
        ba = rng.randrange(2)
        cases.append({"nblk": nblk, "block_axis": ba, "stage_nblk": snblk,
                      "prog": gen_program(rng, nblk, rng.randint(2, 7),
                                          stage_nblk=snblk)})
    # overflow across the mesh: >512 commands, sources on every shard
    cases.append({"nblk": 2048, "block_axis": 0,
                  "prog": [["copy", [[i, 1024 + i] for i in range(600)]]]})
    spec = tmp_path / "cases.json"
    spec.write_text(json.dumps({"cases": cases}))
    child = MESH_CHILD.replace(
        "__TEST_DIR__", repr(os.path.dirname(os.path.abspath(__file__))))
    results = run_device_subprocess(child, args=[str(spec)],
                                    tmp_path=tmp_path)
    assert len(results) == len(cases)
    # the overflow case drains in exactly two collective launches
    assert results[-1]["launches"] == 2, results[-1]


JOURNAL_CHILD = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
os.environ["JAX_PLATFORMS"] = "cpu"
import json, random, sys
import jax, numpy as np
from jax.sharding import Mesh

sys.path.insert(0, __TEST_DIR__)
from test_dispatch_properties import gen_program, mk_engine, run_program

mesh = Mesh(np.asarray(jax.devices()).reshape(2, 4), ("data", "model"))
rng = random.Random(0xFA117)
results = []
for i in range(3):
    nblk, snblk = 64, 32               # both divisible by the 8 shards
    ba = rng.randrange(2)
    prog = gen_program(rng, nblk, rng.randint(2, 6), stage_nblk=snblk)
    eng = mk_engine(nblk, ba, use_fused=True, mesh=mesh, stage_nblk=snblk)
    cut = rng.randint(0, len(prog))
    run_program(eng, prog[:cut])
    snap = eng.snapshot()
    run_program(eng, prog[cut:])
    want = {n: np.asarray(p) for n, p in eng.pools.items()}
    for p in eng.pools.values():
        p.delete()
    rep = eng.recover(snapshot=snap)
    for name in eng.pools:
        np.testing.assert_array_equal(
            np.ascontiguousarray(np.asarray(eng.pools[name])).view(np.uint8),
            np.ascontiguousarray(want[name]).view(np.uint8),
            err_msg=f"pool {name} case={i} ba={ba} cut={cut}")
    results.append({"replayed": rep.replayed_flushes,
                    "restored": len(rep.pools_restored)})
print("RESULTS:" + json.dumps(results))
"""


@pytest.mark.slow
@pytest.mark.mesh
def test_property_crash_replay_bitwise_mesh(tmp_path):
    """The crash-replay property under the 8-device collective drain:
    replayed flushes re-partition into the same ShardPlans, so the
    restored pools match bitwise on the mesh path too."""
    child = JOURNAL_CHILD.replace(
        "__TEST_DIR__", repr(os.path.dirname(os.path.abspath(__file__))))
    results = run_device_subprocess(child, tmp_path=tmp_path)
    assert len(results) == 3
    assert all(r["restored"] == 4 for r in results), results


# ---------------------------------------------------------------------------
# regression: adversarial delta subsets must not grow the sharded jit cache
# without bound — past MAX_DELTA_SIGNATURES distinct (deltas, t) signatures
# the plan folds onto the full delta set (cmdqueue.fold_shard_plan)
# ---------------------------------------------------------------------------

JIT_CACHE_CHILD = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
os.environ["JAX_PLATFORMS"] = "cpu"
import itertools, json, sys
import jax, numpy as np
from jax.sharding import Mesh

sys.path.insert(0, __TEST_DIR__)
from test_dispatch_properties import assert_pools_equal, mk_engine
from repro.kernels import fused_dispatch as fd

mesh = Mesh(np.asarray(jax.devices()).reshape(2, 4), ("data", "model"))
nblk = 64                                  # 8 device shards of 8 blocks
sharded = mk_engine(nblk, 0, use_fused=True, mesh=mesh)
oracle = mk_engine(nblk, 0, use_fused=True)
for eng in (sharded, oracle):
    eng.alloc.mark_written(list(range(1, 8)))

# adversarial churn: a fresh delta subset per flush (src shard 0, one
# cross-shard copy per delta — distinct dsts, srcs disjoint from dsts, so
# no hazard splits the flush)
subsets = []
for r in (1, 2, 3):
    subsets.extend(itertools.combinations(range(1, 8), r))
subsets = subsets[:3 * fd.MAX_DELTA_SIGNATURES]
for subset in subsets:
    pairs = [(1 + j, delta * 8 + 7) for j, delta in enumerate(subset)]
    for eng in (sharded, oracle):
        eng.memcopy(pairs)                  # autoflush: one launch each

assert_pools_equal(sharded, oracle, "post-fold parity")
info = fd._sharded_runner.cache_info()
print("RESULTS:" + json.dumps({
    "subsets": len(subsets),
    "compiled_bodies": info.misses,
    "max_sigs": fd.MAX_DELTA_SIGNATURES,
    "launches": sharded.stats.launches,
}))
"""


@pytest.mark.slow
@pytest.mark.mesh
def test_jit_cache_bounded_under_adversarial_deltas(tmp_path):
    """3x MAX_DELTA_SIGNATURES flushes with pairwise-distinct delta
    subsets: compiled collective bodies stay O(1) (the threshold plus the
    one folded full-delta body), every flush is still one launch, and the
    folded drains stay bitwise-equal to the single-slab oracle."""
    child = JIT_CACHE_CHILD.replace(
        "__TEST_DIR__", repr(os.path.dirname(os.path.abspath(__file__))))
    res = run_device_subprocess(child, tmp_path=tmp_path)
    assert res["subsets"] == 3 * res["max_sigs"], res
    # unbounded behaviour would compile one body per subset (24); the
    # bound admits MAX distinct signatures + 1 folded body
    assert res["compiled_bodies"] <= res["max_sigs"] + 1, res
    assert res["launches"] == res["subsets"], res


# ---------------------------------------------------------------------------
# regression: an all-NOP/empty flush is a no-op on every path
# ---------------------------------------------------------------------------

@pytest.mark.mesh
def test_unshardable_pool_warns_and_degrades(tmp_path):
    """nblk not divisible by the device shard count can't be partitioned:
    the engine must warn once and fall back to the legacy fan-out rather
    than silently pretending the one-launch invariant holds."""
    script = r"""
import os, warnings
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
os.environ["JAX_PLATFORMS"] = "cpu"
import jax, numpy as np
from jax.sharding import Mesh
from repro.core import RowCloneEngine, SubarrayAllocator
mesh = Mesh(np.asarray(jax.devices()).reshape(2, 4), ("data", "model"))
nblk = 36                      # % 8 != 0 -> unshardable
alloc = SubarrayAllocator(nblk, 4)
pools = {"k": jax.random.normal(jax.random.key(0), (nblk, 4, 8))}
eng = RowCloneEngine(pools, alloc, mesh=mesh)
want = np.asarray(pools["k"])
alloc.mark_written([1])
with warnings.catch_warnings(record=True) as w:
    warnings.simplefilter("always")
    eng.memcopy([(1, 2)])
    eng.memcopy([(3, 4)])      # second flush: warn only once
hits = [x for x in w if "legacy" in str(x.message)]
assert len(hits) == 1, [str(x.message) for x in w]
np.testing.assert_array_equal(np.asarray(eng.pools["k"][2]), want[1])
print("OK")
"""
    out = run_device_subprocess(script, marker=None, timeout=600,
                                tmp_path=tmp_path)
    assert "OK" in out.stdout, out.stdout


@pytest.mark.parametrize("use_fused", [True, False])
def test_empty_and_all_nop_flush_no_launch(use_fused):
    """Empty queue flush and an all-NOP table must not touch the device on
    either dispatch path (the fused path used to burn a launch on a table
    with no valid rows)."""
    eng = mk_engine(32, 0, use_fused=use_fused)
    events = []
    hook = lambda n, p, mech: events.append(mech)
    fd.add_launch_hook(hook)
    try:
        assert eng.flush() == 0
        with eng.batch():
            pass
        eng.memcopy([])
        eng.meminit([], lazy=False)
        table = np.full((8, 3), fd.OP_NOP, np.int32)
        assert eng._dispatch_table(table, 0) == 0
    finally:
        fd.remove_launch_hook(hook)
    assert events == []
    assert eng.stats.launches == 0
