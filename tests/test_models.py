"""Per-arch smoke tests (reduced configs) + prefill/decode consistency."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config, list_archs
from repro.data import make_batch
from repro.models import build_model, split_params
from repro.models.common import rms_norm
from repro.optim import apply_updates, init_state
from repro.configs.base import TrainConfig

ARCHS = list_archs()


def _batch_for(cfg, B, S, key):
    batch = {
        "tokens": jax.random.randint(key, (B, S), 0, cfg.vocab_size),
        "labels": jax.random.randint(key, (B, S), 0, cfg.vocab_size),
        "mask": jnp.ones((B, S), jnp.float32),
    }
    if cfg.family == "vlm":
        batch["patch_embeds"] = jax.random.normal(
            key, (B, cfg.vision_tokens, cfg.d_model)) * 0.02
    if cfg.family == "encdec":
        batch["src_embeds"] = jax.random.normal(
            key, (B, max(S // cfg.src_frames_ratio, 1), cfg.d_model)) * 0.02
    return batch


def test_all_archs_registered():
    assert len(ARCHS) == 10


@pytest.mark.parametrize("arch", ARCHS)
def test_arch_smoke_forward_and_train_step(arch):
    """One forward + one train step on the reduced config: correct shapes,
    no NaNs, params actually change."""
    cfg = get_config(arch).reduced()
    model = build_model(cfg)
    params, _ = split_params(model.init_params(jax.random.key(0)))
    B, S = 2, 64
    batch = _batch_for(cfg, B, S, jax.random.key(1))

    loss, metrics = model.loss_fn(params, batch, None)
    assert np.isfinite(float(loss)), arch
    assert float(loss) > 0

    tcfg = TrainConfig(total_steps=10, warmup_steps=1)
    (l2, _), grads = jax.value_and_grad(
        lambda p: model.loss_fn(p, batch, None), has_aux=True)(params)
    gnorm = float(jnp.sqrt(sum(
        jnp.sum(jnp.square(g.astype(jnp.float32)))
        for g in jax.tree_util.tree_leaves(grads))))
    assert np.isfinite(gnorm) and gnorm > 0, arch
    new_params, _, m = apply_updates(params, grads, init_state(params), tcfg)
    changed = any(
        float(jnp.max(jnp.abs(a.astype(jnp.float32) -
                              b.astype(jnp.float32)))) > 0
        for a, b in zip(jax.tree_util.tree_leaves(params),
                        jax.tree_util.tree_leaves(new_params)))
    assert changed, arch


@pytest.mark.parametrize("arch", ARCHS)
def test_arch_full_config_consistency(arch):
    """Full (unreduced) config sanity: divisibility constraints the sharded
    mesh relies on, and analytic param counts are positive."""
    cfg = get_config(arch)
    assert cfg.padded_vocab % 256 == 0
    if cfg.family not in ("ssm",):
        assert cfg.q_dim % 16 == 0 and cfg.kv_dim % 16 == 0
        assert cfg.num_heads % cfg.num_kv_heads == 0
    if cfg.ssm_heads:
        assert cfg.ssm_heads * cfg.ssm_head_dim == cfg.ssm_d_inner
        assert cfg.ssm_heads % 16 == 0
    if cfg.family == "moe":
        assert cfg.num_experts % 16 == 0
    if cfg.d_ff:
        assert cfg.d_ff % 16 == 0
    assert cfg.param_count() > 0
    assert cfg.active_param_count() <= cfg.param_count()
    if cfg.family == "hybrid":
        assert cfg.num_layers % cfg.shared_attn_every == 0


@pytest.mark.parametrize("arch", ARCHS)
def test_prefill_decode_matches_full_forward(arch):
    """decode_step after prefill == full forward at the last position.
    (MoE differs by train-time capacity dropping; checked with loose tol.)"""
    cfg = get_config(arch).reduced()
    model = build_model(cfg)
    params, _ = split_params(model.init_params(jax.random.key(0)))
    B, S = 2, 64
    key = jax.random.key(2)
    tokens = jax.random.randint(key, (B, S + 1), 0, cfg.vocab_size)
    batch = {"tokens": tokens[:, :S]}
    if cfg.family == "vlm":
        batch["patch_embeds"] = jax.random.normal(
            key, (B, cfg.vision_tokens, cfg.d_model)) * 0.02
    if cfg.family == "encdec":
        batch["src_embeds"] = jax.random.normal(
            key, (B, S // cfg.src_frames_ratio, cfg.d_model)) * 0.02
    _, state = model.prefill(params, batch, None)
    logits_dec, state2 = model.decode_step(params, state, tokens[:, S], None)
    expected_len = S + 1 + (cfg.vision_tokens if cfg.family == "vlm" else 0)
    assert int(state2["seq_lens"][0]) == expected_len
    batch2 = dict(batch, tokens=tokens)
    x, _, _, _, prefix = model._backbone_train(params, batch2, None,
                                               "minimal")
    xn = rms_norm(x[:, -1, :], params["final_norm"].astype(jnp.float32),
                  cfg.norm_eps)
    ref = model._logits(params, xn, None)
    err = float(jnp.max(jnp.abs(logits_dec - ref)))
    if cfg.family == "moe":
        assert err < 1.0  # capacity dropping in the train-path reference
    else:
        assert err < 2e-3, f"{arch}: {err}"


@pytest.mark.parametrize("arch", ["llama3.2-3b", "mamba2-780m",
                                  "zamba2-2.7b"])
def test_multi_step_decode_matches_incremental_forward(arch):
    """Greedy-decode 4 tokens via decode_step; logits at every step match a
    fresh full forward over the growing sequence."""
    cfg = get_config(arch).reduced()
    model = build_model(cfg)
    params, _ = split_params(model.init_params(jax.random.key(0)))
    B, S = 1, 32
    tokens = jax.random.randint(jax.random.key(3), (B, S), 0, cfg.vocab_size)
    _, state = model.prefill(params, batch={"tokens": tokens}, mesh=None,
                             margin_tokens=8)
    seq = np.asarray(tokens)
    for step in range(4):
        nxt = jnp.asarray(seq[:, -1]) if step == 0 else nxt_tok
        if step == 0:
            # feed the last prompt token? No: prefill consumed all S tokens;
            # decode the argmax of prefill logits next.
            pass
        # reference full forward over seq so far
        x, _, _, _, _ = model._backbone_train(
            params, {"tokens": jnp.asarray(seq)}, None, "minimal")
        xn = rms_norm(x[:, -1, :], params["final_norm"].astype(jnp.float32),
                      cfg.norm_eps)
        ref_logits = np.asarray(model._logits(params, xn, None))
        nxt_tok = jnp.asarray(ref_logits.argmax(-1).astype(np.int32))
        logits_dec, state = model.decode_step(params, state, nxt_tok, None)
        seq = np.concatenate([seq, np.asarray(nxt_tok)[:, None]], axis=1)
        # the decode logits must match the next full forward's last position
        x2, _, _, _, _ = model._backbone_train(
            params, {"tokens": jnp.asarray(seq)}, None, "minimal")
        xn2 = rms_norm(x2[:, -1, :],
                       params["final_norm"].astype(jnp.float32),
                       cfg.norm_eps)
        ref2 = np.asarray(model._logits(params, xn2, None))
        np.testing.assert_allclose(np.asarray(logits_dec), ref2, atol=5e-3)


def test_vlm_prefix_is_bidirectional():
    """Early patch positions must attend to later patch positions."""
    cfg = get_config("paligemma-3b").reduced()
    model = build_model(cfg)
    params, _ = split_params(model.init_params(jax.random.key(0)))
    B, S = 1, 32
    key = jax.random.key(4)
    tokens = jax.random.randint(key, (B, S), 0, cfg.vocab_size)
    patches = jax.random.normal(key, (B, cfg.vision_tokens, cfg.d_model))
    batch = {"tokens": tokens, "patch_embeds": patches}
    x1, *_ = model._backbone_train(params, batch, None, "minimal")
    # change the LAST patch; if the prefix were causal, position 0's
    # activation could not change
    patches2 = patches.at[:, -1].add(1.0)
    x2, *_ = model._backbone_train(
        params, dict(batch, patch_embeds=patches2), None, "minimal")
    delta0 = float(jnp.abs(x1[:, 0] - x2[:, 0]).max())
    assert delta0 > 0, "prefix-LM mask is not bidirectional"


def test_data_pipeline_is_deterministic():
    cfg = get_config("llama3.2-3b").reduced()
    b1 = make_batch(cfg, 2, 64, step=7)
    b2 = make_batch(cfg, 2, 64, step=7)
    b3 = make_batch(cfg, 2, 64, step=8)
    np.testing.assert_array_equal(b1["tokens"], b2["tokens"])
    assert not np.array_equal(b1["tokens"], b3["tokens"])
