"""obs subsystem suite: telemetry, tracing, and profiler-driven tuning.

The contracts under test:

* **metrics == journal** — the drain-side counters emitted during a
  scripted flush equal (exactly, not approximately) the per-opcode row
  counts of the flush's :class:`JournalRecord`, and the queue-side
  counters equal the ticket's command count.
* **span nesting** — ``flush`` wraps ``drain`` (depth/parent recorded),
  ``ticket-wait`` records on ``wait()``, and capture/adopt regions keep
  the tree well-formed.
* **TunedProfile** — JSON round-trip, and the startup precedence chain
  *explicit kwarg > tuned profile > built-in default* observed by a real
  ``RowCloneEngine``.
* **autotuner smoke** — the tiny sweep matrix writes a profile the
  loader reads back, with the fused 1-launch invariant intact under
  every swept configuration.
* **bitwise parity** — a deterministic property-style command stream
  produces bit-identical pools and identical launch accounting with
  metrics+tracing ON vs OFF (the "always-on is free" contract).
* **adaptive ring** — sustained low admission pressure shrinks the
  staging ring (slots parked, counters/gauges emitted); demand regrows
  it before an admission would fail.
"""
import importlib.util
import pathlib

import jax
import numpy as np
import pytest

from repro.core import (BlockRef, FlushTicket, RowCloneEngine,
                        SubarrayAllocator, cmdqueue)
from repro.core.opcodes import OPCODE_NAMES
from repro.obs import metrics as obs
from repro.obs import trace
from repro.obs.autotune import (TunedProfile, load_profile, pick_winner,
                                save_profile)


@pytest.fixture(autouse=True)
def _fresh_obs():
    """Each test sees an empty registry/span ring and leaves metrics on."""
    obs.registry().reset()
    trace.reset_spans()
    yield
    obs.registry().reset()
    trace.reset_spans()
    obs.set_metrics_enabled(True)
    trace.set_tracing(True)


def mk_engine(seed=0, nblk=32, snblk=8, **kw):
    alloc = SubarrayAllocator(nblk, 4, reserved_zero_per_slab=1)
    pools = {
        "k": jax.random.normal(jax.random.key(seed), (nblk, 4, 8)),
        "v": jax.random.normal(jax.random.key(seed + 1), (nblk, 4, 8)),
        "k_stage": jax.random.normal(jax.random.key(seed + 2), (snblk, 4, 8)),
        "v_stage": jax.random.normal(jax.random.key(seed + 3), (snblk, 4, 8)),
    }
    return RowCloneEngine(pools, alloc, max_requests=64,
                          staging={"k_stage": "k", "v_stage": "v"}, **kw)


# ---------------------------------------------------------------------------
# metrics == journal (exact equality)
# ---------------------------------------------------------------------------

def test_flush_metrics_match_journal_exactly():
    """Every drain counter of a scripted flush equals the journaled
    record: per-opcode row counts, spacer rows, launches — and the
    queue-side enqueue counters equal the ticket's command count."""
    eng = mk_engine()
    eng.alloc.mark_written([1, 2, 3])
    s = eng.stream("scripted")
    s.memcopy([(1, 5), (2, 6)])
    s.materialize_zeros([9, 10])
    s.memcopy_cross([(BlockRef("k_stage", 2), BlockRef("k", 11))])
    t = s.flush()
    assert isinstance(t, FlushTicket) and t.commands == 5

    rec = eng.journal.records[-1]
    assert rec.stream == "scripted"
    want: dict = {}
    spacers = 0
    for op, _src, _dst in rec.rows:
        if op < 0:
            spacers += 1
        else:
            name = OPCODE_NAMES[int(op)]
            want[name] = want.get(name, 0) + 1

    reg = obs.registry()
    got = {dict(labels)["opcode"]: int(v)
           for labels, v in reg.series("drain.rows").items()
           if dict(labels)["stream"] == "scripted"}
    assert got == want                                  # EXACT equality
    assert int(reg.get("drain.spacer_rows", stream="scripted")) == spacers
    assert int(reg.get("drain.launches", stream="scripted")) \
        == rec.launches == t.launches == 1
    enqueued = sum(v for labels, v in reg.series("queue.enqueued").items()
                   if dict(labels)["stream"] == "scripted")
    assert int(enqueued) == t.commands
    # histograms observed once for the single flush
    assert len(reg.hist("drain.flush_us", stream="scripted")) == 1
    assert reg.hist("drain.table_len", stream="scripted") \
        == [float(t.timing.table_len)]


def test_ticket_timing_field():
    """FlushTicket.timing carries the drain quad; empty flushes have
    None (nothing drained, nothing to time)."""
    eng = mk_engine(seed=2)
    eng.alloc.mark_written([4])
    s = eng.stream("timed")
    s.memcopy([(4, 9)])
    t = s.flush()
    assert t.timing is not None
    assert t.timing.launches == t.launches == 1
    assert t.timing.drain_us > 0.0
    assert t.timing.queue_residency_us >= 0.0
    assert t.timing.table_len >= 1
    t2 = s.flush()
    assert t2.timing is None            # empty flush: no drain happened


# ---------------------------------------------------------------------------
# span nesting
# ---------------------------------------------------------------------------

def test_span_nesting_flush_drain_wait():
    """flush() opens a "flush" span with the "drain" span nested inside
    (depth 1, parent = the flush record); wait() records "ticket-wait"."""
    eng = mk_engine(seed=5)
    eng.alloc.mark_written([2])
    s = eng.stream("spanned")
    with s.capture():                   # capture region: enqueue only
        eng.memcopy([(2, 7)])
    assert trace.spans("drain") == []   # capture alone drains nothing
    s.flush().wait()

    recs = trace.spans()
    flush_idx = [i for i, r in enumerate(recs) if r.name == "flush"]
    drains = [r for r in recs if r.name == "drain"]
    waits = [r for r in recs if r.name == "ticket-wait"]
    assert len(flush_idx) == 1 and len(drains) == 1 and len(waits) == 1
    f = recs[flush_idx[0]]
    d = drains[0]
    assert f.depth == 0 and f.parent == -1
    assert d.depth == 1 and d.parent == flush_idx[0]
    assert waits[0].depth == 0
    assert d.end >= d.start and f.end >= d.end >= f.start
    assert dict(f.labels)["stream"] == "spanned"
    tree = trace.span_tree()
    flush_node = next(n for n in tree if n["name"] == "flush")
    assert [c["name"] for c in flush_node["children"]] == ["drain"]


def test_set_tracing_off_records_nothing():
    """Tracing off: no records, engine behavior unchanged."""
    prev = trace.set_tracing(False)
    try:
        eng = mk_engine(seed=6)
        eng.alloc.mark_written([3])
        s = eng.stream("silent")
        s.memcopy([(3, 8)])
        t = s.flush()
        assert t.launches == 1
        assert trace.spans() == []
    finally:
        trace.set_tracing(prev)


# ---------------------------------------------------------------------------
# TunedProfile round-trip + startup precedence
# ---------------------------------------------------------------------------

def test_profile_roundtrip_and_engine_precedence(tmp_path, monkeypatch):
    """kwarg > profile > default, observed through RowCloneEngine:
    a saved profile's overlap=False applies when the kwarg is omitted,
    an explicit kwarg wins, and no profile means the built-in default."""
    monkeypatch.delenv("REPRO_NO_TUNED", raising=False)
    monkeypatch.setenv("REPRO_TUNED_DIR", str(tmp_path))
    prof = TunedProfile(backend="cpu", buckets=(4, 16, 64, 256),
                        overlap=False, max_delta_signatures=4,
                        ring_capacity=3, us_per_flush=10.0,
                        baseline_us_per_flush=20.0,
                        swept={"flush": {"rows": []}})
    path = save_profile(prof)
    assert path == tmp_path / "cpu.json"
    assert load_profile() == prof                       # JSON round-trip

    eng = mk_engine()                   # no kwarg: profile wins
    assert eng.overlap is False and eng.profile == prof
    eng_kw = mk_engine(overlap=True)    # explicit kwarg beats profile
    assert eng_kw.overlap is True

    monkeypatch.setenv("REPRO_NO_TUNED", "1")
    assert load_profile() is None       # opt-out: no profile at all
    eng_def = mk_engine()
    assert eng_def.overlap is True and eng_def.profile is None


def test_profile_malformed_file_degrades_to_none(tmp_path, monkeypatch):
    monkeypatch.delenv("REPRO_NO_TUNED", raising=False)
    monkeypatch.setenv("REPRO_TUNED_DIR", str(tmp_path))
    (tmp_path / "cpu.json").write_text("{not json")
    assert load_profile() is None


def test_pick_winner_margin_rule():
    """A candidate unseats the default only past the 3% margin; the
    default's absence is an error (the sweep must measure it)."""
    rows = [{"cfg": {"x": 0}, "us_per_flush": 100.0},
            {"cfg": {"x": 1}, "us_per_flush": 98.0}]
    assert pick_winner(rows, {"x": 0})["cfg"] == {"x": 0}   # 2% < margin
    rows[1]["us_per_flush"] = 90.0
    assert pick_winner(rows, {"x": 0})["cfg"] == {"x": 1}   # 10% > margin
    with pytest.raises(ValueError):
        pick_winner(rows, {"x": 99})
    with pytest.raises(ValueError):
        pick_winner([], {"x": 0})


# ---------------------------------------------------------------------------
# autotuner smoke (tiny matrix)
# ---------------------------------------------------------------------------

def _load_bench_autotune():
    path = (pathlib.Path(__file__).resolve().parents[1] / "benchmarks"
            / "bench_autotune.py")
    spec = importlib.util.spec_from_file_location("_test_bench_autotune",
                                                  path)
    mod = importlib.util.module_from_spec(spec)
    spec.loader.exec_module(mod)
    return mod


@pytest.mark.slow
def test_autotune_smoke_writes_loadable_profile(tmp_path, monkeypatch):
    """The quick sweep writes a per-backend profile that load_profile
    reads back, with baseline measured and 1.0 launches/flush under
    every swept configuration."""
    monkeypatch.delenv("REPRO_NO_TUNED", raising=False)
    ba = _load_bench_autotune()
    prof = ba.tune(out_dir=str(tmp_path), quick=True, skip_ring=True,
                   skip_mesh=True)
    assert (tmp_path / f"{prof.backend}.json").is_file()
    loaded = load_profile(directory=str(tmp_path))
    assert loaded == prof
    assert prof.baseline_us_per_flush > 0.0
    assert prof.us_per_flush <= prof.baseline_us_per_flush
    for row in prof.swept["flush"]["rows"]:
        assert row["launches_per_flush"] == 1.0
    # the sweep restored the process-wide default buckets
    assert cmdqueue.get_buckets() == cmdqueue.DEFAULT_BUCKETS


# ---------------------------------------------------------------------------
# metrics-on vs metrics-off: bitwise parity
# ---------------------------------------------------------------------------

def _scripted_rounds(rng, nblk, rounds=4):
    """A deterministic mixed script: per round, a few copies from
    already-written blocks, some zero inits, one cross-pool promotion."""
    script = []
    written = [1, 2, 3]
    for _ in range(rounds):
        srcs = rng.choice(written, size=2, replace=False).tolist()
        dsts = rng.choice(np.arange(nblk // 2, nblk - 1), size=2,
                         replace=False).tolist()
        zeros = rng.choice(np.arange(4, nblk // 2), size=2,
                           replace=False).tolist()
        stage = int(rng.integers(0, 4))
        promote = int(rng.integers(nblk // 2, nblk - 1))
        script.append((list(zip(srcs, dsts)), zeros, stage, promote))
        written = sorted(set(written) | set(dsts))
    return script


def test_metrics_on_off_pools_bitwise_identical():
    """The property-stream contract: the same command script with
    metrics+tracing ON vs OFF yields bit-identical pool bytes and
    identical launch accounting — observability never touches device
    state."""
    script = _scripted_rounds(np.random.default_rng(11), nblk=32)

    def run(flag):
        prev_m = obs.set_metrics_enabled(flag)
        prev_t = trace.set_tracing(flag)
        try:
            eng = mk_engine(seed=9)
            eng.alloc.mark_written([1, 2, 3])
            s = eng.stream("prop")
            launches = []
            for pairs, zeros, stage, promote in script:
                s.memcopy(pairs)
                s.materialize_zeros(zeros)
                s.memcopy_cross([(BlockRef("k_stage", stage),
                                  BlockRef("k", promote))])
                launches.append(s.flush().launches)
            jax.block_until_ready(list(eng.pools.values()))
            return {n: np.asarray(p).tobytes()
                    for n, p in eng.pools.items()}, launches
        finally:
            obs.set_metrics_enabled(prev_m)
            trace.set_tracing(prev_t)

    pools_on, launches_on = run(True)
    pools_off, launches_off = run(False)
    assert launches_on == launches_off
    assert set(pools_on) == set(pools_off)
    for name in pools_on:
        assert pools_on[name] == pools_off[name], \
            f"pool {name!r} bytes diverged metrics-on vs metrics-off"
    # and OFF really suppressed emission
    obs.registry().reset()
    prev = obs.set_metrics_enabled(False)
    try:
        obs.inc("drain.rows", 3, stream="x", opcode="fpm_copy")
    finally:
        obs.set_metrics_enabled(prev)
    assert obs.registry().series("drain.rows") == {}


# ---------------------------------------------------------------------------
# adaptive staging ring
# ---------------------------------------------------------------------------

def _mk_serving(**kw):
    from repro.configs import get_config
    from repro.launch.serve import ServingEngine
    from repro.models import build_model, split_params
    cfg = get_config("llama3.2-3b").reduced()
    model = build_model(cfg)
    params, _ = split_params(model.init_params(jax.random.key(0)))
    return cfg, ServingEngine(cfg, params, max_seqs=8,
                              max_blocks_per_seq=16, max_admit_pages=8,
                              **kw)


@pytest.mark.slow
def test_adaptive_ring_shrinks_then_regrows_on_demand():
    """Sustained low admission pressure parks staging slots (shrink);
    an admission that needs more slots than the clamped ring re-opens it
    before staging, so no admission ever fails to the clamp."""
    from repro.launch.serve import ServingEngine
    cfg, eng = _mk_serving(adaptive_ring=True)
    rng = np.random.default_rng(0)
    prompt = rng.integers(2, cfg.vocab_size, size=24).astype(np.int32)
    eng.add_request(prompt)
    # idle decode rounds: admitted-page pressure stays at/near zero, so
    # two RING_WINDOW cycles are enough to clamp the ring down
    for _ in range(2 * ServingEngine.RING_WINDOW + 1):
        eng.decode_round()
    assert eng.ring_shrinks >= 1
    limit = eng.engine.stage_limit
    assert limit is not None and limit < eng.engine.stage_capacity
    assert len(eng.engine._stage_parked) > 0
    reg = obs.registry()
    assert reg.get("serve.ring_shrinks") == eng.ring_shrinks
    assert reg.gauge_value("serve.ring_limit") == float(limit)
    # free + parked + in-flight always accounts for every slot
    assert len(eng.engine._stage_free) + len(eng.engine._stage_parked) \
        == eng.engine.stage_capacity

    # demand: a 2-page prompt (page_size=64 tokens) against the clamped
    # ring must regrow before staging
    big = rng.integers(2, cfg.vocab_size, size=100).astype(np.int32)
    sid = eng.add_request(big)
    assert eng.ring_regrows >= 1
    assert eng.engine.stage_limit is None       # fully re-opened
    assert reg.get("serve.ring_regrows") == eng.ring_regrows
    eng.decode_round()                          # and serving still works
    assert len(eng.tokens[sid]) >= 1


@pytest.mark.slow
def test_adaptive_ring_off_never_clamps():
    cfg, eng = _mk_serving(adaptive_ring=False)
    rng = np.random.default_rng(1)
    eng.add_request(rng.integers(2, cfg.vocab_size, size=24)
                    .astype(np.int32))
    from repro.launch.serve import ServingEngine
    for _ in range(2 * ServingEngine.RING_WINDOW + 1):
        eng.decode_round()
    assert eng.ring_shrinks == 0
    assert eng.engine.stage_limit is None
    assert eng.engine._stage_parked == []
