"""Serving engine end-to-end: admission, fork correctness, CoW isolation."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.launch.serve import ServingEngine
from repro.models import build_model, split_params
from repro.models.common import rms_norm


@pytest.fixture(scope="module")
def setup():
    cfg = get_config("llama3.2-3b").reduced()
    model = build_model(cfg)
    params, _ = split_params(model.init_params(jax.random.key(0)))
    return cfg, model, params


def _full_forward_logits(model, params, cfg, tokens):
    x, _, _, _, _ = model._backbone_train(
        params, {"tokens": jnp.asarray(tokens)}, None, "minimal")
    xn = rms_norm(x[:, -1, :], params["final_norm"].astype(jnp.float32),
                  cfg.norm_eps)
    return np.asarray(model._logits(params, xn, None))


def test_serving_greedy_matches_full_forward(setup):
    """4 greedy tokens through the engine == argmax replay of full
    forwards (the cache/CoW machinery is semantically invisible)."""
    cfg, model, params = setup
    eng = ServingEngine(cfg, params, max_seqs=8)
    rng = np.random.default_rng(0)
    prompt = rng.integers(2, cfg.vocab_size, size=24).astype(np.int32)
    sid = eng.add_request(prompt)
    seq_ref = prompt.copy()
    for _ in range(4):
        eng.decode_round()
        # reference: greedy from full forward
        ref_logits = _full_forward_logits(model, params, cfg, seq_ref[None])
        ref_next = int(ref_logits.argmax())
        assert eng.tokens[sid][len(seq_ref)] == ref_next
        seq_ref = np.append(seq_ref, ref_next).astype(np.int32)


def test_fork_children_decode_identically_then_isolated(setup):
    """Children share prompt pages; after divergence, appends to one child
    never perturb the other sharers' outputs."""
    cfg, model, params = setup
    eng = ServingEngine(cfg, params, max_seqs=8)
    rng = np.random.default_rng(1)
    prompt = rng.integers(2, cfg.vocab_size, size=20).astype(np.int32)
    sid = eng.add_request(prompt)
    c1, c2 = eng.fork(sid, 2)
    shares0 = eng.engine.alloc.stats.cow_shares
    assert shares0 > 0 and eng.engine.stats.fpm_copies == 0

    eng.decode_round()  # all three decode the same next token
    t_parent = eng.tokens[sid][-1]
    assert eng.tokens[c1][-1] == t_parent
    assert eng.tokens[c2][-1] == t_parent

    # force divergence on c1 by sampling a different token
    forced = {c1: (t_parent + 1) % cfg.vocab_size}

    def sampler_factory():
        def sample(lg):
            return int(np.argmax(lg))
        return sample

    # manual divergent step: append forced token to c1 only
    lg_c1 = eng.last_logits[c1]
    eng.cache.append_token(c1)
    # decode rounds continue greedily; c1's path diverges
    seq_c2_before = list(eng.tokens[c2])
    # run two more rounds for everyone
    eng.decode_round()
    eng.decode_round()
    # c2's tokens are a pure function of the shared prefix: verify against
    # full forward replay
    seq = np.asarray(eng.tokens[c2], np.int32)[None]
    # last token should equal greedy on the previous prefix
    ref = _full_forward_logits(model, params, cfg, seq[:, :-1])
    assert int(ref.argmax()) == eng.tokens[c2][-1]


def test_lazy_zero_blocks_do_not_pollute_attention(setup):
    """ZI leaves garbage bytes in 'zeroed' blocks; attention masking makes
    them unobservable: decoding is identical whether the engine materializes
    zeros or not."""
    cfg, model, params = setup
    rng = np.random.default_rng(2)
    prompt = rng.integers(2, cfg.vocab_size, size=12).astype(np.int32)

    eng_zi = ServingEngine(cfg, params, max_seqs=4)
    # poison the pool so any leak is visible
    eng_zi.engine.pools["k"] = jnp.full_like(eng_zi.engine.pools["k"], 50.0)
    eng_zi.engine.pools["v"] = jnp.full_like(eng_zi.engine.pools["v"], 50.0)
    sid = eng_zi.add_request(prompt)
    eng_zi.decode_round()

    from repro.configs import RowCloneConfig
    eng_mat = ServingEngine(cfg, params, max_seqs=4,
                            rc=RowCloneConfig(enable_zi=False))
    sid2 = eng_mat.add_request(prompt)
    eng_mat.decode_round()
    assert eng_zi.tokens[sid][-1] == eng_mat.tokens[sid2][-1]
    assert eng_zi.engine.stats.zero_lazy > 0


def test_rowclone_stats_accumulate(setup):
    cfg, model, params = setup
    eng = ServingEngine(cfg, params, max_seqs=16)
    rng = np.random.default_rng(3)
    for _ in range(3):
        eng.add_request(rng.integers(2, cfg.vocab_size,
                                     size=12).astype(np.int32))
    sid0 = sorted(eng.cache.seqs)[0]
    eng.fork(sid0, 3)
    for _ in range(6):
        eng.decode_round()
    s = eng.engine.stats
    a = eng.engine.alloc.stats
    assert a.cow_shares >= 3
    assert s.fpm_copies >= 1            # CoW splits after fork divergence
    assert s.zero_lazy >= 3             # fresh prompt blocks BuZ'd lazily
    assert s.bytes_avoided > 0
    assert a.fpm_eligible > 0           # subarray-aware placement worked
