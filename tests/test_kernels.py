"""Per-kernel validation: Pallas (interpret mode) vs pure-jnp oracles,
swept over shapes and dtypes, plus hypothesis property tests."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypo import given, settings, st

from repro.kernels import ref as kref
from repro.kernels.flash_attention import flash_attention_pallas
from repro.kernels.fpm_copy import fpm_copy_cross_pallas, fpm_copy_pallas
from repro.kernels.paged_attention import paged_attention_slab_pallas
from repro.kernels.ssd_chunk import ssd_intra_chunk_pallas
from repro.kernels.zero_init import zero_init_pallas
from repro.models.mamba2 import _ssd_intra_chunk_jnp


# ---------------------------------------------------------------------------
# FPM copy
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16, jnp.int32])
@pytest.mark.parametrize("block_shape", [(8, 128), (16, 4, 64), (128,)])
def test_fpm_copy_shapes_dtypes(dtype, block_shape):
    nblk = 16
    key = jax.random.key(0)
    pool = (jax.random.normal(key, (nblk,) + block_shape) * 10).astype(dtype)
    ids = jnp.array([[0, 5], [3, 7], [2, -1], [1, 9]], jnp.int32)
    out = fpm_copy_pallas(pool.copy(), ids, interpret=True)
    ref = kref.fpm_copy(pool, ids[:, 0], ids[:, 1])
    np.testing.assert_array_equal(np.asarray(out), np.asarray(ref))


@settings(max_examples=25, deadline=None)
@given(st.data())
def test_fpm_copy_property(data):
    """Engine contract: destinations are disjoint from sources (CoW targets
    are fresh blocks), sources read the pre-copy pool state."""
    nblk = data.draw(st.integers(8, 32))
    half = nblk // 2
    m = data.draw(st.integers(1, min(half, 8)))
    srcs = data.draw(st.lists(st.integers(0, half - 1), min_size=m,
                              max_size=m))
    dsts = data.draw(st.lists(st.integers(half, nblk - 1), min_size=m,
                              max_size=m, unique=True))
    pool = jnp.arange(nblk * 8, dtype=jnp.float32).reshape(nblk, 8)
    ids = jnp.asarray(np.stack([srcs, dsts], 1).astype(np.int32))
    out = np.asarray(fpm_copy_pallas(pool.copy(), ids, interpret=True))
    ref = np.array(pool)  # writable copy
    for s, d in zip(srcs, dsts):
        ref[d] = np.asarray(pool)[s]
    np.testing.assert_array_equal(out, ref)


def test_fpm_copy_cross():
    src = jax.random.normal(jax.random.key(1), (8, 4, 128))
    dst = jnp.zeros((12, 4, 128))
    ids = jnp.array([[0, 3], [7, 11], [2, -1]], jnp.int32)
    out = fpm_copy_cross_pallas(dst.copy(), src, ids, interpret=True)
    ref = kref.fpm_copy_cross(dst, src, ids[:, 0], ids[:, 1])
    np.testing.assert_array_equal(np.asarray(out), np.asarray(ref))


# ---------------------------------------------------------------------------
# zero init (BuZ)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_zero_init(dtype):
    pool = (jax.random.normal(jax.random.key(2), (10, 8, 128)) + 1).astype(dtype)
    zb = jnp.zeros((1, 8, 128), dtype)
    ids = jnp.array([1, 4, -1, 9], jnp.int32)
    out = zero_init_pallas(pool.copy(), zb, ids, interpret=True)
    ref = kref.zero_init(pool, ids)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(ref))
    assert float(jnp.abs(out[1]).max()) == 0
    assert float(jnp.abs(out[0]).max()) > 0


# ---------------------------------------------------------------------------
# paged attention slab
# ---------------------------------------------------------------------------

def _random_paged_case(key, B, H, KVH, D, page, nblk, max_len):
    ks = jax.random.split(key, 8)
    q = jax.random.normal(ks[0], (B, H, D), jnp.float32)
    k_slab = jax.random.normal(ks[1], (nblk, page, KVH, D), jnp.float32)
    v_slab = jax.random.normal(ks[2], (nblk, page, KVH, D), jnp.float32)
    lens = jax.random.randint(ks[3], (B,), 1, max_len + 1)
    # contiguous identity layout
    nper = nblk // B
    mask = np.zeros((nblk, B), np.int8)
    base = np.zeros(nblk, np.int32)
    for b in range(B):
        for j in range(nper):
            mask[b * nper + j, b] = 1
            base[b * nper + j] = j * page
    return q, k_slab, v_slab, jnp.asarray(mask), jnp.asarray(base), lens


@pytest.mark.parametrize("B,H,KVH,D,page", [
    (4, 8, 2, 64, 16), (2, 4, 4, 128, 8), (8, 16, 1, 128, 16),
])
def test_paged_attention_kernel_vs_ref(B, H, KVH, D, page):
    nblk = B * 4
    q, ks_, vs_, mask, base, lens = _random_paged_case(
        jax.random.key(3), B, H, KVH, D, page, nblk, 4 * page)
    out_p = paged_attention_slab_pallas(q, ks_, vs_, mask, base, lens,
                                        page=page, block_chunk=4,
                                        interpret=True)
    out_r = kref.paged_attention_slab(q, ks_, vs_, mask, base, lens,
                                      page=page, block_chunk=4)
    for a, b in zip(out_p, out_r):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-4)


def test_paged_attention_vs_dense_oracle():
    """Slab partials normalized == dense attention over contiguous cache."""
    B, H, KVH, D, page = 3, 6, 2, 32, 8
    nper, nblk = 4, 12
    q, ks_, vs_, mask, base, lens = _random_paged_case(
        jax.random.key(4), B, H, KVH, D, page, nblk, nper * page)
    acc, l, m = kref.paged_attention_slab(q, ks_, vs_, mask, base, lens,
                                          page=page)
    out = np.asarray(acc / np.maximum(np.asarray(l), 1e-30)[..., None])
    k_dense = np.asarray(ks_).reshape(B, nper * page, KVH, D)
    v_dense = np.asarray(vs_).reshape(B, nper * page, KVH, D)
    ref = kref.paged_attention_dense_ref(q, jnp.asarray(k_dense),
                                         jnp.asarray(v_dense), lens)
    np.testing.assert_allclose(out, np.asarray(ref), atol=1e-5)


def test_paged_attention_cow_sharing():
    """A block shared by two sequences contributes to both."""
    B, H, KVH, D, page, nblk = 2, 4, 2, 32, 8, 4
    key = jax.random.key(5)
    q = jax.random.normal(key, (B, H, D))
    ks_ = jax.random.normal(jax.random.key(6), (nblk, page, KVH, D))
    vs_ = jax.random.normal(jax.random.key(7), (nblk, page, KVH, D))
    # block 0 shared at position 0 by both; blocks 1,2 private tails
    mask = jnp.asarray(np.array([[1, 1], [1, 0], [0, 1], [0, 0]], np.int8))
    base = jnp.asarray(np.array([0, page, page, 0], np.int32))
    lens = jnp.asarray(np.array([2 * page, page + 3], np.int32))
    acc, l, m = kref.paged_attention_slab(q, ks_, vs_, mask, base, lens,
                                          page=page)
    out = np.asarray(acc / np.maximum(np.asarray(l), 1e-30)[..., None])
    # dense reference per sequence
    k0 = np.concatenate([np.asarray(ks_[0]), np.asarray(ks_[1])])[None]
    v0 = np.concatenate([np.asarray(vs_[0]), np.asarray(vs_[1])])[None]
    k1 = np.concatenate([np.asarray(ks_[0]), np.asarray(ks_[2])])[None]
    v1 = np.concatenate([np.asarray(vs_[0]), np.asarray(vs_[2])])[None]
    r0 = kref.paged_attention_dense_ref(q[:1], jnp.asarray(k0),
                                        jnp.asarray(v0), lens[:1])
    r1 = kref.paged_attention_dense_ref(q[1:], jnp.asarray(k1),
                                        jnp.asarray(v1), lens[1:])
    np.testing.assert_allclose(out[0], np.asarray(r0)[0], atol=1e-5)
    np.testing.assert_allclose(out[1], np.asarray(r1)[0], atol=1e-5)


@settings(max_examples=10, deadline=None)
@given(st.integers(1, 4), st.integers(0, 1), st.integers(1, 3))
def test_paged_attention_property_lengths(B, kvh_pow, nper):
    """Random valid lengths: normalized output finite, masked slots inert."""
    KVH = 2 ** kvh_pow
    H, D, page = 2 * KVH, 32, 8
    nblk = B * nper
    q, ks_, vs_, mask, base, lens = _random_paged_case(
        jax.random.key(8), B, H, KVH, D, page, nblk, nper * page)
    acc, l, m = kref.paged_attention_slab(q, ks_, vs_, mask, base, lens,
                                          page=page)
    out = np.asarray(acc / np.maximum(np.asarray(l), 1e-30)[..., None])
    assert np.isfinite(out).all()
    # mutating data beyond each sequence's length must not change output
    spoiled = np.asarray(ks_).copy()
    for b in range(B):
        L = int(lens[b])
        blk, off = L // page, L % page
        g = b * nper + blk
        if blk < nper:
            spoiled[g, off:] = 1e9
        for j in range(blk + 1, nper):
            spoiled[b * nper + j] = 1e9
    acc2, l2, _ = kref.paged_attention_slab(
        q, jnp.asarray(spoiled), vs_, mask, base, lens, page=page)
    out2 = np.asarray(acc2 / np.maximum(np.asarray(l2), 1e-30)[..., None])
    np.testing.assert_allclose(out, out2, atol=1e-5)


# ---------------------------------------------------------------------------
# flash attention
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("S,prefix,causal", [
    (64, 0, True), (128, 16, True), (64, 0, False),
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_kernel_vs_ref(S, prefix, causal, dtype):
    B, H, KVH, D = 2, 4, 2, 64
    q = jax.random.normal(jax.random.key(9), (B, H, S, D)).astype(dtype)
    k = jax.random.normal(jax.random.key(10), (B, KVH, S, D)).astype(dtype)
    v = jax.random.normal(jax.random.key(11), (B, KVH, S, D)).astype(dtype)
    out = flash_attention_pallas(q, k, v, causal=causal, prefix_len=prefix,
                                 bq=32, bk=32, interpret=True)
    pos = jnp.broadcast_to(jnp.arange(S), (B, S)).astype(jnp.int32)
    ref = kref.flash_attention_ref(
        q.transpose(0, 2, 1, 3), k.transpose(0, 2, 1, 3),
        v.transpose(0, 2, 1, 3), pos, pos, jnp.ones((B, S), bool),
        causal=causal, prefix_len=prefix).transpose(0, 2, 1, 3)
    atol = 1e-4 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32), atol=atol)


def test_flash_jnp_scan_vs_ref():
    """The in-model scan flash (models/attention.py) vs naive oracle."""
    from repro.models.attention import MaskInfo, flash_attention
    B, S, H, KVH, D = 2, 96, 4, 2, 32
    q = jax.random.normal(jax.random.key(12), (B, S, H, D))
    k = jax.random.normal(jax.random.key(13), (B, S, KVH, D))
    v = jax.random.normal(jax.random.key(14), (B, S, KVH, D))
    pos = jnp.broadcast_to(jnp.arange(S), (B, S)).astype(jnp.int32)
    valid = jnp.ones((B, S), bool)
    out = flash_attention(q, k, v, pos, pos, valid,
                          MaskInfo(causal=True, prefix_len=8), kv_chunk=32)
    ref = kref.flash_attention_ref(q, k, v, pos, pos, valid, causal=True,
                                   prefix_len=8)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=1e-4)


# ---------------------------------------------------------------------------
# SSD intra-chunk
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("Q,P,N", [(32, 16, 8), (64, 32, 16)])
def test_ssd_intra_kernel_vs_ref(Q, P, N):
    B, H = 2, 4
    xb = jax.random.normal(jax.random.key(15), (B, Q, H, P))
    dtb = jax.nn.softplus(jax.random.normal(jax.random.key(16), (B, Q, H)))
    cum = jnp.cumsum(-0.2 * dtb, axis=1)
    Bm = jax.random.normal(jax.random.key(17), (B, Q, N))
    Cm = jax.random.normal(jax.random.key(18), (B, Q, N))
    out = ssd_intra_chunk_pallas(xb, dtb, cum, Bm, Cm, interpret=True)
    ref = _ssd_intra_chunk_jnp(xb, dtb, cum, Bm, Cm)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=1e-4)


def test_ssd_chunked_vs_naive_recurrence():
    """Chunked SSD == token-by-token recurrence (the paper-exact check)."""
    from repro.models.mamba2 import ssd_chunked
    B, S, H, P, N = 2, 64, 4, 16, 8
    x = jax.random.normal(jax.random.key(19), (B, S, H, P)) * 0.5
    dt = jax.nn.softplus(jax.random.normal(jax.random.key(20), (B, S, H)))
    A = -jnp.exp(jax.random.normal(jax.random.key(21), (H,)) * 0.3)
    Bm = jax.random.normal(jax.random.key(22), (B, S, N)) * 0.5
    Cm = jax.random.normal(jax.random.key(23), (B, S, N)) * 0.5
    D = jnp.ones((H,))
    y_chunk, h_chunk = ssd_chunked(x, dt, A, Bm, Cm, D, chunk=16)
    y_ref = kref.ssd_ref(x, dt, A, Bm, Cm, D)
    np.testing.assert_allclose(np.asarray(y_chunk), np.asarray(y_ref),
                               atol=2e-3, rtol=1e-3)


def test_ssd_final_state_matches_decode_seed():
    """h_final from the chunked path == state after running the naive
    recurrence, so prefill->decode handoff is exact."""
    from repro.models.mamba2 import ssd_chunked
    B, S, H, P, N = 1, 48, 2, 8, 4
    x = jax.random.normal(jax.random.key(24), (B, S, H, P)) * 0.5
    dt = jax.nn.softplus(jax.random.normal(jax.random.key(25), (B, S, H)))
    A = -jnp.exp(jnp.zeros((H,)))
    Bm = jax.random.normal(jax.random.key(26), (B, S, N)) * 0.5
    Cm = jax.random.normal(jax.random.key(27), (B, S, N)) * 0.5
    Dk = jnp.zeros((H,))
    _, h_final = ssd_chunked(x, dt, A, Bm, Cm, Dk, chunk=16)
    # naive state
    h = np.zeros((B, H, P, N), np.float32)
    for t in range(S):
        decay = np.exp(np.asarray(dt[:, t]) * np.asarray(A)[None])
        h = h * decay[..., None, None] + np.einsum(
            "bhp,bn,bh->bhpn", np.asarray(x[:, t], np.float32),
            np.asarray(Bm[:, t], np.float32), np.asarray(dt[:, t]))
    np.testing.assert_allclose(np.asarray(h_final), h, atol=2e-3, rtol=1e-3)


def test_paged_attention_exclusive_mode_matches_allpairs():
    """owner-gather fast path == all-pairs when no block is shared."""
    B, H, KVH, D, page = 4, 8, 2, 64, 16
    nblk = B * 4
    q, ks_, vs_, mask, base, lens = _random_paged_case(
        jax.random.key(30), B, H, KVH, D, page, nblk, 4 * page)
    a1 = kref.paged_attention_slab(q, ks_, vs_, mask, base, lens, page=page,
                                   block_chunk=4, exclusive=False)
    a2 = kref.paged_attention_slab(q, ks_, vs_, mask, base, lens, page=page,
                                   block_chunk=4, exclusive=True)
    o1 = np.asarray(a1[0] / np.maximum(np.asarray(a1[1]), 1e-30)[..., None])
    o2 = np.asarray(a2[0] / np.maximum(np.asarray(a2[1]), 1e-30)[..., None])
    np.testing.assert_allclose(o1, o2, atol=1e-5)


def test_psm_rdma_kernel_traces_on_multidevice_mesh():
    """PSM remote-DMA kernel (TARGET TPU code — RDMA can't execute on CPU):
    abstract evaluation inside shard_map must succeed, proving the kernel
    body, BlockSpecs, and semaphore plumbing are well-formed."""
    import subprocess, sys, os, textwrap
    script = textwrap.dedent("""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
        import jax, jax.numpy as jnp, numpy as np
        from jax.sharding import Mesh, PartitionSpec as P
        from repro.compat import shard_map
        from repro.kernels.psm_transfer import psm_transfer_pallas
        mesh = Mesh(np.asarray(jax.devices()).reshape(2, 4),
                    ("data", "model"))
        def local(pool_slab, ids):
            return psm_transfer_pallas.__wrapped__(pool_slab, ids,
                                                   axis_name="model")
        with mesh:
            out = jax.eval_shape(
                lambda p, i: shard_map(
                    local, mesh=mesh, in_specs=(P("model"), P(None)),
                    out_specs=P("model"), check_vma=False)(p, i),
                jax.ShapeDtypeStruct((32, 16, 128), jnp.float32),
                jax.ShapeDtypeStruct((3, 3), jnp.int32))
        assert out.shape == (32, 16, 128)
        print("OK")
    """)
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.abspath(
        os.path.join(os.path.dirname(__file__), "..", "src"))
    out = subprocess.run([sys.executable, "-c", script], env=env,
                         capture_output=True, text=True, timeout=600)
    assert out.returncode == 0 and "OK" in out.stdout, out.stderr[-2000:]
