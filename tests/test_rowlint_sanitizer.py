"""Mutation tests for the static/dynamic contract checkers (PR 9).

Three legs:

* **rowlint mutations** — the linter passes on the real tree, then each
  rule (RC101..RC104) is exercised by seeding its violation into a
  copied tree and asserting the lint catches exactly that rule (plus the
  line-waiver escape hatch).
* **sanitizer violations** — hand-built corrupt tables driven through
  ``RowCloneEngine(sanitize=True)``'s drain path must raise
  :class:`SanitizerError` with the right check id and leave pool bytes
  untouched (fail-stop), including a shadow-execution diff seeded by
  corrupting the dispatch kernel.
* **REPRO_SANITIZE=1 streams** — the dispatch property streams run on a
  sanitized engine and a plain twin: bitwise-equal pools, identical
  launch events (the oracle issues no launches), zero findings.
"""
import dataclasses
import pathlib
import random
import shutil
import sys

import jax
import numpy as np
import pytest

REPO = pathlib.Path(__file__).resolve().parent.parent
sys.path.insert(0, str(REPO))     # the `tools` package (rowlint)

from tools import rowlint  # noqa: E402

from repro.core import (RowCloneEngine, SubarrayAllocator,  # noqa: E402
                        opcodes as oc)
from repro.core.cmdqueue import partition_commands  # noqa: E402
from repro.core.journal import JournalRecord, RecoveryError  # noqa: E402
from repro.core.opcodes import (MAX_PACK_BLOCKS, OP_AND,  # noqa: E402
                                OP_FPM_COPY, OP_NOP, check_pack_total,
                                pack_bitwise_src, unpack_bitwise_src)
from repro.core.sanitizer import (DrainSanitizer,  # noqa: E402
                                  SanitizerError)
from repro.kernels import ops as kops  # noqa: E402
from test_dispatch_properties import (assert_pools_equal,  # noqa: E402
                                      gen_program, mk_engine, run_program)


# ---------------------------------------------------------------------------
# rowlint: clean tree + seeded mutations
# ---------------------------------------------------------------------------

def _copy_tree(tmp_path: pathlib.Path) -> pathlib.Path:
    """Copy src/repro + tools into a scratch root rowlint can lint."""
    root = tmp_path / "mutant"
    (root / "src").mkdir(parents=True)
    ignore = shutil.ignore_patterns("__pycache__")
    shutil.copytree(REPO / "src" / "repro", root / "src" / "repro",
                    ignore=ignore)
    shutil.copytree(REPO / "tools", root / "tools", ignore=ignore)
    return root


def _rules(violations):
    return {v.rule for v in violations}


def test_rowlint_clean_on_real_tree():
    assert rowlint.lint(REPO) == []


def test_rowlint_rc101_unregistered_opcode(tmp_path):
    root = _copy_tree(tmp_path)
    mod = root / "src" / "repro" / "core" / "cmdqueue.py"
    mod.write_text(mod.read_text() + "\n_MUTANT = OP_STRIDED_COPY\n")
    found = rowlint.lint(root)
    assert _rules(found) == {"RC101"}
    assert any("OP_STRIDED_COPY" in v.message for v in found)


def test_rowlint_rc101_waiver_suppresses(tmp_path):
    root = _copy_tree(tmp_path)
    mod = root / "src" / "repro" / "core" / "cmdqueue.py"
    mod.write_text(mod.read_text()
                   + "\n_MUTANT = OP_STRIDED_COPY  "
                     "# rowlint: disable=RC101\n")
    assert rowlint.lint(root) == []


def test_rowlint_rc102_stacked_id_arithmetic(tmp_path):
    root = _copy_tree(tmp_path)
    mod = root / "src" / "repro" / "core" / "journal.py"
    mod.write_text(mod.read_text()
                   + "\n\ndef _mutant_gid(pool, nblk, block):\n"
                     "    return pool * nblk + block\n")
    assert _rules(rowlint.lint(root)) == {"RC102"}


def test_rowlint_rc102_legal_in_poolspec(tmp_path):
    # the codec module itself is the one allowed home for the arithmetic
    root = _copy_tree(tmp_path)
    mod = root / "src" / "repro" / "core" / "poolspec.py"
    mod.write_text(mod.read_text()
                   + "\n\ndef _mutant_gid(pool, nblk, block):\n"
                     "    return pool * nblk + block\n")
    assert rowlint.lint(root) == []


def test_rowlint_rc103_pool_mutation(tmp_path):
    root = _copy_tree(tmp_path)
    mod = root / "src" / "repro" / "core" / "journal.py"
    mod.write_text(mod.read_text()
                   + "\n\ndef _mutant_write(engine, name, arr):\n"
                     "    engine.pools[name] = arr\n")
    assert _rules(rowlint.lint(root)) == {"RC103"}


def test_rowlint_rc104_verb_without_mirror(tmp_path):
    root = _copy_tree(tmp_path)
    mod = root / "src" / "repro" / "core" / "rowclone.py"
    src = mod.read_text()
    verb = ('    def memswap(self, pairs):\n'
            '        """Mutant verb: enqueues with no stream mirror."""\n'
            '        for s, d in pairs:\n'
            '            self._queues["default"].enqueue(0, s, d)\n'
            '\n'
            '    def memand(')
    assert "    def memand(" in src
    mod.write_text(src.replace("    def memand(", verb, 1))
    found = rowlint.lint(root)
    assert _rules(found) == {"RC104"}
    # both halves of the rule fire: no mirror AND no check_docs pin
    assert any("no\nCommandStream mirror" in v.message
               or "no CommandStream mirror" in v.message for v in found)
    assert any("check_docs pin" in v.message for v in found)


def test_rowlint_rc104_dropped_pin(tmp_path):
    # deleting a REQUIRED_SYMBOLS pin for an existing verb is caught too
    root = _copy_tree(tmp_path)
    docs = root / "tools" / "check_docs.py"
    src = docs.read_text()
    pin = '    "repro.core.stream.CommandStream.memcopy",\n'
    assert pin in src
    docs.write_text(src.replace(pin, "", 1))
    found = rowlint.lint(root)
    assert _rules(found) == {"RC104"}
    assert any("memcopy" in v.message for v in found)


# ---------------------------------------------------------------------------
# sanitizer: seeded violations fail stopped, with the right check id
# ---------------------------------------------------------------------------

def _sane_engine(nblk=8):
    alloc = SubarrayAllocator(nblk, 4, reserved_zero_per_slab=1)
    pools = {
        "k": jax.random.normal(jax.random.key(0), (nblk, 4, 8)),
        "k_stage": jax.random.normal(jax.random.key(1), (nblk, 4, 8)),
    }
    return RowCloneEngine(pools, alloc, max_requests=64, use_fused=True,
                          staging={"k_stage": "k"}, sanitize=True)


def _pool_bytes(eng):
    return {n: np.asarray(p).tobytes() for n, p in eng.pools.items()}


def _assert_drain_fails(eng, rows, check):
    before = _pool_bytes(eng)
    with pytest.raises(SanitizerError) as ei:
        eng._drain_rows(rows, pre_spaced=True)
    assert check in {f.check for f in ei.value.report.findings}
    assert not ei.value.report.ok
    # fail-stop: the violating chunk never dispatched
    assert _pool_bytes(eng) == before


def test_sanitizer_catches_adjacent_war():
    # row 1 writes block 0, which row 0 reads: the dropped-spacer race
    _assert_drain_fails(_sane_engine(),
                        [(OP_FPM_COPY, 0, 1), (OP_FPM_COPY, 2, 0)],
                        "war-adjacency")


def test_sanitizer_catches_raw_pair():
    # row 1 reads block 1, which row 0 writes: must have been flush-split
    _assert_drain_fails(_sane_engine(),
                        [(OP_FPM_COPY, 0, 1), (OP_NOP, -1, -1),
                         (OP_FPM_COPY, 1, 2)],
                        "raw-waw-free")


def test_sanitizer_catches_malformed_nop():
    _assert_drain_fails(_sane_engine(), [(OP_NOP, 3, 7)],
                        "nop-well-formed")


def test_sanitizer_catches_misdeclared_dst():
    eng = _sane_engine()
    total = eng.group.total_blocks
    # a bitwise row whose dst is outside the global id space
    _assert_drain_fails(eng, [(OP_AND, pack_bitwise_src(1, 2, total),
                               total + 5)],
                        "operand-contract")


def test_sanitizer_catches_unknown_opcode():
    _assert_drain_fails(_sane_engine(), [(42, 0, 1)], "opcode-registry")


def test_sanitizer_catches_staging_illegal_dst(monkeypatch):
    # no shipped opcode forbids staging destinations, so tighten the
    # registry entry for cross-pool copies and aim one at the stage ring
    eng = _sane_engine()
    sp = oc.OPCODES[oc.OP_CROSS_POOL_COPY]
    monkeypatch.setitem(oc.OPCODES, oc.OP_CROSS_POOL_COPY,
                        dataclasses.replace(sp, staging_dst_ok=False))
    gid = eng.group.base("k_stage") + 1
    _assert_drain_fails(eng, [(oc.OP_CROSS_POOL_COPY, 0, gid)],
                        "staging-legality")


def test_sanitizer_shadow_diff(monkeypatch):
    # corrupt the real dispatch: the jnp oracle disagrees bitwise
    eng = _sane_engine()
    real = kops.fused_dispatch

    def bad(pools, zero_blocks, cmds, **kw):
        out = list(real(pools, zero_blocks, cmds, **kw))
        out[0] = out[0].at[2].add(1.0)
        return tuple(out)

    monkeypatch.setattr(kops, "fused_dispatch", bad)
    with pytest.raises(SanitizerError) as ei:
        eng._drain_rows([(OP_FPM_COPY, 0, 1)], pre_spaced=True)
    assert {f.check for f in ei.value.report.findings} == {"shadow-diff"}


def test_sanitizer_clean_drain_reports():
    eng = _sane_engine()
    eng._drain_rows([(OP_FPM_COPY, 0, 1), (OP_NOP, -1, -1),
                     (OP_FPM_COPY, 2, 3)], pre_spaced=True)
    san = eng.sanitizer
    assert san.tables_checked == 1 and san.shadow_runs == 1
    assert all(r.ok for r in san.reports)
    # reports[0] is the table receipt, reports[-1] the shadow receipt
    assert san.reports[0].rows == 2
    assert "war-adjacency" in san.reports[0].checks
    assert san.reports[-1].checks == ("shadow-diff",)


def test_sanitizer_plan_partition():
    eng = mk_engine(16, 0, True)
    san = DrainSanitizer(eng)
    rows = [(OP_FPM_COPY, 0, 1), (OP_FPM_COPY, 8, 9)]
    replicated = tuple([False] * len(eng.group))
    plan = partition_commands(rows, n_shards=2, group=eng.group,
                              replicated=replicated)
    san.check_plan(rows, plan, replicated)          # exact partition: ok
    assert san.plans_checked == 1
    with pytest.raises(SanitizerError) as ei:
        # a row the plan never partitioned: want/got sets diverge
        san.check_plan(rows + [(OP_FPM_COPY, 4, 5)], plan, replicated)
    assert "plan-partition" in {f.check for f in ei.value.report.findings}


# ---------------------------------------------------------------------------
# journal replay + packing-bound contract enforcement
# ---------------------------------------------------------------------------

def test_replay_rejects_unregistered_opcode():
    eng = _sane_engine()
    eng.journal.append(JournalRecord(stream="x", index=99,
                                     rows=((42, 0, 1),)))
    with pytest.raises(RecoveryError, match="opcode contract"):
        eng.journal.replay(eng, after=98)


def test_replay_rejects_malformed_padding():
    eng = _sane_engine()
    eng.journal.append(JournalRecord(stream="x", index=99,
                                     rows=((OP_NOP, 3, 7),)))
    with pytest.raises(RecoveryError, match="padding row"):
        eng.journal.replay(eng, after=98)


def test_replay_rejects_packed_src_outside_square():
    eng = _sane_engine()
    total = eng.group.total_blocks
    eng.journal.append(JournalRecord(stream="x", index=99,
                                     rows=((OP_AND, total * total, 1),)))
    with pytest.raises(RecoveryError, match="opcode contract"):
        eng.journal.replay(eng, after=98)


def test_pack_bitwise_bound():
    check_pack_total(MAX_PACK_BLOCKS)
    with pytest.raises(ValueError):
        check_pack_total(MAX_PACK_BLOCKS + 1)
    with pytest.raises(ValueError):
        pack_bitwise_src(0, 0, MAX_PACK_BLOCKS + 1)
    s = pack_bitwise_src(3, 5, 100)
    assert unpack_bitwise_src(s, 100) == (3, 5)
    with pytest.raises(ValueError):
        unpack_bitwise_src(100 * 100, 100)


# ---------------------------------------------------------------------------
# REPRO_SANITIZE=1: property streams, sanitized vs plain twin
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("seed", [0, 1, 2])
def test_sanitized_streams_bitwise_and_launch_parity(monkeypatch, seed):
    rng = random.Random(seed)
    prog = gen_program(rng, 16, 6)
    monkeypatch.setenv("REPRO_SANITIZE", "1")
    eng_s = mk_engine(16, 0, True, seed=seed)
    assert eng_s.sanitizer is not None     # env attached the sanitizer
    monkeypatch.setenv("REPRO_SANITIZE", "0")
    eng_p = mk_engine(16, 0, True, seed=seed)
    assert eng_p.sanitizer is None

    events_s = run_program(eng_s, prog)
    events_p = run_program(eng_p, prog)

    # the oracle shadow issues no launches: identical accounting
    assert events_s == events_p
    assert_pools_equal(eng_s, eng_p, ctx=f"sanitized twin seed={seed}")
    san = eng_s.sanitizer
    assert san.tables_checked > 0
    assert san.shadow_runs == san.tables_checked
    assert all(r.ok for r in san.reports)
