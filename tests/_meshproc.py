"""Shared harness for multi-device subprocess tests.

jax locks the host device count at first init, so every mesh test forks a
fresh interpreter whose script sets ``XLA_FLAGS`` before importing jax.
This helper owns the env plumbing and the ``MARKER:json`` stdout protocol
so the call sites (tests/test_multidevice.py, tests/test_dispatch.py,
tests/test_dispatch_properties.py) don't each re-implement — and drift —
the boilerplate.  benchmarks/bench_dispatch.py keeps its own copy: it must
run standalone without tests/ on the path.
"""
from __future__ import annotations

import json
import os
import subprocess
import sys
from typing import Optional, Sequence

TESTS_DIR = os.path.dirname(os.path.abspath(__file__))
SRC_DIR = os.path.abspath(os.path.join(TESTS_DIR, "..", "src"))


def run_device_subprocess(script: str, *, args: Sequence[str] = (),
                          marker: Optional[str] = "RESULTS:",
                          timeout: int = 1200, tmp_path=None):
    """Run ``script`` in a fresh interpreter with src/ on PYTHONPATH.

    The script itself must set XLA_FLAGS/JAX_PLATFORMS before importing
    jax (device count is fixed at first init).  Returns the JSON payload
    following ``marker`` on stdout; with ``marker=None`` returns the raw
    CompletedProcess (caller asserts on stdout).  Fails loudly with the
    subprocess stderr tail on non-zero exit or a missing marker line.
    """
    if tmp_path is not None:
        path = tmp_path / "mesh_script.py"
        path.write_text(script)
        cmd = [sys.executable, str(path), *args]
    else:
        cmd = [sys.executable, "-c", script, *args]
    env = dict(os.environ)
    env["PYTHONPATH"] = SRC_DIR + (os.pathsep + env["PYTHONPATH"]
                                   if env.get("PYTHONPATH") else "")
    out = subprocess.run(cmd, env=env, capture_output=True, text=True,
                         timeout=timeout)
    assert out.returncode == 0, out.stderr[-4000:]
    if marker is None:
        return out
    lines = [l for l in out.stdout.splitlines() if l.startswith(marker)]
    assert lines, out.stdout
    return json.loads(lines[0][len(marker):])
