"""Property-testing front-end: real hypothesis when installed, else a
minimal deterministic fallback.

The test image does not always ship ``hypothesis`` (the seed suite failed at
*collection* on it).  The fallback below implements just the surface these
tests use — ``given``, ``settings``, ``st.integers/lists/sampled_from/data``
— running each property over a fixed number of seeded-random examples.  It
is intentionally dumb: no shrinking, no database, no reproduction strings —
but the properties still execute and still catch regressions.
"""
from __future__ import annotations

try:
    from hypothesis import given, settings, strategies as st  # noqa: F401
    HAVE_HYPOTHESIS = True
except ImportError:
    import random

    HAVE_HYPOTHESIS = False
    _DEFAULT_EXAMPLES = 20

    class _Strategy:
        def __init__(self, sample):
            self._sample = sample

        def example(self, rng):
            return self._sample(rng)

    class _DataStrategy:
        """Marker for ``st.data()``."""

    class _DataObject:
        def __init__(self, rng):
            self._rng = rng

        def draw(self, strategy, label=None):
            return strategy.example(self._rng)

    class _Namespace:
        @staticmethod
        def integers(min_value, max_value):
            return _Strategy(lambda rng: rng.randint(min_value, max_value))

        @staticmethod
        def sampled_from(elements):
            elements = list(elements)
            return _Strategy(lambda rng: rng.choice(elements))

        @staticmethod
        def lists(elem, min_size=0, max_size=10, unique=False):
            def sample(rng):
                n = rng.randint(min_size, max_size)
                if not unique:
                    return [elem.example(rng) for _ in range(n)]
                out = set()
                # elem domains in these tests are comfortably larger than n
                for _ in range(10000):
                    if len(out) == n:
                        break
                    out.add(elem.example(rng))
                if len(out) != n:
                    raise ValueError("could not draw enough unique elements")
                return list(out)
            return _Strategy(sample)

        @staticmethod
        def data():
            return _DataStrategy()

    st = _Namespace()

    def settings(max_examples=_DEFAULT_EXAMPLES, deadline=None, **_kw):
        def deco(fn):
            fn._max_examples = max_examples
            return fn
        return deco

    def given(*strategies):
        def deco(fn):
            def wrapper():
                n = getattr(wrapper, "_max_examples", _DEFAULT_EXAMPLES)
                for ex in range(n):
                    rng = random.Random(0xC0DE + ex)
                    args = [
                        _DataObject(rng) if isinstance(s, _DataStrategy)
                        else s.example(rng)
                        for s in strategies
                    ]
                    fn(*args)
            wrapper.__name__ = fn.__name__
            wrapper.__doc__ = fn.__doc__
            wrapper.__module__ = fn.__module__
            return wrapper
        return deco
